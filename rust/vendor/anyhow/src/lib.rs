//! Offline stand-in for the `anyhow` crate.
//!
//! The tc-stencil build environment has no crates.io access, so this
//! vendored crate implements exactly the subset of anyhow's API the
//! workspace uses: [`Error`], [`Result`], the [`anyhow!`], [`bail!`] and
//! [`ensure!`] macros, and the [`Context`] extension trait.  Semantics
//! match upstream where it matters:
//!
//! * `{}` displays the outermost message only; `{:#}` walks the whole
//!   context chain (`outer: inner: root`), which is what `stencilctl`
//!   prints on fatal errors.
//! * `Error` deliberately does NOT implement `std::error::Error`, so the
//!   blanket `From<E: std::error::Error>` impl below cannot overlap it.

use std::fmt;

/// An error chain: the outermost message first, causes after it.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn display_and_alternate() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: boom 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let v: i32 = "xyz".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn with_context_wraps() {
        let r: Result<()> = fails().with_context(|| "while testing");
        let e = r.unwrap_err();
        assert_eq!(format!("{e:#}"), "while testing: boom 42");
        assert_eq!(e.root_cause(), "boom 42");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(v: i32) -> Result<i32> {
            ensure!(v > 0, "non-positive {v}");
            Ok(v)
        }
        assert!(check(1).is_ok());
        assert!(check(-1).is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }
}

//! Cross-layer consistency: the python build path (manifest metadata,
//! produced by compile/model.py) and the rust model must agree on every
//! quantity they both compute — α, K, K^(t), and the flatten-scheme
//! sparsity S (identical operand constructions on both sides).

use tc_stencil::model::redundancy;
use tc_stencil::model::sparsity::{flatten_sparsity, Scheme};
use tc_stencil::model::stencil::StencilPattern;
use tc_stencil::runtime::manifest::{default_dir, Manifest};

/// The manifest, or None in artifact-free checkouts (each test then
/// skips: the python/rust agreement can only be checked against real
/// `make artifacts` output).  Set TC_REQUIRE_ARTIFACTS=1 to turn the
/// silent skip into a hard failure (artifact-enabled CI should).
fn manifest() -> Option<Manifest> {
    match Manifest::load(&default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            if std::env::var("TC_REQUIRE_ARTIFACTS").is_ok() {
                panic!("artifacts required but unavailable: {e:#}");
            }
            None
        }
    }
}

#[test]
fn alpha_agrees_with_python_manifest() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    for v in &m.variants {
        let p = v.pattern().unwrap();
        let ours = redundancy::alpha(&p, v.t);
        assert!(
            (ours - v.alpha).abs() < 1e-9,
            "{}: rust α={ours} python α={}",
            v.name,
            v.alpha
        );
    }
}

#[test]
fn k_counts_agree_with_python_manifest() {
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    for v in &m.variants {
        let p = v.pattern().unwrap();
        assert_eq!(p.k_points(), v.k_points, "{}", v.name);
        assert_eq!(p.fused_k_points(v.t), v.k_fused, "{}", v.name);
    }
}

#[test]
fn flatten_sparsity_agrees_with_python_operand() {
    // Both sides construct the same (Kp × NW) B operand; the measured
    // non-zero fraction must match the rust closed form exactly.
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut checked = 0;
    for v in m.variants.iter().filter(|v| v.scheme == Scheme::Flatten) {
        let p = v.pattern().unwrap();
        let ours = flatten_sparsity(&p, v.t);
        let python = v.sparsity_measured.expect("flatten has measured S");
        assert!(
            (ours - python).abs() < 1e-9,
            "{}: rust S={ours} python S={python}",
            v.name
        );
        checked += 1;
    }
    assert!(checked >= 3, "expected several flatten artifacts");
}

#[test]
fn banded_sparsity_within_band_model_tolerance() {
    // decompose/sparse24 measured S uses NT=16 bands; the rust model is
    // the same construction — require equality for 2D, and closeness for
    // 3D (lead-row enumeration is identical, so equality expected too).
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut checked = 0;
    for v in m
        .variants
        .iter()
        .filter(|v| matches!(v.scheme, Scheme::Decompose | Scheme::Sparse24))
    {
        let p = v.pattern().unwrap();
        let ours = tc_stencil::model::sparsity::decompose_sparsity(&p, v.t);
        let python = v.sparsity_measured.expect("banded has measured S");
        assert!(
            (ours - python).abs() < 1e-9,
            "{}: rust S={ours} python S={python}",
            v.name
        );
        checked += 1;
    }
    assert!(checked >= 5);
}

#[test]
fn manifest_covers_paper_evaluation_matrix() {
    // §5.1 coverage at CPU scale: both shapes, 2D+3D, f32+f64, all four
    // schemes, fusion depths including t=7 (Table 3 cases 3/4).
    let Some(m) = manifest() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let has = |f: &dyn Fn(&tc_stencil::runtime::ArtifactMeta) -> bool| {
        m.variants.iter().any(|v| f(v))
    };
    assert!(has(&|v| v.t == 7));
    assert!(has(&|v| v.d == 3));
    assert!(has(&|v| v.dtype == tc_stencil::model::perf::Dtype::F64));
    assert!(has(&|v| v.shape == tc_stencil::Shape::Star));
    for scheme in [Scheme::Direct, Scheme::Flatten, Scheme::Decompose, Scheme::Sparse24] {
        assert!(has(&|v| v.scheme == scheme), "{scheme:?}");
    }
}

//! Property tests: the native backend against the golden oracle across
//! random patterns, dims 1–3, fused depths t ∈ {1..4}, both dtypes —
//! no artifacts, no PJRT, runs in every checkout.
//!
//! f64 jobs must be BIT-IDENTICAL to the oracle (max|Δ| == 0): the
//! engine mirrors the oracle's per-point accumulation order exactly.
//! f32 jobs run genuinely in f32 and must match to rounding.

use tc_stencil::backend::{self, Backend, BackendKind, NativeBackend, TemporalMode};
use tc_stencil::coordinator::scheduler;
use tc_stencil::model::perf::Dtype;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::sim::golden;
use tc_stencil::util::prop::{forall, Config};
use tc_stencil::util::rng::Rng;

/// A randomly drawn job description (compact for shrink reports).
#[derive(Debug, Clone)]
struct Case {
    shape: Shape,
    d: usize,
    r: usize,
    t: usize,
    steps: usize,
    dtype: Dtype,
    domain: Vec<usize>,
    threads: usize,
    seed: u64,
}

fn gen_case(rng: &mut Rng) -> Case {
    let shape = if rng.f64() < 0.5 { Shape::Box } else { Shape::Star };
    let d = rng.range_usize(1, 3);
    let r = rng.range_usize(1, 2);
    let t = rng.range_usize(1, 4);
    let steps = rng.range_usize(0, 2 * t + 1); // exercises the remainder path
    let dtype = if rng.f64() < 0.5 { Dtype::F32 } else { Dtype::F64 };
    let max_side = match d {
        1 => 64,
        2 => 24,
        _ => 12,
    };
    let domain: Vec<usize> = (0..d).map(|_| rng.range_usize(1, max_side)).collect();
    Case {
        shape,
        d,
        r,
        t,
        steps,
        dtype,
        domain,
        threads: rng.range_usize(1, 4),
        seed: rng.next_u64(),
    }
}

fn random_weights(rng: &mut Rng, d: usize, r: usize, shape: Shape) -> Vec<f64> {
    // Random weights masked to the pattern's support (so star jobs carry
    // genuinely star-shaped kernels), L1-normalized so fused kernels do
    // not amplify the field (keeps the f32 rounding tolerance meaningful).
    let p = StencilPattern::new(shape, d, r).unwrap();
    let sup = p.support();
    let mut w: Vec<f64> = sup
        .cells
        .iter()
        .map(|&b| if b { rng.range_f64(-0.5, 0.5) } else { 0.0 })
        .collect();
    let l1: f64 = w.iter().map(|v| v.abs()).sum();
    if l1 > 1e-9 {
        for v in &mut w {
            *v /= l1;
        }
    }
    w
}

fn run_case(case: &Case) -> Result<(), String> {
    let mut rng = Rng::new(case.seed);
    let weights = random_weights(&mut rng, case.d, case.r, case.shape);
    let n: usize = case.domain.iter().product();
    let init: Vec<f64> = match case.dtype {
        // Pre-round f32 inputs so the oracle sees what the engine sees.
        Dtype::F32 => (0..n).map(|_| rng.normal() as f32 as f64).collect(),
        Dtype::F64 => (0..n).map(|_| rng.normal()).collect(),
    };
    let job = backend::Job {
        pattern: StencilPattern::new(case.shape, case.d, case.r).unwrap(),
        dtype: case.dtype,
        domain: case.domain.clone(),
        steps: case.steps,
        t: case.t,
        temporal: TemporalMode::Sweep,
        weights: weights.clone(),
        threads: case.threads,
    };
    let mut field = init.clone();
    let mut be = NativeBackend::new();
    scheduler::advance(&mut be, &job, &mut field).map_err(|e| format!("{e:#}"))?;

    let w = golden::Weights::new(case.d, 2 * case.r + 1, weights);
    let mut want = golden::Field::from_vec(&case.domain, init);
    for _ in 0..case.steps / case.t {
        want = golden::apply_fused(&want, &w, case.t);
    }
    for _ in 0..case.steps % case.t {
        want = golden::apply_once(&want, &w);
    }
    let got = golden::Field::from_vec(&case.domain, field);
    let err = got.max_abs_diff(&want);
    match case.dtype {
        Dtype::F64 if err != 0.0 => Err(format!("f64 not bit-identical: max|Δ|={err:.3e}")),
        Dtype::F32 if err > 2e-4 * (case.steps.max(1) as f64) => {
            Err(format!("f32 outside rounding tolerance: max|Δ|={err:.3e}"))
        }
        _ => Ok(()),
    }
}

#[test]
fn property_native_matches_oracle() {
    forall(Config::with_cases(120), gen_case, run_case).unwrap();
}

#[test]
fn property_threads_do_not_change_bits() {
    forall(
        Config { seed: 0xD1CE, ..Config::with_cases(40) },
        gen_case,
        |case| {
            let mut results: Vec<Vec<f64>> = Vec::new();
            for threads in [1usize, 5] {
                let mut rng = Rng::new(case.seed);
                let weights = random_weights(&mut rng, case.d, case.r, case.shape);
                let n: usize = case.domain.iter().product();
                let init: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let job = backend::Job {
                    pattern: StencilPattern::new(case.shape, case.d, case.r).unwrap(),
                    dtype: case.dtype,
                    domain: case.domain.clone(),
                    steps: case.steps,
                    t: case.t,
                    temporal: TemporalMode::Sweep,
                    weights,
                    threads,
                };
                let mut field = init;
                NativeBackend::new()
                    .advance(&job, &mut field)
                    .map_err(|e| format!("{e:#}"))?;
                results.push(field);
            }
            if results[0] == results[1] {
                Ok(())
            } else {
                Err("thread count changed the bits".into())
            }
        },
    )
    .unwrap();
}

#[test]
fn backend_kind_auto_resolves_to_native_without_artifacts() {
    let job = backend::Job {
        pattern: StencilPattern::new(Shape::Star, 2, 1).unwrap(),
        dtype: Dtype::F64,
        domain: vec![16, 16],
        steps: 4,
        t: 2,
        temporal: TemporalMode::Sweep,
        weights: {
            let mut w = vec![0.0; 9];
            w[4] = 0.6;
            for i in [1usize, 3, 5, 7] {
                w[i] = 0.1;
            }
            w
        },
        threads: 2,
    };
    let dir = std::path::PathBuf::from("/definitely-not-an-artifact-dir");
    let mut be = backend::create(BackendKind::Auto, &dir, &job, None).unwrap();
    assert_eq!(be.name(), "native");
    let mut field = vec![1.0; 256];
    let metrics = scheduler::advance(be.as_mut(), &job, &mut field).unwrap();
    assert_eq!(metrics.steps, 4);
    assert_eq!(metrics.launches, 2);
    assert!(metrics.throughput() > 0.0);
}

#[test]
fn capability_probe_reports_reasons() {
    let good = backend::Job {
        pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
        dtype: Dtype::F64,
        domain: vec![8, 8],
        steps: 2,
        t: 1,
        temporal: TemporalMode::Sweep,
        weights: vec![1.0 / 9.0; 9],
        threads: 1,
    };
    let native = NativeBackend::new();
    assert!(native.supports(&good).is_ok());
    let mut bad = good.clone();
    bad.weights = vec![0.0; 5];
    let why = native.supports(&bad).unwrap_err();
    assert!(why.contains("weights"), "{why}");
    let mut bad = good;
    bad.domain = vec![8];
    let why = native.supports(&bad).unwrap_err();
    assert!(why.contains("rank"), "{why}");
}

//! Sparse and variable-coefficient stencils, end to end.
//!
//! Execution: for every coefficient variant (aniso, varcoef, sparse24)
//! across shapes, dtypes, odd/prime domains, fused depths, temporal
//! realizations, and shard fan-outs, the dispatched executor
//! (`KernelMode::Auto`) must be BIT-IDENTICAL to the generic
//! offset-list loop (`KernelMode::Generic`) — and, in f64, to the
//! golden oracle (`apply_steps_varcoef` for varcoef, the standard
//! fused/sequential chains otherwise).  Modes are pinned via
//! `with_mode`, so the suite holds under any `STENCILCTL_KERNELS`
//! environment (CI runs it both ways).
//!
//! Planning: the sparsity-expanded profitable region (§4.3 — SpTC
//! doubles ℙ at unchanged S) must flip a dense-vs-sparse candidate
//! decision exactly where `model::sparsity` predicts, and the 2:4
//! pruning of the pattern itself must move a compute-bound dense job
//! back under the ridge.  The pinned constants here are machine-checked
//! by the independent Python port in python/tests/test_planner_sparse.py.

use tc_stencil::backend::kernels::KernelMode;
use tc_stencil::backend::{self, Backend, NativeBackend, TemporalMode};
use tc_stencil::coordinator::grid::{ShardPlan, ShardSpec};
use tc_stencil::coordinator::planner::{self, Request};
use tc_stencil::coordinator::scheduler;
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::{Dtype, Unit, Workload};
use tc_stencil::model::roofline::Bound;
use tc_stencil::model::stencil::{Coeffs, Shape, StencilPattern};
use tc_stencil::sim::golden;

/// Odd / prime sides so tile and interior windows never divide evenly.
fn awkward_domain(d: usize) -> Vec<usize> {
    match d {
        1 => vec![101],
        2 => vec![19, 23],
        _ => vec![7, 11, 13],
    }
}

fn advance_with(mode: KernelMode, job: &backend::Job, init: &[f64]) -> (Vec<f64>, String) {
    let mut field = init.to_vec();
    let m = NativeBackend::with_mode(mode).advance(job, &mut field).unwrap();
    (field, m.kernel)
}

/// The f64 golden oracle for a coefficient-variant job: varcoef always
/// chains modulated base steps (fused varcoef sweeps are rejected at
/// validation; the blocked path runs base steps per tile), const-weight
/// variants follow the usual fused-sweep / sequential-blocked split.
fn oracle(job: &backend::Job, init: &[f64]) -> Vec<f64> {
    let side = 2 * job.pattern.r + 1;
    let w = golden::Weights::new(job.pattern.d, side, job.weights.clone());
    let mut want = golden::Field::from_vec(&job.domain, init.to_vec());
    if job.pattern.coeffs == Coeffs::VarCoef {
        want = golden::apply_steps_varcoef(&want, &w, job.steps);
    } else if job.temporal == TemporalMode::Blocked {
        want = golden::apply_steps(&want, &w, job.steps);
    } else {
        for _ in 0..job.steps / job.t {
            want = golden::apply_fused(&want, &w, job.t);
        }
        for _ in 0..job.steps % job.t {
            want = golden::apply_once(&want, &w);
        }
    }
    want.data
}

fn assert_bits(got: &[f64], want: &[f64], label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label}: point {i}: {a} vs {b}");
    }
}

/// Deterministic non-trivial initial field (plain LCG; golden::gaussian
/// would hide sign/asymmetry mistakes behind its symmetry).
fn init_field(n: usize, seed: u64) -> Vec<f64> {
    let mut s = seed | 1;
    (0..n)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 11) as f64 / (1u64 << 53) as f64) * 2.0 - 1.0
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Tentpole sweep: specialized ≡ generic ≡ oracle across the full
// pattern × dtype × t × temporal grid (≥100 cases).
// ---------------------------------------------------------------------------

#[test]
fn coeff_variants_match_generic_and_oracle_across_the_grid() {
    let variants: Vec<StencilPattern> = [
        (Shape::Star, 1, Coeffs::Aniso),
        (Shape::Star, 1, Coeffs::VarCoef),
        (Shape::Star, 1, Coeffs::Sparse24),
        (Shape::Star, 2, Coeffs::Aniso),
        (Shape::Star, 2, Coeffs::VarCoef),
        (Shape::Star, 2, Coeffs::Sparse24),
        (Shape::Box, 2, Coeffs::Aniso),
        (Shape::Box, 2, Coeffs::VarCoef),
        (Shape::Box, 2, Coeffs::Sparse24),
        (Shape::Star, 3, Coeffs::Sparse24),
        (Shape::Box, 3, Coeffs::Sparse24),
    ]
    .iter()
    .map(|&(s, d, c)| StencilPattern::new(s, d, 1).unwrap().with_coeffs(c))
    .collect();
    let mut cases = 0usize;
    for pattern in variants {
        let domain = awkward_domain(pattern.d);
        let n: usize = domain.iter().product();
        let weights = pattern.default_weights();
        let init = init_field(n, 0xC0FFEE ^ pattern.k_points());
        for dtype in [Dtype::F32, Dtype::F64] {
            // f32 jobs quantize through f32 state; pre-round the field
            // so the oracle comparison below stays meaningful.
            let init: Vec<f64> = match dtype {
                Dtype::F32 => init.iter().map(|&v| v as f32 as f64).collect(),
                Dtype::F64 => init.clone(),
            };
            for t in 1..=4usize {
                for temporal in [TemporalMode::Sweep, TemporalMode::Blocked] {
                    if pattern.coeffs == Coeffs::VarCoef
                        && temporal == TemporalMode::Sweep
                        && t > 1
                    {
                        // fused varcoef sweeps are rejected at validation
                        continue;
                    }
                    let steps = 2 * t + 1; // whole launches plus a remainder
                    let job = backend::Job {
                        pattern,
                        dtype,
                        domain: domain.clone(),
                        steps,
                        t,
                        temporal,
                        weights: weights.clone(),
                        threads: 2,
                    };
                    let label = format!(
                        "{} {} t={t} {}",
                        pattern.label(),
                        dtype.as_str(),
                        temporal.as_str()
                    );
                    let (auto_f, auto_k) = advance_with(KernelMode::Auto, &job, &init);
                    let (gen_f, gen_k) = advance_with(KernelMode::Generic, &job, &init);
                    assert_eq!(auto_f, gen_f, "{label}: auto vs generic bits differ");
                    assert_eq!(gen_k, "generic", "{label}");
                    if dtype == Dtype::F64 {
                        assert_bits(&auto_f, &oracle(&job, &init), &label);
                    }
                    // sparse24 dispatch resolves the PRUNED arity: the
                    // kernel name carries the coeffs-suffixed shape key
                    if auto_k != "generic" && pattern.coeffs == Coeffs::Sparse24 {
                        let want = format!(
                            "{}-{}d1r-sparse24/{}/",
                            pattern.shape.as_str(),
                            pattern.d,
                            dtype.as_str()
                        );
                        assert!(auto_k.starts_with(&want), "{label}: kernel {auto_k}");
                    }
                    cases += 1;
                }
            }
        }
    }
    assert!(cases >= 100, "property grid shrank to {cases} cases");
}

// ---------------------------------------------------------------------------
// Shard plane: fan-outs stay bit-identical for the const-weight
// variants (varcoef is global-index-keyed and always runs monolithic —
// enforced by the CLI and the serve daemon, asserted in their tests).
// ---------------------------------------------------------------------------

#[test]
fn sharded_fanout_stays_bit_identical_for_sparse_and_aniso() {
    for coeffs in [Coeffs::Aniso, Coeffs::Sparse24] {
        for (shape, d) in [(Shape::Box, 2), (Shape::Star, 2), (Shape::Box, 3)] {
            let pattern = StencilPattern::new(shape, d, 1).unwrap().with_coeffs(coeffs);
            let domain = match d {
                2 => vec![29, 17],
                _ => vec![13, 7, 11],
            };
            let n: usize = domain.iter().product();
            let init = init_field(n, 0x5EED ^ pattern.k_points());
            for t in 1..=2usize {
                for shards in 2..=4usize {
                    let job = backend::Job {
                        pattern,
                        dtype: Dtype::F64,
                        domain: domain.clone(),
                        steps: 2 * t,
                        t,
                        temporal: TemporalMode::Sweep,
                        weights: pattern.default_weights(),
                        threads: 1,
                    };
                    let label =
                        format!("{} t={t} shards={shards}", pattern.label());
                    let plan =
                        ShardPlan::dim0(&domain, shards, pattern.r, t).unwrap();
                    let mut fanned = init.clone();
                    scheduler::advance_sharded(&job, &plan, &mut fanned, 2).unwrap();
                    let (mono, _) = advance_with(KernelMode::Auto, &job, &init);
                    assert_bits(&fanned, &mono, &label);
                    assert_bits(&fanned, &oracle(&job, &init), &label);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// ARITIES-miss fallback: arbitrary user weight sets — including
// degenerate all-zero and single-tap patterns — must fall back to the
// generic loop cleanly (no panic) and stay correct.
// ---------------------------------------------------------------------------

#[test]
fn arities_miss_falls_back_cleanly_for_arbitrary_weight_sets() {
    let pattern = StencilPattern::new(Shape::Box, 2, 1).unwrap();
    let domain = vec![17, 19];
    let n: usize = domain.iter().product();
    let init = init_field(n, 0xFA11);
    // live-tap counts off the registered ARITIES table (8), on it via a
    // shape the registry never specialized (3 on a box), and degenerate
    // (0 = all-zero stencil, 1 = single off-center tap).
    let sets: Vec<(usize, Vec<f64>)> = vec![
        (8, {
            let mut w = vec![0.125; 9];
            w[4] = 0.0; // drop the center: 8 taps, not in ARITIES
            w
        }),
        (3, vec![0.0, 0.5, 0.0, 0.25, 0.0, 0.0, 0.0, 0.25, 0.0]),
        (0, vec![0.0; 9]),
        (1, {
            let mut w = vec![0.0; 9];
            w[2] = 1.0; // single corner tap
            w
        }),
    ];
    for (nnz, weights) in sets {
        for temporal in [TemporalMode::Sweep, TemporalMode::Blocked] {
            let job = backend::Job {
                pattern,
                dtype: Dtype::F64,
                domain: domain.clone(),
                steps: 3,
                t: 2,
                temporal,
                weights: weights.clone(),
                threads: 2,
            };
            let label = format!("nnz={nnz} {}", temporal.as_str());
            let (auto_f, _) = advance_with(KernelMode::Auto, &job, &init);
            let (gen_f, _) = advance_with(KernelMode::Generic, &job, &init);
            assert_eq!(auto_f, gen_f, "{label}: auto vs generic bits differ");
            assert_bits(&auto_f, &oracle(&job, &init), &label);
        }
    }
}

// ---------------------------------------------------------------------------
// Planner: the sparsity-expanded region and the pruned-pattern flip.
// ---------------------------------------------------------------------------

fn plan_req(coeffs: Coeffs, dtype: Dtype, max_t: usize, temporal: TemporalMode) -> Request {
    Request {
        pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap().with_coeffs(coeffs),
        dtype,
        domain: vec![256, 256],
        steps: 64,
        gpu: Gpu::a100(),
        backend: backend::BackendKind::Auto,
        max_t,
        temporal,
        shards: ShardSpec::Fixed(1),
        lanes: 1,
        threads: 1,
        kernels: KernelMode::Auto,
        kernel_peaks: Vec::new(),
    }
}

fn engines_of(plan: &planner::Plan) -> Vec<&'static str> {
    let mut names: Vec<&'static str> = std::iter::once(&plan.chosen)
        .chain(plan.alternatives.iter())
        .map(|c| c.engine.name)
        .collect();
    names.sort_unstable();
    names.dedup();
    names
}

/// §4.3: doubled SpTC ℙ at unchanged S expands the profitable region —
/// on A100/f32 the dense box-2d1r crosses from ConvStencil (TC) to
/// SPIDER (SpTC) exactly between max_t 6 and 7.  The same constants are
/// machine-checked by python/tests/test_planner_sparse.py.
#[test]
fn sparsity_expanded_region_flips_dense_tc_to_sptc_at_depth_seven() {
    let at6 = planner::plan(&plan_req(Coeffs::Const, Dtype::F32, 6, TemporalMode::Auto), None)
        .unwrap();
    assert_eq!(at6.chosen.engine.name, "ConvStencil", "max_t=6 stays dense TC");
    assert_eq!(at6.chosen.t, 6);
    for mt in [7usize, 8] {
        let p = planner::plan(&plan_req(Coeffs::Const, Dtype::F32, mt, TemporalMode::Auto), None)
            .unwrap();
        assert_eq!(p.chosen.engine.name, "SPIDER", "max_t={mt} crosses into SpTC");
        assert_eq!(p.chosen.engine.unit, Unit::SparseTensorCore);
        assert_eq!(p.chosen.t, mt);
        assert_eq!(p.chosen.temporal, TemporalMode::Sweep);
    }
}

/// The 2:4-pruned pattern halves K (9→5 taps) and drops the blocked
/// intensity t·K/D back under the CUDA ridge at t=8 (I = 10.00 <
/// 10.08): the dense job's SpTC winner gives way to a memory-bound
/// scalar EBISU plan whose throughput the roofline pins exactly.
#[test]
fn pruned_pattern_flips_the_dense_sptc_choice_back_to_scalar() {
    let p = planner::plan(&plan_req(Coeffs::Sparse24, Dtype::F32, 8, TemporalMode::Auto), None)
        .unwrap();
    assert_eq!(p.chosen.engine.name, "EBISU");
    assert_eq!(p.chosen.t, 8);
    assert_eq!(p.chosen.temporal, TemporalMode::Blocked);
    assert_eq!(p.chosen.prediction.bound, Bound::Memory);
    // pruned intensity: t·K_eff/D = 8·5/4 = 10.00 exactly
    assert_eq!(p.chosen.prediction.intensity, 10.0);
    // memory-bound blocked throughput: η_mem·𝔹·I / (2·K_eff)
    let want = 0.72 * (1.935e12 * 10.0) / (2.0 * 5.0);
    let got = p.chosen.prediction.throughput;
    assert!(
        (got / want - 1.0).abs() < 1e-12,
        "throughput {got:.6e} vs pinned {want:.6e}"
    );
    // ...and the dense pattern at the same depth is NOT memory-bound on
    // the scalar path (I = 8·9/4 = 18 > ridge 10.08): pruning alone
    // moved the job across the ridge.
    let roof = Gpu::a100().roof(Unit::CudaCore, Dtype::F32).unwrap();
    let dense = Workload::new(StencilPattern::new(Shape::Box, 2, 1).unwrap(), 8, Dtype::F32);
    assert!(dense.intensity_cuda() > roof.ridge());
    assert!(10.0 < roof.ridge());
}

/// Candidate admission per coefficient variant: sparse24 keeps SpTC
/// engines and drops dense-TC ones; varcoef is scalar-only.
#[test]
fn candidate_sets_respect_the_coeff_variant() {
    let sparse =
        planner::plan(&plan_req(Coeffs::Sparse24, Dtype::F32, 8, TemporalMode::Auto), None)
            .unwrap();
    let names = engines_of(&sparse);
    assert!(names.contains(&"SPIDER"), "{names:?}");
    assert!(names.contains(&"SparStencil"), "{names:?}");
    for dense_tc in ["TCStencil", "ConvStencil", "LoRAStencil"] {
        assert!(!names.contains(&dense_tc), "{dense_tc} priced for a 2:4 pattern");
    }
    let var = planner::plan(&plan_req(Coeffs::VarCoef, Dtype::F32, 8, TemporalMode::Auto), None)
        .unwrap();
    for c in std::iter::once(&var.chosen).chain(var.alternatives.iter()) {
        assert_eq!(c.engine.unit, Unit::CudaCore, "{} priced for varcoef", c.engine.name);
        if c.temporal == TemporalMode::Sweep {
            assert_eq!(c.t, 1, "fused varcoef sweep candidate {}", c.engine.name);
        }
    }
}

/// The coefficient axis is part of the plan identity: same geometry,
/// different coeffs, different `PlanKey`.
#[test]
fn plan_key_carries_the_coeffs_axis() {
    let base = plan_req(Coeffs::Const, Dtype::F32, 8, TemporalMode::Auto);
    let sparse = plan_req(Coeffs::Sparse24, Dtype::F32, 8, TemporalMode::Auto);
    let var = plan_req(Coeffs::VarCoef, Dtype::F32, 8, TemporalMode::Auto);
    let keys = [
        base.plan_key().canonical(),
        sparse.plan_key().canonical(),
        var.plan_key().canonical(),
    ];
    assert_ne!(keys[0], keys[1]);
    assert_ne!(keys[0], keys[2]);
    assert_ne!(keys[1], keys[2]);
    assert!(keys[1].contains("sparse24"), "{}", keys[1]);
}

/// The effective-count plumbing the planner prices with: 2:4 pruning of
/// box-2d1r keeps {(-1,-1),(-1,0),(0,0),(0,1),(1,1)} — 5 taps — and the
/// fused pruned support grows as the Minkowski powers 5,12,22,35,…
#[test]
fn effective_counts_match_the_hand_derived_pruning() {
    let b = StencilPattern::new(Shape::Box, 2, 1).unwrap().with_coeffs(Coeffs::Sparse24);
    assert_eq!(b.effective_k_points(), 5);
    let fused: Vec<u64> = (1..=8).map(|t| b.fused_effective_k_points(t)).collect();
    assert_eq!(fused, vec![5, 12, 22, 35, 51, 70, 92, 117]);
    // α_eff(8) = 117/(8·5) = 2.925 < dense α(8) = 289/72 ≈ 4.014
    let w = Workload::new(b, 8, Dtype::F32);
    assert!((w.alpha() - 2.925).abs() < 1e-12);
    let s = StencilPattern::new(Shape::Star, 2, 1).unwrap().with_coeffs(Coeffs::Sparse24);
    assert_eq!(s.effective_k_points(), 4);
    // const-weight patterns keep the geometric counts
    let dense = StencilPattern::new(Shape::Box, 2, 1).unwrap();
    assert_eq!(dense.effective_k_points(), 9);
    assert_eq!(dense.fused_effective_k_points(2), 25);
}

//! The specialized kernel registry, end to end: for EVERY registered
//! shape specialization (star-1/2/3D, box-2/3D at r=1), both dtypes,
//! fused depths, and both temporal realizations, the dispatched
//! (`KernelMode::Auto`) executor must be BIT-IDENTICAL to the generic
//! offset-list loop (`KernelMode::Generic`) — and, in f64, to the
//! golden oracle.  The modes are pinned via `with_mode`, so this suite
//! holds under any `STENCILCTL_KERNELS` environment (CI runs it both
//! ways).  The planner side closes the loop: a machine profile carrying
//! per-kernel measured ℙ entries must be able to flip a sweep↔blocked
//! decision that the flat profile resolves the other way, while
//! `--kernels generic` reproduces flat planning exactly.

use tc_stencil::backend::kernels::{self, KernelMode, KernelPeak};
use tc_stencil::backend::{self, Backend, NativeBackend, TemporalMode};
use tc_stencil::coordinator::metrics::RunMetrics;
use tc_stencil::coordinator::planner::{self, Request};
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::{Dtype, Unit, Workload};
use tc_stencil::model::stencil::StencilPattern;
use tc_stencil::sim::golden;
use tc_stencil::util::rng::Rng;

/// Odd / prime sides so tile and interior windows never divide evenly.
fn awkward_domain(d: usize) -> Vec<usize> {
    match d {
        1 => vec![101],
        2 => vec![23, 29],
        _ => vec![11, 13, 17],
    }
}

/// Deterministic non-uniform weights over the pattern's support —
/// uniform taps would hide accumulation-order mistakes behind symmetry.
fn varied_weights(pattern: &StencilPattern, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    let sup = pattern.support();
    let mut w: Vec<f64> = sup
        .cells
        .iter()
        .map(|&b| if b { rng.range_f64(-0.5, 0.5) } else { 0.0 })
        .collect();
    let l1: f64 = w.iter().map(|v| v.abs()).sum();
    if l1 > 1e-9 {
        for v in &mut w {
            *v /= l1;
        }
    }
    w
}

fn advance_with(mode: KernelMode, job: &backend::Job, init: &[f64]) -> (Vec<f64>, RunMetrics) {
    let mut field = init.to_vec();
    let m = NativeBackend::with_mode(mode).advance(job, &mut field).unwrap();
    (field, m)
}

#[test]
fn every_registered_kernel_matches_generic_and_oracle() {
    let mut specialized_seen = 0usize;
    for pattern in kernels::probe_shapes() {
        let domain = awkward_domain(pattern.d);
        let n: usize = domain.iter().product();
        let weights = varied_weights(&pattern, 0xD15);
        let mut rng = Rng::new(0x5EED ^ pattern.k_points());
        for dtype in [Dtype::F32, Dtype::F64] {
            let init: Vec<f64> = match dtype {
                Dtype::F32 => (0..n).map(|_| rng.normal() as f32 as f64).collect(),
                Dtype::F64 => (0..n).map(|_| rng.normal()).collect(),
            };
            for t in 1..=4usize {
                for temporal in [TemporalMode::Sweep, TemporalMode::Blocked] {
                    let steps = 2 * t + 1; // whole launches plus a remainder
                    let job = backend::Job {
                        pattern,
                        dtype,
                        domain: domain.clone(),
                        steps,
                        t,
                        temporal,
                        weights: weights.clone(),
                        threads: 2,
                    };
                    let label = format!(
                        "{} {} t={t} {}",
                        kernels::shape_key(&pattern),
                        dtype.as_str(),
                        temporal.as_str()
                    );
                    let (auto_f, auto_m) = advance_with(KernelMode::Auto, &job, &init);
                    let (gen_f, gen_m) = advance_with(KernelMode::Generic, &job, &init);
                    // Dispatch must never change a single bit — in
                    // EITHER dtype: the specialized kernels keep the
                    // generic loop's per-point accumulation order.
                    assert_eq!(auto_f, gen_f, "{label}: auto vs generic bits differ");
                    // The forced-generic path must resolve no kernel.
                    assert_eq!(gen_m.kernel, "generic", "{label}");
                    if auto_m.kernel != "generic" {
                        let prefix =
                            format!("{}/{}/", kernels::shape_key(&pattern), dtype.as_str());
                        assert!(
                            auto_m.kernel.starts_with(&prefix),
                            "{label}: kernel name {:?} lacks prefix {prefix:?}",
                            auto_m.kernel
                        );
                        specialized_seen += 1;
                    }
                    // Coverage accounting is pure geometry — identical
                    // across modes, and non-empty for a real run.
                    assert_eq!(
                        (auto_m.interior_points, auto_m.boundary_points),
                        (gen_m.interior_points, gen_m.boundary_points),
                        "{label}: coverage split diverged across modes"
                    );
                    assert!(
                        auto_m.interior_points + auto_m.boundary_points > 0,
                        "{label}: empty coverage counters"
                    );
                    // f64 must be bit-identical to the golden oracle.
                    if dtype == Dtype::F64 {
                        let w = golden::Weights::new(
                            pattern.d,
                            2 * pattern.r + 1,
                            weights.clone(),
                        );
                        let start = golden::Field::from_vec(&domain, init.clone());
                        let want = if temporal == TemporalMode::Blocked {
                            golden::apply_steps(&start, &w, steps)
                        } else {
                            let mut f = start;
                            for _ in 0..steps / t {
                                f = golden::apply_fused(&f, &w, t);
                            }
                            for _ in 0..steps % t {
                                f = golden::apply_once(&f, &w);
                            }
                            f
                        };
                        let got = golden::Field::from_vec(&domain, auto_f.clone());
                        let err = got.max_abs_diff(&want);
                        assert_eq!(err, 0.0, "{label}: f64 drifted from oracle by {err:.3e}");
                    }
                }
            }
        }
    }
    // The sweep is vacuous if dispatch never actually resolved a
    // specialized kernel (base arities are registered on every ISA via
    // the portable tier, so t=1 at least must hit).
    assert!(specialized_seen >= 10, "only {specialized_seen} specialized runs resolved");
}

#[test]
fn interior_dominated_run_reports_fast_path_coverage() {
    let pattern = StencilPattern::new(tc_stencil::model::stencil::Shape::Star, 2, 1).unwrap();
    let job = backend::Job {
        pattern,
        dtype: Dtype::F64,
        domain: vec![128, 128],
        steps: 4,
        t: 1,
        temporal: TemporalMode::Sweep,
        weights: pattern.uniform_weights(),
        threads: 2,
    };
    let mut field = golden::gaussian(&[128, 128]);
    let m = NativeBackend::with_mode(KernelMode::Auto).advance(&job, &mut field).unwrap();
    // 126² interior rows/cols of 128² per step → ~96.9% fast path.
    assert!(
        m.interior_fraction() > 0.9,
        "interior fraction {:.3} too low for a 128² domain",
        m.interior_fraction()
    );
    let total = m.interior_points + m.boundary_points;
    assert_eq!(total, (128 * 128 * 4) as u64, "coverage must account every point");
    assert!(
        m.kernel.starts_with("star-2d1r/double/"),
        "resolved kernel {:?} — star-2d1r is registered on every ISA tier",
        m.kernel
    );
}

#[test]
fn per_kernel_peaks_flip_planner_temporal_decision() {
    // Box-2D1R f32 on V100 (no tensor units — the scalar pair decides).
    // At t=8 the fused-sweep intensity sits far above the CUDA ridge,
    // so flat planning resolves depth-8 to BLOCKED (the temporal rule
    // proven in rust/tests/temporal_blocking.rs).  A measured profile
    // whose blocked box-2d1r kernel is catastrophically slow must flip
    // that same depth to SWEEP — and flip the overall plan with it.
    let gpu = Gpu::v100();
    let pattern = StencilPattern::new(tc_stencil::model::stencil::Shape::Box, 2, 1).unwrap();
    let req = |kernels_mode: KernelMode, peaks: Vec<KernelPeak>| Request {
        pattern,
        dtype: Dtype::F32,
        domain: vec![256, 256],
        steps: 64,
        gpu: gpu.clone(),
        backend: backend::BackendKind::Native,
        max_t: 8,
        temporal: TemporalMode::Auto,
        shards: tc_stencil::coordinator::grid::ShardSpec::Fixed(1),
        lanes: 1,
        threads: 1,
        kernels: kernels_mode,
        kernel_peaks: peaks,
    };
    // Premise: depth 8 is past the machine balance point.
    let roof = gpu.roof(Unit::CudaCore, Dtype::F32).unwrap();
    let w = Workload::new(pattern, 8, Dtype::F32);
    assert!(
        w.intensity_fused_sweep() >= roof.ridge(),
        "premise broken: fused I {:.2} below ridge {:.2}",
        w.intensity_fused_sweep(),
        roof.ridge()
    );
    let best_at_8 = |plan: &planner::Plan| {
        std::iter::once(&plan.chosen)
            .chain(plan.alternatives.iter())
            .find(|c| c.t == 8)
            .cloned()
            .unwrap()
    };
    let flat = planner::plan(&req(KernelMode::Auto, Vec::new()), None).unwrap();
    assert_eq!(best_at_8(&flat).temporal, TemporalMode::Blocked, "flat depth-8 is blocked");

    // The measured profile: the blocked box-2d1r f32 kernel barely
    // moves.  Every blocked scalar candidate (base arity 9, registered)
    // reprices against ℙ = 1 kFLOP/s; sweep candidates keep flat ℙ.
    let crushed = vec![KernelPeak {
        shape: "box-2d1r".to_string(),
        dtype: Dtype::F32,
        blocked: true,
        flops: 1e3,
    }];
    let tuned = planner::plan(&req(KernelMode::Auto, crushed.clone()), None).unwrap();
    assert_eq!(
        best_at_8(&tuned).temporal,
        TemporalMode::Sweep,
        "per-kernel ℙ must flip depth 8 blocked -> sweep"
    );
    assert_eq!(tuned.chosen.temporal, TemporalMode::Sweep, "and the overall plan with it");
    assert!(
        best_at_8(&tuned).prediction.throughput < best_at_8(&flat).prediction.throughput,
        "the repriced depth must predict slower than flat"
    );

    // --kernels generic ignores the measured peaks entirely: planning
    // is bit-identical to the flat profile, crushed entries and all.
    let generic = planner::plan(&req(KernelMode::Generic, crushed), None).unwrap();
    assert_eq!(generic.chosen.temporal, flat.chosen.temporal);
    assert_eq!(generic.chosen.t, flat.chosen.t);
    assert_eq!(
        generic.chosen.prediction.throughput.to_bits(),
        flat.chosen.prediction.throughput.to_bits(),
        "generic-mode planning must reproduce flat predictions bit-exactly"
    );
}

//! Service-layer integration: the daemon driven over `--stdio` (real
//! subprocess) and over a localhost socket with two concurrent clients —
//! session reuse, plan-cache hit counters, model-guided admission
//! rejection, and f64 results bit-identical to `sim::golden` after a
//! multi-request streamed run.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::Arc;

use tc_stencil::service::protocol;
use tc_stencil::service::server::{serve_listener, ServeOpts, Service, ServiceState};
use tc_stencil::sim::golden;
use tc_stencil::util::json::Json;

fn test_opts() -> ServeOpts {
    ServeOpts {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
        ..Default::default()
    }
}

/// A line-oriented protocol client over one TCP connection.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { reader, writer: stream }
    }

    fn req(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").expect("write request");
        self.writer.flush().expect("flush");
        let mut resp = String::new();
        self.reader.read_line(&mut resp).expect("read response");
        Json::parse_line(&resp).expect("parse response")
    }

    fn req_ok(&mut self, line: &str) -> Json {
        let j = self.req(line);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j}");
        j
    }
}

fn spawn_server(opts: ServeOpts) -> (Service, std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let svc = Service::start(opts);
    let (listener, addr) = svc.bind().expect("bind ephemeral port");
    let state: Arc<ServiceState> = svc.state();
    let handle = std::thread::spawn(move || {
        serve_listener(state, listener).expect("serve_listener");
    });
    (svc, addr, handle)
}

/// The golden replay of one streamed session: gaussian init, then
/// `advances` × (steps/t fused launches + steps%t single steps).
fn golden_replay(
    domain: &[usize],
    weights: &[f64],
    advances: usize,
    steps: usize,
    t: usize,
) -> Vec<f64> {
    let w = golden::Weights::new(domain.len(), 3, weights.to_vec());
    let mut f = golden::Field::from_vec(domain, golden::gaussian(domain));
    for _ in 0..advances {
        for _ in 0..steps / t {
            f = golden::apply_fused(&f, &w, t);
        }
        for _ in 0..steps % t {
            f = golden::apply_once(&f, &w);
        }
    }
    f.data
}

#[test]
fn tcp_two_concurrent_clients_sessions_cache_and_bit_identity() {
    let (mut svc, addr, handle) = spawn_server(test_opts());
    let create = |name: &str| {
        format!(
            r#"{{"op":"create_session","session":"{name}","shape":"star","d":2,"r":1,
                "dtype":"double","domain":[24,24],"backend":"native","threads":2}}"#
        )
        .replace('\n', " ")
    };
    let advances: usize = 3;
    let clients: Vec<_> = ["c1", "c2"]
        .iter()
        .map(|name| {
            let name = name.to_string();
            let create = create(&name);
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                c.req_ok(&create);
                for _ in 0..advances {
                    let a = c.req_ok(&format!(
                        r#"{{"op":"advance","session":"{name}","steps":2,"t":2}}"#
                    ));
                    assert_eq!(a.get("t").unwrap().as_usize(), Some(2));
                }
                let f = c.req_ok(&format!(
                    r#"{{"op":"fetch","session":"{name}","encoding":"hex"}}"#
                ));
                protocol::decode_field(f.get("field").unwrap()).unwrap()
            })
        })
        .collect();
    let fields: Vec<Vec<f64>> = clients.into_iter().map(|h| h.join().expect("client")).collect();

    // Both sessions saw the same streamed workload: bit-identical to the
    // golden oracle replay, and to each other.
    let pattern = tc_stencil::model::stencil::StencilPattern::new(
        tc_stencil::model::stencil::Shape::Star,
        2,
        1,
    )
    .unwrap();
    let want = golden_replay(&[24, 24], &pattern.uniform_weights(), advances, 2, 2);
    for (ci, got) in fields.iter().enumerate() {
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "client {ci} point {i}: {a} vs golden {b}"
            );
        }
    }

    // A third connection reads aggregate stats: both sessions live, all
    // jobs completed, and the second identical workload hit the cache.
    let mut c = Client::connect(addr);
    let st = c.req_ok(r#"{"op":"stats"}"#);
    assert_eq!(st.get("sessions").unwrap().as_usize(), Some(2));
    assert_eq!(st.get("jobs_completed").unwrap().as_usize(), Some(2 * advances));
    assert_eq!(st.get("jobs_failed").unwrap().as_usize(), Some(0));
    let hits = st.get("plan_hits").unwrap().as_i64().unwrap();
    assert!(hits > 0, "identical workloads must hit the plan cache (hits={hits})");
    let rows = st.get("session_stats").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 2);
    for row in rows {
        assert_eq!(row.get("jobs").unwrap().as_usize(), Some(advances));
        assert_eq!(row.get("steps").unwrap().as_usize(), Some(2 * advances));
    }

    // Shutdown ends the accept loop; everything joins cleanly.
    let sd = c.req_ok(r#"{"op":"shutdown"}"#);
    assert_eq!(sd.get("op").unwrap().as_str(), Some("shutdown"));
    handle.join().expect("listener thread");
    svc.shutdown();
}

#[test]
fn tcp_admission_rejects_over_budget_with_classification() {
    let mut opts = test_opts();
    opts.budget_ms = Some(0.0); // predicted runtime is always > 0
    let (mut svc, addr, handle) = spawn_server(opts);
    let mut c = Client::connect(addr);
    c.req_ok(
        r#"{"op":"create_session","session":"rj","shape":"box","d":2,"r":1,"dtype":"float","domain":[16,16],"backend":"native"}"#,
    );
    let rej = c.req(r#"{"op":"advance","session":"rj","steps":4}"#);
    assert_eq!(rej.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(rej.get("error").unwrap().as_str(), Some("admission"));
    assert!(rej.get("predicted_ms").unwrap().as_f64().unwrap() > 0.0);
    let class = rej.get("classification").unwrap().as_str().unwrap().to_string();
    assert!(
        class.contains("Scenario") || class.contains("bound"),
        "refusal must cite the paper's classification: {class}"
    );
    // the session is untouched: fetch still returns the gaussian init
    let f = c.req_ok(r#"{"op":"fetch","session":"rj","encoding":"hex"}"#);
    let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
    let want = golden::gaussian(&[16, 16]);
    assert_eq!(got.len(), want.len());
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let st = c.req_ok(r#"{"op":"stats"}"#);
    assert!(st.get("jobs_rejected").unwrap().as_i64().unwrap() >= 1);
    assert_eq!(st.get("jobs_completed").unwrap().as_usize(), Some(0));
    c.req_ok(r#"{"op":"shutdown"}"#);
    handle.join().expect("listener thread");
    svc.shutdown();
}

#[test]
fn stdio_subprocess_serves_the_full_protocol() {
    let exe = env!("CARGO_BIN_EXE_stencilctl");
    let mut child = Command::new(exe)
        .args(["serve", "--stdio", "--workers", "1", "--artifacts", "/nonexistent-artifacts"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn stencilctl serve --stdio");
    let requests = [
        r#"{"op":"ping"}"#.to_string(),
        r#"{"op":"plan","shape":"box","d":2,"r":1,"dtype":"float","steps":8}"#.to_string(),
        r#"{"op":"plan","shape":"box","d":2,"r":1,"dtype":"float","steps":8}"#.to_string(),
        r#"{"op":"create_session","session":"s","shape":"star","d":2,"r":1,"dtype":"double","domain":[8,8],"backend":"native","threads":1}"#.to_string(),
        r#"{"op":"advance","session":"s","steps":2,"t":1}"#.to_string(),
        r#"{"op":"fetch","session":"s","encoding":"hex"}"#.to_string(),
        r#"{"op":"stats"}"#.to_string(),
        r#"{"op":"shutdown"}"#.to_string(),
    ];
    {
        let mut stdin = child.stdin.take().expect("stdin");
        for r in &requests {
            writeln!(stdin, "{r}").expect("write request");
        }
        // dropping stdin closes the pipe (EOF after the shutdown line)
    }
    let stdout = child.stdout.take().expect("stdout");
    let responses: Vec<Json> = BufReader::new(stdout)
        .lines()
        .map(|l| Json::parse_line(&l.expect("read line")).expect("parse response"))
        .collect();
    assert_eq!(responses.len(), requests.len());
    for (i, j) in responses.iter().enumerate() {
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "response {i}: {j}");
    }
    assert_eq!(responses[1].get("cache").unwrap().as_str(), Some("miss"));
    assert_eq!(responses[2].get("cache").unwrap().as_str(), Some("hit"));
    // the streamed session matches the golden oracle bit-for-bit
    let got = protocol::decode_field(responses[5].get("field").unwrap()).unwrap();
    let pattern = tc_stencil::model::stencil::StencilPattern::new(
        tc_stencil::model::stencil::Shape::Star,
        2,
        1,
    )
    .unwrap();
    let want = golden_replay(&[8, 8], &pattern.uniform_weights(), 1, 2, 1);
    for (a, b) in got.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert!(responses[6].get("plan_hits").unwrap().as_i64().unwrap() >= 1);
    assert_eq!(responses[6].get("jobs_completed").unwrap().as_usize(), Some(1));
    let status = child.wait().expect("wait for child");
    assert!(status.success(), "daemon must exit cleanly after shutdown: {status:?}");
}

//! Multi-tenant serving integration: PlanKey-coalesced batch dispatch
//! (N concurrent identical jobs share ONE plan-cache lookup and stay
//! bit-identical to unbatched execution), bit-exact session tiering
//! mid-session under a resident-bytes cap, deficit-round-robin fairness
//! convergence, and EDF deadline refusals carrying roofline evidence.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

use tc_stencil::service::admission::{TenantSched, TenantVerdict};
use tc_stencil::service::protocol;
use tc_stencil::service::server::{serve_listener, ServeOpts, Service, ServiceState};
use tc_stencil::sim::golden;
use tc_stencil::util::json::Json;

fn test_opts() -> ServeOpts {
    ServeOpts {
        addr: "127.0.0.1:0".to_string(),
        workers: 2,
        artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
        ..Default::default()
    }
}

/// A line-oriented protocol client over one TCP connection.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { reader, writer: stream }
    }

    fn req(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{}", line.replace('\n', " ")).expect("write request");
        self.writer.flush().expect("flush");
        let mut resp = String::new();
        self.reader.read_line(&mut resp).expect("read response");
        Json::parse_line(&resp).expect("parse response")
    }

    fn req_ok(&mut self, line: &str) -> Json {
        let j = self.req(line);
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j}");
        j
    }
}

fn spawn_server(opts: ServeOpts) -> (Service, std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let svc = Service::start(opts);
    let (listener, addr) = svc.bind().expect("bind ephemeral port");
    let state: Arc<ServiceState> = svc.state();
    let handle = std::thread::spawn(move || {
        serve_listener(state, listener).expect("serve_listener");
    });
    (svc, addr, handle)
}

/// Golden replay of one streamed session: gaussian init, then
/// `advances` × (steps/t fused launches + steps%t single steps).
fn golden_replay(
    domain: &[usize],
    weights: &[f64],
    advances: usize,
    steps: usize,
    t: usize,
) -> Vec<f64> {
    let w = golden::Weights::new(domain.len(), 3, weights.to_vec());
    let mut f = golden::Field::from_vec(domain, golden::gaussian(domain));
    for _ in 0..advances {
        for _ in 0..steps / t {
            f = golden::apply_fused(&f, &w, t);
        }
        for _ in 0..steps % t {
            f = golden::apply_once(&f, &w);
        }
    }
    f.data
}

fn assert_bits(got: &[f64], want: &[f64], tag: &str) {
    assert_eq!(got.len(), want.len(), "{tag}: length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{tag} point {i}: {a} vs {b}");
    }
}

fn star_weights() -> Vec<f64> {
    tc_stencil::model::stencil::StencilPattern::new(tc_stencil::model::stencil::Shape::Star, 2, 1)
        .unwrap()
        .uniform_weights()
}

/// N concurrent identical-PlanKey advances coalesce into ONE batched
/// dispatch: exactly one plan-cache lookup for the whole cohort, every
/// member's reply stamped with the batch size, and the fields
/// bit-identical to the same workload run unbatched.
#[test]
fn coalesced_batch_shares_one_plan_lookup_and_stays_bit_identical() {
    const N: usize = 3;
    let mut opts = test_opts();
    opts.batch_window_ms = 600.0; // generous gather window: no flakes
    let (mut svc, addr, handle) = spawn_server(opts);
    let create = |name: &str, tenant: &str| {
        format!(
            r#"{{"op":"create_session","session":"{name}","shape":"star","d":2,"r":1,
                "dtype":"double","domain":[20,20],"backend":"native","threads":2,
                "shards":1,"tenant":"{tenant}"}}"#
        )
    };
    {
        let mut c = Client::connect(addr);
        for i in 0..N {
            c.req_ok(&create(&format!("s{i}"), &format!("tenant{i}")));
        }
    }
    // N clients fire the same advance simultaneously; the leader's
    // gather window collects all of them into one batch.
    let threads: Vec<_> = (0..N)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let a = c.req_ok(&format!(r#"{{"op":"advance","session":"s{i}","steps":4,"t":2}}"#));
                let batched = a.get("batched").unwrap().as_usize().unwrap();
                let f = c.req_ok(&format!(r#"{{"op":"fetch","session":"s{i}","encoding":"hex"}}"#));
                (batched, protocol::decode_field(f.get("field").unwrap()).unwrap())
            })
        })
        .collect();
    let results: Vec<(usize, Vec<f64>)> =
        threads.into_iter().map(|h| h.join().expect("client")).collect();

    for (i, (batched, _)) in results.iter().enumerate() {
        assert_eq!(*batched, N, "member {i} must see the full batch");
    }
    // Bit-identity: golden oracle replay == every batched member.
    let want = golden_replay(&[20, 20], &star_weights(), 1, 4, 2);
    for (i, (_, got)) in results.iter().enumerate() {
        assert_bits(got, &want, &format!("batched member {i}"));
    }

    let mut c = Client::connect(addr);
    let st = c.req_ok(r#"{"op":"stats"}"#);
    assert_eq!(st.get("jobs_completed").unwrap().as_usize(), Some(N));
    assert_eq!(st.get("batches").unwrap().as_usize(), Some(1), "{st}");
    assert_eq!(st.get("jobs_batched").unwrap().as_usize(), Some(N));
    // THE acceptance assertion: one lookup amortized over N jobs.
    assert_eq!(st.get("plan_misses").unwrap().as_usize(), Some(1), "{st}");
    assert_eq!(st.get("plan_hits").unwrap().as_usize(), Some(0), "{st}");
    // every tenant's row shows exactly its own admitted job
    let rows = st.get("tenants").unwrap().as_arr().unwrap();
    for i in 0..N {
        let t = format!("tenant{i}");
        let row =
            rows.iter().find(|r| r.get("tenant").unwrap().as_str() == Some(t.as_str())).unwrap();
        assert_eq!(row.get("admitted").unwrap().as_usize(), Some(1));
        assert_eq!(row.get("refused").unwrap().as_usize(), Some(0));
    }
    c.req_ok(r#"{"op":"shutdown"}"#);
    handle.join().expect("listener thread");
    svc.shutdown();

    // The same workload on an unbatched server (window 0, sequential
    // client): N plan lookups instead of 1, but bit-identical fields.
    let (mut svc2, addr2, handle2) = spawn_server(test_opts());
    let mut c = Client::connect(addr2);
    for i in 0..N {
        c.req_ok(&create(&format!("s{i}"), &format!("tenant{i}")));
        c.req_ok(&format!(r#"{{"op":"advance","session":"s{i}","steps":4,"t":2}}"#));
        let f = c.req_ok(&format!(r#"{{"op":"fetch","session":"s{i}","encoding":"hex"}}"#));
        let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
        assert_bits(&got, &results[i].1, &format!("unbatched vs batched s{i}"));
    }
    let st = c.req_ok(r#"{"op":"stats"}"#);
    assert_eq!(st.get("batches").unwrap().as_usize(), Some(0));
    assert_eq!(st.get("plan_misses").unwrap().as_usize(), Some(1));
    assert_eq!(st.get("plan_hits").unwrap().as_usize(), Some(N - 1), "sequential reuse hits");
    c.req_ok(r#"{"op":"shutdown"}"#);
    handle2.join().expect("listener thread");
    svc2.shutdown();
}

/// Sharded fan-out and temporal blocking under a batching server: the
/// sharded path settles out of the gate and fans out as before, the
/// blocked path keeps sequential-stepping semantics — both bit-exact.
#[test]
fn sharded_and_blocked_stay_bit_exact_under_batching() {
    let mut opts = test_opts();
    opts.batch_window_ms = 300.0;
    let (mut svc, addr, handle) = spawn_server(opts);
    // two concurrent sharded advances (threads=1 vs 2 workers → the
    // planner picks a 2-shard fan-out; identical PlanKeys meet at the
    // gate, then withdraw into the shard scheduler)
    for name in ["sha", "shb"] {
        Client::connect(addr).req_ok(&format!(
            r#"{{"op":"create_session","session":"{name}","shape":"box","d":2,"r":1,
                "dtype":"double","domain":[24,24],"backend":"native","temporal":"sweep",
                "threads":1}}"#
        ));
    }
    let threads: Vec<_> = ["sha", "shb"]
        .iter()
        .map(|name| {
            let name = name.to_string();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                c.req_ok(&format!(r#"{{"op":"advance","session":"{name}","steps":4,"t":2}}"#));
                let f =
                    c.req_ok(&format!(r#"{{"op":"fetch","session":"{name}","encoding":"hex"}}"#));
                protocol::decode_field(f.get("field").unwrap()).unwrap()
            })
        })
        .collect();
    let box_weights = tc_stencil::model::stencil::StencilPattern::new(
        tc_stencil::model::stencil::Shape::Box,
        2,
        1,
    )
    .unwrap()
    .uniform_weights();
    let want = golden_replay(&[24, 24], &box_weights, 1, 4, 2);
    for (i, h) in threads.into_iter().enumerate() {
        assert_bits(&h.join().expect("client"), &want, &format!("sharded client {i}"));
    }
    // temporal blocking through the same server: bit-identical to
    // SEQUENTIAL stepping (not the fused chain)
    let mut c = Client::connect(addr);
    c.req_ok(
        r#"{"op":"create_session","session":"blk","shape":"star","d":2,"r":1,
            "dtype":"double","domain":[64,64],"backend":"native","temporal":"blocked",
            "threads":2}"#,
    );
    let a = c.req_ok(r#"{"op":"advance","session":"blk","steps":8,"t":4}"#);
    assert_eq!(a.get("temporal").unwrap().as_str(), Some("blocked"));
    let f = c.req_ok(r#"{"op":"fetch","session":"blk","encoding":"hex"}"#);
    let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
    let w = golden::Weights::new(2, 3, star_weights());
    let want = golden::apply_steps(
        &golden::Field::from_vec(&[64, 64], golden::gaussian(&[64, 64])),
        &w,
        8,
    );
    assert_bits(&got, &want.data, "blocked");
    let st = c.req_ok(r#"{"op":"stats"}"#);
    assert!(st.get("jobs_sharded").unwrap().as_i64().unwrap() >= 2, "{st}");
    assert_eq!(st.get("jobs_failed").unwrap().as_usize(), Some(0));
    c.req_ok(r#"{"op":"shutdown"}"#);
    handle.join().expect("listener thread");
    svc.shutdown();
}

/// Session tiering mid-session: a 1-byte resident cap forces every
/// idle session's field to disk between requests, and a multi-round
/// interleaved stream still ends bit-identical to the golden replay.
#[test]
fn tiered_spill_and_restore_are_bit_exact_mid_session() {
    let mut opts = test_opts();
    opts.workers = 1;
    opts.resident_bytes = Some(1);
    let (mut svc, addr, handle) = spawn_server(opts);
    let mut c = Client::connect(addr);
    for (name, tenant) in [("t1", "acme"), ("t2", "umbrella")] {
        c.req_ok(&format!(
            r#"{{"op":"create_session","session":"{name}","shape":"star","d":2,"r":1,
                "dtype":"double","domain":[16,16],"backend":"native","threads":1,
                "shards":1,"tenant":"{tenant}"}}"#
        ));
    }
    let advances = 3;
    for round in 0..advances {
        for name in ["t1", "t2"] {
            c.req_ok(&format!(r#"{{"op":"advance","session":"{name}","steps":2,"t":2}}"#));
        }
        if round == 0 {
            // mid-session: the idle sessions have already been spilled
            let st = c.req_ok(r#"{"op":"stats"}"#);
            assert!(st.get("spilled_bytes").unwrap().as_i64().unwrap() > 0, "{st}");
            let rows = st.get("tenants").unwrap().as_arr().unwrap();
            let spilled: u64 = rows
                .iter()
                .map(|r| r.get("spilled_bytes").unwrap().as_i64().unwrap() as u64)
                .sum();
            assert!(spilled > 0, "per-tenant rows must attribute the spill: {st}");
        }
    }
    let want = golden_replay(&[16, 16], &star_weights(), advances, 2, 2);
    for name in ["t1", "t2"] {
        let f = c.req_ok(&format!(r#"{{"op":"fetch","session":"{name}","encoding":"hex"}}"#));
        let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
        assert_bits(&got, &want, &format!("tiered session {name}"));
    }
    c.req_ok(r#"{"op":"shutdown"}"#);
    handle.join().expect("listener thread");
    svc.shutdown();
}

/// An unmeetable deadline is refused BEFORE execution, with the
/// roofline-predicted completion time as evidence; a meetable one is
/// admitted through the EDF urgent tier and still runs bit-exactly.
#[test]
fn unmeetable_deadline_refused_with_roofline_evidence() {
    let (mut svc, addr, handle) = spawn_server(test_opts());
    let mut c = Client::connect(addr);
    c.req_ok(
        r#"{"op":"create_session","session":"dl","shape":"star","d":2,"r":1,
            "dtype":"double","domain":[32,32],"backend":"native","threads":1,
            "shards":1,"tenant":"slo"}"#,
    );
    let rej = c.req(r#"{"op":"advance","session":"dl","steps":4,"t":2,"deadline_ms":0.000001}"#);
    assert_eq!(rej.get("ok").unwrap().as_bool(), Some(false));
    assert_eq!(rej.get("error").unwrap().as_str(), Some("deadline_unmeetable"));
    assert_eq!(rej.get("tenant").unwrap().as_str(), Some("slo"));
    let predicted = rej.get("predicted_completion_ms").unwrap().as_f64().unwrap();
    let cost = rej.get("cost_ms").unwrap().as_f64().unwrap();
    assert!(predicted > 0.000001 && cost > 0.0, "evidence missing: {rej}");
    // the refused advance never touched the field
    let f = c.req_ok(r#"{"op":"fetch","session":"dl","encoding":"hex"}"#);
    let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
    assert_bits(&got, &golden::gaussian(&[32, 32]), "refused advance must not run");
    // a meetable deadline rides the EDF tier and runs bit-exactly
    let ok = c.req_ok(r#"{"op":"advance","session":"dl","steps":4,"t":2,"deadline_ms":60000}"#);
    assert_eq!(ok.get("tenant").unwrap().as_str(), Some("slo"));
    let f = c.req_ok(r#"{"op":"fetch","session":"dl","encoding":"hex"}"#);
    let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
    assert_bits(&got, &golden_replay(&[32, 32], &star_weights(), 1, 4, 2), "EDF advance");
    let st = c.req_ok(r#"{"op":"stats"}"#);
    let rows = st.get("tenants").unwrap().as_arr().unwrap();
    let slo = rows.iter().find(|r| r.get("tenant").unwrap().as_str() == Some("slo")).unwrap();
    assert_eq!(slo.get("refused").unwrap().as_usize(), Some(1));
    assert_eq!(slo.get("admitted").unwrap().as_usize(), Some(1));
    c.req_ok(r#"{"op":"shutdown"}"#);
    handle.join().expect("listener thread");
    svc.shutdown();
}

/// Deficit-round-robin convergence under a zipfian demand mix: the hog
/// is deferred under pressure until the starved tenants' served shares
/// converge to within one quantum, after which everyone is admitted.
#[test]
fn drr_shares_converge_under_zipfian_demand() {
    let sched = TenantSched::new(2);
    let cost = 10.0;
    // zipf-ish opening burst: tenant0 issues 8x what the tail does
    for _ in 0..32 {
        assert!(matches!(sched.admit("tenant0", cost, None, true), TenantVerdict::Admit { .. }));
    }
    for t in ["tenant1", "tenant2"] {
        for _ in 0..4 {
            assert!(matches!(sched.admit(t, cost, None, true), TenantVerdict::Admit { .. }));
        }
    }
    // under pressure, the hog is deferred with evidence while the tail
    // catches up
    let mut served = std::collections::BTreeMap::new();
    for round in 0..40 {
        for t in ["tenant0", "tenant1", "tenant2"] {
            match sched.admit(t, cost, None, true) {
                TenantVerdict::Admit { urgent, .. } => {
                    assert!(!urgent, "no deadline → FIFO tier");
                    *served.entry(t).or_insert(0u32) += 1;
                }
                TenantVerdict::OverShare(fs) => {
                    assert_eq!(fs.tenant, t);
                    assert!(
                        fs.served_ms > fs.fair_share_ms + fs.quantum_ms,
                        "round {round}: deferral without evidence: {fs:?}"
                    );
                }
                other => panic!("unexpected verdict {other:?}"),
            }
        }
    }
    let hog = served["tenant0"];
    for t in ["tenant1", "tenant2"] {
        assert!(served[t] > hog, "starved tenant {t} must out-admit the hog ({hog})");
    }
    // converged: one full round admits every tenant
    for t in ["tenant0", "tenant1", "tenant2"] {
        assert!(
            matches!(sched.admit(t, cost, None, true), TenantVerdict::Admit { .. }),
            "post-convergence round must admit {t}"
        );
    }
}

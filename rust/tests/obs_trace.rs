//! Acceptance suite for the obs tracing plane:
//!
//! * a traced multi-worker sharded blocked job emits a span per
//!   (phase × shard), a barrier and an assembly span per phase, with
//!   ordering/nesting invariants and per-phase `bytes`/`flops` payloads
//!   that sum **exactly** to the job's `RunMetrics`;
//! * the Chrome trace-event rendering keeps one track per worker and
//!   shows every `ShardPhase` and barrier;
//! * the NDJSON sink round-trips every payload bit-exactly (NaN, -0.0,
//!   subnormals travel as hex-f64);
//! * histogram bucket boundaries are exact powers of two;
//! * disabled mode (the default) emits exactly zero events and leaves
//!   the computed field bit-identical to the traced run.
//!
//! The obs plane is process-global state, so every test serializes on
//! one mutex and restores the disabled default before releasing it.

use std::collections::BTreeSet;
use std::sync::{Mutex, MutexGuard};

use tc_stencil::backend::{self, Backend, NativeBackend, TemporalMode};
use tc_stencil::coordinator::grid::ShardPlan;
use tc_stencil::coordinator::metrics::RunMetrics;
use tc_stencil::coordinator::scheduler;
use tc_stencil::model::perf::Dtype;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::obs::{self, Payload, Span, SpanKind};
use tc_stencil::sim::golden;

static OBS: Mutex<()> = Mutex::new(());

/// Take the obs lock and reset the plane to its disabled default.
fn obs_lock() -> MutexGuard<'static, ()> {
    let g = OBS.lock().unwrap_or_else(|e| e.into_inner());
    obs::disable();
    obs::clear_sink();
    let _ = obs::drain_all();
    g
}

fn job(domain: Vec<usize>, steps: usize, t: usize, temporal: TemporalMode) -> backend::Job {
    let pattern = StencilPattern::new(Shape::Star, domain.len(), 1).unwrap();
    backend::Job {
        pattern,
        dtype: Dtype::F64,
        domain,
        steps,
        t,
        temporal,
        weights: pattern.uniform_weights(),
        threads: 1,
    }
}

/// Run one sharded job under a fresh trace and return its spans plus
/// the job-level metrics and final field.
fn traced_sharded(
    j: &backend::Job,
    plan: &ShardPlan,
    lanes: usize,
    init: &[f64],
) -> (Vec<Span>, RunMetrics, Vec<f64>) {
    let trace = obs::next_trace_id();
    let scope = obs::trace_scope(trace);
    let mut f = init.to_vec();
    let m = scheduler::advance_sharded(j, plan, &mut f, lanes).unwrap();
    drop(scope);
    (obs::drain(trace), m, f)
}

#[test]
fn sharded_blocked_job_spans_nest_order_and_sum_exactly() {
    let _g = obs_lock();
    let j = job(vec![32, 16], 6, 2, TemporalMode::Blocked);
    let shards = 3usize;
    let plan = ShardPlan::dim0(&j.domain, shards, j.pattern.r, j.t).unwrap();
    let init = golden::gaussian(&j.domain);
    obs::enable();
    let (spans, m, _f) = traced_sharded(&j, &plan, 2, &init);
    obs::disable();

    let n_phases = backend::shard_phases(&j).len();
    assert_eq!(n_phases, 3, "6 steps at t=2 blocked = 3 shard phases");
    let kinds: BTreeSet<SpanKind> = spans.iter().map(|s| s.kind).collect();
    assert_eq!(
        kinds,
        [SpanKind::ShardPhase, SpanKind::Barrier, SpanKind::Assembly].into_iter().collect(),
        "a direct scheduler call emits exactly the executor span kinds"
    );

    // One ShardPhase span per (phase × shard), covering the full grid.
    let phase_spans: Vec<&Span> =
        spans.iter().filter(|s| s.kind == SpanKind::ShardPhase).collect();
    assert_eq!(phase_spans.len(), n_phases * shards);
    let grid: BTreeSet<(u64, u64)> = phase_spans
        .iter()
        .map(|s| match &s.payload {
            Payload::Phase { index, shard, .. } => (*index, *shard),
            p => panic!("ShardPhase span carries {p:?}"),
        })
        .collect();
    assert_eq!(grid.len(), n_phases * shards, "every (phase, shard) pair exactly once");

    // Scoped chunk threads tag distinct worker tracks (lanes=2 → 2).
    let workers: BTreeSet<u64> = phase_spans.iter().map(|s| s.worker).collect();
    assert!(workers.len() >= 2, "multi-worker run must spread tracks, got {workers:?}");

    // Per phase: every shard span ends before the barrier completes,
    // the barrier precedes assembly, and assembly precedes the next
    // phase's first shard span.
    let mut prev_assembly_end = 0u64;
    for pi in 0..n_phases as u64 {
        let mine: Vec<&&Span> = phase_spans
            .iter()
            .filter(|s| matches!(&s.payload, Payload::Phase { index, .. } if *index == pi))
            .collect();
        let barrier = spans
            .iter()
            .find(|s| {
                matches!(&s.payload, Payload::Barrier { index, .. } if *index == pi)
            })
            .expect("one barrier span per phase");
        let Payload::Barrier { shards: bs, stall_ns, .. } = &barrier.payload else {
            unreachable!()
        };
        assert_eq!(*bs, shards as u64);
        assert_eq!(*stall_ns, barrier.wall_ns(), "stall payload is the span's wall");
        for s in &mine {
            assert!(
                s.start_ns >= prev_assembly_end,
                "phase {pi} starts before the previous assembly finished"
            );
            assert!(s.end_ns <= barrier.end_ns, "shard span outlives its barrier");
        }
        let first_start = mine.iter().map(|s| s.start_ns).min().unwrap();
        assert!(barrier.start_ns >= first_start, "barrier stall starts after work begins");
        // Assembly spans carry no payload; pick the pi-th in time order
        // (drain sorts by start time, one assembly per phase).
        let assembly = spans
            .iter()
            .filter(|s| s.kind == SpanKind::Assembly)
            .nth(pi as usize)
            .expect("one assembly span per phase");
        assert!(assembly.start_ns >= barrier.start_ns, "assembly follows the barrier");
        prev_assembly_end = assembly.end_ns;
    }

    // The acceptance bar: per-phase span payloads sum EXACTLY to the
    // job's RunMetrics — per phase index and in total.
    assert_eq!(m.phases.len(), n_phases);
    let mut total_bytes = 0u64;
    let mut total_flops = 0u64;
    for pm in &m.phases {
        let (b, f): (u64, u64) = phase_spans
            .iter()
            .filter_map(|s| match &s.payload {
                Payload::Phase { index, bytes, flops, .. } if *index == pm.index as u64 => {
                    Some((*bytes, *flops))
                }
                _ => None,
            })
            .fold((0, 0), |(ab, af), (b, f)| (ab + b, af + f));
        assert_eq!(b, pm.bytes_moved, "phase {} bytes", pm.index);
        assert_eq!(f, pm.flops, "phase {} flops", pm.index);
        total_bytes += b;
        total_flops += f;
    }
    assert_eq!(total_bytes, m.bytes_moved, "span bytes sum to the job total");
    assert_eq!(total_flops, m.flops, "span flops sum to the job total");
    let kernels: BTreeSet<&str> = phase_spans
        .iter()
        .filter_map(|s| match &s.payload {
            Payload::Phase { kernel, .. } => Some(kernel.as_str()),
            _ => None,
        })
        .collect();
    assert_eq!(kernels.into_iter().collect::<Vec<_>>(), vec![m.kernel.as_str()]);

    // Chrome rendering: one named track per worker; every ShardPhase
    // and barrier shows up as an X event on its worker's track.
    let chrome = obs::export::chrome_trace(&spans);
    let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
    let tracks: Vec<&tc_stencil::util::json::Json> = events
        .iter()
        .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
        .collect();
    let all_workers: BTreeSet<u64> = spans.iter().map(|s| s.worker).collect();
    assert_eq!(tracks.len(), all_workers.len(), "one metadata track per worker");
    for pi in 0..n_phases {
        for si in 0..shards {
            let name = format!("phase{pi}/shard{si}");
            let ev = events
                .iter()
                .find(|e| e.get("name").unwrap().as_str() == Some(name.as_str()))
                .unwrap_or_else(|| panic!("chrome event {name} missing"));
            let tid = ev.get("tid").unwrap().as_i64().unwrap() as u64;
            assert!(all_workers.contains(&tid));
        }
        let bname = format!("barrier{pi}");
        assert!(
            events.iter().any(|e| e.get("name").unwrap().as_str() == Some(bname.as_str())),
            "chrome event {bname} missing"
        );
    }
}

#[test]
fn ndjson_sink_roundtrips_payloads_bit_exactly() {
    let _g = obs_lock();
    let path = std::env::temp_dir().join(format!("tc_obs_trace_{}.ndjson", std::process::id()));
    obs::set_sink(&path).unwrap();
    obs::enable();
    let trace = obs::next_trace_id();
    {
        let _t = obs::trace_scope(trace);
        obs::record(
            SpanKind::Job,
            5,
            9,
            Payload::Job { steps: 3, shards: 2, model_err: f64::NAN },
        );
        obs::record(
            SpanKind::Drift,
            9,
            9,
            Payload::Drift { region: "mem/blocked".into(), ewma: -0.0, flagged: false },
        );
        obs::record(
            SpanKind::Drift,
            9,
            10,
            Payload::Drift { region: "kern/sweep".into(), ewma: 5e-324, flagged: true },
        );
    }
    obs::clear_sink();
    obs::disable();
    let ring = obs::drain(trace);
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let back = obs::export::read_ndjson(&text).unwrap();
    assert_eq!(ring.len(), 3, "flight recorder kept every span");
    assert_eq!(back.len(), 3, "sink streamed every span");
    // Ring drain is time-sorted; these spans were recorded in time
    // order, so the streams align one to one.
    for (a, b) in ring.iter().zip(&back) {
        assert_eq!((a.trace, a.worker, a.kind), (b.trace, b.worker, b.kind));
        assert_eq!((a.start_ns, a.end_ns), (b.start_ns, b.end_ns));
        match (&a.payload, &b.payload) {
            (Payload::Job { model_err: x, .. }, Payload::Job { model_err: y, .. }) => {
                assert_eq!(x.to_bits(), y.to_bits(), "NaN survives the hex codec");
            }
            (
                Payload::Drift { ewma: x, region: ra, flagged: fa },
                Payload::Drift { ewma: y, region: rb, flagged: fb },
            ) => {
                assert_eq!(x.to_bits(), y.to_bits(), "-0.0/subnormal survive the hex codec");
                assert_eq!((ra, fa), (rb, fb));
            }
            (p, q) => panic!("payload mismatch: {p:?} vs {q:?}"),
        }
    }
}

#[test]
fn histogram_buckets_land_exactly_on_power_of_two_bounds() {
    use tc_stencil::obs::prom::Histogram;
    let h = Histogram::new(3, 6); // bounds 8, 16, 32, 64 + overflow
    assert_eq!(h.bounds(), vec![8.0, 16.0, 32.0, 64.0]);
    h.observe(8.0); // le is inclusive: lands in the first bucket
    h.observe(8.0 + f64::EPSILON * 8.0); // one ulp past: second bucket
    h.observe(64.0);
    h.observe(64.5); // overflow
    h.observe(-3.0); // clamps into the first bucket
    h.observe(f64::NAN); // dropped
    assert_eq!(h.snapshot(), vec![2, 1, 0, 1, 1]);
    assert_eq!(h.count(), 5);
    // The process-global registry uses the standard layouts: times
    // span ~1 µs (2^10 ns) to ~17 s (2^34 ns).
    let bounds = obs::metrics().queue_wait_ns.bounds();
    assert_eq!(bounds.first().copied(), Some(1024.0));
    assert_eq!(bounds.last().copied(), Some(2f64.powi(34)));
}

#[test]
fn disabled_mode_emits_zero_events_and_identical_bits() {
    let _g = obs_lock();
    let j = job(vec![24, 18], 5, 2, TemporalMode::Blocked);
    let plan = ShardPlan::dim0(&j.domain, 2, j.pattern.r, j.t).unwrap();
    let init = golden::gaussian(&j.domain);

    assert!(!obs::enabled(), "disabled is the default");
    let (off_spans, m_off, f_off) = traced_sharded(&j, &plan, 2, &init);
    assert!(off_spans.is_empty(), "disabled mode recorded {} spans", off_spans.len());
    assert!(obs::drain_all().is_empty(), "no stray spans on any ring");

    obs::enable();
    let (on_spans, m_on, f_on) = traced_sharded(&j, &plan, 2, &init);
    obs::disable();
    assert!(!on_spans.is_empty(), "enabled mode must record spans");

    // Tracing must never perturb the computation: bit-identical field,
    // identical instrumented work accounting.
    for (i, (a, b)) in f_off.iter().zip(&f_on).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "point {i} differs under tracing");
    }
    assert_eq!(m_off.bytes_moved, m_on.bytes_moved);
    assert_eq!(m_off.flops, m_on.flops);
    assert_eq!(m_off.launches, m_on.launches);
    assert_eq!(m_off.phases.len(), m_on.phases.len());

    // A second disabled run drains nothing even after an enabled one.
    let (again, _, _) = traced_sharded(&j, &plan, 2, &init);
    assert!(again.is_empty());
}

#[test]
fn monolithic_run_records_the_kernel_span() {
    let _g = obs_lock();
    let j = job(vec![20, 20], 3, 1, TemporalMode::Sweep);
    let mut f = golden::gaussian(&j.domain);
    obs::enable();
    let trace = obs::next_trace_id();
    let scope = obs::trace_scope(trace);
    let m = NativeBackend::new().advance(&j, &mut f).unwrap();
    drop(scope);
    let spans = obs::drain(trace);
    obs::disable();
    let kernel: Vec<&Span> = spans.iter().filter(|s| s.kind == SpanKind::Kernel).collect();
    assert_eq!(kernel.len(), 1, "one kernel-dispatch span per monolithic run");
    match &kernel[0].payload {
        Payload::Kernel { name, nnz } => {
            assert_eq!(name, &m.kernel);
            assert_eq!(*nnz, 5, "star-2d1r executes five taps per point");
        }
        p => panic!("kernel span carries {p:?}"),
    }
    // The compact reply block keeps the dashboard sort keys.
    let compact = obs::export::compact_spans(&spans);
    let arr = compact.as_arr().unwrap();
    assert_eq!(arr.len(), spans.len());
    assert!(arr
        .iter()
        .any(|o| o.get("kind").unwrap().as_str() == Some("kernel")
            && o.get("kernel").unwrap().as_str() == Some(m.kernel.as_str())));
}

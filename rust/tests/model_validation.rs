//! Cross-module validation: the paper's quantitative claims checked
//! end-to-end through the public API (model + hardware + engines + sim).

use tc_stencil::engines;
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::{Dtype, Unit, Workload};
use tc_stencil::model::roofline::Bound;
use tc_stencil::model::scenario::{compare, Scenario};
use tc_stencil::model::sparsity::Scheme;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::sim::exec;
use tc_stencil::util::prop::{forall, Config};

fn wl(shape: Shape, d: usize, r: usize, t: usize, dt: Dtype) -> Workload {
    Workload::new(StencilPattern::new(shape, d, r).unwrap(), t, dt)
}

#[test]
fn paper_abstract_speedups_fig2_shape() {
    // Fig 2: TCStencil 1.48×, ConvStencil 2.23×, SPIDER 4.60× over
    // DRStencil.  Our calibrated simulator must keep the ORDER and the
    // rough magnitudes (>1, increasing, SPIDER > 2×).
    let gpu = Gpu::a100();
    let w = |t| wl(Shape::Box, 2, 1, t, Dtype::F32);
    let dr = (1..=4)
        .map(|t| exec::predict(&engines::drstencil(), &w(t), &gpu).unwrap().gstencils())
        .fold(f64::NAN, f64::max);
    let cv = (1..=8)
        .map(|t| exec::predict(&engines::convstencil(), &w(t), &gpu).unwrap().gstencils())
        .fold(f64::NAN, f64::max);
    let sp = (1..=8)
        .map(|t| exec::predict(&engines::spider(), &w(t), &gpu).unwrap().gstencils())
        .fold(f64::NAN, f64::max);
    assert!(cv / dr > 1.0, "ConvStencil {cv} vs DRStencil {dr}");
    assert!(sp / cv > 1.0, "SPIDER {sp} vs ConvStencil {cv}");
    assert!(sp / dr > 2.0, "SPIDER speedup {}", sp / dr);
}

#[test]
fn fig10_transition_depths() {
    // §4.2: "box stencils transition at t=3, star at t=5" (locked clock).
    let gpu = Gpu::a100().locked(engines::calib::PROFILING_CLOCK_LOCK);
    let roof = gpu.roof(Unit::CudaCore, Dtype::F32).unwrap();
    let first_compute = |shape: Shape, d: usize, r: usize| -> usize {
        (1..=16)
            .find(|&t| roof.bound(wl(shape, d, r, t, Dtype::F32).intensity_cuda()) == Bound::Compute)
            .unwrap_or(99)
    };
    let box_t = first_compute(Shape::Box, 2, 1);
    let star_t = first_compute(Shape::Star, 2, 1);
    assert!((3..=5).contains(&box_t), "box transition t={box_t}");
    assert!((6..=8).contains(&star_t), "star transition t={star_t}");
    assert!(star_t > box_t, "star transitions later (lower intensity)");
    // Box-3D2R is compute-bound even without fusion (paper §4.2).
    assert_eq!(first_compute(Shape::Box, 3, 2), 1);
}

#[test]
fn clock_lock_shifts_transitions_earlier() {
    // §5.2: locked clocks lower the ceiling → transitions at shallower t.
    let free = Gpu::a100();
    let locked = Gpu::a100().locked(0.7);
    let t_free = (1..=16)
        .find(|&t| {
            free.roof(Unit::CudaCore, Dtype::F32)
                .unwrap()
                .bound(wl(Shape::Star, 2, 1, t, Dtype::F32).intensity_cuda())
                == Bound::Compute
        })
        .unwrap();
    let t_locked = (1..=16)
        .find(|&t| {
            locked
                .roof(Unit::CudaCore, Dtype::F32)
                .unwrap()
                .bound(wl(Shape::Star, 2, 1, t, Dtype::F32).intensity_cuda())
                == Bound::Compute
        })
        .unwrap();
    assert!(t_locked <= t_free, "locked {t_locked} vs free {t_free}");
}

#[test]
fn scenario1_exact_equivalence_property() {
    // Eq. 14 as a property: whenever BOTH units are memory-bound the
    // actual-performance ratio is exactly 1, for any workload/S.
    let gpu = Gpu::a100();
    forall(
        Config { cases: 200, ..Default::default() },
        |rng| {
            let shape = if rng.f64() < 0.5 { Shape::Box } else { Shape::Star };
            let d = rng.range_usize(1, 3);
            let r = rng.range_usize(1, 3);
            let t = rng.range_usize(1, 8);
            (shape, d, r, t)
        },
        |&(shape, d, r, t)| {
            let w = wl(shape, d, r, t, Dtype::F64);
            let cu = gpu.roof(Unit::CudaCore, Dtype::F64).map_err(|e| e.to_string())?;
            let tc = gpu.roof(Unit::TensorCore, Dtype::F64).map_err(|e| e.to_string())?;
            let cmp = compare(&w, &cu, &tc, Unit::TensorCore, Scheme::Decompose);
            if cmp.scenario == Scenario::MemToMem && (cmp.speedup - 1.0).abs() > 1e-9 {
                return Err(format!("ratio {} != 1", cmp.speedup));
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn scenario2_strictly_loses_property() {
    // Eq. 16 as a property: MB→CB always degrades.
    let gpu = Gpu::a100();
    forall(
        Config { cases: 200, seed: 99, ..Default::default() },
        |rng| {
            let r = rng.range_usize(1, 4);
            let t = rng.range_usize(1, 8);
            let dt = if rng.f64() < 0.5 { Dtype::F32 } else { Dtype::F64 };
            (r, t, dt)
        },
        |&(r, t, dt)| {
            let w = wl(Shape::Box, 2, r, t, dt);
            let cu = gpu.roof(Unit::CudaCore, dt).map_err(|e| e.to_string())?;
            let tc = gpu.roof(Unit::TensorCore, dt).map_err(|e| e.to_string())?;
            for scheme in [Scheme::Flatten, Scheme::Decompose] {
                let cmp = compare(&w, &cu, &tc, Unit::TensorCore, scheme);
                if cmp.scenario == Scenario::MemToComp && cmp.speedup >= 1.0 {
                    return Err(format!("scenario2 ratio {} >= 1", cmp.speedup));
                }
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn scenario3_breaks_cuda_ceiling_property() {
    // Eq. 17: CB→MB exceeds the CUDA compute ceiling.
    let gpu = Gpu::a100();
    let cu = gpu.roof(Unit::CudaCore, Dtype::F32).unwrap();
    let sptc = gpu.roof(Unit::SparseTensorCore, Dtype::F32).unwrap();
    let mut found = 0;
    for r in 1..=7usize {
        for t in 1..=8usize {
            let w = wl(Shape::Box, 2, r, t, Dtype::F32);
            let cmp = compare(&w, &cu, &sptc, Unit::SparseTensorCore, Scheme::Sparse24);
            if cmp.scenario == Scenario::CompToMem {
                found += 1;
                assert!(
                    cmp.tensor_perf_actual > cu.peak_flops * 0.999,
                    "r={r} t={t}: actual {} must exceed CUDA peak {}",
                    cmp.tensor_perf_actual,
                    cu.peak_flops
                );
            }
        }
    }
    assert!(found > 0, "the sweep must contain scenario-3 cases");
}

#[test]
fn eq19_boundary_is_sharp() {
    // Walk t upward in scenario 4 and check profitability flips exactly
    // when α crosses S·P_TC/P_CU.
    let gpu = Gpu::a100();
    let cu = gpu.roof(Unit::CudaCore, Dtype::F64).unwrap();
    let tc = gpu.roof(Unit::TensorCore, Dtype::F64).unwrap();
    let p = StencilPattern::new(Shape::Box, 2, 3).unwrap();
    for t in 1..=8usize {
        let w = Workload::new(p, t, Dtype::F64);
        let cmp = compare(&w, &cu, &tc, Unit::TensorCore, Scheme::Flatten);
        if cmp.scenario != Scenario::CompToComp {
            continue;
        }
        let s = w.sparsity(Scheme::Flatten);
        let threshold = s * tc.peak_flops / cu.peak_flops;
        let profitable = cmp.speedup > 1.0;
        assert_eq!(
            profitable,
            w.alpha() < threshold,
            "t={t}: α={} thr={threshold} ratio={}",
            w.alpha(),
            cmp.speedup
        );
    }
}

#[test]
fn engine_predictions_monotone_in_bandwidth() {
    // Sanity: a memory-bound workload speeds up with a faster-HBM GPU.
    let w = wl(Shape::Box, 2, 1, 1, Dtype::F32);
    let a100 = exec::predict(&engines::ebisu(), &w, &Gpu::a100()).unwrap();
    let h100 = exec::predict(&engines::ebisu(), &w, &Gpu::h100()).unwrap();
    assert_eq!(a100.bound, Bound::Memory);
    assert!(h100.throughput > a100.throughput);
}

#[test]
fn star_exact_alpha_differs_from_box_closed_form() {
    // Using Eq. 10 for stars would misclassify: check the exact Minkowski
    // count diverges from the box formula (ablation (b) motivation).
    let star = StencilPattern::new(Shape::Star, 2, 1).unwrap();
    for t in 2..=6usize {
        let exact = star.fused_k_points(t) as f64 / (t as f64 * star.k_points() as f64);
        let box_formula = ((2 * t + 1) * (2 * t + 1)) as f64 / (t as f64 * 5.0);
        assert!(
            (box_formula - exact) / exact > 0.5,
            "t={t}: box formula {box_formula} vs exact {exact}"
        );
    }
}

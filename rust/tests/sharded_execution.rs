//! The sharded execution plane's acceptance suite:
//!
//! * shard ≡ unsharded f64 **bit-identity** property sweep — star/box
//!   patterns, odd/prime domains, t ∈ 1..4, sweep AND blocked
//!   semantics, shard counts 1..5, lane-count invariance;
//! * per-shard metrics sum exactly to the job-level reply, halo
//!   recompute included, and match `model::shard`'s prediction term
//!   for term;
//! * planner regression: >1 shard is chosen exactly when the
//!   redundancy-adjusted gain crosses 1 (the shard-axis analogue of
//!   the temporal balance-point regression).

use tc_stencil::backend::{self, Backend, NativeBackend, TemporalMode};
use tc_stencil::coordinator::grid::{ShardPlan, ShardSpec};
use tc_stencil::coordinator::{planner, scheduler};
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::{Dtype, Workload};
use tc_stencil::model::shard;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::sim::golden;
use tc_stencil::util::prop::{forall, Config};
use tc_stencil::util::rng::Rng;

fn job(
    shape: Shape,
    domain: Vec<usize>,
    steps: usize,
    t: usize,
    temporal: TemporalMode,
    dtype: Dtype,
) -> backend::Job {
    let d = domain.len();
    let pattern = StencilPattern::new(shape, d, 1).unwrap();
    backend::Job {
        pattern,
        dtype,
        domain,
        steps,
        t,
        temporal,
        weights: pattern.uniform_weights(),
        threads: 1,
    }
}

fn dim0_plan(job: &backend::Job, shards: usize) -> ShardPlan {
    ShardPlan::dim0(&job.domain, shards, job.pattern.r, job.t).unwrap()
}

fn rand_field(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal()).collect()
}

#[test]
fn property_sharded_is_bit_identical_to_unsharded() {
    // The acceptance bar: for ANY decomposition the assembled sharded
    // result equals the monolithic executor bit for bit (which is
    // itself pinned to the golden oracle by backend_native.rs and
    // temporal_blocking.rs).
    let primes = [5usize, 7, 11, 13, 17, 19, 23];
    forall(
        Config { cases: 60, ..Default::default() },
        |rng| {
            let shape = if rng.f64() < 0.5 { Shape::Box } else { Shape::Star };
            let d = rng.range_usize(2, 3);
            let mut domain: Vec<usize> =
                (0..d).map(|_| primes[rng.range_usize(0, primes.len() - 1)]).collect();
            if d == 3 {
                domain[2] = domain[2].min(7); // keep 3-D cases quick
            }
            let t = rng.range_usize(1, 4);
            let steps = rng.range_usize(1, 6);
            let blocked = rng.f64() < 0.5;
            let shards = rng.range_usize(1, 5);
            let lanes = rng.range_usize(1, 3);
            (shape, domain, t, steps, blocked, shards, lanes)
        },
        |&(shape, ref domain, t, steps, blocked, shards, lanes)| {
            let temporal = if blocked { TemporalMode::Blocked } else { TemporalMode::Sweep };
            let j = job(shape, domain.clone(), steps, t, temporal, Dtype::F64);
            let n: usize = domain.iter().product();
            let init = rand_field(0xC0FFEE ^ (n as u64) ^ (t as u64) << 8, n);
            let mut mono = init.clone();
            NativeBackend::new()
                .advance(&j, &mut mono)
                .map_err(|e| format!("mono: {e:#}"))?;
            let plan = dim0_plan(&j, shards);
            let mut fanned = init.clone();
            scheduler::advance_sharded(&j, &plan, &mut fanned, lanes)
                .map_err(|e| format!("sharded: {e:#}"))?;
            for (i, (a, b)) in fanned.iter().zip(&mono).enumerate() {
                if a.to_bits() != b.to_bits() {
                    return Err(format!(
                        "{shape:?} {domain:?} t={t} steps={steps} blocked={blocked} \
                         S={shards} lanes={lanes}: point {i} {a} != {b}"
                    ));
                }
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn sharded_blocked_matches_sequential_oracle_directly() {
    // Belt and braces: pin the sharded blocked path to the ORACLE (not
    // just the monolithic executor) on odd domains with t 1..4.
    for t in 1..=4usize {
        for shards in [2usize, 3, 5] {
            let j = job(
                Shape::Box,
                vec![19, 13],
                2 * t + 1,
                t,
                TemporalMode::Blocked,
                Dtype::F64,
            );
            let init = rand_field(7 + t as u64, 19 * 13);
            let plan = dim0_plan(&j, shards);
            let mut got = init.clone();
            scheduler::advance_sharded(&j, &plan, &mut got, 2).unwrap();
            let w = golden::Weights::new(2, 3, j.weights.clone());
            let want =
                golden::apply_steps(&golden::Field::from_vec(&[19, 13], init), &w, 2 * t + 1);
            let gotf = golden::Field::from_vec(&[19, 13], got);
            assert_eq!(gotf.max_abs_diff(&want), 0.0, "t={t} S={shards}");
        }
    }
}

#[test]
fn sharded_sweep_matches_fused_oracle_directly() {
    for (steps, t) in [(4usize, 2usize), (5, 3), (3, 1)] {
        let j = job(Shape::Star, vec![17, 11], steps, t, TemporalMode::Sweep, Dtype::F64);
        let init = rand_field(40 + steps as u64, 17 * 11);
        let plan = dim0_plan(&j, 4);
        let mut got = init.clone();
        scheduler::advance_sharded(&j, &plan, &mut got, 3).unwrap();
        let w = golden::Weights::new(2, 3, j.weights.clone());
        let mut want = golden::Field::from_vec(&[17, 11], init);
        for _ in 0..steps / t {
            want = golden::apply_fused(&want, &w, t);
        }
        for _ in 0..steps % t {
            want = golden::apply_once(&want, &w);
        }
        let gotf = golden::Field::from_vec(&[17, 11], got);
        assert_eq!(gotf.max_abs_diff(&want), 0.0, "steps={steps} t={t}");
    }
}

#[test]
fn lane_count_never_changes_bits() {
    // Thread-count invariance on the shard plane: the lane budget is a
    // scheduling knob, never a numerical one.
    for temporal in [TemporalMode::Sweep, TemporalMode::Blocked] {
        let j = job(Shape::Box, vec![23, 9], 5, 2, temporal, Dtype::F64);
        let init = rand_field(99, 23 * 9);
        let plan = dim0_plan(&j, 5);
        let mut want: Option<Vec<f64>> = None;
        for lanes in [1usize, 2, 7] {
            let mut f = init.clone();
            scheduler::advance_sharded(&j, &plan, &mut f, lanes).unwrap();
            match &want {
                None => want = Some(f),
                Some(w) => assert_eq!(w, &f, "lanes={lanes} {temporal:?}"),
            }
        }
    }
}

#[test]
fn f32_sharded_tracks_the_monolithic_f32_path() {
    // Per-phase f64↔f32 marshalling is exact (every intermediate is an
    // f32 value), so even f32 jobs reproduce the monolithic path.
    let j = job(Shape::Star, vec![21, 13], 4, 2, TemporalMode::Blocked, Dtype::F32);
    let init: Vec<f64> =
        rand_field(123, 21 * 13).iter().map(|&v| v as f32 as f64).collect();
    let mut mono = init.clone();
    NativeBackend::new().advance(&j, &mut mono).unwrap();
    let plan = dim0_plan(&j, 3);
    let mut fanned = init.clone();
    scheduler::advance_sharded(&j, &plan, &mut fanned, 2).unwrap();
    for (i, (a, b)) in fanned.iter().zip(&mono).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "point {i}");
    }
}

#[test]
fn per_shard_metrics_sum_to_the_job_reply_and_match_the_model() {
    // Drive every (shard × phase) by hand through advance_shard,
    // summing per-shard metrics; the job driver must report exactly
    // that sum, and both must equal model::shard's prediction.
    for (temporal, blocked) in
        [(TemporalMode::Blocked, true), (TemporalMode::Sweep, false)]
    {
        let j = job(Shape::Box, vec![32, 16], 9, 4, temporal, Dtype::F64);
        let shards = 3usize;
        let plan = dim0_plan(&j, shards);
        let init = rand_field(5, 32 * 16);

        // by hand: phase loop with explicit barrier
        let be = NativeBackend::new();
        let plane = plan.plane();
        let mut field = init.clone();
        let mut hand = tc_stencil::coordinator::metrics::RunMetrics::default();
        for phase in backend::shard_phases(&j) {
            let mut slabs = Vec::new();
            for s in plan.shards() {
                let mut slab = vec![0.0; s.payload()];
                let m = be.advance_shard(&j, &plan, s.index, phase, &field, &mut slab).unwrap();
                assert_eq!(m.launches, 1);
                hand.absorb(&m);
                slabs.push(slab);
            }
            for (s, slab) in plan.shards().iter().zip(&slabs) {
                let (a, b) = s.rows();
                field[a * plane..b * plane].copy_from_slice(slab);
            }
        }

        // driver: must aggregate to the same totals
        let mut f2 = init.clone();
        let m = scheduler::advance_sharded(&j, &plan, &mut f2, 2).unwrap();
        assert_eq!(f2, field, "hand-driven and driver fields agree");
        assert_eq!(m.bytes_moved, hand.bytes_moved);
        assert_eq!(m.flops, hand.flops);
        assert_eq!(m.launches, hand.launches);
        assert_eq!(m.steps, 9);
        assert_eq!(m.points, 32 * 16);

        // and the model's shard-aware prediction is exact (uniform
        // weights: kernel nnz == K, fused nnz == K^(t))
        let w = Workload::new(j.pattern, j.t, j.dtype);
        let predicted = shard::predicted_job_intensity(&w, j.steps, blocked, 32, shards);
        let achieved = m.achieved_intensity();
        assert!(
            (achieved - predicted).abs() < 1e-12,
            "{temporal:?}: achieved {achieved} vs predicted {predicted}"
        );
        // sharding strictly lowers intensity vs the monolithic model
        let mono = tc_stencil::model::calib::predicted_job_intensity(&w, j.steps, blocked);
        assert!(predicted < mono, "halo redundancy must show: {predicted} !< {mono}");
    }
}

#[test]
fn planner_shards_exactly_past_the_redundancy_crossover() {
    // The shard-axis regression (mirror of the temporal balance-point
    // regression): sweeping the dim-0 extent with 4 lanes against a
    // 2-thread monolith, the planner must pick >1 shard exactly when
    // max_S gain(S) crosses 1 — small deep-blocked domains stay
    // monolithic (trapezoid recompute dominates), large ones shard.
    let gpu = Gpu::v100(); // scalar-only: the shard axis decides alone
    let mut saw_mono = false;
    let mut saw_sharded = false;
    for n0 in [8usize, 12, 32, 64, 256] {
        let req = planner::Request {
            pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
            dtype: Dtype::F32,
            domain: vec![n0, 256],
            steps: 64,
            gpu: gpu.clone(),
            backend: backend::BackendKind::Native,
            max_t: 8,
            temporal: TemporalMode::Blocked,
            shards: ShardSpec::Auto,
            lanes: 4,
            threads: 2,
            kernels: tc_stencil::backend::kernels::KernelMode::Auto,
            kernel_peaks: Vec::new(),
        };
        let plan = planner::plan(&req, None).unwrap();
        let t = plan.chosen.t;
        let best_gain = (2..=4usize)
            .map(|s| shard::gain(n0, s, 1, t, true, 4, 2))
            .fold(f64::MIN, f64::max);
        assert_eq!(
            plan.chosen.shards > 1,
            best_gain > 1.0,
            "n0={n0}: chose {} shards at t={t}, best modeled gain {best_gain:.3}",
            plan.chosen.shards
        );
        saw_mono |= plan.chosen.shards == 1;
        saw_sharded |= plan.chosen.shards > 1;
    }
    assert!(saw_mono && saw_sharded, "the sweep must straddle the crossover");
}

#[test]
fn shard_plan_rejects_mismatched_jobs() {
    let j = job(Shape::Box, vec![16, 16], 2, 2, TemporalMode::Sweep, Dtype::F64);
    let plan = dim0_plan(&j, 2);
    let be = NativeBackend::new();
    let field = vec![0.0; 256];
    // wrong slab size
    let mut bad = vec![0.0; 3];
    assert!(be
        .advance_shard(&j, &plan, 0, backend::ShardPhase { depth: 2, fused: true }, &field, &mut bad)
        .is_err());
    // shard index out of range
    let mut slab = vec![0.0; 8 * 16];
    assert!(be
        .advance_shard(&j, &plan, 5, backend::ShardPhase { depth: 1, fused: true }, &field, &mut slab)
        .is_err());
    // phase deeper than the plan's halo ring
    assert!(be
        .advance_shard(&j, &plan, 0, backend::ShardPhase { depth: 3, fused: true }, &field, &mut slab)
        .is_err());
    // 1-D domains cannot slab-shard
    let j1 = job(Shape::Box, vec![64], 2, 1, TemporalMode::Sweep, Dtype::F64);
    assert!(ShardPlan::new(&[64], &[2], 1, 1).is_ok());
    let p1 = ShardPlan::new(&[64], &[2], 1, 1).unwrap();
    let mut slab1 = vec![0.0; 32];
    let f1 = vec![0.0; 64];
    assert!(be
        .advance_shard(&j1, &p1, 0, backend::ShardPhase { depth: 1, fused: true }, &f1, &mut slab1)
        .is_err());
}

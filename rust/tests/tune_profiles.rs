//! Integration tests for the tune/ plane: machine profiles end to end.
//!
//! The acceptance contract of the measured-constants plane:
//!
//! * profile JSON round-trips **bit-exactly** (hex f64 fields);
//! * with no profile, planning is bit-identical to the static registry
//!   table (the builtin fallback);
//! * with a loaded profile, planner/admission/criteria decisions derive
//!   from ITS constants — swapping two synthetic profiles flips both
//!   the blocked-vs-sweep temporal crossover and the shards>1 crossover
//!   (expectations machine-checked against an independent Python port
//!   of the scoring math);
//! * crossovers move monotonically as bandwidth scales;
//! * drift EWMAs trigger at the documented threshold and stale-profile
//!   version strings are rejected with a clear error.

use tc_stencil::backend::{BackendKind, TemporalMode};
use tc_stencil::coordinator::grid::ShardSpec;
use tc_stencil::coordinator::planner::{self, Request};
use tc_stencil::engines;
use tc_stencil::hardware::{Gpu, PeakTable};
use tc_stencil::model::perf::Dtype;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::tune::drift::{DriftTracker, DRIFT_MIN_SAMPLES, DRIFT_THRESHOLD};
use tc_stencil::tune::profile::{self, MachineProfile, ProfileSource, PROFILE_VERSION};

/// A synthetic scalar-only profile: bandwidth + one f64 peak.
fn synth(name: &str, bandwidth: f64, cuda_f64: f64) -> MachineProfile {
    MachineProfile {
        version: PROFILE_VERSION.to_string(),
        name: name.to_string(),
        source: ProfileSource::Measured,
        created_unix: 1,
        bandwidth,
        peaks: PeakTable {
            cuda_f64: Some(cuda_f64),
            cuda_f32: Some(cuda_f64),
            ..Default::default()
        },
        clock_lock: 1.0,
        kernels: Vec::new(),
        probes: Vec::new(),
    }
}

/// The fixed request both crossover tests plan: Box-3D1R f64 over a
/// thin dim-0 domain, 4 lanes against a 2-thread monolith, everything
/// else `Auto` so the profile constants decide.
fn crossover_request(gpu: Gpu) -> Request {
    Request {
        pattern: StencilPattern::new(Shape::Box, 3, 1).unwrap(),
        dtype: Dtype::F64,
        domain: vec![4, 64, 64],
        steps: 12,
        gpu,
        backend: BackendKind::Native,
        max_t: 6,
        temporal: TemporalMode::Auto,
        shards: ShardSpec::Auto,
        lanes: 4,
        threads: 2,
        kernels: tc_stencil::backend::kernels::KernelMode::Auto,
        kernel_peaks: Vec::new(),
    }
}

#[test]
fn profile_json_roundtrip_is_bit_exact_through_disk() {
    // Adversarial values: non-terminating decimals, a subnormal, -0.0's
    // cousin territory, and a probe record.
    let mut p = synth("bitexact", 0.1 + 0.2, 1.0 / 3.0);
    p.peaks.tc_f32 = Some(5e-324);
    p.probes.push(tc_stencil::tune::micro::ProbeRecord {
        name: "stream/triad/8mib".to_string(),
        reps: 3,
        median: 6.02214076e23,
        spread: 1.7976931348623157e308,
    });
    let path = std::env::temp_dir().join("tcs_tune_roundtrip.json");
    p.save(&path).unwrap();
    let q = MachineProfile::load(&path).unwrap();
    assert_eq!(q.bandwidth.to_bits(), p.bandwidth.to_bits());
    assert_eq!(q.peaks.cuda_f64.unwrap().to_bits(), p.peaks.cuda_f64.unwrap().to_bits());
    assert_eq!(q.peaks.tc_f32.unwrap().to_bits(), p.peaks.tc_f32.unwrap().to_bits());
    assert_eq!(q.probes[0].median.to_bits(), p.probes[0].median.to_bits());
    assert_eq!(q.probes[0].spread.to_bits(), p.probes[0].spread.to_bits());
    assert_eq!(q.name, "bitexact");
    assert_eq!(q.source, ProfileSource::Measured);
    // and the derived Gpu carries the exact constants into planning
    assert_eq!(q.gpu().bandwidth.to_bits(), p.bandwidth.to_bits());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn stale_version_strings_are_rejected_with_a_clear_error() {
    let path = std::env::temp_dir().join("tcs_tune_stale_version.json");
    let mut p = synth("old", 1e12, 1e13);
    p.version = "tcs-machine-profile-v0".to_string();
    p.save(&path).unwrap();
    let err = format!("{:#}", MachineProfile::load(&path).unwrap_err());
    assert!(err.contains("unsupported machine-profile version"), "{err}");
    assert!(err.contains("tcs-machine-profile-v0"), "names the stale version: {err}");
    assert!(err.contains(PROFILE_VERSION), "names the wanted version: {err}");
    assert!(err.contains("tune"), "points at the fix: {err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn missing_profile_falls_back_to_the_static_table_bit_identically() {
    let gpu = Gpu::a100();
    let resolved = profile::resolve(None, &gpu).unwrap();
    // planning through the resolved builtin profile must produce the
    // plan the raw registry Gpu produces — same engine, t, temporal,
    // shards, and bit-identical predicted throughput
    let via_profile = planner::plan(&crossover_request(resolved.gpu()), None).unwrap();
    let via_registry = planner::plan(&crossover_request(gpu), None).unwrap();
    assert_eq!(via_profile.chosen.engine.name, via_registry.chosen.engine.name);
    assert_eq!(via_profile.chosen.t, via_registry.chosen.t);
    assert_eq!(via_profile.chosen.temporal, via_registry.chosen.temporal);
    assert_eq!(via_profile.chosen.shards, via_registry.chosen.shards);
    assert_eq!(
        via_profile.chosen.prediction.throughput.to_bits(),
        via_registry.chosen.prediction.throughput.to_bits(),
        "builtin fallback must be bit-identical"
    );
}

#[test]
fn swapping_profiles_flips_the_temporal_and_shard_crossovers() {
    // Machine-checked against an independent Python port of the
    // planner's scalar scoring (see the PR description):
    //
    //   P = 1e13, B = 1e11 (ridge 100, compute-rich): every realization
    //   is memory-bound; the fused sweep rides free redundancy and the
    //   κ=1 sweep shards saturate the lanes
    //       → EBISU t=4 SWEEP, shards = 4.
    //
    //   P = 1e13, B = 1e12 (ridge 10, bandwidth-rich): the fused-sweep
    //   intensity (2t+1)³/8 crosses the ridge, redundant flops start to
    //   cost, and the thin 4-plane dim-0 domain makes every shard
    //   trapezoid recompute-dominated (κ up to 2.33)
    //       → EBISU t=3 BLOCKED, shards = 1.
    //
    // Same request; only the profile constants differ.
    let sweepy = synth("synthetic-compute-rich", 1e11, 1e13);
    let blocky = synth("synthetic-bandwidth-rich", 1e12, 1e13);

    let p1 = planner::plan(&crossover_request(sweepy.gpu()), None).unwrap();
    assert_eq!(p1.chosen.engine.name, "EBISU");
    assert_eq!(p1.chosen.temporal, TemporalMode::Sweep);
    assert_eq!(p1.chosen.t, 4);
    assert_eq!(p1.chosen.shards, 4, "κ=1 sweep shards must saturate the lanes");

    let p2 = planner::plan(&crossover_request(blocky.gpu()), None).unwrap();
    assert_eq!(p2.chosen.engine.name, "EBISU");
    assert_eq!(p2.chosen.temporal, TemporalMode::Blocked, "swap must flip temporal");
    assert_eq!(p2.chosen.t, 3);
    assert_eq!(p2.chosen.shards, 1, "swap must flip the shard crossover");

    // the profiles survive a disk round-trip and still flip the plan
    let path = std::env::temp_dir().join("tcs_tune_flip.json");
    blocky.save(&path).unwrap();
    let reloaded = MachineProfile::load(&path).unwrap();
    let p3 = planner::plan(&crossover_request(reloaded.gpu()), None).unwrap();
    assert_eq!(p3.chosen.temporal, TemporalMode::Blocked);
    assert_eq!(p3.chosen.shards, 1);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn blocked_crossover_moves_monotonically_with_bandwidth() {
    // For a fixed scalar (engine, t) candidate pair, blocked beats
    // sweep exactly when the fused intensity crosses the profile's
    // balance point — so as bandwidth rises (ridge falls) the
    // "blocked strictly wins" indicator must switch on at most once
    // and never switch back.
    for t in 2..=6usize {
        let mut prev_won = false;
        for i in 0..10 {
            let bw = 1e10 * 2f64.powi(i);
            let mut req = crossover_request(synth("mono", bw, 1e13).gpu());
            req.shards = ShardSpec::Fixed(1);
            let cands = planner::candidates(&req, None);
            let thr = |temporal: TemporalMode| {
                cands
                    .iter()
                    .find(|c| c.engine.name == "EBISU" && c.t == t && c.temporal == temporal)
                    .map(|c| c.prediction.throughput)
            };
            let (Some(sweep), Some(blocked)) =
                (thr(TemporalMode::Sweep), thr(TemporalMode::Blocked))
            else {
                panic!("EBISU t={t} variants must exist");
            };
            let won = blocked > sweep;
            assert!(
                won || !prev_won,
                "t={t}: blocked won at lower bandwidth but lost at {bw:e}"
            );
            prev_won = won;
        }
        // sanity: the crossover actually occurs somewhere in the sweep
        // for deep fusion (α(t) > 1 for t ≥ 2)
        if t >= 3 {
            let lo = crossover_request(synth("lo", 1e10, 1e13).gpu());
            let hi = crossover_request(synth("hi", 5.12e12, 1e13).gpu());
            let wins = |req: &Request| {
                let cands = planner::candidates(req, None);
                let get = |tm| {
                    cands
                        .iter()
                        .find(|c| {
                            c.engine.name == "EBISU" && c.t == t && c.temporal == tm && c.shards == 1
                        })
                        .unwrap()
                        .prediction
                        .throughput
                };
                get(TemporalMode::Blocked) > get(TemporalMode::Sweep)
            };
            assert!(!wins(&lo), "t={t}: memory-bound variants tie at low bandwidth");
            assert!(wins(&hi), "t={t}: blocked must win once the sweep crosses the ridge");
        }
    }
}

#[test]
fn drift_ewma_triggers_at_the_documented_threshold() {
    // The documented contract: DRIFT_THRESHOLD == the model's region
    // tolerance, flagging needs DRIFT_MIN_SAMPLES, and the EWMA is
    // |err|-based.
    assert_eq!(DRIFT_THRESHOLD, tc_stencil::model::calib::REGION_TOLERANCE);
    let t = DriftTracker::new(DRIFT_THRESHOLD);
    // a constant error exactly AT the threshold never flags (strict >)
    for _ in 0..10 {
        assert!(!t.record("r", DRIFT_THRESHOLD).over);
    }
    // a constant error just past it flags exactly at min samples
    let t = DriftTracker::new(DRIFT_THRESHOLD);
    let eps = DRIFT_THRESHOLD + 1e-6;
    let mut first_over = None;
    for i in 1..=10u64 {
        if t.record("r", eps).over && first_over.is_none() {
            first_over = Some(i);
        }
    }
    assert_eq!(first_over, Some(DRIFT_MIN_SAMPLES));
}

#[test]
fn measured_profile_plans_scalar_only() {
    // A measured CPU profile has no MMA paths, so planning against it
    // must never propose a tensor engine — the honest answer for the
    // machine actually serving the traffic.
    let measured =
        tc_stencil::tune::micro::measure(&tiny_probe_opts()).expect("probe run");
    let mut req = crossover_request(measured.gpu());
    req.pattern = StencilPattern::new(Shape::Box, 2, 1).unwrap();
    req.domain = vec![64, 64];
    req.dtype = Dtype::F32;
    let plan = planner::plan(&req, None).unwrap();
    assert!(!plan.chosen.engine.is_tensor());
    for c in &plan.alternatives {
        assert!(!c.engine.is_tensor(), "{} has no tensor path", measured.name);
    }
    // and the builtin A100 profile on the same request does propose one
    let a100 = engines::builtin_profile(&Gpu::a100());
    req.gpu = a100.gpu();
    let plan = planner::plan(&req, None).unwrap();
    assert!(plan.chosen.engine.is_tensor(), "registry profile keeps the TC plane");
}

fn tiny_probe_opts() -> tc_stencil::tune::micro::MicroOpts {
    tc_stencil::tune::micro::MicroOpts {
        reps: 2,
        stream_mib: 1,
        domain_side: 32,
        steps: 4,
        threads: 1,
        label: "quick",
    }
}

//! Integration: tiled halo-exchange scheduling over the PJRT runtime
//! reproduces the golden oracle on arbitrary (non-divisible) domains.
//!
//! Requires artifacts and the `pjrt` feature (compiled out otherwise);
//! the artifact-free equivalents live in rust/tests/backend_native.rs.

#![cfg(feature = "pjrt")]

use tc_stencil::backend::BackendKind;
use tc_stencil::coordinator::planner;
use tc_stencil::coordinator::scheduler::{run, Job};
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::Dtype;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::runtime::{manifest, Runtime};
use tc_stencil::sim::golden;
use tc_stencil::util::rng::Rng;

fn runtime() -> Runtime {
    Runtime::load(&manifest::default_dir())
        .expect("artifacts/ missing — run `make artifacts` first")
}

fn box_weights(d: usize, r: usize) -> Vec<f64> {
    let p = StencilPattern::new(Shape::Box, d, r).unwrap();
    let sup = p.support();
    let k = sup.count() as f64;
    sup.cells.iter().map(|&b| if b { 1.0 / k } else { 0.0 }).collect()
}

fn golden_launches(domain: &[usize], field: &[f64], w: &[f64], r: usize, spe: usize, launches: usize) -> golden::Field {
    let gw = golden::Weights::new(domain.len(), 2 * r + 1, w.to_vec());
    let mut cur = golden::Field::from_vec(domain, field.to_vec());
    for _ in 0..launches {
        cur = golden::apply_fused(&cur, &gw, spe);
    }
    cur
}

#[test]
fn tiled_2d_run_matches_golden_on_odd_domain() {
    let mut rt = runtime();
    // 100×76 is not a multiple of the 64² artifact payload — exercises
    // truncated tiles and zero-fill at the boundary.
    let domain = vec![100usize, 76];
    let n: usize = domain.iter().product();
    let mut rng = Rng::new(0xBEEF);
    let init: Vec<f64> = (0..n).map(|_| rng.normal() as f32 as f64).collect();
    let weights = box_weights(2, 1);
    let artifact = "decompose_box2d_r1_t3_f32_g64x64";
    let mut field = init.clone();
    let job = Job {
        artifact: artifact.into(),
        domain: domain.clone(),
        steps: 6, // two launches of t=3
        weights: weights.clone(),
        threads: 2,
    };
    let metrics = run(&mut rt, &job, &mut field).unwrap();
    assert_eq!(metrics.steps, 6);
    let want = golden_launches(&domain, &init, &weights, 1, 3, 2);
    let got = golden::Field::from_vec(&domain, field);
    let err = got.max_abs_diff(&want);
    assert!(err < 5e-4, "tiled vs golden: max|Δ|={err:.3e}");
}

#[test]
fn tiled_3d_run_matches_golden() {
    let mut rt = runtime();
    let domain = vec![20usize, 18, 22];
    let n: usize = domain.iter().product();
    let mut rng = Rng::new(0xCAFE);
    let init: Vec<f64> = (0..n).map(|_| rng.normal() as f32 as f64).collect();
    let weights = box_weights(3, 1);
    let mut field = init.clone();
    let job = Job {
        artifact: "direct_box3d_r1_t1_f32_g16x16x16".into(),
        domain: domain.clone(),
        steps: 2,
        weights: weights.clone(),
        threads: 4,
    };
    run(&mut rt, &job, &mut field).unwrap();
    let want = golden_launches(&domain, &init, &weights, 1, 1, 2);
    let got = golden::Field::from_vec(&domain, field);
    let err = got.max_abs_diff(&want);
    assert!(err < 5e-4, "3d tiled vs golden: max|Δ|={err:.3e}");
}

#[test]
fn thread_count_does_not_change_results() {
    let mut rt = runtime();
    let domain = vec![90usize, 90];
    let n: usize = domain.iter().product();
    let mut rng = Rng::new(1);
    let init: Vec<f64> = (0..n).map(|_| rng.normal() as f32 as f64).collect();
    let weights = box_weights(2, 1);
    let mut results = Vec::new();
    for threads in [1usize, 3, 8] {
        let mut field = init.clone();
        let job = Job {
            artifact: "direct_box2d_r1_t2_f32_g64x64".into(),
            domain: domain.clone(),
            steps: 4,
            weights: weights.clone(),
            threads,
        };
        run(&mut rt, &job, &mut field).unwrap();
        results.push(field);
    }
    assert_eq!(results[0], results[1]);
    assert_eq!(results[0], results[2]);
}

#[test]
fn rejects_step_mismatch_and_bad_field() {
    let mut rt = runtime();
    let weights = box_weights(2, 1);
    let mut field = vec![0.0; 64 * 64];
    let mut job = Job {
        artifact: "direct_box2d_r1_t3_f32_g64x64".into(),
        domain: vec![64, 64],
        steps: 4, // not a multiple of 3
        weights: weights.clone(),
        threads: 1,
    };
    assert!(run(&mut rt, &job, &mut field).is_err());
    job.steps = 3;
    let mut short = vec![0.0; 10];
    assert!(run(&mut rt, &job, &mut short).is_err());
}

#[test]
fn planner_artifact_mode_yields_runnable_plan() {
    let rt = runtime();
    let req = planner::Request {
        pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
        dtype: Dtype::F32,
        domain: vec![256, 256],
        steps: 8,
        gpu: Gpu::a100(),
        backend: BackendKind::Pjrt,
        max_t: 8,
        temporal: tc_stencil::backend::TemporalMode::Auto,
        shards: tc_stencil::coordinator::grid::ShardSpec::Fixed(1),
        lanes: 1,
        threads: 1,
        kernels: tc_stencil::backend::kernels::KernelMode::Auto,
        kernel_peaks: Vec::new(),
    };
    let plan = planner::plan(&req, Some(&rt.manifest)).unwrap();
    let name = plan.chosen.artifact.expect("artifact-constrained plan");
    // the chosen artifact must exist and match the request
    let meta = rt.manifest.get(&name).unwrap();
    assert_eq!(meta.shape, Shape::Box);
    assert_eq!(meta.d, 2);
    assert_eq!(meta.r, 1);
    assert_eq!(meta.dtype, Dtype::F32);
    assert_eq!(meta.t, plan.chosen.t);
}

#[test]
fn end_to_end_plan_then_run() {
    let mut rt = runtime();
    let req = planner::Request {
        pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
        dtype: Dtype::F32,
        domain: vec![80, 80],
        steps: 8,
        gpu: Gpu::a100(),
        backend: BackendKind::Pjrt,
        max_t: 4,
        temporal: tc_stencil::backend::TemporalMode::Auto,
        shards: tc_stencil::coordinator::grid::ShardSpec::Fixed(1),
        lanes: 1,
        threads: 1,
        kernels: tc_stencil::backend::kernels::KernelMode::Auto,
        kernel_peaks: Vec::new(),
    };
    let plan = planner::plan(&req, Some(&rt.manifest)).unwrap();
    let artifact = plan.chosen.artifact.unwrap();
    let meta = rt.manifest.get(&artifact).unwrap().clone();
    let spe = meta.steps_per_exec();
    let steps = 8usize.div_ceil(spe) * spe;
    let domain = vec![80usize, 80];
    let n: usize = domain.iter().product();
    let mut rng = Rng::new(3);
    let init: Vec<f64> = (0..n).map(|_| rng.normal() as f32 as f64).collect();
    let weights = box_weights(2, 1);
    let mut field = init.clone();
    let job = Job { artifact, domain: domain.clone(), steps, weights: weights.clone(), threads: 2 };
    let metrics = run(&mut rt, &job, &mut field).unwrap();
    assert!(metrics.throughput() > 0.0);
    let want = golden_launches(&domain, &init, &weights, 1, spe, steps / spe);
    let got = golden::Field::from_vec(&domain, field);
    assert!(got.max_abs_diff(&want) < 1e-3);
}

//! Integration: every AOT artifact executes on PJRT-CPU and matches the
//! rust-native golden oracle (no shared code with the Python build path).
//!
//! Requires `make artifacts` to have produced ./artifacts and a build
//! with the `pjrt` feature (the whole file is compiled out otherwise).

#![cfg(feature = "pjrt")]

use tc_stencil::model::perf::Dtype;
use tc_stencil::model::sparsity::Scheme;
use tc_stencil::runtime::{manifest, Runtime, TensorData};
use tc_stencil::sim::golden;
use tc_stencil::util::rng::Rng;

fn runtime() -> Runtime {
    let dir = manifest::default_dir();
    Runtime::load(&dir).expect(
        "artifacts/ missing or unreadable — run `make artifacts` before `cargo test`",
    )
}

fn random_field(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Normalized box/star weights over the artifact's hull.
fn pattern_weights(meta: &tc_stencil::runtime::ArtifactMeta) -> Vec<f64> {
    let p = meta.pattern().unwrap();
    let sup = p.support();
    let k = sup.count() as f64;
    sup.cells.iter().map(|&b| if b { 1.0 / k } else { 0.0 }).collect()
}

fn to_tensor(dtype: Dtype, v: &[f64]) -> TensorData {
    match dtype {
        Dtype::F32 => TensorData::F32(v.iter().map(|&x| x as f32).collect()),
        Dtype::F64 => TensorData::F64(v.to_vec()),
    }
}

fn tol(dtype: Dtype, t: usize) -> f64 {
    match dtype {
        Dtype::F32 => 5e-5 * t as f64,
        Dtype::F64 => 1e-10 * t as f64,
    }
}

#[test]
fn platform_is_cpu_pjrt() {
    let rt = runtime();
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    assert!(rt.manifest.variants.len() >= 20, "expected the full AOT matrix");
}

#[test]
fn every_artifact_matches_golden_oracle() {
    let mut rt = runtime();
    let metas = rt.manifest.variants.clone();
    let mut rng = Rng::new(0xA100);
    let mut checked = 0;
    for meta in &metas {
        let n = meta.points() as usize;
        let field = random_field(&mut rng, n);
        let weights = pattern_weights(meta);
        let x = to_tensor(meta.dtype, &field);
        let w = to_tensor(meta.dtype, &weights);
        let out = rt
            .execute(&meta.name, &x, &w)
            .unwrap_or_else(|e| panic!("{}: {e:#}", meta.name));
        let gw = golden::Weights::new(meta.d, 2 * meta.r + 1, weights.clone());
        let gf = golden::Field::from_vec(&meta.grid, field.clone());
        // Account for the f32 round-trip of the inputs.
        let gf = match meta.dtype {
            Dtype::F32 => golden::Field::from_vec(
                &meta.grid,
                field.iter().map(|&v| v as f32 as f64).collect(),
            ),
            Dtype::F64 => gf,
        };
        let want = match meta.scheme {
            // direct kernels do t sequential masked steps, n_outer times
            Scheme::Direct => {
                let mut cur = gf;
                for _ in 0..meta.n_outer {
                    cur = golden::apply_steps(&cur, &gw, meta.t);
                }
                cur
            }
            // monolithic schemes apply the fused kernel once per launch
            _ => golden::apply_fused(&gf, &gw, meta.t),
        };
        let got = golden::Field::from_vec(&meta.grid, out.to_f64_vec());
        let err = got.max_abs_diff(&want);
        assert!(
            err < tol(meta.dtype, meta.t * meta.n_outer),
            "{}: max|Δ|={err:.3e}",
            meta.name
        );
        checked += 1;
    }
    assert_eq!(checked, metas.len());
}

#[test]
fn executable_cache_compiles_once() {
    let mut rt = runtime();
    let meta = rt.manifest.variants[0].clone();
    let n = meta.points() as usize;
    let mut rng = Rng::new(7);
    let field = random_field(&mut rng, n);
    let weights = pattern_weights(&meta);
    let x = to_tensor(meta.dtype, &field);
    let w = to_tensor(meta.dtype, &weights);
    rt.execute(&meta.name, &x, &w).unwrap();
    let compiles_after_first = rt.stats.compiles;
    for _ in 0..3 {
        rt.execute(&meta.name, &x, &w).unwrap();
    }
    assert_eq!(rt.stats.compiles, compiles_after_first, "cache must prevent recompiles");
    assert_eq!(rt.stats.executions, 4);
    assert_eq!(rt.cached(), 1);
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let mut rt = runtime();
    let meta = rt.manifest.variants[0].clone();
    let weights = pattern_weights(&meta);
    let w = to_tensor(meta.dtype, &weights);
    // wrong field size
    let bad_x = to_tensor(meta.dtype, &vec![0.0; 10]);
    assert!(rt.execute(&meta.name, &bad_x, &w).is_err());
    // wrong weights size
    let x = to_tensor(meta.dtype, &vec![0.0; meta.points() as usize]);
    let bad_w = to_tensor(meta.dtype, &vec![0.0; 2]);
    assert!(rt.execute(&meta.name, &x, &bad_w).is_err());
    // wrong dtype
    let flip = match meta.dtype {
        Dtype::F32 => TensorData::F64(vec![0.0; meta.points() as usize]),
        Dtype::F64 => TensorData::F32(vec![0.0; meta.points() as usize]),
    };
    assert!(rt.execute(&meta.name, &flip, &w).is_err());
}

#[test]
fn unknown_artifact_errors() {
    let mut rt = runtime();
    let x = TensorData::F32(vec![0.0; 4]);
    assert!(rt.execute("no_such_variant", &x, &x).is_err());
}

#[test]
fn chain_artifact_equals_repeated_launches() {
    let mut rt = runtime();
    let Some(chain) = rt
        .manifest
        .variants
        .iter()
        .find(|v| v.n_outer > 1)
        .cloned()
    else {
        panic!("manifest must carry a chain variant (ablation d)");
    };
    let single = rt
        .manifest
        .find(chain.scheme, chain.shape, chain.d, chain.r, chain.t, chain.dtype)
        .expect("matching single-step artifact")
        .clone();
    let n = chain.points() as usize;
    let mut rng = Rng::new(42);
    let field = random_field(&mut rng, n);
    let weights = pattern_weights(&chain);
    let x = to_tensor(chain.dtype, &field);
    let w = to_tensor(chain.dtype, &weights);
    let fused = rt.execute(&chain.name, &x, &w).unwrap().to_f64_vec();
    // n_outer sequential launches of the single-step artifact
    let mut cur = x.clone();
    for _ in 0..chain.n_outer {
        cur = rt.execute(&single.name, &cur, &w).unwrap();
    }
    let stepped = cur.to_f64_vec();
    let max_err = fused
        .iter()
        .zip(&stepped)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    assert!(max_err < 1e-4, "chain vs launches: max|Δ|={max_err:.3e}");
}

#[test]
fn weights_are_truly_dynamic() {
    // The paper requires runtime kernel values (§5.1); two different
    // weight sets through the same executable must give different results.
    let mut rt = runtime();
    let meta = rt
        .manifest
        .find(Scheme::Direct, tc_stencil::Shape::Box, 2, 1, 1, Dtype::F32)
        .unwrap()
        .clone();
    let n = meta.points() as usize;
    let mut rng = Rng::new(9);
    let field = random_field(&mut rng, n);
    let x = to_tensor(meta.dtype, &field);
    let w1 = pattern_weights(&meta);
    let mut w2 = w1.clone();
    w2[4] *= 2.0; // perturb the center weight
    let y1 = rt.execute(&meta.name, &x, &to_tensor(meta.dtype, &w1)).unwrap();
    let y2 = rt.execute(&meta.name, &x, &to_tensor(meta.dtype, &w2)).unwrap();
    assert_ne!(y1.to_f64_vec(), y2.to_f64_vec());
}

//! Temporal blocking, end to end: the blocked native path must be f64
//! BIT-IDENTICAL to the sequential golden oracle (chained `apply_once`)
//! across star/box patterns, odd domain sizes, fused depths t ∈ {1..6},
//! remainder step counts, and thread counts — and the planner must pick
//! the blocked candidate exactly when the model's fused-kernel
//! intensity crosses the machine balance point.

use tc_stencil::backend::{self, Backend, NativeBackend, TemporalMode};
use tc_stencil::coordinator::planner::{self, Request};
use tc_stencil::hardware::Gpu;
use tc_stencil::model::calib;
use tc_stencil::model::perf::{Dtype, Unit, Workload};
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::sim::golden;
use tc_stencil::util::prop::{forall, Config};
use tc_stencil::util::rng::Rng;

/// A randomly drawn blocked job (compact for shrink reports).
#[derive(Debug, Clone)]
struct Case {
    shape: Shape,
    d: usize,
    r: usize,
    t: usize,
    steps: usize,
    dtype: Dtype,
    domain: Vec<usize>,
    threads: usize,
    seed: u64,
}

fn gen_case(rng: &mut Rng) -> Case {
    let shape = if rng.f64() < 0.5 { Shape::Box } else { Shape::Star };
    let d = rng.range_usize(1, 3);
    let r = rng.range_usize(1, 2);
    let t = rng.range_usize(1, 6);
    let steps = rng.range_usize(0, 2 * t + 1); // exercises partial blocks
    let dtype = if rng.f64() < 0.5 { Dtype::F32 } else { Dtype::F64 };
    let max_side = match d {
        1 => 64,
        2 => 24,
        _ => 12,
    };
    // Odd sizes stress tile/halo boundaries that never divide evenly.
    let domain: Vec<usize> = (0..d).map(|_| rng.range_usize(1, max_side) | 1).collect();
    Case {
        shape,
        d,
        r,
        t,
        steps,
        dtype,
        domain,
        threads: rng.range_usize(1, 4),
        seed: rng.next_u64(),
    }
}

fn random_weights(rng: &mut Rng, d: usize, r: usize, shape: Shape) -> Vec<f64> {
    let p = StencilPattern::new(shape, d, r).unwrap();
    let sup = p.support();
    let mut w: Vec<f64> = sup
        .cells
        .iter()
        .map(|&b| if b { rng.range_f64(-0.5, 0.5) } else { 0.0 })
        .collect();
    let l1: f64 = w.iter().map(|v| v.abs()).sum();
    if l1 > 1e-9 {
        for v in &mut w {
            *v /= l1;
        }
    }
    w
}

fn run_case(case: &Case) -> Result<(), String> {
    let mut rng = Rng::new(case.seed);
    let weights = random_weights(&mut rng, case.d, case.r, case.shape);
    let n: usize = case.domain.iter().product();
    let init: Vec<f64> = match case.dtype {
        Dtype::F32 => (0..n).map(|_| rng.normal() as f32 as f64).collect(),
        Dtype::F64 => (0..n).map(|_| rng.normal()).collect(),
    };
    let job = backend::Job {
        pattern: StencilPattern::new(case.shape, case.d, case.r).unwrap(),
        dtype: case.dtype,
        domain: case.domain.clone(),
        steps: case.steps,
        t: case.t,
        temporal: TemporalMode::Blocked,
        weights: weights.clone(),
        threads: case.threads,
    };
    let mut field = init.clone();
    let metrics = NativeBackend::new()
        .advance(&job, &mut field)
        .map_err(|e| format!("{e:#}"))?;
    // Blocked semantics are sequential: `steps` chained base steps,
    // regardless of the tile depth t.
    let w = golden::Weights::new(case.d, 2 * case.r + 1, weights);
    let want =
        golden::apply_steps(&golden::Field::from_vec(&case.domain, init), &w, case.steps);
    let got = golden::Field::from_vec(&case.domain, field);
    let err = got.max_abs_diff(&want);
    match case.dtype {
        Dtype::F64 if err != 0.0 => {
            return Err(format!("f64 not bit-identical: max|Δ|={err:.3e}"))
        }
        Dtype::F32 if err > 2e-4 * (case.steps.max(1) as f64) => {
            return Err(format!("f32 outside rounding tolerance: max|Δ|={err:.3e}"))
        }
        _ => {}
    }
    // Instrumentation invariant: every executing blocked job accounts
    // its traffic and flops.  (Tight model-region bounds live in the
    // large-domain tests below — tiny domains clamp the halo so hard
    // that per-block intensity can exceed the asymptotic t·K/D.)
    if case.steps > 0 {
        if metrics.bytes_moved == 0 || metrics.flops == 0 {
            return Err("blocked run left traffic accounting empty".into());
        }
    } else if metrics.bytes_moved != 0 {
        return Err("zero-step run accounted phantom traffic".into());
    }
    Ok(())
}

#[test]
fn property_blocked_matches_sequential_oracle() {
    forall(Config::with_cases(120), gen_case, run_case).unwrap();
}

#[test]
fn blocked_threads_do_not_change_bits() {
    forall(
        Config { seed: 0xB10C, ..Config::with_cases(30) },
        gen_case,
        |case| {
            let mut results: Vec<Vec<f64>> = Vec::new();
            for threads in [1usize, 6] {
                let mut rng = Rng::new(case.seed);
                let weights = random_weights(&mut rng, case.d, case.r, case.shape);
                let n: usize = case.domain.iter().product();
                let init: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let job = backend::Job {
                    pattern: StencilPattern::new(case.shape, case.d, case.r).unwrap(),
                    dtype: case.dtype,
                    domain: case.domain.clone(),
                    steps: case.steps,
                    t: case.t,
                    temporal: TemporalMode::Blocked,
                    weights,
                    threads,
                };
                let mut field = init;
                NativeBackend::new()
                    .advance(&job, &mut field)
                    .map_err(|e| format!("{e:#}"))?;
                results.push(field);
            }
            if results[0] == results[1] {
                Ok(())
            } else {
                Err("thread count changed the bits".into())
            }
        },
    )
    .unwrap();
}

#[test]
fn planner_blocked_iff_fused_intensity_crosses_machine_balance() {
    // Regression for the temporal decision rule: sweeping the fusion
    // depth with the planner pinned to one depth at a time, the chosen
    // scalar-unit candidate must be the blocked variant exactly when
    // α·t·K/D (the fused-kernel intensity the sweep would realize)
    // crosses the CUDA roof's ridge.  V100 has no tensor units, so the
    // scalar pair decides every plan.
    let gpu = Gpu::v100();
    let roof = gpu.roof(Unit::CudaCore, Dtype::F32).unwrap();
    let pattern = StencilPattern::new(Shape::Box, 2, 1).unwrap();
    let mut saw_blocked = false;
    let mut saw_sweep = false;
    for t in 1..=8usize {
        let req = Request {
            pattern,
            dtype: Dtype::F32,
            domain: vec![256, 256],
            steps: 64,
            gpu: gpu.clone(),
            backend: backend::BackendKind::Native,
            max_t: t,
            temporal: TemporalMode::Auto,
            shards: tc_stencil::coordinator::grid::ShardSpec::Fixed(1),
            lanes: 1,
            threads: 1,
            kernels: tc_stencil::backend::kernels::KernelMode::Auto,
            kernel_peaks: Vec::new(),
        };
        let plan = planner::plan(&req, None).unwrap();
        // Find the best candidate at exactly depth t (the pinned depth
        // may lose the argmax to a shallower one; compare variants at
        // the same depth instead).
        let best_at_t = std::iter::once(&plan.chosen)
            .chain(plan.alternatives.iter())
            .find(|c| c.t == t)
            .unwrap();
        let w = Workload::new(pattern, t, Dtype::F32);
        let crossed = w.intensity_fused_sweep() >= roof.ridge();
        let expect = if crossed { TemporalMode::Blocked } else { TemporalMode::Sweep };
        assert_eq!(
            best_at_t.temporal, expect,
            "t={t}: fused I={:.2} vs ridge {:.2}",
            w.intensity_fused_sweep(),
            roof.ridge()
        );
        saw_blocked |= crossed;
        saw_sweep |= !crossed;
    }
    assert!(saw_blocked && saw_sweep, "sweep must straddle the balance point");
}

#[test]
fn large_domain_blocked_intensity_lands_in_model_region() {
    // 256×256 f64 star-1 at t=4: many cache-sized tiles, whole blocks —
    // the achieved intensity must sit within calib's predicted region,
    // below the t·K/D ceiling (halo overhead only).
    let job = backend::Job {
        pattern: StencilPattern::new(Shape::Star, 2, 1).unwrap(),
        dtype: Dtype::F64,
        domain: vec![256, 256],
        steps: 8,
        t: 4,
        temporal: TemporalMode::Blocked,
        weights: StencilPattern::new(Shape::Star, 2, 1).unwrap().uniform_weights(),
        threads: 2,
    };
    let mut field = golden::gaussian(&[256, 256]);
    let m = NativeBackend::new().advance(&job, &mut field).unwrap();
    let w = Workload::new(job.pattern, job.t, job.dtype);
    let rep = calib::report(&w, job.steps, true, m.achieved_intensity());
    assert!((rep.predicted - 4.0 * 5.0 / 8.0).abs() < 1e-12, "t·K/D = 2.5");
    assert!(rep.measured > 0.0 && rep.measured <= rep.predicted + 1e-9);
    assert!(rep.within_region, "err {:+.3}", rep.rel_error);
    // and the sweep path of the same job measures the fused-kernel
    // intensity instead (α·t·K/D with K^(t) non-zeros).
    let mut sweep_job = job.clone();
    sweep_job.temporal = TemporalMode::Sweep;
    let mut field = golden::gaussian(&[256, 256]);
    let ms = NativeBackend::new().advance(&sweep_job, &mut field).unwrap();
    let srep = calib::report(&w, job.steps, false, ms.achieved_intensity());
    assert!(srep.within_region, "sweep err {:+.3}", srep.rel_error);
    assert!(
        ms.achieved_intensity() > m.achieved_intensity(),
        "fused sweeps burn α× the flops for the same traffic"
    );
    assert!(ms.flops > m.flops, "redundancy must show up in the flop counter");
}

#[test]
fn blocked_and_sweep_agree_in_the_deep_interior() {
    // The two semantics differ only within t·r of the boundary: at the
    // domain centre they must agree to rounding (they are both K^t).
    let n = 41usize;
    let t = 3usize;
    let job = |temporal| backend::Job {
        pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
        dtype: Dtype::F64,
        domain: vec![n, n],
        steps: t,
        t,
        temporal,
        weights: vec![1.0 / 9.0; 9],
        threads: 2,
    };
    let init = golden::gaussian(&[n, n]);
    let mut blocked = init.clone();
    NativeBackend::new().advance(&job(TemporalMode::Blocked), &mut blocked).unwrap();
    let mut sweep = init.clone();
    NativeBackend::new().advance(&job(TemporalMode::Sweep), &mut sweep).unwrap();
    let c = n / 2;
    for di in 0..5usize {
        for dj in 0..5usize {
            let i = (c - 2 + di) * n + (c - 2 + dj);
            assert!(
                (blocked[i] - sweep[i]).abs() < 1e-12,
                "interior point ({di},{dj}): {} vs {}",
                blocked[i],
                sweep[i]
            );
        }
    }
    // ...and the boundary genuinely differs (zero-halo re-application).
    let max_edge_diff = (0..n)
        .map(|j| (blocked[j] - sweep[j]).abs())
        .fold(0.0f64, f64::max);
    assert!(max_edge_diff > 1e-9, "boundary rows should differ across semantics");
}

//! Ablations for the design choices called out in DESIGN.md §4:
//!   (a) planner-chosen fusion depth vs fixed t
//!   (b) exact Minkowski α vs the box closed form applied to stars
//!   (c) L2-filter model on/off vs the Table-2 M deltas
//!   (d) rust-driven launch loop vs in-graph lax.scan chain (real timing)
//!   (e) gather worker threads 1 vs 4 (real timing)

use tc_stencil::backend::{BackendKind, TemporalMode};
use tc_stencil::coordinator::planner::{plan, Request};
use tc_stencil::coordinator::scheduler::{run, Job};
use tc_stencil::engines;
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::{Dtype, Workload};
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::runtime::{manifest, Runtime, TensorData};
use tc_stencil::sim::cache::L2Model;
use tc_stencil::sim::counters::{measured_m, Schedule};
use tc_stencil::sim::exec;
use tc_stencil::util::bench::Bench;
use tc_stencil::util::rng::Rng;

fn main() {
    ablation_a_planner_vs_fixed_t();
    ablation_b_alpha_formula();
    ablation_c_l2_filter();
    ablation_d_and_e_real_timings();
}

fn ablation_a_planner_vs_fixed_t() {
    println!("### (a) planner-chosen t vs fixed t (Box-2D1R float, A100)");
    let gpu = Gpu::a100();
    let req = Request {
        pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
        dtype: Dtype::F32,
        domain: vec![256, 256],
        steps: 64,
        gpu: gpu.clone(),
        backend: BackendKind::Auto,
        max_t: 8,
        temporal: TemporalMode::Auto,
        shards: tc_stencil::coordinator::grid::ShardSpec::Fixed(1),
        lanes: 1,
        threads: 1,
        kernels: tc_stencil::backend::kernels::KernelMode::Auto,
        kernel_peaks: Vec::new(),
    };
    let p = plan(&req, None).unwrap();
    let auto = p.chosen.prediction.gstencils();
    println!("  planner: {} t={} -> {:.1} GSt/s", p.chosen.engine.name, p.chosen.t, auto);
    for t in [1usize, 3, 7] {
        let w = Workload::new(req.pattern, t, Dtype::F32);
        let best = [engines::ebisu(), engines::convstencil(), engines::spider()]
            .iter()
            .filter_map(|e| exec::predict(e, &w, &gpu).ok())
            .map(|pr| pr.gstencils())
            .fold(f64::NAN, f64::max);
        println!("  fixed t={t}: best engine -> {best:.1} GSt/s ({:.2}x of auto)", best / auto);
        assert!(best <= auto * 1.0001, "fixed t must never beat the planner");
    }
    println!();
}

fn ablation_b_alpha_formula() {
    println!("### (b) exact Minkowski α vs box closed form on star stencils");
    // Applying Eq. 10 (box closed form) to star patterns overstates the
    // fusion redundancy — the fused star support is an L1 ball, not a
    // cube.  Overstated α inflates C_TC and I_TC and can misclassify the
    // Tensor-Core bottleneck near the ridge.
    let gpu = Gpu::a100();
    let tc = gpu.roof(tc_stencil::model::perf::Unit::TensorCore, Dtype::F32).unwrap();
    let star = StencilPattern::new(Shape::Star, 2, 1).unwrap();
    let mut max_err = 0.0f64;
    let mut bound_flips = 0;
    for t in 1..=8usize {
        let w = Workload::new(star, t, Dtype::F32);
        let s = w.sparsity(tc_stencil::model::sparsity::Scheme::Decompose);
        let alpha_exact = w.alpha();
        let alpha_box = ((2 * t + 1) * (2 * t + 1)) as f64 / (t as f64 * 5.0);
        let err = (alpha_box - alpha_exact) / alpha_exact;
        max_err = max_err.max(err);
        let i_exact = t as f64 * alpha_exact / s * w.k() / 4.0;
        let i_box = t as f64 * alpha_box / s * w.k() / 4.0;
        let flip = (i_exact < tc.ridge()) != (i_box < tc.ridge());
        if flip {
            bound_flips += 1;
        }
        println!(
            "  t={t}: α_exact={alpha_exact:.3} α_boxform={alpha_box:.3} \
             (+{:.0}% error){}",
            err * 100.0,
            if flip { "  -> TC bound MISCLASSIFIED" } else { "" }
        );
    }
    println!(
        "  box formula overstates star α by up to {:.0}%; TC-bound \
         misclassifications: {bound_flips}/8\n",
        max_err * 100.0
    );
    assert!(max_err > 0.5, "the closed form must be badly wrong for stars");
}

fn ablation_c_l2_filter() {
    println!("### (c) L2-filter model on/off vs Table-2 M deltas");
    let w = Workload::new(StencilPattern::new(Shape::Box, 2, 1).unwrap(), 3, Dtype::F64);
    let on = Schedule::cuda_core();
    let mut off = Schedule::cuda_core();
    off.l2 = L2Model::off();
    let m_on = measured_m(&w, &on);
    let m_off = measured_m(&w, &off);
    let m_a = w.m_bytes();
    println!("  analytical M = {m_a}");
    println!("  with L2 model:   {m_on:.3}  (Δ {:+.2}%)  — paper row 1: −0.30%", (m_on - m_a) / m_a * 100.0);
    println!("  without L2 model:{m_off:.3}  (Δ {:+.2}%)  — halo spill only", (m_off - m_a) / m_a * 100.0);
    assert!(m_on < m_a, "with the filter M must undershoot (paper sign)");
    assert!(m_off > m_a, "without the filter the halo reads dominate");
    println!();
}

fn ablation_d_and_e_real_timings() {
    println!("### (d) rust launch loop vs in-graph scan chain + (e) gather threads");
    let mut rt = Runtime::load(&manifest::default_dir()).expect("run `make artifacts`");
    let mut rng = Rng::new(5);
    let x = TensorData::F32(rng.normal_vec_f32(64 * 64));
    let w = TensorData::F32(vec![1.0 / 9.0; 9]);
    let mut b = Bench::new("ablation");
    // (d): 8 steps as 8 rust launches vs one chain8 artifact.
    let single = "direct_box2d_r1_t1_f32_g64x64";
    let chain = "direct_box2d_r1_t1_f32_g64x64_chain8";
    rt.execute(single, &x, &w).unwrap();
    rt.execute(chain, &x, &w).unwrap();
    b.run_items("rust_loop_8x", Some(64.0 * 64.0 * 8.0), || {
        let mut cur = x.clone();
        for _ in 0..8 {
            cur = rt.execute(single, &cur, &w).unwrap();
        }
        std::hint::black_box(cur);
    });
    b.run_items("scan_chain8", Some(64.0 * 64.0 * 8.0), || {
        std::hint::black_box(rt.execute(chain, &x, &w).unwrap());
    });
    // (e): coordinator gather threads.
    let field: Vec<f64> = (0..256 * 256).map(|_| rng.normal()).collect();
    for threads in [1usize, 4] {
        let job = Job {
            artifact: "direct_box2d_r1_t3_f32_g64x64".into(),
            domain: vec![256, 256],
            steps: 3,
            weights: vec![1.0 / 9.0; 9],
            threads,
        };
        let mut f = field.clone();
        run(&mut rt, &job, &mut f).unwrap(); // warm
        b.run_items(&format!("coordinator_threads_{threads}"), Some(256.0 * 256.0 * 3.0), || {
            let mut ff = field.clone();
            std::hint::black_box(run(&mut rt, &job, &mut ff).unwrap());
        });
    }
}

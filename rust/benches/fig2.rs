//! Regenerates paper Fig 2: speedups of Tensor-Core implementations over
//! DRStencil, plus real CPU-PJRT latencies of the corresponding kernel
//! schemes (direct vs flatten vs decompose vs sparse24).

use tc_stencil::hardware::Gpu;
use tc_stencil::report;
use tc_stencil::runtime::{manifest, Runtime, TensorData};
use tc_stencil::util::bench::Bench;
use tc_stencil::util::rng::Rng;

fn main() {
    let gpu = Gpu::a100();
    println!("{}", report::fig2(&gpu).render());
    println!(
        "paper Fig 2 reports 1.48x / 2.23x / 4.60x for TCStencil /\n\
         ConvStencil / SPIDER — the ordering above must match.\n"
    );

    let mut rt = Runtime::load(&manifest::default_dir()).expect("run `make artifacts`");
    let mut rng = Rng::new(2);
    let x = TensorData::F32(rng.normal_vec_f32(64 * 64));
    let w = TensorData::F32(vec![1.0 / 9.0; 9]);
    let mut b = Bench::new("fig2/scheme-latency");
    for name in [
        "direct_box2d_r1_t3_f32_g64x64",
        "flatten_box2d_r1_t3_f32_g64x64",
        "decompose_box2d_r1_t3_f32_g64x64",
        "sparse24_box2d_r1_t3_f32_g64x64",
    ] {
        rt.execute(name, &x, &w).unwrap();
        b.run_items(name, Some((64 * 64 * 3) as f64), || {
            std::hint::black_box(rt.execute(name, &x, &w).unwrap());
        });
    }
}

//! Regenerates paper Fig 8/9: the four-scenario partition of workload
//! space and the per-scenario performance verdicts.

use tc_stencil::hardware::Gpu;
use tc_stencil::report;
use tc_stencil::util::bench::Bench;

fn main() {
    let gpu = Gpu::a100();
    println!("{}", report::fig8_regions(&gpu).render());
    let census = report::scenario_census(&gpu);
    println!(
        "scenario census over the sweep: S1={} S2={} S3={} S4={}\n",
        census[0], census[1], census[2], census[3]
    );
    // All four behaviours must be reachable on A100 (Fig 9's point).
    assert!(census.iter().filter(|&&c| c > 0).count() >= 3);

    let mut b = Bench::new("fig8");
    b.run("region_sweep", || {
        std::hint::black_box(report::fig8_regions(&gpu));
    });
}

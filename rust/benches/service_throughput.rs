//! Service-layer throughput: jobs/sec and p50/p99 request latency
//! through the bounded queue + worker pool, cold vs warm plan cache, on
//! the paper's workhorse shapes (star-2d, heat-3d).  Each client thread
//! owns a session and streams `advance` requests through the same
//! [`handle_line`] path a TCP connection uses — so the numbers include
//! protocol parsing, planning/cache, admission, queueing, and reply.
//!
//! Run with: `cargo bench --bench service_throughput` (BENCH_FAST=1 for
//! CI).  Emits BENCH_service.json for EXPERIMENTS.md-style tracking.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use tc_stencil::service::server::{handle_line, ServeOpts, Service, ServiceState};
use tc_stencil::util::json::Json;
use tc_stencil::util::stats;

struct ShapeCase {
    name: &'static str,
    shape: &'static str,
    d: usize,
    domain: &'static str,
    steps: usize,
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn run_case(case: &ShapeCase, clients: usize, per_client: usize) -> Json {
    let svc = Service::start(ServeOpts {
        workers: std::thread::available_parallelism().map(|n| n.get().min(4)).unwrap_or(2),
        max_queue: 256,
        artifacts_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
        ..Default::default()
    });
    let state: Arc<ServiceState> = svc.state();
    let create = |name: &str| {
        format!(
            r#"{{"op":"create_session","session":"{name}","shape":"{}","d":{},"r":1,"dtype":"double","domain":"{}","backend":"native","threads":1}}"#,
            case.shape, case.d, case.domain
        )
    };
    let advance =
        |name: &str| format!(r#"{{"op":"advance","session":"{name}","steps":{}}}"#, case.steps);

    // Cold: the very first advance pays the planner (cache miss).
    let (resp, _) = handle_line(&state, &create("cold"));
    assert!(resp.contains("\"ok\":true"), "{resp}");
    let t0 = Instant::now();
    let (resp, _) = handle_line(&state, &advance("cold"));
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(resp.contains("\"cache\":\"miss\""), "{resp}");

    // Warm: concurrent clients stream advances; every plan is a hit.
    let wall0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            let state = state.clone();
            let name = format!("warm{ci}");
            let create = create(&name);
            let advance = advance(&name);
            std::thread::spawn(move || {
                let (resp, _) = handle_line(&state, &create);
                assert!(resp.contains("\"ok\":true"), "{resp}");
                let mut lat_ns = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let t0 = Instant::now();
                    let (resp, _) = handle_line(&state, &advance);
                    lat_ns.push(t0.elapsed().as_nanos() as f64);
                    assert!(resp.contains("\"ok\":true"), "{resp}");
                }
                lat_ns
            })
        })
        .collect();
    let lat_ns: Vec<f64> =
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect();
    let wall_s = wall0.elapsed().as_secs_f64();

    let jobs = lat_ns.len();
    let jobs_per_sec = jobs as f64 / wall_s;
    let p50_ms = stats::percentile(&lat_ns, 50.0) / 1e6;
    let p99_ms = stats::percentile(&lat_ns, 99.0) / 1e6;
    let snap = state.counters.snapshot();
    println!(
        "{:<18} {jobs:>5} jobs  {jobs_per_sec:>9.1} jobs/s  cold {cold_ms:>8.3} ms  \
         p50 {p50_ms:>7.3} ms  p99 {p99_ms:>7.3} ms  plan hits {}/{}",
        case.name,
        snap.plan_hits,
        snap.plan_hits + snap.plan_misses,
    );
    assert!(snap.plan_hits > 0, "warm runs must hit the plan cache");
    drop(svc); // shutdown: close queue, join workers
    obj(vec![
        ("shape", Json::Str(case.name.to_string())),
        ("domain", Json::Str(case.domain.to_string())),
        ("steps", Json::Num(case.steps as f64)),
        ("clients", Json::Num(clients as f64)),
        ("jobs", Json::Num(jobs as f64)),
        ("jobs_per_sec", Json::Num(jobs_per_sec)),
        ("cold_ms", Json::Num(cold_ms)),
        ("warm_p50_ms", Json::Num(p50_ms)),
        ("warm_p99_ms", Json::Num(p99_ms)),
        ("plan_hits", Json::Num(snap.plan_hits as f64)),
        ("plan_misses", Json::Num(snap.plan_misses as f64)),
    ])
}

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    let (clients, per_client) = if fast { (2, 5) } else { (4, 50) };
    let cases = [
        ShapeCase { name: "star2d/192x192", shape: "star", d: 2, domain: "192x192", steps: 4 },
        ShapeCase { name: "heat3d/32x32x32", shape: "star", d: 3, domain: "32x32x32", steps: 2 },
    ];
    println!("### bench group: service_throughput ({clients} clients × {per_client} jobs)");
    let results: Vec<Json> = cases.iter().map(|c| run_case(c, clients, per_client)).collect();
    let doc = obj(vec![
        ("bench", Json::Str("service_throughput".to_string())),
        ("fast", Json::Bool(fast)),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_service.json", format!("{doc}\n")).expect("write BENCH_service.json");
    println!("wrote BENCH_service.json");
}

//! Service-layer throughput: jobs/sec and p50/p99 request latency
//! through the bounded queue + worker pool, cold vs warm plan cache, on
//! the paper's workhorse shapes (star-2d, heat-3d) — plus the sharded
//! large-domain bar: the same session advanced with `shards:1`
//! (monolithic) vs `shards:auto` (the planner's redundancy-adjusted
//! fan-out across the pool).  Each client thread owns a session and
//! streams `advance` requests through the same [`handle_line`] path a
//! TCP connection uses — so the numbers include protocol parsing,
//! planning/cache, admission, shard fan-out, and reply.
//!
//! Run with: `cargo bench --bench service_throughput` (BENCH_FAST=1 for
//! CI).  Emits BENCH_service.json (via `util::bench::write_bench_json`)
//! for EXPERIMENTS.md-style tracking.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use tc_stencil::service::server::{handle_line, ServeOpts, Service, ServiceState};
use tc_stencil::util::bench::write_bench_json;
use tc_stencil::util::json::Json;
use tc_stencil::util::stats;

struct ShapeCase {
    name: &'static str,
    shape: &'static str,
    d: usize,
    domain: &'static str,
    steps: usize,
}

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn run_case(case: &ShapeCase, clients: usize, per_client: usize) -> Json {
    let svc = Service::start(ServeOpts {
        workers: std::thread::available_parallelism().map(|n| n.get().min(4)).unwrap_or(2),
        max_queue: 256,
        artifacts_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
        ..Default::default()
    });
    let state: Arc<ServiceState> = svc.state();
    let create = |name: &str| {
        format!(
            r#"{{"op":"create_session","session":"{name}","shape":"{}","d":{},"r":1,"dtype":"double","domain":"{}","backend":"native","threads":1}}"#,
            case.shape, case.d, case.domain
        )
    };
    let advance =
        |name: &str| format!(r#"{{"op":"advance","session":"{name}","steps":{}}}"#, case.steps);

    // Cold: the very first advance pays the planner (cache miss).
    let (resp, _) = handle_line(&state, &create("cold"));
    assert!(resp.contains("\"ok\":true"), "{resp}");
    let t0 = Instant::now();
    let (resp, _) = handle_line(&state, &advance("cold"));
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(resp.contains("\"cache\":\"miss\""), "{resp}");

    // Warm: concurrent clients stream advances; every plan is a hit.
    let wall0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            let state = state.clone();
            let name = format!("warm{ci}");
            let create = create(&name);
            let advance = advance(&name);
            std::thread::spawn(move || {
                let (resp, _) = handle_line(&state, &create);
                assert!(resp.contains("\"ok\":true"), "{resp}");
                let mut lat_ns = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    let t0 = Instant::now();
                    let (resp, _) = handle_line(&state, &advance);
                    lat_ns.push(t0.elapsed().as_nanos() as f64);
                    assert!(resp.contains("\"ok\":true"), "{resp}");
                }
                lat_ns
            })
        })
        .collect();
    let lat_ns: Vec<f64> =
        handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect();
    let wall_s = wall0.elapsed().as_secs_f64();

    let jobs = lat_ns.len();
    let jobs_per_sec = jobs as f64 / wall_s;
    let p50_ms = stats::percentile(&lat_ns, 50.0) / 1e6;
    let p99_ms = stats::percentile(&lat_ns, 99.0) / 1e6;
    let snap = state.counters.snapshot();
    println!(
        "{:<18} {jobs:>5} jobs  {jobs_per_sec:>9.1} jobs/s  cold {cold_ms:>8.3} ms  \
         p50 {p50_ms:>7.3} ms  p99 {p99_ms:>7.3} ms  plan hits {}/{}",
        case.name,
        snap.plan_hits,
        snap.plan_hits + snap.plan_misses,
    );
    assert!(snap.plan_hits > 0, "warm runs must hit the plan cache");
    drop(svc); // shutdown: close queue, join workers
    obj(vec![
        ("shape", Json::Str(case.name.to_string())),
        ("domain", Json::Str(case.domain.to_string())),
        ("steps", Json::Num(case.steps as f64)),
        ("clients", Json::Num(clients as f64)),
        ("jobs", Json::Num(jobs as f64)),
        ("jobs_per_sec", Json::Num(jobs_per_sec)),
        ("cold_ms", Json::Num(cold_ms)),
        ("warm_p50_ms", Json::Num(p50_ms)),
        ("warm_p99_ms", Json::Num(p99_ms)),
        ("plan_hits", Json::Num(snap.plan_hits as f64)),
        ("plan_misses", Json::Num(snap.plan_misses as f64)),
    ])
}

/// The sharded large-domain bar: one thread-1 session on a 4-worker
/// pool, advanced with a pinned monolith (`shards:1`) and with the
/// planner's auto fan-out — the wall-clock ratio is the serving-plane
/// payoff the `model::shard::gain` model predicts.
fn run_sharded_bar(jobs: usize) -> Json {
    let svc = Service::start(ServeOpts {
        workers: 4,
        max_queue: 256,
        artifacts_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
        ..Default::default()
    });
    let state: Arc<ServiceState> = svc.state();
    let side = if std::env::var("BENCH_FAST").is_ok() { 256 } else { 1024 };
    let (resp, _) = handle_line(
        &state,
        &format!(
            r#"{{"op":"create_session","session":"big","shape":"star","d":2,"r":1,"dtype":"double","domain":"{side}x{side}","backend":"native","temporal":"sweep","threads":1}}"#
        ),
    );
    assert!(resp.contains("\"ok\":true"), "{resp}");
    let mut wall = [0.0f64; 2];
    let mut shards_seen = [0i64; 2];
    for (i, spec) in ["1", "\"auto\""].iter().enumerate() {
        let line = format!(
            r#"{{"op":"advance","session":"big","steps":2,"t":1,"shards":{spec}}}"#
        );
        let t0 = Instant::now();
        for _ in 0..jobs {
            let (resp, _) = handle_line(&state, &line);
            assert!(resp.contains("\"ok\":true"), "{resp}");
            let j = Json::parse_line(&resp).unwrap();
            shards_seen[i] = j.get("shards").unwrap().as_i64().unwrap();
        }
        wall[i] = t0.elapsed().as_secs_f64();
    }
    let speedup = wall[0] / wall[1];
    println!(
        "sharded bar {side}x{side}: shards=1 {:.3}s vs shards=auto({}) {:.3}s -> {speedup:.2}x",
        wall[0], shards_seen[1], wall[1]
    );
    drop(svc);
    obj(vec![
        ("bar", Json::Str(format!("sharded/{side}x{side}"))),
        ("jobs", Json::Num(jobs as f64)),
        ("mono_s", Json::Num(wall[0])),
        ("auto_s", Json::Num(wall[1])),
        ("auto_shards", Json::Num(shards_seen[1] as f64)),
        ("speedup", Json::Num(speedup)),
    ])
}

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    let (clients, per_client) = if fast { (2, 5) } else { (4, 50) };
    let cases = [
        ShapeCase { name: "star2d/192x192", shape: "star", d: 2, domain: "192x192", steps: 4 },
        ShapeCase { name: "heat3d/32x32x32", shape: "star", d: 3, domain: "32x32x32", steps: 2 },
    ];
    println!("### bench group: service_throughput ({clients} clients × {per_client} jobs)");
    let results: Vec<Json> = cases.iter().map(|c| run_case(c, clients, per_client)).collect();
    let sharded = run_sharded_bar(if fast { 3 } else { 10 });
    write_bench_json(
        "BENCH_service.json",
        "service_throughput",
        vec![("results", Json::Arr(results)), ("sharded", sharded)],
    )
    .expect("write BENCH_service.json");
}

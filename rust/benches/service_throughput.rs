//! Multi-tenant service throughput under overload: a zipfian tenant
//! mix driving 2× more concurrent clients than workers through the
//! full `handle_line` path (protocol parse, plan/cache, DRR admission,
//! queue, reply), with the p99 request latency as the headline — plus
//! two serving-plane bars:
//!
//! * **batched vs unbatched** — N concurrent identical-PlanKey
//!   advances with and without a coalescing window: the batched column
//!   pays ONE plan-cache lookup per round where the unbatched one pays
//!   N, at identical (bit-exact) results;
//! * **tiered vs resident** — the same interleaved session stream with
//!   and without a `--resident-bytes` cap small enough to spill every
//!   idle session, pricing the hex-f64 spill/restore round-trip.
//!
//! Run with: `cargo bench --bench service_throughput` (BENCH_FAST=1 for
//! CI).  Emits BENCH_service.json (via `util::bench::write_bench_json`)
//! for EXPERIMENTS.md-style tracking.

use std::collections::BTreeMap;
use std::sync::{Arc, Barrier};
use std::time::Instant;

use tc_stencil::service::server::{handle_line, ServeOpts, Service, ServiceState};
use tc_stencil::util::bench::write_bench_json;
use tc_stencil::util::json::Json;
use tc_stencil::util::stats;

fn obj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn opts(workers: usize) -> ServeOpts {
    ServeOpts {
        workers,
        max_queue: 256,
        artifacts_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
        ..Default::default()
    }
}

fn stats_json(state: &Arc<ServiceState>) -> Json {
    let (resp, _) = handle_line(state, r#"{"op":"stats"}"#);
    Json::parse_line(&resp).expect("stats reply")
}

/// Deterministic LCG (no wall-clock seeding: benches must replay).
fn lcg(s: &mut u64) -> f64 {
    *s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    ((*s >> 11) as f64) / ((1u64 << 53) as f64)
}

/// Zipf(1) tenant sampler: tenant k carries weight 1/(k+1).
fn zipf_cdf(tenants: usize) -> Vec<f64> {
    let w: Vec<f64> = (0..tenants).map(|k| 1.0 / (k + 1) as f64).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    w.iter()
        .map(|x| {
            acc += x / total;
            acc
        })
        .collect()
}

/// Headline: `2×workers` concurrent clients stream a zipfian tenant
/// mix — sustained overload, so DRR has contention to arbitrate.  Every
/// client owns one session per tenant (sessions are single-flight; the
/// tenant label is what admission and accounting key on).
fn run_zipfian_overload(tenants: usize, per_client: usize) -> Json {
    let workers = std::thread::available_parallelism().map(|n| n.get().min(4)).unwrap_or(2);
    let clients = workers * 2;
    let svc = Service::start(opts(workers));
    let state: Arc<ServiceState> = svc.state();
    let cdf = Arc::new(zipf_cdf(tenants));
    let wall0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            let state = state.clone();
            let cdf = cdf.clone();
            std::thread::spawn(move || {
                for k in 0..tenants {
                    let (resp, _) = handle_line(
                        &state,
                        &format!(
                            r#"{{"op":"create_session","session":"z{ci}x{k}","shape":"star","d":2,"r":1,"dtype":"double","domain":"96x96","backend":"native","threads":1,"shards":1,"tenant":"tenant{k}"}}"#
                        ),
                    );
                    assert!(resp.contains("\"ok\":true"), "{resp}");
                }
                let mut seed = 0x9e3779b97f4a7c15u64 ^ (ci as u64) << 32;
                let mut lat_ns = Vec::with_capacity(per_client);
                let mut refused = 0usize;
                for _ in 0..per_client {
                    let u = lcg(&mut seed);
                    let k = cdf.iter().position(|c| u <= *c).unwrap_or(tenants - 1);
                    let line =
                        format!(r#"{{"op":"advance","session":"z{ci}x{k}","steps":2,"t":1}}"#);
                    let t0 = Instant::now();
                    let (resp, _) = handle_line(&state, &line);
                    if resp.contains("\"ok\":true") {
                        lat_ns.push(t0.elapsed().as_nanos() as f64);
                    } else {
                        refused += 1; // fair-share deferral under pressure
                    }
                }
                (lat_ns, refused)
            })
        })
        .collect();
    let mut lat_ns = Vec::new();
    let mut refused = 0usize;
    for h in handles {
        let (l, r) = h.join().expect("client thread");
        lat_ns.extend(l);
        refused += r;
    }
    let wall_s = wall0.elapsed().as_secs_f64();
    let jobs = lat_ns.len();
    let p50_ms = stats::percentile(&lat_ns, 50.0) / 1e6;
    let p99_ms = stats::percentile(&lat_ns, 99.0) / 1e6;
    let st = stats_json(&state);
    let tenant_rows: Vec<Json> = st
        .get("tenants")
        .and_then(|t| t.as_arr().map(|a| a.to_vec()))
        .unwrap_or_default();
    println!(
        "zipfian overload: {tenants} tenants, {clients} clients vs {workers} workers: \
         {jobs} ok + {refused} deferred  {:.1} jobs/s  p50 {p50_ms:.3} ms  p99 {p99_ms:.3} ms",
        jobs as f64 / wall_s
    );
    drop(svc);
    obj(vec![
        ("tenants", Json::Num(tenants as f64)),
        ("workers", Json::Num(workers as f64)),
        ("clients", Json::Num(clients as f64)),
        ("jobs_ok", Json::Num(jobs as f64)),
        ("jobs_deferred", Json::Num(refused as f64)),
        ("jobs_per_sec", Json::Num(jobs as f64 / wall_s)),
        ("p50_ms", Json::Num(p50_ms)),
        ("p99_ms", Json::Num(p99_ms)),
        ("per_tenant", Json::Arr(tenant_rows)),
    ])
}

/// Batched-vs-unbatched bar: R rounds of N simultaneous identical-
/// PlanKey advances (a fresh `steps`, hence a fresh PlanKey, every
/// round — planning is always cold).  The unbatched column pays N
/// plan-cache lookups per round; the coalescing window pays one.
fn run_batching_bar(clients: usize, rounds: usize) -> Json {
    let mut cols = Vec::new();
    for (label, window_ms) in [("unbatched", 0.0), ("batched", 15.0)] {
        let mut o = opts(4);
        o.batch_window_ms = window_ms;
        let svc = Service::start(o);
        let state: Arc<ServiceState> = svc.state();
        for ci in 0..clients {
            let (resp, _) = handle_line(
                &state,
                &format!(
                    r#"{{"op":"create_session","session":"b{ci}","shape":"star","d":2,"r":1,"dtype":"double","domain":"64x64","backend":"native","threads":1,"shards":1}}"#
                ),
            );
            assert!(resp.contains("\"ok\":true"), "{resp}");
        }
        let wall0 = Instant::now();
        let mut lat_ns = Vec::new();
        for round in 0..rounds {
            let barrier = Arc::new(Barrier::new(clients));
            let steps = round + 1; // steps is in the PlanKey: cold plan
            let handles: Vec<_> = (0..clients)
                .map(|ci| {
                    let state = state.clone();
                    let barrier = barrier.clone();
                    std::thread::spawn(move || {
                        let line = format!(
                            r#"{{"op":"advance","session":"b{ci}","steps":{steps},"t":1}}"#
                        );
                        barrier.wait();
                        let t0 = Instant::now();
                        let (resp, _) = handle_line(&state, &line);
                        assert!(resp.contains("\"ok\":true"), "{resp}");
                        t0.elapsed().as_nanos() as f64
                    })
                })
                .collect();
            lat_ns.extend(handles.into_iter().map(|h| h.join().expect("client")));
        }
        let wall_s = wall0.elapsed().as_secs_f64();
        let snap = state.counters.snapshot();
        let p99_ms = stats::percentile(&lat_ns, 99.0) / 1e6;
        println!(
            "batching bar [{label:>9}]: {rounds} rounds × {clients} clients  {wall_s:.3}s  \
             p99 {p99_ms:.3} ms  plan lookups {}  batches {} ({} members)",
            snap.plan_hits + snap.plan_misses,
            snap.batches,
            snap.jobs_batched,
        );
        drop(svc);
        cols.push(obj(vec![
            ("mode", Json::Str(label.to_string())),
            ("window_ms", Json::Num(window_ms)),
            ("wall_s", Json::Num(wall_s)),
            ("p99_ms", Json::Num(p99_ms)),
            ("plan_lookups", Json::Num((snap.plan_hits + snap.plan_misses) as f64)),
            ("batches", Json::Num(snap.batches as f64)),
            ("jobs_batched", Json::Num(snap.jobs_batched as f64)),
        ]));
    }
    obj(vec![
        ("clients", Json::Num(clients as f64)),
        ("rounds", Json::Num(rounds as f64)),
        ("columns", Json::Arr(cols)),
    ])
}

/// Tiered-vs-resident bar: the same interleaved multi-session stream
/// with and without a 1-byte resident cap (every idle session spills),
/// pricing the lossless hex-f64 spill/restore round-trip.
fn run_tiering_bar(sessions: usize, rounds: usize) -> Json {
    let mut cols = Vec::new();
    for (label, cap) in [("resident", None), ("tiered", Some(1u64))] {
        let mut o = opts(2);
        o.resident_bytes = cap;
        let svc = Service::start(o);
        let state: Arc<ServiceState> = svc.state();
        for s in 0..sessions {
            let (resp, _) = handle_line(
                &state,
                &format!(
                    r#"{{"op":"create_session","session":"t{s}","shape":"star","d":2,"r":1,"dtype":"double","domain":"128x128","backend":"native","threads":1,"shards":1,"tenant":"tenant{s}"}}"#
                ),
            );
            assert!(resp.contains("\"ok\":true"), "{resp}");
        }
        let wall0 = Instant::now();
        let mut lat_ns = Vec::with_capacity(sessions * rounds);
        for _ in 0..rounds {
            for s in 0..sessions {
                let line = format!(r#"{{"op":"advance","session":"t{s}","steps":2,"t":1}}"#);
                let t0 = Instant::now();
                let (resp, _) = handle_line(&state, &line);
                assert!(resp.contains("\"ok\":true"), "{resp}");
                lat_ns.push(t0.elapsed().as_nanos() as f64);
            }
        }
        let wall_s = wall0.elapsed().as_secs_f64();
        let st = stats_json(&state);
        let spilled = st.get("spilled_bytes").and_then(|v| v.as_i64()).unwrap_or(0);
        let p99_ms = stats::percentile(&lat_ns, 99.0) / 1e6;
        println!(
            "tiering bar [{label:>8}]: {rounds} rounds × {sessions} sessions  {wall_s:.3}s  \
             p99 {p99_ms:.3} ms  spilled {spilled} B",
        );
        drop(svc);
        cols.push(obj(vec![
            ("mode", Json::Str(label.to_string())),
            ("wall_s", Json::Num(wall_s)),
            ("p99_ms", Json::Num(p99_ms)),
            ("spilled_bytes", Json::Num(spilled as f64)),
        ]));
    }
    obj(vec![
        ("sessions", Json::Num(sessions as f64)),
        ("rounds", Json::Num(rounds as f64)),
        ("columns", Json::Arr(cols)),
    ])
}

/// The sharded large-domain bar: one thread-1 session on a 4-worker
/// pool, advanced with a pinned monolith (`shards:1`) and with the
/// planner's auto fan-out — the wall-clock ratio is the serving-plane
/// payoff the `model::shard::gain` model predicts.
fn run_sharded_bar(jobs: usize) -> Json {
    let svc = Service::start(opts(4));
    let state: Arc<ServiceState> = svc.state();
    let side = if std::env::var("BENCH_FAST").is_ok() { 256 } else { 1024 };
    let (resp, _) = handle_line(
        &state,
        &format!(
            r#"{{"op":"create_session","session":"big","shape":"star","d":2,"r":1,"dtype":"double","domain":"{side}x{side}","backend":"native","temporal":"sweep","threads":1}}"#
        ),
    );
    assert!(resp.contains("\"ok\":true"), "{resp}");
    let mut wall = [0.0f64; 2];
    let mut shards_seen = [0i64; 2];
    for (i, spec) in ["1", "\"auto\""].iter().enumerate() {
        let line = format!(
            r#"{{"op":"advance","session":"big","steps":2,"t":1,"shards":{spec}}}"#
        );
        let t0 = Instant::now();
        for _ in 0..jobs {
            let (resp, _) = handle_line(&state, &line);
            assert!(resp.contains("\"ok\":true"), "{resp}");
            let j = Json::parse_line(&resp).unwrap();
            shards_seen[i] = j.get("shards").unwrap().as_i64().unwrap();
        }
        wall[i] = t0.elapsed().as_secs_f64();
    }
    let speedup = wall[0] / wall[1];
    println!(
        "sharded bar {side}x{side}: shards=1 {:.3}s vs shards=auto({}) {:.3}s -> {speedup:.2}x",
        wall[0], shards_seen[1], wall[1]
    );
    drop(svc);
    obj(vec![
        ("bar", Json::Str(format!("sharded/{side}x{side}"))),
        ("jobs", Json::Num(jobs as f64)),
        ("mono_s", Json::Num(wall[0])),
        ("auto_s", Json::Num(wall[1])),
        ("auto_shards", Json::Num(shards_seen[1] as f64)),
        ("speedup", Json::Num(speedup)),
    ])
}

fn main() {
    let fast = std::env::var("BENCH_FAST").is_ok();
    println!("### bench group: service_throughput (multi-tenant overload)");
    let zipf = run_zipfian_overload(6, if fast { 8 } else { 60 });
    let batching = run_batching_bar(4, if fast { 3 } else { 8 });
    let tiering = run_tiering_bar(6, if fast { 4 } else { 12 });
    let sharded = run_sharded_bar(if fast { 3 } else { 10 });
    write_bench_json(
        "BENCH_service.json",
        "service_throughput",
        vec![
            ("zipfian_overload", zipf),
            ("batching", batching),
            ("tiering", tiering),
            ("sharded", sharded),
        ],
    )
    .expect("write BENCH_service.json");
}

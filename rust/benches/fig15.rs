//! Regenerates paper Fig 15: arithmetic intensity vs fusion depth on
//! CUDA Cores (double) — the linear relationship with slope K/D that
//! anchors the whole temporal-fusion analysis.

use tc_stencil::report;
use tc_stencil::util::bench::Bench;

fn main() {
    let (table, slope, r2) = report::fig15();
    println!("{}", table.render());
    println!("linear fit: I = a + {slope:.4}·t, r² = {r2:.6} (analytical slope K/D = 1.125)\n");
    assert!((slope - 1.125).abs() / 1.125 < 0.1, "slope {slope} strays from K/D");
    assert!(r2 > 0.99, "linearity broken: r²={r2}");

    let mut b = Bench::new("fig15");
    b.run("profiled_sweep", || {
        std::hint::black_box(report::fig15());
    });
}

//! Regenerates paper Table 2: analytical vs profiled C/M/I per output
//! point for EBISU / ConvStencil / SPIDER rows, and times the profiler.

use tc_stencil::engines;
use tc_stencil::model::perf::{Dtype, Workload};
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::report;
use tc_stencil::sim::profiler;
use tc_stencil::util::bench::Bench;

fn main() {
    println!("{}", report::table2().render());
    // Sanity gates mirroring §5.2's findings.
    let w = Workload::new(StencilPattern::new(Shape::Box, 2, 1).unwrap(), 3, Dtype::F64);
    let p = profiler::profile(&engines::ebisu(), &w);
    assert!(p.delta_c() > 0.0, "measured C must exceed analytical (§5.2.4)");
    assert!(p.delta_m() < 0.0, "measured M must undershoot analytical (§5.2.4)");

    let mut b = Bench::new("table2");
    b.run("profile_one_row", || {
        std::hint::black_box(profiler::profile(&engines::spider(), &w));
    });
    b.run("full_table", || {
        std::hint::black_box(report::table2().render());
    });
}

//! Regenerates paper Fig 13/14: how Sparse Tensor Cores raise the
//! ceiling and EXPAND the sweet spot across fusion depths.

use tc_stencil::hardware::Gpu;
use tc_stencil::report;
use tc_stencil::util::bench::Bench;

fn main() {
    let gpu = Gpu::a100();
    let t = report::fig13(&gpu);
    println!("{}", t.render());
    let expanded: Vec<&str> = t
        .rows
        .iter()
        .filter(|r| r[5] == "no" && r[6] == "yes")
        .map(|r| r[0].as_str())
        .collect();
    println!("fusion depths recovered by SpTC (dense-unprofitable, sparse-profitable): {expanded:?}\n");
    assert!(!expanded.is_empty(), "SpTC must expand the profitable region");

    let mut b = Bench::new("fig13");
    b.run("sweep_t32", || {
        std::hint::black_box(report::fig13(&gpu));
    });
}

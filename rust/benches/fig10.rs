//! Regenerates paper Fig 10: classification of stencil configurations by
//! the fusion depth at which they cross into the compute-bound region,
//! on datasheet and clock-locked A100 roofs (§4.2's empirical-vs-model
//! discrepancy discussion).

use tc_stencil::engines::calib;
use tc_stencil::hardware::Gpu;
use tc_stencil::report;
use tc_stencil::util::bench::Bench;

fn main() {
    let gpu = Gpu::a100();
    println!("{}", report::fig10(&gpu).render());
    println!(
        "--- clock-locked ({}): transitions shift EARLIER (paper §4.2) ---",
        calib::PROFILING_CLOCK_LOCK
    );
    let locked = gpu.locked(calib::PROFILING_CLOCK_LOCK);
    println!("{}", report::fig10(&locked).render());

    // Gate: every locked transition depth <= datasheet transition depth.
    let a = report::fig10(&gpu);
    let b = report::fig10(&locked);
    for (ra, rb) in a.rows.iter().zip(&b.rows) {
        let ta: usize = ra[4].parse().unwrap_or(99);
        let tb: usize = rb[4].parse().unwrap_or(99);
        assert!(tb <= ta, "{}: locked {tb} > free {ta}", ra[0]);
    }

    let mut bench = Bench::new("fig10");
    bench.run("classification_sweep", || {
        std::hint::black_box(report::fig10(&gpu));
    });
}

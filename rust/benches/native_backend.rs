//! Native-backend throughput (GStencils/s) vs the golden per-point
//! oracle on the paper's workhorse shapes: heat-3d (Star-3D1R) and
//! star-2d (Star-2D1R).  Reports the speedup of the tiled halo-split
//! engine over the scalar oracle path (acceptance bar: ≥ 10×), the
//! fused-t variants the oracle cannot amortize, and the temporal
//! blocking acceptance bar: star-1 f32 at t=4 on a domain whose sweeps
//! spill the cache while a time tile stays resident must run ≥ 2×
//! faster than repeated single-step sweeps, with its measured achieved
//! intensity inside the model's predicted region (Eq. 8's t·K/D).
//!
//! Run with: `cargo bench --bench native_backend` (BENCH_FAST=1 for CI).

use tc_stencil::backend::{self, Backend, NativeBackend, TemporalMode};
use tc_stencil::model::calib;
use tc_stencil::model::perf::{Dtype, Workload};
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::sim::golden;
use tc_stencil::util::bench::Bench;
use tc_stencil::util::rng::Rng;

fn star_weights(d: usize) -> Vec<f64> {
    // Explicit FTCS heat step: centre 1−2dκ, axis neighbours κ.
    let kappa = 0.1;
    let p = StencilPattern::new(Shape::Star, d, 1).unwrap();
    let sup = p.support();
    let side = 3usize;
    let centre = side.pow(d as u32) / 2;
    sup.cells
        .iter()
        .enumerate()
        .map(|(i, &b)| {
            if i == centre {
                1.0 - 2.0 * d as f64 * kappa
            } else if b {
                kappa
            } else {
                0.0
            }
        })
        .collect()
}

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut b = Bench::new("native_backend");
    let shapes: [(&str, usize, Vec<usize>, usize); 2] = [
        ("star2d/384x384", 2, vec![384, 384], 4),
        ("heat3d/48x48x48", 3, vec![48, 48, 48], 2),
    ];
    for (label, d, domain, steps) in shapes {
        let n: usize = domain.iter().product();
        let mut rng = Rng::new(0x57A7);
        let init: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let weights = star_weights(d);
        let items = (n * steps) as f64;

        // 1. Golden per-point oracle (the pre-backend fallback path).
        let gw = golden::Weights::new(d, 3, weights.clone());
        let mut gf = golden::Field::from_vec(&domain, init.clone());
        let oracle = b
            .run_items(&format!("{label}/oracle"), Some(items), || {
                gf = golden::apply_steps(&gf, &gw, steps);
            })
            .throughput()
            .unwrap();

        // 2. Native backend, sequential semantics (t=1), same job.
        let mut job = backend::Job {
            pattern: StencilPattern::new(Shape::Star, d, 1).unwrap(),
            dtype: Dtype::F64,
            domain: domain.clone(),
            steps,
            t: 1,
            temporal: backend::TemporalMode::Sweep,
            weights: weights.clone(),
            threads,
        };
        let mut be = NativeBackend::new();
        let mut field = init.clone();
        let native = b
            .run_items(&format!("{label}/native_t1_{threads}thr"), Some(items), || {
                be.advance(&job, &mut field).unwrap();
            })
            .throughput()
            .unwrap();

        // 3. Fused launches (t = steps): one kernel pass per launch.
        job.t = steps;
        let mut fused_field = init.clone();
        b.run_items(&format!("{label}/native_fused_t{steps}"), Some(items), || {
            be.advance(&job, &mut fused_field).unwrap();
        });

        println!(
            ">>> {label}: native {:.1} MSt/s vs oracle {:.1} MSt/s -> {:.1}x speedup{}",
            native / 1e6,
            oracle / 1e6,
            native / oracle,
            if native / oracle >= 10.0 { " (meets >=10x bar)" } else { "" }
        );
    }

    // Temporal-blocking acceptance bar: star-1 f32, t=4.  The domain is
    // sized so one field sweep traffics far more than any LLC slice
    // (2048² f32 = 16.8 MB per buffer) while a time tile fits in L2 —
    // repeated sweeps pay DRAM per step, the blocked path pays it once
    // per 4 steps.
    let side = if std::env::var("BENCH_FAST").is_ok() { 768usize } else { 2048 };
    let steps = 4usize;
    let pattern = StencilPattern::new(Shape::Star, 2, 1).unwrap();
    let weights = star_weights(2);
    let n = side * side;
    let mut rng = Rng::new(0xB10C);
    let init: Vec<f64> = (0..n).map(|_| (rng.normal() as f32) as f64).collect();
    let items = (n * steps) as f64;
    let job = |temporal, t| backend::Job {
        pattern,
        dtype: Dtype::F32,
        domain: vec![side, side],
        steps,
        t,
        temporal,
        weights: weights.clone(),
        threads,
    };
    let mut be = NativeBackend::new();
    let label = format!("star1_f32/{side}x{side}");
    let mut f_sweep = init.clone();
    let sweeps = b
        .run_items(&format!("{label}/sweeps_t1"), Some(items), || {
            be.advance(&job(TemporalMode::Sweep, 1), &mut f_sweep).unwrap();
        })
        .throughput()
        .unwrap();
    let mut f_blocked = init.clone();
    let blocked = b
        .run_items(&format!("{label}/blocked_t{steps}"), Some(items), || {
            be.advance(&job(TemporalMode::Blocked, steps), &mut f_blocked).unwrap();
        })
        .throughput()
        .unwrap();
    // One instrumented run for the intensity report.
    let mut f_probe = init.clone();
    let m = be.advance(&job(TemporalMode::Blocked, steps), &mut f_probe).unwrap();
    let w = Workload::new(pattern, steps, Dtype::F32);
    let rep = calib::report(&w, steps, true, m.achieved_intensity());
    let speedup = blocked / sweeps;
    println!(
        ">>> {label} t={steps}: blocked {:.1} MSt/s vs repeated sweeps {:.1} MSt/s \
         -> {:.2}x{}",
        blocked / 1e6,
        sweeps / 1e6,
        speedup,
        if speedup >= 2.0 { " (meets >=2x bar)" } else { " (BELOW 2x bar)" }
    );
    println!(
        ">>> {label} intensity: achieved {:.2} F/B vs model t·K/D = {:.2} F/B \
         (error {:+.1}%, {})",
        rep.measured,
        rep.predicted,
        rep.rel_error * 100.0,
        if rep.within_region { "within predicted region" } else { "OUTSIDE predicted region" }
    );
}

//! Native-backend throughput (GStencils/s) vs the golden per-point
//! oracle on the paper's workhorse shapes: heat-3d (Star-3D1R) and
//! star-2d (Star-2D1R).  Reports the speedup of the tiled halo-split
//! engine over the scalar oracle path (acceptance bar: ≥ 10×), the
//! fused-t variants the oracle cannot amortize, and the temporal
//! blocking acceptance bar: star-1 f32 at t=4 on a domain whose sweeps
//! spill the cache while a time tile stays resident must run ≥ 2×
//! faster than repeated single-step sweeps, with its measured achieved
//! intensity inside the model's predicted region (Eq. 8's t·K/D).
//!
//! Run with: `cargo bench --bench native_backend` (BENCH_FAST=1 for CI).

use tc_stencil::backend::kernels::{self, KernelMode};
use tc_stencil::backend::{self, Backend, NativeBackend, TemporalMode};
use tc_stencil::coordinator::grid::ShardPlan;
use tc_stencil::coordinator::scheduler;
use tc_stencil::model::calib;
use tc_stencil::model::perf::{Dtype, Workload};
use tc_stencil::model::stencil::{Coeffs, Shape, StencilPattern};
use tc_stencil::model::shard;
use tc_stencil::sim::golden;
use tc_stencil::util::bench::Bench;
use tc_stencil::util::json::Json;
use tc_stencil::util::rng::Rng;

fn star_weights(d: usize) -> Vec<f64> {
    // Explicit FTCS heat step: centre 1−2dκ, axis neighbours κ.
    let kappa = 0.1;
    let p = StencilPattern::new(Shape::Star, d, 1).unwrap();
    let sup = p.support();
    let side = 3usize;
    let centre = side.pow(d as u32) / 2;
    sup.cells
        .iter()
        .enumerate()
        .map(|(i, &b)| {
            if i == centre {
                1.0 - 2.0 * d as f64 * kappa
            } else if b {
                kappa
            } else {
                0.0
            }
        })
        .collect()
}

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut b = Bench::new("native_backend");
    let mut extras: Vec<(&str, Json)> = Vec::new();
    let mut speedups: Vec<Json> = Vec::new();
    let shapes: [(&str, usize, Vec<usize>, usize); 2] = [
        ("star2d/384x384", 2, vec![384, 384], 4),
        ("heat3d/48x48x48", 3, vec![48, 48, 48], 2),
    ];
    for (label, d, domain, steps) in shapes {
        let n: usize = domain.iter().product();
        let mut rng = Rng::new(0x57A7);
        let init: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let weights = star_weights(d);
        let items = (n * steps) as f64;

        // 1. Golden per-point oracle (the pre-backend fallback path).
        let gw = golden::Weights::new(d, 3, weights.clone());
        let mut gf = golden::Field::from_vec(&domain, init.clone());
        let oracle = b
            .run_items(&format!("{label}/oracle"), Some(items), || {
                gf = golden::apply_steps(&gf, &gw, steps);
            })
            .throughput()
            .unwrap();

        // 2. Native backend, sequential semantics (t=1), same job.
        let mut job = backend::Job {
            pattern: StencilPattern::new(Shape::Star, d, 1).unwrap(),
            dtype: Dtype::F64,
            domain: domain.clone(),
            steps,
            t: 1,
            temporal: backend::TemporalMode::Sweep,
            weights: weights.clone(),
            threads,
        };
        let mut be = NativeBackend::new();
        let mut field = init.clone();
        let native = b
            .run_items(&format!("{label}/native_t1_{threads}thr"), Some(items), || {
                be.advance(&job, &mut field).unwrap();
            })
            .throughput()
            .unwrap();

        // 3. Fused launches (t = steps): one kernel pass per launch.
        job.t = steps;
        let mut fused_field = init.clone();
        b.run_items(&format!("{label}/native_fused_t{steps}"), Some(items), || {
            be.advance(&job, &mut fused_field).unwrap();
        });

        println!(
            ">>> {label}: native {:.1} MSt/s vs oracle {:.1} MSt/s -> {:.1}x speedup{}",
            native / 1e6,
            oracle / 1e6,
            native / oracle,
            if native / oracle >= 10.0 { " (meets >=10x bar)" } else { "" }
        );
        speedups.push(Json::Obj(
            [
                ("bar".to_string(), Json::Str(format!("{label}/native_vs_oracle"))),
                ("speedup".to_string(), Json::Num(native / oracle)),
                ("threshold".to_string(), Json::Num(10.0)),
            ]
            .into_iter()
            .collect(),
        ));
    }

    // Temporal-blocking acceptance bar: star-1 f32, t=4.  The domain is
    // sized so one field sweep traffics far more than any LLC slice
    // (2048² f32 = 16.8 MB per buffer) while a time tile fits in L2 —
    // repeated sweeps pay DRAM per step, the blocked path pays it once
    // per 4 steps.
    let side = if std::env::var("BENCH_FAST").is_ok() { 768usize } else { 2048 };
    let steps = 4usize;
    let pattern = StencilPattern::new(Shape::Star, 2, 1).unwrap();
    let weights = star_weights(2);
    let n = side * side;
    let mut rng = Rng::new(0xB10C);
    let init: Vec<f64> = (0..n).map(|_| (rng.normal() as f32) as f64).collect();
    let items = (n * steps) as f64;
    let job = |temporal, t| backend::Job {
        pattern,
        dtype: Dtype::F32,
        domain: vec![side, side],
        steps,
        t,
        temporal,
        weights: weights.clone(),
        threads,
    };
    let mut be = NativeBackend::new();
    let label = format!("star1_f32/{side}x{side}");
    let mut f_sweep = init.clone();
    let sweeps = b
        .run_items(&format!("{label}/sweeps_t1"), Some(items), || {
            be.advance(&job(TemporalMode::Sweep, 1), &mut f_sweep).unwrap();
        })
        .throughput()
        .unwrap();
    let mut f_blocked = init.clone();
    let blocked = b
        .run_items(&format!("{label}/blocked_t{steps}"), Some(items), || {
            be.advance(&job(TemporalMode::Blocked, steps), &mut f_blocked).unwrap();
        })
        .throughput()
        .unwrap();
    // One instrumented run for the intensity report.
    let mut f_probe = init.clone();
    let m = be.advance(&job(TemporalMode::Blocked, steps), &mut f_probe).unwrap();
    let w = Workload::new(pattern, steps, Dtype::F32);
    let rep = calib::report(&w, steps, true, m.achieved_intensity());
    let speedup = blocked / sweeps;
    println!(
        ">>> {label} t={steps}: blocked {:.1} MSt/s vs repeated sweeps {:.1} MSt/s \
         -> {:.2}x{}",
        blocked / 1e6,
        sweeps / 1e6,
        speedup,
        if speedup >= 2.0 { " (meets >=2x bar)" } else { " (BELOW 2x bar)" }
    );
    println!(
        ">>> {label} intensity: achieved {:.2} F/B vs model t·K/D = {:.2} F/B \
         (error {:+.1}%, {})",
        rep.measured,
        rep.predicted,
        rep.rel_error * 100.0,
        if rep.within_region { "within predicted region" } else { "OUTSIDE predicted region" }
    );
    speedups.push(Json::Obj(
        [
            ("bar".to_string(), Json::Str(format!("{label}/blocked_vs_sweeps"))),
            ("speedup".to_string(), Json::Num(speedup)),
            ("threshold".to_string(), Json::Num(2.0)),
            ("achieved_intensity".to_string(), Json::Num(rep.measured)),
            ("predicted_intensity".to_string(), Json::Num(rep.predicted)),
        ]
        .into_iter()
        .collect(),
    ));

    // Sharded large-domain bar: shards=1 (the monolithic single-lane
    // baseline the planner's gain model compares against) vs the
    // auto-resolved fan-out (min(lanes, n0) dim-0 slab shards, one
    // lane each) driven through scheduler::advance_sharded — the same
    // advance_shard primitive the serve queue schedules.  Large domain,
    // t=1 sweep phases: pure parallel gain minus halo re-reads.
    let side = if std::env::var("BENCH_FAST").is_ok() { 512usize } else { 1536 };
    let steps = 2usize;
    let pattern = StencilPattern::new(Shape::Star, 2, 1).unwrap();
    let weights = star_weights(2);
    let n = side * side;
    let mut rng = Rng::new(0x5A4D);
    let init: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let items = (n * steps) as f64;
    let lanes = threads.clamp(1, 8);
    let auto_shards = shard::cuts(side, lanes).len();
    let job = |threads| backend::Job {
        pattern,
        dtype: Dtype::F64,
        domain: vec![side, side],
        steps,
        t: 1,
        temporal: TemporalMode::Sweep,
        weights: weights.clone(),
        threads,
    };
    let label = format!("sharded_f64/{side}x{side}");
    let mut f1 = init.clone();
    let mut be = NativeBackend::new();
    let mono = b
        .run_items(&format!("{label}/shards1_1thr"), Some(items), || {
            be.advance(&job(1), &mut f1).unwrap();
        })
        .throughput()
        .unwrap();
    let plan = ShardPlan::new(&[side, side], &[auto_shards, 1], 1, 1).unwrap();
    let mut fs = init.clone();
    let sharded = b
        .run_items(&format!("{label}/shards{auto_shards}_auto"), Some(items), || {
            scheduler::advance_sharded(&job(1), &plan, &mut fs, lanes).unwrap();
        })
        .throughput()
        .unwrap();
    let g_model = shard::gain(side, auto_shards, 1, 1, false, lanes, 1);
    println!(
        ">>> {label}: shards=auto({auto_shards}) {:.1} MSt/s vs shards=1 {:.1} MSt/s \
         -> {:.2}x (model gain {:.2}x)",
        sharded / 1e6,
        mono / 1e6,
        sharded / mono,
        g_model,
    );
    speedups.push(Json::Obj(
        [
            ("bar".to_string(), Json::Str(format!("{label}/auto_vs_shards1"))),
            ("speedup".to_string(), Json::Num(sharded / mono)),
            ("shards".to_string(), Json::Num(auto_shards as f64)),
            ("model_gain".to_string(), Json::Num(g_model)),
        ]
        .into_iter()
        .collect(),
    ));

    // Per-kernel dispatch bars: the specialized SIMD registry vs the
    // forced-generic offset-list loop, every probed shape × dtype, on
    // interior-dominated domains (the boundary scalar path is identical
    // in both modes, so it must not dilute the ratio).  The ≥2× bar is
    // asserted on the shapes whose arithmetic is lean enough for the
    // vector width to show (star-1, box-2); the rest are recorded.
    let fast = std::env::var("BENCH_FAST").is_ok();
    let mut kernel_bars: Vec<Json> = Vec::new();
    for pattern in kernels::probe_shapes() {
        let domain: Vec<usize> = match pattern.d {
            1 => vec![if fast { 1 << 18 } else { 1 << 22 }],
            2 => vec![if fast { 384 } else { 1024 }; 2],
            _ => vec![if fast { 40 } else { 96 }; 3],
        };
        let n: usize = domain.iter().product();
        let steps = 2usize;
        let mut rng = Rng::new(0x4B52);
        let init: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // default_weights follows the coefficient variant: the sparse24
        // probe shapes must exercise their pruned-tap kernels, not fall
        // back to generic on an arity the registry never saw.
        let weights = pattern.default_weights();
        let items = (n * steps) as f64;
        let key = kernels::shape_key(&pattern);
        for dtype in [Dtype::F32, Dtype::F64] {
            let job = backend::Job {
                pattern,
                dtype,
                domain: domain.clone(),
                steps,
                t: 1,
                temporal: TemporalMode::Sweep,
                weights: weights.clone(),
                threads,
            };
            let dl = dtype.as_str();
            let mut ba = NativeBackend::with_mode(KernelMode::Auto);
            let mut fa = init.clone();
            let spec = b
                .run_items(&format!("kernel/{key}/{dl}/specialized"), Some(items), || {
                    ba.advance(&job, &mut fa).unwrap();
                })
                .throughput()
                .unwrap();
            let mut bg = NativeBackend::with_mode(KernelMode::Generic);
            let mut fg = init.clone();
            let gen = b
                .run_items(&format!("kernel/{key}/{dl}/generic"), Some(items), || {
                    bg.advance(&job, &mut fg).unwrap();
                })
                .throughput()
                .unwrap();
            let ratio = spec / gen;
            let barred = key == "star-1d1r" || key == "box-2d1r";
            println!(
                ">>> kernel {key} {dl}: specialized {:.1} MSt/s vs generic {:.1} MSt/s \
                 -> {:.2}x{}",
                spec / 1e6,
                gen / 1e6,
                ratio,
                match (barred, ratio >= 2.0) {
                    (true, true) => " (meets >=2x bar)",
                    (true, false) => " (BELOW 2x bar)",
                    _ => "",
                }
            );
            kernel_bars.push(Json::Obj(
                [
                    ("bar".to_string(), Json::Str(format!("kernel/{key}/{dl}"))),
                    ("specialized_msts".to_string(), Json::Num(spec / 1e6)),
                    ("generic_msts".to_string(), Json::Num(gen / 1e6)),
                    ("speedup".to_string(), Json::Num(ratio)),
                    ("threshold".to_string(), Json::Num(if barred { 2.0 } else { 1.0 })),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }
    extras.push(("kernel_dispatch", Json::Arr(kernel_bars)));

    // Dense vs 2:4-sparse GPts/s bars: the same geometry with the
    // const vs pruned coefficient axis — the executor-side realization
    // of the planner's effective-count pricing (a pruned kernel does
    // 5/9 of box-2d1r's per-point work, so the point rate should rise;
    // the ratio is recorded, not barred — memory-bound domains cap it).
    let mut sparse_bars: Vec<Json> = Vec::new();
    for (shape, d) in [(Shape::Box, 2), (Shape::Star, 2), (Shape::Box, 3)] {
        let dense_p = StencilPattern::new(shape, d, 1).unwrap();
        let sparse_p = dense_p.with_coeffs(Coeffs::Sparse24);
        let domain: Vec<usize> = match d {
            2 => vec![if fast { 384 } else { 1024 }; 2],
            _ => vec![if fast { 40 } else { 96 }; 3],
        };
        let n: usize = domain.iter().product();
        let steps = 2usize;
        let mut rng = Rng::new(0x2424);
        let init: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let items = (n * steps) as f64;
        let key = kernels::shape_key(&dense_p);
        for dtype in [Dtype::F32, Dtype::F64] {
            let dl = dtype.as_str();
            let mut rates = [0.0f64; 2];
            for (slot, p) in [dense_p, sparse_p].into_iter().enumerate() {
                let job = backend::Job {
                    pattern: p,
                    dtype,
                    domain: domain.clone(),
                    steps,
                    t: 1,
                    temporal: TemporalMode::Sweep,
                    weights: p.default_weights(),
                    threads,
                };
                let tag = if slot == 0 { "dense" } else { "sparse24" };
                let mut be = NativeBackend::new();
                let mut f = init.clone();
                rates[slot] = b
                    .run_items(&format!("sparse/{key}/{dl}/{tag}"), Some(items), || {
                        be.advance(&job, &mut f).unwrap();
                    })
                    .throughput()
                    .unwrap();
            }
            let (dense, sparse) = (rates[0], rates[1]);
            println!(
                ">>> sparse {key} {dl}: 2:4 {:.3} GPts/s vs dense {:.3} GPts/s -> {:.2}x",
                sparse / 1e9,
                dense / 1e9,
                sparse / dense
            );
            sparse_bars.push(Json::Obj(
                [
                    ("bar".to_string(), Json::Str(format!("sparse/{key}/{dl}"))),
                    ("dense_gpts".to_string(), Json::Num(dense / 1e9)),
                    ("sparse24_gpts".to_string(), Json::Num(sparse / 1e9)),
                    ("ratio".to_string(), Json::Num(sparse / dense)),
                ]
                .into_iter()
                .collect(),
            ));
        }
    }
    extras.push(("dense_vs_sparse", Json::Arr(sparse_bars)));

    extras.push(("speedups", Json::Arr(speedups)));
    b.write_json("BENCH_native.json", extras).expect("write BENCH_native.json");
}

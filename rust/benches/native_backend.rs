//! Native-backend throughput (GStencils/s) vs the golden per-point
//! oracle on the paper's workhorse shapes: heat-3d (Star-3D1R) and
//! star-2d (Star-2D1R).  Reports the speedup of the tiled halo-split
//! engine over the scalar oracle path — the ISSUE acceptance bar is
//! ≥ 10× — plus the fused-t variants the oracle cannot amortize.
//!
//! Run with: `cargo bench --bench native_backend` (BENCH_FAST=1 for CI).

use tc_stencil::backend::{self, Backend, NativeBackend};
use tc_stencil::model::perf::Dtype;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::sim::golden;
use tc_stencil::util::bench::Bench;
use tc_stencil::util::rng::Rng;

fn star_weights(d: usize) -> Vec<f64> {
    // Explicit FTCS heat step: centre 1−2dκ, axis neighbours κ.
    let kappa = 0.1;
    let p = StencilPattern::new(Shape::Star, d, 1).unwrap();
    let sup = p.support();
    let side = 3usize;
    let centre = side.pow(d as u32) / 2;
    sup.cells
        .iter()
        .enumerate()
        .map(|(i, &b)| {
            if i == centre {
                1.0 - 2.0 * d as f64 * kappa
            } else if b {
                kappa
            } else {
                0.0
            }
        })
        .collect()
}

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut b = Bench::new("native_backend");
    let shapes: [(&str, usize, Vec<usize>, usize); 2] = [
        ("star2d/384x384", 2, vec![384, 384], 4),
        ("heat3d/48x48x48", 3, vec![48, 48, 48], 2),
    ];
    for (label, d, domain, steps) in shapes {
        let n: usize = domain.iter().product();
        let mut rng = Rng::new(0x57A7);
        let init: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let weights = star_weights(d);
        let items = (n * steps) as f64;

        // 1. Golden per-point oracle (the pre-backend fallback path).
        let gw = golden::Weights::new(d, 3, weights.clone());
        let mut gf = golden::Field::from_vec(&domain, init.clone());
        let oracle = b
            .run_items(&format!("{label}/oracle"), Some(items), || {
                gf = golden::apply_steps(&gf, &gw, steps);
            })
            .throughput()
            .unwrap();

        // 2. Native backend, sequential semantics (t=1), same job.
        let mut job = backend::Job {
            pattern: StencilPattern::new(Shape::Star, d, 1).unwrap(),
            dtype: Dtype::F64,
            domain: domain.clone(),
            steps,
            t: 1,
            weights: weights.clone(),
            threads,
        };
        let mut be = NativeBackend::new();
        let mut field = init.clone();
        let native = b
            .run_items(&format!("{label}/native_t1_{threads}thr"), Some(items), || {
                be.advance(&job, &mut field).unwrap();
            })
            .throughput()
            .unwrap();

        // 3. Fused launches (t = steps): one kernel pass per launch.
        job.t = steps;
        let mut fused_field = init.clone();
        b.run_items(&format!("{label}/native_fused_t{steps}"), Some(items), || {
            be.advance(&job, &mut fused_field).unwrap();
        });

        println!(
            ">>> {label}: native {:.1} MSt/s vs oracle {:.1} MSt/s -> {:.1}x speedup{}",
            native / 1e6,
            oracle / 1e6,
            native / oracle,
            if native / oracle >= 10.0 { " (meets >=10x bar)" } else { "" }
        );
    }
}

//! Regenerates paper Table 4 (dense vs sparse Tensor Cores) AND measures
//! the real CPU-PJRT latency of the dense vs 2:4-compressed kernels —
//! the structural ablation behind the 3.06× GPU claim.

use tc_stencil::hardware::Gpu;
use tc_stencil::report;
use tc_stencil::runtime::{manifest, Runtime, TensorData};
use tc_stencil::util::bench::Bench;
use tc_stencil::util::rng::Rng;

fn main() {
    let gpu = Gpu::a100();
    println!("{}", report::table4(&gpu).render());
    let t = report::table4(&gpu);
    let dense: f64 = t.rows[0][4].parse().unwrap();
    let sparse: f64 = t.rows[1][4].parse().unwrap();
    println!(
        "speedup sparse/dense = {:.2}x (paper: 3.06x; bottleneck flips {} -> {})\n",
        sparse / dense,
        t.rows[0][3],
        t.rows[1][3]
    );
    assert!(sparse / dense > 2.0);

    // Real execution: decompose (dense band GEMM) vs sparse24 (compressed)
    // artifacts at the same (Box-2D1R, t=7) workload.
    let mut rt = Runtime::load(&manifest::default_dir()).expect("run `make artifacts`");
    let mut rng = Rng::new(4);
    let x = TensorData::F32(rng.normal_vec_f32(64 * 64));
    let w = TensorData::F32(vec![1.0 / 9.0; 9]);
    let mut b = Bench::new("table4/cpu-pjrt");
    for name in ["decompose_box2d_r1_t7_f32_g64x64", "sparse24_box2d_r1_t7_f32_g64x64"] {
        rt.execute(name, &x, &w).unwrap(); // compile outside timing
        b.run_items(name, Some((64 * 64 * 7) as f64), || {
            std::hint::black_box(rt.execute(name, &x, &w).unwrap());
        });
    }
    println!(
        "note: CPU-PJRT timings exercise the real kernels; the GPU-side\n\
         2x SpTC throughput advantage is modeled (hardware registry), not\n\
         measurable on this testbed — see DESIGN.md §2."
    );
}

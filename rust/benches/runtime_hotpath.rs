//! L3 hot-path microbenchmarks (the §Perf targets): PJRT execute latency
//! per scheme, gather/scatter tiling cost, manifest parsing, planner
//! latency, and the end-to-end coordinator step on a 256² domain —
//! plus the obs tracing-overhead bars (off vs. on), which run first and
//! artifact-free so `BENCH_obs.json` exists even without `make artifacts`.

use std::path::Path;

use tc_stencil::backend::{self, BackendKind, TemporalMode};
use tc_stencil::coordinator::grid::{ShardPlan, Tiling};
use tc_stencil::coordinator::planner::{plan, Request};
use tc_stencil::coordinator::scheduler::{self, run, Job};
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::Dtype;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::obs;
use tc_stencil::runtime::{manifest, Manifest, Runtime, TensorData};
use tc_stencil::sim::golden;
use tc_stencil::util::bench::Bench;
use tc_stencil::util::json::Json;
use tc_stencil::util::rng::Rng;

/// Tracing-overhead bars: the same sharded blocked advance with the
/// obs plane disabled (the default), enabled ring-only (serve's reply
/// spans), and enabled with an NDJSON sink (`--trace-out`).  Written
/// to `BENCH_obs.json` with the derived overhead fractions.
fn obs_overhead() {
    let mut b = Bench::new("obs");
    let domain = vec![128usize, 128];
    let pattern = StencilPattern::new(Shape::Star, 2, 1).unwrap();
    let job = backend::Job {
        pattern,
        dtype: Dtype::F64,
        domain: domain.clone(),
        steps: 4,
        t: 2,
        temporal: TemporalMode::Blocked,
        weights: pattern.uniform_weights(),
        threads: 2,
    };
    let shard_plan = ShardPlan::dim0(&domain, 2, pattern.r, 2).unwrap();
    let field0 = golden::gaussian(&domain);
    let items = 128.0 * 128.0 * 4.0;
    obs::disable();
    let off = b
        .run_items("advance_sharded/off", Some(items), || {
            let mut f = field0.clone();
            std::hint::black_box(
                scheduler::advance_sharded(&job, &shard_plan, &mut f, 2).unwrap(),
            );
        })
        .mean_ns;
    obs::enable();
    let on = b
        .run_items("advance_sharded/on", Some(items), || {
            let mut f = field0.clone();
            std::hint::black_box(
                scheduler::advance_sharded(&job, &shard_plan, &mut f, 2).unwrap(),
            );
            // Serve drains per job; draining here keeps the ring from
            // wrapping and charges that cost to the enabled bar.
            std::hint::black_box(obs::drain_all());
        })
        .mean_ns;
    let sink_path = std::env::temp_dir().join("tc_stencil_bench_obs.ndjson");
    obs::set_sink(&sink_path).unwrap();
    let on_sink = b
        .run_items("advance_sharded/on_sink", Some(items), || {
            let mut f = field0.clone();
            std::hint::black_box(
                scheduler::advance_sharded(&job, &shard_plan, &mut f, 2).unwrap(),
            );
            std::hint::black_box(obs::drain_all());
        })
        .mean_ns;
    obs::clear_sink();
    obs::disable();
    let _ = std::fs::remove_file(&sink_path);
    // Journal bars: the gate-only no-op (journal closed — serve's
    // default, what every hot-path probe site costs) vs. a real
    // append+flush per event (serve --journal).
    let jpath = std::env::temp_dir().join("tc_stencil_bench_journal.ndjson");
    let jrot = std::path::PathBuf::from(format!("{}.1", jpath.display()));
    let _ = std::fs::remove_file(&jpath);
    let _ = std::fs::remove_file(&jrot);
    let j_off = b
        .run_items("journal_emit/off", Some(1.0), || {
            obs::journal::emit("bench", &[("v", obs::journal::f(1.0))]);
        })
        .mean_ns;
    obs::journal::open(&jpath, obs::journal::DEFAULT_MAX_BYTES).unwrap();
    let j_on = b
        .run_items("journal_emit/on", Some(1.0), || {
            obs::journal::emit("bench", &[("v", obs::journal::f(1.0))]);
        })
        .mean_ns;
    obs::journal::close();
    let _ = std::fs::remove_file(&jpath);
    let _ = std::fs::remove_file(&jrot);
    let overhead = on / off - 1.0;
    let overhead_sink = on_sink / off - 1.0;
    println!(
        "tracing overhead: ring {:+.2}%, ring+sink {:+.2}%; \
         journal emit: closed {j_off:.1} ns, open {j_on:.1} ns",
        overhead * 100.0,
        overhead_sink * 100.0
    );
    b.write_json(
        "BENCH_obs.json",
        vec![
            ("overhead_frac", Json::Num(overhead)),
            ("overhead_sink_frac", Json::Num(overhead_sink)),
            ("journal_emit_off_ns", Json::Num(j_off)),
            ("journal_emit_on_ns", Json::Num(j_on)),
        ],
    )
    .unwrap();
}

fn main() {
    obs_overhead();

    let dir = manifest::default_dir();
    let Ok(mut rt) = Runtime::load(&dir) else {
        eprintln!("skipping PJRT hot-path benches: no artifacts (run `make artifacts`)");
        return;
    };
    let mut rng = Rng::new(0xFEED);

    let mut b = Bench::new("hotpath");

    // 1. Raw execute latency (dominant hot-path cost).
    let x = TensorData::F32(rng.normal_vec_f32(64 * 64));
    let w = TensorData::F32(vec![1.0 / 9.0; 9]);
    for name in [
        "direct_box2d_r1_t1_f32_g64x64",
        "direct_box2d_r1_t3_f32_g64x64",
        "decompose_box2d_r1_t7_f32_g64x64",
        "sparse24_box2d_r1_t7_f32_g64x64",
    ] {
        rt.execute(name, &x, &w).unwrap();
        let meta = rt.manifest.get(name).unwrap();
        let items = (meta.points() * meta.steps_per_exec() as u64) as f64;
        b.run_items(&format!("execute/{name}"), Some(items), || {
            std::hint::black_box(rt.execute(name, &x, &w).unwrap());
        });
    }

    // 2. Tiling gather/scatter on a 256² domain with halo 3.
    let domain = vec![256usize, 256];
    let field: Vec<f64> = (0..256 * 256).map(|_| rng.normal()).collect();
    let tiling = Tiling::new(&domain, &[64, 64], 3).unwrap();
    let tiles = tiling.tiles();
    b.run_items("gather/256x256_h3", Some(tiles.len() as f64), || {
        for t in &tiles {
            std::hint::black_box(tiling.gather(&field, t));
        }
    });
    let mut out = vec![0.0f64; 256 * 256];
    let tile_out = tiling.gather(&field, &tiles[0]);
    b.run_items("scatter/256x256_h3", Some(tiles.len() as f64), || {
        for t in &tiles {
            tiling.scatter(std::hint::black_box(&tile_out), t, &mut out);
        }
    });

    // 3. Manifest parse (startup path).
    let text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    b.run("manifest_parse", || {
        std::hint::black_box(Manifest::parse(Path::new("artifacts"), &text).unwrap());
    });

    // 4. Planner decision latency.
    let req = Request {
        pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
        dtype: Dtype::F32,
        domain: vec![256, 256],
        steps: 64,
        gpu: Gpu::a100(),
        backend: BackendKind::Pjrt,
        max_t: 8,
        temporal: tc_stencil::backend::TemporalMode::Auto,
        shards: tc_stencil::coordinator::grid::ShardSpec::Fixed(1),
        lanes: 1,
        threads: 1,
        kernels: tc_stencil::backend::kernels::KernelMode::Auto,
        kernel_peaks: Vec::new(),
    };
    b.run("planner_plan", || {
        std::hint::black_box(plan(&req, Some(&rt.manifest)).unwrap());
    });

    // 5. End-to-end coordinator step: 256² domain, one t=3 launch set.
    let weights = vec![1.0 / 9.0; 9];
    let mut f = field.clone();
    let job = Job {
        artifact: "direct_box2d_r1_t3_f32_g64x64".into(),
        domain: domain.clone(),
        steps: 3,
        weights,
        threads: 4,
    };
    run(&mut rt, &job, &mut f).unwrap(); // warm compile
    b.run_items("coordinator_launch/256x256_t3", Some(256.0 * 256.0 * 3.0), || {
        let mut ff = field.clone();
        std::hint::black_box(run(&mut rt, &job, &mut ff).unwrap());
    });

    // Observability: overhead split of the last run.
    let mut ff = field.clone();
    let m = run(&mut rt, &job, &mut ff).unwrap();
    println!("\ncoordinator phase split: {}", m.render());
    println!("tiling overhead fraction: {:.1}%", m.overhead_fraction() * 100.0);
}

//! Regenerates paper Fig 16: overall performance comparison across all
//! baselines, shapes, radii and precisions (best fusion depth each).

use tc_stencil::hardware::Gpu;
use tc_stencil::report;
use tc_stencil::util::bench::Bench;

fn main() {
    let gpu = Gpu::a100();
    let t = report::fig16(&gpu);
    println!("{}", t.render());

    // Gates mirroring §5.5: EBISU is the CUDA-Core SOTA (beats cuDNN and
    // DRStencil everywhere); SPIDER dominates float rows where present.
    for row in &t.rows {
        let parse = |s: &String| s.parse::<f64>().ok();
        if let (Some(cudnn), Some(dr), Some(eb)) = (parse(&row[2]), parse(&row[3]), parse(&row[4]))
        {
            assert!(eb >= dr && eb >= cudnn, "EBISU must lead CUDA engines: {row:?}");
        }
    }
    let float_spider_wins = t
        .rows
        .iter()
        .filter(|r| r[1] == "float" && r[7] == "SPIDER")
        .count();
    println!("SPIDER wins {float_spider_wins} of the float configurations\n");

    let mut b = Bench::new("fig16");
    b.run("full_matrix", || {
        std::hint::black_box(report::fig16(&gpu));
    });
}

//! Regenerates paper Table 3: the six representative cases with
//! bottleneck transitions and GStencils/s, on datasheet and clock-locked
//! A100 roofs.

use tc_stencil::engines::{self, calib};
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::{Dtype, Workload};
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::report;
use tc_stencil::sim::exec;
use tc_stencil::util::bench::Bench;

fn main() {
    let gpu = Gpu::a100();
    println!("{}", report::table3(&gpu).render());
    println!("--- with profiling clock lock ({}) ---", calib::PROFILING_CLOCK_LOCK);
    println!("{}", report::table3(&gpu.locked(calib::PROFILING_CLOCK_LOCK)).render());

    // Direction gates: ↓ ≈ ↑ ↑ ↓ ↓ per the paper.
    let t = report::table3(&gpu);
    for (i, want) in ["↓", "≈", "↑", "↑", "↓", "↓"].iter().enumerate() {
        assert!(
            t.rows[i][9].starts_with(want),
            "case {} direction: got {:?}, want {want}",
            i + 1,
            t.rows[i][9]
        );
    }

    let mut b = Bench::new("table3");
    let w = Workload::new(StencilPattern::new(Shape::Box, 2, 1).unwrap(), 7, Dtype::F32);
    b.run("predict", || {
        std::hint::black_box(exec::predict(&engines::spider(), &w, &gpu).unwrap());
    });
    b.run("full_table", || {
        std::hint::black_box(report::table3(&gpu).render());
    });
}

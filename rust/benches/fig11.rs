//! Regenerates paper Fig 11: the EBISU roofline across fusion depths —
//! and measures the REAL fusion-depth effect on CPU-PJRT: per-step cost
//! of the direct kernels at t = 1, 2, 3 (temporal fusion amortizes HBM
//! traffic; on CPU it amortizes per-launch overhead the same way).

use tc_stencil::hardware::Gpu;
use tc_stencil::report;
use tc_stencil::runtime::{manifest, Runtime, TensorData};
use tc_stencil::util::bench::Bench;
use tc_stencil::util::rng::Rng;

fn main() {
    let gpu = Gpu::a100();
    println!("{}", report::fig11(&gpu).render());

    // Gate: box f32 transitions from memory to compute within t <= 8.
    let t = report::fig11(&gpu);
    let rows: Vec<_> = t.rows.iter().filter(|r| r[0] == "Box-2D1R" && r[1] == "float").collect();
    assert_eq!(rows[0][4], "Memory");
    assert_eq!(rows[7][4], "Compute");

    let mut rt = Runtime::load(&manifest::default_dir()).expect("run `make artifacts`");
    let mut rng = Rng::new(11);
    let x = TensorData::F32(rng.normal_vec_f32(64 * 64));
    let w = TensorData::F32(vec![1.0 / 9.0; 9]);
    let mut b = Bench::new("fig11/fusion-depth");
    for (name, steps) in [
        ("direct_box2d_r1_t1_f32_g64x64", 1.0),
        ("direct_box2d_r1_t2_f32_g64x64", 2.0),
        ("direct_box2d_r1_t3_f32_g64x64", 3.0),
    ] {
        rt.execute(name, &x, &w).unwrap();
        // items = point-updates per launch: deeper fusion does more steps
        // per launch — throughput per launch must grow with t.
        b.run_items(name, Some(64.0 * 64.0 * steps), || {
            std::hint::black_box(rt.execute(name, &x, &w).unwrap());
        });
    }
}

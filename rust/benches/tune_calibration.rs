//! tune_calibration — the model-fidelity bench: probe stability and
//! profile-vs-static planner decision divergence.
//!
//! Two questions the perf trajectory needs answered per machine:
//!
//! 1. **Probe variance** — how repeatable are the microbenchmark
//!    constants a measured profile is built from?  (A profile whose
//!    bandwidth wobbles 30% between runs cannot anchor admission.)
//! 2. **Decision divergence** — across a grid of representative
//!    requests, how many planner decisions (engine, t, temporal,
//!    shards) change when planning against the measured profile
//!    instead of the builtin A100 table?  This is the observable
//!    payoff of the tune/ plane: where the machine disagrees with the
//!    datasheet, the plans move.
//!
//! Emits `BENCH_tune.json` via `util::bench::write_bench_json`.

use tc_stencil::backend::kernels::{KernelMode, KernelPeak};
use tc_stencil::backend::{BackendKind, TemporalMode};
use tc_stencil::coordinator::grid::ShardSpec;
use tc_stencil::coordinator::planner::{self, Request};
use tc_stencil::engines;
use tc_stencil::hardware::Gpu;
use tc_stencil::model::perf::Dtype;
use tc_stencil::model::stencil::{Shape, StencilPattern};
use tc_stencil::tune::micro::{self, MicroOpts};
use tc_stencil::util::bench::{write_bench_json, Bench};
use tc_stencil::util::json::Json;
use tc_stencil::util::stats;

fn request(
    shape: Shape,
    d: usize,
    r: usize,
    dtype: Dtype,
    gpu: Gpu,
    kernel_peaks: Vec<KernelPeak>,
) -> Request {
    Request {
        pattern: StencilPattern::new(shape, d, r).unwrap(),
        dtype,
        domain: match d {
            2 => vec![128, 128],
            _ => vec![32, 64, 64],
        },
        steps: 16,
        gpu,
        backend: BackendKind::Native,
        max_t: 8,
        temporal: TemporalMode::Auto,
        shards: ShardSpec::Auto,
        lanes: 4,
        threads: 2,
        kernels: KernelMode::Auto,
        kernel_peaks,
    }
}

fn main() {
    let mut b = Bench::new("tune_calibration");
    let opts = MicroOpts::quick();

    // ---- probe variance: repeat whole probes, look at the medians ----
    let mut bw_medians: Vec<f64> = Vec::new();
    b.run("bandwidth_probe", || {
        bw_medians.push(micro::bandwidth_probe(&opts).median);
    });
    let mut kern_medians: Vec<f64> = Vec::new();
    b.run("kernel_probe_f64_sweep_t1", || {
        let r = micro::kernel_probe(Dtype::F64, TemporalMode::Sweep, 1, &opts)
            .expect("kernel probe");
        kern_medians.push(r.median);
    });
    let rel_spread = |v: &[f64]| {
        if v.len() < 2 {
            return 0.0;
        }
        let m = stats::mean(v);
        if m == 0.0 {
            0.0
        } else {
            stats::stddev(v) / m
        }
    };
    let bw_spread = rel_spread(&bw_medians);
    let kern_spread = rel_spread(&kern_medians);
    println!(
        "probe stability: bandwidth median spread {:.1}% over {} runs, \
         kernel {:.1}% over {} runs",
        bw_spread * 100.0,
        bw_medians.len(),
        kern_spread * 100.0,
        kern_medians.len()
    );

    // ---- decision divergence: measured profile vs builtin table ----
    let measured = micro::measure(&opts).expect("measure profile");
    let builtin = engines::builtin_profile(&Gpu::a100());
    let grid: Vec<(Shape, usize, usize, Dtype)> = vec![
        (Shape::Box, 2, 1, Dtype::F32),
        (Shape::Box, 2, 1, Dtype::F64),
        (Shape::Box, 2, 2, Dtype::F64),
        (Shape::Star, 2, 1, Dtype::F32),
        (Shape::Star, 2, 1, Dtype::F64),
        (Shape::Box, 3, 1, Dtype::F32),
        (Shape::Box, 3, 1, Dtype::F64),
        (Shape::Star, 3, 1, Dtype::F64),
    ];
    let mut diffs = 0usize;
    let mut rows = Vec::new();
    for &(shape, d, r, dtype) in &grid {
        // The builtin table has no per-kernel entries; the measured side
        // plans against the ℙ of the kernel each candidate would run.
        let pb =
            planner::plan(&request(shape, d, r, dtype, builtin.gpu(), Vec::new()), None).unwrap();
        let pm = planner::plan(
            &request(shape, d, r, dtype, measured.gpu(), measured.kernels.clone()),
            None,
        )
        .unwrap();
        let same = pb.chosen.engine.name == pm.chosen.engine.name
            && pb.chosen.t == pm.chosen.t
            && pb.chosen.temporal == pm.chosen.temporal
            && pb.chosen.shards == pm.chosen.shards;
        if !same {
            diffs += 1;
        }
        println!(
            "  {:<12} {:>6}: builtin -> {:<10} t={} {:<7} sh{}   measured -> {:<10} t={} {:<7} sh{}{}",
            format!("{shape:?}-{d}D{r}R"),
            dtype.as_str(),
            pb.chosen.engine.name,
            pb.chosen.t,
            pb.chosen.temporal.as_str(),
            pb.chosen.shards,
            pm.chosen.engine.name,
            pm.chosen.t,
            pm.chosen.temporal.as_str(),
            pm.chosen.shards,
            if same { "" } else { "   << diverges" }
        );
        rows.push(Json::Str(format!(
            "{shape:?}-{d}D{r}R/{}:{}",
            dtype.as_str(),
            if same { "same" } else { "diverges" }
        )));
    }
    println!(
        "planner decision divergence: {diffs}/{} requests change under the measured profile",
        grid.len()
    );

    // ---- per-kernel ℙ spread: how much the flat peak hides ----
    // One measured FLOP/s per (shape, dtype, realization); the max/min
    // ratio per dtype is the headroom the per-kernel planner pricing
    // recovers over a single flat constant.
    let mut kernel_rows = Vec::new();
    let spread_for = |dtype: Dtype| {
        let v: Vec<f64> = measured
            .kernels
            .iter()
            .filter(|k| k.dtype == dtype && k.flops > 0.0)
            .map(|k| k.flops)
            .collect();
        let lo = v.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = v.iter().cloned().fold(0.0f64, f64::max);
        if v.is_empty() || lo <= 0.0 {
            1.0
        } else {
            hi / lo
        }
    };
    let (spread_f32, spread_f64) = (spread_for(Dtype::F32), spread_for(Dtype::F64));
    for k in &measured.kernels {
        println!(
            "  kernel P  {:<10} {:<6} {:<7} {:>9.2} GFLOP/s",
            k.shape,
            k.dtype.as_str(),
            if k.blocked { "blocked" } else { "sweep" },
            k.flops / 1e9
        );
        kernel_rows.push(Json::Obj(
            [
                ("shape".to_string(), Json::Str(k.shape.clone())),
                ("dtype".to_string(), Json::Str(k.dtype.as_str().to_string())),
                ("blocked".to_string(), Json::Bool(k.blocked)),
                ("gflops".to_string(), Json::Num(k.flops / 1e9)),
            ]
            .into_iter()
            .collect(),
        ));
    }
    println!(
        "per-kernel P spread: f32 max/min {spread_f32:.2}x, f64 max/min {spread_f64:.2}x \
         over {} measured kernels",
        measured.kernels.len()
    );

    let results = Json::Arr(b.results.iter().map(|m| m.to_json()).collect());
    write_bench_json(
        "BENCH_tune.json",
        "tune_calibration",
        vec![
            ("bandwidth_probe_rel_spread", Json::Num(bw_spread)),
            ("kernel_probe_rel_spread", Json::Num(kern_spread)),
            ("measured_bandwidth", Json::Num(measured.bandwidth)),
            ("measured_peak_f64", Json::Num(measured.peaks.cuda_f64.unwrap_or(0.0))),
            ("decision_diffs", Json::Num(diffs as f64)),
            ("decisions_total", Json::Num(grid.len() as f64)),
            ("decision_grid", Json::Arr(rows)),
            ("kernel_peaks", Json::Arr(kernel_rows)),
            ("kernel_peak_spread_f32", Json::Num(spread_f32)),
            ("kernel_peak_spread_f64", Json::Num(spread_f64)),
            ("results", results),
        ],
    )
    .expect("write BENCH_tune.json");
}

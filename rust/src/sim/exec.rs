//! Throughput/time prediction: the calibrated roofline executor.
//!
//! For an (engine, workload, GPU) triple this computes the paper's model
//! quantities end to end: intensities with the engine's S, the bound on
//! its unit, the raw and actual rooflines (Eq. 8/12/20) and a predicted
//! stencil throughput  η × P_actual / 2K  in point-updates/s, plus wall
//! time for a given domain.  This is the quantity Tables 3/4 and Figs.
//! 2/11/16 report (GStencils/s).

use anyhow::Result;

use crate::engines::Engine;
use crate::hardware::Gpu;
use crate::model::perf::{Unit, Workload};
use crate::model::roofline::{Bound, Roof};

/// A full prediction record.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub engine: &'static str,
    pub unit: Unit,
    /// Arithmetic intensity on the engine's unit (with its S).
    pub intensity: f64,
    /// Ridge point of the engine's roof.
    pub ridge: f64,
    pub bound: Bound,
    /// Raw roofline FLOP/s (counting redundant ops).
    pub raw_flops: f64,
    /// Actual useful FLOP/s (Eq. 12 normalization).
    pub actual_flops: f64,
    /// Predicted stencil throughput in point-updates/s (× η).
    pub throughput: f64,
}

impl Prediction {
    pub fn gstencils(&self) -> f64 {
        self.throughput / 1e9
    }
}

/// Engine-aware intensity: I = t·(α/S)·K/D with the ENGINE's S (paper S
/// constants override the operand-derived value when provided).
pub fn engine_intensity(e: &Engine, w: &Workload) -> f64 {
    match e.unit {
        Unit::CudaCore => w.intensity_cuda(),
        _ => w.t as f64 * w.alpha() / e.sparsity(w) * w.k() / w.dtype.bytes() as f64,
    }
}

/// Predict throughput of `engine` on `workload` on `gpu`.
pub fn predict(e: &Engine, w: &Workload, gpu: &Gpu) -> Result<Prediction> {
    anyhow::ensure!(e.supports(w), "{} does not support {}", e.name, w.pattern.label());
    let roof: Roof = gpu.roof(e.unit, w.dtype)?;
    let i = engine_intensity(e, w);
    let bound = roof.bound(i);
    let raw = roof.attainable(i);
    let inflation = match e.unit {
        Unit::CudaCore => 1.0,
        _ => w.alpha() / e.sparsity(w),
    };
    let actual = raw / inflation;
    let eta = match bound {
        Bound::Memory => e.eta_mem,
        Bound::Compute => e.eta_comp,
    };
    let throughput = eta * actual / (2.0 * w.k());
    Ok(Prediction {
        engine: e.name,
        unit: e.unit,
        intensity: i,
        ridge: roof.ridge(),
        bound,
        raw_flops: raw,
        actual_flops: actual,
        throughput,
    })
}

/// Predict a *fused-kernel sweep* on a CUDA-style unit: one launch of
/// the t-fold self-convolved kernel per `t` steps, which is what the
/// native backend's sweep path (and every AOT artifact) executes.
///
/// Per output point it moves the same 2D bytes as temporal blocking but
/// computes α·t·2K flops (Eq. 9's redundancy applied to Eq. 8), so the
/// raw intensity is α·t·K/D while only 1/α of the flops are useful:
///
/// * memory-bound (α·t·K/D below the ridge): the redundant flops are
///   free — useful FLOP/s collapse to Eq. 8's 𝔹·t·K/D, *bit-identical*
///   to [`predict`]'s memory-bound value, so planner candidates tie
///   exactly and the tie-break (sweep first) is deterministic;
/// * compute-bound: the unit saturates on redundant work and useful
///   FLOP/s drop to ℙ/α — strictly worse than the blocked variant.
///
/// The crossover is precisely the machine balance point: the planner
/// picks the blocked candidate exactly when α·t·K/D crosses the ridge.
pub fn predict_sweep(e: &Engine, w: &Workload, gpu: &Gpu) -> Result<Prediction> {
    anyhow::ensure!(
        e.unit == Unit::CudaCore,
        "{} targets {}; fused-sweep scoring models scalar units only",
        e.name,
        e.unit.as_str()
    );
    anyhow::ensure!(e.supports(w), "{} does not support {}", e.name, w.pattern.label());
    let roof: Roof = gpu.roof(e.unit, w.dtype)?;
    let i = w.intensity_fused_sweep();
    let bound = roof.bound(i);
    let raw = roof.attainable(i);
    let actual = match bound {
        Bound::Memory => roof.bandwidth * w.intensity_cuda(),
        Bound::Compute => roof.peak_flops / w.alpha(),
    };
    let eta = match bound {
        Bound::Memory => e.eta_mem,
        Bound::Compute => e.eta_comp,
    };
    let throughput = eta * actual / (2.0 * w.k());
    Ok(Prediction {
        engine: e.name,
        unit: e.unit,
        intensity: i,
        ridge: roof.ridge(),
        bound,
        raw_flops: raw,
        actual_flops: actual,
        throughput,
    })
}

/// Ideal-model prediction (η = 1): the pure Eq. 12/20 value, used when
/// validating the analytical criteria rather than implementations.
pub fn predict_ideal(e: &Engine, w: &Workload, gpu: &Gpu) -> Result<Prediction> {
    let mut p = predict(e, w, gpu)?;
    let eta = match p.bound {
        Bound::Memory => e.eta_mem,
        Bound::Compute => e.eta_comp,
    };
    p.throughput /= eta;
    Ok(p)
}

/// Wall-clock seconds to advance `points` grid points by `steps` time
/// steps at the predicted throughput (steps need not be a multiple of t —
/// the final partial fused launch still pays full time per launch).
pub fn wall_time(p: &Prediction, points: u64, steps: usize, t: usize) -> f64 {
    let launches = steps.div_ceil(t) as f64;
    launches * t as f64 * points as f64 / p.throughput
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn wl(shape: Shape, d: usize, r: usize, t: usize, dt: Dtype) -> Workload {
        Workload::new(StencilPattern::new(shape, d, r).unwrap(), t, dt)
    }

    #[test]
    fn table3_case1_shape() {
        // EBISU 260.9 vs ConvStencil 190.14 (↓, scenario 2).
        let gpu = Gpu::a100();
        let w = wl(Shape::Box, 2, 1, 3, Dtype::F64);
        let eb = predict(&engines::ebisu(), &w, &gpu).unwrap();
        let cv = predict(&engines::convstencil(), &w, &gpu).unwrap();
        assert_eq!(eb.bound, Bound::Memory);
        assert_eq!(cv.bound, Bound::Compute);
        assert!((eb.gstencils() - 260.9).abs() / 260.9 < 0.02, "{}", eb.gstencils());
        assert!((cv.gstencils() - 190.1).abs() / 190.1 < 0.02, "{}", cv.gstencils());
        assert!(cv.gstencils() < eb.gstencils());
    }

    #[test]
    fn table3_case2_shape() {
        // Box-2D3R t=1 double: 64.05 vs 63.33 — comparable (≈).
        let gpu = Gpu::a100();
        let w = wl(Shape::Box, 2, 3, 1, Dtype::F64);
        let eb = predict(&engines::ebisu(), &w, &gpu).unwrap();
        let cv = predict(&engines::convstencil(), &w, &gpu).unwrap();
        assert!((eb.gstencils() - 64.05).abs() / 64.05 < 0.02, "{}", eb.gstencils());
        let ratio = cv.gstencils() / eb.gstencils();
        assert!((ratio - 1.0).abs() < 0.1, "ratio={ratio}");
    }

    #[test]
    fn table3_case3_shape() {
        // Box-2D1R t=7 float: EBISU compute-bound vs SPIDER memory-bound;
        // SPIDER ~1003 GSt/s and a clear win.
        let gpu = Gpu::a100();
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
        let eb = predict(&engines::ebisu(), &w, &gpu).unwrap();
        let sp = predict(&engines::spider(), &w, &gpu).unwrap();
        assert_eq!(eb.bound, Bound::Compute);
        assert_eq!(sp.bound, Bound::Memory);
        assert!((sp.gstencils() - 1002.9).abs() / 1002.9 < 0.02, "{}", sp.gstencils());
        assert!(sp.gstencils() / eb.gstencils() > 1.2, "must clearly win");
    }

    #[test]
    fn table3_case5_and_6_degrade() {
        let gpu = Gpu::a100();
        // Case 5: Box-3D1R t=3 double.
        let w5 = wl(Shape::Box, 3, 1, 3, Dtype::F64);
        let eb = predict(&engines::ebisu(), &w5, &gpu).unwrap();
        let cv = predict(&engines::convstencil(), &w5, &gpu).unwrap();
        assert!(cv.gstencils() < eb.gstencils(), "case5 must degrade");
        // Case 6: Box-3D1R t=7 float on SPIDER: compute-bound both.
        let w6 = wl(Shape::Box, 3, 1, 7, Dtype::F32);
        let eb6 = predict(&engines::ebisu(), &w6, &gpu).unwrap();
        let sp6 = predict(&engines::spider(), &w6, &gpu).unwrap();
        assert_eq!(sp6.bound, Bound::Compute);
        assert!(sp6.gstencils() < eb6.gstencils(), "case6 must degrade");
    }

    #[test]
    fn table4_dense_vs_sparse() {
        // SPIDER-Dense 327.39 (compute) vs SPIDER-Sparse 1002.94 (memory):
        // 3.06× speedup from the 2:4 path.
        let gpu = Gpu::a100();
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
        let dense = predict(&engines::spider_dense(), &w, &gpu).unwrap();
        let sparse = predict(&engines::spider(), &w, &gpu).unwrap();
        assert_eq!(dense.bound, Bound::Compute);
        assert_eq!(sparse.bound, Bound::Memory);
        let speedup = sparse.gstencils() / dense.gstencils();
        assert!((2.0..4.5).contains(&speedup), "speedup={speedup}");
        assert!((dense.ridge - 80.6).abs() < 1.0);
        assert!((sparse.ridge - 161.2).abs() < 1.0);
    }

    #[test]
    fn unsupported_workload_errors() {
        let gpu = Gpu::a100();
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F64);
        assert!(predict(&engines::spider(), &w, &gpu).is_err()); // f64 on SPIDER
        assert!(predict(&engines::cudnn(), &wl(Shape::Box, 2, 1, 2, Dtype::F32), &gpu).is_err());
    }

    #[test]
    fn ideal_prediction_removes_eta() {
        let gpu = Gpu::a100();
        let w = wl(Shape::Box, 2, 1, 3, Dtype::F64);
        let p = predict(&engines::ebisu(), &w, &gpu).unwrap();
        let pi = predict_ideal(&engines::ebisu(), &w, &gpu).unwrap();
        assert!((pi.throughput * engines::ebisu().eta_mem - p.throughput).abs() < 1.0);
    }

    #[test]
    fn wall_time_rounds_up_launches() {
        let p = Prediction {
            engine: "x",
            unit: Unit::CudaCore,
            intensity: 1.0,
            ridge: 1.0,
            bound: Bound::Memory,
            raw_flops: 1.0,
            actual_flops: 1.0,
            throughput: 1e9,
        };
        // 10 steps at t=4 → 3 launches → 12 step-equivalents.
        let secs = wall_time(&p, 1_000_000, 10, 4);
        assert!((secs - 12.0 * 1e6 / 1e9).abs() < 1e-12);
    }

    #[test]
    fn v100_has_no_tensor_path() {
        let w = wl(Shape::Box, 2, 1, 3, Dtype::F32);
        assert!(predict(&engines::convstencil(), &w, &Gpu::v100()).is_err());
    }
}

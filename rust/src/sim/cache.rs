//! L2 cache behaviour.
//!
//! Two layers: (1) the *parametric* [`L2Model`] the counters use (halo hit
//! rate + compulsory filter fraction), and (2) a small set-associative LRU
//! [`CacheSim`] that replays a tile's halo access stream to show the
//! parametric numbers are the right order — ablation (c) in DESIGN.md.

/// Parametric L2 effect used by `counters::measured_m`.
#[derive(Debug, Clone, Copy)]
pub struct L2Model {
    /// Fraction of halo re-reads served on-chip.
    pub halo_hit_rate: f64,
    /// Fraction of compulsory traffic filtered (write coalescing etc.).
    pub compulsory_filter: f64,
}

impl L2Model {
    pub fn off() -> L2Model {
        L2Model { halo_hit_rate: 0.0, compulsory_filter: 0.0 }
    }
}

/// Set-associative LRU cache simulator (line granularity).
#[derive(Debug)]
pub struct CacheSim {
    sets: Vec<Vec<u64>>, // per set: line tags, most-recent last
    assoc: usize,
    line_bytes: u64,
    n_sets: u64,
    pub hits: u64,
    pub misses: u64,
}

impl CacheSim {
    /// `capacity_bytes` total, `assoc`-way, `line_bytes` lines.
    pub fn new(capacity_bytes: u64, assoc: usize, line_bytes: u64) -> CacheSim {
        assert!(capacity_bytes % (assoc as u64 * line_bytes) == 0);
        let n_sets = capacity_bytes / (assoc as u64 * line_bytes);
        CacheSim {
            sets: vec![Vec::with_capacity(assoc); n_sets as usize],
            assoc,
            line_bytes,
            n_sets,
            hits: 0,
            misses: 0,
        }
    }

    /// Access one byte address; returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr / self.line_bytes;
        let set = (line % self.n_sets) as usize;
        let tags = &mut self.sets[set];
        if let Some(pos) = tags.iter().position(|&t| t == line) {
            tags.remove(pos);
            tags.push(line);
            self.hits += 1;
            true
        } else {
            if tags.len() == self.assoc {
                tags.remove(0);
            }
            tags.push(line);
            self.misses += 1;
            false
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Replay the read stream of two adjacent 2D tiles (side `tile`, halo `h`,
/// element size `elem`) over a row-major field of width `width`, and
/// return the hit rate observed for the *second* tile's halo columns —
/// an estimate of `halo_hit_rate` for neighbour-sharing access patterns.
pub fn simulate_halo_hit_rate(
    tile: usize,
    h: usize,
    width: usize,
    elem: u64,
    l2_bytes: u64,
) -> f64 {
    const LINE: u64 = 128;
    let mut sim = CacheSim::new(l2_bytes, 16, LINE);
    // Access at LINE granularity (one probe per line) so the measured
    // rate reflects inter-tile reuse, not intra-line spatial locality.
    let line_elems = (LINE / elem).max(1) as usize;
    let addr = |row: usize, col: usize| -> u64 { ((row * width + col) as u64) * elem };
    // Tile A reads [0, tile+2h) × [0, tile+2h).
    for row in 0..tile + 2 * h {
        for col in (0..tile + 2 * h).step_by(line_elems) {
            sim.access(addr(row, col));
        }
    }
    // Tile B (right neighbour) reads [0, tile+2h) × [tile, 2·tile+2h);
    // its left halo columns [tile, tile+2h) were loaded by A.
    let mut halo_hits = 0u64;
    let mut halo_total = 0u64;
    for row in 0..tile + 2 * h {
        for col in (tile..2 * tile + 2 * h).step_by(line_elems) {
            let hit = sim.access(addr(row, col));
            if col < tile + 2 * h {
                halo_total += 1;
                if hit {
                    halo_hits += 1;
                }
            }
        }
    }
    halo_hits as f64 / halo_total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cold_cache_all_misses() {
        let mut c = CacheSim::new(1 << 20, 8, 128);
        for i in 0..100u64 {
            assert!(!c.access(i * 128));
        }
        assert_eq!(c.misses, 100);
        assert_eq!(c.hits, 0);
    }

    #[test]
    fn rereads_hit() {
        let mut c = CacheSim::new(1 << 20, 8, 128);
        c.access(0);
        assert!(c.access(0));
        assert!(c.access(64)); // same 128B line
        assert_eq!(c.hits, 2);
    }

    #[test]
    fn lru_evicts_oldest() {
        // 2 sets × 2-way × 128B = 512B cache; addresses mapping to set 0:
        let mut c = CacheSim::new(512, 2, 128);
        c.access(0); // line 0 -> set 0
        c.access(256); // line 2 -> set 0
        c.access(512); // line 4 -> set 0, evicts line 0
        assert!(!c.access(0), "line 0 must have been evicted");
        assert!(c.access(512));
    }

    #[test]
    fn halo_hit_rate_high_when_l2_fits_rows() {
        // A100-ish 40 MiB L2 easily retains a 352-wide tile stream.
        let rate = simulate_halo_hit_rate(352, 7, 4096, 4, 40 << 20);
        assert!(rate > 0.9, "rate={rate}");
    }

    #[test]
    fn halo_hit_rate_collapses_with_tiny_cache() {
        let rate = simulate_halo_hit_rate(352, 7, 4096, 4, 1 << 14);
        assert!(rate < 0.5, "rate={rate}");
    }

    #[test]
    fn parametric_defaults_bracket_simulated() {
        // counters::Schedule::cuda_core uses 0.95 — the line-replay sim
        // on realistic sizes lands at/above that.
        let rate = simulate_halo_hit_rate(352, 3, 8192, 8, 40 << 20);
        assert!(rate >= 0.95, "rate={rate}");
    }
}

//! Executed-operation and memory-traffic counters (the "achieved work /
//! achieved traffic" ncu reports, §5.2).
//!
//! The analytical model (Eq. 6–12) deliberately omits two implementation
//! realities the profiler sees (§5.2.4):
//!
//! * **C inflation** — thread blocks recompute their halo: temporally
//!   fused kernels walk a trapezoid (step s computes a region enlarged by
//!   2r(t−s)), and blocks additionally compute a spatial halo ring of
//!   width ~r to avoid divergent edges.  Both are exact geometry given
//!   the engine's GPU tile side.
//! * **M deflation** — the L2 cache serves most halo re-reads and filters
//!   a small fraction of compulsory traffic, so DRAM traffic lands
//!   slightly *below* 2D bytes/point (or above, when halo spill exceeds
//!   the filter — ConvStencil at deep fusion, Table 2 row 7).

use crate::model::perf::Workload;
use crate::sim::cache::L2Model;

/// GPU-schedule parameters of an engine implementation.
#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    /// Effective thread-block tile side on the GPU (per dimension).
    pub tile_side: usize,
    /// Spatial halo-compute width factor (×r): blocks compute this ring.
    pub halo_compute: f64,
    /// L2 behaviour for this engine's access pattern.
    pub l2: L2Model,
}

impl Schedule {
    /// CUDA-Core temporal-blocking engines (EBISU/DRStencil family).
    pub fn cuda_core() -> Schedule {
        Schedule {
            tile_side: 224,
            halo_compute: 1.0,
            l2: L2Model { halo_hit_rate: 0.95, compulsory_filter: 0.005 },
        }
    }

    /// Dense-TC engines (ConvStencil family): im2col gathers spill more.
    pub fn tensor_core() -> Schedule {
        Schedule {
            tile_side: 224,
            halo_compute: 0.6,
            l2: L2Model { halo_hit_rate: 0.60, compulsory_filter: 0.005 },
        }
    }

    /// SpTC engines (SPIDER family): compressed operands, tight traffic.
    pub fn sparse_tensor_core() -> Schedule {
        Schedule {
            tile_side: 512,
            halo_compute: 0.0,
            l2: L2Model { halo_hit_rate: 0.97, compulsory_filter: 0.012 },
        }
    }
}

/// Counted (measured) per-point metrics.
#[derive(Debug, Clone, Copy)]
pub struct Counted {
    /// Executed FLOPs per output point (incl. halo recompute).
    pub c: f64,
    /// DRAM bytes per output point (after L2 filtering).
    pub m: f64,
}

impl Counted {
    pub fn intensity(&self) -> f64 {
        self.c / self.m
    }
}

/// Exact trapezoid + spatial-halo compute inflation factor (≥ 1).
///
/// Step s ∈ 1..=t of an in-block fused kernel computes a region of side
/// T + 2r(t−s) + 2·hc·r; the factor is the total over t steps relative to
/// the ideal t·T^d.
pub fn compute_inflation(w: &Workload, sched: &Schedule) -> f64 {
    let t = w.t as f64;
    let r = w.pattern.r as f64;
    let d = w.pattern.d as i32;
    let side = sched.tile_side as f64;
    let mut total = 0.0;
    for s in 1..=w.t {
        let grown = side + 2.0 * r * (w.t - s) as f64 + 2.0 * sched.halo_compute * r;
        total += grown.powi(d);
    }
    total / (t * side.powi(d))
}

/// Fraction of extra (halo) reads relative to compulsory reads.
pub fn halo_read_fraction(w: &Workload, sched: &Schedule) -> f64 {
    let rt = (w.pattern.r * w.t) as f64;
    let side = sched.tile_side as f64;
    let d = w.pattern.d as i32;
    ((side + 2.0 * rt).powi(d) - side.powi(d)) / side.powi(d)
}

/// Measured C per point: analytical C × geometric inflation.
pub fn measured_c(w: &Workload, c_analytical: f64, sched: &Schedule) -> f64 {
    c_analytical * compute_inflation(w, sched)
}

/// Measured M per point: compulsory 2D bytes, + the halo re-reads the L2
/// fails to serve, − the compulsory traffic it filters.
pub fn measured_m(w: &Workload, sched: &Schedule) -> f64 {
    let d_bytes = w.dtype.bytes() as f64;
    let compulsory = 2.0 * d_bytes;
    let halo_reads = d_bytes * halo_read_fraction(w, sched);
    let spill = halo_reads * (1.0 - sched.l2.halo_hit_rate);
    let filtered = compulsory * sched.l2.compulsory_filter;
    compulsory + spill - filtered
}

/// Full counted metrics for a workload on an engine schedule, given the
/// engine's analytical C (CUDA: t·2K; TC: (α/S)·t·2K).
pub fn count(w: &Workload, c_analytical: f64, sched: &Schedule) -> Counted {
    Counted { c: measured_c(w, c_analytical, sched), m: measured_m(w, sched) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::perf::{Dtype, Workload};
    use crate::model::stencil::{Shape, StencilPattern};

    fn wl(r: usize, t: usize, dt: Dtype) -> Workload {
        Workload::new(StencilPattern::new(Shape::Box, 2, r).unwrap(), t, dt)
    }

    #[test]
    fn inflation_is_at_least_one_and_shrinks_with_tile() {
        let w = wl(1, 3, Dtype::F64);
        let small = Schedule { tile_side: 64, ..Schedule::cuda_core() };
        let big = Schedule { tile_side: 512, ..Schedule::cuda_core() };
        assert!(compute_inflation(&w, &small) > compute_inflation(&w, &big));
        assert!(compute_inflation(&w, &big) > 1.0);
    }

    #[test]
    fn table2_row1_c_delta_shape() {
        // EBISU Box-2D1R t=3 double: paper ΔC = +3.30%.
        let w = wl(1, 3, Dtype::F64);
        let c = measured_c(&w, w.c_cuda(), &Schedule::cuda_core());
        let delta = (c - 54.0) / 54.0;
        assert!((0.02..0.05).contains(&delta), "ΔC={delta}");
    }

    #[test]
    fn table2_row3_c_delta_shape() {
        // EBISU Box-2D1R t=7 float: paper ΔC = +9.01%.
        let w = wl(1, 7, Dtype::F32);
        let c = measured_c(&w, w.c_cuda(), &Schedule::cuda_core());
        let delta = (c - 126.0) / 126.0;
        assert!((0.06..0.12).contains(&delta), "ΔC={delta}");
    }

    #[test]
    fn table2_row4_c_delta_shape() {
        // EBISU Box-2D7R t=1 float: paper ΔC = +7.61% (pure spatial halo).
        let w = wl(7, 1, Dtype::F32);
        let c = measured_c(&w, w.c_cuda(), &Schedule::cuda_core());
        let delta = (c - 450.0) / 450.0;
        assert!((0.04..0.16).contains(&delta), "ΔC={delta}");
    }

    #[test]
    fn table2_m_deltas_small_and_signed() {
        // EBISU rows: M lands slightly BELOW analytical (−0.3…−1.1%).
        let sched = Schedule::cuda_core();
        for (r, t, dt, m_a) in [
            (1usize, 3usize, Dtype::F64, 16.0),
            (3, 1, Dtype::F64, 16.0),
            (1, 7, Dtype::F32, 8.0),
            (7, 1, Dtype::F32, 8.0),
        ] {
            let m = measured_m(&wl(r, t, dt), &sched);
            let delta = (m - m_a) / m_a;
            assert!((-0.02..0.0).contains(&delta), "r={r} t={t} ΔM={delta}");
        }
    }

    #[test]
    fn convstencil_deep_fusion_m_exceeds_analytical() {
        // Table 2 row 7: ConvStencil t=7 float ΔM = +3.36% — halo spill
        // beats the L2 filter for the im2col access pattern.
        let w = wl(1, 7, Dtype::F32);
        let m = measured_m(&w, &Schedule::tensor_core());
        let delta = (m - 8.0) / 8.0;
        assert!((0.005..0.06).contains(&delta), "ΔM={delta}");
    }

    #[test]
    fn spider_m_below_analytical() {
        // Table 2 row 9: SPIDER ΔM = −1.35%.
        let w = wl(1, 7, Dtype::F32);
        let m = measured_m(&w, &Schedule::sparse_tensor_core());
        let delta = (m - 8.0) / 8.0;
        assert!((-0.02..0.0).contains(&delta), "ΔM={delta}");
    }

    #[test]
    fn spider_c_counts_exactly() {
        // Table 2 row 9: SPIDER ΔC = 0.00% — no halo recompute.
        let w = wl(1, 7, Dtype::F32);
        let sched = Schedule::sparse_tensor_core();
        // trapezoid vanishes: SPIDER issues ONE fused kernel (t steps in
        // one monolithic GEMM), so s runs 1..=1 at full depth... model it
        // as t=1 at the fused radius: feed c analytical directly.
        let c_a = w.alpha() / 0.46875 * w.c_cuda();
        let mono = Workload::new(w.pattern, 1, w.dtype);
        let c = measured_c(&mono, c_a, &sched);
        assert!((c - c_a) / c_a < 0.001, "ΔC={}", (c - c_a) / c_a);
    }

    #[test]
    fn counted_intensity_consistent() {
        let w = wl(1, 3, Dtype::F64);
        let got = count(&w, w.c_cuda(), &Schedule::cuda_core());
        assert!((got.intensity() - got.c / got.m).abs() < 1e-12);
        assert!(got.intensity() > w.intensity_cuda()); // C up, M down
    }
}

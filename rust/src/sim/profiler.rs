//! ncu facade: "profiled" kernel metrics — analytical vs measured C/M/I
//! with the paper's Δ formatting.  Backs the Table 2 reproduction.

use crate::engines::Engine;
use crate::model::perf::{Unit, Workload};
use crate::sim::counters::{self, Schedule};

/// One Table-2-style row: analytical and measured per-point metrics.
#[derive(Debug, Clone)]
pub struct ProfiledKernel {
    pub engine: &'static str,
    pub pattern: String,
    pub t: usize,
    pub dtype: &'static str,
    pub alpha: Option<f64>,
    pub sparsity: Option<f64>,
    pub c_analytical: f64,
    pub m_analytical: f64,
    pub i_analytical: f64,
    pub c_measured: f64,
    pub m_measured: f64,
    pub i_measured: f64,
}

impl ProfiledKernel {
    pub fn delta_c(&self) -> f64 {
        (self.c_measured - self.c_analytical) / self.c_analytical
    }

    pub fn delta_m(&self) -> f64 {
        (self.m_measured - self.m_analytical) / self.m_analytical
    }

    pub fn delta_i(&self) -> f64 {
        (self.i_measured - self.i_analytical) / self.i_analytical
    }
}

/// Engine-appropriate GPU schedule for the counters.
pub fn schedule_for(e: &Engine) -> Schedule {
    match e.unit {
        Unit::CudaCore => Schedule::cuda_core(),
        Unit::TensorCore => Schedule::tensor_core(),
        Unit::SparseTensorCore => Schedule::sparse_tensor_core(),
    }
}

/// Profile one (engine, workload) pair — the ncu "achieved work/traffic".
pub fn profile(e: &Engine, w: &Workload) -> ProfiledKernel {
    let sched = schedule_for(e);
    let is_tensor = e.is_tensor();
    let (c_a, alpha, s) = if is_tensor {
        let s = e.sparsity(w);
        (w.alpha() / s * w.c_cuda(), Some(w.alpha()), Some(s))
    } else {
        (w.c_cuda(), None, None)
    };
    let m_a = w.m_bytes();
    // Tensor-core engines launch ONE monolithic kernel per t steps — the
    // trapezoid recompute collapses (§2.2.3); model via a t=1 workload at
    // the same fused footprint.
    let count_w = if is_tensor { Workload::new(w.pattern, 1, w.dtype) } else { *w };
    let counted = counters::count(&count_w, c_a, &sched);
    ProfiledKernel {
        engine: e.name,
        pattern: w.pattern.label(),
        t: w.t,
        dtype: w.dtype.as_str(),
        alpha,
        sparsity: s,
        c_analytical: c_a,
        m_analytical: m_a,
        i_analytical: c_a / m_a,
        c_measured: counted.c,
        m_measured: counted.m,
        i_measured: counted.c / counted.m,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engines;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn wl(r: usize, t: usize, dt: Dtype) -> Workload {
        Workload::new(StencilPattern::new(Shape::Box, 2, r).unwrap(), t, dt)
    }

    #[test]
    fn table2_row1_full_row() {
        let p = profile(&engines::ebisu(), &wl(1, 3, Dtype::F64));
        assert_eq!(p.c_analytical, 54.0);
        assert_eq!(p.m_analytical, 16.0);
        assert!((p.i_analytical - 3.375).abs() < 1e-12);
        // paper: C 55.78 (+3.30%), M 15.95 (−0.30%), I 3.50 (+3.61%)
        assert!(p.delta_c() > 0.0 && p.delta_c() < 0.06);
        assert!(p.delta_m() < 0.0 && p.delta_m() > -0.02);
        assert!(p.delta_i() > p.delta_c()); // C up & M down ⇒ I up more
    }

    #[test]
    fn table2_row5_convstencil() {
        let p = profile(&engines::convstencil(), &wl(1, 3, Dtype::F64));
        assert!((p.c_analytical - 196.0).abs() < 1e-9);
        assert!((p.i_analytical - 12.25).abs() < 1e-9);
        assert_eq!(p.alpha.map(|a| (a * 100.0).round() / 100.0), Some(1.81));
        assert_eq!(p.sparsity, Some(0.5));
    }

    #[test]
    fn table2_row9_spider() {
        let p = profile(&engines::spider(), &wl(1, 7, Dtype::F32));
        assert!((p.c_analytical - 960.0).abs() < 1e-9);
        assert!((p.i_analytical - 120.0).abs() < 1e-9);
        // ΔC ≈ 0 (row 9 reports exactly 0.00%)
        assert!(p.delta_c().abs() < 0.005, "{}", p.delta_c());
        assert!(p.delta_m() < 0.0);
    }

    #[test]
    fn cuda_rows_have_no_alpha_s() {
        let p = profile(&engines::ebisu(), &wl(3, 1, Dtype::F64));
        assert!(p.alpha.is_none() && p.sparsity.is_none());
    }

    #[test]
    fn measured_c_always_at_least_analytical() {
        for e in [engines::ebisu(), engines::convstencil(), engines::spider()] {
            for t in [1usize, 3, 7] {
                let p = profile(&e, &wl(1, t, Dtype::F32));
                assert!(p.c_measured >= p.c_analytical * 0.999, "{} t={t}", e.name);
            }
        }
    }
}

//! Rust-native scalar stencil oracle.
//!
//! Mirrors python/compile/kernels/ref.py exactly (zero Dirichlet halo;
//! sequential vs fused semantics) so integration tests can check the PJRT
//! artifacts against an implementation with no shared code or runtime.

/// Row-major strides for a dims vector — the single stride definition
/// shared by [`Field`], kernel fusion, and the native backend (their
/// bit-identity guarantee depends on agreeing on layout).
pub(crate) fn strides_for(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

/// A dense d-dimensional field (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Field {
    pub dims: Vec<usize>,
    pub data: Vec<f64>,
}

impl Field {
    pub fn zeros(dims: &[usize]) -> Field {
        Field { dims: dims.to_vec(), data: vec![0.0; dims.iter().product()] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<f64>) -> Field {
        assert_eq!(data.len(), dims.iter().product::<usize>());
        Field { dims: dims.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Row-major strides of this field's dims.
    fn strides(&self) -> Vec<usize> {
        strides_for(&self.dims)
    }

    /// Value at a (possibly out-of-domain) signed index — zero halo.
    /// `strides` are hoisted to the caller: recomputing (and
    /// heap-allocating) them per point access dominated `apply_once`.
    fn at_or_zero(&self, idx: &[i64], strides: &[usize]) -> f64 {
        let mut flat = 0usize;
        for ((&i, &n), &s) in idx.iter().zip(&self.dims).zip(strides) {
            if i < 0 || i >= n as i64 {
                return 0.0;
            }
            flat += i as usize * s;
        }
        self.data[flat]
    }

    pub fn max_abs_diff(&self, other: &Field) -> f64 {
        assert_eq!(self.dims, other.dims);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Weight kernel over a (2r+1)^d hull (row-major, zeros off-support).
#[derive(Debug, Clone)]
pub struct Weights {
    pub d: usize,
    pub side: usize, // 2r+1 (odd)
    pub data: Vec<f64>,
}

impl Weights {
    pub fn new(d: usize, side: usize, data: Vec<f64>) -> Weights {
        assert!(side % 2 == 1);
        assert_eq!(data.len(), side.pow(d as u32));
        Weights { d, side, data }
    }

    pub fn r(&self) -> usize {
        (self.side - 1) / 2
    }

    /// Non-zero hull offsets (row-major hull order) with their weights —
    /// the canonical accumulation order every backend mirrors.
    pub fn offsets(&self) -> Vec<(Vec<i64>, f64)> {
        let r = self.r() as i64;
        let mut out = Vec::new();
        let n = self.side;
        for (flat, &w) in self.data.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let mut idx = Vec::with_capacity(self.d);
            let mut rem = flat;
            for k in (0..self.d).rev() {
                idx.push((rem % n) as i64 - r);
                rem /= n;
                let _ = k;
            }
            idx.reverse();
            out.push((idx, w));
        }
        out
    }

    /// Full nd self-convolution t-fold — the monolithic fused kernel.
    pub fn fuse(&self, t: usize) -> Weights {
        assert!(t >= 1);
        let mut acc = self.clone();
        for _ in 1..t {
            acc = acc.convolve(self);
        }
        acc
    }

    fn convolve(&self, other: &Weights) -> Weights {
        assert_eq!(self.d, other.d);
        let side = self.side + other.side - 1;
        let r_out = (side - 1) as i64 / 2;
        let mut out = Weights::new(self.d, side, vec![0.0; side.pow(self.d as u32)]);
        let strides = strides_for(&vec![side; self.d]);
        for (a_off, a_w) in self.offsets() {
            for (b_off, b_w) in other.offsets() {
                let mut flat = 0usize;
                for k in 0..self.d {
                    flat += (a_off[k] + b_off[k] + r_out) as usize * strides[k];
                }
                out.data[flat] += a_w * b_w;
            }
        }
        out
    }
}

/// The standard initial condition: a centered Gaussian bump over the
/// domain (row-major).  Shared by `stencilctl run`, the service's
/// `init: "gaussian"` sessions, and the integration tests, so a client
/// can reproduce a server-side field without shipping it over the wire.
pub fn gaussian(domain: &[usize]) -> Vec<f64> {
    let n: usize = domain.iter().product();
    let mut out = vec![0.0; n];
    let d = domain.len();
    let mut idx = vec![0usize; d];
    for (flat, v) in out.iter_mut().enumerate() {
        let mut rem = flat;
        for k in (0..d).rev() {
            idx[k] = rem % domain[k];
            rem /= domain[k];
        }
        let mut q = 0.0;
        for k in 0..d {
            let c = (idx[k] as f64 - domain[k] as f64 / 2.0) / (domain[k] as f64 / 6.0);
            q += c * c;
        }
        *v = (-q / 2.0).exp();
    }
    out
}

/// One stencil application with zero halo.
pub fn apply_once(x: &Field, w: &Weights) -> Field {
    assert_eq!(x.dims.len(), w.d);
    let mut out = Field::zeros(&x.dims);
    let offsets = w.offsets();
    let dims = x.dims.clone();
    let strides = x.strides();
    let mut idx = vec![0i64; w.d];
    let mut nb = vec![0i64; w.d];
    for flat in 0..out.len() {
        // decompose flat -> idx
        let mut rem = flat;
        for k in (0..w.d).rev() {
            idx[k] = (rem % dims[k]) as i64;
            rem /= dims[k];
        }
        let mut acc = 0.0;
        for (off, wv) in &offsets {
            for k in 0..w.d {
                nb[k] = idx[k] + off[k];
            }
            acc += wv * x.at_or_zero(&nb, &strides);
        }
        out.data[flat] = acc;
    }
    out
}

/// t sequential steps (CUDA-Core semantics).
pub fn apply_steps(x: &Field, w: &Weights, t: usize) -> Field {
    let mut cur = x.clone();
    for _ in 0..t {
        cur = apply_once(&cur, w);
    }
    cur
}

/// One application of the fused kernel (Tensor-Core semantics).
pub fn apply_fused(x: &Field, w: &Weights, t: usize) -> Field {
    apply_once(x, &w.fuse(t))
}

/// Deterministic per-point coefficient modulation for variable-coefficient
/// stencils: a hash of (output flat index, tap index) mapped into
/// [0.5, 1.5). The tap index is the position of the tap in
/// [`Weights::offsets`] — the canonical enumeration every backend
/// mirrors — so oracle and executor agree on which factor scales which
/// tap. Only the low 16 product bits are kept, so the value is identical
/// on every platform with usize ≥ 32 bits.
pub fn vc_mod(flat: usize, tap: usize) -> f64 {
    let h = flat
        .wrapping_mul(0x9E37_79B1)
        .wrapping_add(tap.wrapping_mul(0x85EB_CA77))
        & 0xFFFF;
    0.5 + h as f64 / 65536.0
}

/// One variable-coefficient application with zero halo: tap `j`'s
/// effective weight at output point `flat` is `w_j · vc_mod(flat, j)`,
/// multiplied out *before* the tap's multiply-accumulate so the
/// per-point accumulation chain is `acc + (w·m)·v`, left to right in
/// offsets order — the exact recipe the native backend replays.
pub fn apply_once_varcoef(x: &Field, w: &Weights) -> Field {
    assert_eq!(x.dims.len(), w.d);
    let mut out = Field::zeros(&x.dims);
    let offsets = w.offsets();
    let dims = x.dims.clone();
    let strides = x.strides();
    let mut idx = vec![0i64; w.d];
    let mut nb = vec![0i64; w.d];
    for flat in 0..out.len() {
        let mut rem = flat;
        for k in (0..w.d).rev() {
            idx[k] = (rem % dims[k]) as i64;
            rem /= dims[k];
        }
        let mut acc = 0.0;
        for (j, (off, wv)) in offsets.iter().enumerate() {
            for k in 0..w.d {
                nb[k] = idx[k] + off[k];
            }
            acc += (wv * vc_mod(flat, j)) * x.at_or_zero(&nb, &strides);
        }
        out.data[flat] = acc;
    }
    out
}

/// t sequential variable-coefficient steps (the modulation field is
/// time-invariant: every step applies the same per-point factors).
pub fn apply_steps_varcoef(x: &Field, w: &Weights, t: usize) -> Field {
    let mut cur = x.clone();
    for _ in 0..t {
        cur = apply_once_varcoef(&cur, w);
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn identity3(d: usize) -> Weights {
        let side = 3usize;
        let mut data = vec![0.0; side.pow(d as u32)];
        let center = data.len() / 2;
        data[center] = 1.0;
        Weights::new(d, side, data)
    }

    fn box_avg(d: usize, r: usize) -> Weights {
        let side = 2 * r + 1;
        let n = side.pow(d as u32);
        Weights::new(d, side, vec![1.0 / n as f64; n])
    }

    fn rand_field(rng: &mut Rng, dims: &[usize]) -> Field {
        Field::from_vec(dims, (0..dims.iter().product()).map(|_| rng.normal()).collect())
    }

    #[test]
    fn identity_kernel_preserves_field() {
        let mut rng = Rng::new(1);
        let x = rand_field(&mut rng, &[6, 6]);
        let y = apply_once(&x, &identity3(2));
        assert!(x.max_abs_diff(&y) < 1e-15);
    }

    #[test]
    fn constant_field_interior_average() {
        let x = Field::from_vec(&[8, 8], vec![1.0; 64]);
        let y = apply_once(&x, &box_avg(2, 1));
        // interior cells: average of nine 1s = 1
        assert!((y.data[3 * 8 + 3] - 1.0).abs() < 1e-12);
        // corner sees 5 zero-halo neighbours: 4/9
        assert!((y.data[0] - 4.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn fused_equals_sequential_in_interior() {
        let mut rng = Rng::new(7);
        let x = rand_field(&mut rng, &[16, 16]);
        let w = box_avg(2, 1);
        let t = 3;
        let seq = apply_steps(&x, &w, t);
        let fus = apply_fused(&x, &w, t);
        // interior (≥ rt from edges) must match exactly
        for i in 3..13usize {
            for j in 3..13usize {
                let a = seq.data[i * 16 + j];
                let b = fus.data[i * 16 + j];
                assert!((a - b).abs() < 1e-12, "({i},{j}): {a} vs {b}");
            }
        }
        // and boundaries genuinely differ (the ref.py semantics note)
        assert!(seq.max_abs_diff(&fus) > 1e-9);
    }

    #[test]
    fn fuse_support_size_box() {
        let w = box_avg(2, 1);
        let wf = w.fuse(3);
        assert_eq!(wf.side, 7);
        assert_eq!(wf.offsets().len(), 49);
    }

    #[test]
    fn fuse_mass_preserved() {
        let w = box_avg(3, 1);
        let wf = w.fuse(2);
        let sum: f64 = wf.data.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
    }

    #[test]
    fn works_in_3d() {
        let mut rng = Rng::new(3);
        let x = rand_field(&mut rng, &[6, 6, 6]);
        let y = apply_once(&x, &identity3(3));
        assert!(x.max_abs_diff(&y) < 1e-15);
        let z = apply_steps(&x, &box_avg(3, 1), 2);
        assert_eq!(z.dims, vec![6, 6, 6]);
    }

    #[test]
    fn vc_mod_is_deterministic_and_bounded() {
        // hand-walked low 16 bits of flat·0x9E3779B1 + tap·0x85EBCA77
        assert_eq!(vc_mod(0, 0), 0.5); // h = 0
        assert_eq!(vc_mod(0, 1), 0.5 + 51831.0 / 65536.0);
        assert_eq!(vc_mod(1, 0), 0.5 + 31153.0 / 65536.0);
        assert_eq!(vc_mod(2, 1), 0.5 + 48601.0 / 65536.0);
        for flat in 0..64 {
            for tap in 0..8 {
                let m = vc_mod(flat, tap);
                assert!((0.5..1.5).contains(&m));
                assert_eq!(m, vc_mod(flat, tap), "pure function");
            }
        }
    }

    #[test]
    fn varcoef_1d_three_point_fixture() {
        // w = [0.2, 0.5, 0.3] over x = [1, 2, 3], zero halo.  Expected
        // values hand-derived from the pinned vc_mod table above, e.g.
        // out[0] = 0.5·(0.5+17448/65536)·... — exact decimal reprs.
        let w = Weights::new(1, 3, vec![0.2, 0.5, 0.3]);
        let x = Field::from_vec(&[3], vec![1.0, 2.0, 3.0]);
        let y = apply_once_varcoef(&x, &w);
        assert_eq!(y.data, vec![1.2944931030273437, 1.4627090454101561, 2.442674255371094]);
    }

    #[test]
    fn varcoef_star_2d_delta_fixture() {
        // Star-2D1R uniform (1/5 per tap) applied to a unit impulse at
        // the center of a 3×3 field: out[p] = 0.2·vc_mod(p, j(p)) on the
        // 5 support points, 0 elsewhere.
        let mut data = vec![0.0; 9];
        for i in [1, 3, 4, 5, 7] {
            data[i] = 0.2;
        }
        let w = Weights::new(2, 3, data);
        let mut x = Field::zeros(&[3, 3]);
        x.data[4] = 1.0;
        let y = apply_once_varcoef(&x, &w);
        let expect = [
            0.0,
            0.22777404785156252,
            0.0,
            0.2597412109375,
            0.19663696289062502,
            0.13353271484375,
            0.0,
            0.1654998779296875,
            0.0,
        ];
        assert_eq!(y.data, expect);
    }

    #[test]
    fn sparse24_1d_fixture_runs_through_plain_apply() {
        // 2:4-pruned star-1d1r keeps offsets {-1, 0} with weight 1/2
        // each; the pruned kernel is just a Weights with zeros dropped,
        // so the *dense* oracle applies unchanged: out = (x[i-1]+x[i])/2.
        use crate::model::stencil::{Coeffs, Shape, StencilPattern};
        let p = StencilPattern::new(Shape::Star, 1, 1)
            .unwrap()
            .with_coeffs(Coeffs::Sparse24);
        let wv = p.default_weights();
        assert_eq!(wv, vec![0.5, 0.5, 0.0]);
        let w = Weights::new(1, 3, wv);
        assert_eq!(w.offsets().len() as u64, p.effective_k_points());
        let x = Field::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        let y = apply_once(&x, &w);
        assert_eq!(y.data, vec![0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn aniso_1d_fixture() {
        // Aniso star-1d1r weights: raw factors (1.1 + off/8) for off in
        // {-1,0,1} → [0.975, 1.1, 1.225]/3.3, applied to [2, 4, 6].
        use crate::model::stencil::{Coeffs, Shape, StencilPattern};
        let p = StencilPattern::new(Shape::Star, 1, 1)
            .unwrap()
            .with_coeffs(Coeffs::Aniso);
        let wv = p.default_weights();
        assert_eq!(wv, vec![0.29545454545454547, 0.3333333333333333, 0.3712121212121212]);
        let w = Weights::new(1, 3, wv);
        let x = Field::from_vec(&[3], vec![2.0, 4.0, 6.0]);
        let y = apply_once(&x, &w);
        assert_eq!(y.data, vec![2.1515151515151514, 4.151515151515152, 3.1818181818181817]);
    }

    #[test]
    fn varcoef_steps_compose_single_applications() {
        let mut rng = Rng::new(11);
        let x = rand_field(&mut rng, &[7, 5]);
        let w = box_avg(2, 1);
        let once = apply_once_varcoef(&x, &w);
        let twice = apply_once_varcoef(&once, &w);
        let stepped = apply_steps_varcoef(&x, &w, 2);
        assert_eq!(twice.data, stepped.data);
        // and it genuinely differs from the constant-coefficient result
        assert!(apply_steps(&x, &w, 2).max_abs_diff(&stepped) > 1e-6);
    }

    #[test]
    fn shift_kernel_moves_mass() {
        // weight at offset (-1, 0): out[i][j] = x[i-1][j]... careful:
        // out[i] = sum w[off]·x[i+off]; off=(-1,0) reads the row above.
        let mut data = vec![0.0; 9];
        data[1] = 1.0; // hull index (0,1) → offset (-1,0)
        let w = Weights::new(2, 3, data);
        let mut x = Field::zeros(&[4, 4]);
        x.data[1 * 4 + 2] = 5.0;
        let y = apply_once(&x, &w);
        assert_eq!(y.data[2 * 4 + 2], 5.0); // moved DOWN one row
        assert_eq!(y.data.iter().filter(|&&v| v != 0.0).count(), 1);
    }
}

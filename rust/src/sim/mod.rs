//! The execution "testbed" standing in for the paper's A100 (DESIGN.md §2).
//!
//! * [`counters`] — executed-FLOP / DRAM-traffic counting over the real
//!   GPU tiling schedule (temporal-trapezoid recompute + spatial halo);
//!   reproduces the systematic C/M deviations of Table 2 (§5.2.4).
//! * [`cache`]    — L2 filter: parametric model + a small set-associative
//!   LRU simulator used to justify the parameters (ablation (c)).
//! * [`exec`]     — throughput/time prediction: calibrated roofline
//!   (η × min(ℙ, 𝔹·I)) per engine × workload × GPU.
//! * [`profiler`] — ncu facade: "achieved work/traffic" reports.
//! * [`golden`]   — rust-native scalar stencil oracle for integration
//!   tests against the PJRT artifacts.

pub mod counters;
pub mod cache;
pub mod exec;
pub mod profiler;
pub mod golden;

//! Prometheus-style metrics: lock-free histograms with power-of-two
//! (log-bucketed) bounds, and the text exposition the `stats --prom` /
//! `"metrics"` protocol surfaces render.
//!
//! Histograms are **always on** — observations are counter updates
//! that never change replies, so they need no enable gate (unlike
//! spans).  Bucket bounds are powers of two, `le = 2^e` for
//! `e ∈ [emin, emax]` plus a `+Inf` overflow bucket: exact to compare
//! against, cheap to index, and wide enough that one layout covers
//! nanosecond stalls and multi-second jobs alike.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::metrics::{ServiceSnapshot, TenantRow};
use crate::service::plan_cache::CacheStats;

/// Lock-free histogram with `le = 2^e` bucket bounds.
///
/// Per-bucket counts are stored *non*-cumulative (one `fetch_add` per
/// observation touches exactly one bucket) and cumulated at exposition
/// time, where Prometheus' `le` convention wants running totals.
#[derive(Debug)]
pub struct Histogram {
    emin: i32,
    emax: i32,
    /// One slot per finite bound, plus the `+Inf` overflow slot.
    buckets: Vec<AtomicU64>,
    /// Σ observed values, carried as f64 bits under CAS so `sum` stays
    /// lock-free alongside the bucket counters.
    sum_bits: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    /// A histogram with finite bounds `2^emin ..= 2^emax` (`emax ≥
    /// emin` enforced) and a `+Inf` overflow bucket.
    pub fn new(emin: i32, emax: i32) -> Histogram {
        let emax = emax.max(emin);
        let finite = (emax - emin + 1) as usize;
        Histogram {
            emin,
            emax,
            buckets: (0..=finite).map(|_| AtomicU64::new(0)).collect(),
            sum_bits: AtomicU64::new(0.0_f64.to_bits()),
            count: AtomicU64::new(0),
        }
    }

    /// The finite bucket bounds, ascending (`2^emin ..= 2^emax`).
    pub fn bounds(&self) -> Vec<f64> {
        (self.emin..=self.emax).map(|e| 2.0_f64.powi(e)).collect()
    }

    /// Index of the bucket an observation lands in: the first bound
    /// with `v <= 2^e` (Prometheus' inclusive-`le` convention), or the
    /// overflow slot past them all.  Negative values clamp into the
    /// first bucket; the scan is exact at every boundary because both
    /// sides are powers of two.
    pub fn bucket_index(&self, v: f64) -> usize {
        let v = v.max(0.0);
        for (i, bound) in self.bounds().iter().enumerate() {
            if v <= *bound {
                return i;
            }
        }
        self.buckets.len() - 1
    }

    /// Record one observation (NaN/∞ are dropped: a non-finite sample
    /// carries no magnitude to bucket and would poison `sum`).
    pub fn observe(&self, v: f64) {
        if !v.is_finite() {
            return;
        }
        let idx = self.bucket_index(v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v.max(0.0)).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observed values (negative samples clamp to 0, matching
    /// the bucketing).
    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// Per-bucket (non-cumulative) counts, overflow slot last.
    pub fn snapshot(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Estimate the `q`-quantile (`0 < q <= 1`) from the bucket
    /// counts: walk the cumulative distribution to the rank and return
    /// that bucket's **upper bound**.
    ///
    /// Error bound: buckets are `le = 2^e`, so the true sample lies in
    /// `(bound/2, bound]` — the estimate is never below the true value
    /// and **at most 2× above it** (exactly the bucket resolution).
    /// Samples that clamped into the first bucket can be overestimated
    /// by more than 2× (the bucket floor truncates the distribution's
    /// left tail); latency layouts put `2^emin` well below interesting
    /// values so this only affects sub-microsecond noise.  Returns
    /// `None` on an empty histogram and `+∞` when the rank lands in the
    /// overflow bucket (the estimator refuses to invent a finite bound
    /// it doesn't have).
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let counts = self.snapshot();
        let total: u64 = counts.iter().sum();
        if total == 0 || !(0.0..=1.0).contains(&q) {
            return None;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, n) in counts.iter().enumerate() {
            cum += n;
            if cum >= rank {
                return Some(if i < counts.len() - 1 {
                    2.0_f64.powi(self.emin + i as i32)
                } else {
                    f64::INFINITY
                });
            }
        }
        unreachable!("rank <= total")
    }

    /// Append this histogram's exposition lines (cumulative `le`
    /// buckets, `_sum`, `_count`) under `name`, with optional extra
    /// `labels` (e.g. `kernel="star-2d1r/double/avx2"`).
    fn render(&self, out: &mut String, name: &str, labels: &str) {
        let counts = self.snapshot();
        let sep = if labels.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        for (bound, n) in self.bounds().iter().zip(&counts) {
            cum += n;
            let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"{bound}\"}} {cum}");
        }
        cum += counts.last().copied().unwrap_or(0);
        let _ = writeln!(out, "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {cum}");
        let lb = if labels.is_empty() { String::new() } else { format!("{{{labels}}}") };
        let _ = writeln!(out, "{name}_sum{lb} {}", self.sum());
        let _ = writeln!(out, "{name}_count{lb} {cum}");
    }
}

/// The process-wide metric registry (reached via
/// [`crate::obs::metrics`]).
#[derive(Debug)]
pub struct Metrics {
    /// Admission → dequeue wait per task, nanoseconds.
    pub queue_wait_ns: Histogram,
    /// One shard × phase (or monolithic kernel) compute wall, ns.
    pub phase_wall_ns: Histogram,
    /// First-shard-done → barrier-complete straggler stall, ns.
    pub barrier_stall_ns: Histogram,
    /// Per-job |measured − predicted| / predicted intensity.
    pub model_err: Histogram,
    /// Per-kernel achieved GStencils/s (GPts/s), one histogram per
    /// resolved kernel name.
    kernel_gpts: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    /// Registry with the crate's standard bucket layouts: ~1 µs–17 s
    /// for times, ~0.001–16 for model error, ~0.008–128 for GPts/s.
    pub fn new() -> Metrics {
        Metrics {
            queue_wait_ns: Histogram::new(10, 34),
            phase_wall_ns: Histogram::new(10, 34),
            barrier_stall_ns: Histogram::new(10, 34),
            model_err: Histogram::new(-10, 4),
            kernel_gpts: Mutex::new(BTreeMap::new()),
        }
    }

    /// Record one job's achieved GPts/s under its resolved kernel.
    pub fn observe_kernel_gpts(&self, kernel: &str, gpts: f64) {
        if kernel.is_empty() || !gpts.is_finite() {
            return;
        }
        if let Ok(mut map) = self.kernel_gpts.lock() {
            map.entry(kernel.to_string())
                .or_insert_with(|| Histogram::new(-7, 7))
                .observe(gpts);
        }
    }

    /// (kernel, count, sum) rows of the per-kernel GPts/s histograms.
    pub fn kernel_rows(&self) -> Vec<(String, u64, f64)> {
        match self.kernel_gpts.lock() {
            Ok(map) => map.iter().map(|(k, h)| (k.clone(), h.count(), h.sum())).collect(),
            Err(_) => Vec::new(),
        }
    }

    /// Render the full Prometheus text exposition: service counters
    /// from `snap`, plan-cache counters from `cache`, per-tenant
    /// labeled counters from `tenants`, the queue-depth gauge, and
    /// every histogram.
    pub fn exposition(
        &self,
        snap: &ServiceSnapshot,
        cache: &CacheStats,
        tenants: &[TenantRow],
    ) -> String {
        let mut out = String::new();
        let counters: &[(&str, &str, u64)] = &[
            ("requests", "Protocol requests received.", snap.requests),
            ("errors", "Requests that returned an error.", snap.errors),
            ("jobs_accepted", "Advance jobs admitted.", snap.jobs_accepted),
            ("jobs_downgraded", "Jobs admitted with a downgraded plan.", snap.jobs_downgraded),
            ("jobs_rejected", "Jobs refused by admission control.", snap.jobs_rejected),
            ("queue_rejected", "Jobs refused because the queue was full.", snap.queue_rejected),
            ("jobs_completed", "Jobs that ran to completion.", snap.jobs_completed),
            ("jobs_failed", "Jobs that failed in execution.", snap.jobs_failed),
            ("jobs_sharded", "Jobs that fanned out into shard tasks.", snap.jobs_sharded),
            ("shard_tasks", "Shard tasks those jobs fanned out into.", snap.shard_tasks),
            ("batches", "Coalesced identical-PlanKey batch dispatches.", snap.batches),
            ("jobs_batched", "Member jobs executed inside batches.", snap.jobs_batched),
            ("plan_hits", "Plan lookups served from cache.", snap.plan_hits),
            ("plan_misses", "Plan lookups that re-planned.", snap.plan_misses),
            ("steps", "Time steps advanced, summed over jobs.", snap.steps_total),
            (
                "point_steps",
                "Point-updates executed, summed over jobs.",
                snap.point_steps_total,
            ),
            ("exec_wall_ns", "Job wall time, nanoseconds, summed.", snap.exec_wall_ns),
            (
                "intensity_err_permille",
                "Accumulated |measured-predicted|/predicted intensity, 0.1% units.",
                snap.intensity_err_permille,
            ),
            (
                "intensity_samples",
                "Jobs that contributed an intensity error sample.",
                snap.intensity_samples,
            ),
            ("plan_cache_hits", "Plan-cache hits since start.", cache.hits),
            ("plan_cache_misses", "Plan-cache misses since start.", cache.misses),
            ("plan_cache_evictions", "Plan-cache LRU evictions since start.", cache.evictions),
        ];
        for (name, help, v) in counters {
            let _ = writeln!(out, "# HELP stencilctl_{name}_total {help}");
            let _ = writeln!(out, "# TYPE stencilctl_{name}_total counter");
            let _ = writeln!(out, "stencilctl_{name}_total {v}");
        }
        let gauges: &[(&str, &str, f64)] = &[
            ("queue_depth", "Tasks currently queued.", snap.queue_depth as f64),
            ("plan_cache_size", "Plans currently cached.", cache.len as f64),
            (
                "plan_cache_generation",
                "Plan-cache invalidation generation.",
                cache.generation as f64,
            ),
            (
                "model_error",
                "Mean |measured-predicted|/predicted intensity.",
                snap.model_error(),
            ),
        ];
        for (name, help, v) in gauges {
            let _ = writeln!(out, "# HELP stencilctl_{name} {help}");
            let _ = writeln!(out, "# TYPE stencilctl_{name} gauge");
            let _ = writeln!(out, "stencilctl_{name} {v}");
        }
        let hists: &[(&str, &str, &Histogram)] = &[
            (
                "queue_wait_ns",
                "Admission to dequeue wait per task, nanoseconds.",
                &self.queue_wait_ns,
            ),
            (
                "phase_wall_ns",
                "Shard-phase (or kernel) compute wall, nanoseconds.",
                &self.phase_wall_ns,
            ),
            (
                "barrier_stall_ns",
                "Straggler stall at the halo-assembly barrier, nanoseconds.",
                &self.barrier_stall_ns,
            ),
            (
                "model_err",
                "Per-job |measured-predicted|/predicted intensity.",
                &self.model_err,
            ),
        ];
        for (name, help, h) in hists {
            let _ = writeln!(out, "# HELP stencilctl_{name} {help}");
            let _ = writeln!(out, "# TYPE stencilctl_{name} histogram");
            h.render(&mut out, &format!("stencilctl_{name}"), "");
            // Bucket-bound quantile estimates (≤2× error; see
            // `Histogram::quantile`).  Empty histograms and
            // overflow-bucket estimates emit nothing rather than lying.
            let _ = writeln!(
                out,
                "# HELP stencilctl_{name}_est Bucket-bound quantile estimate (<=2x error)."
            );
            let _ = writeln!(out, "# TYPE stencilctl_{name}_est gauge");
            for (q, tag) in [(0.5, "0.5"), (0.95, "0.95"), (0.99, "0.99")] {
                if let Some(v) = h.quantile(q).filter(|v| v.is_finite()) {
                    let _ =
                        writeln!(out, "stencilctl_{name}_est{{quantile=\"{tag}\"}} {v}");
                }
            }
        }
        let _ = writeln!(out, "# HELP stencilctl_kernel_gpts Achieved GStencils/s per kernel.");
        let _ = writeln!(out, "# TYPE stencilctl_kernel_gpts histogram");
        if let Ok(map) = self.kernel_gpts.lock() {
            for (kernel, h) in map.iter() {
                h.render(&mut out, "stencilctl_kernel_gpts", &format!("kernel=\"{kernel}\""));
            }
        }
        if !tenants.is_empty() {
            let series: &[(&str, &str, fn(&TenantRow) -> u64)] = &[
                (
                    "tenant_jobs_admitted_total",
                    "Jobs admitted, per tenant.",
                    |r| r.admitted,
                ),
                (
                    "tenant_jobs_refused_total",
                    "Jobs refused (budget, fair-share, deadline, queue), per tenant.",
                    |r| r.refused,
                ),
                (
                    "tenant_deadline_missed_total",
                    "Completed deadline jobs that overran their SLO, per tenant.",
                    |r| r.deadline_missed,
                ),
                (
                    "tenant_resident_bytes",
                    "In-memory session field bytes, per tenant.",
                    |r| r.resident_bytes,
                ),
                (
                    "tenant_spilled_bytes",
                    "Disk-spilled session field bytes, per tenant.",
                    |r| r.spilled_bytes,
                ),
            ];
            for (name, help, get) in series {
                let kind = if name.ends_with("_total") { "counter" } else { "gauge" };
                let _ = writeln!(out, "# HELP stencilctl_{name} {help}");
                let _ = writeln!(out, "# TYPE stencilctl_{name} {kind}");
                for r in tenants {
                    let _ = writeln!(
                        out,
                        "stencilctl_{name}{{tenant=\"{}\"}} {}",
                        r.tenant,
                        get(r)
                    );
                }
            }
        }
        out
    }
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_inclusive_powers_of_two() {
        let h = Histogram::new(0, 3); // bounds 1, 2, 4, 8
        assert_eq!(h.bounds(), vec![1.0, 2.0, 4.0, 8.0]);
        assert_eq!(h.bucket_index(0.0), 0);
        assert_eq!(h.bucket_index(1.0), 0, "le is inclusive");
        assert_eq!(h.bucket_index(1.0001), 1);
        assert_eq!(h.bucket_index(2.0), 1);
        assert_eq!(h.bucket_index(8.0), 3);
        assert_eq!(h.bucket_index(8.0001), 4, "overflow slot");
        assert_eq!(h.bucket_index(-5.0), 0, "negatives clamp");
    }

    #[test]
    fn fractional_bounds_stay_exact() {
        let h = Histogram::new(-2, 1); // 0.25, 0.5, 1, 2
        assert_eq!(h.bounds(), vec![0.25, 0.5, 1.0, 2.0]);
        assert_eq!(h.bucket_index(0.25), 0);
        assert_eq!(h.bucket_index(0.250001), 1);
    }

    #[test]
    fn observe_accumulates_and_drops_non_finite() {
        let h = Histogram::new(0, 3);
        h.observe(1.0);
        h.observe(3.0);
        h.observe(100.0);
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.count(), 3);
        assert_eq!(h.snapshot(), vec![1, 0, 1, 0, 1]);
        assert!((h.sum() - 104.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_walks_the_cumulative_distribution() {
        let h = Histogram::new(0, 3); // bounds 1, 2, 4, 8, +Inf
        assert_eq!(h.quantile(0.5), None, "empty histogram has no quantiles");
        for v in [0.5, 1.5, 1.5, 3.0, 3.0, 3.0, 7.0, 7.0, 7.0, 7.0] {
            h.observe(v);
        }
        // counts per bucket: [1, 2, 3, 4]; cumulative [1, 3, 6, 10]
        assert_eq!(h.quantile(0.1), Some(1.0));
        assert_eq!(h.quantile(0.3), Some(2.0));
        assert_eq!(h.quantile(0.5), Some(4.0));
        assert_eq!(h.quantile(0.99), Some(8.0));
        assert_eq!(h.quantile(1.0), Some(8.0));
        h.observe(1e9);
        assert_eq!(h.quantile(1.0), Some(f64::INFINITY), "overflow never fakes a bound");
        assert_eq!(h.quantile(-0.1), None);
        assert_eq!(h.quantile(1.5), None);
    }

    #[test]
    fn quantile_estimate_is_within_2x_of_the_exact_percentile() {
        // The satellite-3 bound: estimate ∈ [exact, 2·exact] for every
        // sample population above the first bucket.  Deterministic
        // pseudo-random samples (LCG) spread across four decades.
        let h = Histogram::new(0, 34);
        let mut samples: Vec<f64> = Vec::new();
        let mut state: u64 = 0x2545_f491_4f6c_dd1d;
        for _ in 0..5000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // magnitude in [1, 2^34): exponent then mantissa from the LCG
            let exp = (state >> 59) % 33; // 0..=32
            let frac = 1.0 + (state >> 11) as f64 / (1u64 << 53) as f64;
            samples.push((1u64 << exp) as f64 * frac);
        }
        for s in &samples {
            h.observe(*s);
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.9, 0.95, 0.99] {
            let rank = ((q * sorted.len() as f64).ceil() as usize).max(1);
            let exact = sorted[rank - 1];
            let est = h.quantile(q).unwrap();
            assert!(
                est >= exact && est <= exact * 2.0,
                "q={q}: exact {exact} vs estimate {est} breaks the 2x bound"
            );
        }
    }

    #[test]
    fn exposition_cumulates_le_buckets() {
        let h = Histogram::new(0, 2); // 1, 2, 4
        h.observe(1.0);
        h.observe(1.5);
        h.observe(50.0);
        let mut out = String::new();
        h.render(&mut out, "x", "");
        assert!(out.contains("x_bucket{le=\"1\"} 1"), "{out}");
        assert!(out.contains("x_bucket{le=\"2\"} 2"), "{out}");
        assert!(out.contains("x_bucket{le=\"4\"} 2"), "{out}");
        assert!(out.contains("x_bucket{le=\"+Inf\"} 3"), "{out}");
        assert!(out.contains("x_count 3"), "{out}");
        assert!(out.contains("x_sum 52.5"), "{out}");
    }

    #[test]
    fn registry_exposition_is_prometheus_shaped() {
        let m = Metrics::new();
        m.queue_wait_ns.observe(2048.0);
        m.model_err.observe(0.07);
        m.observe_kernel_gpts("star-2d1r/double/avx2", 0.5);
        m.observe_kernel_gpts("", 1.0); // unresolved: ignored
        let snap = ServiceSnapshot { requests: 5, queue_depth: 2, ..Default::default() };
        let cache = CacheStats { hits: 3, ..Default::default() };
        let tenants = vec![TenantRow {
            tenant: "acme".into(),
            admitted: 7,
            refused: 2,
            deadline_missed: 1,
            resident_bytes: 4096,
            spilled_bytes: 512,
        }];
        let text = m.exposition(&snap, &cache, &tenants);
        assert!(text.contains("# TYPE stencilctl_requests_total counter"), "{text}");
        assert!(text.contains("stencilctl_requests_total 5"));
        assert!(text.contains("# TYPE stencilctl_queue_depth gauge"));
        assert!(text.contains("stencilctl_queue_depth 2"));
        assert!(text.contains("stencilctl_plan_cache_hits_total 3"));
        assert!(text.contains("# TYPE stencilctl_queue_wait_ns histogram"));
        assert!(text.contains("stencilctl_queue_wait_ns_bucket{le=\"2048\"} 1"));
        assert!(text
            .contains("stencilctl_kernel_gpts_bucket{kernel=\"star-2d1r/double/avx2\",le=\"0.5\"} 1"));
        assert_eq!(m.kernel_rows().len(), 1);
        // per-tenant labeled series
        assert!(text.contains("# TYPE stencilctl_tenant_jobs_admitted_total counter"), "{text}");
        assert!(text.contains("stencilctl_tenant_jobs_admitted_total{tenant=\"acme\"} 7"));
        assert!(text.contains("stencilctl_tenant_jobs_refused_total{tenant=\"acme\"} 2"));
        assert!(text.contains("stencilctl_tenant_deadline_missed_total{tenant=\"acme\"} 1"));
        assert!(text.contains("# TYPE stencilctl_tenant_resident_bytes gauge"));
        assert!(text.contains("stencilctl_tenant_resident_bytes{tenant=\"acme\"} 4096"));
        assert!(text.contains("stencilctl_tenant_spilled_bytes{tenant=\"acme\"} 512"));
        // no tenants → no per-tenant series, still well-formed
        assert!(!m.exposition(&snap, &cache, &[]).contains("tenant_jobs_admitted"));
        // every line is either a comment or name{labels}? value
        for line in text.lines() {
            assert!(
                line.starts_with('#')
                    || line.split_whitespace().count() == 2
                    && line.starts_with("stencilctl_"),
                "malformed line: {line}"
            );
        }
    }
}

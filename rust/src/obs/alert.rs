//! Declarative alert rules over the prom registry — the paging plane.
//!
//! Rules are evaluated lazily, inside the `stats`/`metrics`/`alerts`
//! verbs (and `stencilctl top`'s refresh loop), never on the job hot
//! path: a rule evaluation reads counters/histograms that are already
//! maintained, so serving cost is zero between evaluations.  Each rule
//! keeps firing/resolved state with a `for` hysteresis (consecutive
//! breached evaluations before firing); transitions emit
//! `alert_firing`/`alert_resolved` journal events
//! ([`crate::obs::journal`]) and a transitions counter, and the
//! current state renders as `stencilctl_alerts{rule,label}` gauges in
//! the Prometheus exposition.
//!
//! Rule file (`--alert-rules <file>`): a JSON array of objects.
//!
//! ```json
//! [
//!   {"name":"queue-p99","kind":"p99_over","metric":"queue_wait_ns","threshold_ms":500,"for":2},
//!   {"name":"slo-burn","kind":"slo_burn","max_frac":0.1,"min_samples":4},
//!   {"name":"model-drift","kind":"model_err"},
//!   {"name":"queue-sat","kind":"queue_saturation","frac":0.8}
//! ]
//! ```
//!
//! `for` defaults to 1 (fire on the first breached evaluation).
//! Omitting `--alert-rules` installs [`builtin_rules`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::journal;

/// What a rule watches.
#[derive(Debug, Clone, PartialEq)]
pub enum RuleKind {
    /// p99 of a latency histogram above a threshold (ns domain).
    P99Over {
        /// `queue_wait_ns` | `phase_wall_ns` | `barrier_stall_ns`.
        metric: String,
        /// Threshold in nanoseconds.
        threshold_ns: f64,
    },
    /// Per-tenant SLO burn: deadline_missed / admitted above a
    /// fraction once enough jobs have been admitted.
    SloBurn {
        /// Maximum tolerated missed fraction.
        max_frac: f64,
        /// Admitted jobs before the ratio is meaningful.
        min_samples: u64,
    },
    /// Any drift region whose model-error EWMA breached its threshold.
    ModelErr,
    /// Queue depth at or above a fraction of capacity.
    QueueSaturation {
        /// Saturation fraction of `--max-queue`.
        frac: f64,
    },
}

impl RuleKind {
    /// Stable wire name.
    pub fn as_str(&self) -> &'static str {
        match self {
            RuleKind::P99Over { .. } => "p99_over",
            RuleKind::SloBurn { .. } => "slo_burn",
            RuleKind::ModelErr => "model_err",
            RuleKind::QueueSaturation { .. } => "queue_saturation",
        }
    }
}

/// One declarative rule.
#[derive(Debug, Clone, PartialEq)]
pub struct Rule {
    /// Operator-facing rule name (the `rule` label).
    pub name: String,
    pub kind: RuleKind,
    /// Consecutive breached evaluations before firing (≥ 1).
    pub for_evals: u32,
}

/// The defaults installed when `--alert-rules` is absent: queue
/// saturation at 80%, any drift-region breach, 10% SLO burn after 4
/// admitted jobs, and p99 queue wait over 500 ms.
pub fn builtin_rules() -> Vec<Rule> {
    vec![
        Rule {
            name: "queue_saturated".to_string(),
            kind: RuleKind::QueueSaturation { frac: 0.8 },
            for_evals: 1,
        },
        Rule { name: "model_err_region".to_string(), kind: RuleKind::ModelErr, for_evals: 1 },
        Rule {
            name: "slo_burn".to_string(),
            kind: RuleKind::SloBurn { max_frac: 0.10, min_samples: 4 },
            for_evals: 1,
        },
        Rule {
            name: "queue_wait_p99".to_string(),
            kind: RuleKind::P99Over {
                metric: "queue_wait_ns".to_string(),
                threshold_ns: 500e6,
            },
            for_evals: 1,
        },
    ]
}

/// Parse a rule file (JSON array; see the module grammar).
pub fn parse_rules(text: &str) -> Result<Vec<Rule>> {
    let doc = Json::parse(text).context("alert rule file is not valid JSON")?;
    let arr = match doc.as_arr() {
        Some(a) => a,
        None => bail!("alert rule file must be a JSON array of rule objects"),
    };
    let mut out = Vec::with_capacity(arr.len());
    for (i, r) in arr.iter().enumerate() {
        let name = r
            .get("name")
            .and_then(|j| j.as_str().map(str::to_string).ok_or_else(|| anyhow::anyhow!("")))
            .with_context(|| format!("rule {i}: missing \"name\""))?;
        let kind_s = r
            .get("kind")
            .and_then(|j| j.as_str().map(str::to_string).ok_or_else(|| anyhow::anyhow!("")))
            .with_context(|| format!("rule {i}: missing \"kind\""))?;
        let num = |key: &str| -> Result<f64> {
            r.get(key)
                .ok()
                .and_then(Json::as_f64)
                .filter(|v| v.is_finite() && *v >= 0.0)
                .with_context(|| format!("rule {i} ({name:?}): needs finite \"{key}\" >= 0"))
        };
        let kind = match kind_s.as_str() {
            "p99_over" => RuleKind::P99Over {
                metric: r
                    .get("metric")
                    .ok()
                    .and_then(|j| j.as_str())
                    .unwrap_or("queue_wait_ns")
                    .to_string(),
                threshold_ns: num("threshold_ms")? * 1e6,
            },
            "slo_burn" => RuleKind::SloBurn {
                max_frac: num("max_frac")?,
                min_samples: r.get("min_samples").ok().and_then(Json::as_usize).unwrap_or(1)
                    as u64,
            },
            "model_err" => RuleKind::ModelErr,
            "queue_saturation" => RuleKind::QueueSaturation { frac: num("frac")? },
            other => bail!("rule {i} ({name:?}): unknown kind {other:?}"),
        };
        let for_evals =
            r.get("for").ok().and_then(Json::as_usize).unwrap_or(1).max(1) as u32;
        out.push(Rule { name, kind, for_evals });
    }
    Ok(out)
}

/// One drift region's current error state (the `model_err` input).
#[derive(Debug, Clone)]
pub struct RegionErr {
    pub region: String,
    pub ewma: f64,
    pub threshold: f64,
    pub over: bool,
}

/// One tenant's SLO bookkeeping (the `slo_burn` input).
#[derive(Debug, Clone)]
pub struct TenantSlo {
    pub tenant: String,
    pub admitted: u64,
    pub deadline_missed: u64,
}

/// The snapshot an evaluation runs against.  Histogram quantiles are
/// read from the process registry ([`crate::obs::metrics`]) directly.
#[derive(Debug, Clone, Default)]
pub struct EvalInput {
    pub queue_depth: u64,
    pub queue_cap: u64,
    pub regions: Vec<RegionErr>,
    pub tenants: Vec<TenantSlo>,
}

/// One rule×label's evaluated state.
#[derive(Debug, Clone, PartialEq)]
pub struct AlertRow {
    pub rule: String,
    /// Discriminating label (tenant for `slo_burn`, region for
    /// `model_err`, empty otherwise).
    pub label: String,
    pub kind: &'static str,
    pub firing: bool,
    /// The observed value the rule compared.
    pub value: f64,
    /// The rule's threshold in the same unit.
    pub threshold: f64,
}

#[derive(Debug, Default, Clone, Copy)]
struct CellState {
    consecutive: u32,
    firing: bool,
}

/// Evaluated rules + firing/resolved state + transition accounting.
pub struct AlertEngine {
    rules: Vec<Rule>,
    state: Mutex<BTreeMap<(String, String), CellState>>,
    transitions: AtomicU64,
}

impl AlertEngine {
    pub fn new(rules: Vec<Rule>) -> AlertEngine {
        AlertEngine { rules, state: Mutex::new(BTreeMap::new()), transitions: AtomicU64::new(0) }
    }

    /// The installed rules.
    pub fn rules(&self) -> &[Rule] {
        &self.rules
    }

    /// Lifetime firing/resolved transitions.
    pub fn transitions(&self) -> u64 {
        self.transitions.load(Ordering::Relaxed)
    }

    /// Evaluate every rule against the snapshot, updating state.  Each
    /// breached evaluation advances the rule's `for` counter; crossing
    /// it fires, a clean evaluation resolves.  Transitions land in the
    /// journal (when open) and the transitions counter.
    pub fn evaluate(&self, input: &EvalInput) -> Vec<AlertRow> {
        let mut rows = Vec::new();
        for rule in &self.rules {
            match &rule.kind {
                RuleKind::P99Over { metric, threshold_ns } => {
                    let m = super::metrics();
                    let h = match metric.as_str() {
                        "queue_wait_ns" => &m.queue_wait_ns,
                        "phase_wall_ns" => &m.phase_wall_ns,
                        "barrier_stall_ns" => &m.barrier_stall_ns,
                        _ => &m.queue_wait_ns,
                    };
                    let p99 = h.quantile(0.99).unwrap_or(0.0);
                    rows.push(self.update(rule, "", p99, *threshold_ns, p99 > *threshold_ns));
                }
                RuleKind::SloBurn { max_frac, min_samples } => {
                    for t in &input.tenants {
                        let frac = if t.admitted > 0 {
                            t.deadline_missed as f64 / t.admitted as f64
                        } else {
                            0.0
                        };
                        let breached = t.admitted >= *min_samples && frac > *max_frac;
                        rows.push(self.update(rule, &t.tenant, frac, *max_frac, breached));
                    }
                }
                RuleKind::ModelErr => {
                    for r in &input.regions {
                        rows.push(self.update(rule, &r.region, r.ewma, r.threshold, r.over));
                    }
                }
                RuleKind::QueueSaturation { frac } => {
                    let cap = input.queue_cap.max(1) as f64;
                    let fill = input.queue_depth as f64 / cap;
                    rows.push(self.update(rule, "", fill, *frac, fill >= *frac));
                }
            }
        }
        rows
    }

    fn update(
        &self,
        rule: &Rule,
        label: &str,
        value: f64,
        threshold: f64,
        breached: bool,
    ) -> AlertRow {
        let key = (rule.name.clone(), label.to_string());
        let mut g = self.state.lock().unwrap_or_else(|p| p.into_inner());
        let cell = g.entry(key).or_default();
        let was = cell.firing;
        if breached {
            cell.consecutive = cell.consecutive.saturating_add(1);
            if cell.consecutive >= rule.for_evals {
                cell.firing = true;
            }
        } else {
            cell.consecutive = 0;
            cell.firing = false;
        }
        let firing = cell.firing;
        drop(g);
        if firing != was {
            self.transitions.fetch_add(1, Ordering::Relaxed);
            journal::emit(
                if firing { "alert_firing" } else { "alert_resolved" },
                &[
                    ("rule", Json::Str(rule.name.clone())),
                    ("label", Json::Str(label.to_string())),
                    ("kind", Json::Str(rule.kind.as_str().to_string())),
                    ("value", journal::f(value)),
                    ("threshold", journal::f(threshold)),
                ],
            );
        }
        AlertRow {
            rule: rule.name.clone(),
            label: label.to_string(),
            kind: rule.kind.as_str(),
            firing,
            value,
            threshold,
        }
    }
}

/// Render the evaluated rows as Prometheus series: a 0/1
/// `stencilctl_alerts` gauge per rule×label plus the lifetime
/// transitions counter.
pub fn render_prom(rows: &[AlertRow], transitions: u64) -> String {
    let mut out = String::new();
    out.push_str("# HELP stencilctl_alerts Alert state per rule (1 = firing).\n");
    out.push_str("# TYPE stencilctl_alerts gauge\n");
    for r in rows {
        if r.label.is_empty() {
            out.push_str(&format!(
                "stencilctl_alerts{{rule=\"{}\"}} {}\n",
                r.rule,
                u8::from(r.firing)
            ));
        } else {
            out.push_str(&format!(
                "stencilctl_alerts{{rule=\"{}\",label=\"{}\"}} {}\n",
                r.rule,
                r.label,
                u8::from(r.firing)
            ));
        }
    }
    out.push_str("# HELP stencilctl_alert_transitions_total Firing/resolved transitions.\n");
    out.push_str("# TYPE stencilctl_alert_transitions_total counter\n");
    out.push_str(&format!("stencilctl_alert_transitions_total {transitions}\n"));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_rules_cover_the_four_kinds() {
        let rules = builtin_rules();
        assert_eq!(rules.len(), 4);
        let kinds: Vec<&str> = rules.iter().map(|r| r.kind.as_str()).collect();
        for k in ["p99_over", "slo_burn", "model_err", "queue_saturation"] {
            assert!(kinds.contains(&k), "missing builtin {k}");
        }
    }

    #[test]
    fn rule_file_parses_and_rejects_garbage() {
        let rules = parse_rules(
            r#"[
              {"name":"q","kind":"p99_over","metric":"phase_wall_ns","threshold_ms":250,"for":3},
              {"name":"b","kind":"slo_burn","max_frac":0.05,"min_samples":10},
              {"name":"m","kind":"model_err"},
              {"name":"s","kind":"queue_saturation","frac":0.5}
            ]"#,
        )
        .unwrap();
        assert_eq!(rules.len(), 4);
        assert_eq!(rules[0].for_evals, 3);
        assert_eq!(
            rules[0].kind,
            RuleKind::P99Over { metric: "phase_wall_ns".into(), threshold_ns: 250e6 }
        );
        assert_eq!(rules[1].kind, RuleKind::SloBurn { max_frac: 0.05, min_samples: 10 });
        assert_eq!(rules[3].kind, RuleKind::QueueSaturation { frac: 0.5 });
        assert!(parse_rules("{}").is_err(), "must be an array");
        assert!(parse_rules(r#"[{"kind":"model_err"}]"#).is_err(), "name required");
        assert!(parse_rules(r#"[{"name":"x","kind":"nope"}]"#).is_err(), "unknown kind");
        assert!(
            parse_rules(r#"[{"name":"x","kind":"queue_saturation"}]"#).is_err(),
            "missing frac"
        );
    }

    #[test]
    fn queue_saturation_fires_resolves_and_counts_transitions() {
        let eng = AlertEngine::new(vec![Rule {
            name: "sat".into(),
            kind: RuleKind::QueueSaturation { frac: 0.8 },
            for_evals: 1,
        }]);
        let mut input = EvalInput { queue_depth: 9, queue_cap: 10, ..Default::default() };
        let rows = eng.evaluate(&input);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].firing, "{rows:?}");
        assert!((rows[0].value - 0.9).abs() < 1e-12);
        assert_eq!(eng.transitions(), 1);
        // still firing: no new transition
        assert!(eng.evaluate(&input)[0].firing);
        assert_eq!(eng.transitions(), 1);
        input.queue_depth = 1;
        assert!(!eng.evaluate(&input)[0].firing, "resolves when the queue drains");
        assert_eq!(eng.transitions(), 2);
    }

    #[test]
    fn for_hysteresis_delays_firing() {
        let eng = AlertEngine::new(vec![Rule {
            name: "sat3".into(),
            kind: RuleKind::QueueSaturation { frac: 0.5 },
            for_evals: 3,
        }]);
        let hot = EvalInput { queue_depth: 8, queue_cap: 10, ..Default::default() };
        let cold = EvalInput { queue_depth: 0, queue_cap: 10, ..Default::default() };
        assert!(!eng.evaluate(&hot)[0].firing, "1st breach");
        assert!(!eng.evaluate(&hot)[0].firing, "2nd breach");
        assert!(eng.evaluate(&hot)[0].firing, "3rd consecutive breach fires");
        // a clean evaluation resets the streak entirely
        assert!(!eng.evaluate(&cold)[0].firing);
        assert!(!eng.evaluate(&hot)[0].firing, "streak restarted");
    }

    #[test]
    fn model_err_and_slo_burn_label_per_region_and_tenant() {
        let eng = AlertEngine::new(vec![
            Rule { name: "drift".into(), kind: RuleKind::ModelErr, for_evals: 1 },
            Rule {
                name: "burn".into(),
                kind: RuleKind::SloBurn { max_frac: 0.1, min_samples: 4 },
                for_evals: 1,
            },
        ]);
        let input = EvalInput {
            queue_depth: 0,
            queue_cap: 8,
            regions: vec![
                RegionErr { region: "mem/sweep".into(), ewma: 0.4, threshold: 0.25, over: true },
                RegionErr { region: "comp/fused".into(), ewma: 0.01, threshold: 0.25, over: false },
            ],
            tenants: vec![
                TenantSlo { tenant: "a".into(), admitted: 10, deadline_missed: 5 },
                TenantSlo { tenant: "b".into(), admitted: 2, deadline_missed: 2 },
                TenantSlo { tenant: "c".into(), admitted: 10, deadline_missed: 0 },
            ],
        };
        let rows = eng.evaluate(&input);
        let firing: Vec<(&str, &str)> = rows
            .iter()
            .filter(|r| r.firing)
            .map(|r| (r.rule.as_str(), r.label.as_str()))
            .collect();
        assert!(firing.contains(&("drift", "mem/sweep")));
        assert!(!firing.contains(&("drift", "comp/fused")));
        assert!(firing.contains(&("burn", "a")), "50% burn over 10 admitted fires");
        assert!(
            !firing.contains(&("burn", "b")),
            "2 admitted < min_samples: burn ratio not yet meaningful"
        );
        assert!(!firing.contains(&("burn", "c")));
        let text = render_prom(&rows, eng.transitions());
        assert!(text.contains("stencilctl_alerts{rule=\"drift\",label=\"mem/sweep\"} 1"));
        assert!(text.contains("stencilctl_alerts{rule=\"drift\",label=\"comp/fused\"} 0"));
        assert!(text.contains("stencilctl_alert_transitions_total 2"));
    }
}

//! Span exporters: the NDJSON wire codec (bit-exact f64 payloads via
//! the hex codec), the compact `"spans"` block attached to advance
//! replies, and the Chrome trace-event converter behind
//! `stencilctl trace --chrome`.
//!
//! Wire shape: one JSON object per span, payload fields flattened next
//! to the envelope (`trace`/`worker`/`kind`/`start_ns`/`end_ns`).
//! Times are integer nanoseconds (exact in JSON below 2^53); every
//! f64 payload field travels as 16 hex digits of its IEEE-754 bits
//! ([`hex_f64`]) so NaN model errors and subnormal EWMAs round-trip
//! without moving a ulp — `Json::Num` would flatten them to `null`.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use anyhow::{anyhow, bail, Result};

use super::{Payload, Span, SpanKind};
use crate::util::json::{f64_from_hex, hex_f64, Json};

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Encode one span as a flat JSON object (one NDJSON line when
/// `Display`ed).
pub fn span_to_json(s: &Span) -> Json {
    let mut o = BTreeMap::new();
    o.insert("trace".to_string(), num(s.trace));
    o.insert("worker".to_string(), num(s.worker));
    o.insert("kind".to_string(), Json::Str(s.kind.name().to_string()));
    o.insert("start_ns".to_string(), num(s.start_ns));
    o.insert("end_ns".to_string(), num(s.end_ns));
    match &s.payload {
        Payload::None => {}
        Payload::Plan { key, hit } => {
            o.insert("plan_key".to_string(), Json::Str(key.clone()));
            o.insert("hit".to_string(), Json::Bool(*hit));
        }
        Payload::Queue { depth } => {
            o.insert("depth".to_string(), num(*depth));
        }
        Payload::Phase { index, shard, depth, fused, bytes, flops, kernel } => {
            o.insert("phase".to_string(), num(*index));
            o.insert("shard".to_string(), num(*shard));
            o.insert("depth".to_string(), num(*depth));
            o.insert("fused".to_string(), Json::Bool(*fused));
            o.insert("bytes".to_string(), num(*bytes));
            o.insert("flops".to_string(), num(*flops));
            o.insert("kernel".to_string(), Json::Str(kernel.clone()));
        }
        Payload::Barrier { index, shards, stall_ns } => {
            o.insert("phase".to_string(), num(*index));
            o.insert("shards".to_string(), num(*shards));
            o.insert("stall_ns".to_string(), num(*stall_ns));
        }
        Payload::Kernel { name, nnz } => {
            o.insert("kernel".to_string(), Json::Str(name.clone()));
            o.insert("nnz".to_string(), num(*nnz));
        }
        Payload::Job { steps, shards, model_err } => {
            o.insert("steps".to_string(), num(*steps));
            o.insert("shards".to_string(), num(*shards));
            o.insert("model_err".to_string(), Json::Str(hex_f64(*model_err)));
        }
        Payload::Drift { region, ewma, flagged } => {
            o.insert("region".to_string(), Json::Str(region.clone()));
            o.insert("ewma".to_string(), Json::Str(hex_f64(*ewma)));
            o.insert("flagged".to_string(), Json::Bool(*flagged));
        }
        Payload::Retune { ok } => {
            o.insert("ok".to_string(), Json::Bool(*ok));
        }
        Payload::Batch { jobs, key } => {
            o.insert("jobs".to_string(), num(*jobs));
            o.insert("plan_key".to_string(), Json::Str(key.clone()));
        }
        Payload::Spill { session, bytes } | Payload::Restore { session, bytes } => {
            o.insert("session".to_string(), Json::Str(session.clone()));
            o.insert("bytes".to_string(), num(*bytes));
        }
    }
    Json::Obj(o)
}

fn get_u64(j: &Json, key: &str) -> Result<u64> {
    j.get(key)?
        .as_f64()
        .filter(|v| v.fract() == 0.0 && *v >= 0.0)
        .map(|v| v as u64)
        .ok_or_else(|| anyhow!("field {key:?} is not a non-negative integer"))
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)?
        .as_str()
        .ok_or_else(|| anyhow!("field {key:?} is not a string"))?
        .to_string())
}

fn get_bool(j: &Json, key: &str) -> Result<bool> {
    j.get(key)?
        .as_bool()
        .ok_or_else(|| anyhow!("field {key:?} is not a bool"))
}

fn get_hex(j: &Json, key: &str) -> Result<f64> {
    f64_from_hex(
        j.get(key)?
            .as_str()
            .ok_or_else(|| anyhow!("field {key:?} is not a hex-f64 string"))?,
    )
}

/// Decode the inverse of [`span_to_json`].
pub fn span_from_json(j: &Json) -> Result<Span> {
    let kind_name = get_str(j, "kind")?;
    let kind = SpanKind::from_name(&kind_name)
        .ok_or_else(|| anyhow!("unknown span kind {kind_name:?}"))?;
    let payload = match kind {
        SpanKind::PlanLookup => {
            Payload::Plan { key: get_str(j, "plan_key")?, hit: get_bool(j, "hit")? }
        }
        SpanKind::QueueWait => Payload::Queue { depth: get_u64(j, "depth")? },
        SpanKind::ShardPhase => Payload::Phase {
            index: get_u64(j, "phase")?,
            shard: get_u64(j, "shard")?,
            depth: get_u64(j, "depth")?,
            fused: get_bool(j, "fused")?,
            bytes: get_u64(j, "bytes")?,
            flops: get_u64(j, "flops")?,
            kernel: get_str(j, "kernel")?,
        },
        SpanKind::Barrier => Payload::Barrier {
            index: get_u64(j, "phase")?,
            shards: get_u64(j, "shards")?,
            stall_ns: get_u64(j, "stall_ns")?,
        },
        SpanKind::Kernel => {
            Payload::Kernel { name: get_str(j, "kernel")?, nnz: get_u64(j, "nnz")? }
        }
        SpanKind::Job => Payload::Job {
            steps: get_u64(j, "steps")?,
            shards: get_u64(j, "shards")?,
            model_err: get_hex(j, "model_err")?,
        },
        SpanKind::Drift => Payload::Drift {
            region: get_str(j, "region")?,
            ewma: get_hex(j, "ewma")?,
            flagged: get_bool(j, "flagged")?,
        },
        SpanKind::Retune => Payload::Retune { ok: get_bool(j, "ok")? },
        SpanKind::Batch => {
            Payload::Batch { jobs: get_u64(j, "jobs")?, key: get_str(j, "plan_key")? }
        }
        SpanKind::Spill => {
            Payload::Spill { session: get_str(j, "session")?, bytes: get_u64(j, "bytes")? }
        }
        SpanKind::Restore => {
            Payload::Restore { session: get_str(j, "session")?, bytes: get_u64(j, "bytes")? }
        }
        SpanKind::Admission | SpanKind::Assembly => Payload::None,
    };
    Ok(Span {
        trace: get_u64(j, "trace")?,
        worker: get_u64(j, "worker")?,
        kind,
        start_ns: get_u64(j, "start_ns")?,
        end_ns: get_u64(j, "end_ns")?,
        payload,
    })
}

/// Parse an NDJSON trace file's text: one span per non-blank line.
pub fn read_ndjson(text: &str) -> Result<Vec<Span>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse_line(line).map_err(|e| anyhow!("line {}: {e}", i + 1))?;
        out.push(span_from_json(&j).map_err(|e| anyhow!("line {}: {e}", i + 1))?);
    }
    Ok(out)
}

/// The compact `"spans"` block an advance reply carries when tracing
/// is enabled: one small object per span, timing in integer ns, heavy
/// payloads reduced to the fields a dashboard sorts by.
pub fn compact_spans(spans: &[Span]) -> Json {
    Json::Arr(
        spans
            .iter()
            .map(|s| {
                let mut o = BTreeMap::new();
                o.insert("kind".to_string(), Json::Str(s.kind.name().to_string()));
                o.insert("worker".to_string(), num(s.worker));
                o.insert("wall_ns".to_string(), num(s.wall_ns()));
                match &s.payload {
                    Payload::Phase { index, shard, .. } => {
                        o.insert("phase".to_string(), num(*index));
                        o.insert("shard".to_string(), num(*shard));
                    }
                    Payload::Barrier { index, stall_ns, .. } => {
                        o.insert("phase".to_string(), num(*index));
                        o.insert("stall_ns".to_string(), num(*stall_ns));
                    }
                    Payload::Kernel { name, nnz } => {
                        o.insert("kernel".to_string(), Json::Str(name.clone()));
                        o.insert("nnz".to_string(), num(*nnz));
                    }
                    Payload::Plan { hit, .. } => {
                        o.insert("hit".to_string(), Json::Bool(*hit));
                    }
                    Payload::Batch { jobs, .. } => {
                        o.insert("jobs".to_string(), num(*jobs));
                    }
                    Payload::Spill { bytes, .. } | Payload::Restore { bytes, .. } => {
                        o.insert("bytes".to_string(), num(*bytes));
                    }
                    _ => {}
                }
                Json::Obj(o)
            })
            .collect(),
    )
}

/// Render spans as Chrome trace-event JSON (`chrome://tracing`,
/// Perfetto): one `"X"` complete event per span on `tid = worker`
/// (timestamps in µs, so barrier stalls show up as literal gaps in a
/// worker's track), plus one `"M"` metadata event naming each track.
pub fn chrome_trace(spans: &[Span]) -> Json {
    let workers: BTreeSet<u64> = spans.iter().map(|s| s.worker).collect();
    let mut events = Vec::new();
    for w in &workers {
        let mut args = BTreeMap::new();
        args.insert("name".to_string(), Json::Str(format!("worker-{w}")));
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str("thread_name".to_string()));
        o.insert("ph".to_string(), Json::Str("M".to_string()));
        o.insert("pid".to_string(), num(1));
        o.insert("tid".to_string(), num(*w));
        o.insert("args".to_string(), Json::Obj(args));
        events.push(Json::Obj(o));
    }
    for s in spans {
        let mut o = BTreeMap::new();
        let name = match &s.payload {
            Payload::Phase { index, shard, .. } => format!("phase{index}/shard{shard}"),
            Payload::Barrier { index, .. } => format!("barrier{index}"),
            Payload::Kernel { name, .. } => format!("kernel {name}"),
            Payload::Batch { jobs, .. } => format!("batch x{jobs}"),
            Payload::Spill { session, bytes } => format!("spill {session} ({bytes} B)"),
            Payload::Restore { session, bytes } => format!("restore {session} ({bytes} B)"),
            _ => s.kind.name().to_string(),
        };
        o.insert("name".to_string(), Json::Str(name));
        o.insert("cat".to_string(), Json::Str(s.kind.name().to_string()));
        o.insert("ph".to_string(), Json::Str("X".to_string()));
        o.insert("ts".to_string(), Json::Num(s.start_ns as f64 / 1000.0));
        o.insert("dur".to_string(), Json::Num(s.wall_ns() as f64 / 1000.0));
        o.insert("pid".to_string(), num(1));
        o.insert("tid".to_string(), num(s.worker));
        let Json::Obj(mut args) = span_to_json(s) else { unreachable!() };
        args.remove("kind");
        args.remove("start_ns");
        args.remove("end_ns");
        args.remove("worker");
        o.insert("args".to_string(), Json::Obj(args));
        events.push(Json::Obj(o));
    }
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(events));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(top)
}

/// Human-readable per-worker summary of a span set (the `trace`
/// subcommand's default, non-Chrome output).
pub fn summarize(spans: &[Span]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let traces: BTreeSet<u64> = spans.iter().map(|s| s.trace).collect();
    let workers: BTreeSet<u64> = spans.iter().map(|s| s.worker).collect();
    let _ = writeln!(
        out,
        "{} spans, {} trace(s), {} worker track(s)",
        spans.len(),
        traces.len(),
        workers.len()
    );
    for w in &workers {
        let mine: Vec<&Span> = spans.iter().filter(|s| s.worker == *w).collect();
        let busy: u64 = mine.iter().map(|s| s.wall_ns()).sum();
        let stalls: u64 = mine
            .iter()
            .filter_map(|s| match s.payload {
                Payload::Barrier { stall_ns, .. } => Some(stall_ns),
                _ => None,
            })
            .sum();
        let _ = writeln!(
            out,
            "  worker-{w}: {} spans, {:.3} ms spanned, {:.3} ms barrier stall",
            mine.len(),
            busy as f64 / 1e6,
            stalls as f64 / 1e6
        );
    }
    for k in [
        SpanKind::Admission,
        SpanKind::PlanLookup,
        SpanKind::QueueWait,
        SpanKind::ShardPhase,
        SpanKind::Barrier,
        SpanKind::Assembly,
        SpanKind::Kernel,
        SpanKind::Job,
        SpanKind::Drift,
        SpanKind::Retune,
        SpanKind::Batch,
        SpanKind::Spill,
        SpanKind::Restore,
    ] {
        let n = spans.iter().filter(|s| s.kind == k).count();
        if n > 0 {
            let wall: u64 = spans.iter().filter(|s| s.kind == k).map(|s| s.wall_ns()).sum();
            let _ =
                writeln!(out, "  {:<11} × {n:<4} Σ {:.3} ms", k.name(), wall as f64 / 1e6);
        }
    }
    // Serving-plane detail: batches carry member counts, spill/restore
    // carry the bytes that crossed the disk boundary.
    let (mut batches, mut batch_jobs) = (0u64, 0u64);
    let (mut spill_bytes, mut restore_bytes) = (0u64, 0u64);
    for s in spans {
        match &s.payload {
            Payload::Batch { jobs, .. } => {
                batches += 1;
                batch_jobs += jobs;
            }
            Payload::Spill { bytes, .. } => spill_bytes += bytes,
            Payload::Restore { bytes, .. } => restore_bytes += bytes,
            _ => {}
        }
    }
    if batches > 0 {
        let _ = writeln!(
            out,
            "  batches: {batches} dispatch(es) covering {batch_jobs} member job(s)"
        );
    }
    if spill_bytes > 0 || restore_bytes > 0 {
        let _ = writeln!(
            out,
            "  session tiering: {:.3} MiB spilled, {:.3} MiB restored",
            spill_bytes as f64 / (1024.0 * 1024.0),
            restore_bytes as f64 / (1024.0 * 1024.0)
        );
    }
    out
}

/// Parse + validate a whole NDJSON trace, erroring on an empty set —
/// the `trace` subcommand's entry point.
pub fn load_trace(text: &str) -> Result<Vec<Span>> {
    let spans = read_ndjson(text)?;
    if spans.is_empty() {
        bail!("trace holds no spans (was the run traced with --trace-out?)");
    }
    Ok(spans)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans() -> Vec<Span> {
        vec![
            Span {
                trace: 1,
                worker: 0,
                kind: SpanKind::Admission,
                start_ns: 10,
                end_ns: 30,
                payload: Payload::None,
            },
            Span {
                trace: 1,
                worker: 0,
                kind: SpanKind::PlanLookup,
                start_ns: 12,
                end_ns: 20,
                payload: Payload::Plan { key: "star-2d1r/double/64x64/t4".into(), hit: true },
            },
            Span {
                trace: 1,
                worker: 2,
                kind: SpanKind::ShardPhase,
                start_ns: 40,
                end_ns: 90,
                payload: Payload::Phase {
                    index: 1,
                    shard: 0,
                    depth: 2,
                    fused: false,
                    bytes: 4096,
                    flops: 18432,
                    kernel: "star-2d1r/double/avx2".into(),
                },
            },
            Span {
                trace: 1,
                worker: 2,
                kind: SpanKind::Barrier,
                start_ns: 90,
                end_ns: 95,
                payload: Payload::Barrier { index: 1, shards: 2, stall_ns: 5 },
            },
            Span {
                trace: 1,
                worker: 0,
                kind: SpanKind::Job,
                start_ns: 10,
                end_ns: 100,
                payload: Payload::Job { steps: 4, shards: 2, model_err: f64::NAN },
            },
            Span {
                trace: 1,
                worker: 0,
                kind: SpanKind::Drift,
                start_ns: 100,
                end_ns: 100,
                payload: Payload::Drift { region: "mem/blocked".into(), ewma: -0.0, flagged: true },
            },
        ]
    }

    #[test]
    fn ndjson_roundtrip_is_bit_exact() {
        for s in spans() {
            let line = span_to_json(&s).to_string();
            assert!(!line.contains('\n'));
            let back = span_from_json(&Json::parse_line(&line).unwrap()).unwrap();
            // NaN payloads break PartialEq — compare via bits.
            match (&s.payload, &back.payload) {
                (Payload::Job { model_err: a, .. }, Payload::Job { model_err: b, .. }) => {
                    assert_eq!(a.to_bits(), b.to_bits(), "NaN must round-trip bit-exactly");
                }
                (Payload::Drift { ewma: a, .. }, Payload::Drift { ewma: b, .. }) => {
                    assert_eq!(a.to_bits(), b.to_bits(), "-0.0 must round-trip bit-exactly");
                }
                _ => assert_eq!(s.payload, back.payload),
            }
            assert_eq!((s.trace, s.worker, s.kind), (back.trace, back.worker, back.kind));
            assert_eq!((s.start_ns, s.end_ns), (back.start_ns, back.end_ns));
        }
    }

    #[test]
    fn read_ndjson_skips_blanks_and_reports_bad_lines() {
        let all = spans();
        let text = format!(
            "{}\n\n{}\n",
            span_to_json(&all[0]),
            span_to_json(&all[2])
        );
        let back = read_ndjson(&text).unwrap();
        assert_eq!(back.len(), 2);
        let err = format!("{:#}", read_ndjson("{\"kind\":\"bogus\"}").unwrap_err());
        assert!(err.contains("line 1"), "{err}");
        assert!(load_trace("\n\n").is_err(), "empty trace must error");
    }

    #[test]
    fn compact_block_keeps_sort_keys_only() {
        let j = compact_spans(&spans());
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 6);
        let phase = &arr[2];
        assert_eq!(phase.get("kind").unwrap().as_str(), Some("shard_phase"));
        assert_eq!(phase.get("phase").unwrap().as_i64(), Some(1));
        assert_eq!(phase.get("wall_ns").unwrap().as_i64(), Some(50));
        assert!(phase.get("bytes").is_err(), "heavy fields stay out of replies");
        let barrier = &arr[3];
        assert_eq!(barrier.get("stall_ns").unwrap().as_i64(), Some(5));
    }

    #[test]
    fn chrome_trace_has_tracks_and_microsecond_events() {
        let j = chrome_trace(&spans());
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 distinct workers -> 2 metadata events + 6 X events
        assert_eq!(events.len(), 8);
        let meta: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("M")).collect();
        assert_eq!(meta.len(), 2);
        assert_eq!(meta[0].get("args").unwrap().get("name").unwrap().as_str(), Some("worker-0"));
        let phase = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("phase1/shard0"))
            .expect("phase event");
        assert_eq!(phase.get("tid").unwrap().as_i64(), Some(2));
        assert_eq!(phase.get("ts").unwrap().as_f64(), Some(0.04), "40 ns = 0.04 µs");
        assert_eq!(phase.get("dur").unwrap().as_f64(), Some(0.05));
        assert_eq!(phase.get("args").unwrap().get("bytes").unwrap().as_i64(), Some(4096));
        assert!(phase.get("args").unwrap().get("kind").is_err(), "envelope stays out of args");
        // the whole thing parses back as one JSON document
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn serving_plane_kinds_roundtrip() {
        let extra = vec![
            Span {
                trace: 2,
                worker: 0,
                kind: SpanKind::Batch,
                start_ns: 5,
                end_ns: 25,
                payload: Payload::Batch { jobs: 3, key: "star-2d1r|f64|64x64".into() },
            },
            Span {
                trace: 0,
                worker: 0,
                kind: SpanKind::Spill,
                start_ns: 30,
                end_ns: 31,
                payload: Payload::Spill { session: "cold-7".into(), bytes: 32768 },
            },
            Span {
                trace: 2,
                worker: 1,
                kind: SpanKind::Restore,
                start_ns: 40,
                end_ns: 44,
                payload: Payload::Restore { session: "cold-7".into(), bytes: 32768 },
            },
        ];
        for s in &extra {
            let line = span_to_json(s).to_string();
            let back = span_from_json(&Json::parse_line(&line).unwrap()).unwrap();
            assert_eq!(s, &back, "serving-plane span must round-trip exactly");
        }
        let text = summarize(&extra);
        for needle in ["batch", "spill", "restore"] {
            assert!(text.contains(needle), "{text}");
        }
        // satellite: member counts and bytes are rendered, not dropped
        assert!(text.contains("1 dispatch(es) covering 3 member job(s)"), "{text}");
        assert!(text.contains("0.031 MiB spilled, 0.031 MiB restored"), "{text}");
        let chrome = chrome_trace(&extra);
        let events = chrome.get("traceEvents").unwrap().as_arr().unwrap();
        let names: Vec<&str> =
            events.iter().filter_map(|e| e.get("name").ok()?.as_str()).collect();
        assert!(names.contains(&"batch x3"), "{names:?}");
        assert!(names.contains(&"spill cold-7 (32768 B)"), "{names:?}");
        assert!(names.contains(&"restore cold-7 (32768 B)"), "{names:?}");
        let compact = compact_spans(&extra);
        let arr = compact.as_arr().unwrap();
        assert_eq!(arr[0].get("jobs").unwrap().as_i64(), Some(3));
        assert_eq!(arr[1].get("bytes").unwrap().as_i64(), Some(32768));
        assert_eq!(arr[2].get("bytes").unwrap().as_i64(), Some(32768));
    }

    #[test]
    fn summary_counts_kinds_and_stalls() {
        let s = summarize(&spans());
        assert!(s.contains("6 spans"), "{s}");
        assert!(s.contains("worker-2"), "{s}");
        assert!(s.contains("shard_phase"), "{s}");
        assert!(s.contains("barrier"), "{s}");
    }
}

//! Trace diffing: `stencilctl trace --diff a.ndjson b.ndjson`.
//!
//! Aligns two traced runs by `(phase index, shard, kernel)` — the
//! stable identity of a compute interval across runs of the same plan
//! — and reports per-phase wall/bytes/intensity deltas, plus the
//! serving-side delta (queue wait + barrier stall).  Each regressed
//! phase carries an attribution verdict ([`super::attrib::Term`])
//! derived from *which* observable moved:
//!
//! * bytes grew → **redundancy** (the planner is moving traffic it
//!   didn't price: halo growth, lost reuse);
//! * wall grew at equal bytes on a fused (compute-leaning) phase →
//!   **kernel** (achieved GPts/s fell vs the ℙ that priced the plan);
//! * wall grew at equal bytes on an unfused (memory-bound sweep)
//!   phase → **bandwidth** (achieved B/s fell vs profile 𝔹);
//! * queue/barrier time grew → **serving**.
//!
//! Wall-time regressions need both a ratio (>1.5×) *and* an absolute
//! floor (>10 ms) so two identical healthy runs — whose phase walls
//! jitter by scheduler noise — never flag (the CI trace-diff smoke
//! depends on this).  Byte counts are deterministic for a fixed plan,
//! so any growth beyond 2% flags regardless of wall time.

use std::collections::BTreeMap;
use std::collections::BTreeSet;

use super::attrib::Term;
use super::{Payload, Span, SpanKind};

/// Wall ratio a phase must exceed to count as regressed…
pub const WALL_RATIO: f64 = 1.5;
/// …and the absolute wall floor that filters scheduler jitter.
pub const WALL_FLOOR_NS: u64 = 10_000_000;
/// Deterministic byte counts flag on any growth beyond this ratio.
pub const BYTES_RATIO: f64 = 1.02;

/// One aligned phase's aggregate on one side of the diff.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseAgg {
    pub wall_ns: u64,
    pub bytes: u64,
    pub flops: u64,
    pub count: u64,
    pub fused: bool,
}

impl PhaseAgg {
    /// Arithmetic intensity (flop/byte); 0 when no bytes moved.
    pub fn intensity(&self) -> f64 {
        if self.bytes == 0 {
            0.0
        } else {
            self.flops as f64 / self.bytes as f64
        }
    }
}

/// One `(phase, shard, kernel)` cell present in both runs.
#[derive(Debug, Clone)]
pub struct PhaseDelta {
    pub phase: u64,
    pub shard: u64,
    pub kernel: String,
    pub a: PhaseAgg,
    pub b: PhaseAgg,
    /// `Some(term)` when run B regressed vs run A.
    pub verdict: Option<Term>,
}

impl PhaseDelta {
    pub fn regressed(&self) -> bool {
        self.verdict.is_some()
    }
}

/// The full two-run comparison.
#[derive(Debug, Clone)]
pub struct DiffReport {
    pub phases: Vec<PhaseDelta>,
    /// Queue wait + barrier stall per run, ms.
    pub serving_a_ms: f64,
    pub serving_b_ms: f64,
    pub serving_regressed: bool,
    /// Cells present only in one run (plan shape changed).
    pub only_a: Vec<(u64, u64, String)>,
    pub only_b: Vec<(u64, u64, String)>,
}

impl DiffReport {
    /// Count of regressed phases (serving counted separately).
    pub fn regressions(&self) -> usize {
        self.phases.iter().filter(|p| p.regressed()).count()
    }

    /// Human-readable console rendering (`trace --diff`'s output).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "trace diff: {} aligned phase cell(s), {} only in A, {} only in B",
            self.phases.len(),
            self.only_a.len(),
            self.only_b.len()
        );
        for p in &self.phases {
            let mark = match &p.verdict {
                Some(t) => format!("REGRESSED [{}]", t.as_str()),
                None => "ok".to_string(),
            };
            let _ = writeln!(
                out,
                "  phase{}/shard{} {:<28} wall {:>9.3} -> {:>9.3} ms  bytes {:>10} -> {:>10}  \
                 intensity {:.3} -> {:.3}  {mark}",
                p.phase,
                p.shard,
                p.kernel,
                p.a.wall_ns as f64 / 1e6,
                p.b.wall_ns as f64 / 1e6,
                p.a.bytes,
                p.b.bytes,
                p.a.intensity(),
                p.b.intensity(),
            );
        }
        let _ = writeln!(
            out,
            "  serving (queue wait + barrier stall): {:.3} -> {:.3} ms  {}",
            self.serving_a_ms,
            self.serving_b_ms,
            if self.serving_regressed { "REGRESSED [serving]" } else { "ok" }
        );
        for (phase, shard, kernel) in &self.only_a {
            let _ = writeln!(out, "  phase{phase}/shard{shard} {kernel}: only in A");
        }
        for (phase, shard, kernel) in &self.only_b {
            let _ = writeln!(out, "  phase{phase}/shard{shard} {kernel}: only in B");
        }
        let total = self.regressions() + usize::from(self.serving_regressed);
        if total == 0 {
            let _ = writeln!(out, "no regressions: run B within thresholds of run A");
        } else {
            let _ = writeln!(out, "{total} regression(s): run B slower than run A");
        }
        out
    }
}

fn aggregate(spans: &[Span]) -> BTreeMap<(u64, u64, String), PhaseAgg> {
    let mut map: BTreeMap<(u64, u64, String), PhaseAgg> = BTreeMap::new();
    for s in spans {
        if let Payload::Phase { index, shard, fused, bytes, flops, ref kernel, .. } = s.payload {
            let agg = map.entry((index, shard, kernel.clone())).or_default();
            agg.wall_ns += s.wall_ns();
            agg.bytes += bytes;
            agg.flops += flops;
            agg.count += 1;
            agg.fused = fused;
        }
    }
    map
}

fn serving_ns(spans: &[Span]) -> u64 {
    spans
        .iter()
        .map(|s| match s.payload {
            Payload::Barrier { stall_ns, .. } => stall_ns,
            _ if s.kind == SpanKind::QueueWait => s.wall_ns(),
            _ => 0,
        })
        .sum()
}

/// Did B regress vs A, and which model term is to blame?
fn judge(a: &PhaseAgg, b: &PhaseAgg) -> Option<Term> {
    let bytes_grew =
        a.bytes > 0 && (b.bytes as f64) > (a.bytes as f64) * BYTES_RATIO;
    if bytes_grew {
        return Some(Term::Redundancy);
    }
    let wall_grew = b.wall_ns > WALL_FLOOR_NS + a.wall_ns
        && (b.wall_ns as f64) > (a.wall_ns as f64) * WALL_RATIO;
    if wall_grew {
        // Equal traffic, more time: a rate constant broke.  Fused
        // phases lean on the kernel peak ℙ; unfused sweeps are the
        // memory-bound side priced by 𝔹.
        return Some(if b.fused { Term::Kernel } else { Term::Bandwidth });
    }
    None
}

/// Align run A (baseline) against run B (candidate) and judge each
/// shared `(phase, shard, kernel)` cell.
pub fn diff(a: &[Span], b: &[Span]) -> DiffReport {
    let ma = aggregate(a);
    let mb = aggregate(b);
    let keys: BTreeSet<&(u64, u64, String)> = ma.keys().chain(mb.keys()).collect();
    let mut phases = Vec::new();
    let mut only_a = Vec::new();
    let mut only_b = Vec::new();
    for key in keys {
        match (ma.get(key), mb.get(key)) {
            (Some(pa), Some(pb)) => phases.push(PhaseDelta {
                phase: key.0,
                shard: key.1,
                kernel: key.2.clone(),
                a: *pa,
                b: *pb,
                verdict: judge(pa, pb),
            }),
            (Some(_), None) => only_a.push(key.clone()),
            (None, Some(_)) => only_b.push(key.clone()),
            (None, None) => unreachable!(),
        }
    }
    let sa = serving_ns(a);
    let sb = serving_ns(b);
    let serving_regressed =
        sb > WALL_FLOOR_NS + sa && (sb as f64) > (sa as f64) * WALL_RATIO;
    DiffReport {
        phases,
        serving_a_ms: sa as f64 / 1e6,
        serving_b_ms: sb as f64 / 1e6,
        serving_regressed,
        only_a,
        only_b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phase(index: u64, shard: u64, kernel: &str, wall_ns: u64, bytes: u64, fused: bool) -> Span {
        Span {
            trace: 1,
            worker: shard,
            kind: SpanKind::ShardPhase,
            start_ns: 0,
            end_ns: wall_ns,
            payload: Payload::Phase {
                index,
                shard,
                depth: 1,
                fused,
                bytes,
                flops: bytes * 9,
                kernel: kernel.to_string(),
            },
        }
    }

    fn queue_wait(wall_ns: u64) -> Span {
        Span {
            trace: 1,
            worker: 0,
            kind: SpanKind::QueueWait,
            start_ns: 0,
            end_ns: wall_ns,
            payload: Payload::Queue { depth: 3 },
        }
    }

    #[test]
    fn identical_runs_report_no_regressions() {
        let run = vec![
            phase(0, 0, "star-2d1r/double/avx2", 20_000_000, 1 << 20, false),
            phase(0, 1, "star-2d1r/double/avx2", 21_000_000, 1 << 20, false),
            queue_wait(2_000_000),
        ];
        let rep = diff(&run, &run);
        assert_eq!(rep.phases.len(), 2);
        assert_eq!(rep.regressions(), 0);
        assert!(!rep.serving_regressed);
        assert!(rep.render().contains("no regressions"), "{}", rep.render());
    }

    #[test]
    fn scheduler_jitter_below_the_floor_never_flags() {
        // 3x ratio but only 3 ms absolute: under the 10 ms floor.
        let a = vec![phase(0, 0, "k", 1_500_000, 4096, false)];
        let b = vec![phase(0, 0, "k", 4_500_000, 4096, false)];
        assert_eq!(diff(&a, &b).regressions(), 0);
    }

    #[test]
    fn slow_unfused_sweep_blames_bandwidth() {
        let a = vec![phase(0, 0, "sweep", 20_000_000, 1 << 20, false)];
        let b = vec![phase(0, 0, "sweep", 60_000_000, 1 << 20, false)];
        let rep = diff(&a, &b);
        assert_eq!(rep.regressions(), 1);
        assert_eq!(rep.phases[0].verdict, Some(Term::Bandwidth));
        assert!(rep.render().contains("REGRESSED [bandwidth]"), "{}", rep.render());
    }

    #[test]
    fn slow_fused_phase_blames_the_kernel() {
        let a = vec![phase(2, 1, "fused", 20_000_000, 1 << 20, true)];
        let b = vec![phase(2, 1, "fused", 60_000_000, 1 << 20, true)];
        let rep = diff(&a, &b);
        assert_eq!(rep.phases[0].verdict, Some(Term::Kernel));
    }

    #[test]
    fn byte_growth_blames_redundancy_even_at_equal_wall() {
        let a = vec![phase(0, 0, "halo", 20_000_000, 1_000_000, false)];
        let b = vec![phase(0, 0, "halo", 20_000_000, 1_100_000, false)];
        let rep = diff(&a, &b);
        assert_eq!(rep.phases[0].verdict, Some(Term::Redundancy));
        // intensity drops with the extra traffic
        assert!(rep.phases[0].b.intensity() < rep.phases[0].a.intensity());
    }

    #[test]
    fn inflated_queue_wait_is_a_serving_regression() {
        let a = vec![phase(0, 0, "k", 20_000_000, 4096, false), queue_wait(1_000_000)];
        let b = vec![phase(0, 0, "k", 20_000_000, 4096, false), queue_wait(40_000_000)];
        let rep = diff(&a, &b);
        assert_eq!(rep.regressions(), 0, "compute is unchanged");
        assert!(rep.serving_regressed);
        assert!(rep.render().contains("REGRESSED [serving]"), "{}", rep.render());
    }

    #[test]
    fn unaligned_cells_are_listed_not_judged() {
        let a = vec![phase(0, 0, "k", 20_000_000, 4096, false)];
        let b = vec![phase(1, 0, "k", 20_000_000, 4096, false)];
        let rep = diff(&a, &b);
        assert!(rep.phases.is_empty());
        assert_eq!(rep.only_a, vec![(0, 0, "k".to_string())]);
        assert_eq!(rep.only_b, vec![(1, 0, "k".to_string())]);
        let text = rep.render();
        assert!(text.contains("only in A") && text.contains("only in B"), "{text}");
    }
}

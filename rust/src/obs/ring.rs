//! Bounded per-worker span ring — the flight recorder's storage cell.
//!
//! Each worker track owns one [`Ring`]: a fixed-capacity FIFO of
//! completed [`Span`]s.  When full, the oldest span is dropped (and
//! counted), so memory stays bounded no matter how long the daemon
//! runs — the recorder always holds the most recent window of
//! activity, which is exactly what a post-hoc "what just happened"
//! drain wants.

use std::collections::VecDeque;

use super::Span;

/// Fixed-capacity FIFO of completed spans (oldest evicted first).
#[derive(Debug)]
pub struct Ring {
    cap: usize,
    buf: VecDeque<Span>,
    dropped: u64,
}

impl Ring {
    /// A ring holding at most `cap` spans (`cap` ≥ 1 enforced).
    pub fn new(cap: usize) -> Ring {
        Ring { cap: cap.max(1), buf: VecDeque::new(), dropped: 0 }
    }

    /// Append a span, evicting the oldest when at capacity.
    pub fn push(&mut self, s: Span) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(s);
    }

    /// Spans currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no spans are held.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Spans evicted (lost) since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Remove and return every span belonging to `trace`, preserving
    /// recording order.  Spans of other traces stay in the ring, so a
    /// per-job drain cannot eat a concurrent job's history.
    pub fn drain_trace(&mut self, trace: u64) -> Vec<Span> {
        let mut out = Vec::new();
        let mut keep = VecDeque::with_capacity(self.buf.len());
        for s in self.buf.drain(..) {
            if s.trace == trace {
                out.push(s);
            } else {
                keep.push_back(s);
            }
        }
        self.buf = keep;
        out
    }

    /// Remove and return every held span, preserving recording order.
    pub fn drain_all(&mut self) -> Vec<Span> {
        self.buf.drain(..).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Payload, SpanKind};
    use super::*;

    fn span(trace: u64, start: u64) -> Span {
        Span {
            trace,
            worker: 0,
            kind: SpanKind::Kernel,
            start_ns: start,
            end_ns: start + 1,
            payload: Payload::None,
        }
    }

    #[test]
    fn bounded_fifo_evicts_oldest() {
        let mut r = Ring::new(3);
        for i in 0..5 {
            r.push(span(1, i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let got = r.drain_all();
        assert_eq!(got.iter().map(|s| s.start_ns).collect::<Vec<_>>(), vec![2, 3, 4]);
        assert!(r.is_empty());
    }

    #[test]
    fn drain_trace_is_selective() {
        let mut r = Ring::new(8);
        r.push(span(1, 0));
        r.push(span(2, 1));
        r.push(span(1, 2));
        let one = r.drain_trace(1);
        assert_eq!(one.len(), 2);
        assert_eq!(r.len(), 1);
        assert_eq!(r.drain_trace(2).len(), 1);
        assert!(r.is_empty());
        // zero-capacity requests still hold one span
        let mut z = Ring::new(0);
        z.push(span(1, 0));
        assert_eq!(z.len(), 1);
    }
}

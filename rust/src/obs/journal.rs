//! Append-only NDJSON event journal — the forensics plane.
//!
//! Spans answer "what did this job do"; the journal answers "what did
//! the *service* decide, and why" across jobs: admission refusals with
//! their evidence, drift flags, retune install/reject episodes,
//! session spill/restore, and alert firing/resolved transitions.  One
//! JSON object per line, floats in the crate's bit-exact hex-f64 codec
//! ([`crate::util::json::hex_f64`]) so evidence replays without losing
//! a ulp.
//!
//! The journal is **off unless `stencilctl serve --journal <path>`
//! opened it**: every probe site pays one relaxed atomic load and
//! nothing else, so a journal-less serve run writes zero events and
//! allocates nothing on the hot path.  Files are size-capped: when an
//! append would cross `max_bytes`, the current file rotates to
//! `<path>.1` (replacing any previous rotation) and a fresh file
//! continues — the journal holds the most recent window, bounded on
//! disk like the span rings are in memory.
//!
//! [`read_events`] tolerates a crash-truncated final line (a process
//! killed mid-append loses at most that line, never the file).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use crate::util::json::{hex_f64, Json};

/// Default rotation cap (`--journal` without a size knob): 4 MiB per
/// file, two files on disk worst-case.
pub const DEFAULT_MAX_BYTES: u64 = 4 * 1024 * 1024;

/// Wrap an f64 as a hex-f64 JSON string — the journal's float payload
/// encoding (bit-exact evidence; `"nan"`-free lines).
pub fn f(v: f64) -> Json {
    Json::Str(hex_f64(v))
}

/// One size-capped NDJSON journal file (the struct form; the process
/// global below wraps one of these).
pub struct Journal {
    path: PathBuf,
    max_bytes: u64,
    writer: BufWriter<File>,
    written: u64,
    seq: u64,
    rotations: u64,
}

impl Journal {
    /// Create (truncating) the journal at `path` with a rotation cap.
    pub fn create(path: &Path, max_bytes: u64) -> Result<Journal> {
        let writer = BufWriter::new(
            File::create(path)
                .with_context(|| format!("creating journal {}", path.display()))?,
        );
        Ok(Journal {
            path: path.to_path_buf(),
            max_bytes: max_bytes.max(1),
            writer,
            written: 0,
            seq: 0,
            rotations: 0,
        })
    }

    /// Rotation path: `<path>.1` (one previous window kept).
    fn rotated_path(&self) -> PathBuf {
        let mut s = self.path.as_os_str().to_os_string();
        s.push(".1");
        PathBuf::from(s)
    }

    /// Append one event line: `{"event":…,"seq":…,"ts_ns":…, fields…}`.
    /// Rotates first when the line would cross the cap (so a single
    /// file never exceeds `max_bytes` unless one line alone does).
    pub fn emit(&mut self, event: &str, fields: &[(&str, Json)]) -> Result<()> {
        self.seq += 1;
        let mut map = std::collections::BTreeMap::new();
        map.insert("event".to_string(), Json::Str(event.to_string()));
        map.insert("seq".to_string(), Json::Num(self.seq as f64));
        map.insert("ts_ns".to_string(), Json::Num(super::now_ns() as f64));
        for (k, v) in fields {
            map.insert((*k).to_string(), v.clone());
        }
        let line = Json::Obj(map).to_string();
        let bytes = line.len() as u64 + 1;
        if self.written > 0 && self.written + bytes > self.max_bytes {
            self.rotate()?;
        }
        writeln!(self.writer, "{line}")?;
        // Flushed per event: journal lines are evidence — a crash must
        // lose at most the line being written.
        self.writer.flush()?;
        self.written += bytes;
        Ok(())
    }

    fn rotate(&mut self) -> Result<()> {
        self.writer.flush()?;
        std::fs::rename(&self.path, self.rotated_path())
            .with_context(|| format!("rotating journal {}", self.path.display()))?;
        self.writer = BufWriter::new(
            File::create(&self.path)
                .with_context(|| format!("recreating journal {}", self.path.display()))?,
        );
        self.written = 0;
        self.rotations += 1;
        Ok(())
    }

    /// Bytes written to the current (post-rotation) file.
    pub fn written(&self) -> u64 {
        self.written
    }

    /// How many times the file has rotated.
    pub fn rotations(&self) -> u64 {
        self.rotations
    }
}

/// Parse a journal file back into events.  A crash-truncated final
/// line (no trailing newline, or an unparseable tail) is skipped; a
/// malformed line anywhere else is a real error with its line number.
pub fn read_events(path: &Path) -> Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading journal {}", path.display()))?;
    let complete = text.ends_with('\n');
    let lines: Vec<&str> = text.lines().collect();
    let mut out = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse_line(line) {
            Ok(v) => out.push(v),
            Err(e) => {
                if i + 1 == lines.len() && !complete {
                    break; // torn tail: the crash ate this line
                }
                bail!("journal {} line {}: {e:#}", path.display(), i + 1);
            }
        }
    }
    Ok(out)
}

// ---- the process-global journal (`stencilctl serve --journal`) ----

static ON: AtomicBool = AtomicBool::new(false);

fn cell() -> &'static Mutex<Option<Journal>> {
    static C: OnceLock<Mutex<Option<Journal>>> = OnceLock::new();
    C.get_or_init(|| Mutex::new(None))
}

/// True when the global journal is open (one relaxed load — the whole
/// disabled-mode cost of a probe site).
pub fn enabled() -> bool {
    ON.load(Ordering::Relaxed)
}

/// Open the process journal (truncating `path`).  Idempotent in the
/// sense that reopening replaces the previous journal.
pub fn open(path: &Path, max_bytes: u64) -> Result<()> {
    let j = Journal::create(path, max_bytes)?;
    *cell().lock().unwrap_or_else(|p| p.into_inner()) = Some(j);
    ON.store(true, Ordering::SeqCst);
    Ok(())
}

/// Close the process journal (flushing it); further [`emit`]s no-op.
pub fn close() {
    ON.store(false, Ordering::SeqCst);
    *cell().lock().unwrap_or_else(|p| p.into_inner()) = None;
}

/// Emit one event into the process journal.  No-op (one atomic load)
/// when no journal is open; I/O errors are swallowed — forensics must
/// never take the serving path down.
pub fn emit(event: &str, fields: &[(&str, Json)]) {
    if !enabled() {
        return;
    }
    if let Ok(mut g) = cell().lock() {
        if let Some(j) = g.as_mut() {
            let _ = j.emit(event, fields);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::f64_from_hex;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("tcs-journal-{}-{tag}.ndjson", std::process::id()))
    }

    #[test]
    fn events_roundtrip_with_hex_floats() {
        let path = tmp("roundtrip");
        let mut j = Journal::create(&path, 1 << 20).unwrap();
        j.emit("drift_flag", &[("region", Json::Str("mem/sweep".into())), ("ewma", f(0.1 + 0.2))])
            .unwrap();
        j.emit("retune_install", &[("cause", Json::Str("bandwidth".into()))]).unwrap();
        let events = read_events(&path).unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("event").unwrap().as_str(), Some("drift_flag"));
        assert_eq!(events[0].get("seq").unwrap().as_i64(), Some(1));
        let ewma = f64_from_hex(events[0].get("ewma").unwrap().as_str().unwrap()).unwrap();
        assert_eq!(ewma.to_bits(), (0.1 + 0.2_f64).to_bits(), "hex-f64 evidence is bit-exact");
        assert!(events[1].get("ts_ns").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rotation_caps_file_size_and_keeps_one_previous_window() {
        let path = tmp("rotate");
        // Cap small enough that a handful of events cross it.
        let mut j = Journal::create(&path, 256).unwrap();
        for i in 0..12 {
            j.emit("spill", &[("session", Json::Str(format!("s{i}")))]).unwrap();
            assert!(
                std::fs::metadata(&path).unwrap().len() <= 256,
                "current file stays under the cap"
            );
        }
        assert!(j.rotations() >= 1, "the cap forced at least one rotation");
        assert!(j.written() > 0 && j.written() <= 256);
        let rotated = {
            let mut s = path.as_os_str().to_os_string();
            s.push(".1");
            PathBuf::from(s)
        };
        assert!(rotated.exists(), "previous window parked at <path>.1");
        // Both windows parse; sequence numbers are continuous across
        // the rotation boundary and nothing is duplicated.
        let mut seqs: Vec<i64> = read_events(&rotated)
            .unwrap()
            .iter()
            .chain(read_events(&path).unwrap().iter())
            .map(|e| e.get("seq").unwrap().as_i64().unwrap())
            .collect();
        seqs.sort_unstable();
        assert!(seqs.len() >= 2);
        for w in seqs.windows(2) {
            assert_eq!(w[1], w[0] + 1, "no gap or duplicate at the rotation boundary");
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&rotated);
    }

    #[test]
    fn reader_tolerates_a_crash_truncated_final_line() {
        let path = tmp("torn");
        let mut j = Journal::create(&path, 1 << 20).unwrap();
        j.emit("alert_firing", &[("rule", Json::Str("queue_saturated".into()))]).unwrap();
        j.emit("alert_resolved", &[("rule", Json::Str("queue_saturated".into()))]).unwrap();
        drop(j);
        // Simulate a crash mid-append: a torn, newline-less tail.
        use std::io::Write as _;
        let mut file = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        write!(file, "{{\"event\":\"spill\",\"seq\":3,\"ts").unwrap();
        drop(file);
        let events = read_events(&path).unwrap();
        assert_eq!(events.len(), 2, "complete lines survive, the torn tail is dropped");
        assert_eq!(events[1].get("event").unwrap().as_str(), Some("alert_resolved"));
        // …but a malformed line mid-file is a real error, not silence.
        std::fs::write(&path, "{\"event\":\"a\",\"seq\":1}\ngarbage\n{\"event\":\"b\",\"seq\":2}\n")
            .unwrap();
        let err = format!("{:#}", read_events(&path).unwrap_err());
        assert!(err.contains("line 2"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn global_journal_gates_on_enabled() {
        // The global is shared process state; this test serializes with
        // the obs flag tests' lock to avoid cross-test interference.
        let _g = crate::obs::test_lock();
        close();
        assert!(!enabled());
        emit("drift_flag", &[]); // must be a silent no-op
        let path = tmp("global");
        open(&path, 1 << 20).unwrap();
        assert!(enabled());
        emit("drift_flag", &[("region", Json::Str("mem/sweep".into()))]);
        close();
        assert!(!enabled());
        emit("drift_flag", &[]); // after close: no-op again
        let events = read_events(&path).unwrap();
        assert_eq!(events.len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}

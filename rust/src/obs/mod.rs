//! Structured tracing + metrics spine (the observability plane).
//!
//! Every layer of the runtime emits into this module instead of
//! growing ad-hoc counters:
//!
//! * **Spans** — monotonic-clock intervals with typed payloads
//!   ([`Payload`]), one [`SpanKind`] per pipeline stage: admission →
//!   plan-cache lookup → queue wait → each shard phase → halo-assembly
//!   barrier → kernel dispatch, plus drift/retune episodes from
//!   [`crate::tune::drift`].  Spans are recorded into per-worker
//!   bounded rings (a flight recorder: the most recent window is
//!   always available, memory never grows) and optionally streamed as
//!   NDJSON to a `--trace-out` sink.  f64 payload fields travel in the
//!   crate's bit-exact hex codec ([`crate::util::json::hex_f64`]).
//! * **Metrics** — always-on Prometheus-style counters and
//!   log-bucketed histograms ([`prom`]): queue wait, phase wall,
//!   barrier stall, model error, per-kernel GPts/s — with p50/p95/p99
//!   estimators over the log₂ buckets.
//! * **Explainability** — per-term model-error attribution
//!   ([`attrib`]), declarative alert rules with firing/resolved state
//!   ([`alert`]), an append-only forensics journal ([`journal`]), and
//!   two-run trace diffing ([`diff`]).
//!
//! Tracing is **disabled by default and zero-cost when disabled**: the
//! only residue on the hot path is one relaxed atomic load per probe
//! site, and a disabled run emits exactly zero events with replies
//! bit-identical to a build without this module.  Trace ids and queue
//! timestamps are still assigned unconditionally (one atomic add / one
//! monotonic-clock read per *job*, not per point) so the always-on
//! histograms stay meaningful.
//!
//! Correlation model: each job gets a trace id at admission
//! ([`next_trace_id`]); the handling thread enters it with
//! [`trace_scope`], worker threads tag themselves with [`set_worker`],
//! and every [`record`] call stamps the current (trace, worker) pair.
//! [`drain`] removes one trace's spans from all rings — concurrent
//! jobs cannot eat each other's history.

pub mod alert;
pub mod attrib;
pub mod diff;
pub mod export;
pub mod journal;
pub mod prom;
mod ring;

pub use ring::Ring;

use std::cell::Cell;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Worker tracks the flight recorder keeps (worker ids hash into
/// these; more workers than tracks share rings, never block).
pub const WORKER_TRACKS: usize = 64;
/// Spans each worker track retains before evicting the oldest.
pub const RING_CAP: usize = 512;

/// Pipeline stage a span measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Admission control: request arrival → accept/downgrade/reject.
    Admission,
    /// Plan-cache lookup (hit or recompute) for the job's `PlanKey`.
    PlanLookup,
    /// Admission → first dequeue by a worker.
    QueueWait,
    /// One shard × one `ShardPhase` compute interval.
    ShardPhase,
    /// Halo-assembly barrier: first shard done → last shard done.
    Barrier,
    /// Slab-gather/scatter assembly after a barrier completes.
    Assembly,
    /// Kernel dispatch: one monolithic `run_field` execution.
    Kernel,
    /// Whole job: admission → reply, with model feedback attached.
    Job,
    /// A drift reading that flagged the machine profile.
    Drift,
    /// A retune episode (measure → install or reject).
    Retune,
    /// A coalesced batch dispatch: N identical-`PlanKey` jobs sharing
    /// one plan resolution and one shard schedule.
    Batch,
    /// An idle session's field spilled to disk (bit-exact hex-f64).
    Spill,
    /// A spilled session's field restored from disk on next use.
    Restore,
}

impl SpanKind {
    /// Stable wire name (NDJSON `kind` field, Chrome event name).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Admission => "admission",
            SpanKind::PlanLookup => "plan_lookup",
            SpanKind::QueueWait => "queue_wait",
            SpanKind::ShardPhase => "shard_phase",
            SpanKind::Barrier => "barrier",
            SpanKind::Assembly => "assembly",
            SpanKind::Kernel => "kernel",
            SpanKind::Job => "job",
            SpanKind::Drift => "drift",
            SpanKind::Retune => "retune",
            SpanKind::Batch => "batch",
            SpanKind::Spill => "spill",
            SpanKind::Restore => "restore",
        }
    }

    /// Inverse of [`SpanKind::name`].
    pub fn from_name(s: &str) -> Option<SpanKind> {
        Some(match s {
            "admission" => SpanKind::Admission,
            "plan_lookup" => SpanKind::PlanLookup,
            "queue_wait" => SpanKind::QueueWait,
            "shard_phase" => SpanKind::ShardPhase,
            "barrier" => SpanKind::Barrier,
            "assembly" => SpanKind::Assembly,
            "kernel" => SpanKind::Kernel,
            "job" => SpanKind::Job,
            "drift" => SpanKind::Drift,
            "retune" => SpanKind::Retune,
            "batch" => SpanKind::Batch,
            "spill" => SpanKind::Spill,
            "restore" => SpanKind::Restore,
            _ => return None,
        })
    }
}

/// Typed span payload — what the stage measured, beyond wall time.
/// Per-phase `bytes`/`flops` make achieved intensity (Eq. 7/8's
/// measured `I = C/M`) computable *per phase*, not just per job.
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    /// No payload.
    None,
    /// Plan-cache lookup: rendered plan key + hit/miss.
    Plan {
        /// Human-readable plan key (pattern/dtype/domain/steps…).
        key: String,
        /// True when the cache served a stamped plan without planning.
        hit: bool,
    },
    /// Queue wait: depth observed at dequeue.
    Queue {
        /// Tasks still queued when this one was popped.
        depth: u64,
    },
    /// One shard × phase compute interval.
    Phase {
        /// Phase index within the job's `shard_phases` schedule.
        index: u64,
        /// Shard index within the phase.
        shard: u64,
        /// Temporal depth the phase executes.
        depth: u64,
        /// True when the phase runs a fused kernel.
        fused: bool,
        /// Principal-memory bytes this shard moved in this phase.
        bytes: u64,
        /// Multiply-add FLOPs this shard executed in this phase.
        flops: u64,
        /// Resolved row-kernel name (empty if unresolved).
        kernel: String,
    },
    /// Halo-assembly barrier for one phase.
    Barrier {
        /// Phase index the barrier closes.
        index: u64,
        /// Shards the barrier waited for.
        shards: u64,
        /// First-shard-done → last-shard-done straggler stall.
        stall_ns: u64,
    },
    /// Kernel dispatch: the resolved row-kernel name.
    Kernel {
        /// `"{shape}/{dtype}/{isa}"` or `"generic"`.
        name: String,
        /// Effective non-zero taps per point update (pruned count for
        /// 2:4-sparse patterns, geometric otherwise).
        nnz: u64,
    },
    /// Whole-job summary attached to the `Job` span.
    Job {
        /// Time steps the job advanced.
        steps: u64,
        /// Shards the job fanned out into (1 = monolithic).
        shards: u64,
        /// |measured − predicted| / predicted intensity (NaN when the
        /// backend did not instrument traffic).
        model_err: f64,
    },
    /// Drift reading that flagged the machine profile.
    Drift {
        /// Drift region key (`mem/…` / `comp/…`).
        region: String,
        /// EWMA of the model error in that region.
        ewma: f64,
        /// True when this reading crossed the threshold.
        flagged: bool,
    },
    /// Retune episode outcome.
    Retune {
        /// True when a fresh measured profile was installed.
        ok: bool,
    },
    /// Coalesced batch dispatch: gather window open → plan distributed.
    Batch {
        /// Member jobs that shared the one plan resolution.
        jobs: u64,
        /// Canonical rendering of the shared `PlanKey`.
        key: String,
    },
    /// Session field spilled to disk (tiering).
    Spill {
        /// Session name.
        session: String,
        /// Resident bytes written (8 × field length).
        bytes: u64,
    },
    /// Session field restored from disk (tiering).
    Restore {
        /// Session name.
        session: String,
        /// Resident bytes read back (8 × field length).
        bytes: u64,
    },
}

/// One completed interval: (trace, worker, kind, clock, payload).
/// Times are nanoseconds on the recorder's private monotonic epoch —
/// comparable to each other, never to wall clocks.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Job trace id ([`next_trace_id`]); 0 = outside any job.
    pub trace: u64,
    /// Worker track ([`set_worker`]); 0 = handler/main thread.
    pub worker: u64,
    /// Pipeline stage.
    pub kind: SpanKind,
    /// Start, ns since the recorder epoch.
    pub start_ns: u64,
    /// End, ns since the recorder epoch (≥ `start_ns`).
    pub end_ns: u64,
    /// Stage-typed measurement.
    pub payload: Payload,
}

impl Span {
    /// Span duration in nanoseconds.
    pub fn wall_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static TRACE_SEQ: AtomicU64 = AtomicU64::new(1);

struct Recorder {
    epoch: Instant,
    rings: Vec<Mutex<Ring>>,
    sink: Mutex<Option<BufWriter<File>>>,
}

fn recorder() -> &'static Recorder {
    static R: OnceLock<Recorder> = OnceLock::new();
    R.get_or_init(|| Recorder {
        epoch: Instant::now(),
        rings: (0..WORKER_TRACKS).map(|_| Mutex::new(Ring::new(RING_CAP))).collect(),
        sink: Mutex::new(None),
    })
}

thread_local! {
    static CURRENT_TRACE: Cell<u64> = const { Cell::new(0) };
    static WORKER_ID: Cell<u64> = const { Cell::new(0) };
}

/// True when span recording is on (one relaxed load — the entire
/// disabled-mode cost of a probe site).
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on (idempotent).  The recorder epoch is pinned
/// on first use, before the flag flips, so no span can observe an
/// uninitialized clock.
pub fn enable() {
    recorder();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn span recording off (idempotent).  Rings keep their contents;
/// the NDJSON sink, if any, stays attached but receives nothing.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Nanoseconds since the recorder's monotonic epoch.  Fits a JSON
/// number exactly (< 2^53 ns ≈ 104 days of uptime per value).
pub fn now_ns() -> u64 {
    recorder().epoch.elapsed().as_nanos() as u64
}

/// Allocate the next job trace id (monotonic from 1; 0 is reserved
/// for "outside any job").
pub fn next_trace_id() -> u64 {
    TRACE_SEQ.fetch_add(1, Ordering::Relaxed)
}

/// The calling thread's active trace id (0 outside any scope).
pub fn current_trace() -> u64 {
    CURRENT_TRACE.with(|c| c.get())
}

/// Tag the calling thread as worker `w` for span attribution
/// (worker-pool threads call this once at startup).
pub fn set_worker(w: usize) {
    WORKER_ID.with(|c| c.set(w as u64));
}

/// The calling thread's worker id (0 unless [`set_worker`] was called).
pub fn worker_id() -> u64 {
    WORKER_ID.with(|c| c.get())
}

/// RAII guard restoring the previous thread-local trace id on drop.
#[derive(Debug)]
pub struct TraceScope {
    prev: u64,
}

/// Enter `trace` on the calling thread until the guard drops (scopes
/// nest; the previous id is restored).
#[must_use = "the scope ends when the guard drops"]
pub fn trace_scope(trace: u64) -> TraceScope {
    let prev = CURRENT_TRACE.with(|c| c.replace(trace));
    TraceScope { prev }
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        let prev = self.prev;
        CURRENT_TRACE.with(|c| c.set(prev));
    }
}

/// Record one completed span under the calling thread's (trace,
/// worker).  No-op when disabled.  The span lands in the worker's ring
/// and, when a sink is attached, as one NDJSON line.
pub fn record(kind: SpanKind, start_ns: u64, end_ns: u64, payload: Payload) {
    if !enabled() {
        return;
    }
    let span = Span {
        trace: current_trace(),
        worker: worker_id(),
        kind,
        start_ns,
        end_ns,
        payload,
    };
    let r = recorder();
    if let Ok(mut g) = r.sink.lock() {
        if let Some(w) = g.as_mut() {
            // Flushed per line so a crash or shutdown loses at most
            // the current span; trace files are read by external tools.
            let _ = writeln!(w, "{}", export::span_to_json(&span));
            let _ = w.flush();
        }
    }
    let track = span.worker as usize % WORKER_TRACKS;
    if let Ok(mut ring) = r.rings[track].lock() {
        ring.push(span);
    }
}

/// Remove and return every recorded span of `trace`, across all worker
/// rings, sorted by start time.  Other traces' spans are untouched.
pub fn drain(trace: u64) -> Vec<Span> {
    let r = recorder();
    let mut out = Vec::new();
    for ring in &r.rings {
        if let Ok(mut g) = ring.lock() {
            out.extend(g.drain_trace(trace));
        }
    }
    out.sort_by_key(|s| (s.start_ns, s.end_ns, s.worker));
    out
}

/// Remove and return every recorded span, sorted by start time.
pub fn drain_all() -> Vec<Span> {
    let r = recorder();
    let mut out = Vec::new();
    for ring in &r.rings {
        if let Ok(mut g) = ring.lock() {
            out.extend(g.drain_all());
        }
    }
    out.sort_by_key(|s| (s.start_ns, s.end_ns, s.worker));
    out
}

/// Attach an NDJSON sink: every recorded span is appended to `path`
/// as one JSON line (created/truncated here).  Implies nothing about
/// [`enable`] — callers wire both.
pub fn set_sink(path: &Path) -> std::io::Result<()> {
    let f = File::create(path)?;
    if let Ok(mut g) = recorder().sink.lock() {
        *g = Some(BufWriter::new(f));
    }
    Ok(())
}

/// Detach the NDJSON sink (flushing it), if one is attached.
pub fn clear_sink() {
    if let Ok(mut g) = recorder().sink.lock() {
        if let Some(w) = g.as_mut() {
            let _ = w.flush();
        }
        *g = None;
    }
}

/// The process-wide metrics registry (always on; independent of span
/// recording because counter/histogram updates never change replies).
pub fn metrics() -> &'static prom::Metrics {
    static M: OnceLock<prom::Metrics> = OnceLock::new();
    M.get_or_init(prom::Metrics::new)
}

#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    // Tests that flip the global ENABLED flag must serialize, or a
    // concurrent disabled-mode assertion would observe their window.
    static L: Mutex<()> = Mutex::new(());
    L.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let _g = test_lock();
        disable();
        let trace = next_trace_id();
        let _s = trace_scope(trace);
        record(SpanKind::Kernel, 0, 10, Payload::None);
        assert!(drain(trace).is_empty());
        assert!(!enabled());
    }

    #[test]
    fn spans_land_under_the_active_trace_and_worker() {
        let _g = test_lock();
        enable();
        let trace = next_trace_id();
        {
            let _s = trace_scope(trace);
            set_worker(3);
            let t0 = now_ns();
            record(SpanKind::Admission, t0, now_ns(), Payload::None);
            record(
                SpanKind::Kernel,
                now_ns(),
                now_ns(),
                Payload::Kernel { name: "generic".into(), nnz: 5 },
            );
            set_worker(0);
        }
        disable();
        let spans = drain(trace);
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().all(|s| s.trace == trace && s.worker == 3));
        assert_eq!(spans[0].kind, SpanKind::Admission);
        assert!(spans[0].start_ns <= spans[1].start_ns, "sorted by start");
        // a second drain finds nothing: spans were removed
        assert!(drain(trace).is_empty());
    }

    #[test]
    fn trace_scopes_nest_and_restore() {
        let _g = test_lock();
        assert_eq!(current_trace(), 0);
        let outer = trace_scope(7);
        assert_eq!(current_trace(), 7);
        {
            let _inner = trace_scope(9);
            assert_eq!(current_trace(), 9);
        }
        assert_eq!(current_trace(), 7);
        drop(outer);
        assert_eq!(current_trace(), 0);
    }

    #[test]
    fn drain_is_trace_selective() {
        let _g = test_lock();
        enable();
        let (a, b) = (next_trace_id(), next_trace_id());
        {
            let _s = trace_scope(a);
            record(SpanKind::Job, 0, 1, Payload::None);
        }
        {
            let _s = trace_scope(b);
            record(SpanKind::Job, 2, 3, Payload::None);
        }
        disable();
        assert_eq!(drain(a).len(), 1);
        assert_eq!(drain(b).len(), 1);
    }

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(a != 0 && b != 0 && a != b);
        let t0 = now_ns();
        let t1 = now_ns();
        assert!(t1 >= t0, "monotonic epoch clock");
    }
}

//! Model-error attribution: *which term broke*.
//!
//! The calibration plane ([`crate::model::calib`]) reports a scalar
//! model error; the drift plane EWMAs it per region.  Neither says
//! whether the bandwidth constant 𝔹, the kernel peak ℙ, the planner's
//! redundancy assumption (α fused, κ/τ sharded), or the serving layer
//! (queue wait + gather window + barrier stall) is the term that
//! disagrees with the machine.  This module decomposes one completed
//! job's measured-vs-predicted wall time into per-term residuals — the
//! roofline-attribution style of analysis — and ranks them into a
//! verdict the reply, `stats`, the trace differ, and
//! [`crate::tune::drift`]'s retune episodes all cite.
//!
//! The decomposition (all terms in milliseconds, model − measurement):
//!
//! * **serving** = handler wall − execution wall: time the job spent
//!   queued, gathering co-batchers, or stalled at barriers.  The
//!   roofline predicts zero of it.
//! * **redundancy** = (bytes_moved − bytes_predicted) / 𝔹: extra
//!   traffic the planner did not price (halo re-reads, trapezoid
//!   recompute beyond the assumed κ/τ/α).
//! * **bandwidth** (memory-bound jobs) = exec − bytes_moved / 𝔹: with
//!   the *actual* traffic priced at the profile's 𝔹, what remains is
//!   the achieved-bandwidth shortfall — i.e. 𝔹 itself is wrong.
//! * **kernel** (compute-bound jobs) = exec − flops / ℙ: the same
//!   shortfall against the peak that priced the plan.
//! * **unattributed** = total residual − Σ terms: what the model has
//!   no name for (kept explicit so a bad decomposition is visible,
//!   not silently absorbed into the largest term).
//!
//! A crushed 𝔹 shows up as a dominant (negative) bandwidth residual, a
//! crushed ℙ as a kernel residual, an inflated queue as a serving
//! residual — the single-term perturbation tests below pin each.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;

/// A model term blame can land on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Term {
    /// The profile bandwidth 𝔹 (Eq. 4's memory roof).
    Bandwidth,
    /// The kernel peak ℙ (Eq. 4/20's compute roof, per-kernel measured).
    Kernel,
    /// Planner-assumed redundancy (α fused, κ/τ sharded) vs actual bytes.
    Redundancy,
    /// Queue wait + batch gather window + barrier stall.
    Serving,
    /// Residual the decomposition cannot name.
    Unattributed,
}

impl Term {
    /// Stable wire name (`"attribution"` blocks, journal events).
    pub fn as_str(self) -> &'static str {
        match self {
            Term::Bandwidth => "bandwidth",
            Term::Kernel => "kernel",
            Term::Redundancy => "redundancy",
            Term::Serving => "serving",
            Term::Unattributed => "unattributed",
        }
    }

    /// Every term, in declaration order (aggregation tables).
    pub fn all() -> [Term; 5] {
        [Term::Bandwidth, Term::Kernel, Term::Redundancy, Term::Serving, Term::Unattributed]
    }
}

/// What one completed job observed — the attribution inputs, already
/// reduced to scalars so the decomposition is pure arithmetic.
#[derive(Debug, Clone, Copy)]
pub struct JobObservation {
    /// Admission's roofline wall prediction (ms).
    pub predicted_ms: f64,
    /// Measured execution wall (ms) — worker-side, queue excluded.
    pub exec_ms: f64,
    /// Handler wall minus execution wall (ms): queue + gather + stalls.
    pub serve_ms: f64,
    /// The job priced under the memory roof (below the ridge).
    pub mem_bound: bool,
    /// Principal-memory bytes the backend actually moved.
    pub bytes_moved: f64,
    /// Bytes the planner's intensity assumed for the same FLOPs
    /// (`flops / predicted_intensity`).
    pub bytes_predicted: f64,
    /// Multiply-add FLOPs the job executed (deterministic counter).
    pub flops: f64,
    /// The profile 𝔹 that priced the plan (bytes/s).
    pub bandwidth: f64,
    /// The ℙ that priced the plan (FLOP/s; per-kernel measured peak
    /// when the registry had one, the unit roof otherwise).
    pub peak_flops: f64,
}

/// One term's share of the job's residual.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TermResidual {
    pub term: Term,
    /// Signed milliseconds: positive = slower than the term's model
    /// value, negative = the model constant overpriced the machine.
    pub residual_ms: f64,
}

/// The ranked verdict for one job (or one aggregated region).
#[derive(Debug, Clone, PartialEq)]
pub struct Attribution {
    /// Admission's prediction (ms).
    pub predicted_ms: f64,
    /// Handler-measured total (exec + serving, ms).
    pub measured_ms: f64,
    /// Per-term residuals, ranked by |residual| descending.
    pub terms: Vec<TermResidual>,
    /// The top-ranked term — what broke.
    pub verdict: Term,
}

impl Attribution {
    /// The `"attribution"` block of advance replies and `stats`.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("predicted_ms".to_string(), Json::Num(self.predicted_ms));
        o.insert("measured_ms".to_string(), Json::Num(self.measured_ms));
        o.insert("verdict".to_string(), Json::Str(self.verdict.as_str().to_string()));
        o.insert(
            "terms".to_string(),
            Json::Arr(
                self.terms
                    .iter()
                    .map(|t| {
                        let mut m = BTreeMap::new();
                        m.insert("term".to_string(), Json::Str(t.term.as_str().to_string()));
                        m.insert("residual_ms".to_string(), Json::Num(t.residual_ms));
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(o)
    }
}

/// Finite-or-zero guard: a degenerate input (zero bandwidth, NaN wall)
/// must rank last, not poison the sort.
fn fin(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// Decompose one job's residual into ranked per-term blame.
pub fn attribute(o: &JobObservation) -> Attribution {
    let mut terms: Vec<TermResidual> = Vec::with_capacity(5);
    let serving = fin(o.serve_ms).max(0.0);
    terms.push(TermResidual { term: Term::Serving, residual_ms: serving });
    let redundancy = if o.bandwidth > 0.0 {
        fin((o.bytes_moved - o.bytes_predicted) / o.bandwidth * 1e3)
    } else {
        0.0
    };
    terms.push(TermResidual { term: Term::Redundancy, residual_ms: redundancy });
    let roof = if o.mem_bound {
        let r = if o.bandwidth > 0.0 {
            fin(o.exec_ms - o.bytes_moved / o.bandwidth * 1e3)
        } else {
            0.0
        };
        TermResidual { term: Term::Bandwidth, residual_ms: r }
    } else {
        let r = if o.peak_flops > 0.0 {
            fin(o.exec_ms - o.flops / o.peak_flops * 1e3)
        } else {
            0.0
        };
        TermResidual { term: Term::Kernel, residual_ms: r }
    };
    terms.push(roof);
    let measured_ms = fin(o.exec_ms) + serving;
    let total = measured_ms - fin(o.predicted_ms);
    let named: f64 = terms.iter().map(|t| t.residual_ms).sum();
    terms.push(TermResidual { term: Term::Unattributed, residual_ms: fin(total - named) });
    // Rank by |residual| descending; the tie-break keeps the order
    // deterministic (serving before redundancy before the roof term).
    let mut ranked = terms;
    ranked.sort_by(|a, b| {
        b.residual_ms
            .abs()
            .partial_cmp(&a.residual_ms.abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    Attribution {
        predicted_ms: fin(o.predicted_ms),
        measured_ms,
        verdict: ranked[0].term,
        terms: ranked,
    }
}

/// One drift-region's aggregated attribution.
#[derive(Debug, Clone)]
pub struct RegionAttrib {
    /// Drift-region key (`mem/sweep`, `comp/fused+shard`, …).
    pub region: String,
    /// Jobs aggregated.
    pub jobs: u64,
    /// The most frequent per-job verdict (ties → term order).
    pub dominant: Term,
    /// Per-term (mean |residual| ms, verdict count), [`Term::all`] order.
    pub terms: Vec<(Term, f64, u64)>,
}

#[derive(Debug, Default, Clone, Copy)]
struct Agg {
    sum_abs_ms: f64,
    verdicts: u64,
}

#[derive(Debug, Default)]
struct RegionAgg {
    jobs: u64,
    per_term: [Agg; 5],
}

/// Per-drift-region attribution aggregation (the `stats` view: one
/// ranked verdict per region, not per job).
#[derive(Debug, Default)]
pub struct AttribStore {
    inner: Mutex<BTreeMap<String, RegionAgg>>,
}

impl AttribStore {
    pub fn new() -> AttribStore {
        AttribStore::default()
    }

    /// Fold one job's attribution into its region's aggregate.
    pub fn record(&self, region: &str, a: &Attribution) {
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        let agg = g.entry(region.to_string()).or_default();
        agg.jobs += 1;
        for t in &a.terms {
            let i = Term::all().iter().position(|&x| x == t.term).unwrap_or(4);
            agg.per_term[i].sum_abs_ms += t.residual_ms.abs();
            if t.term == a.verdict {
                agg.per_term[i].verdicts += 1;
            }
        }
    }

    /// Region-ordered snapshot for `stats` / `top`.
    pub fn snapshot(&self) -> Vec<RegionAttrib> {
        let g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        g.iter()
            .map(|(region, agg)| {
                let terms: Vec<(Term, f64, u64)> = Term::all()
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| {
                        let mean = if agg.jobs > 0 {
                            agg.per_term[i].sum_abs_ms / agg.jobs as f64
                        } else {
                            0.0
                        };
                        (t, mean, agg.per_term[i].verdicts)
                    })
                    .collect();
                let dominant = terms
                    .iter()
                    .max_by_key(|(_, _, v)| *v)
                    .map(|(t, _, _)| *t)
                    .unwrap_or(Term::Unattributed);
                RegionAttrib { region: region.clone(), jobs: agg.jobs, dominant, terms }
            })
            .collect()
    }

    /// Jobs aggregated across all regions.
    pub fn total_jobs(&self) -> u64 {
        let g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        g.values().map(|a| a.jobs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A healthy memory-bound job: 1 GB at 100 GB/s = 10 ms, predicted
    /// 10 ms, negligible serving.  Every term should be near zero.
    fn healthy() -> JobObservation {
        JobObservation {
            predicted_ms: 10.0,
            exec_ms: 10.05,
            serve_ms: 0.02,
            mem_bound: true,
            bytes_moved: 1e9,
            bytes_predicted: 1e9,
            flops: 3.375e9,
            bandwidth: 1e11,
            peak_flops: 1e13,
        }
    }

    #[test]
    fn healthy_job_attributes_nothing_big() {
        let a = attribute(&healthy());
        assert!((a.measured_ms - 10.07).abs() < 1e-9);
        for t in &a.terms {
            assert!(t.residual_ms.abs() < 0.1, "{:?}", t);
        }
        assert_eq!(a.terms.len(), 5);
        // the roof term for a mem-bound job is bandwidth, never kernel
        assert!(a.terms.iter().any(|t| t.term == Term::Bandwidth));
        assert!(!a.terms.iter().any(|t| t.term == Term::Kernel));
    }

    #[test]
    fn crushed_bandwidth_blames_the_bandwidth_term() {
        // 𝔹 halved in the profile: the prediction doubles, the machine
        // still runs at the true bandwidth.  exec = bytes/𝔹_true = 10ms
        // but the plan priced bytes/𝔹_crushed = 20ms.
        let o = JobObservation {
            predicted_ms: 20.0,
            exec_ms: 10.0,
            bandwidth: 0.5e11, // the crushed constant the plan priced
            ..healthy()
        };
        let a = attribute(&o);
        assert_eq!(a.verdict, Term::Bandwidth, "{a:?}");
        let bw = a.terms.iter().find(|t| t.term == Term::Bandwidth).unwrap();
        assert!(bw.residual_ms < -5.0, "overpriced 𝔹 ⇒ large negative residual: {bw:?}");
        // total residual reconciles: measured − predicted = Σ terms
        let sum: f64 = a.terms.iter().map(|t| t.residual_ms).sum();
        assert!((sum - (a.measured_ms - a.predicted_ms)).abs() < 1e-9);
    }

    #[test]
    fn crushed_kernel_peak_blames_the_kernel_term() {
        // Compute-bound: flops/ℙ_true = 10 ms, ℙ halved ⇒ predicted 20.
        let o = JobObservation {
            predicted_ms: 20.0,
            exec_ms: 10.0,
            serve_ms: 0.02,
            mem_bound: false,
            bytes_moved: 1e8,
            bytes_predicted: 1e8,
            flops: 1e11,
            bandwidth: 1e11,
            peak_flops: 0.5e13, // the crushed constant
        };
        let a = attribute(&o);
        assert_eq!(a.verdict, Term::Kernel, "{a:?}");
        assert!(!a.terms.iter().any(|t| t.term == Term::Bandwidth), "compute-bound: no 𝔹 term");
    }

    #[test]
    fn inflated_queue_wait_blames_the_serving_term() {
        let o = JobObservation { serve_ms: 45.0, ..healthy() };
        let a = attribute(&o);
        assert_eq!(a.verdict, Term::Serving, "{a:?}");
        let s = a.terms.iter().find(|t| t.term == Term::Serving).unwrap();
        assert!((s.residual_ms - 45.0).abs() < 1e-9);
    }

    #[test]
    fn unpriced_halo_traffic_blames_the_redundancy_term() {
        // The backend moved 3× the bytes the planner's κ/τ assumed; the
        // machine still achieved profile 𝔹 on what it did move.
        let o = JobObservation {
            exec_ms: 30.0,
            bytes_moved: 3e9,
            ..healthy()
        };
        let a = attribute(&o);
        assert_eq!(a.verdict, Term::Redundancy, "{a:?}");
        let r = a.terms.iter().find(|t| t.term == Term::Redundancy).unwrap();
        assert!((r.residual_ms - 20.0).abs() < 1e-6, "2 GB unpriced at 100 GB/s = 20 ms");
        // bandwidth residual stays small: actual bytes at 𝔹 ≈ exec
        let bw = a.terms.iter().find(|t| t.term == Term::Bandwidth).unwrap();
        assert!(bw.residual_ms.abs() < 0.5, "{bw:?}");
    }

    #[test]
    fn degenerate_inputs_rank_last_instead_of_poisoning() {
        let o = JobObservation {
            predicted_ms: f64::NAN,
            bandwidth: 0.0,
            peak_flops: 0.0,
            ..healthy()
        };
        let a = attribute(&o);
        assert_eq!(a.terms.len(), 5);
        assert!(a.terms.iter().all(|t| t.residual_ms.is_finite()));
        assert!(a.predicted_ms == 0.0 && a.measured_ms.is_finite());
    }

    #[test]
    fn store_aggregates_per_region_with_dominant_verdict() {
        let store = AttribStore::new();
        let crushed = JobObservation {
            predicted_ms: 20.0,
            exec_ms: 10.0,
            bandwidth: 0.5e11,
            ..healthy()
        };
        for _ in 0..3 {
            store.record("mem/sweep", &attribute(&crushed));
        }
        store.record("mem/sweep", &attribute(&JobObservation { serve_ms: 45.0, ..healthy() }));
        store.record("comp/fused", &attribute(&healthy()));
        let snap = store.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(store.total_jobs(), 5);
        let mem = snap.iter().find(|r| r.region == "mem/sweep").unwrap();
        assert_eq!(mem.jobs, 4);
        assert_eq!(mem.dominant, Term::Bandwidth, "3 of 4 verdicts blame 𝔹");
        let bw = mem.terms.iter().find(|(t, _, _)| *t == Term::Bandwidth).unwrap();
        assert_eq!(bw.2, 3);
        assert!(bw.1 > 5.0, "mean |residual| carries the magnitude");
        // to_json renders the block shape the protocol ships
        let j = attribute(&crushed).to_json();
        assert_eq!(j.get("verdict").unwrap().as_str(), Some("bandwidth"));
        assert_eq!(j.get("terms").unwrap().as_arr().unwrap().len(), 5);
    }
}

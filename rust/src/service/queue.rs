//! The bounded task queue + worker pool — the shard is the unit of
//! scheduling.
//!
//! Connection handlers enqueue [`Task`]s without blocking — a full
//! queue is load-shedding feedback, not backpressure-by-hanging — and
//! wait on a per-job reply channel.  Two task kinds share the pool:
//!
//! * [`Task::Job`] — the monolithic path: one worker resolves a
//!   backend, advances the session's resident field under the session
//!   lock, and replies with the job's [`RunMetrics`].
//! * [`Task::Batch`] — N coalesced monolithic jobs with identical
//!   `PlanKey`s: one worker resolves a single backend (one kernel
//!   compilation) and advances each member's session in turn, replying
//!   per member.  Execution order within the batch is the arrival
//!   order, so results are bit-identical to running the members
//!   sequentially unbatched.
//!
//! Deadline (`deadline_ms`) jobs admitted through the EDF tier bypass
//! the FIFO: [`JobQueue::push_urgent`] keeps an
//! earliest-deadline-first side queue that workers drain before any
//! FIFO task.  [`JobQueue::depth`] is job-weighted — a coalesced batch
//! counts as its member-job count, not 1 — so the queue-depth gauge
//! and `PushError::Full` evidence reflect real backlog.
//! * [`Task::Shard`] — one shard × one synchronization phase of a
//!   [`ShardedRun`]: an admitted job fans out into `S` shard tasks
//!   that run on multiple workers **concurrently**, each computing its
//!   disjoint write-back slab from the shared phase-start field.  The
//!   worker that completes a phase's last shard performs the
//!   halo-exchange barrier — assembles the slabs into the next
//!   phase-start field and re-enqueues the next phase's shard tasks —
//!   so tasks never block on each other and any pool size (even one
//!   worker) makes progress without deadlock.
//!
//! Per-shard [`RunMetrics`] (halo re-reads and trapezoid recompute
//! included) are aggregated into the job-level reply.  Closing the
//! queue wakes every worker; they drain what was admitted (in-flight
//! sharded jobs keep re-enqueueing their remaining phases internally)
//! and exit.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::backend::{self, NativeBackend, ShardPhase};
use crate::coordinator::grid::ShardPlan;
use crate::coordinator::metrics::{RunMetrics, ServiceCounters};
use crate::obs;
use crate::util::json::Json;

use super::session::{Session, SessionStore};

/// One admitted monolithic job, bound to its session and reply channel.
pub struct QueuedJob {
    pub session: Arc<Mutex<Session>>,
    /// Owning tenant, for per-tenant refusal attribution when a
    /// coalesced batch push is refused by a full queue.
    pub tenant: String,
    /// The owning store, when session tiering is on: the executing
    /// worker restores a spilled field under the session lock right
    /// before advancing (an `enforce` between admission and execution
    /// may have spilled it).
    pub store: Option<Arc<SessionStore>>,
    pub job: backend::Job,
    pub kind: backend::BackendKind,
    /// Whether a PJRT resolution can possibly succeed (manifest present
    /// + pjrt-enabled binary).  When false, `auto` jobs go straight to
    /// the native backend instead of re-probing the artifact dir on
    /// disk for every job on the hot serving path.
    pub pjrt_possible: bool,
    pub artifacts_dir: PathBuf,
    /// Worker → connection handler result channel (the job's metrics,
    /// or the execution error as a rendered string).
    pub reply: mpsc::Sender<Result<RunMetrics, String>>,
    /// Job trace id ([`obs::next_trace_id`]), stamped at admission so
    /// worker-side spans correlate with the handler's.
    pub trace: u64,
    /// [`obs::now_ns`] at enqueue — the queue-wait span/histogram start.
    pub queued_ns: u64,
}

/// N monolithic jobs coalesced on one `PlanKey`: plan resolution
/// happened once at the gate; kernel compilation happens once here.
pub struct BatchRun {
    pub members: Vec<QueuedJob>,
    /// Canonical `PlanKey` the members coalesced on (obs label).
    pub key: String,
}

/// One schedulable unit.
pub enum Task {
    /// A whole job, executed by one worker (shards = 1).
    Job(QueuedJob),
    /// Coalesced identical-`PlanKey` jobs, executed back-to-back by one
    /// worker sharing a single backend resolution.
    Batch(BatchRun),
    /// Shard `usize` of a sharded run's current phase.
    Shard(Arc<ShardedRun>, usize),
    /// Background machine recalibration (`--retune auto` after drift):
    /// run the microbenchmark suite and install the fresh profile.
    Retune(RetuneTask),
}

impl Task {
    /// Member-job count for queue-depth accounting (a coalesced batch
    /// is its member count, not 1; maintenance tasks count 1).
    fn weight(&self) -> usize {
        match self {
            Task::Batch(b) => b.members.len().max(1),
            _ => 1,
        }
    }
}

/// A scheduled background recalibration.  Runs on an ordinary pool
/// worker for lifecycle simplicity (drains with the queue, no private
/// threads) — which also means live jobs on the OTHER workers can
/// contend with the probes.  Contention shows up as rep-to-rep spread,
/// so [`RetuneTask::run`] refuses to install a profile whose probes
/// were too noisy ([`crate::tune::micro::MAX_PROBE_SPREAD`]) rather
/// than letting contention-biased constants drive every future plan;
/// the next drifted sample retries, and quiet moments eventually win.
pub struct RetuneTask {
    /// The hub the fresh profile is installed into.
    pub hub: Arc<crate::tune::drift::ProfileHub>,
    /// The plan cache to invalidate once constants change.
    pub plans: Arc<super::plan_cache::PlanCache>,
    /// Probe preset (quick for background retunes).
    pub opts: crate::tune::micro::MicroOpts,
    /// Why this retune was scheduled: the attribution verdict of the
    /// drifted region ([`crate::obs::attrib`]) when one exists, or
    /// `"ewma_crossing"` when the episode predates any attribution —
    /// journaled with the install/reject so forensics can say what
    /// evidence drove each recalibration.
    pub cause: String,
}

impl RetuneTask {
    /// Execute the recalibration: measure, install, invalidate plans.
    /// A failed OR contention-noisy probe run releases the hub's
    /// retune latch without installing anything — the stale flag stays
    /// set (visible in stats) and the next drifted sample retries.
    fn run(&self) {
        let r0 = if obs::enabled() { obs::now_ns() } else { 0 };
        let mut installed = false;
        match crate::tune::micro::measure(&self.opts) {
            Ok(profile) => {
                let worst = crate::tune::micro::worst_spread(&profile);
                if worst > crate::tune::micro::MAX_PROBE_SPREAD {
                    eprintln!(
                        "stencilctl serve: rejecting retune — probe spread {:.0}% \
                         (> {:.0}%), likely contention with live jobs; will retry",
                        worst * 100.0,
                        crate::tune::micro::MAX_PROBE_SPREAD * 100.0
                    );
                    self.hub.retune_failed();
                    obs::journal::emit(
                        "retune_reject",
                        &[
                            ("cause", Json::Str(self.cause.clone())),
                            ("reason", Json::Str("probe_spread".to_string())),
                            ("spread", obs::journal::f(worst)),
                            (
                                "spread_max",
                                obs::journal::f(crate::tune::micro::MAX_PROBE_SPREAD),
                            ),
                        ],
                    );
                } else {
                    // Clear on BOTH sides of the install: a plan that
                    // began its miss before the first clear is refused
                    // by the cache's generation stamp; one that missed
                    // between the clear and the install (old constants,
                    // same PlanKey identity) is dropped by the second;
                    // anything after the install reads the new
                    // constants.  plan_for's own hub-generation
                    // re-check handles the serving side.
                    self.plans.clear();
                    self.hub.install(profile);
                    self.plans.clear();
                    installed = true;
                    obs::journal::emit(
                        "retune_install",
                        &[
                            ("cause", Json::Str(self.cause.clone())),
                            ("generation", Json::Num(self.hub.generation() as f64)),
                            ("spread", obs::journal::f(worst)),
                        ],
                    );
                }
            }
            Err(e) => {
                eprintln!("stencilctl serve: background retune failed: {e:#}");
                self.hub.retune_failed();
                obs::journal::emit(
                    "retune_reject",
                    &[
                        ("cause", Json::Str(self.cause.clone())),
                        ("reason", Json::Str("probe_error".to_string())),
                        ("message", Json::Str(format!("{e:#}"))),
                    ],
                );
            }
        }
        if obs::enabled() {
            obs::record(
                obs::SpanKind::Retune,
                r0,
                obs::now_ns(),
                obs::Payload::Retune { ok: installed },
            );
        }
    }
}

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// At capacity — the caller should shed the job.  Carries the
    /// observed depth and the configured capacity so shed clients (and
    /// the admission log) can see why.
    Full {
        /// Tasks queued at refusal time.
        depth: usize,
        /// Configured queue capacity.
        cap: usize,
    },
    /// Shutting down — no new work is admitted.
    Closed,
}

#[derive(Default)]
struct Inner {
    tasks: VecDeque<Task>,
    /// EDF tier: kept sorted by (absolute deadline ns, admission seq);
    /// workers drain it before any FIFO task.
    urgent: VecDeque<(u64, u64, Task)>,
    /// Tie-break sequence for equal deadlines (admission order).
    useq: u64,
    open: bool,
}

impl Inner {
    /// Job-weighted backlog across both tiers.
    fn weight(&self) -> usize {
        self.tasks.iter().map(Task::weight).sum::<usize>()
            + self.urgent.iter().map(|(_, _, t)| t.weight()).sum::<usize>()
    }
}

/// Bounded MPMC task queue (Mutex + Condvar; std only).
pub struct JobQueue {
    cap: usize,
    inner: Mutex<Inner>,
    ready: Condvar,
}

impl JobQueue {
    pub fn new(cap: usize) -> JobQueue {
        JobQueue {
            cap: cap.max(1),
            inner: Mutex::new(Inner { open: true, ..Inner::default() }),
            ready: Condvar::new(),
        }
    }

    /// Non-blocking admission; the task is dropped on refusal (its
    /// reply sender with it, so nobody ends up waiting on a dead
    /// channel).
    pub fn push(&self, t: Task) -> Result<(), PushError> {
        self.push_batch(vec![t])
    }

    /// Atomically admit a batch (a sharded job's phase-0 fan-out):
    /// either every task is queued or none is.
    pub fn push_batch(&self, ts: Vec<Task>) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if !g.open {
            return Err(PushError::Closed);
        }
        let incoming: usize = ts.iter().map(Task::weight).sum();
        let depth = g.weight();
        if depth + incoming > self.cap {
            return Err(PushError::Full { depth, cap: self.cap });
        }
        let n = ts.len();
        g.tasks.extend(ts);
        drop(g);
        if n == 1 {
            self.ready.notify_one();
        } else {
            self.ready.notify_all();
        }
        Ok(())
    }

    /// Admit a deadline job into the EDF tier: capacity-checked like
    /// [`JobQueue::push`], but popped before any FIFO task, earliest
    /// absolute deadline first (admission order breaks ties).
    pub fn push_urgent(&self, t: Task, deadline_ns: u64) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if !g.open {
            return Err(PushError::Closed);
        }
        let depth = g.weight();
        if depth + t.weight() > self.cap {
            return Err(PushError::Full { depth, cap: self.cap });
        }
        g.useq += 1;
        let seq = g.useq;
        let at = g.urgent.partition_point(|&(d, s, _)| (d, s) <= (deadline_ns, seq));
        g.urgent.insert(at, (deadline_ns, seq, t));
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Maintenance push (a drift-triggered background retune): exempt
    /// from the capacity bound — shedding a recalibration under load
    /// would keep serving from constants known to be wrong — but not
    /// from the closed flag (no new work after shutdown).
    pub(crate) fn push_maintenance(&self, t: Task) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if !g.open {
            return Err(PushError::Closed);
        }
        g.tasks.push_back(t);
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Internal continuation push (the next phase of an already-admitted
    /// sharded job): bypasses both the capacity bound and the closed
    /// flag, so admitted work always drains to completion — admission
    /// control happens once, at fan-out.
    fn push_internal(&self, ts: Vec<Task>) {
        let mut g = self.inner.lock().unwrap();
        g.tasks.extend(ts);
        drop(g);
        self.ready.notify_all();
    }

    /// Blocking worker pop; `None` once closed and drained.  The EDF
    /// tier drains ahead of the FIFO.
    pub fn pop(&self) -> Option<Task> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some((_, _, t)) = g.urgent.pop_front() {
                return Some(t);
            }
            if let Some(t) = g.tasks.pop_front() {
                return Some(t);
            }
            if !g.open {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Stop admitting; wake every worker so the pool can drain and exit.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.open = false;
        drop(g);
        self.ready.notify_all();
    }

    /// Job-weighted backlog: a coalesced batch counts as its member-job
    /// count, not 1 — the gauge must not understate a loaded queue.
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().weight()
    }
}

/// Phase-synchronized state of one sharded job.
struct ShardState {
    /// The phase-start field every shard task of the current phase
    /// reads (shared immutably via the Arc).
    src: Arc<Vec<f64>>,
    /// Per-shard write-back slabs, owned by the run between phases and
    /// checked out by the executing task.
    slabs: Vec<Option<Vec<f64>>>,
    /// Current phase index into [`ShardedRun::phases`].
    phase: usize,
    /// Shard tasks of the current phase still outstanding.
    pending: usize,
    /// Job-level aggregate (per-shard metrics absorbed as they land).
    metrics: RunMetrics,
    /// First shard failure, if any — poisons the remaining tasks of
    /// the phase into no-ops and the job into an error reply.
    failed: Option<String>,
    /// [`obs::now_ns`] when the phase's first shard finished (the
    /// barrier-stall span start; `u64::MAX` = none yet, reset per
    /// phase, only stamped while tracing is enabled).
    first_done_ns: u64,
}

/// One admitted job fanned out into shard tasks — the shard executor's
/// shared state: the phase schedule, the barrier bookkeeping, and the
/// session the result is written back to.
pub struct ShardedRun {
    session: Arc<Mutex<Session>>,
    job: backend::Job,
    plan: ShardPlan,
    phases: Vec<ShardPhase>,
    reply: mpsc::Sender<Result<RunMetrics, String>>,
    counters: Arc<ServiceCounters>,
    started: Instant,
    /// Admitting handler's trace id, re-entered by every shard task.
    trace: u64,
    /// [`obs::now_ns`] at fan-out (phase-0 queue-wait start).
    queued_ns: u64,
    state: Mutex<ShardState>,
}

impl ShardedRun {
    /// Build the run, taking ownership of the session's field as the
    /// phase-0 source (the caller has already marked the session busy).
    /// `job.threads` is ignored on this path: parallelism comes from
    /// the pool scheduling shard tasks, one thread each.
    pub fn new(
        session: Arc<Mutex<Session>>,
        job: backend::Job,
        plan: ShardPlan,
        field: Vec<f64>,
        reply: mpsc::Sender<Result<RunMetrics, String>>,
        counters: Arc<ServiceCounters>,
    ) -> ShardedRun {
        let phases = backend::shard_phases(&job);
        let nshards = plan.len();
        let metrics =
            RunMetrics { steps: job.steps, points: job.points(), ..Default::default() };
        ShardedRun {
            session,
            job,
            plan,
            phases,
            reply,
            counters,
            started: Instant::now(),
            trace: obs::current_trace(),
            queued_ns: obs::now_ns(),
            state: Mutex::new(ShardState {
                src: Arc::new(field),
                slabs: (0..nshards).map(|_| None).collect(),
                phase: 0,
                pending: nshards,
                metrics,
                failed: None,
                first_done_ns: u64::MAX,
            }),
        }
    }

    /// Shard count of the fan-out.
    pub fn shard_count(&self) -> usize {
        self.plan.len()
    }

    /// Phase count of the schedule.
    pub fn phase_count(&self) -> usize {
        self.phases.len()
    }

    /// The current phase's tasks to enqueue (one per shard).
    pub fn fan_out(run: &Arc<ShardedRun>) -> Vec<Task> {
        (0..run.shard_count()).map(|i| Task::Shard(run.clone(), i)).collect()
    }

    /// Undo a failed admission: hand the field back to the session and
    /// clear its busy flag (no task has run, the field is untouched).
    pub fn abort_admission(&self) {
        let field = {
            let mut st = self.state.lock().unwrap();
            take_field(&mut st.src)
        };
        let mut g = self.session.lock().unwrap();
        g.field = field;
        g.busy = false;
    }

    /// Execute shard `idx` of the current phase; the completing worker
    /// of each phase runs the barrier (assemble slabs → next phase or
    /// finalize).
    fn run_shard(run: &Arc<ShardedRun>, queue: &JobQueue, idx: usize) {
        let _in_trace = obs::trace_scope(run.trace);
        let (src, mut slab, phase_idx, poisoned) = {
            let mut st = run.state.lock().unwrap();
            let need = run.plan.shards()[idx].payload();
            let slab = st.slabs[idx].take().unwrap_or_else(|| vec![0.0; need]);
            (st.src.clone(), slab, st.phase, st.failed.is_some())
        };
        if phase_idx == 0 {
            // Later phases are pushed internally at the barrier — only
            // the fan-out batch measures admission-queue wait.
            let popped = obs::now_ns();
            obs::metrics().queue_wait_ns.observe(popped.saturating_sub(run.queued_ns) as f64);
            if obs::enabled() {
                obs::record(
                    obs::SpanKind::QueueWait,
                    run.queued_ns,
                    popped,
                    obs::Payload::Queue { depth: queue.depth() as u64 },
                );
            }
        }
        let s0 = if obs::enabled() { obs::now_ns() } else { 0 };
        let res = if poisoned {
            Ok(RunMetrics::default())
        } else {
            // A panicking shard must not wedge the barrier (pending
            // would never reach 0, hanging the client and leaving the
            // session busy forever) — convert it into a shard failure
            // like any other error.
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                NativeBackend::new().advance_shard(
                    &run.job,
                    &run.plan,
                    idx,
                    run.phases[phase_idx],
                    &src,
                    &mut slab,
                )
            }))
            .unwrap_or_else(|_| Err(anyhow::anyhow!("shard task panicked")))
            .map_err(|e| format!("{e:#}"))
        };
        drop(src); // release our read handle before the barrier reclaims it
        let done_ns = if obs::enabled() { obs::now_ns() } else { 0 };
        let mut st = run.state.lock().unwrap();
        match res {
            Ok(mut m) => {
                m.tag_phase(phase_idx);
                if obs::enabled() && !poisoned {
                    let phase = run.phases[phase_idx];
                    obs::metrics().phase_wall_ns.observe(done_ns.saturating_sub(s0) as f64);
                    obs::record(
                        obs::SpanKind::ShardPhase,
                        s0,
                        done_ns,
                        obs::Payload::Phase {
                            index: phase_idx as u64,
                            shard: idx as u64,
                            depth: phase.depth as u64,
                            fused: phase.fused,
                            bytes: m.bytes_moved,
                            flops: m.flops,
                            kernel: m.kernel.clone(),
                        },
                    );
                }
                st.metrics.absorb(&m);
            }
            Err(e) => {
                if st.failed.is_none() {
                    st.failed = Some(e);
                }
            }
        }
        if obs::enabled() {
            st.first_done_ns = st.first_done_ns.min(done_ns);
        }
        st.slabs[idx] = Some(slab);
        st.pending -= 1;
        if st.pending > 0 {
            return; // phase still in flight on other workers
        }
        // ---- barrier: this worker owns the phase transition ----
        if obs::enabled() {
            let end = obs::now_ns();
            let start = if st.first_done_ns == u64::MAX { end } else { st.first_done_ns.min(end) };
            let stall = end.saturating_sub(start);
            obs::metrics().barrier_stall_ns.observe(stall as f64);
            obs::record(
                obs::SpanKind::Barrier,
                start,
                end,
                obs::Payload::Barrier {
                    index: phase_idx as u64,
                    shards: run.shard_count() as u64,
                    stall_ns: stall,
                },
            );
        }
        if let Some(msg) = st.failed.clone() {
            // Restore the last consistent (phase-start) field so the
            // session survives with well-defined state.
            let field = take_field(&mut st.src);
            drop(st);
            {
                let mut g = run.session.lock().unwrap();
                g.field = field;
                g.busy = false;
            }
            ServiceCounters::bump(&run.counters.jobs_failed);
            let _ = run.reply.send(Err(msg));
            return;
        }
        let t0 = Instant::now();
        let a0 = if obs::enabled() { obs::now_ns() } else { 0 };
        let plane = run.plan.plane();
        let mut field = take_field(&mut st.src);
        for (shard, slab) in run.plan.shards().iter().zip(&st.slabs) {
            let (a, b) = shard.rows();
            field[a * plane..b * plane]
                .copy_from_slice(slab.as_ref().expect("slab returned before barrier"));
        }
        let assembled = t0.elapsed();
        st.metrics.add_scatter(assembled);
        st.metrics.add_phase_assembly(phase_idx, assembled);
        if obs::enabled() {
            obs::record(obs::SpanKind::Assembly, a0, obs::now_ns(), obs::Payload::None);
        }
        if st.phase + 1 < run.phases.len() {
            st.src = Arc::new(field);
            st.phase += 1;
            st.pending = run.shard_count();
            st.first_done_ns = u64::MAX;
            drop(st);
            queue.push_internal(ShardedRun::fan_out(run));
            return;
        }
        // ---- final phase: write back, account, reply ----
        st.metrics.wall_ns = run.started.elapsed().as_nanos() as u64;
        let metrics = st.metrics.clone();
        drop(st);
        {
            let mut g = run.session.lock().unwrap();
            g.field = field;
            g.busy = false;
            g.stats.record_run(&metrics);
        }
        run.counters.record_run(&metrics);
        let _ = run.reply.send(Ok(metrics));
    }
}

/// Swap the shared source out of the state, reclaiming the buffer
/// without a copy when (as at every barrier) no task still holds it.
fn take_field(src: &mut Arc<Vec<f64>>) -> Vec<f64> {
    let n = src.len();
    match Arc::try_unwrap(std::mem::replace(src, Arc::new(Vec::new()))) {
        Ok(v) => v,
        Err(shared) => {
            // Defensive: a straggling handle forces one copy.
            let mut v = vec![0.0; n];
            v.copy_from_slice(&shared);
            v
        }
    }
}

/// Fixed set of worker threads draining a shared [`JobQueue`].
pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn start(
        workers: usize,
        queue: Arc<JobQueue>,
        counters: Arc<ServiceCounters>,
    ) -> WorkerPool {
        let handles = (0..workers.max(1))
            .map(|i| {
                let queue = queue.clone();
                let counters = counters.clone();
                std::thread::Builder::new()
                    .name(format!("stencil-worker-{i}"))
                    .spawn(move || {
                        // Worker 0 is the handler/main thread; pool
                        // workers tag themselves 1..=N for span tracks.
                        obs::set_worker(i + 1);
                        while let Some(task) = queue.pop() {
                            match task {
                                Task::Job(q) => {
                                    let _in_trace = obs::trace_scope(q.trace);
                                    let popped = obs::now_ns();
                                    obs::metrics()
                                        .queue_wait_ns
                                        .observe(popped.saturating_sub(q.queued_ns) as f64);
                                    if obs::enabled() {
                                        obs::record(
                                            obs::SpanKind::QueueWait,
                                            q.queued_ns,
                                            popped,
                                            obs::Payload::Queue {
                                                depth: queue.depth() as u64,
                                            },
                                        );
                                    }
                                    let res = execute(&q);
                                    match &res {
                                        Ok(m) => counters.record_run(m),
                                        Err(_) => {
                                            ServiceCounters::bump(&counters.jobs_failed)
                                        }
                                    }
                                    // A vanished receiver (client gone) is fine.
                                    let _ = q.reply.send(res);
                                }
                                Task::Batch(b) => run_batch(b, &queue, &counters),
                                Task::Shard(run, idx) => {
                                    ShardedRun::run_shard(&run, &queue, idx)
                                }
                                Task::Retune(rt) => rt.run(),
                            }
                        }
                    })
                    .expect("spawn service worker")
            })
            .collect();
        WorkerPool { handles }
    }

    /// Wait for every worker to drain and exit (close the queue first).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Run one monolithic job against its session's resident field.
fn execute(q: &QueuedJob) -> Result<RunMetrics, String> {
    // `auto` can only ever resolve to native when PJRT is unreachable —
    // skip backend::create's per-job manifest probe in that case.
    let kind = match q.kind {
        backend::BackendKind::Auto if !q.pjrt_possible => backend::BackendKind::Native,
        k => k,
    };
    let mut be = backend::create(kind, &q.artifacts_dir, &q.job, None)
        .map_err(|e| format!("{e:#}"))?;
    advance_member(be.as_mut(), q)
}

/// Advance one (possibly batched) member against its session under the
/// session lock, restoring a spilled field first when tiering is on.
fn advance_member(
    be: &mut dyn backend::Backend,
    q: &QueuedJob,
) -> Result<RunMetrics, String> {
    let mut s = q.session.lock().unwrap();
    if s.busy {
        return Err("session busy: a sharded advance is in flight".to_string());
    }
    if let Some(store) = &q.store {
        store.ensure_resident(&mut s).map_err(|e| format!("{e:#}"))?;
        store.touch(&mut s);
    }
    let m = be.advance(&q.job, &mut s.field).map_err(|e| format!("{e:#}"))?;
    s.stats.record_run(&m);
    Ok(m)
}

/// Run a coalesced batch: one backend resolution (one kernel
/// compilation) shared by every member.  Identical `PlanKey`s mean
/// identical kernel-selection axes; weights and fields are per-advance
/// arguments, so executing members back-to-back in arrival order is
/// bit-identical to running them sequentially unbatched.
fn run_batch(b: BatchRun, queue: &JobQueue, counters: &Arc<ServiceCounters>) {
    if b.members.is_empty() {
        return;
    }
    let b0 = if obs::enabled() { obs::now_ns() } else { 0 };
    let jobs = b.members.len() as u64;
    let lead_trace = b.members[0].trace;
    let first = &b.members[0];
    let kind = match first.kind {
        backend::BackendKind::Auto if !first.pjrt_possible => backend::BackendKind::Native,
        k => k,
    };
    match backend::create(kind, &first.artifacts_dir, &first.job, None) {
        Err(e) => {
            let msg = format!("{e:#}");
            for q in &b.members {
                ServiceCounters::bump(&counters.jobs_failed);
                let _ = q.reply.send(Err(msg.clone()));
            }
        }
        Ok(mut be) => {
            for q in &b.members {
                let _in_trace = obs::trace_scope(q.trace);
                let popped = obs::now_ns();
                obs::metrics().queue_wait_ns.observe(popped.saturating_sub(q.queued_ns) as f64);
                if obs::enabled() {
                    obs::record(
                        obs::SpanKind::QueueWait,
                        q.queued_ns,
                        popped,
                        obs::Payload::Queue { depth: queue.depth() as u64 },
                    );
                }
                let res = advance_member(be.as_mut(), q);
                match &res {
                    Ok(m) => counters.record_run(m),
                    Err(_) => ServiceCounters::bump(&counters.jobs_failed),
                }
                let _ = q.reply.send(res);
            }
        }
    }
    if obs::enabled() {
        let _in_trace = obs::trace_scope(lead_trace);
        obs::record(
            obs::SpanKind::Batch,
            b0,
            obs::now_ns(),
            obs::Payload::Batch { jobs, key: b.key },
        );
    }
}

/// Minimal [`QueuedJob`] construction for sibling modules' unit tests
/// (the batch gate's settle/dispatch bookkeeping needs real jobs).
#[cfg(test)]
pub mod test_support {
    use super::*;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};
    use crate::service::protocol::{FieldInit, JobSpec};

    pub fn queued_job(reply: mpsc::Sender<Result<RunMetrics, String>>) -> QueuedJob {
        let spec = JobSpec {
            pattern: StencilPattern::new(Shape::Star, 2, 1).unwrap(),
            dtype: Dtype::F64,
            domain: vec![8, 8],
            steps: 1,
            t: None,
            backend: backend::BackendKind::Native,
            temporal: backend::TemporalMode::Auto,
            shards: crate::coordinator::grid::ShardSpec::Auto,
            threads: 1,
            weights: None,
            tenant: "default".to_string(),
            deadline_ms: None,
        };
        let session =
            Arc::new(Mutex::new(Session::create("ts", &spec, &FieldInit::Zeros).unwrap()));
        let job = backend::Job {
            pattern: spec.pattern,
            dtype: spec.dtype,
            domain: spec.domain.clone(),
            steps: 1,
            t: 1,
            temporal: backend::TemporalMode::Sweep,
            weights: Default::default(),
            threads: 1,
        };
        QueuedJob {
            session,
            tenant: "default".to_string(),
            store: None,
            job,
            kind: backend::BackendKind::Native,
            pjrt_possible: false,
            artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
            reply,
            trace: 0,
            queued_ns: obs::now_ns(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::coordinator::grid::ShardSpec;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};
    use crate::service::protocol::{FieldInit, JobSpec};
    use crate::sim::golden;

    fn sess(domain: Vec<usize>) -> Arc<Mutex<Session>> {
        let spec = JobSpec {
            pattern: StencilPattern::new(Shape::Star, domain.len(), 1).unwrap(),
            dtype: Dtype::F64,
            domain,
            steps: 2,
            t: None,
            backend: BackendKind::Native,
            temporal: backend::TemporalMode::Sweep,
            shards: ShardSpec::Auto,
            threads: 1,
            weights: None,
            tenant: "default".into(),
            deadline_ms: None,
        };
        Arc::new(Mutex::new(Session::create("q", &spec, &FieldInit::Gaussian).unwrap()))
    }

    fn qjob(
        session: &Arc<Mutex<Session>>,
        reply: mpsc::Sender<Result<RunMetrics, String>>,
    ) -> QueuedJob {
        let s = session.lock().unwrap();
        QueuedJob {
            job: backend::Job {
                pattern: s.pattern,
                dtype: s.dtype,
                domain: s.domain.clone(),
                steps: 2,
                t: 1,
                temporal: backend::TemporalMode::Sweep,
                weights: s.weights.clone(),
                threads: 1,
            },
            kind: BackendKind::Native,
            pjrt_possible: false,
            artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
            session: session.clone(),
            tenant: "default".to_string(),
            store: None,
            reply,
            trace: 0,
            queued_ns: obs::now_ns(),
        }
    }

    fn sharded_run(
        session: &Arc<Mutex<Session>>,
        steps: usize,
        t: usize,
        temporal: backend::TemporalMode,
        shards: usize,
        counters: Arc<ServiceCounters>,
        reply: mpsc::Sender<Result<RunMetrics, String>>,
    ) -> Arc<ShardedRun> {
        let (job, plan, field) = {
            let mut g = session.lock().unwrap();
            let job = backend::Job {
                pattern: g.pattern,
                dtype: g.dtype,
                domain: g.domain.clone(),
                steps,
                t,
                temporal,
                weights: g.weights.clone(),
                threads: 1,
            };
            let plan = ShardPlan::dim0(&g.domain, shards, g.pattern.r, t).unwrap();
            g.busy = true;
            let field = std::mem::take(&mut g.field);
            (job, plan, field)
        };
        Arc::new(ShardedRun::new(session.clone(), job, plan, field, reply, counters))
    }

    #[test]
    fn bounded_push_sheds_with_depth_and_close_refuses() {
        let queue = JobQueue::new(1);
        let s = sess(vec![6, 6]);
        let (tx, _rx) = mpsc::channel();
        assert!(queue.push(Task::Job(qjob(&s, tx.clone()))).is_ok());
        assert_eq!(
            queue.push(Task::Job(qjob(&s, tx.clone()))).unwrap_err(),
            PushError::Full { depth: 1, cap: 1 }
        );
        assert_eq!(queue.depth(), 1);
        queue.close();
        assert_eq!(queue.push(Task::Job(qjob(&s, tx))).unwrap_err(), PushError::Closed);
        // closed queue still drains, then pops None
        assert!(queue.pop().is_some());
        assert!(queue.pop().is_none());
    }

    #[test]
    fn maintenance_push_is_capacity_exempt_but_respects_close() {
        let queue = JobQueue::new(1);
        let s = sess(vec![6, 6]);
        let (tx, _rx) = mpsc::channel();
        queue.push(Task::Job(qjob(&s, tx.clone()))).unwrap();
        // capacity full: a normal push sheds…
        assert!(matches!(
            queue.push(Task::Job(qjob(&s, tx))).unwrap_err(),
            PushError::Full { .. }
        ));
        // …but a retune rides in anyway (serving from wrong constants
        // is worse than one extra queued task)
        let hub = Arc::new(crate::tune::drift::ProfileHub::new(
            crate::engines::builtin_profile(&crate::hardware::Gpu::a100()),
            0.25,
        ));
        let plans = Arc::new(super::super::plan_cache::PlanCache::new(4));
        let rt = || {
            Task::Retune(RetuneTask {
                hub: hub.clone(),
                plans: plans.clone(),
                opts: crate::tune::micro::MicroOpts::quick(),
                cause: "ewma_crossing".to_string(),
            })
        };
        assert!(queue.push_maintenance(rt()).is_ok());
        assert_eq!(queue.depth(), 2);
        queue.close();
        assert_eq!(queue.push_maintenance(rt()).unwrap_err(), PushError::Closed);
    }

    #[test]
    fn batch_admission_is_all_or_nothing() {
        let queue = JobQueue::new(3);
        let s = sess(vec![8, 8]);
        let counters = Arc::new(ServiceCounters::default());
        let (tx, _rx) = mpsc::channel();
        let run = sharded_run(&s, 2, 1, backend::TemporalMode::Sweep, 2, counters, tx.clone());
        assert!(queue.push_batch(ShardedRun::fan_out(&run)).is_ok());
        assert_eq!(queue.depth(), 2);
        // a 2-task batch no longer fits a 3-cap queue holding 2
        let s2 = sess(vec![8, 8]);
        let c2 = Arc::new(ServiceCounters::default());
        let run2 = sharded_run(&s2, 2, 1, backend::TemporalMode::Sweep, 2, c2, tx);
        assert_eq!(
            queue.push_batch(ShardedRun::fan_out(&run2)).unwrap_err(),
            PushError::Full { depth: 2, cap: 3 }
        );
        assert_eq!(queue.depth(), 2, "refused batch admits nothing");
        run2.abort_admission();
        let g = run2.session.lock().unwrap();
        assert!(!g.busy);
        assert_eq!(g.field.len(), 64, "field restored on refusal");
    }

    #[test]
    fn batched_members_match_sequential_and_depth_is_job_weighted() {
        // Three identical-PlanKey sessions advanced as one Task::Batch
        // must be bit-identical to the same three advanced one by one.
        let mk = || sess(vec![9, 7]);
        let (b1, b2, b3) = (mk(), mk(), mk());
        let (u1, u2, u3) = (mk(), mk(), mk());
        let (tx, rx) = mpsc::channel();
        let queue = Arc::new(JobQueue::new(16));
        let batch = BatchRun {
            members: vec![qjob(&b1, tx.clone()), qjob(&b2, tx.clone()), qjob(&b3, tx.clone())],
            key: "test-key".into(),
        };
        queue.push(Task::Batch(batch)).unwrap();
        assert_eq!(queue.depth(), 3, "a coalesced batch counts its members");
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(1, queue.clone(), counters.clone());
        for _ in 0..3 {
            rx.recv().unwrap().unwrap();
        }
        // unbatched reference runs
        for s in [&u1, &u2, &u3] {
            queue.push(Task::Job(qjob(s, tx.clone()))).unwrap();
        }
        for _ in 0..3 {
            rx.recv().unwrap().unwrap();
        }
        queue.close();
        pool.join();
        for (b, u) in [(&b1, &u1), (&b2, &u2), (&b3, &u3)] {
            let (bg, ug) = (b.lock().unwrap(), u.lock().unwrap());
            for (i, (x, y)) in bg.field.iter().zip(&ug.field).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "point {i}");
            }
            assert_eq!(bg.stats.jobs, 1);
        }
        assert_eq!(counters.snapshot().jobs_completed, 6);
    }

    #[test]
    fn urgent_tier_pops_in_deadline_order_before_fifo() {
        let queue = JobQueue::new(8);
        let s = sess(vec![6, 6]);
        let (tx, _rx) = mpsc::channel();
        queue.push(Task::Job(qjob(&s, tx.clone()))).unwrap(); // FIFO
        let tag = |t: Task| match t {
            Task::Job(q) => q.job.steps,
            _ => panic!("expected job"),
        };
        let mut late = qjob(&s, tx.clone());
        late.job.steps = 90;
        let mut soon = qjob(&s, tx.clone());
        soon.job.steps = 91;
        queue.push_urgent(Task::Job(late), 5_000).unwrap();
        queue.push_urgent(Task::Job(soon), 1_000).unwrap();
        assert_eq!(queue.depth(), 3);
        assert_eq!(tag(queue.pop().unwrap()), 91, "earliest deadline first");
        assert_eq!(tag(queue.pop().unwrap()), 90, "then the later deadline");
        assert_eq!(tag(queue.pop().unwrap()), 2, "FIFO drains last");
        // urgent pushes respect capacity and the closed flag
        let tiny = JobQueue::new(1);
        tiny.push(Task::Job(qjob(&s, tx.clone()))).unwrap();
        assert_eq!(
            tiny.push_urgent(Task::Job(qjob(&s, tx.clone())), 1).unwrap_err(),
            PushError::Full { depth: 1, cap: 1 }
        );
        tiny.close();
        assert_eq!(
            tiny.push_urgent(Task::Job(qjob(&s, tx)), 1).unwrap_err(),
            PushError::Closed
        );
    }

    #[test]
    fn workers_execute_and_reply_with_metrics() {
        let queue = Arc::new(JobQueue::new(8));
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(2, queue.clone(), counters.clone());
        let s = sess(vec![8, 8]);
        let (tx, rx) = mpsc::channel();
        queue.push(Task::Job(qjob(&s, tx.clone()))).unwrap();
        queue.push(Task::Job(qjob(&s, tx))).unwrap();
        let m1 = rx.recv().unwrap().unwrap();
        let m2 = rx.recv().unwrap().unwrap();
        assert_eq!(m1.steps, 2);
        assert_eq!(m2.points, 64);
        queue.close();
        pool.join();
        let snap = counters.snapshot();
        assert_eq!(snap.jobs_completed, 2);
        assert_eq!(snap.steps_total, 4);
        let g = s.lock().unwrap();
        assert_eq!(g.stats.jobs, 2);
        assert_eq!(g.stats.steps, 4);
    }

    #[test]
    fn sharded_fanout_runs_on_the_pool_and_matches_golden() {
        // 3 shards × (2 fused t=2 launches + 1 base step) across 2
        // workers: the result must be bit-identical to the golden
        // fused chain, metrics aggregated job-level, session restored.
        let queue = Arc::new(JobQueue::new(16));
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(2, queue.clone(), counters.clone());
        let s = sess(vec![10, 7]);
        let init = s.lock().unwrap().field.clone();
        let (tx, rx) = mpsc::channel();
        let run =
            sharded_run(&s, 5, 2, backend::TemporalMode::Sweep, 3, counters.clone(), tx);
        assert_eq!(run.shard_count(), 3);
        assert_eq!(run.phase_count(), 3);
        queue.push_batch(ShardedRun::fan_out(&run)).unwrap();
        let m = rx.recv().unwrap().unwrap();
        assert_eq!(m.steps, 5);
        assert_eq!(m.points, 70);
        // 3 phases × 3 shards, one launch each
        assert_eq!(m.launches, 9);
        assert!(m.bytes_moved > 0 && m.flops > 0);
        queue.close();
        pool.join();
        // golden replay: 2 fused t=2 launches + 1 base step
        let p = StencilPattern::new(Shape::Star, 2, 1).unwrap();
        let w = golden::Weights::new(2, 3, p.uniform_weights());
        let mut want = golden::Field::from_vec(&[10, 7], init);
        for _ in 0..2 {
            want = golden::apply_fused(&want, &w, 2);
        }
        want = golden::apply_once(&want, &w);
        let g = s.lock().unwrap();
        assert!(!g.busy);
        assert_eq!(g.stats.jobs, 1);
        assert_eq!(g.stats.steps, 5);
        for (i, (a, b)) in g.field.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "point {i}");
        }
        assert_eq!(counters.snapshot().jobs_completed, 1);
    }

    #[test]
    fn sharded_blocked_run_is_sequential_semantics_even_on_one_worker() {
        // One worker must still drain all phases (event-driven barrier,
        // no cross-task blocking): blocked t=3 over 7 steps, 4 shards.
        let queue = Arc::new(JobQueue::new(8));
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(1, queue.clone(), counters.clone());
        let s = sess(vec![9, 6]);
        let init = s.lock().unwrap().field.clone();
        let (tx, rx) = mpsc::channel();
        let run =
            sharded_run(&s, 7, 3, backend::TemporalMode::Blocked, 4, counters, tx);
        queue.push_batch(ShardedRun::fan_out(&run)).unwrap();
        let m = rx.recv().unwrap().unwrap();
        assert_eq!(m.steps, 7);
        queue.close();
        pool.join();
        let p = StencilPattern::new(Shape::Star, 2, 1).unwrap();
        let w = golden::Weights::new(2, 3, p.uniform_weights());
        let want = golden::apply_steps(&golden::Field::from_vec(&[9, 6], init), &w, 7);
        let g = s.lock().unwrap();
        for (i, (a, b)) in g.field.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "point {i}");
        }
    }

    #[test]
    fn monolithic_job_on_busy_session_reports_cleanly() {
        let s = sess(vec![8, 8]);
        s.lock().unwrap().busy = true;
        let (tx, rx) = mpsc::channel();
        let queue = Arc::new(JobQueue::new(4));
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(1, queue.clone(), counters.clone());
        queue.push(Task::Job(qjob(&s, tx))).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("busy"), "{err}");
        queue.close();
        pool.join();
        assert_eq!(counters.snapshot().jobs_failed, 1);
    }

    #[test]
    fn failed_jobs_report_the_reason() {
        let queue = Arc::new(JobQueue::new(8));
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(1, queue.clone(), counters.clone());
        let s = sess(vec![8, 8]);
        let (tx, rx) = mpsc::channel();
        let mut bad = qjob(&s, tx);
        bad.job.weights = vec![0.0; 3]; // hull-size mismatch
        queue.push(Task::Job(bad)).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("weights"), "{err}");
        queue.close();
        pool.join();
        assert_eq!(counters.snapshot().jobs_failed, 1);
        assert_eq!(s.lock().unwrap().stats.jobs, 0);
    }

    #[test]
    fn failed_shard_poisons_the_run_and_restores_the_session() {
        let queue = Arc::new(JobQueue::new(8));
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(2, queue.clone(), counters.clone());
        let s = sess(vec![8, 8]);
        let init = s.lock().unwrap().field.clone();
        let (tx, rx) = mpsc::channel();
        let run = sharded_run(&s, 4, 2, backend::TemporalMode::Blocked, 2, counters.clone(), tx);
        // sabotage: wrong weights hull → every advance_shard errors
        let bad = Arc::new(ShardedRun::new(
            run.session.clone(),
            {
                let mut j = run.job.clone();
                j.weights = vec![0.0; 3];
                j
            },
            run.plan.clone(),
            {
                // move the field from the good run into the bad one
                let mut st = run.state.lock().unwrap();
                take_field(&mut st.src)
            },
            run.reply.clone(),
            counters.clone(),
        ));
        queue.push_batch(ShardedRun::fan_out(&bad)).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("weights"), "{err}");
        queue.close();
        pool.join();
        let g = s.lock().unwrap();
        assert!(!g.busy, "session must be released");
        assert_eq!(g.field, init, "phase-start field restored");
        assert_eq!(counters.snapshot().jobs_failed, 1);
        assert_eq!(g.stats.jobs, 0);
    }
}

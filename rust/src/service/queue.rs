//! The bounded job queue + worker pool.
//!
//! Connection handlers enqueue [`QueuedJob`]s without blocking —
//! a full queue is load-shedding feedback, not backpressure-by-hanging
//! — and wait on a per-job reply channel.  Workers pop jobs, resolve a
//! backend through the existing [`Backend`](crate::backend::Backend)
//! trait, advance the session's resident field, and send the per-job
//! [`RunMetrics`] back.  Closing the queue wakes every worker; they
//! drain what was admitted and exit.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::backend;
use crate::coordinator::metrics::{RunMetrics, ServiceCounters};

use super::session::Session;

/// One admitted job, bound to its session and reply channel.
pub struct QueuedJob {
    pub session: Arc<Mutex<Session>>,
    pub job: backend::Job,
    pub kind: backend::BackendKind,
    /// Whether a PJRT resolution can possibly succeed (manifest present
    /// + pjrt-enabled binary).  When false, `auto` jobs go straight to
    /// the native backend instead of re-probing the artifact dir on
    /// disk for every job on the hot serving path.
    pub pjrt_possible: bool,
    pub artifacts_dir: PathBuf,
    /// Worker → connection handler result channel (the job's metrics,
    /// or the execution error as a rendered string).
    pub reply: mpsc::Sender<Result<RunMetrics, String>>,
}

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// At capacity — the caller should shed the job.
    Full,
    /// Shutting down — no new work is admitted.
    Closed,
}

#[derive(Default)]
struct Inner {
    jobs: VecDeque<QueuedJob>,
    open: bool,
}

/// Bounded MPMC job queue (Mutex + Condvar; std only).
pub struct JobQueue {
    cap: usize,
    inner: Mutex<Inner>,
    ready: Condvar,
}

impl JobQueue {
    pub fn new(cap: usize) -> JobQueue {
        JobQueue {
            cap: cap.max(1),
            inner: Mutex::new(Inner { jobs: VecDeque::new(), open: true }),
            ready: Condvar::new(),
        }
    }

    /// Non-blocking admission; the job is dropped on refusal (its reply
    /// sender with it, so nobody ends up waiting on a dead channel).
    pub fn push(&self, j: QueuedJob) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if !g.open {
            return Err(PushError::Closed);
        }
        if g.jobs.len() >= self.cap {
            return Err(PushError::Full);
        }
        g.jobs.push_back(j);
        drop(g);
        self.ready.notify_one();
        Ok(())
    }

    /// Blocking worker pop; `None` once closed and drained.
    pub fn pop(&self) -> Option<QueuedJob> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(j) = g.jobs.pop_front() {
                return Some(j);
            }
            if !g.open {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Stop admitting; wake every worker so the pool can drain and exit.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.open = false;
        drop(g);
        self.ready.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }
}

/// Fixed set of worker threads draining a shared [`JobQueue`].
pub struct WorkerPool {
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn start(
        workers: usize,
        queue: Arc<JobQueue>,
        counters: Arc<ServiceCounters>,
    ) -> WorkerPool {
        let handles = (0..workers.max(1))
            .map(|i| {
                let queue = queue.clone();
                let counters = counters.clone();
                std::thread::Builder::new()
                    .name(format!("stencil-worker-{i}"))
                    .spawn(move || {
                        while let Some(q) = queue.pop() {
                            let res = execute(&q);
                            match &res {
                                Ok(m) => counters.record_run(m),
                                Err(_) => ServiceCounters::bump(&counters.jobs_failed),
                            }
                            // A vanished receiver (client gone) is fine.
                            let _ = q.reply.send(res);
                        }
                    })
                    .expect("spawn service worker")
            })
            .collect();
        WorkerPool { handles }
    }

    /// Wait for every worker to drain and exit (close the queue first).
    pub fn join(self) {
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Run one job against its session's resident field.
fn execute(q: &QueuedJob) -> Result<RunMetrics, String> {
    // `auto` can only ever resolve to native when PJRT is unreachable —
    // skip backend::create's per-job manifest probe in that case.
    let kind = match q.kind {
        backend::BackendKind::Auto if !q.pjrt_possible => backend::BackendKind::Native,
        k => k,
    };
    let mut be = backend::create(kind, &q.artifacts_dir, &q.job, None)
        .map_err(|e| format!("{e:#}"))?;
    let mut s = q.session.lock().unwrap();
    let m = be.advance(&q.job, &mut s.field).map_err(|e| format!("{e:#}"))?;
    s.stats.record_run(&m);
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};
    use crate::service::protocol::{FieldInit, JobSpec};

    fn sess(domain: Vec<usize>) -> Arc<Mutex<Session>> {
        let spec = JobSpec {
            pattern: StencilPattern::new(Shape::Star, domain.len(), 1).unwrap(),
            dtype: Dtype::F64,
            domain,
            steps: 2,
            t: None,
            backend: BackendKind::Native,
            temporal: backend::TemporalMode::Sweep,
            threads: 1,
            weights: None,
        };
        Arc::new(Mutex::new(Session::create("q", &spec, &FieldInit::Gaussian).unwrap()))
    }

    fn qjob(
        session: &Arc<Mutex<Session>>,
        reply: mpsc::Sender<Result<RunMetrics, String>>,
    ) -> QueuedJob {
        let s = session.lock().unwrap();
        QueuedJob {
            job: backend::Job {
                pattern: s.pattern,
                dtype: s.dtype,
                domain: s.domain.clone(),
                steps: 2,
                t: 1,
                temporal: backend::TemporalMode::Sweep,
                weights: s.weights.clone(),
                threads: 1,
            },
            kind: BackendKind::Native,
            pjrt_possible: false,
            artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
            session: session.clone(),
            reply,
        }
    }

    #[test]
    fn bounded_push_sheds_and_close_refuses() {
        let queue = JobQueue::new(1);
        let s = sess(vec![6, 6]);
        let (tx, _rx) = mpsc::channel();
        assert!(queue.push(qjob(&s, tx.clone())).is_ok());
        assert_eq!(queue.push(qjob(&s, tx.clone())).unwrap_err(), PushError::Full);
        assert_eq!(queue.depth(), 1);
        queue.close();
        assert_eq!(queue.push(qjob(&s, tx)).unwrap_err(), PushError::Closed);
        // closed queue still drains, then pops None
        assert!(queue.pop().is_some());
        assert!(queue.pop().is_none());
    }

    #[test]
    fn workers_execute_and_reply_with_metrics() {
        let queue = Arc::new(JobQueue::new(8));
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(2, queue.clone(), counters.clone());
        let s = sess(vec![8, 8]);
        let (tx, rx) = mpsc::channel();
        queue.push(qjob(&s, tx.clone())).unwrap();
        queue.push(qjob(&s, tx)).unwrap();
        let m1 = rx.recv().unwrap().unwrap();
        let m2 = rx.recv().unwrap().unwrap();
        assert_eq!(m1.steps, 2);
        assert_eq!(m2.points, 64);
        queue.close();
        pool.join();
        let snap = counters.snapshot();
        assert_eq!(snap.jobs_completed, 2);
        assert_eq!(snap.steps_total, 4);
        let g = s.lock().unwrap();
        assert_eq!(g.stats.jobs, 2);
        assert_eq!(g.stats.steps, 4);
    }

    #[test]
    fn failed_jobs_report_the_reason() {
        let queue = Arc::new(JobQueue::new(8));
        let counters = Arc::new(ServiceCounters::default());
        let pool = WorkerPool::start(1, queue.clone(), counters.clone());
        let s = sess(vec![8, 8]);
        let (tx, rx) = mpsc::channel();
        let mut bad = qjob(&s, tx);
        bad.job.weights = vec![0.0; 3]; // hull-size mismatch
        queue.push(bad).unwrap();
        let err = rx.recv().unwrap().unwrap_err();
        assert!(err.contains("weights"), "{err}");
        queue.close();
        pool.join();
        assert_eq!(counters.snapshot().jobs_failed, 1);
        assert_eq!(s.lock().unwrap().stats.jobs, 0);
    }
}

//! The daemon: `stencilctl serve`.
//!
//! A [`Service`] owns the shared [`ServiceState`] (session store, plan
//! cache, bounded queue, counters) and the worker pool.  Frontends are
//! interchangeable transports over the same NDJSON handler:
//!
//! * [`Service::serve_stdio`] — one connection on stdin/stdout (tests,
//!   smoke checks, `popen`-style embedding);
//! * [`Service::serve_tcp`] — a localhost/network listener, one thread
//!   per connection, all sharing the state.
//!
//! Every request line flows through [`handle_line`]: parse →
//! plan-through-cache → model-guided admission → queue → reply.  The
//! connection thread blocks on the job's reply channel, so each client
//! sees strictly ordered responses while jobs from different clients
//! execute concurrently on the worker pool.
//!
//! Multi-tenant serving (this layer's scale story) adds three planes:
//!
//! * **batching** — concurrent `advance`s with identical
//!   [`PlanKey`](crate::coordinator::planner::PlanKey)s coalesce at
//!   the [`BatchGate`](super::batch::BatchGate): one shared plan
//!   lookup, one `Task::Batch` dispatch, per-job metrics, bit-exact;
//! * **fairness/SLO** — after the per-job budget check,
//!   [`TenantSched`](super::admission::TenantSched) runs
//!   deficit-round-robin over roofline cost (`fair_share` refusals
//!   under pressure) and an EDF deadline tier (`deadline_unmeetable`
//!   refusals carry the predicted completion as evidence);
//! * **tiering** — under `--resident-bytes`, idle sessions spill to
//!   disk via the lossless hex-f64 codec and restore transparently
//!   ([`SessionStore::enforce`]).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, bail, Result};

use crate::backend;
use crate::coordinator::grid::{ShardPlan, ShardSpec};
use crate::coordinator::metrics::{RunMetrics, ServiceCounters, TenantLedger};
use crate::coordinator::planner::{self, Plan, PlanKey};
use crate::hardware::Gpu;
use crate::model::perf::Unit;
use crate::obs;
use crate::report;
use crate::runtime::manifest::Manifest;
use crate::tune::drift::{self, ProfileHub, RetuneMode};
use crate::tune::micro::MicroOpts;
use crate::tune::profile::MachineProfile;
use crate::util::json::Json;

use super::admission::{self, Decision, TenantSched, TenantVerdict};
use super::batch::{self, BatchGate};
use super::plan_cache::PlanCache;
use super::protocol::{self, JobSpec, Obj, Request};
use super::queue::{
    BatchRun, JobQueue, PushError, QueuedJob, RetuneTask, ShardedRun, Task, WorkerPool,
};
use super::session::{Session, SessionStore};

/// Daemon configuration (`stencilctl serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// TCP listen address (`--addr`); port 0 = ephemeral.
    pub addr: String,
    /// Worker threads draining the job queue (`--workers`).
    pub workers: usize,
    /// Bounded queue capacity (`--max-queue`).
    pub max_queue: usize,
    /// Admission budget in predicted milliseconds (`--budget-ms`;
    /// `None` = accept everything).
    pub budget_ms: Option<f64>,
    /// Plan-cache capacity in entries (`--plan-cache`).
    pub plan_cache_cap: usize,
    /// Default temporal strategy for sessions that leave theirs at
    /// `auto` (`--temporal`); `Auto` defers to the planner per job.
    pub temporal: backend::TemporalMode,
    /// Default shard spec for sessions that leave theirs at `auto`
    /// (`--shards`); `Auto` defers to the planner's redundancy-adjusted
    /// gain per job.
    pub shards: ShardSpec,
    pub artifacts_dir: PathBuf,
    /// The machine profile the planner/admission predictions run
    /// against (resolved at startup: `--profile <path>` or the builtin
    /// registry table) — the single source of every 𝔹/ℙ constant the
    /// service plans with.
    pub profile: MachineProfile,
    /// What to do when drift flags the profile (`--retune off|auto`).
    pub retune: RetuneMode,
    /// Per-region EWMA threshold at which `model_err` flags the profile
    /// stale (`--drift-threshold`; defaults to the model's region
    /// tolerance).
    pub drift_threshold: f64,
    /// Threads background recalibration probes run with (the serve
    /// `--threads` flag) — kept equal to what `stencilctl tune
    /// --threads N` would use so an auto-retuned profile is measured
    /// under the same parallelism as an operator-measured one.
    pub probe_threads: usize,
    /// Resident-field byte budget (`--resident-bytes`): when the sum of
    /// in-memory session fields exceeds it, idle sessions spill to disk
    /// LRU-first and restore transparently on next use.  `None` = every
    /// session stays resident.
    pub resident_bytes: Option<u64>,
    /// Batch-coalescing gather window in milliseconds
    /// (`--batch-window-ms`): how long the first arrival for a
    /// `PlanKey` waits for co-batchers before performing the one shared
    /// plan lookup.  0 still coalesces jobs that arrive while the
    /// leader plans, without adding latency.
    pub batch_window_ms: f64,
    /// Alert rule file (`--alert-rules <file>`; JSON array — see
    /// `obs::alert`).  `None` installs the builtin defaults.
    pub alert_rules: Option<PathBuf>,
    /// Event-journal path (`--journal <file>`): append-only NDJSON
    /// forensics (admission refusals, drift flags, retune episodes,
    /// spill/restore, alert transitions).  `None` = no journal, zero
    /// writes.
    pub journal: Option<PathBuf>,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            addr: "127.0.0.1:7141".to_string(),
            workers: 2,
            max_queue: 64,
            budget_ms: None,
            plan_cache_cap: 128,
            temporal: backend::TemporalMode::Auto,
            shards: ShardSpec::Auto,
            artifacts_dir: crate::runtime::manifest::default_dir(),
            profile: crate::engines::builtin_profile(&Gpu::a100()),
            retune: RetuneMode::Off,
            drift_threshold: drift::DRIFT_THRESHOLD,
            probe_threads: 4,
            resident_bytes: None,
            batch_window_ms: 0.0,
            alert_rules: None,
            journal: None,
        }
    }
}

/// Everything a connection handler or worker can reach.
pub struct ServiceState {
    pub opts: ServeOpts,
    pub sessions: Arc<SessionStore>,
    pub plans: Arc<PlanCache>,
    pub counters: Arc<ServiceCounters>,
    /// The live machine profile + drift tracker every planning decision
    /// resolves its constants from.
    pub profile: Arc<ProfileHub>,
    /// Per-tenant admitted/refused/deadline-missed accounting.
    pub tenants: TenantLedger,
    /// DRR fair-share + EDF deadline admission (roofline-cost currency).
    pub sched: TenantSched,
    /// PlanKey-coalescing gate for batched dispatch.
    batches: BatchGate,
    /// Declarative alert rules with firing/resolved state, evaluated
    /// lazily on the `stats`/`metrics`/`alerts` verbs.
    pub alerts: obs::alert::AlertEngine,
    /// Per-region model-error attribution aggregates (obs-enabled runs
    /// only; see `obs::attrib`).
    pub attrib: obs::attrib::AttribStore,
    queue: Arc<JobQueue>,
    manifest: Option<Manifest>,
    shutdown: AtomicBool,
}

impl ServiceState {
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Flip the shutdown flag and close the queue (workers drain+exit).
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.depth()
    }
}

/// The long-lived daemon: shared state + worker pool.
pub struct Service {
    state: Arc<ServiceState>,
    pool: Option<WorkerPool>,
}

impl Service {
    /// Build the state and start the worker pool (no I/O yet).
    pub fn start(opts: ServeOpts) -> Service {
        let manifest = Manifest::load(&opts.artifacts_dir).ok();
        let queue = Arc::new(JobQueue::new(opts.max_queue));
        let counters = Arc::new(ServiceCounters::default());
        let workers = opts.workers.max(1);
        let profile = Arc::new(ProfileHub::new(opts.profile.clone(), opts.drift_threshold));
        let sessions = Arc::new(match opts.resident_bytes {
            Some(cap) => SessionStore::with_tiering(spill_dir(), cap),
            None => SessionStore::new(),
        });
        if let Some(path) = &opts.journal {
            if let Err(e) = obs::journal::open(path, obs::journal::DEFAULT_MAX_BYTES) {
                eprintln!("stencilctl serve: cannot open journal: {e:#}");
            }
        }
        let rules = match &opts.alert_rules {
            Some(path) => std::fs::read_to_string(path)
                .map_err(anyhow::Error::from)
                .and_then(|text| obs::alert::parse_rules(&text))
                .unwrap_or_else(|e| {
                    eprintln!(
                        "stencilctl serve: bad --alert-rules {}: {e:#}; using builtins",
                        path.display()
                    );
                    obs::alert::builtin_rules()
                }),
            None => obs::alert::builtin_rules(),
        };
        let state = Arc::new(ServiceState {
            sessions,
            plans: Arc::new(PlanCache::new(opts.plan_cache_cap)),
            counters: counters.clone(),
            profile,
            tenants: TenantLedger::default(),
            sched: TenantSched::new(workers),
            batches: BatchGate::new(opts.batch_window_ms),
            alerts: obs::alert::AlertEngine::new(rules),
            attrib: obs::attrib::AttribStore::new(),
            queue: queue.clone(),
            manifest,
            shutdown: AtomicBool::new(false),
            opts,
        });
        let pool = WorkerPool::start(workers, queue, counters);
        Service { state, pool: Some(pool) }
    }

    /// A shared handle to the state (for in-process embedding/tests).
    pub fn state(&self) -> Arc<ServiceState> {
        self.state.clone()
    }

    /// Serve one connection on stdin/stdout until EOF or `shutdown`.
    pub fn serve_stdio(&self) -> Result<()> {
        let stdin = std::io::stdin();
        let stdout = std::io::stdout();
        serve_io(&self.state, stdin.lock(), stdout.lock())
    }

    /// Bind `opts.addr`, returning the listener and its resolved
    /// address (port 0 becomes the ephemeral port actually bound).
    pub fn bind(&self) -> Result<(TcpListener, SocketAddr)> {
        let listener = TcpListener::bind(&self.state.opts.addr)?;
        let addr = listener.local_addr()?;
        Ok((listener, addr))
    }

    /// Bind and serve TCP until a `shutdown` request arrives.
    pub fn serve_tcp(&self) -> Result<()> {
        let (listener, addr) = self.bind()?;
        eprintln!(
            "stencilctl serve: listening on {addr} ({} workers, queue {}, budget {}, \
             profile {}, retune {})",
            self.state.opts.workers,
            self.state.opts.max_queue,
            match self.state.opts.budget_ms {
                Some(ms) => format!("{ms} ms"),
                None => "off".to_string(),
            },
            self.state.opts.profile.identity(),
            self.state.opts.retune.as_str(),
        );
        serve_listener(self.state.clone(), listener)
    }

    /// Stop admitting work, drain the queue, join the workers.
    pub fn shutdown(&mut self) {
        self.state.request_shutdown();
        if let Some(p) = self.pool.take() {
            p.join();
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Accept loop: one handler thread per connection, until shutdown.
/// Handler threads are detached — a client that lingers after shutdown
/// only keeps its own connection alive, never the daemon.
pub fn serve_listener(state: Arc<ServiceState>, listener: TcpListener) -> Result<()> {
    listener.set_nonblocking(true)?;
    while !state.shutdown_requested() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                stream.set_nonblocking(false)?;
                let st = state.clone();
                std::thread::spawn(move || {
                    let reader = BufReader::new(match stream.try_clone() {
                        Ok(s) => s,
                        Err(_) => return,
                    });
                    let _ = serve_io(&st, reader, stream);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Serve one NDJSON connection: request line in, response line out.
pub fn serve_io<R: BufRead, W: Write>(
    state: &Arc<ServiceState>,
    mut reader: R,
    mut writer: W,
) -> Result<()> {
    let mut line = String::new();
    loop {
        line.clear();
        let n = reader.read_line(&mut line)?;
        if n == 0 {
            return Ok(()); // EOF: client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let (resp, keep) = handle_line(state, &line);
        writer.write_all(resp.as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
        if !keep {
            return Ok(());
        }
    }
}

/// Handle one request line; returns `(response line, keep-connection)`.
pub fn handle_line(state: &ServiceState, line: &str) -> (String, bool) {
    ServiceCounters::bump(&state.counters.requests);
    let req = match Json::parse_line(line).and_then(|j| Request::parse(&j)) {
        Ok(r) => r,
        Err(e) => {
            ServiceCounters::bump(&state.counters.errors);
            return (protocol::err("?", "bad_request", &format!("{e:#}")).to_string(), true);
        }
    };
    let op = req.op();
    match handle_request(state, req) {
        Ok((resp, keep)) => (resp.to_string(), keep),
        Err(e) => {
            ServiceCounters::bump(&state.counters.errors);
            (protocol::err(op, "error", &format!("{e:#}")).to_string(), true)
        }
    }
}

/// Per-daemon spill directory: unique per process AND per `Service`
/// instance, so parallel services (tests) never share or delete each
/// other's spill files.
fn spill_dir() -> PathBuf {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let n = SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    std::env::temp_dir().join(format!("stencilctl-spill-{}-{n}", std::process::id()))
}

/// The planner request an `advance`/`plan` resolves through the cache.
/// Split out of [`plan_for`] because its [`PlanKey`] doubles as the
/// batch-coalescing key: the gate must key arrivals *before* any
/// lookup happens.
fn planner_request(
    state: &ServiceState,
    spec: &JobSpec,
    steps: usize,
    t: Option<usize>,
) -> planner::Request {
    // A fan-out is admitted as one atomic batch, so no candidate may
    // propose more shards than --max-queue can hold: clamp the lane
    // budget (bounds Auto enumeration) and any pinned count BEFORE
    // planning, so admission prices exactly the fan-out that will run.
    let queue_cap = state.opts.max_queue.max(1);
    let shards = match spec.shards {
        ShardSpec::Fixed(n) => ShardSpec::Fixed(n.min(queue_cap).max(1)),
        ShardSpec::Auto => ShardSpec::Auto,
    };
    planner::Request {
        pattern: spec.pattern,
        dtype: spec.dtype,
        domain: spec.domain.clone(),
        steps,
        gpu: state.profile.gpu(),
        backend: spec.backend,
        max_t: t.unwrap_or(8).max(1),
        temporal: spec.temporal,
        shards,
        lanes: state.opts.workers.max(1).min(queue_cap),
        threads: spec.threads.max(1),
        kernels: crate::backend::kernels::default_mode(),
        kernel_peaks: state.profile.kernel_peaks(),
    }
}

/// Plan through the shared cache, bumping the hit/miss counters.
/// The shard axis makes planning domain- and parallelism-aware: the
/// serve pool's worker count is the shard lane budget, the session's
/// thread count the monolithic baseline.  The machine constants come
/// from the live profile hub — a retune that installs fresh constants
/// changes every subsequent plan (and cleared the cache when it did).
fn plan_for(
    state: &ServiceState,
    spec: &JobSpec,
    steps: usize,
    t: Option<usize>,
) -> Result<(Arc<Plan>, bool)> {
    // Constants are read from the hub BEFORE planning; if a retune
    // installs a fresh profile while the planner is scoring, the plan
    // we just built (and possibly memoized — a post-install measured
    // profile reuses the same PlanKey gpu identity) was scored under
    // superseded constants.  Detect the generation change, drop the
    // poisoned memo, and re-plan; bounded retries so pathological
    // retune churn degrades to serving one possibly-stale plan
    // uncached rather than looping.
    let mut attempts = 0;
    let p0 = if obs::enabled() { obs::now_ns() } else { 0 };
    loop {
        let hub_gen = state.profile.generation();
        let req = planner_request(state, spec, steps, t);
        let (plan, hit) = state.plans.plan(&req, state.manifest.as_ref())?;
        attempts += 1;
        if state.profile.generation() == hub_gen || attempts >= 3 {
            ServiceCounters::bump(if hit {
                &state.counters.plan_hits
            } else {
                &state.counters.plan_misses
            });
            if obs::enabled() {
                obs::record(
                    obs::SpanKind::PlanLookup,
                    p0,
                    obs::now_ns(),
                    obs::Payload::Plan { key: req.plan_key().canonical(), hit },
                );
            }
            return Ok((plan, hit));
        }
        state.plans.clear();
    }
}

fn handle_request(state: &ServiceState, req: Request) -> Result<(Json, bool)> {
    if state.shutdown_requested() && !matches!(req, Request::Shutdown) {
        return Ok((protocol::err(req.op(), "shutting_down", "service is shutting down"), true));
    }
    match req {
        Request::Ping => Ok((protocol::ok("ping").done(), true)),
        Request::Shutdown => {
            state.request_shutdown();
            Ok((protocol::ok("shutdown").done(), false))
        }
        Request::Plan(spec) => {
            let (plan, hit) = plan_for(state, &spec, spec.steps, spec.t)?;
            let c = &plan.chosen;
            let mut o = protocol::ok("plan")
                .str_("pattern", &spec.pattern.label())
                .str_("dtype", spec.dtype.as_str())
                .str_("engine", c.engine.name)
                .str_("unit", c.engine.unit.as_str())
                .int("t", c.t as u64)
                .str_("temporal", c.temporal.as_str())
                .int("shards", c.shards as u64)
                .str_("target", c.target.as_str())
                .num("gstencils", c.prediction.gstencils())
                .bool_("sweet_spot", c.in_sweet_spot)
                .str_("cache", if hit { "hit" } else { "miss" })
                .int("alternatives", plan.alternatives.len() as u64);
            if let Some(cmp) = &plan.vs_cuda {
                o = o
                    .str_("scenario", &cmp.scenario.label())
                    .num("vs_cuda_ratio", cmp.speedup);
            }
            Ok((o.done(), true))
        }
        Request::CreateSession { session, spec, init } => {
            let mut s = Session::create(&session, &spec, &init)?;
            // The daemon-level --temporal/--shards defaults fill in for
            // sessions that did not pin a strategy themselves.
            if s.temporal == backend::TemporalMode::Auto {
                s.temporal = state.opts.temporal;
            }
            if s.shards == ShardSpec::Auto {
                s.shards = state.opts.shards;
            }
            let points = s.points();
            let label = s.pattern.label();
            state.sessions.create(s)?;
            // A new resident field may push the store over its
            // --resident-bytes cap; idle sessions spill LRU-first.
            state.sessions.enforce();
            Ok((
                protocol::ok("create_session")
                    .str_("session", &session)
                    .str_("pattern", &label)
                    .str_("dtype", spec.dtype.as_str())
                    .int("points", points)
                    .done(),
                true,
            ))
        }
        Request::Advance { session, steps, t, temporal, shards, deadline_ms } => {
            advance(state, &session, steps, t, temporal, shards, deadline_ms)
        }
        Request::Fetch { session, hex } => {
            let sess = state
                .sessions
                .get(&session)
                .ok_or_else(|| anyhow!("unknown session {session:?}"))?;
            let mut g = sess.lock().unwrap();
            if g.busy {
                // The field is checked out into the shard executor —
                // refuse rather than serving the empty placeholder.
                return Ok((
                    protocol::err(
                        "fetch",
                        "session_busy",
                        "a sharded advance is in flight on this session; retry",
                    ),
                    true,
                ));
            }
            // A spilled session restores transparently for the read.
            state.sessions.ensure_resident(&mut g)?;
            state.sessions.touch(&mut g);
            let resp = protocol::ok("fetch")
                .str_("session", &session)
                .int("len", g.field.len() as u64)
                .set("field", protocol::encode_field(&g.field, hex))
                .done();
            drop(g);
            // The restore may have pushed the store back over its cap;
            // this session was just touched, so LRU spills others first.
            state.sessions.enforce();
            Ok((resp, true))
        }
        Request::CloseSession { session } => {
            if let Some(sess) = state.sessions.get(&session) {
                // Deleting a session mid-fan-out would orphan the run
                // (its write-back and stats would land on an
                // unreachable session, and the name could be reused
                // while the old shards still compute) — refuse like
                // fetch does.
                if sess.lock().unwrap().busy {
                    return Ok((
                        protocol::err(
                            "close_session",
                            "session_busy",
                            "a sharded advance is in flight on this session; retry",
                        ),
                        true,
                    ));
                }
            }
            if !state.sessions.remove(&session) {
                bail!("unknown session {session:?}");
            }
            Ok((protocol::ok("close_session").str_("session", &session).done(), true))
        }
        Request::Stats { prom } => Ok((stats_response(state, prom), true)),
        Request::Metrics => {
            let mut snap = state.counters.snapshot();
            snap.profile = state.profile.status();
            snap.queue_depth = state.queue_depth() as u64;
            // Pure read — the delta window belongs to the `stats` op.
            let cache = state.plans.stats();
            let trows = state.tenants.rows(&state.sessions.tenant_bytes());
            let mut text = obs::metrics().exposition(&snap, &cache, &trows);
            // Alert state rides in the scrape: evaluating here is what
            // makes a Prometheus-only deployment see firing rules.
            let rows = evaluate_alerts(state);
            text.push_str(&obs::alert::render_prom(&rows, state.alerts.transitions()));
            Ok((protocol::ok("metrics").str_("exposition", &text).done(), true))
        }
        Request::Alerts => {
            let rows = evaluate_alerts(state);
            let firing = rows.iter().filter(|r| r.firing).count() as u64;
            let arr = Json::Arr(rows.iter().map(alert_row_json).collect());
            Ok((
                protocol::ok("alerts")
                    .int("rules", state.alerts.rules().len() as u64)
                    .int("firing", firing)
                    .int("transitions", state.alerts.transitions())
                    .set("alerts", arr)
                    .done(),
                true,
            ))
        }
    }
}

/// Evaluate the alert rules against a fresh service snapshot (queue
/// fill, per-region drift state, per-tenant SLO burn).  Lazy by
/// design: rules run on the `stats`/`metrics`/`alerts` verbs, never on
/// the job hot path.
fn evaluate_alerts(state: &ServiceState) -> Vec<obs::alert::AlertRow> {
    let threshold = state.profile.threshold();
    let input = obs::alert::EvalInput {
        queue_depth: state.queue_depth() as u64,
        queue_cap: state.opts.max_queue as u64,
        regions: state
            .profile
            .regions()
            .into_iter()
            .map(|r| obs::alert::RegionErr {
                region: r.region,
                ewma: r.ewma,
                threshold,
                over: r.over,
            })
            .collect(),
        tenants: state
            .tenants
            .rows(&state.sessions.tenant_bytes())
            .into_iter()
            .map(|t| obs::alert::TenantSlo {
                tenant: t.tenant,
                admitted: t.admitted,
                deadline_missed: t.deadline_missed,
            })
            .collect(),
    };
    state.alerts.evaluate(&input)
}

fn alert_row_json(r: &obs::alert::AlertRow) -> Json {
    Obj::new()
        .str_("rule", &r.rule)
        .str_("label", &r.label)
        .str_("kind", r.kind)
        .bool_("firing", r.firing)
        .num("value", r.value)
        .num("threshold", r.threshold)
        .done()
}

/// The full `advance` path: plan (coalesced across identical-PlanKey
/// concurrent jobs) → budget admission → tenant fair-share/deadline
/// admission → dispatch (shard fan-out, coalesced batch, or EDF-tier
/// solo job) → await metrics → model-feedback (predicted vs. achieved
/// intensity).
#[allow(clippy::too_many_arguments)]
fn advance(
    state: &ServiceState,
    session: &str,
    steps: usize,
    t: Option<usize>,
    temporal: Option<backend::TemporalMode>,
    shards_override: Option<ShardSpec>,
    deadline_ms: Option<f64>,
) -> Result<(Json, bool)> {
    // Every job gets a trace id at admission; the id and one clock
    // read per job are the only unconditional tracing residue.
    let trace = obs::next_trace_id();
    let _in_trace = obs::trace_scope(trace);
    let admit_ns = obs::now_ns();
    let sess = state
        .sessions
        .get(session)
        .ok_or_else(|| anyhow!("unknown session {session:?} (create_session first)"))?;
    // Snapshot the session's identity without holding the lock across
    // planning/queueing (a running job may hold it for a while).
    let (spec, points, tenant) = {
        let g = sess.lock().unwrap();
        (
            JobSpec {
                pattern: g.pattern,
                dtype: g.dtype,
                domain: g.domain.clone(),
                steps,
                t,
                backend: g.backend,
                // per-advance override > session default
                temporal: temporal.unwrap_or(g.temporal),
                shards: shards_override.unwrap_or(g.shards),
                threads: g.threads,
                weights: Some(g.weights.clone()),
                tenant: g.tenant.clone(),
                deadline_ms,
            },
            g.points(),
            g.tenant.clone(),
        )
    };
    // ---- plan plane: one cache lookup per coalesced batch ----
    // Deadline jobs bypass the gate: a latency-bounded job must not
    // sit out a gather window waiting for co-batchers.
    let key = planner_request(state, &spec, steps, t).plan_key();
    let gate = if deadline_ms.is_none() { Some(state.batches.join(&key)) } else { None };
    let (plan, hit, coalesced) = match &gate {
        Some(batch::Role::Leader(p)) => {
            let window = state.batches.window();
            if !window.is_zero() {
                std::thread::sleep(window);
            }
            // Generation stamp BEFORE the one shared lookup: followers
            // re-check stale_since(gen0), so a cache invalidation that
            // races the gather window can never leak a superseded plan
            // into the batch.
            let gen0 = state.plans.generation();
            match plan_for(state, &spec, steps, t) {
                Ok((plan, hit)) => {
                    let members = state.batches.seal(&key, p, Ok((plan.clone(), hit, gen0)));
                    (plan, hit, Some((p.clone(), members)))
                }
                Err(e) => {
                    state.batches.seal(&key, p, Err(format!("{e:#}")));
                    p.withdraw();
                    if obs::enabled() {
                        drop(obs::drain(trace));
                    }
                    return Err(e);
                }
            }
        }
        Some(batch::Role::Follower(p)) => match p.share() {
            Ok(sh) => {
                if state.plans.stale_since(sh.gen0) {
                    // The shared lookup was invalidated while the batch
                    // gathered: fall back to a fresh lookup of our own
                    // rather than executing a superseded plan.
                    match plan_for(state, &spec, steps, t) {
                        Ok((plan, hit)) => (plan, hit, Some((p.clone(), sh.members))),
                        Err(e) => {
                            if let Some(b) = p.withdraw() {
                                dispatch_batch(state, b, &key);
                            }
                            if obs::enabled() {
                                drop(obs::drain(trace));
                            }
                            return Err(e);
                        }
                    }
                } else {
                    (sh.plan, sh.hit, Some((p.clone(), sh.members)))
                }
            }
            Err(msg) => {
                // The leader's planning failed; an identical request
                // would fail identically.  Settle so the gate's
                // bookkeeping stays exact.
                if let Some(b) = p.withdraw() {
                    dispatch_batch(state, b, &key);
                }
                if obs::enabled() {
                    drop(obs::drain(trace));
                }
                return Err(anyhow!("{msg}"));
            }
        },
        None => {
            let (plan, hit) = plan_for(state, &spec, steps, t)?;
            (plan, hit, None)
        }
    };
    let decision = admission::decide(&plan, t, points, steps, state.opts.budget_ms);
    if obs::enabled() {
        obs::record(obs::SpanKind::Admission, admit_ns, obs::now_ns(), obs::Payload::None);
    }
    let (job_t, job_temporal, job_shards, downgraded, predicted_ms, engine, target) =
        match decision {
            Decision::Accept { t, temporal, shards, predicted_ms, engine, target } => {
                (t, temporal, shards, false, predicted_ms, engine, target)
            }
            Decision::Downgrade { t, temporal, shards, predicted_ms, engine, target, .. } => {
                (t, temporal, shards, true, predicted_ms, engine, target)
            }
            Decision::Reject(r) => {
                ServiceCounters::bump(&state.counters.jobs_rejected);
                state.tenants.refused(&tenant);
                if let Some((p, _)) = &coalesced {
                    if let Some(b) = p.withdraw() {
                        dispatch_batch(state, b, &key);
                    }
                }
                if obs::enabled() {
                    drop(obs::drain(trace)); // rejected: free the ring slots
                }
                obs::journal::emit(
                    "admission_refused",
                    &[
                        ("reason", Json::Str("admission".to_string())),
                        ("tenant", Json::Str(tenant.clone())),
                        ("session", Json::Str(session.to_string())),
                        ("predicted_ms", obs::journal::f(r.predicted_ms)),
                        ("budget_ms", obs::journal::f(r.budget_ms)),
                        ("engine", Json::Str(r.engine.clone())),
                        ("bound", Json::Str(r.bound.to_string())),
                        ("classification", Json::Str(r.classification.clone())),
                    ],
                );
                return Ok((
                    Obj::new()
                        .bool_("ok", false)
                        .str_("op", "advance")
                        .str_("error", "admission")
                        .str_(
                            "message",
                            &format!(
                                "predicted {:.3} ms exceeds budget {:.3} ms ({}, {}, {})",
                                r.predicted_ms, r.budget_ms, r.engine, r.bound, r.classification
                            ),
                        )
                        .str_("tenant", &tenant)
                        .num("predicted_ms", r.predicted_ms)
                        .num("budget_ms", r.budget_ms)
                        .str_("engine", &r.engine)
                        .str_("bound", r.bound)
                        .str_("classification", &r.classification)
                        .done(),
                    true,
                ));
            }
        };
    // ---- tenant plane: DRR fair-share + EDF deadline admission ----
    // Currency is roofline model-milliseconds (the same prediction the
    // budget gate priced), so fairness is cost-aware, not job-count-
    // aware, and deterministic for a given profile.
    let workers = state.opts.workers.max(1);
    let pressured = state.queue_depth() >= workers * 2;
    let urgent = match state.sched.admit(&tenant, predicted_ms, deadline_ms, pressured) {
        TenantVerdict::Admit { urgent, .. } => urgent,
        TenantVerdict::OverShare(fs) => {
            ServiceCounters::bump(&state.counters.jobs_rejected);
            state.tenants.refused(&tenant);
            if let Some((p, _)) = &coalesced {
                if let Some(b) = p.withdraw() {
                    dispatch_batch(state, b, &key);
                }
            }
            if obs::enabled() {
                drop(obs::drain(trace));
            }
            obs::journal::emit(
                "admission_refused",
                &[
                    ("reason", Json::Str("fair_share".to_string())),
                    ("tenant", Json::Str(fs.tenant.clone())),
                    ("session", Json::Str(session.to_string())),
                    ("served_ms", obs::journal::f(fs.served_ms)),
                    ("fair_share_ms", obs::journal::f(fs.fair_share_ms)),
                    ("quantum_ms", obs::journal::f(fs.quantum_ms)),
                ],
            );
            return Ok((
                Obj::new()
                    .bool_("ok", false)
                    .str_("op", "advance")
                    .str_("error", "fair_share")
                    .str_(
                        "message",
                        &format!(
                            "tenant {:?} is over its fair share under pressure (served \
                             {:.1} ms vs fair share {:.1} ms + quantum {:.1} ms); retry",
                            fs.tenant, fs.served_ms, fs.fair_share_ms, fs.quantum_ms
                        ),
                    )
                    .str_("tenant", &fs.tenant)
                    .num("served_ms", fs.served_ms)
                    .num("fair_share_ms", fs.fair_share_ms)
                    .num("quantum_ms", fs.quantum_ms)
                    .done(),
                true,
            ));
        }
        TenantVerdict::Unmeetable(v) => {
            ServiceCounters::bump(&state.counters.jobs_rejected);
            state.tenants.refused(&tenant);
            if let Some((p, _)) = &coalesced {
                if let Some(b) = p.withdraw() {
                    dispatch_batch(state, b, &key);
                }
            }
            if obs::enabled() {
                drop(obs::drain(trace));
            }
            obs::journal::emit(
                "admission_refused",
                &[
                    ("reason", Json::Str("deadline_unmeetable".to_string())),
                    ("tenant", Json::Str(tenant.clone())),
                    ("session", Json::Str(session.to_string())),
                    ("deadline_ms", obs::journal::f(v.deadline_ms)),
                    (
                        "predicted_completion_ms",
                        obs::journal::f(v.predicted_completion_ms),
                    ),
                    ("backlog_ms", obs::journal::f(v.backlog_ms)),
                    ("cost_ms", obs::journal::f(v.cost_ms)),
                ],
            );
            return Ok((
                Obj::new()
                    .bool_("ok", false)
                    .str_("op", "advance")
                    .str_("error", "deadline_unmeetable")
                    .str_(
                        "message",
                        &format!(
                            "deadline {:.1} ms is provably unmeetable: roofline-predicted \
                             completion {:.3} ms (admitted backlog {:.3} ms across {} \
                             workers + job cost {:.3} ms)",
                            v.deadline_ms,
                            v.predicted_completion_ms,
                            v.backlog_ms,
                            workers,
                            v.cost_ms
                        ),
                    )
                    .str_("tenant", &tenant)
                    .num("deadline_ms", v.deadline_ms)
                    .num("predicted_completion_ms", v.predicted_completion_ms)
                    .num("backlog_ms", v.backlog_ms)
                    .num("cost_ms", v.cost_ms)
                    .done(),
                true,
            ));
        }
    };
    // Variable-coefficient modulation is keyed on GLOBAL output indices
    // (golden::vc_mod): a shard advancing a checked-out sub-field would
    // modulate with shard-local flats and diverge from the oracle, so
    // the fan-out collapses to a monolithic run regardless of the
    // admitted shard count.
    let job_shards = if spec.pattern.coeffs == crate::model::stencil::Coeffs::VarCoef {
        1
    } else {
        job_shards
    };
    let job = backend::Job {
        pattern: spec.pattern,
        dtype: spec.dtype,
        domain: spec.domain.clone(),
        steps,
        t: job_t,
        temporal: job_temporal,
        weights: spec.weights.clone().unwrap_or_default(),
        threads: spec.threads,
    };
    let (tx, rx) = mpsc::channel();
    // plan_for clamped the enumeration to --max-queue, so the fan-out
    // batch always fits an empty queue (push_batch remains the load
    // backstop under contention).
    let sharded = job_shards > 1 && steps > 0;
    if sharded {
        // A sharded member's fan-out is its own atomic push: it leaves
        // the coalesced dispatch, but it already shared the batch's
        // one plan lookup.  Settle before any fallible step so the
        // gate's member bookkeeping stays exact.
        if let Some((p, _)) = &coalesced {
            if let Some(b) = p.withdraw() {
                dispatch_batch(state, b, &key);
            }
        }
    }
    let fanout = if sharded {
        // ---- shard plane: the job fans out into shard tasks ----
        // Every early exit below must drain the tenant scheduler's
        // admitted backlog (sched.complete), or deadline predictions
        // would inflate forever on jobs that never ran.
        let shard_plan = match ShardPlan::dim0(&spec.domain, job_shards, spec.pattern.r, job_t) {
            Ok(p) => p,
            Err(e) => {
                state.sched.complete(predicted_ms);
                state.tenants.refused(&tenant);
                if obs::enabled() {
                    drop(obs::drain(trace));
                }
                return Err(e);
            }
        };
        let field = {
            let mut g = sess.lock().unwrap();
            if g.busy {
                state.sched.complete(predicted_ms);
                state.tenants.refused(&tenant);
                if obs::enabled() {
                    drop(obs::drain(trace));
                }
                return Ok((
                    protocol::err(
                        "advance",
                        "session_busy",
                        "a sharded advance is already in flight on this session",
                    ),
                    true,
                ));
            }
            // The shard executor checks the field OUT of the session,
            // so a spilled field must be restored first; the busy flag
            // then shields it from a racing enforce().
            if let Err(e) = state.sessions.ensure_resident(&mut g) {
                state.sched.complete(predicted_ms);
                state.tenants.refused(&tenant);
                if obs::enabled() {
                    drop(obs::drain(trace));
                }
                return Err(e);
            }
            state.sessions.touch(&mut g);
            g.busy = true;
            std::mem::take(&mut g.field)
        };
        let run = Arc::new(ShardedRun::new(
            sess.clone(),
            job,
            shard_plan,
            field,
            tx,
            state.counters.clone(),
        ));
        let n = run.shard_count();
        if let Err(e) = state.queue.push_batch(ShardedRun::fan_out(&run)) {
            run.abort_admission();
            state.sched.complete(predicted_ms);
            state.tenants.refused(&tenant);
            if obs::enabled() {
                drop(obs::drain(trace));
            }
            return Ok((queue_refusal(state, e), true));
        }
        state.counters.record_shard_fanout(n);
        n
    } else {
        let queued = QueuedJob {
            session: sess.clone(),
            tenant: tenant.clone(),
            // Under tiering, an enforce() between here and execution
            // may spill this very session; the worker restores it
            // under the session lock right before advancing.
            store: if state.sessions.tiered() { Some(state.sessions.clone()) } else { None },
            job,
            kind: spec.backend,
            // PJRT is only reachable with a manifest (loaded once at
            // startup) and a pjrt-enabled binary; workers skip the
            // per-job artifact-dir probe entirely when it cannot
            // succeed.
            pjrt_possible: state.manifest.is_some() && crate::runtime::Runtime::available(),
            artifacts_dir: state.opts.artifacts_dir.clone(),
            reply: tx,
            trace,
            queued_ns: obs::now_ns(),
        };
        if let Some((p, _)) = &coalesced {
            // Member of a coalesced batch: deposit; whichever member
            // settles last pushes the single Task::Batch.  The push
            // verdict (including a queue-full refusal) arrives through
            // the reply channel below.
            if let Some(b) = p.deposit(queued) {
                dispatch_batch(state, b, &key);
            }
        } else {
            // Deadline job: EDF tier, popped before any FIFO work,
            // earliest absolute deadline first.
            let deadline_ns =
                obs::now_ns().saturating_add((deadline_ms.unwrap_or(0.0).max(0.0) * 1e6) as u64);
            let pushed = if urgent {
                state.queue.push_urgent(Task::Job(queued), deadline_ns)
            } else {
                state.queue.push(Task::Job(queued))
            };
            if let Err(e) = pushed {
                state.sched.complete(predicted_ms);
                state.tenants.refused(&tenant);
                if obs::enabled() {
                    drop(obs::drain(trace));
                }
                return Ok((queue_refusal(state, e), true));
            }
        }
        1
    };
    // Counted accepted at admission; a coalesced member's queue-full
    // refusal (rare: discovered at dispatch, after deposit) arrives as
    // a sentinel through the reply channel and is counted there.
    ServiceCounters::bump(&state.counters.jobs_accepted);
    state.tenants.admitted(&tenant);
    if downgraded {
        ServiceCounters::bump(&state.counters.jobs_downgraded);
    }
    let received = rx.recv().map_err(|_| anyhow!("worker dropped the job (shutting down?)"));
    // Whatever the outcome, the job has left the scheduler's admitted
    // backlog, and tier residency may need re-enforcing.
    state.sched.complete(predicted_ms);
    state.sessions.enforce();
    let metrics = match received? {
        Ok(m) => m,
        Err(msg) => {
            if obs::enabled() {
                drop(obs::drain(trace));
            }
            return match refusal_from_sentinel(&msg) {
                Some(json) => Ok((json, true)),
                None => Err(anyhow!("{msg}")),
            };
        }
    };
    if let Some(d) = deadline_ms {
        if metrics.wall_ns as f64 / 1e6 > d {
            state.tenants.deadline_missed(&tenant);
        }
    }
    if !metrics.kernel.is_empty() {
        sess.lock().unwrap().kernel = metrics.kernel.clone();
    }
    let mut resp = protocol::ok("advance")
        .str_("session", session)
        .str_("tenant", &tenant)
        .int("steps", metrics.steps as u64)
        .int("t", job_t as u64)
        .str_("temporal", job_temporal.as_str())
        .int("shards", fanout as u64)
        .int("batched", coalesced.as_ref().map_or(1, |(_, m)| *m) as u64)
        .str_("engine", &engine)
        .str_("target", target)
        .str_("cache", if hit { "hit" } else { "miss" })
        .bool_("downgraded", downgraded)
        .num("predicted_ms", predicted_ms)
        .num("wall_ms", metrics.wall_ns as f64 / 1e6)
        .num("mstencils", metrics.throughput() / 1e6)
        .str_("coeffs", spec.pattern.coeffs.as_str())
        .int("nnz", spec.pattern.effective_k_points());
    if !metrics.kernel.is_empty() {
        resp = resp
            .str_("kernel", &metrics.kernel)
            .num("interior_fraction", metrics.interior_fraction());
    }
    resp = intensity_feedback(
        state,
        resp,
        &spec,
        &metrics,
        job_t,
        job_temporal,
        fanout,
        steps,
        predicted_ms,
        admit_ns,
    );
    if obs::enabled() {
        // The flight recorder gives the reply its span log; draining
        // here keeps concurrent jobs from evicting each other's spans.
        resp = resp.set("spans", obs::export::compact_spans(&obs::drain(trace)));
    }
    Ok((resp.done(), true))
}

/// Reply-channel sentinel for a coalesced batch refused by a full
/// queue (`__queue_full:<depth>:<cap>`): the dispatching member can't
/// return a reply on another member's connection, so each member's
/// handler decodes the sentinel back into the structured refusal.
const QUEUE_FULL_SENTINEL: &str = "__queue_full:";
/// Reply-channel sentinel for a batch refused by a closing queue.
const QUEUE_CLOSED_SENTINEL: &str = "__queue_closed";

/// Push a sealed batch's deposits: one `Task::Batch` for a true
/// coalition, a plain `Task::Job` for a batch of one (bit-for-bit the
/// pre-batching fast path).  On refusal, every member's handler gets
/// the structured refusal through its reply channel, counted and
/// attributed to each member's tenant here.
fn dispatch_batch(state: &ServiceState, members: Vec<QueuedJob>, key: &PlanKey) {
    let n = members.len();
    if n == 0 {
        return;
    }
    let routes: Vec<(String, mpsc::Sender<Result<RunMetrics, String>>)> =
        members.iter().map(|q| (q.tenant.clone(), q.reply.clone())).collect();
    let task = if n == 1 {
        Task::Job(members.into_iter().next().unwrap())
    } else {
        Task::Batch(BatchRun { members, key: key.canonical() })
    };
    match state.queue.push(task) {
        Ok(()) => {
            if n > 1 {
                state.counters.record_batch(n);
            }
        }
        Err(e) => {
            let msg = match e {
                PushError::Full { depth, cap } => format!("{QUEUE_FULL_SENTINEL}{depth}:{cap}"),
                PushError::Closed => QUEUE_CLOSED_SENTINEL.to_string(),
            };
            for (member_tenant, reply) in routes {
                ServiceCounters::bump(&state.counters.queue_rejected);
                state.tenants.refused(&member_tenant);
                let _ = reply.send(Err(msg.clone()));
            }
        }
    }
}

/// Decode a dispatcher-side refusal sentinel back into the structured
/// refusal reply; `None` = a genuine execution error.  Counters were
/// already bumped by the dispatcher.
fn refusal_from_sentinel(msg: &str) -> Option<Json> {
    if let Some(rest) = msg.strip_prefix(QUEUE_FULL_SENTINEL) {
        let mut it = rest.splitn(2, ':');
        let depth = it.next().and_then(|s| s.parse::<usize>().ok()).unwrap_or(0);
        let cap = it.next().and_then(|s| s.parse::<usize>().ok()).unwrap_or(0);
        return Some(queue_full_json(depth, cap));
    }
    if msg == QUEUE_CLOSED_SENTINEL {
        return Some(protocol::err("advance", "shutting_down", "service is shutting down"));
    }
    None
}

/// The structured queue-full refusal: observed depth (job-weighted —
/// a coalesced batch counts its member jobs) and capacity, so shed
/// clients can see why.
fn queue_full_json(depth: usize, cap: usize) -> Json {
    Obj::new()
        .bool_("ok", false)
        .str_("op", "advance")
        .str_("error", "queue_full")
        .str_("message", &format!("job queue at capacity ({depth}/{cap} jobs); retry later"))
        .int("queue_depth", depth as u64)
        .int("queue_cap", cap as u64)
        .done()
}

/// Render a direct (un-coalesced) queue push refusal, counting it.
fn queue_refusal(state: &ServiceState, e: PushError) -> Json {
    ServiceCounters::bump(&state.counters.queue_rejected);
    match e {
        PushError::Full { depth, cap } => {
            obs::journal::emit(
                "admission_refused",
                &[
                    ("reason", Json::Str("queue_full".to_string())),
                    ("queue_depth", Json::Num(depth as f64)),
                    ("queue_cap", Json::Num(cap as f64)),
                ],
            );
            queue_full_json(depth, cap)
        }
        PushError::Closed => protocol::err("advance", "shutting_down", "service is shutting down"),
    }
}

/// The model↔measurement feedback path: compare the achieved intensity
/// against the model's prediction for the executed temporal strategy
/// AND shard fan-out, report it to the client, and fold it into the
/// service-wide mean model error.  A blocked run the executor had to
/// degrade to per-step sweeps (1-D / untileable domain) realizes Eq. 8
/// at depth 1, so it is compared against THAT prediction rather than
/// polluting the mean with a false α-sized error; sharded runs compare
/// against the halo-redundancy-adjusted prediction
/// (`model::shard::predicted_job_intensity`).
///
/// The same `model_err` feeds the drift plane: the sample lands in its
/// region's EWMA (region = bound on the *profile's* scalar roof ×
/// realization × fan-out), and the first sample that pushes a region
/// over the drift threshold stales the profile, bumps its generation,
/// empties the plan cache, and — under `--retune auto` — schedules a
/// background recalibration on the worker pool.  The reply carries a
/// `"profile"` and `"drift"` block so clients see the state they ran
/// under.
#[allow(clippy::too_many_arguments)]
fn intensity_feedback(
    state: &ServiceState,
    resp: Obj,
    spec: &JobSpec,
    metrics: &RunMetrics,
    job_t: usize,
    job_temporal: backend::TemporalMode,
    shards: usize,
    steps: usize,
    predicted_ms: f64,
    job_start_ns: u64,
) -> Obj {
    // Per-kernel achieved throughput is an always-on histogram: the
    // paper's GPts/s axis, bucketed per resolved row kernel.
    if metrics.wall_ns > 0 {
        obs::metrics().observe_kernel_gpts(&metrics.kernel, metrics.throughput() / 1e9);
    }
    if metrics.bytes_moved == 0 {
        if obs::enabled() {
            obs::record(
                obs::SpanKind::Job,
                job_start_ns,
                obs::now_ns(),
                obs::Payload::Job {
                    steps: steps as u64,
                    shards: shards as u64,
                    // uninstrumented backend: no traffic, no model error
                    model_err: f64::NAN,
                },
            );
        }
        return resp;
    }
    let blocked = job_temporal == backend::TemporalMode::Blocked;
    let eff_t = if blocked && metrics.degenerate_blocks > 0 { 1 } else { job_t };
    let w = crate::model::perf::Workload::new(spec.pattern, eff_t, spec.dtype);
    let rep = crate::model::calib::report_sharded(
        &w,
        steps,
        blocked,
        spec.domain[0],
        shards,
        metrics.achieved_intensity(),
    );
    state.counters.record_intensity_error(rep.rel_error);
    obs::metrics().model_err.observe(rep.rel_error);
    if obs::enabled() {
        obs::record(
            obs::SpanKind::Job,
            job_start_ns,
            obs::now_ns(),
            obs::Payload::Job {
                steps: steps as u64,
                shards: shards as u64,
                model_err: rep.rel_error,
            },
        );
    }
    // ---- drift plane: region classification over the live profile ----
    let gpu = state.profile.gpu();
    let roof = gpu.roof(Unit::CudaCore, spec.dtype).ok();
    let mem_bound = match &roof {
        Some(roof) => rep.predicted < roof.ridge(),
        None => true, // scalar path absent: call it memory-bound
    };
    let region = drift::region(mem_bound, blocked, shards > 1);
    let (reading, flagged_now) = state.profile.record(&region, rep.rel_error);
    // ---- wall-time channel: the machine-constant drift signal ----
    // The intensity error above is a ratio of deterministic counters —
    // it detects model-structure drift but is blind to the machine
    // itself slowing down.  The measured/predicted wall-time ratio,
    // judged against its post-install baseline, is what catches
    // throttling/contention/migration (see `tune::drift::WallTracker`).
    let mut wall_flag = false;
    let mut wall_reading = None;
    if predicted_ms > 0.0 && metrics.wall_ns > 0 {
        let ratio = (metrics.wall_ns as f64 / 1e6) / predicted_ms;
        let (wr, flagged) = state.profile.record_wall(&region, ratio);
        wall_flag = flagged;
        wall_reading = Some(wr);
    }
    if flagged_now || wall_flag {
        // Every cached plan was scored against constants the machine
        // just disproved.
        state.plans.clear();
        obs::journal::emit(
            "drift_flag",
            &[
                ("region", Json::Str(region.clone())),
                ("ewma", obs::journal::f(reading.ewma)),
                ("wall_channel", Json::Bool(wall_flag)),
            ],
        );
    }
    // Schedule (or retry) a recalibration on any over-threshold
    // reading WHILE THE PROFILE IS STALE AND MEASURED, not just the
    // flagging one: the begin_retune latch keeps it single-flight,
    // retrying per sample is what lets a failed background retune heal
    // instead of leaving a stale profile in force forever, the stale
    // gate keeps the hub's post-flag backoff authoritative, and the
    // measured gate means auto-retune only ever replaces constants
    // that were measured here in the first place (a drifted BUILTIN
    // datasheet profile is flagged and invalidated, but swapping an
    // operator-selected GPU table for CPU-measured constants is never
    // done silently — `serve` refuses that flag combination upfront).
    let channel_over =
        reading.over || wall_reading.as_ref().is_some_and(|w| w.over);
    // ---- attribution: decompose measured−predicted into residuals ----
    // Gated on the obs plane: `attribute` allocates its ranked term
    // vector, and obs-disabled serving must stay allocation-free here.
    let mut attrib_json = None;
    if obs::enabled() {
        let exec_ms = metrics.wall_ns as f64 / 1e6;
        let serve_ms =
            (obs::now_ns().saturating_sub(job_start_ns) as f64 / 1e6 - exec_ms).max(0.0);
        let o = obs::attrib::JobObservation {
            predicted_ms,
            exec_ms,
            serve_ms,
            mem_bound,
            bytes_moved: metrics.bytes_moved as f64,
            bytes_predicted: crate::model::calib::predicted_job_bytes(
                metrics.flops as f64,
                rep.predicted,
            ),
            flops: metrics.flops as f64,
            bandwidth: gpu.bandwidth,
            peak_flops: roof.map(|r| r.peak_flops).unwrap_or(0.0),
        };
        let a = obs::attrib::attribute(&o);
        state.attrib.record(&region, &a);
        if channel_over {
            // The retune episode scheduled below cites this verdict
            // instead of a bare EWMA crossing.
            state.profile.note_cause(&region, a.verdict.as_str());
        }
        attrib_json = Some(a.to_json());
    }
    if channel_over
        && state.opts.retune == RetuneMode::Auto
        && state.profile.measured()
        && state.profile.stale()
        && state.profile.begin_retune()
    {
        let task = Task::Retune(RetuneTask {
            hub: state.profile.clone(),
            plans: state.plans.clone(),
            cause: state
                .profile
                .cause(&region)
                .unwrap_or_else(|| "ewma_crossing".to_string()),
            opts: MicroOpts {
                // probe at the serve-configured parallelism so the
                // installed constants match what `stencilctl tune
                // --threads N` would have measured
                threads: state.opts.probe_threads.max(1),
                ..MicroOpts::quick()
            },
        });
        if state.queue.push_maintenance(task).is_err() {
            state.profile.retune_failed(); // shutting down
        }
    }
    let status = state.profile.status();
    let mut drift_obj = Obj::new()
        .str_("region", &reading.region)
        .num("ewma", reading.ewma)
        .num("threshold", reading.threshold)
        .bool_("flagged", reading.over);
    if let Some(w) = &wall_reading {
        drift_obj = drift_obj
            .num("wall_ratio", w.ratio_ewma)
            .num("wall_departure", w.departure)
            .bool_("wall_flagged", w.over);
    }
    let mut resp = resp
        .num("achieved_intensity", rep.measured)
        .num("predicted_intensity", rep.predicted)
        .num("model_err", rep.rel_error)
        .bool_("within_model_region", rep.within_region)
        .bool_("blocking_degraded", metrics.degenerate_blocks > 0)
        .set(
            "profile",
            Obj::new()
                .str_("name", &status.name)
                .str_("source", &status.source)
                .int("generation", status.generation)
                .bool_("stale", status.stale)
                .done(),
        )
        .set("drift", drift_obj.done());
    if let Some(a) = attrib_json {
        resp = resp.set("attribution", a);
    }
    resp
}

/// The `stats` response: raw counters for machines, a rendered table
/// for humans (`report::service_stats`), and — with `"prom": true` —
/// the Prometheus exposition text.  The machine-profile identity and
/// drift state ride in both forms.  Each `stats` call closes a cache
/// delta window, so successive snapshots report disjoint
/// hits/misses/evictions deltas.
fn stats_response(state: &ServiceState, prom: bool) -> Json {
    let mut snap = state.counters.snapshot();
    snap.profile = state.profile.status();
    snap.queue_depth = state.queue_depth() as u64;
    let rows = state.sessions.rows();
    let cache = state.plans.stats_window();
    let tenant_bytes = state.sessions.tenant_bytes();
    let trows = state.tenants.rows(&tenant_bytes);
    let render = report::service_stats(&snap, &cache, &rows, &trows);
    let drift_rows = Json::Arr(
        state
            .profile
            .regions()
            .iter()
            .map(|r| {
                Obj::new()
                    .str_("region", &r.region)
                    .num("ewma", r.ewma)
                    .int("samples", r.samples)
                    .bool_("over", r.over)
                    .done()
            })
            .collect(),
    );
    let sessions = Json::Arr(
        rows.iter()
            .map(|r| {
                Obj::new()
                    .str_("session", &r.name)
                    .str_("pattern", &r.pattern)
                    .str_("dtype", r.dtype)
                    .str_("domain", &r.domain)
                    .str_("backend", r.backend)
                    .str_("kernel", &r.kernel)
                    .int("jobs", r.stats.jobs)
                    .int("steps", r.stats.steps)
                    .num("mstencils", r.stats.throughput() / 1e6)
                    .done()
            })
            .collect(),
    );
    let tenants_json = Json::Arr(
        trows
            .iter()
            .map(|r| {
                Obj::new()
                    .str_("tenant", &r.tenant)
                    .int("admitted", r.admitted)
                    .int("refused", r.refused)
                    .int("deadline_missed", r.deadline_missed)
                    .int("resident_bytes", r.resident_bytes)
                    .int("spilled_bytes", r.spilled_bytes)
                    .done()
            })
            .collect(),
    );
    let (resident_total, spilled_total) = tenant_bytes
        .values()
        .fold((0u64, 0u64), |(r, s), &(tr, ts)| (r + tr, s + ts));
    // ---- explainability plane: attribution, alerts, latency quantiles ----
    let attrib_rows = Json::Arr(
        state
            .attrib
            .snapshot()
            .iter()
            .map(|r| {
                Obj::new()
                    .str_("region", &r.region)
                    .int("jobs", r.jobs)
                    .str_("dominant", r.dominant.as_str())
                    .set(
                        "terms",
                        Json::Arr(
                            r.terms
                                .iter()
                                .map(|(t, mean_abs_ms, verdicts)| {
                                    Obj::new()
                                        .str_("term", t.as_str())
                                        .num("mean_abs_ms", *mean_abs_ms)
                                        .int("verdicts", *verdicts)
                                        .done()
                                })
                                .collect(),
                        ),
                    )
                    .done()
            })
            .collect(),
    );
    let alert_rows = evaluate_alerts(state);
    let firing = alert_rows.iter().filter(|r| r.firing).count() as u64;
    // log₂-bucket estimates: each is the bucket upper bound, so within
    // 2× of the exact percentile (see `obs::prom::Histogram::quantile`).
    let mut quantiles = Obj::new();
    for (name, h) in [
        ("queue_wait", &obs::metrics().queue_wait_ns),
        ("phase_wall", &obs::metrics().phase_wall_ns),
    ] {
        for (label, q) in [("p50", 0.5), ("p95", 0.95), ("p99", 0.99)] {
            if let Some(v) = h.quantile(q) {
                if v.is_finite() {
                    quantiles = quantiles.num(&format!("{name}_{label}_ms"), v / 1e6);
                }
            }
        }
    }
    let mut o = protocol::ok("stats")
        .int("requests", snap.requests)
        .int("errors", snap.errors)
        .int("jobs_accepted", snap.jobs_accepted)
        .int("jobs_downgraded", snap.jobs_downgraded)
        .int("jobs_rejected", snap.jobs_rejected)
        .int("queue_rejected", snap.queue_rejected)
        .int("jobs_completed", snap.jobs_completed)
        .int("jobs_failed", snap.jobs_failed)
        .int("jobs_sharded", snap.jobs_sharded)
        .int("shard_tasks", snap.shard_tasks)
        .int("jobs_batched", snap.jobs_batched)
        .int("batches", snap.batches)
        .int("resident_bytes", resident_total)
        .int("spilled_bytes", spilled_total)
        .int("plan_hits", snap.plan_hits)
        .int("plan_misses", snap.plan_misses)
        .num("plan_hit_rate", snap.plan_hit_rate())
        .int("plan_cache_size", cache.len as u64)
        .int("plan_cache_evictions", cache.evictions)
        .int("plan_cache_generation", cache.generation)
        .int("plan_cache_hits_delta", cache.d_hits)
        .int("plan_cache_misses_delta", cache.d_misses)
        .int("plan_cache_evictions_delta", cache.d_evictions)
        .int("queue_depth", snap.queue_depth)
        .int("sessions", rows.len() as u64)
        .int("steps_total", snap.steps_total)
        .num("mstencils", snap.throughput() / 1e6)
        .num("model_error", snap.model_error())
        .int("model_samples", snap.intensity_samples)
        .str_("profile_name", &snap.profile.name)
        .str_("profile_source", &snap.profile.source)
        .int("profile_generation", snap.profile.generation)
        .bool_("profile_stale", snap.profile.stale)
        .int("drift_flags", snap.profile.drift_flags)
        .int("retunes", snap.profile.retunes)
        .num("drift_threshold", state.profile.threshold())
        .set("drift", drift_rows)
        .set("session_stats", sessions)
        .set("tenants", tenants_json)
        .set("attribution", attrib_rows)
        .int("attribution_jobs", state.attrib.total_jobs())
        .int("alerts_firing", firing)
        .set(
            "alerts",
            Json::Arr(alert_rows.iter().map(alert_row_json).collect()),
        )
        .set("latency", quantiles.done());
    if prom {
        let mut text = obs::metrics().exposition(&snap, &cache, &trows);
        text.push_str(&obs::alert::render_prom(&alert_rows, state.alerts.transitions()));
        o = o.str_("prom", &text);
    }
    o.str_("render", &render).done()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn svc() -> Service {
        Service::start(ServeOpts {
            workers: 2,
            artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
            ..Default::default()
        })
    }

    fn req(state: &ServiceState, line: &str) -> Json {
        let (resp, _keep) = handle_line(state, line);
        Json::parse_line(&resp).unwrap()
    }

    fn assert_ok(j: &Json) {
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true), "{j}");
    }

    #[test]
    fn ping_plan_and_bad_requests() {
        let s = svc();
        let state = s.state();
        assert_ok(&req(&state, r#"{"op":"ping"}"#));
        let p = req(&state, r#"{"op":"plan","shape":"box","d":2,"r":1,"dtype":"float"}"#);
        assert_ok(&p);
        assert_eq!(p.get("cache").unwrap().as_str(), Some("miss"));
        let p2 = req(&state, r#"{"op":"plan","shape":"box","d":2,"r":1,"dtype":"float"}"#);
        assert_eq!(p2.get("cache").unwrap().as_str(), Some("hit"));
        let bad = req(&state, "not json");
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(bad.get("error").unwrap().as_str(), Some("bad_request"));
        let unknown = req(&state, r#"{"op":"advance","session":"ghost"}"#);
        assert_eq!(unknown.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn session_lifecycle_advance_fetch_stats() {
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"a","shape":"star","d":2,"r":1,
                "dtype":"double","domain":[12,12],"backend":"native","threads":1}"#,
        ));
        // duplicate name refused
        let dup = req(&state, r#"{"op":"create_session","session":"a","domain":[12,12]}"#);
        assert_eq!(dup.get("ok").unwrap().as_bool(), Some(false));
        let a1 = req(&state, r#"{"op":"advance","session":"a","steps":2,"t":1}"#);
        assert_ok(&a1);
        assert_eq!(a1.get("cache").unwrap().as_str(), Some("miss"));
        assert_eq!(a1.get("steps").unwrap().as_usize(), Some(2));
        // the resolved row kernel rides in the reply (mode-dependent ISA suffix)
        let kname = a1.get("kernel").unwrap().as_str().unwrap().to_string();
        assert!(
            kname.starts_with("star-2d1r/double/") || kname == "generic",
            "kernel {kname}"
        );
        assert!(a1.get("interior_fraction").unwrap().as_f64().unwrap() > 0.0);
        let a2 = req(&state, r#"{"op":"advance","session":"a","steps":2,"t":1}"#);
        assert_ok(&a2);
        assert_eq!(a2.get("cache").unwrap().as_str(), Some("hit"));
        let f = req(&state, r#"{"op":"fetch","session":"a","encoding":"hex"}"#);
        assert_ok(&f);
        assert_eq!(f.get("len").unwrap().as_usize(), Some(144));
        assert_eq!(f.get("field").unwrap().as_arr().unwrap().len(), 144);
        let st = req(&state, r#"{"op":"stats"}"#);
        assert_ok(&st);
        assert_eq!(st.get("jobs_completed").unwrap().as_usize(), Some(2));
        assert_eq!(st.get("sessions").unwrap().as_usize(), Some(1));
        assert!(st.get("plan_hits").unwrap().as_i64().unwrap() >= 1);
        assert!(st.get("render").unwrap().as_str().unwrap().contains("service"));
        // per-session kernel name rides in the machine-readable stats too
        let srows = st.get("session_stats").unwrap().as_arr().unwrap();
        assert_eq!(srows[0].get("kernel").unwrap().as_str(), Some(kname.as_str()));
        assert_ok(&req(&state, r#"{"op":"close_session","session":"a"}"#));
        let gone = req(&state, r#"{"op":"fetch","session":"a"}"#);
        assert_eq!(gone.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn advance_matches_golden_oracle_bit_exactly() {
        use crate::sim::golden;
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"g","shape":"box","d":2,"r":1,
                "dtype":"double","domain":[10,10],"backend":"native","threads":2}"#,
        ));
        assert_ok(&req(&state, r#"{"op":"advance","session":"g","steps":2,"t":2}"#));
        assert_ok(&req(&state, r#"{"op":"advance","session":"g","steps":2,"t":2}"#));
        let f = req(&state, r#"{"op":"fetch","session":"g","encoding":"hex"}"#);
        let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
        // replay: gaussian init, two fused t=2 launches
        let p = crate::model::stencil::StencilPattern::new(crate::model::stencil::Shape::Box, 2, 1)
            .unwrap();
        let w = golden::Weights::new(2, 3, p.uniform_weights());
        let mut want = golden::Field::from_vec(&[10, 10], golden::gaussian(&[10, 10]));
        for _ in 0..2 {
            want = golden::apply_fused(&want, &w, 2);
        }
        assert_eq!(got.len(), want.data.len());
        for (i, (a, b)) in got.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "point {i}: {a} vs {b}");
        }
    }

    #[test]
    fn blocked_advance_reports_intensity_feedback() {
        use crate::sim::golden;
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"b","shape":"star","d":2,"r":1,
                "dtype":"double","domain":[64,64],"backend":"native","temporal":"blocked","threads":2}"#,
        ));
        let a = req(&state, r#"{"op":"advance","session":"b","steps":8,"t":4}"#);
        assert_ok(&a);
        assert_eq!(a.get("temporal").unwrap().as_str(), Some("blocked"));
        // Star-2D1R f64 at t=4: the model predicts I = t·K/D = 2.5 F/B;
        // the measured value sits just below it (halo overhead).
        let ai = a.get("achieved_intensity").unwrap().as_f64().unwrap();
        let pi = a.get("predicted_intensity").unwrap().as_f64().unwrap();
        assert!((pi - 2.5).abs() < 1e-9, "predicted {pi}");
        assert!(ai > 0.0 && ai <= pi + 1e-9, "achieved {ai} vs predicted {pi}");
        assert_eq!(a.get("within_model_region").unwrap().as_bool(), Some(true));
        // Blocked semantics: bit-identical to SEQUENTIAL stepping.
        let f = req(&state, r#"{"op":"fetch","session":"b","encoding":"hex"}"#);
        let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
        let p = crate::model::stencil::StencilPattern::new(
            crate::model::stencil::Shape::Star,
            2,
            1,
        )
        .unwrap();
        let w = golden::Weights::new(2, 3, p.uniform_weights());
        let want = golden::apply_steps(
            &golden::Field::from_vec(&[64, 64], golden::gaussian(&[64, 64])),
            &w,
            8,
        );
        for (i, (a, b)) in got.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "point {i}");
        }
        let st = req(&state, r#"{"op":"stats"}"#);
        assert!(st.get("model_samples").unwrap().as_i64().unwrap() >= 1);
        assert!(st.get("model_error").unwrap().as_f64().unwrap() < 0.25);
    }

    #[test]
    fn sharded_advance_fans_out_and_stays_bit_identical() {
        use crate::sim::golden;
        // threads=1 session against a 2-worker pool: the redundancy-
        // adjusted gain picks a 2-shard fan-out (sweep κ=1, 2 lanes vs
        // a 1-thread monolith), and the assembled result must stay
        // bit-identical to the golden fused chain.
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"sh","shape":"box","d":2,"r":1,
                "dtype":"double","domain":[24,24],"backend":"native","temporal":"sweep","threads":1}"#,
        ));
        let a = req(&state, r#"{"op":"advance","session":"sh","steps":4,"t":2}"#);
        assert_ok(&a);
        assert_eq!(a.get("shards").unwrap().as_usize(), Some(2), "{a}");
        assert_eq!(a.get("temporal").unwrap().as_str(), Some("sweep"));
        // the shard-aware prediction sits below the monolithic α·t·K/D
        // (halo re-reads) and the measured value matches it
        assert_eq!(a.get("within_model_region").unwrap().as_bool(), Some(true));
        let f = req(&state, r#"{"op":"fetch","session":"sh","encoding":"hex"}"#);
        let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
        let p = crate::model::stencil::StencilPattern::new(
            crate::model::stencil::Shape::Box,
            2,
            1,
        )
        .unwrap();
        let w = golden::Weights::new(2, 3, p.uniform_weights());
        let mut want = golden::Field::from_vec(&[24, 24], golden::gaussian(&[24, 24]));
        for _ in 0..2 {
            want = golden::apply_fused(&want, &w, 2);
        }
        for (i, (a, b)) in got.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "point {i}");
        }
        let st = req(&state, r#"{"op":"stats"}"#);
        assert!(st.get("jobs_sharded").unwrap().as_i64().unwrap() >= 1);
        assert!(st.get("shard_tasks").unwrap().as_i64().unwrap() >= 2);
        assert_eq!(st.get("jobs_completed").unwrap().as_usize(), Some(1));
        // pinning shards:1 forces the monolithic path on the same session
        let a1 = req(&state, r#"{"op":"advance","session":"sh","steps":2,"t":1,"shards":1}"#);
        assert_ok(&a1);
        assert_eq!(a1.get("shards").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn sparse_pattern_session_reports_kernel_and_sparsity_fields() {
        use crate::model::stencil::{Coeffs, Shape, StencilPattern};
        use crate::sim::golden;
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"sp","pattern":"box-2d1r:sparse24",
                "dtype":"double","domain":[12,12],"backend":"native","threads":1}"#,
        ));
        let a = req(&state, r#"{"op":"advance","session":"sp","steps":2,"t":1}"#);
        assert_ok(&a);
        // the sparsity plane rides in every advance reply: coefficient
        // variant plus the effective (post-pruning) taps per update
        assert_eq!(a.get("coeffs").unwrap().as_str(), Some("sparse24"));
        assert_eq!(a.get("nnz").unwrap().as_usize(), Some(5), "2:4 keeps 5 of 9 box taps");
        let kname = a.get("kernel").unwrap().as_str().unwrap();
        assert!(
            kname.starts_with("box-2d1r-sparse24/double/") || kname == "generic",
            "kernel {kname}"
        );
        // bit-identity to the golden oracle over the pruned weight set
        let f = req(&state, r#"{"op":"fetch","session":"sp","encoding":"hex"}"#);
        let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
        let p = StencilPattern::new(Shape::Box, 2, 1).unwrap().with_coeffs(Coeffs::Sparse24);
        let w = golden::Weights::new(2, 3, p.default_weights());
        let want = golden::apply_steps(
            &golden::Field::from_vec(&[12, 12], golden::gaussian(&[12, 12])),
            &w,
            2,
        );
        for (i, (a, b)) in got.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "point {i}");
        }
    }

    #[test]
    fn varcoef_session_collapses_fanout_and_matches_oracle() {
        use crate::model::stencil::{Coeffs, Shape, StencilPattern};
        use crate::sim::golden;
        let s = svc();
        let state = s.state();
        // shards pinned to 4: per-point modulation is keyed on global
        // indices, so the server must still run the job monolithically
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"vc","pattern":"star-2d1r:varcoef",
                "dtype":"double","domain":[16,16],"backend":"native","temporal":"blocked",
                "shards":4,"threads":1}"#,
        ));
        let a = req(&state, r#"{"op":"advance","session":"vc","steps":3,"t":2}"#);
        assert_ok(&a);
        assert_eq!(a.get("coeffs").unwrap().as_str(), Some("varcoef"));
        assert_eq!(a.get("shards").unwrap().as_usize(), Some(1), "{a}");
        let f = req(&state, r#"{"op":"fetch","session":"vc","encoding":"hex"}"#);
        let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
        let p = StencilPattern::new(Shape::Star, 2, 1).unwrap().with_coeffs(Coeffs::VarCoef);
        let w = golden::Weights::new(2, 3, p.default_weights());
        let want = golden::apply_steps_varcoef(
            &golden::Field::from_vec(&[16, 16], golden::gaussian(&[16, 16])),
            &w,
            3,
        );
        for (i, (a, b)) in got.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "point {i}");
        }
    }

    #[test]
    fn zero_budget_rejects_with_model_classification() {
        let opts = ServeOpts {
            workers: 1,
            budget_ms: Some(0.0),
            artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
            ..Default::default()
        };
        let s = Service::start(opts);
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"r","domain":[16,16],"dtype":"float"}"#,
        ));
        let rej = req(&state, r#"{"op":"advance","session":"r","steps":4}"#);
        assert_eq!(rej.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(rej.get("error").unwrap().as_str(), Some("admission"));
        assert!(rej.get("predicted_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(!rej.get("classification").unwrap().as_str().unwrap().is_empty());
        let st = req(&state, r#"{"op":"stats"}"#);
        assert_eq!(st.get("jobs_rejected").unwrap().as_usize(), Some(1));
        assert_eq!(st.get("jobs_completed").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn stats_carry_the_profile_identity() {
        let s = svc();
        let state = s.state();
        let st = req(&state, r#"{"op":"stats"}"#);
        assert_ok(&st);
        assert_eq!(st.get("profile_name").unwrap().as_str(), Some("A100-80GB-PCIe"));
        assert_eq!(st.get("profile_source").unwrap().as_str(), Some("builtin"));
        assert_eq!(st.get("profile_generation").unwrap().as_usize(), Some(0));
        assert_eq!(st.get("profile_stale").unwrap().as_bool(), Some(false));
        assert_eq!(st.get("plan_cache_generation").unwrap().as_usize(), Some(0));
        assert_eq!(st.get("drift").unwrap().as_arr().unwrap().len(), 0);
        assert!(st.get("render").unwrap().as_str().unwrap().contains("A100-80GB-PCIe"));
    }

    #[test]
    fn drift_flags_the_profile_and_empties_the_plan_cache() {
        // A tiny drift threshold turns the blocked path's ordinary
        // halo-overhead model error into a drift signal: the EWMA
        // crosses on the third instrumented advance (min samples),
        // which must stale the profile, bump its generation, and clear
        // the plan cache — observable in replies and stats.
        let opts = ServeOpts {
            workers: 1,
            drift_threshold: 1e-6,
            artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
            ..Default::default()
        };
        let s = Service::start(opts);
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"d","shape":"star","d":2,"r":1,
                "dtype":"double","domain":[64,64],"backend":"native","temporal":"blocked","threads":2}"#,
        ));
        let a1 = req(&state, r#"{"op":"advance","session":"d","steps":8,"t":4}"#);
        assert_ok(&a1);
        let p = a1.get("profile").unwrap();
        assert_eq!(p.get("stale").unwrap().as_bool(), Some(false), "one sample cannot flag");
        let dr = a1.get("drift").unwrap();
        assert_eq!(dr.get("region").unwrap().as_str(), Some("mem/blocked"));
        assert!(dr.get("ewma").unwrap().as_f64().unwrap() > 1e-6, "halo error feeds the EWMA");
        let a2 = req(&state, r#"{"op":"advance","session":"d","steps":8,"t":4}"#);
        assert_eq!(a2.get("profile").unwrap().get("stale").unwrap().as_bool(), Some(false));
        let a3 = req(&state, r#"{"op":"advance","session":"d","steps":8,"t":4}"#);
        assert_ok(&a3);
        let p3 = a3.get("profile").unwrap();
        assert_eq!(p3.get("stale").unwrap().as_bool(), Some(true), "{a3}");
        assert_eq!(p3.get("generation").unwrap().as_usize(), Some(1));
        assert_eq!(a3.get("drift").unwrap().get("flagged").unwrap().as_bool(), Some(true));
        let st = req(&state, r#"{"op":"stats"}"#);
        assert_eq!(st.get("profile_stale").unwrap().as_bool(), Some(true));
        assert_eq!(st.get("profile_generation").unwrap().as_usize(), Some(1));
        assert_eq!(st.get("drift_flags").unwrap().as_usize(), Some(1));
        assert_eq!(st.get("plan_cache_size").unwrap().as_usize(), Some(0), "cache cleared");
        assert_eq!(st.get("plan_cache_generation").unwrap().as_usize(), Some(1));
        assert_eq!(st.get("retunes").unwrap().as_usize(), Some(0), "retune off by default");
        let drift = st.get("drift").unwrap().as_arr().unwrap();
        assert!(!drift.is_empty());
        assert_eq!(drift[0].get("over").unwrap().as_bool(), Some(true));
        // the invalidation is visible on the next advance: a re-plan
        let a4 = req(&state, r#"{"op":"advance","session":"d","steps":8,"t":4}"#);
        assert_eq!(a4.get("cache").unwrap().as_str(), Some("miss"));
    }

    #[test]
    fn retune_auto_installs_a_measured_profile() {
        // Auto-retune only replaces MEASURED profiles (the CLI refuses
        // --retune auto on a builtin table), so seed with one.
        let mut seed = crate::engines::builtin_profile(&Gpu::a100());
        seed.source = crate::tune::ProfileSource::Measured;
        seed.name = "seed-measured".to_string();
        let opts = ServeOpts {
            workers: 2,
            drift_threshold: 1e-6,
            retune: crate::tune::RetuneMode::Auto,
            profile: seed,
            artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
            ..Default::default()
        };
        let s = Service::start(opts);
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"r","shape":"star","d":2,"r":1,
                "dtype":"double","domain":[64,64],"backend":"native","temporal":"blocked","threads":2}"#,
        ));
        for _ in 0..3 {
            assert_ok(&req(&state, r#"{"op":"advance","session":"r","steps":8,"t":4}"#));
        }
        // The background retune runs on the pool; poll stats for it.
        // Keep advancing while we wait: a retune rejected for probe
        // noise (contention with this very test) is retried on the
        // next drifted sample, so feeding samples guarantees progress.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
        let st = loop {
            let st = req(&state, r#"{"op":"stats"}"#);
            if st.get("retunes").unwrap().as_usize() == Some(1) {
                break st;
            }
            assert!(std::time::Instant::now() < deadline, "retune never landed: {st}");
            let _ = req(&state, r#"{"op":"advance","session":"r","steps":8,"t":4}"#);
            std::thread::sleep(std::time::Duration::from_millis(50));
        };
        assert_eq!(st.get("profile_source").unwrap().as_str(), Some("measured"));
        assert_eq!(st.get("profile_name").unwrap().as_str(), Some("measured-native"));
        assert_eq!(st.get("profile_stale").unwrap().as_bool(), Some(false));
        // generation: 1 (drift flag) + 1 (install)
        assert_eq!(st.get("profile_generation").unwrap().as_usize(), Some(2));
        assert!(st.get("plan_cache_generation").unwrap().as_usize().unwrap() >= 2);
        // subsequent plans run against the measured constants: the
        // PlanKey's gpu identity is the measured profile's name
        let a = req(&state, r#"{"op":"advance","session":"r","steps":2,"t":1}"#);
        assert_ok(&a);
        assert_eq!(
            a.get("profile").unwrap().get("name").unwrap().as_str(),
            Some("measured-native")
        );
    }

    #[test]
    fn advance_reply_attributes_tenant_and_batch_size() {
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"t","domain":[8,8],"dtype":"double",
                "tenant":"acme","threads":1}"#,
        ));
        let a = req(&state, r#"{"op":"advance","session":"t","steps":1}"#);
        assert_ok(&a);
        assert_eq!(a.get("tenant").unwrap().as_str(), Some("acme"));
        // no concurrent identical-plan job: a singleton "batch"
        assert_eq!(a.get("batched").unwrap().as_usize(), Some(1));
        let st = req(&state, r#"{"op":"stats"}"#);
        let rows = st.get("tenants").unwrap().as_arr().unwrap();
        let acme =
            rows.iter().find(|r| r.get("tenant").unwrap().as_str() == Some("acme")).unwrap();
        assert_eq!(acme.get("admitted").unwrap().as_usize(), Some(1));
        assert_eq!(acme.get("refused").unwrap().as_usize(), Some(0));
        assert_eq!(st.get("batches").unwrap().as_usize(), Some(0), "singletons are not batches");
        assert!(st.get("render").unwrap().as_str().unwrap().contains("acme"));
    }

    #[test]
    fn tiered_sessions_spill_idle_fields_and_restore_bit_exactly() {
        use crate::sim::golden;
        // A 1-byte resident cap forces every idle session out of memory
        // after each request; correctness must be unaffected.
        let s = Service::start(ServeOpts {
            workers: 1,
            resident_bytes: Some(1),
            artifacts_dir: PathBuf::from("/nonexistent-artifacts"),
            ..Default::default()
        });
        let state = s.state();
        for name in ["t1", "t2"] {
            assert_ok(&req(
                &state,
                &format!(
                    r#"{{"op":"create_session","session":"{name}","shape":"box","d":2,"r":1,
                        "dtype":"double","domain":[10,10],"backend":"native","threads":1}}"#
                ),
            ));
        }
        assert_ok(&req(&state, r#"{"op":"advance","session":"t1","steps":2,"t":2}"#));
        assert_ok(&req(&state, r#"{"op":"advance","session":"t2","steps":2,"t":2}"#));
        let st = req(&state, r#"{"op":"stats"}"#);
        assert!(st.get("spilled_bytes").unwrap().as_i64().unwrap() > 0, "{st}");
        // the second fused launch runs on a transparently restored
        // field — any codec round-trip error would corrupt it here
        assert_ok(&req(&state, r#"{"op":"advance","session":"t1","steps":2,"t":2}"#));
        let f = req(&state, r#"{"op":"fetch","session":"t1","encoding":"hex"}"#);
        assert_ok(&f);
        let got = protocol::decode_field(f.get("field").unwrap()).unwrap();
        let p = crate::model::stencil::StencilPattern::new(crate::model::stencil::Shape::Box, 2, 1)
            .unwrap();
        let w = golden::Weights::new(2, 3, p.uniform_weights());
        let mut want = golden::Field::from_vec(&[10, 10], golden::gaussian(&[10, 10]));
        for _ in 0..2 {
            want = golden::apply_fused(&want, &w, 2);
        }
        for (i, (a, b)) in got.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "point {i} after spill/restore");
        }
    }

    #[test]
    fn unmeetable_deadline_is_refused_with_predicted_completion() {
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"dl","domain":[32,32],"dtype":"double",
                "tenant":"slo","threads":1}"#,
        ));
        // a sub-microsecond deadline is below any roofline cost
        let rej =
            req(&state, r#"{"op":"advance","session":"dl","steps":4,"deadline_ms":0.000001}"#);
        assert_eq!(rej.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(rej.get("error").unwrap().as_str(), Some("deadline_unmeetable"));
        assert_eq!(rej.get("tenant").unwrap().as_str(), Some("slo"));
        let predicted = rej.get("predicted_completion_ms").unwrap().as_f64().unwrap();
        assert!(predicted > 0.000001, "refusal must carry the evidence: {rej}");
        let st = req(&state, r#"{"op":"stats"}"#);
        let rows = st.get("tenants").unwrap().as_arr().unwrap();
        let slo =
            rows.iter().find(|r| r.get("tenant").unwrap().as_str() == Some("slo")).unwrap();
        assert_eq!(slo.get("refused").unwrap().as_usize(), Some(1));
        assert_eq!(slo.get("admitted").unwrap().as_usize(), Some(0));
        // a generous deadline is admitted through the EDF urgent tier
        let ok = req(&state, r#"{"op":"advance","session":"dl","steps":1,"deadline_ms":60000}"#);
        assert_ok(&ok);
        assert_eq!(ok.get("tenant").unwrap().as_str(), Some("slo"));
    }

    #[test]
    fn shutdown_closes_the_connection_and_queue() {
        let s = svc();
        let state = s.state();
        let (resp, keep) = handle_line(&state, r#"{"op":"shutdown"}"#);
        assert!(!keep);
        assert_ok(&Json::parse_line(&resp).unwrap());
        // post-shutdown requests are refused (except shutdown itself)
        let r = req(&state, r#"{"op":"ping"}"#);
        assert_eq!(r.get("error").unwrap().as_str(), Some("shutting_down"));
    }

    #[test]
    fn alerts_verb_reports_builtin_rules_and_stats_carries_the_plane() {
        let s = svc();
        let state = s.state();
        let al = req(&state, r#"{"op":"alerts"}"#);
        assert_ok(&al);
        assert_eq!(
            al.get("rules").unwrap().as_usize(),
            Some(obs::alert::builtin_rules().len())
        );
        let rows = al.get("alerts").unwrap().as_arr().unwrap();
        // queue_saturated always evaluates (no per-label fan-out
        // needed), and an idle service must not be firing it
        let qs = rows
            .iter()
            .find(|r| r.get("rule").unwrap().as_str() == Some("queue_saturated"))
            .expect("queue_saturated row");
        assert_eq!(qs.get("firing").unwrap().as_bool(), Some(false));
        // the same rows + firing count ride in `stats`, and the prom
        // text gains the stencilctl_alerts series
        let st = req(&state, r#"{"op":"stats","prom":true}"#);
        assert_ok(&st);
        assert_eq!(st.get("alerts_firing").unwrap().as_usize(), Some(0));
        assert!(!st.get("alerts").unwrap().as_arr().unwrap().is_empty());
        let prom = st.get("prom").unwrap().as_str().unwrap();
        assert!(prom.contains("stencilctl_alerts{"), "{prom}");
        assert!(prom.contains("stencilctl_alert_transitions_total"), "{prom}");
    }

    #[test]
    fn advance_carries_an_attribution_verdict_when_obs_is_enabled() {
        let _g = crate::obs::test_lock();
        crate::obs::enable();
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"at","shape":"star","d":2,"r":1,
                "dtype":"double","domain":[48,48],"backend":"native","temporal":"blocked","threads":1}"#,
        ));
        let a = req(&state, r#"{"op":"advance","session":"at","steps":4,"t":2}"#);
        assert_ok(&a);
        let attrib = a.get("attribution").expect("attribution block");
        let verdict = attrib.get("verdict").unwrap().as_str().unwrap().to_string();
        let terms = attrib.get("terms").unwrap().as_arr().unwrap();
        assert!(!terms.is_empty());
        // every named term the verdict could cite is present and ranked
        assert!(terms
            .iter()
            .any(|t| t.get("term").unwrap().as_str() == Some(verdict.as_str())));
        // …and the per-region aggregate shows up in stats
        let st = req(&state, r#"{"op":"stats"}"#);
        assert!(st.get("attribution_jobs").unwrap().as_i64().unwrap() >= 1);
        let regions = st.get("attribution").unwrap().as_arr().unwrap();
        assert!(!regions.is_empty(), "{st}");
        assert!(regions[0].get("dominant").unwrap().as_str().is_some());
        crate::obs::disable();
        // obs disabled: the advance reply must carry no attribution
        let b = req(&state, r#"{"op":"advance","session":"at","steps":4,"t":2}"#);
        assert_ok(&b);
        assert!(b.get("attribution").is_none(), "{b}");
    }

    #[test]
    fn stats_surfaces_latency_quantile_estimates() {
        let s = svc();
        let state = s.state();
        assert_ok(&req(
            &state,
            r#"{"op":"create_session","session":"q","domain":[16,16],"dtype":"double","threads":1}"#,
        ));
        assert_ok(&req(&state, r#"{"op":"advance","session":"q","steps":2,"t":1}"#));
        let st = req(&state, r#"{"op":"stats"}"#);
        assert_ok(&st);
        // the always-on registry observed this job's queue wait and
        // phase wall, so the log₂-bucket estimates must be present
        let lat = st.get("latency").expect("latency block");
        for key in ["queue_wait_p50_ms", "queue_wait_p99_ms", "phase_wall_p50_ms"] {
            let v = lat.get(key).unwrap_or_else(|| panic!("{key} missing: {lat}"));
            assert!(v.as_f64().unwrap() > 0.0, "{key}");
        }
        let p50 = lat.get("queue_wait_p50_ms").unwrap().as_f64().unwrap();
        let p99 = lat.get("queue_wait_p99_ms").unwrap().as_f64().unwrap();
        assert!(p99 >= p50, "quantiles must be monotone: p50={p50} p99={p99}");
    }
}

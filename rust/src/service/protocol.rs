//! The NDJSON wire protocol of `stencilctl serve`.
//!
//! One JSON object per line in each direction: a client writes a
//! request line, the server answers with exactly one response line.
//! Parsing goes through [`Json::parse_line`] (`util::json`) — no new
//! dependencies.  Grammar (fields beyond `op` per operation):
//!
//! ```text
//! request        = { "op": <operation>, ... }
//! operation      = "ping" | "plan" | "create_session" | "advance"
//!                | "fetch" | "close_session" | "stats" | "metrics"
//!                | "alerts" | "shutdown"
//! plan           = jobspec
//! create_session = "session": name, jobspec,
//!                  ( "field": [f64...] | "init": "gaussian"|"zeros" )
//! advance        = "session": name, "steps": n, [ "t": depth ],
//!                  [ "temporal": "auto"|"sweep"|"blocked" ],
//!                  [ "shards": "auto"|n ], [ "deadline_ms": ms ]
//! fetch          = "session": name, [ "encoding": "num"|"hex" ]
//! close_session  = "session": name
//! stats          = [ "prom": true ]   (adds a Prometheus-text block)
//! metrics        = (no fields — replies with the Prometheus text)
//! alerts         = (no fields — evaluates the alert rules now and
//!                   replies with every rule×label row: firing state,
//!                   observed value, threshold; see obs::alert)
//! jobspec        = [ "pattern": "{shape}-{d}d{r}r[:{coeffs}]" ],
//!                  [ "shape": "box"|"star" ], [ "d": 1..3 ], [ "r": n ],
//!                  [ "coeffs": "const"|"aniso"|"varcoef"|"sparse24" ],
//!                  [ "dtype": "float"|"double" ], [ "domain": [n...]|"NxM" ],
//!                  [ "steps": n ], [ "t": depth ], [ "backend": kind ],
//!                  [ "temporal": "auto"|"sweep"|"blocked" ],
//!                  [ "shards": "auto"|n ],
//!                  [ "threads": n ], [ "weights": [f64...] ],
//!                  [ "tenant": id ], [ "deadline_ms": ms ]
//!
//! `"tenant"` names the session's owner for fair-share scheduling and
//! per-tenant accounting (default `"default"`); `"deadline_ms"` marks a
//! job SLO-bound — `advance` refuses it up front (error
//! `deadline_unmeetable`, with the roofline-predicted completion time)
//! when the model proves it cannot finish in time, and meetable
//! deadline jobs dispatch through the queue's EDF tier ahead of
//! best-effort work.
//!
//! `"pattern"` is the compact grammar (`box-2d1r`, `star-3d1r:sparse24`)
//! and takes precedence over `shape`/`d`/`r`; an explicit `"coeffs"`
//! field overrides either form's coefficient variant.  Omitted weights
//! default to the variant's canonical set (uniform, anisotropic, or
//! 2:4-pruned uniform — `StencilPattern::default_weights`).
//! response       = { "ok": true, "op": ..., ... }
//!                | { "ok": false, "op": ..., "error": code, "message": ... }
//! ```
//!
//! Instrumented `advance` responses additionally carry a `"profile"`
//! block (the machine profile's name/source/generation/stale flag) and
//! a `"drift"` block (the sample's region, its model-error EWMA, the
//! threshold, and whether it is flagged); `stats` responses carry the
//! profile identity, drift array, and `plan_cache_generation`
//! (see `tune::drift`).
//!
//! The `hex` field encoding ships each f64 as 16 hex digits of its IEEE
//! bits — bit-exact transport even for values (−0.0, non-shortest
//! decimals) a numeric round-trip could normalize.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::backend::{BackendKind, TemporalMode};
use crate::coordinator::config::RunConfig;
use crate::coordinator::grid::ShardSpec;
use crate::model::perf::Dtype;
use crate::model::stencil::{Coeffs, Shape, StencilPattern};
use crate::util::json::Json;

/// Workload description shared by `plan` and `create_session`.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub pattern: StencilPattern,
    pub dtype: Dtype,
    pub domain: Vec<usize>,
    /// Time steps per request (advance requests carry their own).
    pub steps: usize,
    /// Explicit fusion depth; `None` lets the planner choose (≤ 8).
    pub t: Option<usize>,
    pub backend: BackendKind,
    /// Temporal strategy (auto = planner-resolved via the model).
    pub temporal: TemporalMode,
    /// Shard fan-out (auto = planner-resolved via the redundancy-
    /// adjusted gain; N pins the count, 1 = monolithic).
    pub shards: ShardSpec,
    pub threads: usize,
    /// Base stencil weights; `None` = support-normalized uniform.
    pub weights: Option<Vec<f64>>,
    /// Owning tenant id — the fair-share scheduling and per-tenant
    /// accounting key (`"default"` when the client names none).
    pub tenant: String,
    /// Per-job SLO deadline in milliseconds (None = best-effort).
    pub deadline_ms: Option<f64>,
}

/// How a new session's field is initialized.
#[derive(Debug, Clone, PartialEq)]
pub enum FieldInit {
    Zeros,
    Gaussian,
    Data(Vec<f64>),
}

/// One parsed client request.
#[derive(Debug, Clone)]
pub enum Request {
    Ping,
    Plan(JobSpec),
    CreateSession { session: String, spec: JobSpec, init: FieldInit },
    Advance {
        session: String,
        steps: usize,
        t: Option<usize>,
        temporal: Option<TemporalMode>,
        shards: Option<ShardSpec>,
        /// SLO deadline for this advance (None = best-effort tier).
        deadline_ms: Option<f64>,
    },
    Fetch { session: String, hex: bool },
    CloseSession { session: String },
    Stats {
        /// Append the Prometheus exposition text as a `"prom"` field.
        prom: bool,
    },
    /// Bare Prometheus exposition (counters + histograms) — the verb a
    /// scrape-bridge sidecar polls.
    Metrics,
    /// Evaluate the alert rules now; reply with per-rule firing state
    /// (the verb `stencilctl top` and pagers poll).
    Alerts,
    Shutdown,
}

impl Request {
    /// The wire name of this request's operation.
    pub fn op(&self) -> &'static str {
        match self {
            Request::Ping => "ping",
            Request::Plan(_) => "plan",
            Request::CreateSession { .. } => "create_session",
            Request::Advance { .. } => "advance",
            Request::Fetch { .. } => "fetch",
            Request::CloseSession { .. } => "close_session",
            Request::Stats { .. } => "stats",
            Request::Metrics => "metrics",
            Request::Alerts => "alerts",
            Request::Shutdown => "shutdown",
        }
    }

    /// Parse a request object (one already-parsed NDJSON line).
    pub fn parse(j: &Json) -> Result<Request> {
        let op = j
            .get("op")?
            .as_str()
            .ok_or_else(|| anyhow!("\"op\" must be a string"))?;
        match op {
            "ping" => Ok(Request::Ping),
            "stats" => Ok(Request::Stats {
                prom: j
                    .as_obj()
                    .and_then(|o| o.get("prom"))
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false),
            }),
            "metrics" => Ok(Request::Metrics),
            "alerts" => Ok(Request::Alerts),
            "shutdown" => Ok(Request::Shutdown),
            "plan" => Ok(Request::Plan(JobSpec::parse(j)?)),
            "create_session" => {
                let session = req_str(j, "session")?;
                let spec = JobSpec::parse(j)?;
                let init = match opt_f64_vec(j, "field")? {
                    Some(v) => FieldInit::Data(v),
                    None => match opt_str(j, "init").unwrap_or("gaussian") {
                        "gaussian" => FieldInit::Gaussian,
                        "zeros" => FieldInit::Zeros,
                        other => bail!("unknown init {other:?} (want gaussian|zeros)"),
                    },
                };
                Ok(Request::CreateSession { session, spec, init })
            }
            "advance" => Ok(Request::Advance {
                session: req_str(j, "session")?,
                steps: opt_usize(j, "steps")?.unwrap_or(8),
                t: opt_usize(j, "t")?,
                temporal: opt_str(j, "temporal").map(TemporalMode::parse).transpose()?,
                shards: opt_shards(j)?,
                deadline_ms: opt_f64(j, "deadline_ms")?,
            }),
            "fetch" => Ok(Request::Fetch {
                session: req_str(j, "session")?,
                hex: matches!(opt_str(j, "encoding"), Some("hex")),
            }),
            "close_session" => Ok(Request::CloseSession { session: req_str(j, "session")? }),
            other => bail!("unknown op {other:?}"),
        }
    }
}

impl JobSpec {
    /// Parse the jobspec fields out of a request object, applying the
    /// same defaults as the CLI (`RunConfig::defaults`).
    pub fn parse(j: &Json) -> Result<JobSpec> {
        let domain = opt_domain(j, "domain")?;
        let mut pattern = match opt_str(j, "pattern") {
            Some(s) => StencilPattern::parse(s)?,
            None => {
                let d = match opt_usize(j, "d")? {
                    Some(d) => d,
                    None => domain.as_ref().map(|dm| dm.len()).unwrap_or(2),
                };
                let r = opt_usize(j, "r")?.unwrap_or(1);
                let shape = Shape::parse(opt_str(j, "shape").unwrap_or("box"))?;
                StencilPattern::new(shape, d, r)?
            }
        };
        if let Some(c) = opt_str(j, "coeffs") {
            pattern = pattern.with_coeffs(Coeffs::parse(c)?);
        }
        let domain = match domain {
            Some(dm) => dm,
            None => default_domain(pattern.d)?,
        };
        if domain.len() != pattern.d {
            bail!("domain rank {} != pattern dimensionality {}", domain.len(), pattern.d);
        }
        let dtype = Dtype::parse(opt_str(j, "dtype").unwrap_or("float"))?;
        let backend = BackendKind::parse(opt_str(j, "backend").unwrap_or("auto"))?;
        let temporal = TemporalMode::parse(opt_str(j, "temporal").unwrap_or("auto"))?;
        Ok(JobSpec {
            pattern,
            dtype,
            domain,
            steps: opt_usize(j, "steps")?.unwrap_or(8),
            t: opt_usize(j, "t")?,
            backend,
            temporal,
            shards: opt_shards(j)?.unwrap_or(ShardSpec::Auto),
            threads: opt_usize(j, "threads")?.unwrap_or(4).max(1),
            weights: opt_f64_vec(j, "weights")?,
            tenant: opt_str(j, "tenant").unwrap_or("default").to_string(),
            deadline_ms: opt_f64(j, "deadline_ms")?,
        })
    }

    /// Total domain points.
    pub fn points(&self) -> u64 {
        self.domain.iter().map(|&n| n as u64).product()
    }
}

fn default_domain(d: usize) -> Result<Vec<usize>> {
    Ok(match d {
        1 => vec![1024],
        2 => vec![256, 256],
        3 => vec![64, 64, 64],
        other => bail!("unsupported dimensionality {other}"),
    })
}

fn opt_str<'a>(j: &'a Json, k: &str) -> Option<&'a str> {
    j.as_obj().and_then(|o| o.get(k)).and_then(|v| v.as_str())
}

fn req_str(j: &Json, k: &str) -> Result<String> {
    j.get(k)?
        .as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("field {k:?} must be a string"))
}

fn opt_usize(j: &Json, k: &str) -> Result<Option<usize>> {
    match j.as_obj().and_then(|o| o.get(k)) {
        None => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| anyhow!("field {k:?} must be a non-negative integer")),
    }
}

fn opt_f64(j: &Json, k: &str) -> Result<Option<f64>> {
    match j.as_obj().and_then(|o| o.get(k)) {
        None => Ok(None),
        Some(v) => v
            .as_f64()
            .filter(|x| x.is_finite() && *x >= 0.0)
            .map(Some)
            .ok_or_else(|| anyhow!("field {k:?} must be a non-negative number")),
    }
}

/// The `"shards"` field accepts `"auto"`, a numeric count, or a
/// numeric string.
fn opt_shards(j: &Json) -> Result<Option<ShardSpec>> {
    match j.as_obj().and_then(|o| o.get("shards")) {
        None => Ok(None),
        Some(Json::Str(s)) => ShardSpec::parse(s).map(Some),
        Some(v) => {
            let n = v
                .as_usize()
                .filter(|&n| n >= 1)
                .ok_or_else(|| anyhow!("field \"shards\" must be \"auto\" or a positive integer"))?;
            Ok(Some(ShardSpec::Fixed(n)))
        }
    }
}

fn opt_f64_vec(j: &Json, k: &str) -> Result<Option<Vec<f64>>> {
    let Some(v) = j.as_obj().and_then(|o| o.get(k)) else {
        return Ok(None);
    };
    let arr = v
        .as_arr()
        .ok_or_else(|| anyhow!("field {k:?} must be an array of numbers"))?;
    let mut out = Vec::with_capacity(arr.len());
    for (i, x) in arr.iter().enumerate() {
        out.push(
            x.as_f64()
                .ok_or_else(|| anyhow!("field {k:?}[{i}] must be a number"))?,
        );
    }
    Ok(Some(out))
}

fn opt_domain(j: &Json, k: &str) -> Result<Option<Vec<usize>>> {
    let Some(v) = j.as_obj().and_then(|o| o.get(k)) else {
        return Ok(None);
    };
    match v {
        Json::Str(s) => RunConfig::parse_domain(s).map(Some),
        Json::Arr(items) => {
            let mut dims = Vec::with_capacity(items.len());
            for it in items {
                dims.push(
                    it.as_usize()
                        .filter(|&n| n > 0)
                        .ok_or_else(|| anyhow!("domain extents must be positive integers"))?,
                );
            }
            if dims.is_empty() || dims.len() > 3 {
                bail!("domain must have 1–3 extents, got {}", dims.len());
            }
            Ok(Some(dims))
        }
        _ => bail!("field {k:?} must be \"NxM\" or an array of extents"),
    }
}

/// Chainable JSON-object builder for protocol responses.
#[derive(Debug, Default)]
pub struct Obj(BTreeMap<String, Json>);

impl Obj {
    pub fn new() -> Obj {
        Obj::default()
    }

    pub fn set(mut self, k: &str, v: Json) -> Obj {
        self.0.insert(k.to_string(), v);
        self
    }

    pub fn str_(self, k: &str, v: &str) -> Obj {
        self.set(k, Json::Str(v.to_string()))
    }

    pub fn num(self, k: &str, v: f64) -> Obj {
        self.set(k, Json::Num(v))
    }

    pub fn int(self, k: &str, v: u64) -> Obj {
        self.set(k, Json::Num(v as f64))
    }

    pub fn bool_(self, k: &str, v: bool) -> Obj {
        self.set(k, Json::Bool(v))
    }

    pub fn done(self) -> Json {
        Json::Obj(self.0)
    }
}

/// Start a success response for `op`.
pub fn ok(op: &str) -> Obj {
    Obj::new().bool_("ok", true).str_("op", op)
}

/// A complete error response.
pub fn err(op: &str, code: &str, message: &str) -> Json {
    Obj::new()
        .bool_("ok", false)
        .str_("op", op)
        .str_("error", code)
        .str_("message", message)
        .done()
}

/// Serialize a field for the wire (`hex` = bit-exact IEEE-754 transport).
/// The numeric encoding falls back to hex per element for non-finite
/// values (a diverged simulation must still fetch as valid JSON).
pub fn encode_field(field: &[f64], hex: bool) -> Json {
    Json::Arr(
        field
            .iter()
            .map(|&v| {
                if hex || !v.is_finite() {
                    Json::Str(crate::util::json::hex_f64(v))
                } else {
                    Json::Num(v)
                }
            })
            .collect(),
    )
}

/// Decode a wire field (numbers and/or hex strings, mixed is fine).
pub fn decode_field(v: &Json) -> Result<Vec<f64>> {
    let arr = v.as_arr().ok_or_else(|| anyhow!("field must be an array"))?;
    arr.iter()
        .enumerate()
        .map(|(i, x)| match x {
            Json::Num(n) => Ok(*n),
            Json::Str(s) => crate::util::json::f64_from_hex(s)
                .map_err(|e| anyhow!("field[{i}]: {e:#}")),
            _ => Err(anyhow!("field[{i}] must be a number or a hex string")),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(line: &str) -> Result<Request> {
        Request::parse(&Json::parse_line(line)?)
    }

    #[test]
    fn parses_simple_ops() {
        assert!(matches!(parse(r#"{"op":"ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(parse(r#"{"op":"stats"}"#).unwrap(), Request::Stats { prom: false }));
        assert!(matches!(
            parse(r#"{"op":"stats","prom":true}"#).unwrap(),
            Request::Stats { prom: true }
        ));
        assert!(matches!(parse(r#"{"op":"metrics"}"#).unwrap(), Request::Metrics));
        assert!(matches!(parse(r#"{"op":"alerts"}"#).unwrap(), Request::Alerts));
        assert!(matches!(parse(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown));
        assert!(parse(r#"{"op":"warp"}"#).is_err());
        assert!(parse(r#"{"noop":1}"#).is_err());
    }

    #[test]
    fn jobspec_defaults_match_cli() {
        let Request::Plan(s) = parse(r#"{"op":"plan"}"#).unwrap() else {
            panic!("expected plan");
        };
        assert_eq!(s.pattern.label(), "Box-2D1R");
        assert_eq!(s.dtype, Dtype::F32);
        assert_eq!(s.domain, vec![256, 256]);
        assert_eq!(s.steps, 8);
        assert_eq!(s.backend, BackendKind::Auto);
        assert_eq!(s.temporal, TemporalMode::Auto);
        assert_eq!(s.shards, ShardSpec::Auto);
        assert_eq!(s.t, None);
    }

    #[test]
    fn jobspec_full_parse_and_domain_forms() {
        let Request::Plan(s) = parse(
            r#"{"op":"plan","shape":"star","d":3,"r":1,"dtype":"double",
                "domain":[32,32,32],"steps":12,"t":3,"backend":"native","threads":2}"#,
        )
        .unwrap() else {
            panic!("expected plan");
        };
        assert_eq!(s.pattern.label(), "Star-3D1R");
        assert_eq!(s.domain, vec![32, 32, 32]);
        assert_eq!(s.t, Some(3));
        assert_eq!(s.backend, BackendKind::Native);
        // string form + d inferred from domain rank
        let Request::Plan(s) = parse(r#"{"op":"plan","domain":"64x64x64"}"#).unwrap() else {
            panic!("expected plan");
        };
        assert_eq!(s.pattern.d, 3);
        assert_eq!(s.domain, vec![64, 64, 64]);
        // rank mismatch errors
        assert!(parse(r#"{"op":"plan","d":2,"domain":[8,8,8]}"#).is_err());
        assert!(parse(r#"{"op":"plan","domain":[8,0]}"#).is_err());
    }

    #[test]
    fn jobspec_pattern_grammar_and_coeffs() {
        use crate::model::stencil::Coeffs;
        // compact grammar takes precedence over shape/d/r
        let Request::Plan(s) =
            parse(r#"{"op":"plan","pattern":"star-3d1r:sparse24","shape":"box","d":2}"#).unwrap()
        else {
            panic!("expected plan");
        };
        assert_eq!(s.pattern.label(), "Star-3D1R:sparse24");
        assert_eq!(s.pattern.coeffs, Coeffs::Sparse24);
        assert_eq!(s.domain, vec![64, 64, 64], "default domain follows the pattern's d");
        // standalone coeffs field applies to the shape/d/r form…
        let Request::Plan(s) = parse(r#"{"op":"plan","coeffs":"varcoef"}"#).unwrap() else {
            panic!("expected plan");
        };
        assert_eq!(s.pattern.label(), "Box-2D1R:varcoef");
        // …and overrides the grammar's suffix
        let Request::Plan(s) =
            parse(r#"{"op":"plan","pattern":"box-2d1r:sparse24","coeffs":"aniso"}"#).unwrap()
        else {
            panic!("expected plan");
        };
        assert_eq!(s.pattern.coeffs, Coeffs::Aniso);
        assert!(parse(r#"{"op":"plan","pattern":"hex-2d1r"}"#).is_err());
        assert!(parse(r#"{"op":"plan","coeffs":"random"}"#).is_err());
    }

    #[test]
    fn create_session_inits() {
        let Request::CreateSession { session, init, .. } =
            parse(r#"{"op":"create_session","session":"a","field":[1,2,3]}"#).unwrap()
        else {
            panic!("expected create_session");
        };
        assert_eq!(session, "a");
        assert_eq!(init, FieldInit::Data(vec![1.0, 2.0, 3.0]));
        let Request::CreateSession { init, .. } =
            parse(r#"{"op":"create_session","session":"b","init":"zeros"}"#).unwrap()
        else {
            panic!("expected create_session");
        };
        assert_eq!(init, FieldInit::Zeros);
        let Request::CreateSession { init, .. } =
            parse(r#"{"op":"create_session","session":"c"}"#).unwrap()
        else {
            panic!("expected create_session");
        };
        assert_eq!(init, FieldInit::Gaussian);
        assert!(parse(r#"{"op":"create_session"}"#).is_err()); // name required
        assert!(parse(r#"{"op":"create_session","session":"d","init":"ones"}"#).is_err());
    }

    #[test]
    fn advance_and_fetch_parse() {
        let Request::Advance { session, steps, t, temporal, shards, deadline_ms } =
            parse(r#"{"op":"advance","session":"a","steps":4,"t":2}"#).unwrap()
        else {
            panic!("expected advance");
        };
        assert_eq!((session.as_str(), steps, t), ("a", 4, Some(2)));
        assert_eq!(temporal, None);
        assert_eq!(shards, None);
        assert_eq!(deadline_ms, None);
        let Request::Advance { temporal, shards, .. } =
            parse(r#"{"op":"advance","session":"a","steps":4,"temporal":"blocked","shards":3}"#)
                .unwrap()
        else {
            panic!("expected advance");
        };
        assert_eq!(temporal, Some(TemporalMode::Blocked));
        assert_eq!(shards, Some(ShardSpec::Fixed(3)));
        let Request::Advance { shards, .. } =
            parse(r#"{"op":"advance","session":"a","shards":"auto"}"#).unwrap()
        else {
            panic!("expected advance");
        };
        assert_eq!(shards, Some(ShardSpec::Auto));
        assert!(parse(r#"{"op":"advance","session":"a","temporal":"warp"}"#).is_err());
        assert!(parse(r#"{"op":"advance","session":"a","shards":0}"#).is_err());
        assert!(parse(r#"{"op":"advance","session":"a","shards":"many"}"#).is_err());
        let Request::Plan(s) = parse(r#"{"op":"plan","shards":"2"}"#).unwrap() else {
            panic!("expected plan");
        };
        assert_eq!(s.shards, ShardSpec::Fixed(2));
        let Request::Plan(s) =
            parse(r#"{"op":"plan","temporal":"sweep"}"#).unwrap()
        else {
            panic!("expected plan");
        };
        assert_eq!(s.temporal, TemporalMode::Sweep);
        let Request::Fetch { hex, .. } =
            parse(r#"{"op":"fetch","session":"a","encoding":"hex"}"#).unwrap()
        else {
            panic!("expected fetch");
        };
        assert!(hex);
        let Request::Fetch { hex, .. } = parse(r#"{"op":"fetch","session":"a"}"#).unwrap() else {
            panic!("expected fetch");
        };
        assert!(!hex);
    }

    #[test]
    fn tenant_and_deadline_parse() {
        // jobspec default tenant, no deadline
        let Request::Plan(s) = parse(r#"{"op":"plan"}"#).unwrap() else {
            panic!("expected plan");
        };
        assert_eq!(s.tenant, "default");
        assert_eq!(s.deadline_ms, None);
        // explicit tenant + deadline on a jobspec
        let Request::Plan(s) =
            parse(r#"{"op":"plan","tenant":"acme","deadline_ms":12.5}"#).unwrap()
        else {
            panic!("expected plan");
        };
        assert_eq!(s.tenant, "acme");
        assert_eq!(s.deadline_ms, Some(12.5));
        // per-advance deadline
        let Request::Advance { deadline_ms, .. } =
            parse(r#"{"op":"advance","session":"a","steps":2,"deadline_ms":250}"#).unwrap()
        else {
            panic!("expected advance");
        };
        assert_eq!(deadline_ms, Some(250.0));
        // malformed deadlines are rejected, not silently dropped
        assert!(parse(r#"{"op":"advance","session":"a","deadline_ms":-1}"#).is_err());
        assert!(parse(r#"{"op":"advance","session":"a","deadline_ms":"soon"}"#).is_err());
    }

    #[test]
    fn field_encodings_roundtrip() {
        // Shortest-roundtrip decimals are bit-exact for ordinary values…
        let field = vec![0.1 + 0.2, 1.0 / 3.0, 5e-324, 42.0];
        for hex in [false, true] {
            let wire = encode_field(&field, hex).to_string();
            let back = decode_field(&Json::parse_line(&wire).unwrap()).unwrap();
            assert_eq!(back.len(), field.len());
            for (a, b) in field.iter().zip(&back) {
                assert_eq!(a.to_bits(), b.to_bits(), "hex={hex}");
            }
        }
        // …but only hex preserves −0.0 (the integer fast path prints "0").
        let wire = encode_field(&[-0.0], true).to_string();
        let back = decode_field(&Json::parse_line(&wire).unwrap()).unwrap();
        assert_eq!(back[0].to_bits(), (-0.0f64).to_bits());
        // a diverged field (inf/NaN) still fetches as valid JSON: the
        // numeric encoding falls back to hex per non-finite element
        let diverged = [1.5, f64::INFINITY, f64::NAN];
        let wire = encode_field(&diverged, false).to_string();
        let back = decode_field(&Json::parse_line(&wire).unwrap()).unwrap();
        for (a, b) in diverged.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(decode_field(&Json::parse_line(r#"["zz"]"#).unwrap()).is_err());
        assert!(decode_field(&Json::parse_line("7").unwrap()).is_err());
    }

    #[test]
    fn response_builders_shape() {
        let r = ok("plan").int("t", 3).num("ms", 1.5).done().to_string();
        let j = Json::parse_line(&r).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("t").unwrap().as_usize(), Some(3));
        let e = err("advance", "admission", "over budget");
        assert_eq!(e.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(e.get("error").unwrap().as_str(), Some("admission"));
    }
}

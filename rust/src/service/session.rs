//! The session store: named domain fields resident across requests.
//!
//! A session owns the mutable state a one-shot `stencilctl run` would
//! rebuild every invocation — the field buffer, the kernel weights, the
//! workload identity — so clients stream `advance` calls instead of
//! re-uploading state.  Sessions are `Arc<Mutex<_>>`: the store hands
//! out handles, a worker holds the lock only while advancing, and two
//! sessions never contend with each other.
//!
//! **Tiering** (`--resident-bytes`): with a resident-bytes cap
//! configured, idle sessions spill their field to disk through the
//! bit-exact hex-f64 codec ([`crate::service::protocol::encode_field`])
//! and restore transparently on next use — LRU by logical use order,
//! victims chosen among non-busy resident sessions.  A spilled field
//! restores to the identical bit pattern, so tiered and always-resident
//! serving produce byte-identical results; tenant count is bounded by
//! disk, not RAM.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{BackendKind, TemporalMode};
use crate::coordinator::grid::ShardSpec;
use crate::coordinator::metrics::{SessionRow, SessionStats};
use crate::model::perf::Dtype;
use crate::model::stencil::StencilPattern;
use crate::obs::{self, Payload, SpanKind};
use crate::sim::golden;
use crate::util::json::Json;

use super::protocol::{self, FieldInit, JobSpec};

/// One resident workload: identity + field + accounting.
#[derive(Debug, Clone)]
pub struct Session {
    pub name: String,
    /// Owning tenant (admission fairness + per-tenant stats attribution).
    pub tenant: String,
    pub pattern: StencilPattern,
    pub dtype: Dtype,
    pub domain: Vec<usize>,
    pub backend: BackendKind,
    /// Session-default temporal strategy (advance requests may
    /// override per call).
    pub temporal: TemporalMode,
    /// Session-default shard fan-out (advance requests may override).
    pub shards: ShardSpec,
    pub threads: usize,
    /// Base stencil weights over the (2r+1)^d hull.
    pub weights: Vec<f64>,
    /// The resident field (row-major f64 host representation).
    pub field: Vec<f64>,
    /// A sharded advance is in flight: the field has been checked out
    /// into the shard executor, so concurrent jobs must be refused
    /// instead of seeing an empty buffer.
    pub busy: bool,
    /// Resolved row-kernel name of the most recent advance (empty until
    /// a run resolves one) — surfaced through the `stats` rendering.
    pub kernel: String,
    pub stats: SessionStats,
    /// Logical-clock stamp of the most recent use (LRU spill order).
    pub last_used: u64,
    /// Bytes parked on disk when spilled; 0 while the field is resident.
    pub spilled_bytes: u64,
    /// Lifetime spill / restore counts (surfaced through `stats`).
    pub spills: u64,
    pub restores: u64,
}

impl Session {
    /// Build a session from a create request, validating field/weight
    /// shapes against the pattern and domain.
    pub fn create(name: &str, spec: &JobSpec, init: &FieldInit) -> Result<Session> {
        let n: usize = spec.domain.iter().product();
        let field = match init {
            FieldInit::Zeros => vec![0.0; n],
            FieldInit::Gaussian => golden::gaussian(&spec.domain),
            FieldInit::Data(v) => {
                if v.len() != n {
                    bail!("field has {} elements, domain wants {n}", v.len());
                }
                v.clone()
            }
        };
        let side = 2 * spec.pattern.r + 1;
        let hull = side.pow(spec.pattern.d as u32);
        let weights = match &spec.weights {
            Some(w) => {
                if w.len() != hull {
                    bail!("weights length {} != hull size {hull}", w.len());
                }
                w.clone()
            }
            None => spec.pattern.default_weights(),
        };
        Ok(Session {
            name: name.to_string(),
            tenant: spec.tenant.clone(),
            pattern: spec.pattern,
            dtype: spec.dtype,
            domain: spec.domain.clone(),
            backend: spec.backend,
            temporal: spec.temporal,
            shards: spec.shards,
            threads: spec.threads,
            weights,
            field,
            busy: false,
            kernel: String::new(),
            stats: SessionStats::default(),
            last_used: 0,
            spilled_bytes: 0,
            spills: 0,
            restores: 0,
        })
    }

    /// Total domain points.
    pub fn points(&self) -> u64 {
        self.domain.iter().map(|&n| n as u64).product()
    }

    /// Host bytes held by the resident field (0 while spilled).
    pub fn resident_bytes(&self) -> u64 {
        (self.field.len() * std::mem::size_of::<f64>()) as u64
    }

    /// The field currently lives on disk, not in memory.
    pub fn is_spilled(&self) -> bool {
        self.spilled_bytes > 0
    }

    /// This session's row of the `stats` rendering.
    pub fn row(&self) -> SessionRow {
        let dims: Vec<String> = self.domain.iter().map(|d| d.to_string()).collect();
        SessionRow {
            name: self.name.clone(),
            pattern: self.pattern.label(),
            dtype: self.dtype.as_str(),
            domain: dims.join("x"),
            backend: self.backend.as_str(),
            kernel: self.kernel.clone(),
            stats: self.stats.clone(),
        }
    }
}

/// Disk-spill configuration for session tiering.
#[derive(Debug, Clone)]
pub struct TierCfg {
    /// Directory spill files live in (created on first spill).
    pub dir: PathBuf,
    /// Total resident field bytes allowed before LRU spilling kicks in.
    pub cap_bytes: u64,
}

/// Spill-file path for a session: hex-encoded name so arbitrary
/// session names stay filesystem-safe.
fn spill_path(dir: &std::path::Path, name: &str) -> PathBuf {
    use std::fmt::Write as _;
    let mut stem = String::with_capacity(name.len() * 2 + 6);
    for b in name.bytes() {
        let _ = write!(stem, "{b:02x}");
    }
    stem.push_str(".spill");
    dir.join(stem)
}

/// Concurrent name → session map.
#[derive(Debug, Default)]
pub struct SessionStore {
    inner: Mutex<BTreeMap<String, Arc<Mutex<Session>>>>,
    /// Logical clock stamping `Session::last_used` (LRU spill order).
    clock: AtomicU64,
    tier: Option<TierCfg>,
}

impl SessionStore {
    pub fn new() -> SessionStore {
        SessionStore::default()
    }

    /// A store whose resident field bytes are capped: LRU sessions
    /// beyond `cap_bytes` spill to `dir` via the hex-f64 codec.
    pub fn with_tiering(dir: PathBuf, cap_bytes: u64) -> SessionStore {
        SessionStore { tier: Some(TierCfg { dir, cap_bytes }), ..SessionStore::default() }
    }

    /// Whether a resident-bytes cap is configured.
    pub fn tiered(&self) -> bool {
        self.tier.is_some()
    }

    /// Stamp a session as just-used (call while holding its lock).
    pub fn touch(&self, s: &mut Session) {
        s.last_used = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
    }

    /// Register a new session; names are unique while live.
    pub fn create(&self, s: Session) -> Result<Arc<Mutex<Session>>> {
        let mut g = self.inner.lock().unwrap();
        if g.contains_key(&s.name) {
            bail!("session {:?} already exists", s.name);
        }
        let name = s.name.clone();
        let handle = Arc::new(Mutex::new(s));
        g.insert(name, handle.clone());
        Ok(handle)
    }

    pub fn get(&self, name: &str) -> Option<Arc<Mutex<Session>>> {
        self.inner.lock().unwrap().get(name).cloned()
    }

    /// Drop a session; returns whether it existed.  Spilled state is
    /// deleted from disk along with the session.
    pub fn remove(&self, name: &str) -> bool {
        let handle = self.inner.lock().unwrap().remove(name);
        let Some(handle) = handle else { return false };
        if let Some(tier) = &self.tier {
            let g = handle.lock().unwrap();
            if g.is_spilled() {
                let _ = std::fs::remove_file(spill_path(&tier.dir, &g.name));
            }
        }
        true
    }

    /// Bring a spilled session's field back into memory (no-op when
    /// already resident).  The round-trip uses the hex-f64 codec, so
    /// the restored field is bit-identical to the spilled one.
    pub fn ensure_resident(&self, s: &mut Session) -> Result<()> {
        if !s.is_spilled() {
            return Ok(());
        }
        let tier = self
            .tier
            .as_ref()
            .ok_or_else(|| anyhow!("session {:?} is spilled but tiering is off", s.name))?;
        let t0 = obs::now_ns();
        let path = spill_path(&tier.dir, &s.name);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("restore of session {:?} from {}", s.name, path.display()))?;
        let field = protocol::decode_field(&Json::parse_line(text.trim())?)
            .with_context(|| format!("restore of session {:?}", s.name))?;
        let n: usize = s.domain.iter().product();
        if field.len() != n {
            bail!("spill file for session {:?} has {} elements, domain wants {n}", s.name, field.len());
        }
        let bytes = s.spilled_bytes;
        s.field = field;
        s.spilled_bytes = 0;
        s.restores += 1;
        let _ = std::fs::remove_file(&path);
        obs::record(
            SpanKind::Restore,
            t0,
            obs::now_ns(),
            Payload::Restore { session: s.name.clone(), bytes },
        );
        obs::journal::emit(
            "restore",
            &[
                ("session", Json::Str(s.name.clone())),
                ("bytes", Json::Num(bytes as f64)),
            ],
        );
        Ok(())
    }

    /// Write a session's field to disk and release the host buffer.
    /// Caller holds the session lock and has checked it is resident
    /// and not busy.
    fn spill_session(&self, tier: &TierCfg, s: &mut Session) -> Result<()> {
        let t0 = obs::now_ns();
        std::fs::create_dir_all(&tier.dir)
            .with_context(|| format!("creating spill dir {}", tier.dir.display()))?;
        let path = spill_path(&tier.dir, &s.name);
        let encoded = protocol::encode_field(&s.field, true);
        std::fs::write(&path, format!("{encoded}\n"))
            .with_context(|| format!("spill of session {:?} to {}", s.name, path.display()))?;
        let bytes = s.resident_bytes();
        s.spilled_bytes = bytes;
        s.field = Vec::new();
        s.spills += 1;
        obs::record(
            SpanKind::Spill,
            t0,
            obs::now_ns(),
            Payload::Spill { session: s.name.clone(), bytes },
        );
        obs::journal::emit(
            "spill",
            &[
                ("session", Json::Str(s.name.clone())),
                ("bytes", Json::Num(bytes as f64)),
            ],
        );
        Ok(())
    }

    /// Enforce the resident-bytes cap: spill least-recently-used
    /// resident sessions until total resident bytes fit.  Busy
    /// sessions (field checked out into a shard executor) are skipped.
    /// A spill failure (e.g. disk full) logs and leaves the session
    /// resident — tiering degrades to the untied behavior rather than
    /// losing state.
    pub fn enforce(&self) {
        let Some(tier) = &self.tier else { return };
        let handles: Vec<Arc<Mutex<Session>>> =
            self.inner.lock().unwrap().values().cloned().collect();
        let mut resident_total = 0u64;
        let mut candidates: Vec<(u64, u64, Arc<Mutex<Session>>)> = Vec::new();
        for h in &handles {
            let g = h.lock().unwrap();
            resident_total += g.resident_bytes();
            if !g.busy && !g.is_spilled() && !g.field.is_empty() {
                candidates.push((g.last_used, g.resident_bytes(), h.clone()));
            }
        }
        if resident_total <= tier.cap_bytes {
            return;
        }
        candidates.sort_by_key(|c| c.0); // oldest stamp first
        for (_, bytes, h) in candidates {
            if resident_total <= tier.cap_bytes {
                break;
            }
            let mut g = h.lock().unwrap();
            if g.busy || g.is_spilled() || g.field.is_empty() {
                continue; // state moved under us; re-checked under lock
            }
            match self.spill_session(tier, &mut g) {
                Ok(()) => resident_total -= bytes,
                Err(e) => eprintln!("stencilctl: session spill failed: {e:#}"),
            }
        }
    }

    /// Per-tenant (resident, spilled) field bytes across live sessions.
    pub fn tenant_bytes(&self) -> BTreeMap<String, (u64, u64)> {
        let handles: Vec<Arc<Mutex<Session>>> =
            self.inner.lock().unwrap().values().cloned().collect();
        let mut out: BTreeMap<String, (u64, u64)> = BTreeMap::new();
        for h in handles {
            let g = h.lock().unwrap();
            let e = out.entry(g.tenant.clone()).or_default();
            e.0 += g.resident_bytes();
            e.1 += g.spilled_bytes;
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stats rows for every live session (name order).
    pub fn rows(&self) -> Vec<SessionRow> {
        let handles: Vec<Arc<Mutex<Session>>> =
            self.inner.lock().unwrap().values().cloned().collect();
        handles.iter().map(|h| h.lock().unwrap().row()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::stencil::Shape;

    fn spec(domain: Vec<usize>) -> JobSpec {
        JobSpec {
            pattern: StencilPattern::new(Shape::Star, domain.len(), 1).unwrap(),
            dtype: Dtype::F64,
            domain,
            steps: 4,
            t: None,
            backend: BackendKind::Native,
            temporal: TemporalMode::Auto,
            shards: ShardSpec::Auto,
            threads: 1,
            weights: None,
            tenant: "default".into(),
            deadline_ms: None,
        }
    }

    fn tier_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("tcs-spill-{}-{tag}", std::process::id()))
    }

    #[test]
    fn create_validates_shapes() {
        let s = Session::create("a", &spec(vec![8, 8]), &FieldInit::Zeros).unwrap();
        assert_eq!(s.field.len(), 64);
        assert_eq!(s.weights.len(), 9); // (2r+1)^d hull
        assert_eq!(s.points(), 64);
        // uniform weights are support-normalized: star has 5 live cells
        let live: Vec<f64> = s.weights.iter().copied().filter(|&w| w != 0.0).collect();
        assert_eq!(live.len(), 5);
        assert!((live.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // bad field length
        assert!(Session::create("b", &spec(vec![8, 8]), &FieldInit::Data(vec![0.0; 3])).is_err());
        // bad weights length
        let mut sp = spec(vec![8, 8]);
        sp.weights = Some(vec![1.0; 4]);
        assert!(Session::create("c", &sp, &FieldInit::Zeros).is_err());
    }

    #[test]
    fn default_weights_follow_the_coeff_variant() {
        use crate::model::stencil::Coeffs;
        // sparse24: omitted weights default to uniform over the PRUNED
        // support, so the executor dispatches the pruned-tap arity.
        let mut sp = spec(vec![8, 8]);
        sp.pattern = StencilPattern::new(Shape::Box, 2, 1)
            .unwrap()
            .with_coeffs(Coeffs::Sparse24);
        let s = Session::create("s24", &sp, &FieldInit::Zeros).unwrap();
        let live: Vec<f64> = s.weights.iter().copied().filter(|&w| w != 0.0).collect();
        assert_eq!(live.len(), 5, "2:4 pruning keeps 5 of box-2d1r's 9 taps");
        assert!((live.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gaussian_init_matches_golden() {
        let s = Session::create("g", &spec(vec![6, 6]), &FieldInit::Gaussian).unwrap();
        assert_eq!(s.field, golden::gaussian(&[6, 6]));
    }

    #[test]
    fn store_enforces_unique_names() {
        let store = SessionStore::new();
        assert!(store.is_empty());
        store.create(Session::create("a", &spec(vec![4, 4]), &FieldInit::Zeros).unwrap()).unwrap();
        assert!(store
            .create(Session::create("a", &spec(vec![4, 4]), &FieldInit::Zeros).unwrap())
            .is_err());
        assert_eq!(store.len(), 1);
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
        assert!(store.remove("a"));
        assert!(!store.remove("a"));
        assert!(store.is_empty());
    }

    #[test]
    fn rows_snapshot_identity() {
        let store = SessionStore::new();
        store.create(Session::create("s1", &spec(vec![4, 4]), &FieldInit::Zeros).unwrap()).unwrap();
        let rows = store.rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].name, "s1");
        assert_eq!(rows[0].pattern, "Star-2D1R");
        assert_eq!(rows[0].domain, "4x4");
        assert_eq!(rows[0].backend, "native");
        assert_eq!(rows[0].stats.jobs, 0);
    }

    #[test]
    fn spill_restore_is_bit_exact() {
        let dir = tier_dir("roundtrip");
        let store = SessionStore::with_tiering(dir.clone(), 0);
        assert!(store.tiered());
        let h = store
            .create(Session::create("s", &spec(vec![6, 6]), &FieldInit::Gaussian).unwrap())
            .unwrap();
        let before = h.lock().unwrap().field.clone();
        store.enforce();
        {
            let mut g = h.lock().unwrap();
            assert!(g.is_spilled());
            assert_eq!(g.spilled_bytes, 36 * 8);
            assert!(g.field.is_empty());
            assert_eq!(g.resident_bytes(), 0);
            store.ensure_resident(&mut g).unwrap();
            assert!(!g.is_spilled());
            assert_eq!(g.spills, 1);
            assert_eq!(g.restores, 1);
            let bits_before: Vec<u64> = before.iter().map(|v| v.to_bits()).collect();
            let bits_after: Vec<u64> = g.field.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_before, bits_after, "hex-f64 round-trip must be bit-exact");
        }
        store.remove("s");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn enforce_spills_lru_first_and_skips_busy() {
        let dir = tier_dir("lru");
        // cap fits exactly one 4x4 f64 field (128 bytes) of the three.
        let store = SessionStore::with_tiering(dir.clone(), 128);
        let mk = |n: &str| {
            store.create(Session::create(n, &spec(vec![4, 4]), &FieldInit::Zeros).unwrap()).unwrap()
        };
        let (a, b, c) = (mk("a"), mk("b"), mk("c"));
        store.touch(&mut a.lock().unwrap()); // a oldest...
        store.touch(&mut b.lock().unwrap());
        store.touch(&mut c.lock().unwrap()); // ...c newest
        b.lock().unwrap().busy = true; // checked out: not spillable
        store.enforce();
        assert!(a.lock().unwrap().is_spilled(), "LRU session spills first");
        assert!(!b.lock().unwrap().is_spilled(), "busy session is never spilled");
        // a spilled (128 freed) but busy b still resident: 256 > 128,
        // so c spills too even though it is the most recent.
        assert!(c.lock().unwrap().is_spilled());
        let bytes = store.tenant_bytes();
        assert_eq!(bytes.get("default"), Some(&(128, 256)));
        // removing a spilled session deletes its spill file
        store.remove("a");
        store.remove("c");
        assert_eq!(std::fs::read_dir(&dir).map(|d| d.count()).unwrap_or(0), 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn untied_store_never_spills() {
        let store = SessionStore::new();
        assert!(!store.tiered());
        let h = store
            .create(Session::create("s", &spec(vec![8, 8]), &FieldInit::Gaussian).unwrap())
            .unwrap();
        store.enforce();
        let mut g = h.lock().unwrap();
        assert!(!g.is_spilled());
        assert_eq!(g.field.len(), 64);
        store.ensure_resident(&mut g).unwrap(); // resident: no-op
        assert_eq!(g.spills + g.restores, 0);
    }
}

//! The session store: named domain fields resident across requests.
//!
//! A session owns the mutable state a one-shot `stencilctl run` would
//! rebuild every invocation — the field buffer, the kernel weights, the
//! workload identity — so clients stream `advance` calls instead of
//! re-uploading state.  Sessions are `Arc<Mutex<_>>`: the store hands
//! out handles, a worker holds the lock only while advancing, and two
//! sessions never contend with each other.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::backend::{BackendKind, TemporalMode};
use crate::coordinator::grid::ShardSpec;
use crate::coordinator::metrics::{SessionRow, SessionStats};
use crate::model::perf::Dtype;
use crate::model::stencil::StencilPattern;
use crate::sim::golden;

use super::protocol::{FieldInit, JobSpec};

/// One resident workload: identity + field + accounting.
#[derive(Debug, Clone)]
pub struct Session {
    pub name: String,
    pub pattern: StencilPattern,
    pub dtype: Dtype,
    pub domain: Vec<usize>,
    pub backend: BackendKind,
    /// Session-default temporal strategy (advance requests may
    /// override per call).
    pub temporal: TemporalMode,
    /// Session-default shard fan-out (advance requests may override).
    pub shards: ShardSpec,
    pub threads: usize,
    /// Base stencil weights over the (2r+1)^d hull.
    pub weights: Vec<f64>,
    /// The resident field (row-major f64 host representation).
    pub field: Vec<f64>,
    /// A sharded advance is in flight: the field has been checked out
    /// into the shard executor, so concurrent jobs must be refused
    /// instead of seeing an empty buffer.
    pub busy: bool,
    /// Resolved row-kernel name of the most recent advance (empty until
    /// a run resolves one) — surfaced through the `stats` rendering.
    pub kernel: String,
    pub stats: SessionStats,
}

impl Session {
    /// Build a session from a create request, validating field/weight
    /// shapes against the pattern and domain.
    pub fn create(name: &str, spec: &JobSpec, init: &FieldInit) -> Result<Session> {
        let n: usize = spec.domain.iter().product();
        let field = match init {
            FieldInit::Zeros => vec![0.0; n],
            FieldInit::Gaussian => golden::gaussian(&spec.domain),
            FieldInit::Data(v) => {
                if v.len() != n {
                    bail!("field has {} elements, domain wants {n}", v.len());
                }
                v.clone()
            }
        };
        let side = 2 * spec.pattern.r + 1;
        let hull = side.pow(spec.pattern.d as u32);
        let weights = match &spec.weights {
            Some(w) => {
                if w.len() != hull {
                    bail!("weights length {} != hull size {hull}", w.len());
                }
                w.clone()
            }
            None => spec.pattern.default_weights(),
        };
        Ok(Session {
            name: name.to_string(),
            pattern: spec.pattern,
            dtype: spec.dtype,
            domain: spec.domain.clone(),
            backend: spec.backend,
            temporal: spec.temporal,
            shards: spec.shards,
            threads: spec.threads,
            weights,
            field,
            busy: false,
            kernel: String::new(),
            stats: SessionStats::default(),
        })
    }

    /// Total domain points.
    pub fn points(&self) -> u64 {
        self.domain.iter().map(|&n| n as u64).product()
    }

    /// This session's row of the `stats` rendering.
    pub fn row(&self) -> SessionRow {
        let dims: Vec<String> = self.domain.iter().map(|d| d.to_string()).collect();
        SessionRow {
            name: self.name.clone(),
            pattern: self.pattern.label(),
            dtype: self.dtype.as_str(),
            domain: dims.join("x"),
            backend: self.backend.as_str(),
            kernel: self.kernel.clone(),
            stats: self.stats.clone(),
        }
    }
}

/// Concurrent name → session map.
#[derive(Debug, Default)]
pub struct SessionStore {
    inner: Mutex<BTreeMap<String, Arc<Mutex<Session>>>>,
}

impl SessionStore {
    pub fn new() -> SessionStore {
        SessionStore::default()
    }

    /// Register a new session; names are unique while live.
    pub fn create(&self, s: Session) -> Result<Arc<Mutex<Session>>> {
        let mut g = self.inner.lock().unwrap();
        if g.contains_key(&s.name) {
            bail!("session {:?} already exists", s.name);
        }
        let name = s.name.clone();
        let handle = Arc::new(Mutex::new(s));
        g.insert(name, handle.clone());
        Ok(handle)
    }

    pub fn get(&self, name: &str) -> Option<Arc<Mutex<Session>>> {
        self.inner.lock().unwrap().get(name).cloned()
    }

    /// Drop a session; returns whether it existed.
    pub fn remove(&self, name: &str) -> bool {
        self.inner.lock().unwrap().remove(name).is_some()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stats rows for every live session (name order).
    pub fn rows(&self) -> Vec<SessionRow> {
        let handles: Vec<Arc<Mutex<Session>>> =
            self.inner.lock().unwrap().values().cloned().collect();
        handles.iter().map(|h| h.lock().unwrap().row()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::stencil::Shape;

    fn spec(domain: Vec<usize>) -> JobSpec {
        JobSpec {
            pattern: StencilPattern::new(Shape::Star, domain.len(), 1).unwrap(),
            dtype: Dtype::F64,
            domain,
            steps: 4,
            t: None,
            backend: BackendKind::Native,
            temporal: TemporalMode::Auto,
            shards: ShardSpec::Auto,
            threads: 1,
            weights: None,
        }
    }

    #[test]
    fn create_validates_shapes() {
        let s = Session::create("a", &spec(vec![8, 8]), &FieldInit::Zeros).unwrap();
        assert_eq!(s.field.len(), 64);
        assert_eq!(s.weights.len(), 9); // (2r+1)^d hull
        assert_eq!(s.points(), 64);
        // uniform weights are support-normalized: star has 5 live cells
        let live: Vec<f64> = s.weights.iter().copied().filter(|&w| w != 0.0).collect();
        assert_eq!(live.len(), 5);
        assert!((live.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // bad field length
        assert!(Session::create("b", &spec(vec![8, 8]), &FieldInit::Data(vec![0.0; 3])).is_err());
        // bad weights length
        let mut sp = spec(vec![8, 8]);
        sp.weights = Some(vec![1.0; 4]);
        assert!(Session::create("c", &sp, &FieldInit::Zeros).is_err());
    }

    #[test]
    fn default_weights_follow_the_coeff_variant() {
        use crate::model::stencil::Coeffs;
        // sparse24: omitted weights default to uniform over the PRUNED
        // support, so the executor dispatches the pruned-tap arity.
        let mut sp = spec(vec![8, 8]);
        sp.pattern = StencilPattern::new(Shape::Box, 2, 1)
            .unwrap()
            .with_coeffs(Coeffs::Sparse24);
        let s = Session::create("s24", &sp, &FieldInit::Zeros).unwrap();
        let live: Vec<f64> = s.weights.iter().copied().filter(|&w| w != 0.0).collect();
        assert_eq!(live.len(), 5, "2:4 pruning keeps 5 of box-2d1r's 9 taps");
        assert!((live.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gaussian_init_matches_golden() {
        let s = Session::create("g", &spec(vec![6, 6]), &FieldInit::Gaussian).unwrap();
        assert_eq!(s.field, golden::gaussian(&[6, 6]));
    }

    #[test]
    fn store_enforces_unique_names() {
        let store = SessionStore::new();
        assert!(store.is_empty());
        store.create(Session::create("a", &spec(vec![4, 4]), &FieldInit::Zeros).unwrap()).unwrap();
        assert!(store
            .create(Session::create("a", &spec(vec![4, 4]), &FieldInit::Zeros).unwrap())
            .is_err());
        assert_eq!(store.len(), 1);
        assert!(store.get("a").is_some());
        assert!(store.get("b").is_none());
        assert!(store.remove("a"));
        assert!(!store.remove("a"));
        assert!(store.is_empty());
    }

    #[test]
    fn rows_snapshot_identity() {
        let store = SessionStore::new();
        store.create(Session::create("s1", &spec(vec![4, 4]), &FieldInit::Zeros).unwrap()).unwrap();
        let rows = store.rows();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].name, "s1");
        assert_eq!(rows[0].pattern, "Star-2D1R");
        assert_eq!(rows[0].domain, "4x4");
        assert_eq!(rows[0].backend, "native");
        assert_eq!(rows[0].stats.jobs, 0);
    }
}

//! The plan cache: memoized planner results keyed by [`PlanKey`].
//!
//! Planning is pure (`planner::plan` is a function of the request and
//! the manifest — see [`PlanKey`]'s contract), so the service runs the
//! candidate enumeration + roofline scoring once per distinct workload
//! and serves every subsequent identical request from the cache.  FIFO
//! eviction bounds memory; hit/miss counters feed the `stats` op.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::planner::{self, Plan, PlanKey, Request};
use crate::runtime::manifest::Manifest;

#[derive(Debug, Default)]
struct Inner {
    map: HashMap<PlanKey, Arc<Plan>>,
    order: VecDeque<PlanKey>,
    hits: u64,
    misses: u64,
}

/// Bounded, thread-safe memo of planner decisions.
#[derive(Debug)]
pub struct PlanCache {
    cap: usize,
    inner: Mutex<Inner>,
}

impl PlanCache {
    pub fn new(cap: usize) -> PlanCache {
        PlanCache { cap: cap.max(1), inner: Mutex::new(Inner::default()) }
    }

    /// Plan through the cache; returns the plan and whether it was a hit.
    ///
    /// The lock is dropped while the planner runs: a race between two
    /// misses on the same key costs one redundant (pure) computation,
    /// never a wrong answer — the first insert wins.
    pub fn plan(
        &self,
        req: &Request,
        domain: &[usize],
        manifest: Option<&Manifest>,
    ) -> Result<(Arc<Plan>, bool)> {
        let key = req.plan_key(domain);
        {
            let mut g = self.inner.lock().unwrap();
            let cached = g.map.get(&key).cloned();
            if let Some(p) = cached {
                g.hits += 1;
                return Ok((p, true));
            }
        }
        let plan = Arc::new(planner::plan(req, manifest)?);
        let mut g = self.inner.lock().unwrap();
        g.misses += 1;
        if !g.map.contains_key(&key) {
            if g.map.len() >= self.cap {
                if let Some(old) = g.order.pop_front() {
                    g.map.remove(&old);
                }
            }
            g.map.insert(key.clone(), plan.clone());
            g.order.push_back(key);
        }
        Ok((plan, false))
    }

    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::hardware::Gpu;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn req(shape: Shape, d: usize, r: usize) -> Request {
        Request {
            pattern: StencilPattern::new(shape, d, r).unwrap(),
            dtype: Dtype::F32,
            steps: 8,
            gpu: Gpu::a100(),
            backend: BackendKind::Auto,
            max_t: 8,
            temporal: crate::backend::TemporalMode::Auto,
        }
    }

    #[test]
    fn second_identical_request_hits() {
        let cache = PlanCache::new(8);
        let r = req(Shape::Box, 2, 1);
        let (p1, hit1) = cache.plan(&r, &[256, 256], None).unwrap();
        assert!(!hit1);
        let (p2, hit2) = cache.plan(&r, &[256, 256], None).unwrap();
        assert!(hit2);
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the cached Arc");
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
    }

    #[test]
    fn distinct_workloads_do_not_alias() {
        let cache = PlanCache::new(8);
        let (_, h1) = cache.plan(&req(Shape::Box, 2, 1), &[256, 256], None).unwrap();
        let (_, h2) = cache.plan(&req(Shape::Star, 2, 1), &[256, 256], None).unwrap();
        let (_, h3) = cache.plan(&req(Shape::Box, 2, 1), &[128, 128], None).unwrap();
        assert!(!h1 && !h2 && !h3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn capacity_bounds_entries_fifo() {
        let cache = PlanCache::new(2);
        cache.plan(&req(Shape::Box, 2, 1), &[16, 16], None).unwrap();
        cache.plan(&req(Shape::Box, 2, 2), &[16, 16], None).unwrap();
        cache.plan(&req(Shape::Box, 2, 3), &[16, 16], None).unwrap(); // evicts r=1
        assert_eq!(cache.len(), 2);
        let (_, hit) = cache.plan(&req(Shape::Box, 2, 1), &[16, 16], None).unwrap();
        assert!(!hit, "evicted entry must be recomputed");
        let (_, hit) = cache.plan(&req(Shape::Box, 2, 3), &[16, 16], None).unwrap();
        assert!(hit, "resident entry still served");
    }

    #[test]
    fn planner_errors_are_not_cached() {
        let cache = PlanCache::new(4);
        let mut r = req(Shape::Box, 2, 1);
        r.backend = BackendKind::Pjrt; // no manifest -> no candidates
        assert!(cache.plan(&r, &[16, 16], None).is_err());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.misses(), 0, "failed plans count neither way");
    }
}

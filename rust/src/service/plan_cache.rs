//! The plan cache: memoized planner results keyed by [`PlanKey`].
//!
//! Planning is pure (`planner::plan` is a function of the request and
//! the manifest — see [`PlanKey`]'s contract), so the service runs the
//! candidate enumeration + roofline scoring once per distinct workload
//! and serves every subsequent identical request from the cache.  LRU
//! eviction bounds memory — a hit refreshes the entry's recency, so a
//! steady working set survives one-off workloads passing through —
//! and hit/miss/eviction counters feed the `serve` stats op.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::planner::{self, Plan, PlanKey, Request};
use crate::runtime::manifest::Manifest;

#[derive(Debug)]
struct Entry {
    plan: Arc<Plan>,
    /// Logical clock of the last touch — recency without a list, so
    /// the hit path stays a single O(1) hash probe (eviction pays the
    /// O(len) argmin scan instead, and only on a full-cache miss).
    used: u64,
}

#[derive(Debug, Default)]
struct Inner {
    map: HashMap<PlanKey, Entry>,
    /// Monotonic logical clock feeding `Entry::used`.
    seq: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    /// Bumped by every [`PlanCache::clear`] — how many times the whole
    /// cache was invalidated (profile drift / install).
    generation: u64,
    /// Counter values at the last [`PlanCache::stats_window`] call —
    /// the baseline the since-last-snapshot deltas are computed from.
    last_hits: u64,
    last_misses: u64,
    last_evictions: u64,
}

/// Point-in-time cache counters for the `stats` op.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub len: usize,
    /// Whole-cache invalidations so far (see [`PlanCache::clear`]) —
    /// profile-driven invalidation made observable in `stats` replies.
    pub generation: u64,
    /// Hits since the previous `stats` snapshot window closed
    /// ([`PlanCache::stats_window`]) — a recent-activity view the
    /// lifetime totals can't give once they grow large.
    pub d_hits: u64,
    /// Misses since the previous snapshot window closed.
    pub d_misses: u64,
    /// Evictions since the previous snapshot window closed.
    pub d_evictions: u64,
}

/// Bounded, thread-safe LRU memo of planner decisions.
#[derive(Debug)]
pub struct PlanCache {
    cap: usize,
    inner: Mutex<Inner>,
}

impl PlanCache {
    pub fn new(cap: usize) -> PlanCache {
        PlanCache { cap: cap.max(1), inner: Mutex::new(Inner::default()) }
    }

    /// Plan through the cache; returns the plan and whether it was a hit.
    ///
    /// The lock is dropped while the planner runs: a race between two
    /// misses on the same key costs one redundant (pure) computation,
    /// never a wrong answer — the first insert stands.  Each entry is
    /// implicitly stamped with the cache generation observed when its
    /// miss began: if [`PlanCache::clear`] ran while the planner was
    /// scoring (profile drift flagged / fresh constants installed),
    /// the finished plan was scored under superseded constants — it is
    /// still returned to its caller (that request already raced the
    /// invalidation either way) but NOT memoized, so no post-clear hit
    /// can ever serve a pre-clear plan.
    pub fn plan(&self, req: &Request, manifest: Option<&Manifest>) -> Result<(Arc<Plan>, bool)> {
        let key = req.plan_key();
        let gen0 = {
            let mut g = self.inner.lock().unwrap();
            let inner = &mut *g;
            inner.seq += 1;
            let seq = inner.seq;
            if let Some(e) = inner.map.get_mut(&key) {
                e.used = seq;
                let p = e.plan.clone();
                inner.hits += 1;
                return Ok((p, true));
            }
            inner.generation
        };
        let plan = Arc::new(planner::plan(req, manifest)?);
        let mut g = self.inner.lock().unwrap();
        let inner = &mut *g;
        inner.misses += 1;
        inner.seq += 1;
        let seq = inner.seq;
        if inner.generation != gen0 {
            // invalidated mid-plan: serve, don't memoize
            return Ok((plan, false));
        }
        if let Some(e) = inner.map.get_mut(&key) {
            // racing miss lost: the first insert stands, refresh recency
            e.used = seq;
        } else {
            if inner.map.len() >= self.cap {
                let victim =
                    inner.map.iter().min_by_key(|(_, e)| e.used).map(|(k, _)| k.clone());
                if let Some(old) = victim {
                    inner.map.remove(&old);
                    inner.evictions += 1;
                }
            }
            inner.map.insert(key, Entry { plan: plan.clone(), used: seq });
        }
        Ok((plan, false))
    }

    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }

    /// Entries evicted by the LRU bound so far.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// Drop every cached plan and bump the cache generation.  This is
    /// the profile-invalidation hook: when drift stales the machine
    /// profile (or a recalibrated one is installed), every memoized
    /// plan was scored against constants that no longer describe the
    /// machine.  Returns the number of entries dropped.
    pub fn clear(&self) -> usize {
        let mut g = self.inner.lock().unwrap();
        let n = g.map.len();
        g.map.clear();
        g.generation += 1;
        n
    }

    /// Whole-cache invalidations so far.
    pub fn generation(&self) -> u64 {
        self.inner.lock().unwrap().generation
    }

    /// Whether the cache was invalidated after a caller observed
    /// generation `gen0`.  This is the *batched*-lookup analogue of
    /// the in-`plan` stamp check above: a batch leader records
    /// `generation()` before performing the one shared lookup, and
    /// every follower re-checks with `stale_since(gen0)` before
    /// adopting the leader's plan — if a profile install cleared the
    /// cache in between, followers fall back to their own fresh
    /// lookup instead of executing a plan scored under superseded
    /// constants.
    pub fn stale_since(&self, gen0: u64) -> bool {
        self.generation() != gen0
    }

    /// One consistent snapshot of all counters, deltas measured since
    /// the last [`PlanCache::stats_window`].  Pure: reading stats from
    /// a side channel (the `metrics` verb, tests) does not move the
    /// delta baseline out from under the `stats` op.
    pub fn stats(&self) -> CacheStats {
        let g = self.inner.lock().unwrap();
        Self::stats_of(&g)
    }

    /// Like [`PlanCache::stats`], but also closes the delta window:
    /// the returned deltas cover activity since the previous
    /// `stats_window` call, and the baseline advances so the next call
    /// starts fresh.  The `stats` protocol op uses this — consecutive
    /// `stats` replies report disjoint windows.
    pub fn stats_window(&self) -> CacheStats {
        let mut g = self.inner.lock().unwrap();
        let s = Self::stats_of(&g);
        g.last_hits = g.hits;
        g.last_misses = g.misses;
        g.last_evictions = g.evictions;
        s
    }

    fn stats_of(g: &Inner) -> CacheStats {
        CacheStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            len: g.map.len(),
            generation: g.generation,
            d_hits: g.hits - g.last_hits,
            d_misses: g.misses - g.last_misses,
            d_evictions: g.evictions - g.last_evictions,
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::coordinator::grid::ShardSpec;
    use crate::hardware::Gpu;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn req(shape: Shape, d: usize, r: usize) -> Request {
        req_domain(shape, d, r, vec![256, 256])
    }

    fn req_domain(shape: Shape, d: usize, r: usize, domain: Vec<usize>) -> Request {
        Request {
            pattern: StencilPattern::new(shape, d, r).unwrap(),
            dtype: Dtype::F32,
            domain,
            steps: 8,
            gpu: Gpu::a100(),
            backend: BackendKind::Auto,
            max_t: 8,
            temporal: crate::backend::TemporalMode::Auto,
            shards: ShardSpec::Auto,
            lanes: 2,
            threads: 4,
            kernels: crate::backend::kernels::KernelMode::Auto,
            kernel_peaks: Vec::new(),
        }
    }

    #[test]
    fn second_identical_request_hits() {
        let cache = PlanCache::new(8);
        let r = req(Shape::Box, 2, 1);
        let (p1, hit1) = cache.plan(&r, None).unwrap();
        assert!(!hit1);
        let (p2, hit2) = cache.plan(&r, None).unwrap();
        assert!(hit2);
        assert!(Arc::ptr_eq(&p1, &p2), "hit must return the cached Arc");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.len), (1, 1, 0, 1));
        assert_eq!(s.generation, 0);
    }

    #[test]
    fn clear_empties_and_bumps_the_generation() {
        let cache = PlanCache::new(8);
        cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        cache.plan(&req(Shape::Star, 2, 1), None).unwrap();
        assert_eq!(cache.clear(), 2);
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.generation(), 1);
        // the next identical request re-plans (a miss, not a hit)
        let (_, hit) = cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        assert!(!hit, "cleared entries must be re-planned");
        // hit/miss/eviction history survives a clear; generation counts up
        let s = cache.stats();
        assert_eq!((s.misses, s.len, s.generation), (3, 1, 1));
        assert_eq!(cache.clear(), 1);
        assert_eq!(cache.stats().generation, 2);
    }

    #[test]
    fn distinct_workloads_do_not_alias() {
        let cache = PlanCache::new(8);
        let (_, h1) = cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        let (_, h2) = cache.plan(&req(Shape::Star, 2, 1), None).unwrap();
        let (_, h3) = cache
            .plan(&req_domain(Shape::Box, 2, 1, vec![128, 128]), None)
            .unwrap();
        assert!(!h1 && !h2 && !h3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn capacity_bounds_entries_lru() {
        let cache = PlanCache::new(2);
        cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        cache.plan(&req(Shape::Box, 2, 2), None).unwrap();
        // touch r=1 → r=2 becomes least-recently-used
        let (_, hit) = cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        assert!(hit);
        cache.plan(&req(Shape::Box, 2, 3), None).unwrap(); // evicts r=2, NOT r=1
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        let (_, hit) = cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        assert!(hit, "recently-used entry must survive the eviction");
        let (_, hit) = cache.plan(&req(Shape::Box, 2, 2), None).unwrap();
        assert!(!hit, "LRU entry must have been evicted");
        assert_eq!(cache.evictions(), 2); // r=2's reinsert evicted r=3
    }

    #[test]
    fn stats_deltas_cover_disjoint_windows() {
        let cache = PlanCache::new(8);
        cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        // pure stats() reads the window without closing it
        let s = cache.stats();
        assert_eq!((s.d_hits, s.d_misses), (1, 1));
        let s = cache.stats();
        assert_eq!((s.d_hits, s.d_misses), (1, 1), "stats() must not move the baseline");
        // stats_window() reports the same window, then closes it
        let s = cache.stats_window();
        assert_eq!((s.d_hits, s.d_misses, s.d_evictions), (1, 1, 0));
        let s = cache.stats_window();
        assert_eq!((s.d_hits, s.d_misses), (0, 0), "window must reset");
        assert_eq!((s.hits, s.misses), (1, 1), "lifetime totals keep counting");
        // new activity lands in the fresh window only
        cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        let s = cache.stats_window();
        assert_eq!((s.hits, s.d_hits), (2, 1));
    }

    #[test]
    fn batched_lookup_invalidation_contract() {
        // A batch leader stamps the generation before its one shared
        // lookup; a clear() landing while members gather must be
        // visible to every follower through stale_since().
        let cache = PlanCache::new(8);
        let gen0 = cache.generation();
        let (plan, _) = cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        assert!(!cache.stale_since(gen0), "no clear: followers may adopt the leader's plan");
        cache.clear(); // profile install while the batch gathers
        assert!(cache.stale_since(gen0), "followers must re-plan, not adopt");
        // the fallback lookup is a fresh miss under the new generation
        let gen1 = cache.generation();
        let (replan, hit) = cache.plan(&req(Shape::Box, 2, 1), None).unwrap();
        assert!(!hit);
        assert!(!cache.stale_since(gen1));
        assert!(!Arc::ptr_eq(&plan, &replan), "pre-clear plan is never served post-clear");
    }

    #[test]
    fn planner_errors_are_not_cached() {
        let cache = PlanCache::new(4);
        let mut r = req(Shape::Box, 2, 1);
        r.backend = BackendKind::Pjrt; // no manifest -> no candidates
        assert!(cache.plan(&r, None).is_err());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.misses(), 0, "failed plans count neither way");
        assert_eq!(cache.stats(), CacheStats::default());
    }
}

//! Model-guided admission: the paper's pre-execution go/no-go test as a
//! serving policy.
//!
//! The paper's criteria classify a workload's bottleneck region *before*
//! executing it; a server can therefore predict a job's runtime from the
//! plan's roofline scoring and refuse (or downgrade) work that would
//! blow its latency budget — reporting the classification, not just
//! "no".  Decision order for a job over `points × steps`:
//!
//! 1. no budget configured → accept at the requested/planned depth;
//! 2. predicted wall time within budget → accept;
//! 3. some other scored candidate fits → downgrade to the cheapest
//!    fitting fusion depth (the response says so — fused-launch
//!    semantics differ at domain boundaries, so this is never silent);
//! 4. nothing fits → reject, citing the predicted time, the budget, and
//!    the paper's scenario classification of the chosen candidate.
//!
//! Layered on top, [`TenantSched`] turns the same roofline cost into a
//! multi-tenant policy: deficit-round-robin over per-tenant served
//! milliseconds (a hog is deferred once it runs a quantum past the
//! active tenants' fair share), plus an earliest-deadline-first tier
//! for jobs carrying `deadline_ms` — meetable deadlines jump the FIFO,
//! provably unmeetable ones are refused up front with the predicted
//! completion time as evidence.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::backend::TemporalMode;
use crate::coordinator::planner::{Candidate, Plan};
use crate::model::roofline::Bound;
use crate::sim::exec;

/// The admission controller's verdict for one `advance` request.
#[derive(Debug, Clone)]
pub enum Decision {
    Accept {
        t: usize,
        /// Resolved temporal strategy of the admitted candidate (the
        /// blocked-path prediction when the planner chose blocked).
        temporal: TemporalMode,
        /// Resolved shard fan-out (1 = monolithic; >1 only when the
        /// planner's redundancy-adjusted gain chose a sharded
        /// candidate).
        shards: usize,
        predicted_ms: f64,
        engine: String,
        target: &'static str,
    },
    Downgrade {
        from_t: usize,
        t: usize,
        /// Resolved temporal strategy of the downgraded-to candidate.
        temporal: TemporalMode,
        /// Resolved shard fan-out of the downgraded-to candidate.
        shards: usize,
        predicted_ms: f64,
        /// What the requested depth would have cost.
        requested_ms: f64,
        engine: String,
        target: &'static str,
    },
    Reject(Rejection),
}

/// A refusal, carrying the model's reasoning.
#[derive(Debug, Clone)]
pub struct Rejection {
    pub predicted_ms: f64,
    pub budget_ms: f64,
    pub engine: String,
    pub bound: &'static str,
    /// Paper classification (scenario label / bound on unit).
    pub classification: String,
}

fn wall_ms(c: &Candidate, points: u64, steps: usize, t: usize) -> f64 {
    exec::wall_time(&c.prediction, points, steps, t.max(1)) * 1e3
}

/// Deficit-round-robin quantum: how far past the active tenants' fair
/// share one tenant's served milliseconds may run before admission
/// defers its next job under queue pressure.
pub const DRR_QUANTUM_MS: f64 = 50.0;

/// A tenant counts as active while it arrived within this many total
/// arrivals — long-gone tenants stop diluting the fair share.
const ACTIVE_WINDOW: u64 = 256;

/// Evidence attached to a fair-share deferral.
#[derive(Debug, Clone)]
pub struct FairShare {
    pub tenant: String,
    /// Roofline milliseconds this tenant has been served so far.
    pub served_ms: f64,
    /// Mean served milliseconds across active tenants.
    pub fair_share_ms: f64,
    pub quantum_ms: f64,
}

/// Evidence attached to a deadline refusal: the roofline-predicted
/// completion time that proves the deadline unmeetable.
#[derive(Debug, Clone)]
pub struct DeadlineVerdict {
    pub deadline_ms: f64,
    /// Predicted completion: admitted backlog drained across workers,
    /// plus this job's own roofline cost.
    pub predicted_completion_ms: f64,
    pub backlog_ms: f64,
    pub cost_ms: f64,
}

/// [`TenantSched::admit`]'s verdict.
#[derive(Debug, Clone)]
pub enum TenantVerdict {
    /// Run it.  `urgent` routes the job through the EDF tier ahead of
    /// the FIFO; `predicted_completion_ms` is the roofline estimate
    /// used for the deadline check (backlog/workers + own cost).
    Admit { urgent: bool, predicted_completion_ms: f64 },
    /// Deficit-round-robin deferral: the tenant is a quantum past the
    /// active fair share while the queue is under pressure.
    OverShare(FairShare),
    /// `deadline_ms` is provably unmeetable given the admitted backlog.
    Unmeetable(DeadlineVerdict),
}

#[derive(Debug, Default)]
struct TenantState {
    served_ms: f64,
    last_seen: u64,
}

#[derive(Debug, Default)]
struct SchedInner {
    tenants: BTreeMap<String, TenantState>,
    /// Total arrivals — the logical clock behind `last_seen`.
    arrivals: u64,
    /// Roofline milliseconds admitted but not yet completed.
    backlog_ms: f64,
}

/// Deficit-round-robin + EDF admission across tenants, priced by the
/// same roofline `wall_ms` the budget check uses.  All state is
/// model-predicted milliseconds, so the policy is deterministic and
/// testable without a clock.
#[derive(Debug)]
pub struct TenantSched {
    inner: Mutex<SchedInner>,
    workers: usize,
}

impl TenantSched {
    pub fn new(workers: usize) -> TenantSched {
        TenantSched { inner: Mutex::new(SchedInner::default()), workers: workers.max(1) }
    }

    /// Decide one job of roofline cost `cost_ms` for `tenant`.
    ///
    /// `pressured` is the caller's queue-pressure signal (DRR only
    /// defers when there is contention to arbitrate — an idle server
    /// admits everyone).  Deadline jobs skip DRR entirely: a meetable
    /// deadline is admitted urgent, an unmeetable one refused.
    pub fn admit(
        &self,
        tenant: &str,
        cost_ms: f64,
        deadline_ms: Option<f64>,
        pressured: bool,
    ) -> TenantVerdict {
        let mut g = self.inner.lock().unwrap();
        g.arrivals += 1;
        let now = g.arrivals;
        let e = g.tenants.entry(tenant.to_string()).or_default();
        e.last_seen = now;
        let predicted_completion_ms = g.backlog_ms / self.workers as f64 + cost_ms;
        if let Some(deadline) = deadline_ms {
            if predicted_completion_ms > deadline {
                return TenantVerdict::Unmeetable(DeadlineVerdict {
                    deadline_ms: deadline,
                    predicted_completion_ms,
                    backlog_ms: g.backlog_ms,
                    cost_ms,
                });
            }
            g.charge(tenant, cost_ms);
            return TenantVerdict::Admit { urgent: true, predicted_completion_ms };
        }
        if pressured {
            let (total, n) = g
                .tenants
                .values()
                .filter(|t| now - t.last_seen <= ACTIVE_WINDOW)
                .fold((0.0, 0usize), |(s, n), t| (s + t.served_ms, n + 1));
            let fair_share_ms = total / n.max(1) as f64;
            let served_ms = g.tenants[tenant].served_ms;
            if served_ms > fair_share_ms + DRR_QUANTUM_MS {
                return TenantVerdict::OverShare(FairShare {
                    tenant: tenant.to_string(),
                    served_ms,
                    fair_share_ms,
                    quantum_ms: DRR_QUANTUM_MS,
                });
            }
        }
        g.charge(tenant, cost_ms);
        TenantVerdict::Admit { urgent: false, predicted_completion_ms }
    }

    /// A previously admitted job finished (or failed): drain its
    /// roofline cost from the backlog.
    pub fn complete(&self, cost_ms: f64) {
        let mut g = self.inner.lock().unwrap();
        g.backlog_ms = (g.backlog_ms - cost_ms).max(0.0);
    }

    /// Admitted-but-uncompleted roofline milliseconds (observability).
    pub fn backlog_ms(&self) -> f64 {
        self.inner.lock().unwrap().backlog_ms
    }
}

impl SchedInner {
    fn charge(&mut self, tenant: &str, cost_ms: f64) {
        self.backlog_ms += cost_ms;
        if let Some(t) = self.tenants.get_mut(tenant) {
            t.served_ms += cost_ms;
        }
    }
}

/// Decide whether an `advance` of `steps` over `points` may run.
///
/// `requested_t` is the client's explicit fusion depth (None = the
/// planner's choice); `budget_ms` is the service's per-job latency
/// budget (None = accept everything).
pub fn decide(
    plan: &Plan,
    requested_t: Option<usize>,
    points: u64,
    steps: usize,
    budget_ms: Option<f64>,
) -> Decision {
    let all: Vec<&Candidate> =
        std::iter::once(&plan.chosen).chain(plan.alternatives.iter()).collect();
    let t0 = requested_t.unwrap_or(plan.chosen.t).max(1);
    // The plan's candidate list is already preference-sorted (highest
    // throughput first, sweep before blocked on exact ties), so the
    // first candidate at the requested depth is the one the planner
    // would execute — including its temporal resolution, which is how
    // admission uses the blocked-path prediction whenever the model
    // says blocking is faster.  Falls back to the chosen candidate's
    // prediction when t0 was never scored.
    let c0: &Candidate = all.iter().find(|c| c.t == t0).copied().unwrap_or(&plan.chosen);
    let ms0 = wall_ms(c0, points, steps, t0);
    let Some(budget) = budget_ms else {
        return Decision::Accept {
            t: t0,
            temporal: c0.temporal,
            shards: c0.shards,
            predicted_ms: ms0,
            engine: c0.engine.name.to_string(),
            target: c0.target.as_str(),
        };
    };
    if ms0 <= budget {
        return Decision::Accept {
            t: t0,
            temporal: c0.temporal,
            shards: c0.shards,
            predicted_ms: ms0,
            engine: c0.engine.name.to_string(),
            target: c0.target.as_str(),
        };
    }
    let best_fit = all
        .iter()
        .map(|&c| (c, wall_ms(c, points, steps, c.t)))
        .filter(|(_, ms)| *ms <= budget)
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    if let Some((c, ms)) = best_fit {
        if c.t != t0 {
            return Decision::Downgrade {
                from_t: t0,
                t: c.t,
                temporal: c.temporal,
                shards: c.shards,
                predicted_ms: ms,
                requested_ms: ms0,
                engine: c.engine.name.to_string(),
                target: c.target.as_str(),
            };
        }
    }
    let classification = match &plan.vs_cuda {
        Some(cmp) => format!("{} ({:?})", cmp.scenario.label(), cmp.verdict),
        None => format!(
            "{:?}-bound on {}",
            c0.prediction.bound,
            c0.engine.unit.as_str()
        ),
    };
    Decision::Reject(Rejection {
        predicted_ms: ms0,
        budget_ms: budget,
        engine: c0.engine.name.to_string(),
        bound: match c0.prediction.bound {
            Bound::Memory => "memory-bound",
            Bound::Compute => "compute-bound",
        },
        classification,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::coordinator::planner::{self, Request};
    use crate::hardware::Gpu;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn plan(dtype: Dtype) -> Plan {
        let req = Request {
            pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
            dtype,
            domain: vec![256, 256],
            steps: 8,
            gpu: Gpu::a100(),
            backend: BackendKind::Auto,
            max_t: 8,
            temporal: crate::backend::TemporalMode::Auto,
            shards: crate::coordinator::grid::ShardSpec::Auto,
            lanes: 2,
            threads: 4,
            kernels: crate::backend::kernels::KernelMode::Auto,
            kernel_peaks: Vec::new(),
        };
        planner::plan(&req, None).unwrap()
    }

    #[test]
    fn no_budget_accepts_at_planned_depth() {
        let p = plan(Dtype::F32);
        match decide(&p, None, 1 << 16, 8, None) {
            Decision::Accept { t, temporal, shards, predicted_ms, .. } => {
                assert_eq!(t, p.chosen.t);
                assert_eq!(temporal, p.chosen.temporal);
                assert_ne!(temporal, TemporalMode::Auto, "must be resolved");
                assert_eq!(shards, p.chosen.shards);
                assert!(predicted_ms > 0.0);
            }
            other => panic!("expected accept, got {other:?}"),
        }
    }

    #[test]
    fn generous_budget_accepts_explicit_depth() {
        let p = plan(Dtype::F32);
        match decide(&p, Some(2), 1 << 16, 8, Some(1e9)) {
            Decision::Accept { t, .. } => assert_eq!(t, 2),
            other => panic!("expected accept, got {other:?}"),
        }
    }

    #[test]
    fn zero_budget_rejects_with_classification() {
        let p = plan(Dtype::F32);
        match decide(&p, None, 1 << 20, 64, Some(0.0)) {
            Decision::Reject(r) => {
                assert!(r.predicted_ms > 0.0);
                assert_eq!(r.budget_ms, 0.0);
                assert!(!r.engine.is_empty());
                assert!(
                    r.classification.contains("Scenario") || r.classification.contains("bound"),
                    "classification must cite the model: {}",
                    r.classification
                );
            }
            other => panic!("expected reject, got {other:?}"),
        }
    }

    #[test]
    fn tight_budget_downgrades_an_explicit_depth() {
        // steps=1 at t=8 pays a whole 8-fold fused launch; t=1 pays one
        // step.  A budget between the two must downgrade, not reject.
        let p = plan(Dtype::F64);
        let points = 1u64 << 22;
        let all: Vec<&Candidate> =
            std::iter::once(&p.chosen).chain(p.alternatives.iter()).collect();
        let ms_of = |t: usize| {
            all.iter()
                .filter(|c| c.t == t)
                .map(|&c| wall_ms(c, points, 1, t))
                .fold(f64::INFINITY, f64::min)
        };
        let expensive = {
            let c = all
                .iter()
                .filter(|c| c.t == 8)
                .max_by(|a, b| {
                    a.prediction.throughput.partial_cmp(&b.prediction.throughput).unwrap()
                })
                .copied();
            match c {
                Some(c) => wall_ms(c, points, 1, 8),
                None => wall_ms(&p.chosen, points, 1, 8),
            }
        };
        let cheap = (1..=8).map(ms_of).fold(f64::INFINITY, f64::min);
        assert!(cheap < expensive, "need a separable budget window");
        let budget = (cheap + expensive) / 2.0;
        match decide(&p, Some(8), points, 1, Some(budget)) {
            Decision::Downgrade { from_t, t, predicted_ms, requested_ms, .. } => {
                assert_eq!(from_t, 8);
                assert_ne!(t, 8);
                assert!(predicted_ms <= budget);
                assert!(requested_ms > budget);
            }
            other => panic!("expected downgrade, got {other:?}"),
        }
    }

    fn admitted(v: &TenantVerdict) -> bool {
        matches!(v, TenantVerdict::Admit { .. })
    }

    #[test]
    fn sole_tenant_is_never_deferred() {
        let sched = TenantSched::new(2);
        for _ in 0..100 {
            assert!(admitted(&sched.admit("only", 10.0, None, true)));
        }
    }

    #[test]
    fn drr_defers_the_hog_until_shares_converge() {
        let sched = TenantSched::new(1);
        // tenant A hogs the server while alone: all admitted.
        for _ in 0..40 {
            assert!(admitted(&sched.admit("a", 10.0, None, true)));
        }
        // B arrives under pressure: fair share is (400+0)/2 = 200, so A
        // (served 400) is a quantum past it and must be deferred...
        match sched.admit("a", 10.0, None, true) {
            TenantVerdict::OverShare(fs) => {
                assert_eq!(fs.tenant, "a");
                assert!(fs.served_ms > fs.fair_share_ms + fs.quantum_ms);
            }
            // ...but only once B is active; B's first arrival is below.
            TenantVerdict::Admit { .. } => {}
            other => panic!("unexpected verdict {other:?}"),
        }
        assert!(admitted(&sched.admit("b", 10.0, None, true)), "starved tenant admitted");
        // From here B is admitted and A deferred until B's served share
        // converges to within a quantum of A's.
        let (mut a_ok, mut b_ok) = (0, 0);
        for _ in 0..60 {
            if admitted(&sched.admit("a", 10.0, None, true)) {
                a_ok += 1;
            }
            if admitted(&sched.admit("b", 10.0, None, true)) {
                b_ok += 1;
            }
        }
        assert!(b_ok > a_ok, "starved tenant must catch up: a={a_ok} b={b_ok}");
        // convergence: both within a quantum of the common fair share
        // once B has caught up, so late rounds admit both.
        assert!(admitted(&sched.admit("b", 10.0, None, true)));
        assert!(admitted(&sched.admit("a", 10.0, None, true)));
    }

    #[test]
    fn unpressured_queue_admits_everyone() {
        let sched = TenantSched::new(1);
        for _ in 0..50 {
            assert!(admitted(&sched.admit("hog", 100.0, None, false)));
        }
    }

    #[test]
    fn edf_refuses_unmeetable_deadline_with_evidence() {
        let sched = TenantSched::new(1);
        // build 300ms of admitted backlog
        for _ in 0..3 {
            assert!(admitted(&sched.admit("a", 100.0, None, false)));
        }
        match sched.admit("b", 50.0, Some(200.0), false) {
            TenantVerdict::Unmeetable(v) => {
                assert_eq!(v.deadline_ms, 200.0);
                assert_eq!(v.backlog_ms, 300.0);
                assert_eq!(v.cost_ms, 50.0);
                assert_eq!(v.predicted_completion_ms, 350.0);
            }
            other => panic!("expected unmeetable, got {other:?}"),
        }
        // the refused job is NOT charged to the backlog
        assert_eq!(sched.backlog_ms(), 300.0);
        // a meetable deadline is admitted into the urgent tier
        match sched.admit("b", 50.0, Some(400.0), false) {
            TenantVerdict::Admit { urgent, predicted_completion_ms } => {
                assert!(urgent);
                assert_eq!(predicted_completion_ms, 350.0);
            }
            other => panic!("expected urgent admit, got {other:?}"),
        }
    }

    #[test]
    fn completions_drain_the_backlog() {
        let sched = TenantSched::new(2);
        assert!(admitted(&sched.admit("a", 100.0, None, false)));
        assert!(admitted(&sched.admit("a", 100.0, None, false)));
        assert_eq!(sched.backlog_ms(), 200.0);
        sched.complete(100.0);
        assert_eq!(sched.backlog_ms(), 100.0);
        // backlog/workers + cost: 100/2 + 10 = 60 ≤ 60 → meetable
        assert!(admitted(&sched.admit("a", 10.0, Some(60.0), false)));
        sched.complete(100.0);
        sched.complete(100.0);
        assert_eq!(sched.backlog_ms(), 0.0, "backlog saturates at zero");
    }
}

//! Model-guided admission: the paper's pre-execution go/no-go test as a
//! serving policy.
//!
//! The paper's criteria classify a workload's bottleneck region *before*
//! executing it; a server can therefore predict a job's runtime from the
//! plan's roofline scoring and refuse (or downgrade) work that would
//! blow its latency budget — reporting the classification, not just
//! "no".  Decision order for a job over `points × steps`:
//!
//! 1. no budget configured → accept at the requested/planned depth;
//! 2. predicted wall time within budget → accept;
//! 3. some other scored candidate fits → downgrade to the cheapest
//!    fitting fusion depth (the response says so — fused-launch
//!    semantics differ at domain boundaries, so this is never silent);
//! 4. nothing fits → reject, citing the predicted time, the budget, and
//!    the paper's scenario classification of the chosen candidate.

use crate::backend::TemporalMode;
use crate::coordinator::planner::{Candidate, Plan};
use crate::model::roofline::Bound;
use crate::sim::exec;

/// The admission controller's verdict for one `advance` request.
#[derive(Debug, Clone)]
pub enum Decision {
    Accept {
        t: usize,
        /// Resolved temporal strategy of the admitted candidate (the
        /// blocked-path prediction when the planner chose blocked).
        temporal: TemporalMode,
        /// Resolved shard fan-out (1 = monolithic; >1 only when the
        /// planner's redundancy-adjusted gain chose a sharded
        /// candidate).
        shards: usize,
        predicted_ms: f64,
        engine: String,
        target: &'static str,
    },
    Downgrade {
        from_t: usize,
        t: usize,
        /// Resolved temporal strategy of the downgraded-to candidate.
        temporal: TemporalMode,
        /// Resolved shard fan-out of the downgraded-to candidate.
        shards: usize,
        predicted_ms: f64,
        /// What the requested depth would have cost.
        requested_ms: f64,
        engine: String,
        target: &'static str,
    },
    Reject(Rejection),
}

/// A refusal, carrying the model's reasoning.
#[derive(Debug, Clone)]
pub struct Rejection {
    pub predicted_ms: f64,
    pub budget_ms: f64,
    pub engine: String,
    pub bound: &'static str,
    /// Paper classification (scenario label / bound on unit).
    pub classification: String,
}

fn wall_ms(c: &Candidate, points: u64, steps: usize, t: usize) -> f64 {
    exec::wall_time(&c.prediction, points, steps, t.max(1)) * 1e3
}

/// Decide whether an `advance` of `steps` over `points` may run.
///
/// `requested_t` is the client's explicit fusion depth (None = the
/// planner's choice); `budget_ms` is the service's per-job latency
/// budget (None = accept everything).
pub fn decide(
    plan: &Plan,
    requested_t: Option<usize>,
    points: u64,
    steps: usize,
    budget_ms: Option<f64>,
) -> Decision {
    let all: Vec<&Candidate> =
        std::iter::once(&plan.chosen).chain(plan.alternatives.iter()).collect();
    let t0 = requested_t.unwrap_or(plan.chosen.t).max(1);
    // The plan's candidate list is already preference-sorted (highest
    // throughput first, sweep before blocked on exact ties), so the
    // first candidate at the requested depth is the one the planner
    // would execute — including its temporal resolution, which is how
    // admission uses the blocked-path prediction whenever the model
    // says blocking is faster.  Falls back to the chosen candidate's
    // prediction when t0 was never scored.
    let c0: &Candidate = all.iter().find(|c| c.t == t0).copied().unwrap_or(&plan.chosen);
    let ms0 = wall_ms(c0, points, steps, t0);
    let Some(budget) = budget_ms else {
        return Decision::Accept {
            t: t0,
            temporal: c0.temporal,
            shards: c0.shards,
            predicted_ms: ms0,
            engine: c0.engine.name.to_string(),
            target: c0.target.as_str(),
        };
    };
    if ms0 <= budget {
        return Decision::Accept {
            t: t0,
            temporal: c0.temporal,
            shards: c0.shards,
            predicted_ms: ms0,
            engine: c0.engine.name.to_string(),
            target: c0.target.as_str(),
        };
    }
    let best_fit = all
        .iter()
        .map(|&c| (c, wall_ms(c, points, steps, c.t)))
        .filter(|(_, ms)| *ms <= budget)
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    if let Some((c, ms)) = best_fit {
        if c.t != t0 {
            return Decision::Downgrade {
                from_t: t0,
                t: c.t,
                temporal: c.temporal,
                shards: c.shards,
                predicted_ms: ms,
                requested_ms: ms0,
                engine: c.engine.name.to_string(),
                target: c.target.as_str(),
            };
        }
    }
    let classification = match &plan.vs_cuda {
        Some(cmp) => format!("{} ({:?})", cmp.scenario.label(), cmp.verdict),
        None => format!(
            "{:?}-bound on {}",
            c0.prediction.bound,
            c0.engine.unit.as_str()
        ),
    };
    Decision::Reject(Rejection {
        predicted_ms: ms0,
        budget_ms: budget,
        engine: c0.engine.name.to_string(),
        bound: match c0.prediction.bound {
            Bound::Memory => "memory-bound",
            Bound::Compute => "compute-bound",
        },
        classification,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::coordinator::planner::{self, Request};
    use crate::hardware::Gpu;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn plan(dtype: Dtype) -> Plan {
        let req = Request {
            pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
            dtype,
            domain: vec![256, 256],
            steps: 8,
            gpu: Gpu::a100(),
            backend: BackendKind::Auto,
            max_t: 8,
            temporal: crate::backend::TemporalMode::Auto,
            shards: crate::coordinator::grid::ShardSpec::Auto,
            lanes: 2,
            threads: 4,
            kernels: crate::backend::kernels::KernelMode::Auto,
            kernel_peaks: Vec::new(),
        };
        planner::plan(&req, None).unwrap()
    }

    #[test]
    fn no_budget_accepts_at_planned_depth() {
        let p = plan(Dtype::F32);
        match decide(&p, None, 1 << 16, 8, None) {
            Decision::Accept { t, temporal, shards, predicted_ms, .. } => {
                assert_eq!(t, p.chosen.t);
                assert_eq!(temporal, p.chosen.temporal);
                assert_ne!(temporal, TemporalMode::Auto, "must be resolved");
                assert_eq!(shards, p.chosen.shards);
                assert!(predicted_ms > 0.0);
            }
            other => panic!("expected accept, got {other:?}"),
        }
    }

    #[test]
    fn generous_budget_accepts_explicit_depth() {
        let p = plan(Dtype::F32);
        match decide(&p, Some(2), 1 << 16, 8, Some(1e9)) {
            Decision::Accept { t, .. } => assert_eq!(t, 2),
            other => panic!("expected accept, got {other:?}"),
        }
    }

    #[test]
    fn zero_budget_rejects_with_classification() {
        let p = plan(Dtype::F32);
        match decide(&p, None, 1 << 20, 64, Some(0.0)) {
            Decision::Reject(r) => {
                assert!(r.predicted_ms > 0.0);
                assert_eq!(r.budget_ms, 0.0);
                assert!(!r.engine.is_empty());
                assert!(
                    r.classification.contains("Scenario") || r.classification.contains("bound"),
                    "classification must cite the model: {}",
                    r.classification
                );
            }
            other => panic!("expected reject, got {other:?}"),
        }
    }

    #[test]
    fn tight_budget_downgrades_an_explicit_depth() {
        // steps=1 at t=8 pays a whole 8-fold fused launch; t=1 pays one
        // step.  A budget between the two must downgrade, not reject.
        let p = plan(Dtype::F64);
        let points = 1u64 << 22;
        let all: Vec<&Candidate> =
            std::iter::once(&p.chosen).chain(p.alternatives.iter()).collect();
        let ms_of = |t: usize| {
            all.iter()
                .filter(|c| c.t == t)
                .map(|&c| wall_ms(c, points, 1, t))
                .fold(f64::INFINITY, f64::min)
        };
        let expensive = {
            let c = all
                .iter()
                .filter(|c| c.t == 8)
                .max_by(|a, b| {
                    a.prediction.throughput.partial_cmp(&b.prediction.throughput).unwrap()
                })
                .copied();
            match c {
                Some(c) => wall_ms(c, points, 1, 8),
                None => wall_ms(&p.chosen, points, 1, 8),
            }
        };
        let cheap = (1..=8).map(ms_of).fold(f64::INFINITY, f64::min);
        assert!(cheap < expensive, "need a separable budget window");
        let budget = (cheap + expensive) / 2.0;
        match decide(&p, Some(8), points, 1, Some(budget)) {
            Decision::Downgrade { from_t, t, predicted_ms, requested_ms, .. } => {
                assert_eq!(from_t, 8);
                assert_ne!(t, 8);
                assert!(predicted_ms <= budget);
                assert!(requested_ms > budget);
            }
            other => panic!("expected downgrade, got {other:?}"),
        }
    }
}

//! PlanKey-coalesced batch dispatch: the serving plane's answer to N
//! tenants hammering the same workload shape.
//!
//! Concurrent `advance` jobs whose planner requests hash to the same
//! [`PlanKey`] are *coalesced*: the first arrival becomes the batch
//! **leader**, gathers co-batchers for the configured window
//! (`--batch-window-ms`; 0 still coalesces arrivals that land during
//! the leader's plan resolution), performs the **one** shared
//! plan-cache lookup, and publishes the resulting [`PlanShare`] to
//! every member.  Members then run their own admission (budgets and
//! fair-share are per-job), and the admitted monolithic members
//! deposit their [`QueuedJob`]s back into the gate; whichever member
//! settles last walks away with the whole batch and pushes a single
//! [`Task::Batch`](super::queue::Task::Batch) — one queue slot-check,
//! one backend resolution, one kernel compilation, N per-job
//! [`RunMetrics`](crate::coordinator::metrics::RunMetrics).
//!
//! Correctness against concurrent invalidation: the leader stamps the
//! plan-cache generation (`gen0`) *before* its lookup.  A retune or
//! drift flag that clears the cache while the batch gathers bumps the
//! generation, and every follower re-checks
//! [`PlanCache::stale_since`](super::plan_cache::PlanCache::stale_since)
//! before adopting the share — a stale share is discarded and the
//! follower falls back to its own fresh lookup rather than executing
//! against superseded constants.
//!
//! Bit-exactness is free by construction: a batch member executes the
//! exact same `Backend::advance` on its own session field as an
//! unbatched job would — coalescing shares *resolution* work (plan,
//! backend, compile), never arithmetic.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::coordinator::planner::{Plan, PlanKey};

use super::queue::QueuedJob;

/// What the leader publishes to every member of a sealed batch: the
/// one shared plan lookup's result.
#[derive(Clone)]
pub struct PlanShare {
    pub plan: Arc<Plan>,
    /// Whether the shared lookup was a cache hit.
    pub hit: bool,
    /// Plan-cache generation observed *before* the shared lookup;
    /// members must discard the share when
    /// [`stale_since(gen0)`](super::plan_cache::PlanCache::stale_since)
    /// reports an invalidation raced the batch.
    pub gen0: u64,
    /// Member count at seal time (reported as `"batched"` in replies).
    pub members: usize,
}

struct PendState {
    /// `None` while the leader is still planning; the published share
    /// (or the leader's rendered planning error) afterwards.
    outcome: Option<Result<PlanShare, String>>,
    /// Arrivals so far; frozen into `PlanShare::members` at seal.
    members: usize,
    /// True until the leader seals — only collecting batches admit
    /// followers.
    collecting: bool,
    /// Monolithic jobs contributed by admitted members.
    deposits: Vec<QueuedJob>,
    /// Members that have not yet settled (deposited or withdrawn).
    remaining: usize,
}

/// One in-flight batch for one `PlanKey`.
pub struct Pending {
    state: Mutex<PendState>,
    cv: Condvar,
}

impl Pending {
    fn new() -> Pending {
        Pending {
            state: Mutex::new(PendState {
                outcome: None,
                members: 1, // the leader
                collecting: true,
                deposits: Vec::new(),
                remaining: 0,
            }),
            cv: Condvar::new(),
        }
    }

    /// Leader: publish the shared lookup's result and seal membership.
    /// Returns the sealed member count.
    fn seal(&self, outcome: Result<(Arc<Plan>, bool, u64), String>) -> usize {
        let mut g = self.state.lock().unwrap();
        g.collecting = false;
        let members = g.members;
        g.remaining = members;
        g.outcome =
            Some(outcome.map(|(plan, hit, gen0)| PlanShare { plan, hit, gen0, members }));
        self.cv.notify_all();
        members
    }

    /// Follower: block until the leader publishes, then adopt (or
    /// inherit the leader's planning error — an identical request
    /// would have failed identically).
    pub fn share(&self) -> Result<PlanShare, String> {
        let mut g = self.state.lock().unwrap();
        loop {
            if let Some(o) = &g.outcome {
                return o.clone();
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Member: contribute an admitted monolithic job to the coalesced
    /// dispatch.  Returns the full batch when this settle was the last
    /// one outstanding — the caller becomes the dispatcher.
    pub fn deposit(&self, q: QueuedJob) -> Option<Vec<QueuedJob>> {
        let mut g = self.state.lock().unwrap();
        g.deposits.push(q);
        Self::settle(&mut g)
    }

    /// Member: settle without contributing (refused by admission,
    /// fanned out as shards, or errored).  May still hand back the
    /// batch to dispatch — every member must settle exactly once, and
    /// the last to do so pushes whatever the others deposited.
    pub fn withdraw(&self) -> Option<Vec<QueuedJob>> {
        let mut g = self.state.lock().unwrap();
        Self::settle(&mut g)
    }

    fn settle(g: &mut PendState) -> Option<Vec<QueuedJob>> {
        debug_assert!(g.remaining > 0, "settle without seal");
        g.remaining = g.remaining.saturating_sub(1);
        if g.remaining == 0 && !g.deposits.is_empty() {
            Some(std::mem::take(&mut g.deposits))
        } else {
            None
        }
    }
}

/// What [`BatchGate::join`] made of this arrival.
pub enum Role {
    /// First arrival for the key: gathers the window, performs the one
    /// shared plan lookup, publishes via [`BatchGate::seal`].
    Leader(Arc<Pending>),
    /// Joined while a leader was collecting: adopts the published
    /// share via [`Pending::share`].
    Follower(Arc<Pending>),
}

/// The per-service coalescing gate: at most one collecting batch per
/// `PlanKey` at a time.
pub struct BatchGate {
    window: Duration,
    inner: Mutex<HashMap<PlanKey, Arc<Pending>>>,
}

impl BatchGate {
    pub fn new(window_ms: f64) -> BatchGate {
        BatchGate {
            window: Duration::from_secs_f64((window_ms.max(0.0)) / 1e3),
            inner: Mutex::new(HashMap::new()),
        }
    }

    /// The leader's gather window.
    pub fn window(&self) -> Duration {
        self.window
    }

    /// Join the pending batch for `key`, becoming leader when none is
    /// collecting.
    pub fn join(&self, key: &PlanKey) -> Role {
        let mut g = self.inner.lock().unwrap();
        if let Some(p) = g.get(key) {
            let mut s = p.state.lock().unwrap();
            if s.collecting {
                s.members += 1;
                let p = p.clone();
                drop(s);
                return Role::Follower(p);
            }
        }
        let p = Arc::new(Pending::new());
        g.insert(key.clone(), p.clone());
        Role::Leader(p)
    }

    /// Leader: publish `outcome` `(plan, hit, gen0)` and unregister the
    /// key so later arrivals start a fresh batch.  Returns the sealed
    /// member count.
    pub fn seal(
        &self,
        key: &PlanKey,
        p: &Pending,
        outcome: Result<(Arc<Plan>, bool, u64), String>,
    ) -> usize {
        let members = p.seal(outcome);
        self.inner.lock().unwrap().remove(key);
        members
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner;
    use crate::hardware::Gpu;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn preq(steps: usize) -> planner::Request {
        planner::Request {
            pattern: StencilPattern::new(Shape::Star, 2, 1).unwrap(),
            dtype: Dtype::F64,
            domain: vec![32, 32],
            steps,
            gpu: Gpu::a100(),
            backend: crate::backend::BackendKind::Native,
            max_t: 4,
            temporal: crate::backend::TemporalMode::Auto,
            shards: crate::coordinator::grid::ShardSpec::Auto,
            lanes: 2,
            threads: 1,
            kernels: crate::backend::kernels::KernelMode::Auto,
            kernel_peaks: Vec::new(),
        }
    }

    fn key(steps: usize) -> PlanKey {
        preq(steps).plan_key()
    }

    fn dummy_plan() -> Arc<Plan> {
        Arc::new(planner::plan(&preq(4), None).unwrap())
    }

    #[test]
    fn leader_then_followers_share_one_lookup() {
        let gate = BatchGate::new(0.0);
        let k = key(4);
        let Role::Leader(leader) = gate.join(&k) else {
            panic!("first arrival must lead");
        };
        let Role::Follower(f1) = gate.join(&k) else {
            panic!("second arrival must follow");
        };
        let Role::Follower(_f2) = gate.join(&k) else {
            panic!("third arrival must follow");
        };
        // A different key is its own batch.
        let Role::Leader(_other) = gate.join(&key(8)) else {
            panic!("distinct keys must not coalesce");
        };
        let plan = dummy_plan();
        let members = gate.seal(&k, &leader, Ok((plan.clone(), false, 7)));
        assert_eq!(members, 3);
        let sh = f1.share().unwrap();
        assert_eq!(sh.members, 3);
        assert_eq!(sh.gen0, 7);
        assert!(!sh.hit);
        assert!(Arc::ptr_eq(&sh.plan, &plan));
        // Sealed: the key is free again, next arrival leads anew.
        let Role::Leader(_next) = gate.join(&k) else {
            panic!("sealed batches must not admit followers");
        };
    }

    #[test]
    fn last_settler_takes_the_deposits() {
        let gate = BatchGate::new(0.0);
        let k = key(4);
        let Role::Leader(p) = gate.join(&k) else { panic!() };
        let Role::Follower(_) = gate.join(&k) else { panic!() };
        let Role::Follower(_) = gate.join(&k) else { panic!() };
        gate.seal(&k, &p, Ok((dummy_plan(), true, 0)));
        // Member 1 withdraws (say, sharded fan-out) — not last, no batch.
        assert!(p.withdraw().is_none());
        // Member 2 deposits — still one outstanding.
        let (tx, _rx) = std::sync::mpsc::channel();
        let q = crate::service::queue::test_support::queued_job(tx);
        assert!(p.deposit(q).is_none());
        // Member 3 withdraws last and inherits the dispatch.
        let batch = p.withdraw().expect("last settler takes the batch");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn leader_error_is_inherited_by_followers() {
        let gate = BatchGate::new(0.0);
        let k = key(4);
        let Role::Leader(p) = gate.join(&k) else { panic!() };
        let Role::Follower(f) = gate.join(&k) else { panic!() };
        gate.seal(&k, &p, Err("no such engine".into()));
        assert_eq!(f.share().unwrap_err(), "no such engine");
        // Error path still settles cleanly: no deposits, no dispatch.
        assert!(p.withdraw().is_none());
        assert!(f.withdraw().is_none());
    }
}

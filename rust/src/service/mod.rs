//! The service layer: `stencilctl serve`.
//!
//! Turns the one-shot CLI into a long-lived, concurrent daemon — the
//! first piece of the production serving architecture.  A newline-
//! delimited JSON protocol ([`protocol`], over TCP or stdio) fronts
//! four cooperating components:
//!
//! * [`session`] — named domain fields stay resident across requests,
//!   so clients stream `advance` calls instead of re-uploading state;
//! * [`plan_cache`] — the planner's candidate enumeration + roofline
//!   scoring memoized by [`PlanKey`](crate::coordinator::planner::PlanKey),
//!   run once per distinct workload;
//! * [`queue`] — a bounded job queue drained by a worker pool that
//!   dispatches through the [`Backend`](crate::backend::Backend) trait
//!   with per-job [`RunMetrics`](crate::coordinator::metrics::RunMetrics);
//! * [`admission`] — the paper's analytical criteria as an admission
//!   policy: jobs whose predicted runtime exceeds the budget are
//!   downgraded or refused, with the bottleneck classification in the
//!   refusal — plus the multi-tenant plane: deficit-round-robin
//!   fair-share over roofline cost and an EDF deadline tier
//!   ([`admission::TenantSched`]);
//! * [`batch`] — PlanKey-coalesced batch dispatch: concurrent jobs
//!   with identical plan keys share one plan-cache lookup, one backend
//!   resolution, and one kernel compilation, bit-identically to
//!   unbatched execution.
//!
//! [`session`] also implements bit-exact tiering: under a
//! `--resident-bytes` cap, idle sessions spill their fields to disk
//! through the lossless hex-f64 codec and are restored transparently
//! on their next `advance`/`fetch`.
//!
//! [`server`] wires them together; aggregate accounting lives in
//! [`coordinator::metrics`](crate::coordinator::metrics) and renders
//! through [`report::service_stats`](crate::report::service_stats).

pub mod admission;
pub mod batch;
pub mod plan_cache;
pub mod protocol;
pub mod queue;
pub mod server;
pub mod session;

pub use plan_cache::PlanCache;
pub use server::{Service, ServeOpts};
pub use session::{Session, SessionStore};

//! # tc-stencil — "Do We Need Tensor Cores for Stencil Computations?"
//!
//! Full reproduction of the CS.DC 2026 analysis paper: an enhanced roofline
//! performance model for stencil computations on CUDA Cores, Tensor Cores
//! and Sparse Tensor Cores, the four bottleneck-transition scenarios, the
//! analytical sweet-spot criteria — plus everything needed to *run* it:
//!
//! * [`model`] — the paper's contribution as executable math (Eq. 1–20).
//! * [`hardware`] — GPU spec registry (A100/V100/H100/…, per-dtype peaks).
//! * [`engines`] — the eight baseline implementations the paper evaluates,
//!   as engine descriptors bound to AOT-compiled kernel artifacts.
//! * [`sim`] — the calibrated execution simulator standing in for the
//!   paper's A100 testbed (FLOP/traffic counters, L2 filter, ncu facade).
//! * [`runtime`] — PJRT-CPU loader/executor for the AOT HLO artifacts
//!   (gated behind the `pjrt` cargo feature; stubbed otherwise).
//! * [`backend`] — the unified execution layer: the [`backend::Backend`]
//!   trait plus [`backend::NativeBackend`] (tiled, halo-split,
//!   multi-threaded CPU engine for any pattern/dtype/fusion depth) and
//!   [`backend::PjrtBackend`] (AOT artifacts through [`runtime`]).
//! * [`coordinator`] — planning + dispatch: planner (auto unit+fusion
//!   selection via the criteria), domain tiling + halo exchange,
//!   run/service metrics.
//! * [`service`] — the `stencilctl serve` daemon: NDJSON protocol over
//!   TCP/stdio, resident sessions, a plan cache keyed by
//!   [`coordinator::planner::PlanKey`], a bounded job queue + worker
//!   pool, and model-guided admission control.
//! * [`tune`] — the measurement-and-feedback plane: microbenchmark
//!   probes, versioned measured [`tune::profile::MachineProfile`]s the
//!   planner/admission/criteria constants resolve from, and per-region
//!   drift detection with online recalibration (`stencilctl tune`,
//!   `--profile`, `--retune`).
//! * [`obs`] — the observability plane: per-job trace ids, typed spans
//!   through admission → plan lookup → queue → shard phases → barriers
//!   → kernels recorded into a bounded flight recorder, NDJSON
//!   streaming (`--trace-out`), Chrome trace-event rendering
//!   (`stencilctl trace --chrome`), and always-on Prometheus counters
//!   + log-bucketed histograms (`stats --prom`, the `metrics` verb).
//!   Disabled by default and bit-identical to an untraced build.
//! * [`util`] — from-scratch substrates (JSON, CLI, tables, RNG, property
//!   testing, bench harness): the offline build environment vendors only
//!   the `xla` and `anyhow` crates, so these are implemented here.
//!
//! Python/JAX/Pallas exist only on the build path (`make artifacts`); this
//! crate never shells out to Python.

pub mod util;
pub mod model;
pub mod hardware;
pub mod engines;
pub mod sim;
pub mod runtime;
pub mod backend;
pub mod coordinator;
pub mod service;
pub mod tune;
pub mod obs;
pub mod report;

pub use model::stencil::{Shape, StencilPattern};
pub use model::perf::{Dtype, Workload};

//! artifacts/manifest.json — the contract between `python/compile/aot.py`
//! and the rust runtime.  One entry per AOT-compiled variant.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::model::perf::Dtype;
use crate::model::sparsity::Scheme;
use crate::model::stencil::{Shape, StencilPattern};
use crate::util::json::Json;

/// Metadata of one compiled stencil executable.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub scheme: Scheme,
    pub shape: Shape,
    pub d: usize,
    pub r: usize,
    pub t: usize,
    pub dtype: Dtype,
    pub grid: Vec<usize>,
    pub tile: Vec<usize>,
    pub halo: usize,
    pub k_points: u64,
    pub k_fused: u64,
    pub alpha: f64,
    /// Non-zero fraction of the constructed MMA operand (None for direct).
    pub sparsity_measured: Option<f64>,
    pub vmem_bytes: u64,
    pub n_outer: usize,
}

impl ArtifactMeta {
    pub fn pattern(&self) -> Result<StencilPattern> {
        StencilPattern::new(self.shape, self.d, self.r)
    }

    /// Number of grid points per execution.
    pub fn points(&self) -> u64 {
        self.grid.iter().map(|&g| g as u64).product()
    }

    /// Time steps advanced per execution.
    pub fn steps_per_exec(&self) -> usize {
        self.t * self.n_outer
    }

    fn from_json(j: &Json) -> Result<ArtifactMeta> {
        let usize_vec = |key: &str| -> Result<Vec<usize>> {
            j.get(key)?
                .as_arr()
                .ok_or_else(|| anyhow!("{key} not an array"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("{key}: bad int")))
                .collect()
        };
        let dtype = match j.get("dtype")?.as_str() {
            Some("float32") => Dtype::F32,
            Some("float64") => Dtype::F64,
            other => return Err(anyhow!("bad dtype {other:?}")),
        };
        Ok(ArtifactMeta {
            name: j.get("name")?.as_str().ok_or_else(|| anyhow!("name"))?.to_string(),
            file: j.get("file")?.as_str().ok_or_else(|| anyhow!("file"))?.to_string(),
            scheme: Scheme::parse(j.get("scheme")?.as_str().unwrap_or(""))?,
            shape: Shape::parse(j.get("shape")?.as_str().unwrap_or(""))?,
            d: j.get("d")?.as_usize().ok_or_else(|| anyhow!("d"))?,
            r: j.get("r")?.as_usize().ok_or_else(|| anyhow!("r"))?,
            t: j.get("t")?.as_usize().ok_or_else(|| anyhow!("t"))?,
            dtype,
            grid: usize_vec("grid")?,
            tile: usize_vec("tile")?,
            halo: j.get("halo")?.as_usize().ok_or_else(|| anyhow!("halo"))?,
            k_points: j.get("k_points")?.as_i64().ok_or_else(|| anyhow!("k_points"))? as u64,
            k_fused: j.get("k_fused")?.as_i64().ok_or_else(|| anyhow!("k_fused"))? as u64,
            alpha: j.get("alpha")?.as_f64().ok_or_else(|| anyhow!("alpha"))?,
            sparsity_measured: match j.get("sparsity_measured")? {
                Json::Null => None,
                v => Some(v.as_f64().ok_or_else(|| anyhow!("sparsity_measured"))?),
            },
            vmem_bytes: j.get("vmem_bytes")?.as_i64().unwrap_or(0) as u64,
            n_outer: j.get("n_outer")?.as_usize().unwrap_or(1),
        })
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<ArtifactMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let variants = j
            .get("variants")?
            .as_arr()
            .ok_or_else(|| anyhow!("variants not an array"))?
            .iter()
            .map(ArtifactMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| anyhow!("no artifact named {name:?}"))
    }

    /// Find the best-matching artifact for a request.
    pub fn find(
        &self,
        scheme: Scheme,
        shape: Shape,
        d: usize,
        r: usize,
        t: usize,
        dtype: Dtype,
    ) -> Option<&ArtifactMeta> {
        self.variants.iter().find(|v| {
            v.scheme == scheme
                && v.shape == shape
                && v.d == d
                && v.r == r
                && v.t == t
                && v.dtype == dtype
                && v.n_outer == 1
        })
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

/// Default artifact directory: $TC_STENCIL_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var("TC_STENCIL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "jax_version": "0.8.2",
      "variants": [
        {
          "name": "direct_box2d_r1_t3_f32_g64x64",
          "file": "direct_box2d_r1_t3_f32_g64x64.hlo.txt",
          "scheme": "direct", "shape": "box", "d": 2, "r": 1, "t": 3,
          "dtype": "float32", "grid": [64, 64], "tile": [32, 32],
          "halo": 3, "k_points": 9, "k_fused": 49, "alpha": 1.8148,
          "sparsity_measured": null, "vmem_bytes": 17328,
          "dtype_bytes": 4, "weights_shape": [3, 3], "n_outer": 1
        },
        {
          "name": "decompose_box2d_r1_t7_f32_g64x64",
          "file": "decompose_box2d_r1_t7_f32_g64x64.hlo.txt",
          "scheme": "decompose", "shape": "box", "d": 2, "r": 1, "t": 7,
          "dtype": "float32", "grid": [64, 64], "tile": [32, 32],
          "halo": 7, "k_points": 9, "k_fused": 225, "alpha": 3.5714,
          "sparsity_measured": 0.5, "vmem_bytes": 60000,
          "dtype_bytes": 4, "weights_shape": [3, 3], "n_outer": 1
        }
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert_eq!(m.variants.len(), 2);
        let v = m.get("direct_box2d_r1_t3_f32_g64x64").unwrap();
        assert_eq!(v.scheme, Scheme::Direct);
        assert_eq!(v.dtype, Dtype::F32);
        assert_eq!(v.grid, vec![64, 64]);
        assert_eq!(v.points(), 4096);
        assert_eq!(v.steps_per_exec(), 3);
        assert!(v.sparsity_measured.is_none());
    }

    #[test]
    fn null_vs_value_sparsity() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let v = m.get("decompose_box2d_r1_t7_f32_g64x64").unwrap();
        assert_eq!(v.sparsity_measured, Some(0.5));
    }

    #[test]
    fn find_matches_key_fields() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert!(m
            .find(Scheme::Decompose, Shape::Box, 2, 1, 7, Dtype::F32)
            .is_some());
        assert!(m.find(Scheme::Decompose, Shape::Box, 2, 1, 5, Dtype::F32).is_none());
        assert!(m.find(Scheme::Flatten, Shape::Box, 2, 1, 7, Dtype::F32).is_none());
    }

    #[test]
    fn missing_name_errors() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("/tmp"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/tmp"), "{\"variants\": [{}]}").is_err());
    }

    #[test]
    fn hlo_path_joins_dir() {
        let m = Manifest::parse(Path::new("/art"), SAMPLE).unwrap();
        let v = m.get("direct_box2d_r1_t3_f32_g64x64").unwrap();
        assert_eq!(
            m.hlo_path(v),
            PathBuf::from("/art/direct_box2d_r1_t3_f32_g64x64.hlo.txt")
        );
    }
}

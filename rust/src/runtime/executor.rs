//! PJRT executor: HLO-text → compiled executable → execution, with an
//! executable cache so each variant compiles once per process.
//!
//! Follows /opt/xla-example/load_hlo: text (not serialized proto) is the
//! interchange format; artifacts are lowered with `return_tuple=True`, so
//! results unwrap via `to_tuple1`.
//!
//! The `xla` bindings crate only exists in the artifact-enabled build
//! environment, so everything touching it is gated behind the `pjrt`
//! cargo feature.  Without the feature the same [`Runtime`] surface is
//! compiled as a stub: the manifest still loads (so `stencilctl list`
//! and planning keep working) but compilation/execution report that the
//! binary was built without PJRT — the native backend
//! ([`crate::backend::NativeBackend`]) serves those jobs instead.

use anyhow::{bail, Context, Result};

use crate::model::perf::Dtype;
use crate::runtime::manifest::{ArtifactMeta, Manifest};

/// Typed host tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    F64(Vec<f64>),
}

impl TensorData {
    pub fn dtype(&self) -> Dtype {
        match self {
            TensorData::F32(_) => Dtype::F32,
            TensorData::F64(_) => Dtype::F64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::F64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        match self {
            TensorData::F64(v) => Ok(v),
            _ => bail!("tensor is not f64"),
        }
    }

    /// Lossy view as f64 for comparisons/metrics.
    pub fn to_f64_vec(&self) -> Vec<f64> {
        match self {
            TensorData::F32(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::F64(v) => v.clone(),
        }
    }
}

/// Cumulative executor statistics (hot-path observability).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub compiles: u64,
    pub compile_ns: u64,
    pub executions: u64,
    pub execute_ns: u64,
}

fn validate_inputs(meta: &ArtifactMeta, x: &TensorData, w: &TensorData) -> Result<()> {
    let want_points = meta.points() as usize;
    if x.len() != want_points {
        bail!(
            "{}: field has {} elements, artifact wants {want_points}",
            meta.name,
            x.len()
        );
    }
    let wside = 2 * meta.r + 1;
    let want_w = wside.pow(meta.d as u32);
    if w.len() != want_w {
        bail!("{}: weights have {} elements, want {want_w}", meta.name, w.len());
    }
    if x.dtype() != meta.dtype || w.dtype() != meta.dtype {
        bail!(
            "{}: dtype mismatch (artifact {:?}, field {:?}, weights {:?})",
            meta.name,
            meta.dtype,
            x.dtype(),
            w.dtype()
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
mod client {
    use std::collections::HashMap;
    use std::path::Path;
    use std::time::Instant;

    use anyhow::{anyhow, Result};

    use super::{validate_inputs, ExecStats, TensorData};
    use crate::model::perf::Dtype;
    use crate::runtime::manifest::Manifest;

    impl TensorData {
        fn to_literal(&self, dims: &[usize]) -> Result<xla::Literal> {
            let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
            let lit = match self {
                TensorData::F32(v) => xla::Literal::vec1(v),
                TensorData::F64(v) => xla::Literal::vec1(v),
            };
            Ok(lit.reshape(&dims_i64)?)
        }
    }

    /// The PJRT runtime: client + manifest + executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
        pub stats: ExecStats,
    }

    impl Runtime {
        /// True when this build can actually execute artifacts.
        pub fn available() -> bool {
            true
        }

        /// Create a CPU-PJRT runtime over an artifact directory.
        pub fn load(dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime { client, manifest, cache: HashMap::new(), stats: ExecStats::default() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch cached) executable for a variant.
        pub fn compile(&mut self, name: &str) -> Result<()> {
            if self.cache.contains_key(name) {
                return Ok(());
            }
            let meta = self.manifest.get(name)?.clone();
            let path = self.manifest.hlo_path(&meta);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.stats.compiles += 1;
            self.stats.compile_ns += t0.elapsed().as_nanos() as u64;
            self.cache.insert(name.to_string(), exe);
            Ok(())
        }

        /// Number of executables resident in the cache.
        pub fn cached(&self) -> usize {
            self.cache.len()
        }

        /// Execute a variant: x is the flattened domain field, w the
        /// flattened (2r+1)^d weights.  Returns the output field.
        pub fn execute(&mut self, name: &str, x: &TensorData, w: &TensorData) -> Result<TensorData> {
            self.compile(name)?;
            let meta = self.manifest.get(name)?.clone();
            validate_inputs(&meta, x, w)?;
            let wside = 2 * meta.r + 1;
            let wdims = vec![wside; meta.d];
            let x_lit = x.to_literal(&meta.grid)?;
            let w_lit = w.to_literal(&wdims)?;
            let exe = self.cache.get(name).expect("compiled above");
            let t0 = Instant::now();
            let result = exe
                .execute::<xla::Literal>(&[x_lit, w_lit])
                .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
            let lit = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
            // Artifacts are lowered with return_tuple=True → 1-tuple.
            let out = lit.to_tuple1().map_err(|e| anyhow!("untupling {name}: {e:?}"))?;
            self.stats.executions += 1;
            self.stats.execute_ns += t0.elapsed().as_nanos() as u64;
            match meta.dtype {
                Dtype::F32 => Ok(TensorData::F32(
                    out.to_vec::<f32>().map_err(|e| anyhow!("read f32: {e:?}"))?,
                )),
                Dtype::F64 => Ok(TensorData::F64(
                    out.to_vec::<f64>().map_err(|e| anyhow!("read f64: {e:?}"))?,
                )),
            }
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod client {
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::{validate_inputs, ExecStats, TensorData};
    use crate::runtime::manifest::Manifest;

    /// Stub runtime compiled when the `pjrt` feature is off: the manifest
    /// is still readable, but nothing can execute.
    pub struct Runtime {
        pub manifest: Manifest,
        pub stats: ExecStats,
    }

    impl Runtime {
        /// True when this build can actually execute artifacts.
        pub fn available() -> bool {
            false
        }

        /// Load the manifest; execution members exist but always fail.
        pub fn load(dir: &Path) -> Result<Runtime> {
            let manifest = Manifest::load(dir)?;
            Ok(Runtime { manifest, stats: ExecStats::default() })
        }

        pub fn platform(&self) -> String {
            "unavailable (built without the pjrt feature)".to_string()
        }

        pub fn compile(&mut self, name: &str) -> Result<()> {
            let _ = self.manifest.get(name)?;
            bail!("cannot compile {name}: built without the `pjrt` feature (use --backend native)")
        }

        pub fn cached(&self) -> usize {
            0
        }

        pub fn execute(&mut self, name: &str, x: &TensorData, w: &TensorData) -> Result<TensorData> {
            let meta = self.manifest.get(name)?.clone();
            validate_inputs(&meta, x, w)?;
            bail!("cannot execute {name}: built without the `pjrt` feature (use --backend native)")
        }
    }
}

pub use client::Runtime;

impl Runtime {
    /// Mean execute latency in nanoseconds (0 if nothing ran yet).
    pub fn mean_execute_ns(&self) -> f64 {
        if self.stats.executions == 0 {
            0.0
        } else {
            self.stats.execute_ns as f64 / self.stats.executions as f64
        }
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("artifacts", &self.manifest.variants.len())
            .field("cached", &self.cached())
            .field("stats", &self.stats)
            .finish()
    }
}

/// Convenience: load from the default directory.
pub fn load_default() -> Result<Runtime> {
    let dir = crate::runtime::manifest::default_dir();
    Runtime::load(&dir).with_context(|| format!("loading runtime from {dir:?}"))
}

#[cfg(test)]
mod tests {
    use std::path::Path;

    use super::*;

    #[test]
    fn tensor_data_accessors() {
        let t = TensorData::F32(vec![1.0, 2.0]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.len(), 2);
        assert!(t.as_f32().is_ok());
        assert!(t.as_f64().is_err());
        assert_eq!(t.to_f64_vec(), vec![1.0, 2.0]);
    }

    #[test]
    fn f64_roundtrip_view() {
        let t = TensorData::F64(vec![1.5, -2.5]);
        assert_eq!(t.to_f64_vec(), vec![1.5, -2.5]);
        assert_eq!(t.dtype(), Dtype::F64);
    }

    #[test]
    fn validate_inputs_checks_shapes_and_dtypes() {
        let m = Manifest::parse(
            Path::new("/tmp"),
            r#"{"variants": [{
                "name": "v", "file": "v.hlo.txt", "scheme": "direct",
                "shape": "box", "d": 2, "r": 1, "t": 1, "dtype": "float32",
                "grid": [4, 4], "tile": [4, 4], "halo": 1, "k_points": 9,
                "k_fused": 9, "alpha": 1.0, "sparsity_measured": null,
                "vmem_bytes": 0, "n_outer": 1
            }]}"#,
        )
        .unwrap();
        let meta = m.get("v").unwrap();
        let good_x = TensorData::F32(vec![0.0; 16]);
        let good_w = TensorData::F32(vec![0.0; 9]);
        assert!(validate_inputs(meta, &good_x, &good_w).is_ok());
        assert!(validate_inputs(meta, &TensorData::F32(vec![0.0; 3]), &good_w).is_err());
        assert!(validate_inputs(meta, &good_x, &TensorData::F32(vec![0.0; 2])).is_err());
        assert!(validate_inputs(meta, &TensorData::F64(vec![0.0; 16]), &good_w).is_err());
    }

    // Full PJRT round-trips live in rust/tests/runtime_integration.rs
    // (they need the artifacts directory and the `pjrt` feature).
}

//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client from the rust hot path.  Python never runs here.

pub mod manifest;
pub mod executor;

pub use executor::{Runtime, TensorData};
pub use manifest::{ArtifactMeta, Manifest};

//! Fusion redundancy factor α (paper Eq. 9–10).
//!
//! α = K^(t) / (t·K) quantifies how many extra multiply-adds the
//! monolithic fused kernel executes per time step compared to sequential
//! application.  The box closed form (Eq. 10) is
//! α_box = (2rt+1)^d / (t·(2r+1)^d); for arbitrary shapes we count the
//! fused support exactly (iterated Minkowski sum — see `stencil.rs`).

use crate::model::stencil::{Shape, StencilPattern};

/// α via the exact fused-support count (valid for any shape).
pub fn alpha(pattern: &StencilPattern, t: usize) -> f64 {
    assert!(t >= 1, "fusion depth must be >= 1");
    pattern.fused_k_points(t) as f64 / (t as f64 * pattern.k_points() as f64)
}

/// α via the paper's box closed form (Eq. 10). Panics on non-box shapes.
pub fn alpha_box_closed_form(pattern: &StencilPattern, t: usize) -> f64 {
    assert_eq!(pattern.shape, Shape::Box, "closed form is box-only");
    let num = (2.0 * pattern.r as f64 * t as f64 + 1.0).powi(pattern.d as i32);
    let den = t as f64 * (2.0 * pattern.r as f64 + 1.0).powi(pattern.d as i32);
    num / den
}

/// Growth-rate exponent of α in t: O(t^(d-1)) for boxes (paper §4.1).
/// Estimated numerically as the slope of log α over log t on t ∈ [4, 32].
pub fn growth_exponent(pattern: &StencilPattern) -> f64 {
    let t_lo = 4usize;
    let t_hi = 32usize;
    let a_lo = alpha(pattern, t_lo);
    let a_hi = alpha(pattern, t_hi);
    (a_hi / a_lo).ln() / ((t_hi as f64 / t_lo as f64).ln())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::stencil::{Shape, StencilPattern};

    fn pat(shape: Shape, d: usize, r: usize) -> StencilPattern {
        StencilPattern::new(shape, d, r).unwrap()
    }

    #[test]
    fn paper_table2_alphas() {
        // Table 2 rows 5/7: Box-2D1R t=3 → 1.81, t=7 → 3.57.
        let p = pat(Shape::Box, 2, 1);
        assert!((alpha(&p, 3) - 49.0 / 27.0).abs() < 1e-12);
        assert!((alpha(&p, 7) - 225.0 / 63.0).abs() < 1e-12);
        assert!((alpha(&p, 3) - 1.81).abs() < 0.005);
        assert!((alpha(&p, 7) - 3.57).abs() < 0.005);
    }

    #[test]
    fn closed_form_equals_exact_for_boxes() {
        for d in 1..=3 {
            for r in 1..=2 {
                for t in 1..=6 {
                    let p = pat(Shape::Box, d, r);
                    assert!(
                        (alpha(&p, t) - alpha_box_closed_form(&p, t)).abs() < 1e-12,
                        "{p} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn alpha_unity_at_t1() {
        for shape in [Shape::Box, Shape::Star] {
            for d in 1..=3 {
                let p = pat(shape, d, 1);
                assert!((alpha(&p, 1) - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn alpha_monotone_in_t_for_2d_boxes() {
        let p = pat(Shape::Box, 2, 1);
        let mut prev = 0.0;
        for t in 1..=8 {
            let a = alpha(&p, t);
            assert!(a > prev);
            prev = a;
        }
    }

    #[test]
    fn growth_exponent_matches_paper_scaling() {
        // α_box ~ O(t^(d-1)) — §4.1 scenario 4 discussion.
        assert!((growth_exponent(&pat(Shape::Box, 2, 1)) - 1.0).abs() < 0.1);
        assert!((growth_exponent(&pat(Shape::Box, 3, 1)) - 2.0).abs() < 0.15);
        // star fused support is the L1 ball: also t^(d-1) asymptotically.
        assert!((growth_exponent(&pat(Shape::Star, 2, 1)) - 1.0).abs() < 0.25);
    }

    #[test]
    fn star_alpha_below_box_alpha() {
        // The diamond fused support is smaller than the box one, but star
        // K is also smaller — the paper's case study (Fig. 10) has star
        // kernels reaching compute-bound later; check α relation at d=3.
        let st = pat(Shape::Star, 3, 1);
        let bx = pat(Shape::Box, 3, 1);
        for t in 2..=5 {
            // absolute fused supports: star diamond < box cube
            assert!(st.fused_k_points(t) < bx.fused_k_points(t));
        }
    }

    #[test]
    #[should_panic]
    fn closed_form_rejects_star() {
        alpha_box_closed_form(&pat(Shape::Star, 2, 1), 2);
    }
}

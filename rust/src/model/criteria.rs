//! Analytical acceleration criteria (paper Eq. 19 and §4.3).
//!
//! Scenario 4 (compute-bound on both units) is profitable iff
//! α < S·ℙ_TC/ℙ_CU; the *sweet spot* is that region united with all of
//! scenario 3.  Sparse Tensor Cores double ℙ_TC, which both raises the
//! ceiling for already-profitable workloads and re-admits fusion depths
//! the dense criterion rejected (Fig. 13/14).

use crate::model::perf::{Scheme, Unit, Workload};
use crate::model::roofline::Roof;
use crate::model::scenario::{self, Scenario};

/// Eq. 19: compute-bound/compute-bound profitability test.
///
/// ```
/// use tc_stencil::model::criteria::sweet_spot_cc;
/// // A100 f64: ℙ_TC/ℙ_CU = 19.5/9.7 ≈ 2.01.  With S = 0.5 the α
/// // threshold sits at ≈ 1.005 — Table 3 case 5's α = 4.23 fails it.
/// assert!(sweet_spot_cc(1.0, 0.5, 19.5e12, 9.7e12));
/// assert!(!sweet_spot_cc(4.23, 0.5, 19.5e12, 9.7e12));
/// ```
pub fn sweet_spot_cc(alpha: f64, sparsity: f64, p_tc: f64, p_cu: f64) -> bool {
    alpha < sparsity * p_tc / p_cu
}

/// The largest fusion depth (within `t_max`) that keeps a workload inside
/// the sweet spot on the given roofs, if any.  This is the "careful
/// selection of the fusion step t" the paper calls critical (§4.1).
///
/// ```
/// use tc_stencil::model::criteria::max_profitable_t;
/// use tc_stencil::model::perf::{Dtype, Scheme, Unit};
/// use tc_stencil::model::roofline::Roof;
/// use tc_stencil::model::stencil::{Shape, StencilPattern};
/// // Box-2D1R TF32 on A100 roofs: deep fusion stays profitable on
/// // dense Tensor Cores up to a finite depth (Fig. 13's dense region).
/// let p = StencilPattern::new(Shape::Box, 2, 1).unwrap();
/// let cu = Roof::new(19.5e12, 1.935e12);
/// let tc = Roof::new(156e12, 1.935e12);
/// let t = max_profitable_t(&p, Dtype::F32, &cu, &tc,
///     Unit::TensorCore, Scheme::Decompose, 32).unwrap();
/// assert!((1..=32).contains(&t));
/// ```
pub fn max_profitable_t(
    pattern: &crate::model::stencil::StencilPattern,
    dtype: crate::model::perf::Dtype,
    cuda_roof: &Roof,
    tensor_roof: &Roof,
    unit: Unit,
    scheme: Scheme,
    t_max: usize,
) -> Option<usize> {
    (1..=t_max)
        .filter(|&t| {
            let w = Workload::new(*pattern, t, dtype);
            in_sweet_spot(&w, cuda_roof, tensor_roof, unit, scheme)
        })
        .max()
}

/// Membership in the sweet spot = scenario 3, or scenario 4 passing Eq. 19.
pub fn in_sweet_spot(
    w: &Workload,
    cuda_roof: &Roof,
    tensor_roof: &Roof,
    unit: Unit,
    scheme: Scheme,
) -> bool {
    let cmp = scenario::compare(w, cuda_roof, tensor_roof, unit, scheme);
    match cmp.scenario {
        Scenario::CompToMem => true,
        Scenario::CompToComp => sweet_spot_cc(
            w.alpha(),
            w.sparsity(scheme),
            tensor_roof.peak_flops,
            cuda_roof.peak_flops,
        ),
        _ => false,
    }
}

/// §4.3: the SpTC roof is the dense TC roof with ℙ doubled.
pub fn sptc_roof(tc_roof: &Roof) -> Roof {
    tc_roof.scale_peak(2.0)
}

/// A point of the criteria chart (Fig. 9/14): for one fusion depth,
/// whether dense TC and SpTC are each profitable.
#[derive(Debug, Clone)]
pub struct RegionPoint {
    /// Fusion depth of this point.
    pub t: usize,
    /// Fusion redundancy α at this depth (Eq. 9).
    pub alpha: f64,
    /// Transformation sparsity S at this depth (Eq. 2).
    pub sparsity: f64,
    /// Eq. 19 α-threshold on the dense TC roof: S·ℙ_TC/ℙ_CU.
    pub threshold_dense: f64,
    /// Eq. 19 α-threshold on the SpTC roof (ℙ doubled).
    pub threshold_sparse: f64,
    /// Inside the sweet spot on dense Tensor Cores.
    pub dense_profitable: bool,
    /// Inside the sweet spot on Sparse Tensor Cores.
    pub sparse_profitable: bool,
    /// Bottleneck-transition scenario on the dense roof.
    pub scenario_dense: Scenario,
    /// Bottleneck-transition scenario on the SpTC roof.
    pub scenario_sparse: Scenario,
}

/// Sweep fusion depths, classifying profitability under dense TC and SpTC
/// — the data behind Fig. 9, 13 and 14.
pub fn region_sweep(
    pattern: &crate::model::stencil::StencilPattern,
    dtype: crate::model::perf::Dtype,
    cuda_roof: &Roof,
    tc_roof: &Roof,
    scheme: Scheme,
    t_max: usize,
) -> Vec<RegionPoint> {
    let sp_roof = sptc_roof(tc_roof);
    (1..=t_max)
        .map(|t| {
            let w = Workload::new(*pattern, t, dtype);
            let s = w.sparsity(scheme);
            let a = w.alpha();
            let c_dense = scenario::compare(&w, cuda_roof, tc_roof, Unit::TensorCore, scheme);
            let c_sparse =
                scenario::compare(&w, cuda_roof, &sp_roof, Unit::SparseTensorCore, scheme);
            RegionPoint {
                t,
                alpha: a,
                sparsity: s,
                threshold_dense: s * tc_roof.peak_flops / cuda_roof.peak_flops,
                threshold_sparse: s * sp_roof.peak_flops / cuda_roof.peak_flops,
                dense_profitable: in_sweet_spot(&w, cuda_roof, tc_roof, Unit::TensorCore, scheme),
                sparse_profitable: in_sweet_spot(
                    &w,
                    cuda_roof,
                    &sp_roof,
                    Unit::SparseTensorCore,
                    scheme,
                ),
                scenario_dense: c_dense.scenario,
                scenario_sparse: c_sparse.scenario,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn pat(shape: Shape, d: usize, r: usize) -> StencilPattern {
        StencilPattern::new(shape, d, r).unwrap()
    }

    #[test]
    fn eq19_threshold() {
        // A100 f64: P_TC/P_CU ≈ 2.01; with S=0.5 the threshold is ≈ 1.005.
        assert!(sweet_spot_cc(1.0, 0.5, 19.5e12, 9.7e12));
        assert!(!sweet_spot_cc(1.81, 0.5, 19.5e12, 9.7e12)); // Table 3 case 5 logic
    }

    #[test]
    fn case5_fails_criterion() {
        // Box-3D1R t=3 double: α=343/81≈4.23 > 0.5·2.01 → outside.
        let w = Workload::new(pat(Shape::Box, 3, 1), 3, Dtype::F64);
        let cu = Roof::new(9.7e12, 1.935e12);
        let tc = Roof::new(19.5e12, 1.935e12);
        assert!(!in_sweet_spot(&w, &cu, &tc, Unit::TensorCore, Scheme::Flatten));
    }

    #[test]
    fn scenario3_always_in_sweet_spot() {
        // Box-2D1R t=7 float on SpTC roofs (Table 3 case 3).
        let w = Workload::new(pat(Shape::Box, 2, 1), 7, Dtype::F32);
        let cu = Roof::new(19.5e12, 1.935e12);
        let sptc = Roof::new(312e12, 1.935e12);
        assert!(in_sweet_spot(&w, &cu, &sptc, Unit::SparseTensorCore, Scheme::Sparse24));
    }

    #[test]
    fn sptc_expands_the_region() {
        // Fig. 14: there must exist fusion depths where dense TC is NOT
        // profitable but SpTC IS (TF32 roofs, Box-2D1R).
        let cu = Roof::new(19.5e12, 1.935e12);
        let tc = Roof::new(156e12, 1.935e12);
        let pts = region_sweep(&pat(Shape::Box, 2, 1), Dtype::F32, &cu, &tc, Scheme::Decompose, 40);
        let expanded: Vec<_> = pts
            .iter()
            .filter(|p| !p.dense_profitable && p.sparse_profitable)
            .collect();
        assert!(!expanded.is_empty(), "SpTC must expand the sweet spot");
        // and SpTC profitability is a superset of dense profitability
        for p in &pts {
            if p.dense_profitable {
                assert!(p.sparse_profitable, "t={}", p.t);
            }
        }
    }

    #[test]
    fn max_profitable_t_exists_for_2d_box_f32() {
        let cu = Roof::new(19.5e12, 1.935e12);
        let tc = Roof::new(156e12, 1.935e12);
        let t = max_profitable_t(
            &pat(Shape::Box, 2, 1),
            Dtype::F32,
            &cu,
            &tc,
            Unit::TensorCore,
            Scheme::Decompose,
            32,
        );
        assert!(t.is_some());
        // α grows ~linearly in t for 2D; eventually t drops out.
        let t = t.unwrap();
        assert!(t >= 1 && t <= 32);
    }

    #[test]
    fn no_sweet_spot_when_memory_bound() {
        // Scenarios 1/2 (CUDA memory-bound) are never in the sweet spot.
        let w = Workload::new(pat(Shape::Star, 2, 1), 1, Dtype::F64);
        let cu = Roof::new(9.7e12, 1.935e12);
        let tc = Roof::new(19.5e12, 1.935e12);
        assert!(!in_sweet_spot(&w, &cu, &tc, Unit::TensorCore, Scheme::Decompose));
    }

    #[test]
    fn sptc_roof_doubles_peak_only() {
        let tc = Roof::new(156e12, 1.935e12);
        let sp = sptc_roof(&tc);
        assert_eq!(sp.peak_flops, 312e12);
        assert_eq!(sp.bandwidth, tc.bandwidth);
    }

    #[test]
    fn region_sweep_thresholds_consistent() {
        let cu = Roof::new(19.5e12, 1.935e12);
        let tc = Roof::new(156e12, 1.935e12);
        for p in region_sweep(&pat(Shape::Box, 2, 1), Dtype::F32, &cu, &tc, Scheme::Decompose, 12)
        {
            assert!((p.threshold_sparse - 2.0 * p.threshold_dense).abs() < 1e-9);
            if p.scenario_dense == Scenario::CompToComp {
                assert_eq!(p.dense_profitable, p.alpha < p.threshold_dense);
            }
        }
    }
}

//! The four bottleneck-transition scenarios (paper §4.1, Eq. 13–18).
//!
//! Classification is by the (CUDA-bound, Tensor-bound) pair; the paper's
//! result per scenario:
//!
//! 1. MB → MB: ratio ≡ 1 (Eq. 14) — **equivalent**
//! 2. MB → CB: ratio < 1 (Eq. 16) — TC **underperforms**
//! 3. CB → MB: ratio > 1 (Eq. 17) — TC **outperforms** (ceiling broken)
//! 4. CB → CB: conditional (Eq. 18/19) — sweet-spot test decides

use crate::model::perf::{Scheme, Unit, Workload};
use crate::model::roofline::{Bound, Roof};

pub use crate::model::sparsity::Scheme as TransformScheme;

/// Scenario index per paper §4.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// (1) Memory-bound on both units.
    MemToMem,
    /// (2) Memory-bound on CUDA, compute-bound on Tensor Cores.
    MemToComp,
    /// (3) Compute-bound on CUDA, memory-bound on Tensor Cores.
    CompToMem,
    /// (4) Compute-bound on both units.
    CompToComp,
}

impl Scenario {
    /// The paper's scenario index (1–4).
    pub fn number(&self) -> u8 {
        match self {
            Scenario::MemToMem => 1,
            Scenario::MemToComp => 2,
            Scenario::CompToMem => 3,
            Scenario::CompToComp => 4,
        }
    }

    /// "Scenario N" label used in reports and refusals.
    pub fn label(&self) -> String {
        format!("Scenario {}", self.number())
    }
}

/// Expected outcome of moving to Tensor Cores, per the paper's analysis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// ratio ≈ 1 — no benefit, no loss.
    Equivalent,
    /// ratio < 1 — TC adaptation loses.
    Underperforms,
    /// ratio > 1 — TC breaks the CUDA ceiling.
    Outperforms,
    /// Scenario 4: decided by the sweet-spot criterion (Eq. 19).
    Conditional,
}

/// Full comparison of a workload on a CUDA roof vs a tensor roof.
#[derive(Debug, Clone)]
pub struct Comparison {
    /// Bottleneck-transition scenario (§4.1).
    pub scenario: Scenario,
    /// Expected outcome per the paper's analysis.
    pub verdict: Verdict,
    /// P_TC_actual / P_CU_actual (Eq. 13).
    pub speedup: f64,
    /// Bound on the CUDA roof.
    pub cuda_bound: Bound,
    /// Bound on the tensor roof.
    pub tensor_bound: Bound,
    /// I on CUDA Cores (Eq. 8).
    pub cuda_intensity: f64,
    /// I on the tensor unit (Eq. 11/20).
    pub tensor_intensity: f64,
    /// Actual FLOP/s on CUDA Cores.
    pub cuda_perf: f64,
    /// Actual (useful) FLOP/s on the tensor unit (Eq. 12).
    pub tensor_perf_actual: f64,
}

/// Tolerance band around ratio 1.0 treated as "comparable performance"
/// (the paper's Case ② reads ≈; ncu-level noise is ±5–10%).
pub const EQUIV_BAND: f64 = 0.05;

/// Classify + quantify a workload across units (Eq. 13 and §4.1).
pub fn compare(
    w: &Workload,
    cuda_roof: &Roof,
    tensor_roof: &Roof,
    unit: Unit,
    scheme: Scheme,
) -> Comparison {
    assert!(matches!(unit, Unit::TensorCore | Unit::SparseTensorCore));
    let cuda_bound = w.bound(cuda_roof, Unit::CudaCore, Scheme::Direct);
    let tensor_bound = w.bound(tensor_roof, unit, scheme);
    let scenario = match (cuda_bound, tensor_bound) {
        (Bound::Memory, Bound::Memory) => Scenario::MemToMem,
        (Bound::Memory, Bound::Compute) => Scenario::MemToComp,
        (Bound::Compute, Bound::Memory) => Scenario::CompToMem,
        (Bound::Compute, Bound::Compute) => Scenario::CompToComp,
    };
    let cuda_perf = w.actual_perf(cuda_roof, Unit::CudaCore, Scheme::Direct);
    let tensor_perf_actual = w.actual_perf(tensor_roof, unit, scheme);
    let speedup = tensor_perf_actual / cuda_perf;
    let verdict = match scenario {
        Scenario::MemToMem => Verdict::Equivalent,
        Scenario::MemToComp => Verdict::Underperforms,
        Scenario::CompToMem => Verdict::Outperforms,
        Scenario::CompToComp => {
            if (speedup - 1.0).abs() <= EQUIV_BAND {
                Verdict::Equivalent
            } else {
                Verdict::Conditional
            }
        }
    };
    Comparison {
        scenario,
        verdict,
        speedup,
        cuda_bound,
        tensor_bound,
        cuda_intensity: w.intensity_cuda(),
        tensor_intensity: w.intensity_tensor(scheme),
        cuda_perf,
        tensor_perf_actual,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::perf::{Dtype, Workload};
    use crate::model::stencil::{Shape, StencilPattern};

    fn wl(shape: Shape, d: usize, r: usize, t: usize, dt: Dtype) -> Workload {
        Workload::new(StencilPattern::new(shape, d, r).unwrap(), t, dt)
    }

    // A100 roofs as used in the paper's Table 3 analysis.
    fn a100_cu_f64() -> Roof {
        Roof::new(9.7e12, 1.935e12) // ridge ≈ 5
    }
    fn a100_tc_f64() -> Roof {
        Roof::new(19.5e12, 1.935e12) // ridge ≈ 10
    }
    fn a100_cu_f32() -> Roof {
        Roof::new(19.5e12, 1.935e12) // ridge ≈ 10
    }
    fn a100_sptc_tf32() -> Roof {
        Roof::new(312e12, 1.935e12) // ridge ≈ 161
    }
    fn a100_tc_tf32() -> Roof {
        Roof::new(156e12, 1.935e12) // ridge ≈ 81
    }

    #[test]
    fn table3_case1_scenario2() {
        // Box-2D1R t=3 double: EBISU memory-bound (I=3.38 < 5),
        // ConvStencil compute-bound (I=12.25 > 10) → Scenario 2, TC loses.
        let w = wl(Shape::Box, 2, 1, 3, Dtype::F64);
        let c = compare(&w, &a100_cu_f64(), &a100_tc_f64(), Unit::TensorCore, Scheme::Flatten);
        assert_eq!(c.scenario, Scenario::MemToComp);
        assert_eq!(c.verdict, Verdict::Underperforms);
        assert!(c.speedup < 1.0, "speedup={}", c.speedup);
    }

    #[test]
    fn table3_case2_scenario4_boundary() {
        // Box-2D3R t=1 double: both compute-bound, ratio ≈ 1 (paper: ≈).
        let w = wl(Shape::Box, 2, 3, 1, Dtype::F64);
        let c = compare(&w, &a100_cu_f64(), &a100_tc_f64(), Unit::TensorCore, Scheme::Flatten);
        assert_eq!(c.scenario, Scenario::CompToComp);
        // ratio = (S/α)·P_TC/P_CU with α=1, S≈0.5 → ≈ 1.0
        assert!((c.speedup - 1.0).abs() < 0.12, "speedup={}", c.speedup);
    }

    #[test]
    fn table3_case3_scenario3() {
        // Box-2D1R t=7 float: EBISU compute-bound (I=15.75 > 10), SPIDER
        // memory-bound (I=120 < 161) → Scenario 3, TC wins.
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
        let c = compare(
            &w,
            &a100_cu_f32(),
            &a100_sptc_tf32(),
            Unit::SparseTensorCore,
            Scheme::Sparse24,
        );
        assert_eq!(c.scenario, Scenario::CompToMem);
        assert_eq!(c.verdict, Verdict::Outperforms);
        assert!(c.speedup > 1.0);
    }

    #[test]
    fn table3_case4_scenario3() {
        // Box-2D7R t=1 float: same transition.
        let w = wl(Shape::Box, 2, 7, 1, Dtype::F32);
        let c = compare(
            &w,
            &a100_cu_f32(),
            &a100_sptc_tf32(),
            Unit::SparseTensorCore,
            Scheme::Sparse24,
        );
        assert_eq!(c.scenario, Scenario::CompToMem);
        assert_eq!(c.verdict, Verdict::Outperforms);
    }

    #[test]
    fn table3_case5_scenario4_loses() {
        // Box-3D1R t=3 double: both compute-bound, α≈4.64 too large →
        // fails Eq. 19 → degradation.
        let w = wl(Shape::Box, 3, 1, 3, Dtype::F64);
        let c = compare(&w, &a100_cu_f64(), &a100_tc_f64(), Unit::TensorCore, Scheme::Flatten);
        assert_eq!(c.scenario, Scenario::CompToComp);
        assert!(c.speedup < 1.0, "speedup={}", c.speedup);
    }

    #[test]
    fn table3_case6_scenario4_loses() {
        // Box-3D1R t=7 float on dense TC: α ≈ 16.8 — far outside sweet spot.
        let w = wl(Shape::Box, 3, 1, 7, Dtype::F32);
        let c = compare(
            &w,
            &a100_cu_f32(),
            &a100_tc_tf32(),
            Unit::TensorCore,
            Scheme::Decompose,
        );
        assert_eq!(c.scenario, Scenario::CompToComp);
        assert!(c.speedup < 1.0, "speedup={}", c.speedup);
    }

    #[test]
    fn scenario1_equivalence_eq14() {
        // Low intensity on both → ratio exactly 1.
        let w = wl(Shape::Star, 2, 1, 1, Dtype::F64);
        let c = compare(&w, &a100_cu_f64(), &a100_tc_f64(), Unit::TensorCore, Scheme::Decompose);
        assert_eq!(c.scenario, Scenario::MemToMem);
        assert_eq!(c.verdict, Verdict::Equivalent);
        assert!((c.speedup - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scenario2_never_wins_eq16() {
        // Property: in scenario 2 the ratio is strictly < 1 for any config.
        for r in 1..=3usize {
            for t in 1..=6usize {
                let w = wl(Shape::Box, 2, r, t, Dtype::F64);
                let c = compare(
                    &w,
                    &a100_cu_f64(),
                    &a100_tc_f64(),
                    Unit::TensorCore,
                    Scheme::Decompose,
                );
                if c.scenario == Scenario::MemToComp {
                    assert!(c.speedup < 1.0 + 1e-12, "r={r} t={t} {}", c.speedup);
                }
            }
        }
    }

    #[test]
    fn scenario3_always_wins_eq17() {
        for r in 1..=7usize {
            for t in 1..=8usize {
                let w = wl(Shape::Box, 2, r, t, Dtype::F32);
                let c = compare(
                    &w,
                    &a100_cu_f32(),
                    &a100_sptc_tf32(),
                    Unit::SparseTensorCore,
                    Scheme::Sparse24,
                );
                if c.scenario == Scenario::CompToMem {
                    assert!(c.speedup > 1.0 - 1e-12, "r={r} t={t} {}", c.speedup);
                }
            }
        }
    }

    #[test]
    fn intensities_reported_consistently() {
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
        let c = compare(
            &w,
            &a100_cu_f32(),
            &a100_sptc_tf32(),
            Unit::SparseTensorCore,
            Scheme::Sparse24,
        );
        assert!((c.cuda_intensity - 15.75).abs() < 1e-9);
        // with our measured S=0.5: I_TC = 7·(3.571/0.5)·9/4 = 112.5
        assert!((c.tensor_intensity - 112.5).abs() < 1e-9);
    }
}

//! Model↔measurement feedback: predicted vs. *achieved* arithmetic
//! intensity.
//!
//! The model predicts the intensity a temporal strategy should realize
//! per output point — Eq. 8's `t·K/D` for a temporally blocked
//! execution, `α·t·K/D` (Eq. 9 over Eq. 8) for a fused-kernel sweep.
//! The native backend instruments what it actually did
//! (`RunMetrics::{flops, bytes_moved}` →
//! [`achieved_intensity`](crate::coordinator::metrics::RunMetrics::achieved_intensity)),
//! and this module compares the two, per run and in aggregate: the
//! `stencilctl run` report and every `serve` advance response carry the
//! relative model error, and the service keeps a running mean
//! (`ServiceSnapshot::model_error`).  That closes the loop the paper
//! leaves open — the intensity shift of temporal fusion becomes an
//! observable of our own measurements, not only a scored plan.
//!
//! Deviations are signed and interpretable: the blocked path measures
//! *below* prediction by its overlapped-halo re-reads/recompute
//! (`≈ t·r/B` for tile height B), the sweep path measures on-model
//! because its fused-kernel non-zero count is exactly `K^(t)`.

use crate::model::perf::Workload;

/// Fractional deviation treated as "within the model's predicted
/// region" — generous enough for tile-halo overhead and boundary
/// effects on small domains, tight enough that executing the wrong
/// temporal strategy (a factor of α) is flagged.
pub const REGION_TOLERANCE: f64 = 0.25;

/// The intensity the model predicts for one executed configuration:
/// Eq. 8 (`t·K/D`) when `blocked`, `α·t·K/D` for a fused-kernel sweep.
pub fn predicted_intensity(w: &Workload, blocked: bool) -> f64 {
    if blocked {
        w.intensity_cuda()
    } else {
        w.intensity_fused_sweep()
    }
}

/// Step-count-aware prediction for a whole job: `steps` need not divide
/// by `t`, and the trailing partial block / remainder base-kernel steps
/// dilute the intensity below the pure Eq. 8/9 value.
///
/// Blocked: `ceil(steps/t)` domain traversals carry `steps` base steps,
/// so I = (steps / nblocks)·K/D.  Sweep: `steps/t` fused launches at
/// `K^(t)` flops-per-point each plus `steps % t` base sweeps at `K`.
pub fn predicted_job_intensity(w: &Workload, steps: usize, blocked: bool) -> f64 {
    if steps == 0 {
        return 0.0;
    }
    let k = w.k();
    let d = w.dtype.bytes() as f64;
    if blocked {
        let nblocks = steps.div_ceil(w.t) as f64;
        steps as f64 / nblocks * k / d
    } else {
        let launches = (steps / w.t) as f64;
        let rem = (steps % w.t) as f64;
        let kt = w.pattern.fused_k_points(w.t) as f64;
        (kt * launches + k * rem) / (d * (launches + rem))
    }
}

/// One run's predicted-vs-measured intensity comparison.
#[derive(Debug, Clone)]
pub struct IntensityReport {
    /// Model-predicted intensity (FLOP/byte).
    pub predicted: f64,
    /// Instrumented achieved intensity (FLOP/byte).
    pub measured: f64,
    /// Signed relative error `(measured − predicted) / predicted`.
    pub rel_error: f64,
    /// `|rel_error| ≤` [`REGION_TOLERANCE`].
    pub within_region: bool,
}

/// Compare a job's measured intensity against the model.
///
/// ```
/// use tc_stencil::model::calib;
/// use tc_stencil::model::perf::{Dtype, Workload};
/// use tc_stencil::model::stencil::{Shape, StencilPattern};
/// // Star-2D1R f32 at t=4: blocked execution should achieve ≈ t·K/D = 5.
/// let w = Workload::new(StencilPattern::new(Shape::Star, 2, 1).unwrap(), 4, Dtype::F32);
/// let r = calib::report(&w, 4 * 4, true, 4.8);
/// assert!((r.predicted - 5.0).abs() < 1e-12);
/// assert!(r.rel_error < 0.0 && r.within_region); // halo overhead, on-model
/// ```
pub fn report(w: &Workload, steps: usize, blocked: bool, measured: f64) -> IntensityReport {
    report_against(predicted_job_intensity(w, steps, blocked), measured)
}

/// Shard-aware report: against the halo-redundancy-adjusted prediction
/// ([`shard::predicted_job_intensity`](crate::model::shard::predicted_job_intensity))
/// when the job fanned out, the monolithic [`report`] otherwise — the
/// one selection rule `stencilctl run` and every `serve` advance
/// response share.
pub fn report_sharded(
    w: &Workload,
    steps: usize,
    blocked: bool,
    n0: usize,
    shards: usize,
    measured: f64,
) -> IntensityReport {
    if shards > 1 {
        report_against(
            crate::model::shard::predicted_job_intensity(w, steps, blocked, n0, shards),
            measured,
        )
    } else {
        report(w, steps, blocked, measured)
    }
}

/// The byte traffic a prediction implies for a job: intensity is
/// FLOP/byte, so `bytes = flops / I_predicted`.  This is the
/// "traffic the planner priced" side of the redundancy residual in
/// [`obs::attrib`](crate::obs::attrib) — measured `bytes_moved` above
/// it is recompute/halo traffic the κ/τ/α assumptions didn't cover.
///
/// ```
/// use tc_stencil::model::calib;
/// // 9000 flops priced at intensity 4.5 flop/byte → 2000 bytes.
/// assert_eq!(calib::predicted_job_bytes(9000.0, 4.5), 2000.0);
/// assert_eq!(calib::predicted_job_bytes(9000.0, 0.0), 0.0); // degenerate
/// ```
pub fn predicted_job_bytes(flops: f64, predicted_intensity: f64) -> f64 {
    if predicted_intensity > 0.0 && flops.is_finite() && flops > 0.0 {
        flops / predicted_intensity
    } else {
        0.0
    }
}

/// Compare a measured intensity against an externally computed
/// prediction (the shard-aware path uses
/// [`shard::predicted_job_intensity`](crate::model::shard::predicted_job_intensity)).
pub fn report_against(predicted: f64, measured: f64) -> IntensityReport {
    let rel_error = if predicted > 0.0 { (measured - predicted) / predicted } else { 0.0 };
    IntensityReport {
        predicted,
        measured,
        rel_error,
        within_region: rel_error.abs() <= REGION_TOLERANCE,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn wl(shape: Shape, d: usize, r: usize, t: usize, dt: Dtype) -> Workload {
        Workload::new(StencilPattern::new(shape, d, r).unwrap(), t, dt)
    }

    #[test]
    fn blocked_prediction_is_eq8() {
        let w = wl(Shape::Box, 2, 1, 4, Dtype::F64);
        assert_eq!(predicted_intensity(&w, true), w.intensity_cuda());
        assert_eq!(predicted_intensity(&w, false), w.intensity_fused_sweep());
        // whole blocks: job prediction equals the pure value
        assert!((predicted_job_intensity(&w, 8, true) - w.intensity_cuda()).abs() < 1e-12);
        assert!(
            (predicted_job_intensity(&w, 8, false) - w.intensity_fused_sweep()).abs() < 1e-12
        );
    }

    #[test]
    fn remainders_dilute_the_prediction() {
        let w = wl(Shape::Box, 2, 1, 4, Dtype::F64);
        // 9 steps at t=4 → blocked blocks of 4,4,1: I = 3·K/D.
        let i = predicted_job_intensity(&w, 9, true);
        assert!((i - 3.0 * 9.0 / 8.0).abs() < 1e-12);
        // sweep: 2 fused launches (K^(4)=81) + 1 base sweep.
        let i = predicted_job_intensity(&w, 9, false);
        assert!((i - (81.0 * 2.0 + 9.0) / (8.0 * 3.0)).abs() < 1e-12);
        assert_eq!(predicted_job_intensity(&w, 0, true), 0.0);
    }

    #[test]
    fn report_flags_the_wrong_strategy() {
        // Measuring a sweep's intensity against a blocked prediction is
        // off by α — outside the region for deep 3-D fusion.
        let w = wl(Shape::Box, 3, 1, 4, Dtype::F32);
        let sweep_i = w.intensity_fused_sweep();
        let r = report(&w, 4, true, sweep_i);
        assert!(!r.within_region, "α={} must be flagged", w.alpha());
        let ok = report(&w, 4, true, w.intensity_cuda() * 0.95);
        assert!(ok.within_region);
        assert!(ok.rel_error < 0.0);
    }

    #[test]
    fn report_sharded_selects_the_right_prediction() {
        let w = wl(Shape::Box, 2, 1, 4, Dtype::F64);
        // shards == 1 → exactly the monolithic report
        let mono = report_sharded(&w, 8, true, 64, 1, w.intensity_cuda() * 0.95);
        assert_eq!(mono.predicted, predicted_job_intensity(&w, 8, true));
        // shards > 1 → the halo-redundancy-adjusted prediction
        let shard_pred = crate::model::shard::predicted_job_intensity(&w, 8, true, 64, 4);
        let sh = report_sharded(&w, 8, true, 64, 4, shard_pred);
        assert!((sh.predicted - shard_pred).abs() < 1e-15);
        assert!(sh.rel_error.abs() < 1e-12 && sh.within_region);
        assert!(sh.predicted < mono.predicted, "halo traffic must lower the target");
    }

    #[test]
    fn report_is_symmetric_around_the_prediction() {
        let w = wl(Shape::Star, 2, 1, 2, Dtype::F64);
        let lo = report(&w, 2, true, w.intensity_cuda() * 0.9);
        let hi = report(&w, 2, true, w.intensity_cuda() * 1.1);
        assert!((lo.rel_error + 0.1).abs() < 1e-9 && lo.within_region);
        assert!((hi.rel_error - 0.1).abs() < 1e-9 && hi.within_region);
    }
}

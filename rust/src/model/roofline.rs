//! The classical roofline model (paper §3.1, Eq. 4–5) plus the ridge-point
//! bookkeeping used throughout the scenario analysis.

/// A hardware roof: peak compute ℙ (FLOP/s) and memory bandwidth 𝔹 (B/s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Roof {
    /// ℙ — peak compute throughput in FLOP/s.
    pub peak_flops: f64,
    /// 𝔹 — memory bandwidth in bytes/s.
    pub bandwidth: f64,
}

/// Which side of the ridge a workload lands on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    /// Below the ridge: bandwidth-limited.
    Memory,
    /// At/above the ridge: compute-limited.
    Compute,
}

impl Bound {
    /// Human-readable bound name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Bound::Memory => "Memory",
            Bound::Compute => "Compute",
        }
    }
}

impl Roof {
    /// Build a roof; panics on non-positive peaks.
    pub fn new(peak_flops: f64, bandwidth: f64) -> Roof {
        assert!(peak_flops > 0.0 && bandwidth > 0.0);
        Roof { peak_flops, bandwidth }
    }

    /// Ridge point I* = ℙ / 𝔹 (FLOP/byte) — Eq. 5's break point.
    pub fn ridge(&self) -> f64 {
        self.peak_flops / self.bandwidth
    }

    /// Attainable performance P = min(ℙ, 𝔹·I) — Eq. 5.
    pub fn attainable(&self, intensity: f64) -> f64 {
        assert!(intensity >= 0.0);
        self.peak_flops.min(self.bandwidth * intensity)
    }

    /// Bottleneck classification at intensity I.
    pub fn bound(&self, intensity: f64) -> Bound {
        if intensity < self.ridge() {
            Bound::Memory
        } else {
            Bound::Compute
        }
    }

    /// Scale the compute roof (clock-lock factor, sparsity 2×, …).
    pub fn scale_peak(&self, factor: f64) -> Roof {
        Roof::new(self.peak_flops * factor, self.bandwidth)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // A100 double-precision CUDA-Core roof from the paper (§5.3):
    // ℙ = 9.7 TFLOPS, 𝔹 = 1.935 TB/s → ridge ≈ 5.
    fn a100_f64_cu() -> Roof {
        Roof::new(9.7e12, 1.935e12)
    }

    #[test]
    fn ridge_matches_paper_table3() {
        assert!((a100_f64_cu().ridge() - 5.01).abs() < 0.02);
        let tc = Roof::new(19.5e12, 1.935e12); // A100 f64 Tensor Core
        assert!((tc.ridge() - 10.08).abs() < 0.02);
    }

    #[test]
    fn attainable_is_min_of_two_regimes() {
        let r = a100_f64_cu();
        // memory-bound: below the ridge performance scales linearly
        assert_eq!(r.attainable(1.0), 1.935e12);
        assert_eq!(r.attainable(2.0), 2.0 * 1.935e12);
        // compute-bound: above the ridge it clips at peak
        assert_eq!(r.attainable(100.0), 9.7e12);
    }

    #[test]
    fn continuity_at_ridge() {
        let r = a100_f64_cu();
        let i = r.ridge();
        assert!((r.attainable(i) - r.peak_flops).abs() / r.peak_flops < 1e-12);
    }

    #[test]
    fn bound_classification() {
        let r = a100_f64_cu();
        assert_eq!(r.bound(3.38), Bound::Memory); // Table 3 case 1 EBISU
        assert_eq!(r.bound(6.13), Bound::Compute); // Table 3 case 2 EBISU
    }

    #[test]
    fn scale_peak_moves_ridge_right() {
        let r = a100_f64_cu();
        let s = r.scale_peak(2.0);
        assert!((s.ridge() - 2.0 * r.ridge()).abs() < 1e-9);
        assert_eq!(s.bandwidth, r.bandwidth);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_peak() {
        Roof::new(0.0, 1.0);
    }
}

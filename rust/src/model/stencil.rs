//! Stencil patterns: shape, dimensionality, radius — and their point
//! counts, both per-step (K) and after t-step kernel fusion (K^(t)).
//!
//! K^(t) is computed two ways: the paper's box closed form (Eq. 10
//! numerator) and an *exact* iterated Minkowski-sum support count that is
//! valid for any shape — in particular star stencils, whose fused support
//! is a generalized L1 ball the paper does not give a formula for.

use std::fmt;

use anyhow::{bail, Result};

/// Stencil shape (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Shape {
    /// All points with ‖off‖∞ ≤ r: K = (2r+1)^d.
    Box,
    /// Points on the coordinate axes with |off| ≤ r: K = 2dr+1.
    Star,
}

impl Shape {
    /// Parse a CLI/protocol shape name.
    pub fn parse(s: &str) -> Result<Shape> {
        match s.to_ascii_lowercase().as_str() {
            "box" => Ok(Shape::Box),
            "star" => Ok(Shape::Star),
            other => bail!("unknown stencil shape {other:?} (want box|star)"),
        }
    }

    /// The stable lowercase shape name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Shape::Box => "box",
            Shape::Star => "star",
        }
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Coefficient structure of the stencil kernel — the workload axis that
/// `model::sparsity` prices (§4.3): constant dense taps, anisotropic
/// (axis-asymmetric) constants, per-point variable coefficients, and the
/// 2:4-structured-sparse tap set that SPIDER/SparStencil execute on
/// Sparse Tensor Cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Coeffs {
    /// Constant weights, dense over the support (the PR ≤7 behaviour).
    #[default]
    Const,
    /// Constant but axis-asymmetric weights: same support and point
    /// counts as `Const`; exercises non-symmetric kernels end to end.
    Aniso,
    /// Per-output-point weight field: every tap's weight is modulated by
    /// a deterministic per-point factor ([`crate::sim::golden::vc_mod`]).
    VarCoef,
    /// 2:4-structured sparse taps: over the row-major hull, each group
    /// of 4 cells keeps at most 2 non-zeros (the SpTC constraint).
    Sparse24,
}

impl Coeffs {
    /// Parse a CLI/protocol coefficient-variant name.
    pub fn parse(s: &str) -> Result<Coeffs> {
        match s.to_ascii_lowercase().as_str() {
            "const" | "dense" => Ok(Coeffs::Const),
            "aniso" => Ok(Coeffs::Aniso),
            "varcoef" | "variable" => Ok(Coeffs::VarCoef),
            "sparse24" | "2:4" | "s24" => Ok(Coeffs::Sparse24),
            other => bail!("unknown coeffs variant {other:?} (want const|aniso|varcoef|sparse24)"),
        }
    }

    /// The stable lowercase variant name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Coeffs::Const => "const",
            Coeffs::Aniso => "aniso",
            Coeffs::VarCoef => "varcoef",
            Coeffs::Sparse24 => "sparse24",
        }
    }
}

impl fmt::Display for Coeffs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A stencil pattern: the paper's (shape, d, r) triple.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StencilPattern {
    /// Neighbourhood shape (box or star).
    pub shape: Shape,
    /// Dimensionality (1..=4).
    pub d: usize,
    /// Radius (1..=16).
    pub r: usize,
    /// Coefficient structure (constant / anisotropic / variable / 2:4).
    pub coeffs: Coeffs,
}

impl StencilPattern {
    /// Build a pattern, rejecting degenerate (d, r).
    pub fn new(shape: Shape, d: usize, r: usize) -> Result<StencilPattern> {
        if d == 0 || d > 4 {
            bail!("dimensionality must be 1..=4, got {d}");
        }
        if r == 0 || r > 16 {
            bail!("radius must be 1..=16, got {r}");
        }
        Ok(StencilPattern { shape, d, r, coeffs: Coeffs::Const })
    }

    /// Same pattern with a different coefficient variant.
    pub fn with_coeffs(mut self, coeffs: Coeffs) -> StencilPattern {
        self.coeffs = coeffs;
        self
    }

    /// Parse the pattern grammar `{shape}-{d}d{r}r[:{coeffs}]`, e.g.
    /// `box-2d1r`, `star-3d1r:sparse24`, `Box-2D1R:varcoef`.
    pub fn parse(s: &str) -> Result<StencilPattern> {
        let (geom, coeffs) = match s.split_once(':') {
            Some((g, c)) => (g, Coeffs::parse(c)?),
            None => (s, Coeffs::Const),
        };
        let geom = geom.to_ascii_lowercase();
        let (shape_s, rest) = geom
            .split_once('-')
            .ok_or_else(|| anyhow::anyhow!("bad pattern {s:?} (want {{shape}}-{{d}}d{{r}}r[:{{coeffs}}])"))?;
        let shape = Shape::parse(shape_s)?;
        let body = rest
            .strip_suffix('r')
            .ok_or_else(|| anyhow::anyhow!("bad pattern {s:?}: geometry must end in r"))?;
        let (d_s, r_s) = body
            .split_once('d')
            .ok_or_else(|| anyhow::anyhow!("bad pattern {s:?}: want {{d}}d{{r}}r"))?;
        let d: usize = d_s.parse().map_err(|_| anyhow::anyhow!("bad dimensionality in {s:?}"))?;
        let r: usize = r_s.parse().map_err(|_| anyhow::anyhow!("bad radius in {s:?}"))?;
        Ok(StencilPattern::new(shape, d, r)?.with_coeffs(coeffs))
    }

    /// Paper naming, e.g. "Box-2D1R"; non-constant coefficient variants
    /// carry a suffix, e.g. "Box-2D1R:sparse24".
    pub fn label(&self) -> String {
        let s = match self.shape {
            Shape::Box => "Box",
            Shape::Star => "Star",
        };
        match self.coeffs {
            Coeffs::Const => format!("{s}-{}D{}R", self.d, self.r),
            c => format!("{s}-{}D{}R:{}", self.d, self.r, c.as_str()),
        }
    }

    /// K — number of points in the (unfused) kernel.
    pub fn k_points(&self) -> u64 {
        match self.shape {
            Shape::Box => (2 * self.r as u64 + 1).pow(self.d as u32),
            Shape::Star => 2 * self.d as u64 * self.r as u64 + 1,
        }
    }

    /// The support as a boolean hypercube over the (2r+1)^d hull.
    pub fn support(&self) -> SupportGrid {
        let n = 2 * self.r + 1;
        let mut g = SupportGrid::zeros(self.d, n);
        let r = self.r as i64;
        g.fill_by(|off| match self.shape {
            Shape::Box => true,
            Shape::Star => off.iter().filter(|&&o| o != 0).count() <= 1,
        });
        debug_assert_eq!(g.count(), self.k_points());
        let _ = r;
        g
    }

    /// Support-normalized uniform weights over the (2r+1)^d hull
    /// (row-major, zeros off-support) — the default kernel for CLI runs
    /// and service sessions that don't supply their own.
    pub fn uniform_weights(&self) -> Vec<f64> {
        let sup = self.support();
        let k = sup.count() as f64;
        sup.cells.iter().map(|&b| if b { 1.0 / k } else { 0.0 }).collect()
    }

    /// K^(t) — points in the fused kernel support (exact for any shape).
    ///
    /// Box: (2rt+1)^d (Eq. 10 numerator).  Star: the t-fold Minkowski sum
    /// of the radius-r cross is exactly {x : Σ_i ⌈|x_i|/r⌉ ≤ t} — each
    /// axis displacement |x_i| needs ⌈|x_i|/r⌉ cross steps and steps are
    /// spent independently per axis.  Counted in O((2rt+1)^d) instead of
    /// the O(cells²)-per-step generic Minkowski iteration (which remains
    /// available via `SupportGrid::minkowski_power` and cross-checks this
    /// in the tests).
    pub fn fused_k_points(&self, t: usize) -> u64 {
        assert!(t >= 1);
        match self.shape {
            Shape::Box => (2 * self.r as u64 * t as u64 + 1).pow(self.d as u32),
            Shape::Star => {
                let r = self.r as u64;
                let rt = (r * t as u64) as i64;
                // per-axis tally: for cost c (0..=t), how many x with
                // ceil(|x|/r) == c ?  c=0 → 1 (x=0); c>=1 → 2r values.
                // Count d-tuples with total cost <= t via DP.
                let mut ways = vec![0u64; t + 1]; // ways[c] per axis
                ways[0] = 1;
                for c in 1..=t {
                    ways[c] = 2 * r;
                }
                let _ = rt;
                let mut acc = vec![0u64; t + 1];
                acc[0] = 1; // empty product
                for _ in 0..self.d {
                    let mut next = vec![0u64; t + 1];
                    for total in 0..=t {
                        for c in 0..=total {
                            next[total] += acc[total - c] * ways[c];
                        }
                    }
                    acc = next;
                }
                acc.iter().sum()
            }
        }
    }

    /// The support actually *executed* for this pattern's coefficient
    /// variant: the geometric support, 2:4-pruned for `Sparse24`.
    /// Weight-independent, so planner pricing stays pure in the pattern.
    pub fn effective_support(&self) -> SupportGrid {
        match self.coeffs {
            Coeffs::Sparse24 => self.support().prune24(),
            _ => self.support(),
        }
    }

    /// Effective tap count (non-zeros executed per point). Equals
    /// [`Self::k_points`] except for `Sparse24`, where the 2:4 pruning
    /// removes taps.
    pub fn effective_k_points(&self) -> u64 {
        match self.coeffs {
            Coeffs::Sparse24 => self.effective_support().count(),
            _ => self.k_points(),
        }
    }

    /// Effective fused tap count: support of the t-fold self-convolution
    /// of the *executed* kernel. For `Sparse24` the pruned support has no
    /// closed form, so this uses the exact iterated Minkowski sum.
    pub fn fused_effective_k_points(&self, t: usize) -> u64 {
        assert!(t >= 1);
        match self.coeffs {
            Coeffs::Sparse24 => self.effective_support().minkowski_power(t).count(),
            _ => self.fused_k_points(t),
        }
    }

    /// Default weights for this pattern's coefficient variant, over the
    /// full (2r+1)^d hull (row-major, zeros off the effective support):
    ///
    /// * `Const` / `VarCoef` — support-normalized uniform (VarCoef's
    ///   per-point modulation is applied at execution, not here);
    /// * `Aniso` — deterministic axis-asymmetric positive weights,
    ///   normalized to sum 1;
    /// * `Sparse24` — uniform over the 2:4-pruned support.
    pub fn default_weights(&self) -> Vec<f64> {
        match self.coeffs {
            Coeffs::Const | Coeffs::VarCoef => self.uniform_weights(),
            Coeffs::Aniso => self.aniso_weights(),
            Coeffs::Sparse24 => {
                let sup = self.effective_support();
                let k = sup.count() as f64;
                sup.cells.iter().map(|&b| if b { 1.0 / k } else { 0.0 }).collect()
            }
        }
    }

    /// Deterministic anisotropic weights: per support cell the product
    /// over axes of `1 + 0.1·(axis+1) + off/(4·(r+1))` — axis-dependent
    /// and sign-asymmetric yet strictly positive for every valid (d, r)
    /// (|off| ≤ r < 4·(r+1)) — normalized to sum 1 over the support.
    fn aniso_weights(&self) -> Vec<f64> {
        let sup = self.support();
        let n = sup.n;
        let rad = sup.radius();
        let scale = 4.0 * (self.r as f64 + 1.0);
        let mut w = vec![0.0f64; sup.cells.len()];
        for (flat, slot) in w.iter_mut().enumerate() {
            if !sup.cells[flat] {
                continue;
            }
            let mut rem = flat;
            let mut offs = vec![0i64; self.d];
            for k in (0..self.d).rev() {
                offs[k] = (rem % n) as i64 - rad;
                rem /= n;
            }
            let mut f = 1.0f64;
            for (axis, &o) in offs.iter().enumerate() {
                f *= 1.0 + 0.1 * (axis as f64 + 1.0) + o as f64 / scale;
            }
            *slot = f;
        }
        let total: f64 = w.iter().sum();
        for slot in w.iter_mut() {
            *slot /= total;
        }
        w
    }
}

impl fmt::Display for StencilPattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// Dense boolean grid over a d-dim hull of side n (n odd), centered.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SupportGrid {
    /// Dimensionality of the hull.
    pub d: usize,
    /// Side length of the hull (odd).
    pub n: usize,
    /// Row-major cell membership over the hull.
    pub cells: Vec<bool>,
}

impl SupportGrid {
    /// An empty support over a d-dim hull of (odd) side n.
    pub fn zeros(d: usize, n: usize) -> SupportGrid {
        assert!(n % 2 == 1, "hull side must be odd");
        SupportGrid { d, n, cells: vec![false; n.pow(d as u32)] }
    }

    fn radius(&self) -> i64 {
        ((self.n - 1) / 2) as i64
    }

    /// Linear index of a (centered) offset.
    fn index(&self, off: &[i64]) -> Option<usize> {
        let r = self.radius();
        let mut idx = 0usize;
        for &o in off {
            if o < -r || o > r {
                return None;
            }
            idx = idx * self.n + (o + r) as usize;
        }
        Some(idx)
    }

    /// Iterate all offsets of the hull.
    fn offsets(&self) -> Vec<Vec<i64>> {
        let r = self.radius();
        let mut out = Vec::with_capacity(self.cells.len());
        let mut cur = vec![-r; self.d];
        loop {
            out.push(cur.clone());
            // odometer increment
            let mut k = self.d;
            loop {
                if k == 0 {
                    return out;
                }
                k -= 1;
                if cur[k] < r {
                    cur[k] += 1;
                    for c in cur.iter_mut().skip(k + 1) {
                        *c = -r;
                    }
                    break;
                } else if k == 0 {
                    return out;
                }
            }
        }
    }

    /// Mark every hull offset for which `f` returns true.
    pub fn fill_by<F: Fn(&[i64]) -> bool>(&mut self, f: F) {
        for off in self.offsets() {
            if f(&off) {
                let i = self.index(&off).unwrap();
                self.cells[i] = true;
            }
        }
    }

    /// Number of marked cells (= K for a pattern's own support).
    pub fn count(&self) -> u64 {
        self.cells.iter().filter(|&&b| b).count() as u64
    }

    /// Minkowski sum with another centered support (support dilation).
    pub fn minkowski(&self, other: &SupportGrid) -> SupportGrid {
        assert_eq!(self.d, other.d);
        let n_out = self.n + other.n - 1;
        let mut out = SupportGrid::zeros(self.d, n_out);
        let a_offs = self.offsets();
        let b_offs = other.offsets();
        for a in &a_offs {
            if !self.cells[self.index(a).unwrap()] {
                continue;
            }
            for b in &b_offs {
                if !other.cells[other.index(b).unwrap()] {
                    continue;
                }
                let sum: Vec<i64> = a.iter().zip(b).map(|(x, y)| x + y).collect();
                let i = out.index(&sum).expect("sum fits enlarged hull");
                out.cells[i] = true;
            }
        }
        out
    }

    /// t-fold Minkowski power (t ≥ 1).
    pub fn minkowski_power(&self, t: usize) -> SupportGrid {
        assert!(t >= 1);
        let mut acc = self.clone();
        for _ in 1..t {
            acc = acc.minkowski(self);
        }
        acc
    }

    /// 2:4 structured pruning over the row-major hull: within each
    /// consecutive group of 4 hull cells, keep the first 2 live cells and
    /// drop the rest — the Sparse-Tensor-Core metadata constraint applied
    /// the way SPIDER lays out stencil taps. Deterministic and
    /// weight-independent, so the pruned support is a pure function of
    /// the pattern.
    pub fn prune24(&self) -> SupportGrid {
        let mut out = self.clone();
        let mut kept_in_group = 0usize;
        for (flat, cell) in out.cells.iter_mut().enumerate() {
            if flat % 4 == 0 {
                kept_in_group = 0;
            }
            if *cell {
                if kept_in_group < 2 {
                    kept_in_group += 1;
                } else {
                    *cell = false;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pat(shape: Shape, d: usize, r: usize) -> StencilPattern {
        StencilPattern::new(shape, d, r).unwrap()
    }

    #[test]
    fn k_points_box() {
        assert_eq!(pat(Shape::Box, 2, 1).k_points(), 9);
        assert_eq!(pat(Shape::Box, 2, 3).k_points(), 49);
        assert_eq!(pat(Shape::Box, 2, 7).k_points(), 225);
        assert_eq!(pat(Shape::Box, 3, 1).k_points(), 27);
        assert_eq!(pat(Shape::Box, 3, 2).k_points(), 125);
    }

    #[test]
    fn k_points_star() {
        assert_eq!(pat(Shape::Star, 2, 1).k_points(), 5);
        assert_eq!(pat(Shape::Star, 2, 3).k_points(), 13);
        assert_eq!(pat(Shape::Star, 3, 1).k_points(), 7);
        assert_eq!(pat(Shape::Star, 3, 2).k_points(), 13);
    }

    #[test]
    fn support_count_matches_k() {
        for shape in [Shape::Box, Shape::Star] {
            for d in 1..=3 {
                for r in 1..=3 {
                    let p = pat(shape, d, r);
                    assert_eq!(p.support().count(), p.k_points(), "{p}");
                }
            }
        }
    }

    #[test]
    fn fused_box_closed_form() {
        for d in 1..=3 {
            for r in 1..=2 {
                for t in 1..=4 {
                    let p = pat(Shape::Box, d, r);
                    // exact Minkowski must agree with the closed form
                    let exact = p.support().minkowski_power(t).count();
                    assert_eq!(p.fused_k_points(t), exact, "{p} t={t}");
                }
            }
        }
    }

    #[test]
    fn fused_star_dp_matches_generic_minkowski() {
        // The closed-form DP count must agree with the exact iterated
        // Minkowski sum for every small configuration.
        for d in 1..=3 {
            for r in 1..=2 {
                for t in 1..=4 {
                    let p = pat(Shape::Star, d, r);
                    assert_eq!(
                        p.fused_k_points(t),
                        p.support().minkowski_power(t).count(),
                        "{p} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_star_r1_2d_is_l1_ball() {
        let p = pat(Shape::Star, 2, 1);
        for t in 1..=5u64 {
            assert_eq!(p.fused_k_points(t as usize), 2 * t * t + 2 * t + 1);
        }
    }

    #[test]
    fn fused_t1_is_base() {
        for shape in [Shape::Box, Shape::Star] {
            let p = pat(shape, 2, 2);
            assert_eq!(p.fused_k_points(1), p.k_points());
        }
    }

    #[test]
    fn fused_star_3d_grows_slower_than_box() {
        let st = pat(Shape::Star, 3, 1);
        let bx = pat(Shape::Box, 3, 1);
        for t in 2..=4 {
            assert!(st.fused_k_points(t) < bx.fused_k_points(t));
        }
    }

    #[test]
    fn label_matches_paper_naming() {
        assert_eq!(pat(Shape::Box, 2, 1).label(), "Box-2D1R");
        assert_eq!(pat(Shape::Star, 3, 2).label(), "Star-3D2R");
    }

    #[test]
    fn rejects_degenerate() {
        assert!(StencilPattern::new(Shape::Box, 0, 1).is_err());
        assert!(StencilPattern::new(Shape::Box, 2, 0).is_err());
        assert!(StencilPattern::new(Shape::Box, 5, 1).is_err());
    }

    #[test]
    fn shape_parse_roundtrip() {
        assert_eq!(Shape::parse("box").unwrap(), Shape::Box);
        assert_eq!(Shape::parse("STAR").unwrap(), Shape::Star);
        assert!(Shape::parse("hex").is_err());
    }

    #[test]
    fn pattern_grammar_parses_and_labels() {
        let p = StencilPattern::parse("box-2d1r").unwrap();
        assert_eq!((p.shape, p.d, p.r, p.coeffs), (Shape::Box, 2, 1, Coeffs::Const));
        assert_eq!(p.label(), "Box-2D1R");
        let p = StencilPattern::parse("Star-3D2R:sparse24").unwrap();
        assert_eq!((p.shape, p.d, p.r, p.coeffs), (Shape::Star, 3, 2, Coeffs::Sparse24));
        assert_eq!(p.label(), "Star-3D2R:sparse24");
        let p = StencilPattern::parse("box-2d1r:varcoef").unwrap();
        assert_eq!(p.coeffs, Coeffs::VarCoef);
        assert_eq!(p.label(), "Box-2D1R:varcoef");
        assert!(StencilPattern::parse("box-2d1r:foo").is_err());
        assert!(StencilPattern::parse("box2d1r").is_err());
        assert!(StencilPattern::parse("hex-2d1r").is_err());
        assert!(StencilPattern::parse("box-0d1r").is_err());
    }

    #[test]
    fn coeffs_parse_roundtrip() {
        for c in [Coeffs::Const, Coeffs::Aniso, Coeffs::VarCoef, Coeffs::Sparse24] {
            assert_eq!(Coeffs::parse(c.as_str()).unwrap(), c);
        }
        assert_eq!(Coeffs::parse("2:4").unwrap(), Coeffs::Sparse24);
        assert!(Coeffs::parse("rand").is_err());
    }

    #[test]
    fn prune24_hand_computed_arities() {
        // Hand-walked row-major hulls: groups of 4 cells, first 2 live
        // cells of each group survive.
        let sp24 = |shape, d, r| pat(shape, d, r).with_coeffs(Coeffs::Sparse24);
        assert_eq!(sp24(Shape::Star, 1, 1).effective_k_points(), 2); // keep {0,1} of {0,1,2}
        assert_eq!(sp24(Shape::Star, 2, 1).effective_k_points(), 4); // keep {1,3,4,5} of cross
        assert_eq!(sp24(Shape::Star, 3, 1).effective_k_points(), 6); // keep {4,10,12,13,16,22}
        assert_eq!(sp24(Shape::Box, 2, 1).effective_k_points(), 5); // 2+2+1 over 9 cells
        assert_eq!(sp24(Shape::Box, 3, 1).effective_k_points(), 14); // 6·2 + 2 over 27 cells
        assert_eq!(sp24(Shape::Box, 2, 2).effective_k_points(), 13); // 6·2 + 1 over 25 cells
    }

    #[test]
    fn prune24_kept_cells_are_the_expected_flats() {
        let sup = pat(Shape::Star, 3, 1).support().prune24();
        let kept: Vec<usize> =
            (0..sup.cells.len()).filter(|&i| sup.cells[i]).collect();
        assert_eq!(kept, vec![4, 10, 12, 13, 16, 22]);
        // every group of 4 hull cells holds ≤ 2 survivors
        for g in 0..sup.cells.len().div_ceil(4) {
            let live = sup.cells[g * 4..(g * 4 + 4).min(sup.cells.len())]
                .iter()
                .filter(|&&b| b)
                .count();
            assert!(live <= 2, "group {g} has {live} survivors");
        }
    }

    #[test]
    fn effective_counts_default_to_geometric() {
        for shape in [Shape::Box, Shape::Star] {
            for coeffs in [Coeffs::Const, Coeffs::Aniso, Coeffs::VarCoef] {
                let p = pat(shape, 2, 1).with_coeffs(coeffs);
                assert_eq!(p.effective_k_points(), p.k_points());
                assert_eq!(p.fused_effective_k_points(3), p.fused_k_points(3));
            }
        }
        let p = pat(Shape::Box, 2, 1).with_coeffs(Coeffs::Sparse24);
        assert!(p.effective_k_points() < p.k_points());
        assert_eq!(p.fused_effective_k_points(1), p.effective_k_points());
        assert!(p.fused_effective_k_points(2) <= p.fused_k_points(2));
    }

    #[test]
    fn default_weights_respect_the_variant() {
        // Const: uniform over support.
        let p = pat(Shape::Star, 2, 1);
        assert_eq!(p.default_weights(), p.uniform_weights());
        // Sparse24: uniform over the pruned support, zeros elsewhere.
        let p = p.with_coeffs(Coeffs::Sparse24);
        let w = p.default_weights();
        let nnz = w.iter().filter(|&&x| x != 0.0).count() as u64;
        assert_eq!(nnz, p.effective_k_points());
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        // Aniso: full geometric support, all distinct within a row, sums to 1.
        let p = pat(Shape::Box, 2, 1).with_coeffs(Coeffs::Aniso);
        let w = p.default_weights();
        assert_eq!(w.iter().filter(|&&x| x != 0.0).count() as u64, p.k_points());
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(w[0] != w[2], "sign-asymmetric along last axis");
        assert!(w[1] != w[3], "axis-asymmetric between axes");
        assert!(w.iter().all(|&x| x >= 0.0));
    }
}

//! Stencil patterns: shape, dimensionality, radius — and their point
//! counts, both per-step (K) and after t-step kernel fusion (K^(t)).
//!
//! K^(t) is computed two ways: the paper's box closed form (Eq. 10
//! numerator) and an *exact* iterated Minkowski-sum support count that is
//! valid for any shape — in particular star stencils, whose fused support
//! is a generalized L1 ball the paper does not give a formula for.

use std::fmt;

use anyhow::{bail, Result};

/// Stencil shape (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Shape {
    /// All points with ‖off‖∞ ≤ r: K = (2r+1)^d.
    Box,
    /// Points on the coordinate axes with |off| ≤ r: K = 2dr+1.
    Star,
}

impl Shape {
    /// Parse a CLI/protocol shape name.
    pub fn parse(s: &str) -> Result<Shape> {
        match s.to_ascii_lowercase().as_str() {
            "box" => Ok(Shape::Box),
            "star" => Ok(Shape::Star),
            other => bail!("unknown stencil shape {other:?} (want box|star)"),
        }
    }

    /// The stable lowercase shape name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Shape::Box => "box",
            Shape::Star => "star",
        }
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A stencil pattern: the paper's (shape, d, r) triple.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StencilPattern {
    /// Neighbourhood shape (box or star).
    pub shape: Shape,
    /// Dimensionality (1..=4).
    pub d: usize,
    /// Radius (1..=16).
    pub r: usize,
}

impl StencilPattern {
    /// Build a pattern, rejecting degenerate (d, r).
    pub fn new(shape: Shape, d: usize, r: usize) -> Result<StencilPattern> {
        if d == 0 || d > 4 {
            bail!("dimensionality must be 1..=4, got {d}");
        }
        if r == 0 || r > 16 {
            bail!("radius must be 1..=16, got {r}");
        }
        Ok(StencilPattern { shape, d, r })
    }

    /// Paper naming, e.g. "Box-2D1R".
    pub fn label(&self) -> String {
        let s = match self.shape {
            Shape::Box => "Box",
            Shape::Star => "Star",
        };
        format!("{s}-{}D{}R", self.d, self.r)
    }

    /// K — number of points in the (unfused) kernel.
    pub fn k_points(&self) -> u64 {
        match self.shape {
            Shape::Box => (2 * self.r as u64 + 1).pow(self.d as u32),
            Shape::Star => 2 * self.d as u64 * self.r as u64 + 1,
        }
    }

    /// The support as a boolean hypercube over the (2r+1)^d hull.
    pub fn support(&self) -> SupportGrid {
        let n = 2 * self.r + 1;
        let mut g = SupportGrid::zeros(self.d, n);
        let r = self.r as i64;
        g.fill_by(|off| match self.shape {
            Shape::Box => true,
            Shape::Star => off.iter().filter(|&&o| o != 0).count() <= 1,
        });
        debug_assert_eq!(g.count(), self.k_points());
        let _ = r;
        g
    }

    /// Support-normalized uniform weights over the (2r+1)^d hull
    /// (row-major, zeros off-support) — the default kernel for CLI runs
    /// and service sessions that don't supply their own.
    pub fn uniform_weights(&self) -> Vec<f64> {
        let sup = self.support();
        let k = sup.count() as f64;
        sup.cells.iter().map(|&b| if b { 1.0 / k } else { 0.0 }).collect()
    }

    /// K^(t) — points in the fused kernel support (exact for any shape).
    ///
    /// Box: (2rt+1)^d (Eq. 10 numerator).  Star: the t-fold Minkowski sum
    /// of the radius-r cross is exactly {x : Σ_i ⌈|x_i|/r⌉ ≤ t} — each
    /// axis displacement |x_i| needs ⌈|x_i|/r⌉ cross steps and steps are
    /// spent independently per axis.  Counted in O((2rt+1)^d) instead of
    /// the O(cells²)-per-step generic Minkowski iteration (which remains
    /// available via `SupportGrid::minkowski_power` and cross-checks this
    /// in the tests).
    pub fn fused_k_points(&self, t: usize) -> u64 {
        assert!(t >= 1);
        match self.shape {
            Shape::Box => (2 * self.r as u64 * t as u64 + 1).pow(self.d as u32),
            Shape::Star => {
                let r = self.r as u64;
                let rt = (r * t as u64) as i64;
                // per-axis tally: for cost c (0..=t), how many x with
                // ceil(|x|/r) == c ?  c=0 → 1 (x=0); c>=1 → 2r values.
                // Count d-tuples with total cost <= t via DP.
                let mut ways = vec![0u64; t + 1]; // ways[c] per axis
                ways[0] = 1;
                for c in 1..=t {
                    ways[c] = 2 * r;
                }
                let _ = rt;
                let mut acc = vec![0u64; t + 1];
                acc[0] = 1; // empty product
                for _ in 0..self.d {
                    let mut next = vec![0u64; t + 1];
                    for total in 0..=t {
                        for c in 0..=total {
                            next[total] += acc[total - c] * ways[c];
                        }
                    }
                    acc = next;
                }
                acc.iter().sum()
            }
        }
    }
}

impl fmt::Display for StencilPattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.label())
    }
}

/// Dense boolean grid over a d-dim hull of side n (n odd), centered.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SupportGrid {
    /// Dimensionality of the hull.
    pub d: usize,
    /// Side length of the hull (odd).
    pub n: usize,
    /// Row-major cell membership over the hull.
    pub cells: Vec<bool>,
}

impl SupportGrid {
    /// An empty support over a d-dim hull of (odd) side n.
    pub fn zeros(d: usize, n: usize) -> SupportGrid {
        assert!(n % 2 == 1, "hull side must be odd");
        SupportGrid { d, n, cells: vec![false; n.pow(d as u32)] }
    }

    fn radius(&self) -> i64 {
        ((self.n - 1) / 2) as i64
    }

    /// Linear index of a (centered) offset.
    fn index(&self, off: &[i64]) -> Option<usize> {
        let r = self.radius();
        let mut idx = 0usize;
        for &o in off {
            if o < -r || o > r {
                return None;
            }
            idx = idx * self.n + (o + r) as usize;
        }
        Some(idx)
    }

    /// Iterate all offsets of the hull.
    fn offsets(&self) -> Vec<Vec<i64>> {
        let r = self.radius();
        let mut out = Vec::with_capacity(self.cells.len());
        let mut cur = vec![-r; self.d];
        loop {
            out.push(cur.clone());
            // odometer increment
            let mut k = self.d;
            loop {
                if k == 0 {
                    return out;
                }
                k -= 1;
                if cur[k] < r {
                    cur[k] += 1;
                    for c in cur.iter_mut().skip(k + 1) {
                        *c = -r;
                    }
                    break;
                } else if k == 0 {
                    return out;
                }
            }
        }
    }

    /// Mark every hull offset for which `f` returns true.
    pub fn fill_by<F: Fn(&[i64]) -> bool>(&mut self, f: F) {
        for off in self.offsets() {
            if f(&off) {
                let i = self.index(&off).unwrap();
                self.cells[i] = true;
            }
        }
    }

    /// Number of marked cells (= K for a pattern's own support).
    pub fn count(&self) -> u64 {
        self.cells.iter().filter(|&&b| b).count() as u64
    }

    /// Minkowski sum with another centered support (support dilation).
    pub fn minkowski(&self, other: &SupportGrid) -> SupportGrid {
        assert_eq!(self.d, other.d);
        let n_out = self.n + other.n - 1;
        let mut out = SupportGrid::zeros(self.d, n_out);
        let a_offs = self.offsets();
        let b_offs = other.offsets();
        for a in &a_offs {
            if !self.cells[self.index(a).unwrap()] {
                continue;
            }
            for b in &b_offs {
                if !other.cells[other.index(b).unwrap()] {
                    continue;
                }
                let sum: Vec<i64> = a.iter().zip(b).map(|(x, y)| x + y).collect();
                let i = out.index(&sum).expect("sum fits enlarged hull");
                out.cells[i] = true;
            }
        }
        out
    }

    /// t-fold Minkowski power (t ≥ 1).
    pub fn minkowski_power(&self, t: usize) -> SupportGrid {
        assert!(t >= 1);
        let mut acc = self.clone();
        for _ in 1..t {
            acc = acc.minkowski(self);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pat(shape: Shape, d: usize, r: usize) -> StencilPattern {
        StencilPattern::new(shape, d, r).unwrap()
    }

    #[test]
    fn k_points_box() {
        assert_eq!(pat(Shape::Box, 2, 1).k_points(), 9);
        assert_eq!(pat(Shape::Box, 2, 3).k_points(), 49);
        assert_eq!(pat(Shape::Box, 2, 7).k_points(), 225);
        assert_eq!(pat(Shape::Box, 3, 1).k_points(), 27);
        assert_eq!(pat(Shape::Box, 3, 2).k_points(), 125);
    }

    #[test]
    fn k_points_star() {
        assert_eq!(pat(Shape::Star, 2, 1).k_points(), 5);
        assert_eq!(pat(Shape::Star, 2, 3).k_points(), 13);
        assert_eq!(pat(Shape::Star, 3, 1).k_points(), 7);
        assert_eq!(pat(Shape::Star, 3, 2).k_points(), 13);
    }

    #[test]
    fn support_count_matches_k() {
        for shape in [Shape::Box, Shape::Star] {
            for d in 1..=3 {
                for r in 1..=3 {
                    let p = pat(shape, d, r);
                    assert_eq!(p.support().count(), p.k_points(), "{p}");
                }
            }
        }
    }

    #[test]
    fn fused_box_closed_form() {
        for d in 1..=3 {
            for r in 1..=2 {
                for t in 1..=4 {
                    let p = pat(Shape::Box, d, r);
                    // exact Minkowski must agree with the closed form
                    let exact = p.support().minkowski_power(t).count();
                    assert_eq!(p.fused_k_points(t), exact, "{p} t={t}");
                }
            }
        }
    }

    #[test]
    fn fused_star_dp_matches_generic_minkowski() {
        // The closed-form DP count must agree with the exact iterated
        // Minkowski sum for every small configuration.
        for d in 1..=3 {
            for r in 1..=2 {
                for t in 1..=4 {
                    let p = pat(Shape::Star, d, r);
                    assert_eq!(
                        p.fused_k_points(t),
                        p.support().minkowski_power(t).count(),
                        "{p} t={t}"
                    );
                }
            }
        }
    }

    #[test]
    fn fused_star_r1_2d_is_l1_ball() {
        let p = pat(Shape::Star, 2, 1);
        for t in 1..=5u64 {
            assert_eq!(p.fused_k_points(t as usize), 2 * t * t + 2 * t + 1);
        }
    }

    #[test]
    fn fused_t1_is_base() {
        for shape in [Shape::Box, Shape::Star] {
            let p = pat(shape, 2, 2);
            assert_eq!(p.fused_k_points(1), p.k_points());
        }
    }

    #[test]
    fn fused_star_3d_grows_slower_than_box() {
        let st = pat(Shape::Star, 3, 1);
        let bx = pat(Shape::Box, 3, 1);
        for t in 2..=4 {
            assert!(st.fused_k_points(t) < bx.fused_k_points(t));
        }
    }

    #[test]
    fn label_matches_paper_naming() {
        assert_eq!(pat(Shape::Box, 2, 1).label(), "Box-2D1R");
        assert_eq!(pat(Shape::Star, 3, 2).label(), "Star-3D2R");
    }

    #[test]
    fn rejects_degenerate() {
        assert!(StencilPattern::new(Shape::Box, 0, 1).is_err());
        assert!(StencilPattern::new(Shape::Box, 2, 0).is_err());
        assert!(StencilPattern::new(Shape::Box, 5, 1).is_err());
    }

    #[test]
    fn shape_parse_roundtrip() {
        assert_eq!(Shape::parse("box").unwrap(), Shape::Box);
        assert_eq!(Shape::parse("STAR").unwrap(), Shape::Star);
        assert!(Shape::parse("hex").is_err());
    }
}

//! The paper's analytical performance model, executable.
//!
//! * [`stencil`]    — patterns (shape/d/r + coeffs axis), K, fused
//!   support K^(t), and the 2:4-pruned effective counts K_eff/K_eff^(t)
//! * [`roofline`]   — Eq. 4–5: P = min(ℙ, 𝔹·I), ridge point
//! * [`redundancy`] — Eq. 9–10: fusion redundancy α (closed form + exact)
//! * [`sparsity`]   — Eq. 2: transformation sparsity S per scheme
//! * [`perf`]       — Eq. 6–12, 20: C, M, I and P per execution unit
//! * [`scenario`]   — Eq. 13–18: the four bottleneck-transition scenarios
//! * [`criteria`]   — Eq. 19 + §4.3: sweet-spot and SpTC-expanded regions
//! * [`calib`]      — predicted vs. *measured* intensity feedback
//! * [`shard`]      — shard halo redundancy κ/τ (the distributed α)
//!
//! The full equation-by-equation map from the paper to these symbols
//! lives in `rust/docs/MODEL.md`; the doctest below compiles one call
//! to every symbol that document names, so the map cannot rot silently.
//!
//! ```
//! use tc_stencil::model::{calib, criteria, redundancy, scenario, sparsity};
//! use tc_stencil::model::perf::{Dtype, Scheme, Unit, Workload};
//! use tc_stencil::model::roofline::Roof;
//! use tc_stencil::model::stencil::{Shape, StencilPattern};
//!
//! // Eq. 1 — the stencil pattern and its kernel point count K.
//! let p = StencilPattern::new(Shape::Box, 2, 1).unwrap();
//! assert_eq!(p.k_points(), 9);
//! assert_eq!(p.support().count(), 9);
//! assert_eq!(p.fused_k_points(3), 49); // fused support K^(t)
//!
//! // Eq. 2 — transformation sparsity S per adaptation scheme.
//! let s = sparsity::sparsity(Scheme::Flatten, &p, 3);
//! assert!(s > 0.0 && s <= 1.0);
//! assert!(sparsity::flatten_sparsity(&p, 3) > 0.0);
//! assert!(sparsity::decompose_sparsity(&p, 3) > 0.0);
//!
//! let w = Workload::new(p, 3, Dtype::F64);
//!
//! // Eq. 3 — tensor-core compute volume C = (α/S)·t·2K.
//! assert!(w.c_tensor(Scheme::Flatten) > w.c_cuda());
//!
//! // Eq. 4–5 — the roofline and its ridge point (A100 f64 CUDA roof).
//! let cu = Roof::new(9.7e12, 1.935e12);
//! let tc = Roof::new(19.5e12, 1.935e12);
//! assert!((cu.ridge() - 5.01).abs() < 0.02);
//! assert_eq!(cu.attainable(1.0), 1.935e12);
//!
//! // Eq. 6 — M = 2D bytes per output point.
//! assert_eq!(w.m_bytes(), 16.0);
//!
//! // Eq. 7/8 — I = C/M; CUDA Cores realize t·K/D.
//! assert!((w.intensity_cuda() - 3.375).abs() < 1e-12);
//! assert!(w.intensity_fused_sweep() > w.intensity_cuda()); // α·t·K/D
//! assert_eq!(cu.bound(w.intensity_cuda()), tc_stencil::model::roofline::Bound::Memory);
//!
//! // Eq. 9/10 — fusion redundancy α, exact and box closed form.
//! assert!((redundancy::alpha(&p, 3) - 49.0 / 27.0).abs() < 1e-12);
//! assert!((redundancy::alpha_box_closed_form(&p, 3) - w.alpha()).abs() < 1e-12);
//!
//! // Eq. 11 — tensor intensity (α/S)·t·K/D.
//! assert!(w.intensity_tensor(Scheme::Flatten) > w.intensity_cuda());
//!
//! // Eq. 12 — actual (useful-FLOP) performance divides out α/S.
//! let raw = w.raw_perf(&tc, Unit::TensorCore, Scheme::Flatten);
//! let act = w.actual_perf(&tc, Unit::TensorCore, Scheme::Flatten);
//! assert!(act < raw);
//! assert!(w.stencil_throughput(&cu, Unit::CudaCore, Scheme::Direct) > 0.0);
//!
//! // Eq. 13–18 — the four bottleneck-transition scenarios.
//! let cmp = scenario::compare(&w, &cu, &tc, Unit::TensorCore, Scheme::Flatten);
//! assert_eq!(cmp.scenario, scenario::Scenario::MemToComp); // Table 3 case 1
//! assert_eq!(cmp.verdict, scenario::Verdict::Underperforms);
//! assert!(cmp.speedup < 1.0);
//!
//! // Eq. 19 — the compute/compute sweet-spot criterion.
//! assert!(criteria::sweet_spot_cc(1.0, 0.5, 19.5e12, 9.7e12));
//! assert!(!criteria::in_sweet_spot(&w, &cu, &tc, Unit::TensorCore, Scheme::Flatten));
//! assert!(criteria::max_profitable_t(&p, Dtype::F64, &cu, &tc,
//!     Unit::TensorCore, Scheme::Flatten, 8).is_none());
//!
//! // Eq. 20 — SpTC doubles ℙ and re-runs the same machinery.
//! let sp = criteria::sptc_roof(&tc);
//! assert_eq!(sp.peak_flops, 2.0 * tc.peak_flops);
//! assert!(!criteria::region_sweep(&p, Dtype::F64, &cu, &tc, Scheme::Flatten, 8).is_empty());
//!
//! // Measured-side feedback: Eq. 8 as an observable.
//! assert_eq!(calib::predicted_intensity(&w, true), w.intensity_cuda());
//! let rep = calib::report(&w, 3, true, w.intensity_cuda() * 0.97);
//! assert!(rep.within_region);
//!
//! // Shard halo redundancy — the distributed analogue of α: κ/τ per
//! // balanced dim-0 split, the planner's shard-count gain model, and
//! // the shard-aware intensity prediction (= calib's at one shard).
//! use tc_stencil::model::shard;
//! assert_eq!(shard::cuts(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
//! let f = shard::factors(8, 4, 1, 4, true);
//! assert!((f.compute - 2.0625).abs() < 1e-12 && (f.traffic - 2.25).abs() < 1e-12);
//! assert!((shard::gain(256, 4, 1, 1, false, 4, 1) - 4.0).abs() < 1e-12);
//! assert!(shard::gain(8, 4, 1, 8, true, 4, 2) < 1.0); // redundancy crossover
//! let i4 = shard::predicted_job_intensity(&w, 6, true, 64, 4);
//! let i1 = shard::predicted_job_intensity(&w, 6, true, 64, 1);
//! assert!(i4 < i1 && (i1 - calib::predicted_job_intensity(&w, 6, true)).abs() < 1e-12);
//!
//! // Measured constants (MODEL.md "measured constants" table): a
//! // MachineProfile carries 𝔹 (Eq. 4 bandwidth), the per-unit ℙ table
//! // (Eq. 4/20 peaks), and the §4.2 clock-lock derating — the builtin
//! // profile reproduces the registry roofs bit-exactly, and the drift
//! // plane flags a profile once the EWMA of Eq. 8's measured error
//! // leaves the model's region tolerance.
//! use tc_stencil::engines::builtin_profile;
//! use tc_stencil::tune::drift;
//! let prof = builtin_profile(&tc_stencil::hardware::Gpu::a100());
//! assert_eq!(prof.bandwidth, 1.935e12);              // 𝔹
//! assert_eq!(prof.peaks.cuda_f64, Some(9.7e12));     // ℙ_CU
//! assert_eq!(prof.peaks.sptc_f32, Some(312e12));     // ℙ_SpTC (Eq. 20)
//! assert_eq!(prof.clock_lock, 1.0);                  // §4.2 derating
//! let roof = prof.gpu().roof(Unit::CudaCore, Dtype::F64).unwrap();
//! assert!((roof.ridge() - 5.01).abs() < 0.02);       // measured balance point
//! assert_eq!(drift::DRIFT_THRESHOLD, calib::REGION_TOLERANCE);
//!
//! // Per-kernel peaks (MODEL.md "per-kernel peaks"): profile v2 can
//! // carry one measured ℙ per (shape, dtype, temporal realization);
//! // the planner substitutes it into Eq. 4 for the scalar candidate
//! // whose arity — K blocked, K^(t) fused — the registry covers.
//! use tc_stencil::backend::kernels::{self, KernelPeak};
//! assert_eq!(kernels::shape_key(&p), "box-2d1r");
//! assert!(kernels::ARITIES.contains(&(p.k_points() as usize)));         // K = 9
//! assert!(kernels::ARITIES.contains(&(p.fused_k_points(3) as usize)));  // K^(3) = 49
//! assert!(!kernels::ARITIES.contains(&(p.fused_k_points(7) as usize))); // K^(7) = 225
//! let peaks = vec![KernelPeak {
//!     shape: "box-2d1r".into(),
//!     dtype: Dtype::F64,
//!     blocked: true,
//!     flops: 1.0e11,
//! }];
//! assert_eq!(kernels::peak_for(&peaks, &p, Dtype::F64, true), Some(1.0e11));
//! assert_eq!(kernels::peak_for(&peaks, &p, Dtype::F64, false), None); // sweep unprobed
//! // star-1/2/3D, box-2/3D dense + the three pruned-arity variants
//! assert_eq!(kernels::probe_shapes().len(), 8);
//! assert_eq!(builtin_profile(&tc_stencil::hardware::Gpu::a100()).kernels.len(), 0);
//!
//! // §4.3 sparsity-expanded region (MODEL.md "sparsity-expanded
//! // region"): the pattern's coefficient axis reuses Eq. 2/9/20's
//! // machinery.  A 2:4-pruned pattern shrinks K and K^(t) to the
//! // effective (kept-tap) counts the planner prices with, so α and
//! // every intensity move with them; SpTC engines keep their paper S
//! // while Eq. 20 doubles ℙ — two independent expansions of the
//! // profitable region.
//! use tc_stencil::model::stencil::Coeffs;
//! let sp24 = p.with_coeffs(Coeffs::Sparse24);
//! assert_eq!(sp24.effective_k_points(), 5);         // K_eff: 9 → 5 taps
//! assert_eq!(sp24.fused_effective_k_points(3), 22); // K_eff^(3) < 49
//! assert_eq!(p.effective_k_points(), 9);            // const: geometric
//! let wsp = Workload::new(sp24, 8, Dtype::F32);
//! assert!((wsp.alpha() - 117.0 / 40.0).abs() < 1e-12);  // α_eff(8)
//! assert!(wsp.alpha() < redundancy::alpha(&p, 8));      // < dense α(8)
//! // pruning halves the blocked intensity: t·K_eff/D = 10 sits under
//! // the A100 f32 ridge where the dense t·K/D = 18 was compute-bound
//! let cu32 = Roof::new(19.5e12, 1.935e12);
//! assert_eq!(wsp.intensity_cuda(), 10.0);
//! assert!(wsp.intensity_cuda() < cu32.ridge());
//! assert!(Workload::new(p, 8, Dtype::F32).intensity_cuda() > cu32.ridge());
//! // the SpTC scheme's Eq. 2 operand sparsity is what Eq. 11 divides by
//! assert_eq!(sparsity::sparsity(Scheme::Sparse24, &p, 7),
//!            sparsity::sparsity(Scheme::Decompose, &p, 7));
//!
//! // Exported metrics (MODEL.md "exported metrics" table): the obs
//! // plane streams Eq. 6/8's counters per span — their per-phase
//! // ratio is Eq. 7 measured — and the Prometheus histograms place
//! // the model's thresholds inside readable log₂ buckets.
//! use tc_stencil::coordinator::metrics::PhaseMetrics;
//! use tc_stencil::obs;
//! let ph = PhaseMetrics {
//!     index: 0, depth: 3, fused: false, execute_ns: 1, assemble_ns: 0,
//!     bytes_moved: 16, flops: 54, interior_points: 3, boundary_points: 1,
//! };
//! assert_eq!(ph.achieved_intensity(), 54.0 / 16.0); // Eq. 7: I = C/M, per phase
//! assert_eq!(ph.interior_fraction(), 0.75);         // roofline-priced coverage
//! let om = obs::metrics();
//! // model_err buckets 2⁻¹⁰…2⁴ hold the drift boundary in a finite bucket.
//! assert_eq!(om.model_err.bounds().first().copied(), Some(2.0_f64.powi(-10)));
//! assert!(om.model_err.bucket_index(calib::REGION_TOLERANCE) < om.model_err.bounds().len());
//! // queue wait / phase wall / barrier stall share one ns layout (2¹⁰…2³⁴).
//! assert_eq!(om.queue_wait_ns.bounds().first().copied(), Some(1024.0));
//! assert_eq!(om.phase_wall_ns.bounds(), om.barrier_stall_ns.bounds());
//! // Per-kernel GPts/s — the streamed counterpart of `KernelPeak`.
//! om.observe_kernel_gpts("box-2d1r/double/doctest", 0.25);
//! assert!(om.kernel_rows().iter().any(|(k, n, _)| k.ends_with("/doctest") && *n >= 1));
//! // Quantile estimates walk the log₂ buckets: the p-th estimate is a
//! // bucket upper bound, so it overshoots the exact percentile by at
//! // most 2× (documented on `Histogram::quantile`).
//! let h = tc_stencil::obs::prom::Histogram::new(0, 8);
//! h.observe(3.0);
//! assert_eq!(h.quantile(0.99), Some(4.0)); // 3 ∈ (2, 4] → bound 4
//!
//! // Attribution residuals (MODEL.md "attribution residuals" table):
//! // each term prices one Eq. symbol against what the job measured —
//! // bandwidth = exec − bytes/𝔹 (Eq. 4's memory roof), kernel =
//! // exec − flops/ℙ (Eq. 4's compute roof), redundancy = the bytes
//! // beyond Eq. 8/9's priced traffic (flops / I_predicted, the κ/τ/α
//! // assumptions), serving = handler wall outside execution.
//! use tc_stencil::obs::attrib::{self, JobObservation, Term};
//! assert_eq!(calib::predicted_job_bytes(9000.0, 4.5), 2000.0); // flops / I
//! // A memory-bound job priced at 1 ms that took 2 ms: the profile 𝔹
//! // (2 GB/s) prices its 2 MB at 1 ms, so the extra millisecond lands
//! // on the bandwidth term — the machine's 𝔹 has drifted below the
//! // profile constant.
//! let o = JobObservation {
//!     predicted_ms: 1.0, exec_ms: 2.0, serve_ms: 0.1, mem_bound: true,
//!     bytes_moved: 2.0e6, bytes_predicted: 2.0e6, flops: 9.0e6,
//!     bandwidth: 2.0e9, peak_flops: 9.0e9,
//! };
//! let a = attrib::attribute(&o);
//! assert_eq!(a.verdict, Term::Bandwidth);
//! let bw = a.terms.iter().find(|t| t.term == Term::Bandwidth).unwrap();
//! assert!((bw.residual_ms - 1.0).abs() < 1e-12);     // exec − bytes/𝔹
//! // 4 ranked terms per job: serving, redundancy, ONE roof term
//! // (bandwidth when mem-bound, kernel otherwise), unattributed.
//! assert_eq!(a.terms.len(), 4);
//! assert_eq!(Term::all().len(), 5);
//! ```

#![warn(missing_docs)]

pub mod stencil;
pub mod roofline;
pub mod redundancy;
pub mod sparsity;
pub mod perf;
pub mod scenario;
pub mod criteria;
pub mod calib;
pub mod shard;

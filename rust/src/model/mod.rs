//! The paper's analytical performance model, executable.
//!
//! * [`stencil`]    — patterns (shape/d/r), K, fused support K^(t)
//! * [`roofline`]   — Eq. 4–5: P = min(ℙ, 𝔹·I), ridge point
//! * [`redundancy`] — Eq. 9–10: fusion redundancy α (closed form + exact)
//! * [`sparsity`]   — Eq. 2: transformation sparsity S per scheme
//! * [`perf`]       — Eq. 6–12, 20: C, M, I and P per execution unit
//! * [`scenario`]   — Eq. 13–18: the four bottleneck-transition scenarios
//! * [`criteria`]   — Eq. 19 + §4.3: sweet-spot and SpTC-expanded regions

pub mod stencil;
pub mod roofline;
pub mod redundancy;
pub mod sparsity;
pub mod perf;
pub mod scenario;
pub mod criteria;

//! Shard halo redundancy — the distributed analogue of the paper's
//! fusion redundancy α (Eq. 9).
//!
//! Splitting a domain into shards introduces exactly the kind of
//! redundancy the paper models for hardware-shape adaptation: each
//! shard's halo ring must be **re-read** every synchronization phase
//! (halo traffic) and, for temporally blocked shards, the trapezoid's
//! intermediate steps **recompute** the overlap region (halo
//! recompute).  Both are pure functions of the decomposition geometry,
//! so — like α — they can be folded into the roofline *before*
//! executing anything:
//!
//! * κ ([`ShardFactors::compute`]) — base-kernel applications per
//!   useful point-step, ≥ 1.  For a blocked phase of depth `t` over a
//!   balanced dim-0 split with unclamped halos this is exactly
//!   `κ = 1 + r·(t−1)·(S−1)/n₀` — linear in the shard count, the
//!   distributed mirror of α's `t`-growth.  Sweep phases compute only
//!   their disjoint write-back region, so κ ≡ 1.
//! * τ ([`ShardFactors::traffic`]) — bytes moved per useful 2D bytes,
//!   ≥ 1: every phase re-reads the `t·r`-deepened halo ring.
//!
//! [`gain`] turns these into the planner's shard decision: an S-way
//! sharded job runs its shards on `min(S, lanes)` worker lanes of the
//! service pool (one thread each), while the monolithic path runs on
//! one worker with `mono_threads` intra-job threads.  The native
//! engine saturates compute at stencil intensities, so the time model
//! divides the parallel gain by κ — the planner selects >1 shard
//! exactly when `min(S, lanes)/mono_threads` beats the recompute
//! factor, the shard-axis analogue of Eq. 19's sweet-spot test.
//!
//! [`predicted_job_intensity`] is the shard-aware generalization of
//! [`calib::predicted_job_intensity`](crate::model::calib::predicted_job_intensity)
//! (it reduces to it exactly at `shards == 1`), mirroring the
//! executor's per-shard traffic/flop accounting term for term so the
//! model↔measurement feedback loop stays closed for sharded runs.

use crate::model::perf::Workload;

/// Balanced contiguous cuts of `n0` dim-0 planes into (at most)
/// `shards` shards: the first `n0 % s` shards carry one extra plane.
/// This is the canonical split shared by the execution plan
/// (`coordinator::grid::ShardPlan`) and the model, so predictions and
/// metrics describe the same geometry.
pub fn cuts(n0: usize, shards: usize) -> Vec<(usize, usize)> {
    let s = shards.min(n0).max(1);
    let base = n0 / s;
    let rem = n0 % s;
    let mut out = Vec::with_capacity(s);
    let mut start = 0;
    for i in 0..s {
        let size = base + usize::from(i < rem);
        out.push((start, start + size));
        start += size;
    }
    out
}

/// The two shard redundancy factors of one synchronization phase.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardFactors {
    /// κ — executed base-kernel applications per useful point-step
    /// (trapezoid halo recompute; 1.0 for sweep phases and for a
    /// single shard).
    pub compute: f64,
    /// τ — field bytes moved per useful `2D` bytes (halo re-reads;
    /// 1.0 for a single shard).
    pub traffic: f64,
}

/// Exact κ/τ for one phase of depth `t` over a balanced dim-0 split of
/// `n0` planes into `shards` shards with base-kernel radius `r`.
/// `blocked` phases carry `t` sequential steps per shard (trapezoid
/// recompute); sweep phases launch the `t`-fold fused kernel once
/// (halo reads only, no recompute).
pub fn factors(n0: usize, shards: usize, r: usize, t: usize, blocked: bool) -> ShardFactors {
    let t = t.max(1);
    let cs = cuts(n0, shards);
    let (compute, reads) = if blocked {
        let mut applied = 0usize;
        let mut reads = 0usize;
        for &(a, b) in &cs {
            for s in 1..=t {
                let olo = a.saturating_sub((t - s) * r);
                let ohi = (b + (t - s) * r).min(n0);
                applied += ohi - olo;
            }
            reads += (b + t * r).min(n0) - a.saturating_sub(t * r);
        }
        (applied as f64 / (t * n0) as f64, reads)
    } else {
        let h = r * t;
        let reads: usize =
            cs.iter().map(|&(a, b)| (b + h).min(n0) - a.saturating_sub(h)).sum();
        (1.0, reads)
    };
    ShardFactors { compute, traffic: (reads + n0) as f64 / (2 * n0) as f64 }
}

/// Relative throughput of an S-way sharded execution over the
/// monolithic path: `min(S, lanes)/mono_threads` parallel lanes,
/// divided by the κ recompute factor of the shard geometry (the
/// compute-bound lane model — τ is reported through the intensity
/// feedback instead).  `1.0` for `shards <= 1`; the planner picks a
/// sharded candidate exactly when this exceeds 1 (ties break toward
/// fewer shards).
pub fn gain(
    n0: usize,
    shards: usize,
    r: usize,
    t: usize,
    blocked: bool,
    lanes: usize,
    mono_threads: usize,
) -> f64 {
    if shards <= 1 {
        return 1.0;
    }
    let active = cuts(n0, shards).len().min(lanes.max(1));
    let f = factors(n0, shards, r, t, blocked);
    (active as f64 / mono_threads.max(1) as f64) / f.compute
}

/// The barrier-phase schedule of a job as `(depth, fused)` pairs:
/// blocked → time blocks of depth ≤ `t`; sweep → `steps/t` fused
/// launches plus `steps%t` base launches.  The single source of truth
/// shared by the executor
/// ([`backend::shard_phases`](crate::backend::shard_phases) wraps it)
/// and [`predicted_job_intensity`], so the model can never
/// desynchronize from what actually runs.
pub fn phase_schedule(steps: usize, t: usize, blocked: bool) -> Vec<(usize, bool)> {
    let t = t.max(1);
    let mut out = Vec::new();
    if blocked {
        let mut remaining = steps;
        while remaining > 0 {
            let tb = t.min(remaining);
            out.push((tb, false));
            remaining -= tb;
        }
    } else {
        out.extend(std::iter::repeat((t, true)).take(steps / t));
        out.extend(std::iter::repeat((1, true)).take(steps % t));
    }
    out
}

/// Step-count-aware predicted intensity of an S-way sharded job —
/// mirrors the executor's per-shard accounting exactly: each phase
/// re-reads every shard's `depth·r`-deepened halo ring and (blocked)
/// recomputes the trapezoid overlap.  Reduces to
/// [`calib::predicted_job_intensity`](crate::model::calib::predicted_job_intensity)
/// at `shards == 1`.
pub fn predicted_job_intensity(
    w: &Workload,
    steps: usize,
    blocked: bool,
    n0: usize,
    shards: usize,
) -> f64 {
    if steps == 0 {
        return 0.0;
    }
    let r = w.pattern.r;
    let d_bytes = w.dtype.bytes() as f64;
    let cs = cuts(n0, shards);
    let mut flops = 0.0;
    let mut bytes = 0.0;
    for (depth, fused) in phase_schedule(steps, w.t, blocked) {
        for &(a, b) in &cs {
            if fused {
                let h = r * depth;
                let reads = (b + h).min(n0) - a.saturating_sub(h);
                bytes += d_bytes * (reads + (b - a)) as f64;
                flops += 2.0 * w.pattern.fused_k_points(depth) as f64 * (b - a) as f64;
            } else {
                let reads = (b + depth * r).min(n0) - a.saturating_sub(depth * r);
                bytes += d_bytes * (reads + (b - a)) as f64;
                for s in 1..=depth {
                    let olo = a.saturating_sub((depth - s) * r);
                    let ohi = (b + (depth - s) * r).min(n0);
                    flops += 2.0 * w.k() * (ohi - olo) as f64;
                }
            }
        }
    }
    flops / bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::calib;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};

    fn wl(shape: Shape, d: usize, r: usize, t: usize, dt: Dtype) -> Workload {
        Workload::new(StencilPattern::new(shape, d, r).unwrap(), t, dt)
    }

    #[test]
    fn cuts_partition_and_balance() {
        assert_eq!(cuts(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(cuts(8, 2), vec![(0, 4), (4, 8)]);
        // clamped to one plane per shard, never empty
        assert_eq!(cuts(2, 5).len(), 2);
        assert_eq!(cuts(7, 1), vec![(0, 7)]);
        for (n0, s) in [(100, 7), (13, 4), (5, 5)] {
            let cs = cuts(n0, s);
            assert_eq!(cs.first().unwrap().0, 0);
            assert_eq!(cs.last().unwrap().1, n0);
            for w in cs.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
                assert!(w[0].1 > w[0].0, "non-empty");
            }
            let sizes: Vec<usize> = cs.iter().map(|&(a, b)| b - a).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1, "balanced: {sizes:?}");
        }
    }

    #[test]
    fn single_shard_has_no_redundancy() {
        for blocked in [true, false] {
            let f = factors(64, 1, 1, 4, blocked);
            assert_eq!(f.compute, 1.0);
            assert_eq!(f.traffic, 1.0);
            assert_eq!(gain(64, 1, 1, 4, blocked, 8, 1), 1.0);
        }
    }

    #[test]
    fn blocked_factors_match_hand_geometry() {
        // n0=8, S=4, r=1, t=4 (prototype-pinned): κ = 2.0625, τ = 2.25.
        let f = factors(8, 4, 1, 4, true);
        assert!((f.compute - 2.0625).abs() < 1e-12, "{}", f.compute);
        // reads: shard (0,2): [0,6)=6; (2,4): [0,8)=8; (4,6): [0,8)=8;
        // (6,8): [2,8)=6 → 28; τ = (28+8)/16 = 2.25.
        assert!((f.traffic - 2.25).abs() < 1e-12, "{}", f.traffic);
        // sweep phases never recompute
        let fs = factors(8, 4, 1, 4, false);
        assert_eq!(fs.compute, 1.0);
        assert!(fs.traffic > 1.0);
    }

    #[test]
    fn kappa_grows_linearly_in_shards_for_interior() {
        // Unclamped halos: κ = 1 + r·(t−1)·(S−1)/n0 exactly (interior
        // shards recompute two-sided, the two boundary shards one-sided).
        let n0 = 1024;
        for s in [2usize, 4, 8] {
            let f = factors(n0, s, 1, 4, true);
            let exact = 1.0 + (3 * (s - 1)) as f64 / n0 as f64;
            assert!((f.compute - exact).abs() < 1e-12, "S={s}: {}", f.compute);
        }
    }

    #[test]
    fn gain_crossover_matches_prototype() {
        // Large domain, sweep t=1, 4 lanes vs 1 mono thread: pure 4×.
        assert!((gain(256, 4, 1, 1, false, 4, 1) - 4.0).abs() < 1e-12);
        // Large blocked domain keeps most of the parallel gain.
        let g = gain(256, 4, 1, 4, true, 4, 1);
        assert!((g - 3.864).abs() < 0.01, "{g}");
        // lanes == mono threads: sharding cannot win (exact tie at κ=1).
        assert_eq!(gain(256, 2, 1, 1, false, 2, 2), 1.0);
        // Small deep-blocked domain under 2 mono threads: recompute
        // dominates → below 1 (the planner must keep the monolith).
        assert!(gain(8, 4, 1, 8, true, 4, 2) < 1.0);
        // …but the same request on a large domain shards.
        assert!(gain(256, 4, 1, 8, true, 4, 2) > 1.0);
    }

    #[test]
    fn sharded_intensity_reduces_to_calib_at_one_shard() {
        for shape in [Shape::Box, Shape::Star] {
            for t in [1usize, 2, 4] {
                for steps in [1usize, 4, 9] {
                    for blocked in [true, false] {
                        let w = wl(shape, 2, 1, t, Dtype::F64);
                        let a = predicted_job_intensity(&w, steps, blocked, 64, 1);
                        let b = calib::predicted_job_intensity(&w, steps, blocked);
                        assert!(
                            (a - b).abs() < 1e-12,
                            "{shape:?} t={t} steps={steps} blocked={blocked}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sharding_lowers_the_predicted_intensity() {
        // Halo re-reads raise the denominator: more shards → lower I.
        let w = wl(Shape::Box, 2, 1, 4, Dtype::F64);
        let mono = predicted_job_intensity(&w, 8, true, 64, 1);
        let mut prev = mono;
        for s in [2usize, 4, 8] {
            let i = predicted_job_intensity(&w, 8, true, 64, s);
            assert!(i < prev, "S={s}: {i} !< {prev}");
            prev = i;
        }
        assert_eq!(predicted_job_intensity(&w, 0, true, 64, 4), 0.0);
    }
}

//! Transformation sparsity factor S (paper Eq. 2).
//!
//! S ∈ (0,1] is the non-zero fraction of the MMA operand a transformation
//! scheme constructs; executed MACs inflate by 1/S.  The paper treats S as
//! a per-implementation constant (Table 2: ConvStencil 0.5, SPIDER 0.47).
//! We compute it *from the constructed operands* of our L1 kernels, which
//! mirrors how the manifest reports `sparsity_measured`:
//!
//! * flatten   — B is (Kp × NW): NW shifted embeddings of the fused kernel
//!   in a zero matrix, Kp = lead·(kl+NW−1) rounded up to the MMA k-step.
//! * decompose — per-lead banded matrices ((NT+kl−1) × NT) with K_l-point
//!   diagonals.
//! * 2:4 (SpTC) — same operand as decompose; the paper models SpTC with S
//!   unchanged and ℙ doubled (§4.3), which we follow.

use crate::model::stencil::StencilPattern;

/// Transformation scheme (mirrors python/compile/kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// CUDA-Core direct execution — no operand transform, S = 1.
    Direct,
    /// ConvStencil-style stencil2row + tessellation.
    Flatten,
    /// TCStencil/SPIDER-style banded decomposition.
    Decompose,
    /// SPIDER/SparStencil 2:4 compressed banded decomposition.
    Sparse24,
}

impl Scheme {
    /// Parse a CLI/manifest scheme name.
    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        match s {
            "direct" => Ok(Scheme::Direct),
            "flatten" => Ok(Scheme::Flatten),
            "decompose" => Ok(Scheme::Decompose),
            "sparse24" => Ok(Scheme::Sparse24),
            other => anyhow::bail!("unknown scheme {other:?}"),
        }
    }

    /// The stable scheme name used in manifests and reports.
    pub fn as_str(&self) -> &'static str {
        match self {
            Scheme::Direct => "direct",
            Scheme::Flatten => "flatten",
            Scheme::Decompose => "decompose",
            Scheme::Sparse24 => "sparse24",
        }
    }
}

/// Output columns per GEMM row in the flatten scheme (kernels/flatten.py).
pub const FLATTEN_NW: u64 = 8;
/// GEMM n-tile in the banded schemes (kernels/decompose.py).
pub const BAND_NT: u64 = 16;
/// MMA reduction-granularity padding step.
pub const K_STEP: u64 = 8;

fn round_up(x: u64, m: u64) -> u64 {
    x.div_ceil(m) * m
}

/// S for the flatten scheme: K^(t) non-zeros per column of a Kp-row B.
pub fn flatten_sparsity(pattern: &StencilPattern, t: usize) -> f64 {
    let hull_side = 2 * pattern.r as u64 * t as u64 + 1; // fused hull side
    let lead = hull_side.pow(pattern.d as u32 - 1);
    let span = hull_side + FLATTEN_NW - 1;
    let kp = round_up(lead * span, K_STEP);
    pattern.fused_k_points(t) as f64 / kp as f64
}

/// S for the banded decompose scheme, aggregated over issued bands.
///
/// Issued bands = leading hull offsets with ≥1 fused-support point;
/// non-zeros per band = (row support length)·NT.  Row lengths follow in
/// closed form from the fused-support geometry (box: every row is the
/// full 2rt+1; star: the fused support is {Σ⌈|x_i|/r⌉ ≤ t}, so a row
/// with leading cost C has length 2r(t−C)+1) — no grid iteration, which
/// keeps t-sweeps to 40+ cheap.  Cross-checked against the generic
/// Minkowski support in the tests.
pub fn decompose_sparsity(pattern: &StencilPattern, t: usize) -> f64 {
    let r = pattern.r as u64;
    let rt = r * t as u64;
    let hull_side = 2 * rt + 1;
    let kb = BAND_NT + hull_side - 1; // band rows
    let lead_dims = pattern.d - 1;
    let (mut nnz_rows, mut n_rows) = (0u64, 0u64); // Σ k_l and issued-row count
    match pattern.shape {
        crate::model::stencil::Shape::Box => {
            let rows = hull_side.pow(lead_dims as u32);
            nnz_rows = rows * hull_side;
            n_rows = rows;
        }
        crate::model::stencil::Shape::Star => {
            // ways[c]: per-lead-axis count of offsets with cost c.
            for total_cost in 0..=t {
                // number of (d-1)-tuples with Σ cost = total_cost
                let mut acc = vec![0u64; total_cost + 1];
                acc[0] = 1;
                for _ in 0..lead_dims {
                    let mut next = vec![0u64; total_cost + 1];
                    for s in 0..=total_cost {
                        for c in 0..=s {
                            let ways = if c == 0 { 1 } else { 2 * r };
                            next[s] += acc[s - c] * ways;
                        }
                    }
                    acc = next;
                }
                let rows = acc[total_cost];
                let k_l = 2 * r * (t - total_cost) as u64 + 1;
                nnz_rows += rows * k_l;
                n_rows += rows;
            }
        }
    }
    if n_rows == 0 {
        1.0
    } else {
        (nnz_rows * BAND_NT) as f64 / (n_rows * kb * BAND_NT) as f64
    }
}

/// Grid-based reference implementation of [`decompose_sparsity`] (used by
/// tests to validate the closed form; O(hull²) per call).
pub fn decompose_sparsity_grid(pattern: &StencilPattern, t: usize) -> f64 {
    let hull_side = 2 * pattern.r as u64 * t as u64 + 1;
    let kb = BAND_NT + hull_side - 1;
    let sup = pattern.support().minkowski_power(t);
    let lead = sup.n.pow((pattern.d - 1) as u32);
    let mut nnz = 0u64;
    let mut total = 0u64;
    for li in 0..lead {
        let row = &sup.cells[li * sup.n..(li + 1) * sup.n];
        let k_l = row.iter().filter(|&&b| b).count() as u64;
        if k_l == 0 {
            continue;
        }
        nnz += k_l * BAND_NT;
        total += kb * BAND_NT;
    }
    if total == 0 {
        1.0
    } else {
        nnz as f64 / total as f64
    }
}

/// S per scheme (Direct has no transform: S = 1).
pub fn sparsity(scheme: Scheme, pattern: &StencilPattern, t: usize) -> f64 {
    match scheme {
        Scheme::Direct => 1.0,
        Scheme::Flatten => flatten_sparsity(pattern, t),
        // §4.3: SpTC leaves I (hence S) unchanged; only ℙ doubles.
        Scheme::Decompose | Scheme::Sparse24 => decompose_sparsity(pattern, t),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::stencil::{Shape, StencilPattern};

    fn pat(shape: Shape, d: usize, r: usize) -> StencilPattern {
        StencilPattern::new(shape, d, r).unwrap()
    }

    #[test]
    fn flatten_matches_python_operand() {
        // Box-2D1R t=3: hull 7, lead 7, span 14, Kp = round_up(98,8)=104;
        // S = 49/104 — exactly what kernels/flatten.measured_sparsity gives
        // (python test pins the same value).
        let s = flatten_sparsity(&pat(Shape::Box, 2, 1), 3);
        assert!((s - 49.0 / 104.0).abs() < 1e-12);
    }

    #[test]
    fn flatten_near_paper_convstencil_value() {
        // Paper Table 2 reports S = 0.5 for ConvStencil; our constructed
        // operand (incl. k-padding) gives 0.471 — same phenomenon.
        let s = flatten_sparsity(&pat(Shape::Box, 2, 1), 3);
        assert!((0.44..=0.5).contains(&s), "{s}");
    }

    #[test]
    fn decompose_near_paper_spider_value() {
        // SPIDER Box-2D1R t=7: paper S = 0.47; band analog: 15/30 = 0.5.
        let s = decompose_sparsity(&pat(Shape::Box, 2, 1), 7);
        assert!((s - 0.5).abs() < 1e-12, "{s}");
    }

    #[test]
    fn decompose_small_radius_is_very_sparse() {
        // §2.2.3: r=1 t=1 wastes most of the operand (S ≈ 3/18).
        let s = decompose_sparsity(&pat(Shape::Box, 2, 1), 1);
        assert!((s - 3.0 / 18.0).abs() < 1e-12, "{s}");
    }

    #[test]
    fn sparsity_increases_with_fusion() {
        // §2.2.3: S grows (matrices get denser) as the radius/fusion grows.
        let p = pat(Shape::Box, 2, 1);
        let mut prev = 0.0;
        for t in 1..=7 {
            let s = decompose_sparsity(&p, t);
            assert!(s > prev, "t={t} s={s} prev={prev}");
            prev = s;
        }
    }

    #[test]
    fn direct_has_no_redundancy() {
        assert_eq!(sparsity(Scheme::Direct, &pat(Shape::Box, 2, 1), 5), 1.0);
    }

    #[test]
    fn sparse24_shares_decompose_operand() {
        let p = pat(Shape::Box, 2, 1);
        assert_eq!(
            sparsity(Scheme::Sparse24, &p, 7),
            sparsity(Scheme::Decompose, &p, 7)
        );
    }

    #[test]
    fn closed_form_matches_grid_reference() {
        for shape in [Shape::Box, Shape::Star] {
            for d in 1..=3 {
                for r in 1..=2 {
                    for t in 1..=4 {
                        let p = pat(shape, d, r);
                        let fast = decompose_sparsity(&p, t);
                        let grid = decompose_sparsity_grid(&p, t);
                        assert!(
                            (fast - grid).abs() < 1e-12,
                            "{p} t={t}: {fast} vs {grid}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn star_sparsity_accounts_for_skipped_bands() {
        // Star-3D1R t=1: only 5 of 9 lead offsets are issued.
        let s = decompose_sparsity(&pat(Shape::Star, 3, 1), 1);
        // issued bands: 4 with k_l=1, 1 with k_l=3 → nnz=7·NT, tot=5·18·…
        let kb = BAND_NT + 3 - 1;
        let want = 7.0 * BAND_NT as f64 / (5.0 * kb as f64 * BAND_NT as f64);
        assert!((s - want).abs() < 1e-12);
    }

    #[test]
    fn all_sparsities_in_unit_interval() {
        for shape in [Shape::Box, Shape::Star] {
            for d in 2..=3 {
                for r in 1..=2 {
                    for t in 1..=4 {
                        for sch in [Scheme::Flatten, Scheme::Decompose] {
                            let s = sparsity(sch, &pat(shape, d, r), t);
                            assert!(s > 0.0 && s <= 1.0, "{shape:?} {d} {r} {t} {s}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn scheme_parse_roundtrip() {
        for s in ["direct", "flatten", "decompose", "sparse24"] {
            assert_eq!(Scheme::parse(s).unwrap().as_str(), s);
        }
        assert!(Scheme::parse("conv").is_err());
    }
}

//! Per-unit workload formulation (paper §3.2, Eq. 6–12 and §4.3 Eq. 20).
//!
//! All quantities are *per output point* unless stated otherwise:
//!
//! | unit      | C (FLOPs)        | M (bytes) | I = C/M            |
//! |-----------|------------------|-----------|--------------------|
//! | CUDA Core | t·2K             | 2D        | t·K/D      (Eq. 8) |
//! | TC        | (α/S)·t·2K       | 2D        | t·(α/S)·K/D (Eq.11)|
//! | SpTC      | (α/S)·t·2K       | 2D        | same as TC (Eq.20) |
//!
//! The *actual* (useful) performance on TC/SpTC divides the raw roofline
//! value by the inflation α/S (Eq. 12) — redundant zero-products move data
//! through the MMA units but do not advance the stencil.

use crate::model::redundancy;
use crate::model::roofline::{Bound, Roof};
use crate::model::sparsity;
use crate::model::stencil::StencilPattern;

pub use crate::model::sparsity::Scheme;

/// Element type (the paper evaluates float and double).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// 32-bit IEEE-754 ("float" in the paper's tables).
    F32,
    /// 64-bit IEEE-754 ("double").
    F64,
}

impl Dtype {
    /// D — bytes per element (the denominator of every intensity).
    pub fn bytes(&self) -> u64 {
        match self {
            Dtype::F32 => 4,
            Dtype::F64 => 8,
        }
    }

    /// Parse a CLI/protocol dtype name.
    pub fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" | "float" | "float32" => Ok(Dtype::F32),
            "f64" | "double" | "float64" => Ok(Dtype::F64),
            other => anyhow::bail!("unknown dtype {other:?}"),
        }
    }

    /// The paper's naming ("float" / "double").
    pub fn as_str(&self) -> &'static str {
        match self {
            Dtype::F32 => "float",
            Dtype::F64 => "double",
        }
    }
}

/// Execution unit under analysis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Unit {
    /// The general-purpose SIMT pipeline.
    CudaCore,
    /// Dense MMA units.
    TensorCore,
    /// 2:4 structured-sparsity MMA units.
    SparseTensorCore,
}

impl Unit {
    /// Human-readable unit name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Unit::CudaCore => "CUDA Core",
            Unit::TensorCore => "Tensor Core",
            Unit::SparseTensorCore => "Sparse Tensor Core",
        }
    }
}

/// A stencil workload: pattern × fusion depth × dtype.
///
/// Table 2 row 1 (EBISU, Box-2D1R, t=3, double) as a worked example:
///
/// ```
/// use tc_stencil::model::perf::{Dtype, Workload};
/// use tc_stencil::model::stencil::{Shape, StencilPattern};
/// let w = Workload::new(StencilPattern::new(Shape::Box, 2, 1).unwrap(), 3, Dtype::F64);
/// assert_eq!(w.c_cuda(), 54.0);                       // C = t·2K (Eq. 8)
/// assert_eq!(w.m_bytes(), 16.0);                      // M = 2D (Eq. 6)
/// assert!((w.intensity_cuda() - 3.375).abs() < 1e-12); // paper: 3.38
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Workload {
    /// Stencil pattern (shape, dimensionality, radius).
    pub pattern: StencilPattern,
    /// Temporal fusion depth (t ≥ 1).
    pub t: usize,
    /// Element type.
    pub dtype: Dtype,
}

impl Workload {
    /// Build a workload; panics on `t == 0`.
    pub fn new(pattern: StencilPattern, t: usize, dtype: Dtype) -> Workload {
        assert!(t >= 1);
        Workload { pattern, t, dtype }
    }

    /// K — non-zero points in the unfused kernel actually executed:
    /// the geometric count, 2:4-pruned for `Coeffs::Sparse24` patterns
    /// (the pruned kernel IS the stencil, so its useful work per point
    /// update is 2·K_eff).  Identical to `pattern.k_points()` for every
    /// dense-coefficient pattern.
    pub fn k(&self) -> f64 {
        self.pattern.effective_k_points() as f64
    }

    /// α — fusion redundancy (Eq. 9, exact for any shape), over the
    /// *executed* support: K_eff^(t)/(t·K_eff).  Equals
    /// [`redundancy::alpha`] for dense-coefficient patterns.
    pub fn alpha(&self) -> f64 {
        use crate::model::stencil::Coeffs;
        match self.pattern.coeffs {
            Coeffs::Sparse24 => {
                self.pattern.fused_effective_k_points(self.t) as f64 / (self.t as f64 * self.k())
            }
            _ => redundancy::alpha(&self.pattern, self.t),
        }
    }

    /// S — transformation sparsity for `scheme` (Eq. 2).
    pub fn sparsity(&self, scheme: Scheme) -> f64 {
        sparsity::sparsity(scheme, &self.pattern, self.t)
    }

    /// C per output point on CUDA Cores: t·2K (Eq. 8).
    pub fn c_cuda(&self) -> f64 {
        self.t as f64 * 2.0 * self.k()
    }

    /// C per output point on TC/SpTC with `scheme`: (α/S)·t·2K (Eq. 3/11).
    pub fn c_tensor(&self, scheme: Scheme) -> f64 {
        self.alpha() / self.sparsity(scheme) * self.c_cuda()
    }

    /// M per output point: 2D bytes — one read + one write (§3.2.1), for
    /// every unit (the adaptation does not change compulsory traffic).
    pub fn m_bytes(&self) -> f64 {
        2.0 * self.dtype.bytes() as f64
    }

    /// Arithmetic intensity on CUDA Cores: I = t·K/D (Eq. 8).
    ///
    /// This is the intensity *temporal blocking* realizes: t base steps
    /// per read+write of the domain.  The native backend's blocked path
    /// ([`crate::backend::TemporalMode::Blocked`]) reports its measured
    /// counterpart in `RunMetrics::achieved_intensity`, and
    /// [`crate::model::calib`] closes the loop.
    ///
    /// ```
    /// use tc_stencil::model::perf::{Dtype, Workload};
    /// use tc_stencil::model::stencil::{Shape, StencilPattern};
    /// // Fig. 15: I is linear in t with slope K/D = 9/8 for Box-2D1R f64.
    /// let p = StencilPattern::new(Shape::Box, 2, 1).unwrap();
    /// for t in 1..=8 {
    ///     let w = Workload::new(p, t, Dtype::F64);
    ///     assert!((w.intensity_cuda() - t as f64 * 1.125).abs() < 1e-12);
    /// }
    /// ```
    pub fn intensity_cuda(&self) -> f64 {
        self.c_cuda() / self.m_bytes()
    }

    /// C per output point when the `t` fused steps are realized as ONE
    /// sweep of the monolithic fused kernel on scalar units: α·t·2K —
    /// Eq. 9's redundancy α applied to Eq. 8's useful work.  This is
    /// what the native backend's sweep path actually executes, and what
    /// the planner scores against the blocked variant.
    pub fn c_fused_sweep(&self) -> f64 {
        self.alpha() * self.c_cuda()
    }

    /// Arithmetic intensity of the fused-kernel sweep: I = α·t·K/D.
    ///
    /// Redundant multiply-adds inflate the numerator but the traffic
    /// stays 2D per point, so the *raw* intensity rises by α while only
    /// 1/α of the flops advance the stencil — the planner prefers the
    /// blocked variant exactly when this raw intensity crosses the
    /// machine balance point (the redundant flops stop being free).
    ///
    /// ```
    /// use tc_stencil::model::perf::{Dtype, Workload};
    /// use tc_stencil::model::stencil::{Shape, StencilPattern};
    /// // Box-2D1R t=7 float: α = 225/63, so I = α·7·9/4 = 56.25 F/B.
    /// let w = Workload::new(StencilPattern::new(Shape::Box, 2, 1).unwrap(), 7, Dtype::F32);
    /// assert!((w.intensity_fused_sweep() - w.alpha() * w.intensity_cuda()).abs() < 1e-9);
    /// assert!((w.intensity_fused_sweep() - 56.25).abs() < 1e-9);
    /// ```
    pub fn intensity_fused_sweep(&self) -> f64 {
        self.c_fused_sweep() / self.m_bytes()
    }

    /// Arithmetic intensity on TC/SpTC: I = t·(α/S)·K/D (Eq. 11/20).
    pub fn intensity_tensor(&self, scheme: Scheme) -> f64 {
        self.c_tensor(scheme) / self.m_bytes()
    }

    /// Raw roofline performance on a unit (counting redundant ops too).
    pub fn raw_perf(&self, roof: &Roof, unit: Unit, scheme: Scheme) -> f64 {
        match unit {
            Unit::CudaCore => roof.attainable(self.intensity_cuda()),
            Unit::TensorCore | Unit::SparseTensorCore => {
                roof.attainable(self.intensity_tensor(scheme))
            }
        }
    }

    /// *Actual* (useful-FLOP) performance — Eq. 12 / Eq. 20 third line.
    pub fn actual_perf(&self, roof: &Roof, unit: Unit, scheme: Scheme) -> f64 {
        let raw = self.raw_perf(roof, unit, scheme);
        match unit {
            Unit::CudaCore => raw,
            Unit::TensorCore | Unit::SparseTensorCore => {
                self.sparsity(scheme) / self.alpha() * raw
            }
        }
    }

    /// Bottleneck side for the unit at this workload's intensity.
    pub fn bound(&self, roof: &Roof, unit: Unit, scheme: Scheme) -> Bound {
        match unit {
            Unit::CudaCore => roof.bound(self.intensity_cuda()),
            Unit::TensorCore | Unit::SparseTensorCore => {
                roof.bound(self.intensity_tensor(scheme))
            }
        }
    }

    /// Stencil throughput in point-updates/s ("GStencils/s" when /1e9):
    /// actual FLOP/s divided by the 2K useful FLOPs per point-update.
    pub fn stencil_throughput(&self, roof: &Roof, unit: Unit, scheme: Scheme) -> f64 {
        // actual_perf counts useful FLOPs for the whole fused kernel; each
        // output point advances t steps, so useful FLOPs per point-update
        // are (t·2K)/t = 2K.
        self.actual_perf(roof, unit, scheme) / (2.0 * self.k())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::stencil::{Shape, StencilPattern};

    fn wl(shape: Shape, d: usize, r: usize, t: usize, dt: Dtype) -> Workload {
        Workload::new(StencilPattern::new(shape, d, r).unwrap(), t, dt)
    }

    // ---- Table 2 analytical columns, row by row ----

    #[test]
    fn table2_row1_ebisu_box2d1r_t3_double() {
        let w = wl(Shape::Box, 2, 1, 3, Dtype::F64);
        assert_eq!(w.c_cuda(), 54.0);
        assert_eq!(w.m_bytes(), 16.0);
        assert!((w.intensity_cuda() - 3.375).abs() < 1e-12); // paper: 3.38
    }

    #[test]
    fn table2_row2_ebisu_box2d3r_t1_double() {
        let w = wl(Shape::Box, 2, 3, 1, Dtype::F64);
        assert_eq!(w.c_cuda(), 98.0);
        assert!((w.intensity_cuda() - 6.125).abs() < 1e-12); // paper: 6.12
    }

    #[test]
    fn table2_row3_ebisu_box2d1r_t7_float() {
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
        assert_eq!(w.c_cuda(), 126.0);
        assert_eq!(w.m_bytes(), 8.0);
        assert!((w.intensity_cuda() - 15.75).abs() < 1e-12);
    }

    #[test]
    fn table2_row4_ebisu_box2d7r_t1_float() {
        let w = wl(Shape::Box, 2, 7, 1, Dtype::F32);
        assert_eq!(w.c_cuda(), 450.0);
        assert!((w.intensity_cuda() - 56.25).abs() < 1e-12);
    }

    #[test]
    fn table2_row5_convstencil_box2d1r_t3_double() {
        // Paper: α=1.81, S=0.5 → C=196, I=12.25.  With S=0.5 exactly:
        let w = wl(Shape::Box, 2, 1, 3, Dtype::F64);
        let c = w.alpha() / 0.5 * w.c_cuda();
        assert!((c - 196.0).abs() < 1e-9);
        assert!((c / w.m_bytes() - 12.25).abs() < 1e-9);
    }

    #[test]
    fn table2_row7_convstencil_box2d1r_t7_float() {
        // Paper: α=3.57, S=0.5 → C=900, I=112.5.
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
        let c = w.alpha() / 0.5 * w.c_cuda();
        assert!((c - 900.0).abs() < 1e-9);
    }

    #[test]
    fn table2_row9_spider_box2d1r_t7_float() {
        // Paper: α=3.57, S=0.47 → C=960, I=120.  Our banded operand gives
        // S=0.5 → C=900; with the paper's S the numbers match exactly.
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
        let c_paper_s = w.alpha() / 0.46875 * w.c_cuda();
        assert!((c_paper_s - 960.0).abs() < 1e-9);
        // measured-operand variant stays within 7% of the paper row
        let c_ours = w.c_tensor(Scheme::Decompose);
        assert!((c_ours - 960.0).abs() / 960.0 < 0.07, "{c_ours}");
    }

    // ---- Eq. 12 normalization ----

    #[test]
    fn actual_perf_divides_out_redundancy() {
        let w = wl(Shape::Box, 2, 1, 3, Dtype::F32);
        let roof = Roof::new(156e12, 1.935e12); // A100 TF32 TC
        let raw = w.raw_perf(&roof, Unit::TensorCore, Scheme::Flatten);
        let act = w.actual_perf(&roof, Unit::TensorCore, Scheme::Flatten);
        let infl = w.alpha() / w.sparsity(Scheme::Flatten);
        assert!((raw / act - infl).abs() < 1e-9);
    }

    #[test]
    fn memory_bound_tc_equals_cuda_actual() {
        // Scenario 1 (Eq. 14): both memory-bound → identical actual perf.
        let w = wl(Shape::Box, 2, 1, 1, Dtype::F64);
        let cu = Roof::new(9.7e12, 1.935e12);
        let tc = Roof::new(19.5e12, 1.935e12);
        assert_eq!(w.bound(&cu, Unit::CudaCore, Scheme::Direct), Bound::Memory);
        assert_eq!(w.bound(&tc, Unit::TensorCore, Scheme::Decompose), Bound::Memory);
        let p_cu = w.actual_perf(&cu, Unit::CudaCore, Scheme::Direct);
        let p_tc = w.actual_perf(&tc, Unit::TensorCore, Scheme::Decompose);
        assert!((p_cu / p_tc - 1.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_memory_bound_is_t_b_over_2d() {
        // Memory-bound: updates/s = t·B/(2D) regardless of unit.
        let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
        let tc = Roof::new(312e12, 1.935e12); // SpTC TF32 — ridge 161
        assert_eq!(
            w.bound(&tc, Unit::SparseTensorCore, Scheme::Sparse24),
            Bound::Memory
        );
        let tp = w.stencil_throughput(&tc, Unit::SparseTensorCore, Scheme::Sparse24);
        let want = 7.0 * 1.935e12 / 8.0;
        assert!((tp - want).abs() / want < 1e-12);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("double").unwrap(), Dtype::F64);
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert!(Dtype::parse("bf16").is_err());
    }

    #[test]
    fn intensity_linear_in_t_fig15() {
        // Fig. 15: I vs t is linear with slope K/D on CUDA Cores.
        let k_over_d = 9.0 / 8.0;
        for t in 1..=8 {
            let w = wl(Shape::Box, 2, 1, t, Dtype::F64);
            assert!((w.intensity_cuda() - t as f64 * k_over_d).abs() < 1e-12);
        }
    }
}

//! Paper-artifact generators: one function per table/figure in §5.
//! Shared by `cargo bench` targets and `stencilctl reproduce`.

use crate::engines::{self, Engine};
use crate::hardware::Gpu;
use crate::model::criteria;
use crate::model::perf::{Dtype, Unit, Workload};
use crate::model::roofline::Bound;
use crate::model::scenario::{self, Scenario};
use crate::model::stencil::{Shape, StencilPattern};
use crate::sim::exec;
use crate::sim::profiler;
use crate::util::stats;
use crate::util::table::{delta_pct, fnum, Table};

fn pat(shape: Shape, d: usize, r: usize) -> StencilPattern {
    StencilPattern::new(shape, d, r).unwrap()
}

fn wl(shape: Shape, d: usize, r: usize, t: usize, dt: Dtype) -> Workload {
    Workload::new(pat(shape, d, r), t, dt)
}

/// Table 2 — analytical vs "experimental" (simulated-profiler) C/M/I.
pub fn table2() -> Table {
    let rows: Vec<(Engine, Workload)> = vec![
        (engines::ebisu(), wl(Shape::Box, 2, 1, 3, Dtype::F64)),
        (engines::ebisu(), wl(Shape::Box, 2, 3, 1, Dtype::F64)),
        (engines::ebisu(), wl(Shape::Box, 2, 1, 7, Dtype::F32)),
        (engines::ebisu(), wl(Shape::Box, 2, 7, 1, Dtype::F32)),
        (engines::convstencil(), wl(Shape::Box, 2, 1, 3, Dtype::F64)),
        (engines::convstencil(), wl(Shape::Box, 2, 3, 1, Dtype::F64)),
        (engines::convstencil(), wl(Shape::Box, 2, 1, 7, Dtype::F32)),
        (engines::convstencil(), wl(Shape::Box, 2, 7, 1, Dtype::F32)),
        (engines::spider(), wl(Shape::Box, 2, 1, 7, Dtype::F32)),
        (engines::spider(), wl(Shape::Box, 2, 7, 1, Dtype::F32)),
    ];
    let mut t = Table::new(
        "Table 2 — analytical vs profiled C/M/I per output point",
        &[
            "#", "Baseline", "Pattern", "t", "alpha", "S", "dtype",
            "C", "M", "I", "C_meas (Δ)", "M_meas (Δ)", "I_meas (Δ)",
        ],
    );
    for (i, (e, w)) in rows.iter().enumerate() {
        let p = profiler::profile(e, w);
        t.row(&[
            format!("{}", i + 1),
            e.name.into(),
            p.pattern.clone(),
            format!("{}", w.t),
            p.alpha.map(|a| format!("{a:.2}")).unwrap_or_else(|| "/".into()),
            p.sparsity.map(|s| format!("{s:.2}")).unwrap_or_else(|| "/".into()),
            p.dtype.into(),
            fnum(p.c_analytical),
            fnum(p.m_analytical),
            fnum(p.i_analytical),
            format!("{} ({})", fnum(p.c_measured), delta_pct(p.c_measured, p.c_analytical)),
            format!("{} ({})", fnum(p.m_measured), delta_pct(p.m_measured, p.m_analytical)),
            format!("{} ({})", fnum(p.i_measured), delta_pct(p.i_measured, p.i_analytical)),
        ]);
    }
    t
}

/// Table 3 — the six representative cases: bottlenecks, GStencils/s,
/// scenario classification.
pub fn table3(gpu: &Gpu) -> Table {
    struct Case {
        id: &'static str,
        w: Workload,
        tensor: Engine,
    }
    let cases = vec![
        Case { id: "1", w: wl(Shape::Box, 2, 1, 3, Dtype::F64), tensor: engines::convstencil() },
        Case { id: "2", w: wl(Shape::Box, 2, 3, 1, Dtype::F64), tensor: engines::convstencil() },
        Case { id: "3", w: wl(Shape::Box, 2, 1, 7, Dtype::F32), tensor: engines::spider() },
        Case { id: "4", w: wl(Shape::Box, 2, 7, 1, Dtype::F32), tensor: engines::spider() },
        Case { id: "5", w: wl(Shape::Box, 3, 1, 3, Dtype::F64), tensor: engines::convstencil() },
        Case { id: "6", w: wl(Shape::Box, 3, 1, 7, Dtype::F32), tensor: engines::spider() },
    ];
    let mut t = Table::new(
        "Table 3 — bottleneck transitions across representative cases",
        &[
            "Case", "Pattern", "t", "dtype", "Baseline", "AI", "Ridge",
            "Bottleneck", "GStencils/s", "Change", "Scenario",
        ],
    );
    for c in cases {
        let eb = engines::ebisu();
        let p_cu = exec::predict(&eb, &c.w, gpu).expect("ebisu supports all");
        let p_tc = exec::predict(&c.tensor, &c.w, gpu).expect("tensor engine");
        let cu_roof = gpu.roof(Unit::CudaCore, c.w.dtype).unwrap();
        let tc_roof = gpu.roof(c.tensor.unit, c.w.dtype).unwrap();
        let cmp = scenario::compare(&c.w, &cu_roof, &tc_roof, c.tensor.unit, c.tensor.scheme);
        let ratio = p_tc.gstencils() / p_cu.gstencils();
        let change = if (ratio - 1.0).abs() < 0.1 {
            "≈".to_string()
        } else if ratio > 1.0 {
            format!("↑ {ratio:.2}x")
        } else {
            format!("↓ {:.1}%", (1.0 - ratio) * 100.0)
        };
        t.row(&[
            c.id.into(),
            c.w.pattern.label(),
            format!("{}", c.w.t),
            c.w.dtype.as_str().into(),
            format!("{} / {}", eb.name, c.tensor.name),
            format!("{} / {}", fnum(p_cu.intensity), fnum(p_tc.intensity)),
            format!("{} / {}", fnum(p_cu.ridge), fnum(p_tc.ridge)),
            format!("{} / {}", p_cu.bound.as_str(), p_tc.bound.as_str()),
            format!("{} / {}", fnum(p_cu.gstencils()), fnum(p_tc.gstencils())),
            change,
            cmp.scenario.label(),
        ]);
    }
    t
}

/// Table 4 — SPIDER on dense vs sparse Tensor Cores.
pub fn table4(gpu: &Gpu) -> Table {
    let w = wl(Shape::Box, 2, 1, 7, Dtype::F32);
    let mut t = Table::new(
        "Table 4 — dense vs sparse Tensor Cores (Box-2D1R, t=7, float)",
        &["Baseline", "AI", "Ridge", "Bottleneck", "GStencils/s"],
    );
    for e in [engines::spider_dense(), engines::spider()] {
        let p = exec::predict(&e, &w, gpu).unwrap();
        t.row(&[
            e.name.into(),
            fnum(p.intensity),
            fnum(p.ridge),
            p.bound.as_str().into(),
            fnum(p.gstencils()),
        ]);
    }
    t
}

/// Fig 2 — speedups of TC implementations over DRStencil on the paper's
/// motivating configuration (Box-2D1R float, best fusion per engine).
pub fn fig2(gpu: &Gpu) -> Table {
    let mut tcs = engines::tcstencil();
    tcs.half_only = false; // fp16 runs in the paper's Fig 2
    let list: Vec<Engine> =
        vec![engines::drstencil(), tcs, engines::convstencil(), engines::spider()];
    let mut t = Table::new(
        "Fig 2 — speedup over DRStencil (Box-2D1R float)",
        &["Engine", "Unit", "best t", "GStencils/s", "Speedup"],
    );
    let mut base = None;
    for e in list {
        let (best_t, p) = (1..=e.max_t)
            .filter_map(|tt| {
                let w = wl(Shape::Box, 2, 1, tt, Dtype::F32);
                exec::predict(&e, &w, gpu).ok().map(|p| (tt, p))
            })
            .max_by(|a, b| a.1.throughput.partial_cmp(&b.1.throughput).unwrap())
            .expect("at least t=1");
        let g = p.gstencils();
        if base.is_none() {
            base = Some(g);
        }
        t.row(&[
            e.name.into(),
            e.unit.as_str().into(),
            format!("{best_t}"),
            fnum(g),
            format!("{:.2}x", g / base.unwrap()),
        ]);
    }
    t
}

/// Fig 8/9 — scenario regions: sweep workloads, bucket into scenarios.
pub fn fig8_regions(gpu: &Gpu) -> Table {
    let mut t = Table::new(
        "Fig 8/9 — scenario classification sweep (A100 roofs)",
        &["Pattern", "t", "dtype", "I_CU", "I_TC", "Scenario", "TC/CU ratio", "Verdict"],
    );
    for dt in [Dtype::F64, Dtype::F32] {
        for (shape, d, r) in [(Shape::Box, 2, 1), (Shape::Box, 2, 3), (Shape::Box, 3, 1), (Shape::Star, 2, 1)] {
            for tt in [1usize, 3, 7] {
                let w = wl(shape, d, r, tt, dt);
                let e = if dt == Dtype::F32 { engines::spider() } else { engines::convstencil() };
                let Ok(cu_roof) = gpu.roof(Unit::CudaCore, dt) else { continue };
                let Ok(tc_roof) = gpu.roof(e.unit, dt) else { continue };
                let cmp = scenario::compare(&w, &cu_roof, &tc_roof, e.unit, e.scheme);
                t.row(&[
                    w.pattern.label(),
                    format!("{tt}"),
                    dt.as_str().into(),
                    fnum(cmp.cuda_intensity),
                    fnum(cmp.tensor_intensity),
                    cmp.scenario.label(),
                    format!("{:.3}", cmp.speedup),
                    format!("{:?}", cmp.verdict),
                ]);
            }
        }
    }
    t
}

/// Fig 10 — problem classification: fusion depth at which each stencil
/// config crosses the CUDA ridge (A100 float).
pub fn fig10(gpu: &Gpu) -> Table {
    let mut t = Table::new(
        "Fig 10 — classification vs fusion depth (A100, float)",
        &["Pattern", "K", "I(t=1)", "ridge", "transition t", "class at t=1..8"],
    );
    let roof = gpu.roof(Unit::CudaCore, Dtype::F32).unwrap();
    for (shape, d, r) in [
        (Shape::Star, 2, 1),
        (Shape::Star, 2, 2),
        (Shape::Box, 2, 1),
        (Shape::Box, 2, 2),
        (Shape::Star, 3, 1),
        (Shape::Box, 3, 1),
        (Shape::Box, 3, 2),
    ] {
        let classes: Vec<&str> = (1..=8)
            .map(|tt| match roof.bound(wl(shape, d, r, tt, Dtype::F32).intensity_cuda()) {
                Bound::Memory => "M",
                Bound::Compute => "C",
            })
            .collect();
        let transition = (1..=8)
            .find(|&tt| {
                roof.bound(wl(shape, d, r, tt, Dtype::F32).intensity_cuda()) == Bound::Compute
            })
            .map(|tt| tt.to_string())
            .unwrap_or_else(|| ">8".into());
        let w1 = wl(shape, d, r, 1, Dtype::F32);
        t.row(&[
            w1.pattern.label(),
            format!("{}", w1.pattern.k_points()),
            fnum(w1.intensity_cuda()),
            fnum(roof.ridge()),
            transition,
            classes.join(""),
        ]);
    }
    t
}

/// Fig 11 — EBISU roofline points for 2D r=1, t = 1..8 (float + double).
pub fn fig11(gpu: &Gpu) -> Table {
    let mut t = Table::new(
        "Fig 11 — EBISU roofline (Box-2D1R / Star-2D1R on A100)",
        &["Pattern", "dtype", "t", "I", "bound", "P (TFLOP/s)", "GStencils/s"],
    );
    let e = engines::ebisu();
    for (shape, dt) in [
        (Shape::Box, Dtype::F32),
        (Shape::Box, Dtype::F64),
        (Shape::Star, Dtype::F32),
        (Shape::Star, Dtype::F64),
    ] {
        for tt in 1..=8usize {
            let w = wl(shape, 2, 1, tt, dt);
            let p = exec::predict(&e, &w, gpu).unwrap();
            t.row(&[
                w.pattern.label(),
                dt.as_str().into(),
                format!("{tt}"),
                fnum(p.intensity),
                p.bound.as_str().into(),
                fnum(p.actual_flops / 1e12),
                fnum(p.gstencils()),
            ]);
        }
    }
    t
}

/// Fig 13/14 — SpTC sweet-spot expansion sweep.
pub fn fig13(gpu: &Gpu) -> Table {
    let cu = gpu.roof(Unit::CudaCore, Dtype::F32).unwrap();
    let tc = gpu.roof(Unit::TensorCore, Dtype::F32).unwrap();
    let pts = criteria::region_sweep(
        &pat(Shape::Box, 2, 1),
        Dtype::F32,
        &cu,
        &tc,
        crate::model::sparsity::Scheme::Decompose,
        32,
    );
    let mut t = Table::new(
        "Fig 13/14 — sweet spot: dense TC vs SpTC (Box-2D1R float)",
        &["t", "alpha", "S", "S·P_TC/P_CU", "S·P_SpTC/P_CU", "dense?", "sparse?", "scenario"],
    );
    for p in pts {
        t.row(&[
            format!("{}", p.t),
            format!("{:.3}", p.alpha),
            format!("{:.3}", p.sparsity),
            format!("{:.3}", p.threshold_dense),
            format!("{:.3}", p.threshold_sparse),
            if p.dense_profitable { "yes".into() } else { "no".into() },
            if p.sparse_profitable { "yes".into() } else { "no".into() },
            p.scenario_dense.label(),
        ]);
    }
    t
}

/// Fig 15 — arithmetic intensity vs fusion depth (CUDA, double): linear
/// fit slope must equal K/D.
pub fn fig15() -> (Table, f64, f64) {
    let mut t = Table::new(
        "Fig 15 — I vs t (CUDA Cores, double)",
        &["Pattern", "t", "I analytical", "I profiled"],
    );
    let e = engines::ebisu();
    let mut ts = Vec::new();
    let mut is_meas = Vec::new();
    for tt in 1..=8usize {
        let w = wl(Shape::Box, 2, 1, tt, Dtype::F64);
        let p = profiler::profile(&e, &w);
        ts.push(tt as f64);
        is_meas.push(p.i_measured);
        t.row(&[
            w.pattern.label(),
            format!("{tt}"),
            fnum(p.i_analytical),
            fnum(p.i_measured),
        ]);
    }
    let (_a, slope, r2) = stats::linear_fit(&ts, &is_meas);
    (t, slope, r2)
}

/// Fig 16 — overall comparison: best-fusion GStencils/s per engine per
/// benchmark configuration.
pub fn fig16(gpu: &Gpu) -> Table {
    let mut t = Table::new(
        "Fig 16 — overall performance (best fusion depth per engine)",
        &["Pattern", "dtype", "cuDNN", "DRStencil", "EBISU", "ConvStencil", "SPIDER", "winner"],
    );
    let configs: Vec<(Shape, usize, usize)> = vec![
        (Shape::Box, 2, 1),
        (Shape::Box, 2, 3),
        (Shape::Box, 2, 7),
        (Shape::Star, 2, 1),
        (Shape::Star, 2, 3),
        (Shape::Star, 2, 7),
        (Shape::Box, 3, 1),
        (Shape::Star, 3, 1),
    ];
    for dt in [Dtype::F64, Dtype::F32] {
        for &(shape, d, r) in &configs {
            let mut cells: Vec<String> = vec![pat(shape, d, r).label(), dt.as_str().into()];
            let mut best: (String, f64) = ("-".into(), 0.0);
            for e in [
                engines::cudnn(),
                engines::drstencil(),
                engines::ebisu(),
                engines::convstencil(),
                engines::spider(),
            ] {
                let g = (1..=e.max_t)
                    .filter_map(|tt| exec::predict(&e, &wl(shape, d, r, tt, dt), gpu).ok())
                    .map(|p| p.gstencils())
                    .fold(f64::NAN, f64::max);
                if g.is_nan() {
                    cells.push("-".into());
                } else {
                    if g > best.1 {
                        best = (e.name.to_string(), g);
                    }
                    cells.push(fnum(g));
                }
            }
            cells.push(best.0);
            t.row(&cells);
        }
    }
    t
}

/// Scenario distribution summary used by the fig8 bench assertions.
pub fn scenario_census(gpu: &Gpu) -> [usize; 4] {
    let mut counts = [0usize; 4];
    for dt in [Dtype::F32, Dtype::F64] {
        for (shape, d, r) in [(Shape::Box, 2, 1), (Shape::Box, 2, 3), (Shape::Box, 3, 1), (Shape::Star, 2, 1)] {
            for tt in [1usize, 3, 7] {
                let e = if dt == Dtype::F32 { engines::spider() } else { engines::convstencil() };
                let (Ok(cu), Ok(tc)) = (gpu.roof(Unit::CudaCore, dt), gpu.roof(e.unit, dt)) else {
                    continue;
                };
                let w = wl(shape, d, r, tt, dt);
                let cmp = scenario::compare(&w, &cu, &tc, e.unit, e.scheme);
                let idx = match cmp.scenario {
                    Scenario::MemToMem => 0,
                    Scenario::MemToComp => 1,
                    Scenario::CompToMem => 2,
                    Scenario::CompToComp => 3,
                };
                counts[idx] += 1;
            }
        }
    }
    counts
}

/// The `stats` request's human-readable rendering: service-wide
/// counters (shard fan-outs and plan-cache hit/miss/eviction included)
/// plus one row per live session (`stencilctl serve`).
pub fn service_stats(
    s: &crate::coordinator::metrics::ServiceSnapshot,
    cache: &crate::service::plan_cache::CacheStats,
    sessions: &[crate::coordinator::metrics::SessionRow],
    tenants: &[crate::coordinator::metrics::TenantRow],
) -> String {
    let mut svc = Table::new(
        "service — counters",
        &[
            "requests", "errors", "accepted", "downgraded", "rejected", "queue-full",
            "queued", "completed", "failed", "sharded", "shard tasks", "batches",
            "batched jobs", "plan hits",
            "plan misses", "hit rate", "evicted", "steps", "MSt/s", "model err",
        ],
    );
    svc.row(&[
        s.requests.to_string(),
        s.errors.to_string(),
        s.jobs_accepted.to_string(),
        s.jobs_downgraded.to_string(),
        s.jobs_rejected.to_string(),
        s.queue_rejected.to_string(),
        s.queue_depth.to_string(),
        s.jobs_completed.to_string(),
        s.jobs_failed.to_string(),
        s.jobs_sharded.to_string(),
        s.shard_tasks.to_string(),
        s.batches.to_string(),
        s.jobs_batched.to_string(),
        s.plan_hits.to_string(),
        s.plan_misses.to_string(),
        format!("{:.0}%", s.plan_hit_rate() * 100.0),
        cache.evictions.to_string(),
        s.steps_total.to_string(),
        format!("{:.2}", s.throughput() / 1e6),
        // mean |measured − predicted| intensity over instrumented jobs
        if s.intensity_samples == 0 {
            "-".to_string()
        } else {
            format!("{:.1}%", s.model_error() * 100.0)
        },
    ]);
    let mut prof = Table::new(
        "service — machine profile",
        &[
            "profile", "source", "generation", "stale", "drift flags", "retunes",
            "worst drift", "drift samples", "cache gen",
        ],
    );
    prof.row(&[
        if s.profile.name.is_empty() { "-".to_string() } else { s.profile.name.clone() },
        if s.profile.source.is_empty() { "-".to_string() } else { s.profile.source.clone() },
        s.profile.generation.to_string(),
        if s.profile.stale { "STALE".to_string() } else { "ok".to_string() },
        s.profile.drift_flags.to_string(),
        s.profile.retunes.to_string(),
        if s.profile.drift_samples == 0 {
            "-".to_string()
        } else {
            format!("{:.1}%", s.profile.drift_worst_permille as f64 / 10.0)
        },
        s.profile.drift_samples.to_string(),
        cache.generation.to_string(),
    ]);
    let mut per = Table::new(
        "service — sessions",
        &["session", "pattern", "dtype", "domain", "backend", "kernel", "jobs", "steps", "MSt/s"],
    );
    for r in sessions {
        per.row(&[
            r.name.clone(),
            r.pattern.clone(),
            r.dtype.to_string(),
            r.domain.clone(),
            r.backend.to_string(),
            if r.kernel.is_empty() { "-".to_string() } else { r.kernel.clone() },
            r.stats.jobs.to_string(),
            r.stats.steps.to_string(),
            format!("{:.2}", r.stats.throughput() / 1e6),
        ]);
    }
    let mut ten = Table::new(
        "service — tenants",
        &["tenant", "admitted", "refused", "deadline missed", "resident", "spilled"],
    );
    for r in tenants {
        ten.row(&[
            r.tenant.clone(),
            r.admitted.to_string(),
            r.refused.to_string(),
            r.deadline_missed.to_string(),
            format!("{} B", r.resident_bytes),
            format!("{} B", r.spilled_bytes),
        ]);
    }
    format!("{}\n{}\n{}\n{}", svc.render(), prof.render(), per.render(), ten.render())
}

/// One `stencilctl top` frame, rendered from a parsed `stats` reply
/// and a parsed `alerts` reply: headline counters, the log₂-bucket
/// latency estimates, per-tenant rows, alert states, and the dominant
/// attribution verdict per drift region.  Pure formatting — the
/// refresh loop in `main` owns the transport.
pub fn top_view(
    stats: &crate::util::json::Json,
    alerts: &crate::util::json::Json,
    frame: u64,
) -> String {
    use crate::util::json::Json;
    let gi = |o: &Json, k: &str| o.get(k).and_then(|v| v.as_i64()).unwrap_or(0);
    let gf = |o: &Json, k: &str| o.get(k).and_then(|v| v.as_f64());
    let gs = |o: &Json, k: &str| {
        o.get(k).and_then(|v| v.as_str()).map(str::to_string).unwrap_or_else(|| "-".into())
    };
    let ms = |v: Option<f64>| v.map(|x| format!("{x:.3}")).unwrap_or_else(|| "-".into());
    let mut out = format!(
        "stencilctl top — frame {frame} · profile {} gen {}{}\n",
        gs(stats, "profile_name"),
        gi(stats, "profile_generation"),
        if stats.get("profile_stale").and_then(|v| v.as_bool()).unwrap_or(false) {
            " [STALE]"
        } else {
            ""
        },
    );
    out.push_str(&format!(
        "jobs {} ok / {} failed · queue {} · {:.2} MSt/s · model err {} · alerts firing {}\n",
        gi(stats, "jobs_completed"),
        gi(stats, "jobs_failed"),
        gi(stats, "queue_depth"),
        gf(stats, "mstencils").unwrap_or(0.0),
        gf(stats, "model_error").map(|e| format!("{:.1}%", e * 100.0)).unwrap_or_else(|| "-".into()),
        gi(alerts, "firing"),
    ));
    if let Some(lat) = stats.get("latency") {
        out.push_str(&format!(
            "latency ms — queue wait p50/p95/p99: {}/{}/{} · phase wall: {}/{}/{}\n",
            ms(gf(lat, "queue_wait_p50_ms")),
            ms(gf(lat, "queue_wait_p95_ms")),
            ms(gf(lat, "queue_wait_p99_ms")),
            ms(gf(lat, "phase_wall_p50_ms")),
            ms(gf(lat, "phase_wall_p95_ms")),
            ms(gf(lat, "phase_wall_p99_ms")),
        ));
    }
    let mut ten = Table::new(
        "tenants",
        &["tenant", "admitted", "refused", "deadline missed", "resident", "spilled"],
    );
    if let Some(rows) = stats.get("tenants").and_then(|v| v.as_arr()) {
        for r in rows {
            ten.row(&[
                gs(r, "tenant"),
                gi(r, "admitted").to_string(),
                gi(r, "refused").to_string(),
                gi(r, "deadline_missed").to_string(),
                format!("{} B", gi(r, "resident_bytes")),
                format!("{} B", gi(r, "spilled_bytes")),
            ]);
        }
    }
    out.push_str(&ten.render());
    out.push('\n');
    let mut al = Table::new("alerts", &["rule", "label", "state", "value", "threshold"]);
    if let Some(rows) = alerts.get("alerts").and_then(|v| v.as_arr()) {
        for r in rows {
            al.row(&[
                gs(r, "rule"),
                gs(r, "label"),
                if r.get("firing").and_then(|v| v.as_bool()).unwrap_or(false) {
                    "FIRING".to_string()
                } else {
                    "ok".to_string()
                },
                ms(gf(r, "value")),
                ms(gf(r, "threshold")),
            ]);
        }
    }
    out.push_str(&al.render());
    if let Some(rows) = stats.get("attribution").and_then(|v| v.as_arr()) {
        if !rows.is_empty() {
            out.push('\n');
            let mut at = Table::new("attribution — per drift region", &["region", "jobs", "dominant"]);
            for r in rows {
                at.row(&[gs(r, "region"), gi(r, "jobs").to_string(), gs(r, "dominant")]);
            }
            out.push_str(&at.render());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_view_renders_all_planes_from_parsed_replies() {
        use crate::util::json::Json;
        let stats = Json::parse_line(
            r#"{"profile_name":"tcs","profile_generation":2,"profile_stale":true,
                "jobs_completed":3,"jobs_failed":0,"queue_depth":1,"mstencils":1.5,
                "model_error":0.05,
                "latency":{"queue_wait_p50_ms":0.5,"queue_wait_p99_ms":1.0},
                "tenants":[{"tenant":"acme","admitted":2,"refused":1,"deadline_missed":1,
                            "resident_bytes":4096,"spilled_bytes":0}],
                "attribution":[{"region":"mem/sweep","jobs":3,"dominant":"bandwidth"}]}"#,
        )
        .unwrap();
        let alerts = Json::parse_line(
            r#"{"firing":1,"alerts":[
                {"rule":"slo_burn","label":"acme","firing":true,"value":0.5,"threshold":0.1},
                {"rule":"queue_saturated","label":"queue","firing":false,"value":0.1,"threshold":0.8}]}"#,
        )
        .unwrap();
        let v = top_view(&stats, &alerts, 7);
        assert!(v.contains("frame 7"), "{v}");
        assert!(v.contains("[STALE]"), "{v}");
        assert!(v.contains("alerts firing 1"), "{v}");
        assert!(v.contains("0.500/-/1.000"), "queue-wait quantiles: {v}");
        assert!(v.contains("acme"), "{v}");
        assert!(v.contains("FIRING"), "{v}");
        assert!(v.contains("mem/sweep") && v.contains("bandwidth"), "{v}");
    }

    #[test]
    fn table2_has_paper_rows() {
        let t = table2();
        assert_eq!(t.rows.len(), 10);
        assert!(t.render().contains("EBISU"));
        assert!(t.render().contains("SPIDER"));
    }

    #[test]
    fn table3_reproduces_directions() {
        let t = table3(&Gpu::a100());
        let s = t.render();
        assert_eq!(t.rows.len(), 6);
        // Case 1 degrades, cases 3/4 win, cases 5/6 degrade.
        assert!(t.rows[0][9].starts_with('↓'), "case1: {}", t.rows[0][9]);
        assert!(t.rows[2][9].starts_with('↑'), "case3: {}", t.rows[2][9]);
        assert!(t.rows[3][9].starts_with('↑'), "case4: {}", t.rows[3][9]);
        assert!(t.rows[4][9].starts_with('↓'), "case5: {}", t.rows[4][9]);
        assert!(t.rows[5][9].starts_with('↓'), "case6: {}", t.rows[5][9]);
        assert!(s.contains("Scenario"));
    }

    #[test]
    fn table4_sparse_wins() {
        let t = table4(&Gpu::a100());
        assert_eq!(t.rows.len(), 2);
        let dense: f64 = t.rows[0][4].parse().unwrap();
        let sparse: f64 = t.rows[1][4].parse().unwrap();
        assert!(sparse / dense > 2.0, "sparse {sparse} vs dense {dense}");
    }

    #[test]
    fn fig2_speedups_ordered_like_paper() {
        // Paper Fig 2: TCStencil 1.48×, ConvStencil 2.23×, SPIDER 4.60×
        // over DRStencil — our shape: strictly increasing, SPIDER largest.
        let t = fig2(&Gpu::a100());
        let get = |i: usize| -> f64 {
            t.rows[i][4].trim_end_matches('x').parse().unwrap()
        };
        assert_eq!(get(0), 1.0);
        assert!(get(3) > get(2), "SPIDER must beat ConvStencil");
        assert!(get(2) > 1.0, "ConvStencil must beat DRStencil");
        assert!(get(3) > 2.0, "SPIDER speedup should be large");
    }

    #[test]
    fn fig10_3d_box_compute_bound_immediately() {
        let t = fig10(&Gpu::a100());
        let row = t.rows.iter().find(|r| r[0] == "Box-3D2R").unwrap();
        assert_eq!(row[4], "1"); // compute-bound even without fusion
        // star 2D r1 needs the deepest fusion of the set
        let star = t.rows.iter().find(|r| r[0] == "Star-2D1R").unwrap();
        let star_t: usize = star[4].parse().unwrap_or(99);
        assert!(star_t >= 8, "star transitions latest: {}", star[4]);
    }

    #[test]
    fn fig11_transition_visible() {
        let t = fig11(&Gpu::a100());
        // Box f32 rows: memory at t=1, compute by t=8.
        let rows: Vec<_> = t
            .rows
            .iter()
            .filter(|r| r[0] == "Box-2D1R" && r[1] == "float")
            .collect();
        assert_eq!(rows.len(), 8);
        assert_eq!(rows[0][4], "Memory");
        assert_eq!(rows[7][4], "Compute");
    }

    #[test]
    fn fig13_sptc_superset() {
        let t = fig13(&Gpu::a100());
        for row in &t.rows {
            if row[5] == "yes" {
                assert_eq!(row[6], "yes", "dense profitable must imply sparse at t={}", row[0]);
            }
        }
        // and expansion exists
        assert!(t.rows.iter().any(|r| r[5] == "no" && r[6] == "yes"));
    }

    #[test]
    fn fig15_slope_is_k_over_d() {
        let (_t, slope, r2) = fig15();
        // K/D = 9/8 = 1.125; profiled slope within a few % (halo noise).
        assert!((slope - 1.125).abs() / 1.125 < 0.1, "slope={slope}");
        assert!(r2 > 0.99, "r2={r2}");
    }

    #[test]
    fn fig16_sota_picks_match_paper() {
        let t = fig16(&Gpu::a100());
        // float rows: SPIDER should win most; double rows: EBISU or
        // ConvStencil split by pattern.
        let float_winners: Vec<&str> = t
            .rows
            .iter()
            .filter(|r| r[1] == "float")
            .map(|r| r[7].as_str())
            .collect();
        assert!(
            float_winners.iter().filter(|w| **w == "SPIDER").count() >= float_winners.len() / 2,
            "{float_winners:?}"
        );
    }

    #[test]
    fn census_covers_multiple_scenarios() {
        let c = scenario_census(&Gpu::a100());
        assert!(c.iter().filter(|&&n| n > 0).count() >= 3, "{c:?}");
    }

    #[test]
    fn service_stats_renders_counters_and_sessions() {
        use crate::coordinator::metrics::{ServiceSnapshot, SessionRow, SessionStats, TenantRow};
        let snap = ServiceSnapshot {
            requests: 10,
            jobs_accepted: 4,
            jobs_completed: 4,
            plan_hits: 3,
            plan_misses: 1,
            steps_total: 16,
            point_steps_total: 1600,
            exec_wall_ns: 1_000_000_000,
            ..Default::default()
        };
        let rows = vec![SessionRow {
            name: "a".into(),
            pattern: "Star-2D1R".into(),
            dtype: "double",
            domain: "32x32".into(),
            backend: "native",
            kernel: "star-2d1r/double/portable".into(),
            stats: SessionStats {
                jobs: 4,
                steps: 16,
                point_steps: 1600,
                exec_wall_ns: 1_000_000_000,
            },
        }];
        let cache = crate::service::plan_cache::CacheStats {
            hits: 3,
            misses: 1,
            evictions: 2,
            len: 1,
            generation: 4,
            ..Default::default()
        };
        let tenants = vec![TenantRow {
            tenant: "acme".into(),
            admitted: 3,
            refused: 1,
            deadline_missed: 1,
            resident_bytes: 8192,
            spilled_bytes: 2048,
        }];
        let out = service_stats(&snap, &cache, &rows, &tenants);
        assert!(out.contains("service — counters"));
        assert!(out.contains("service — machine profile"));
        assert!(out.contains("service — sessions"));
        assert!(out.contains("service — tenants"));
        assert!(out.contains("Star-2D1R"));
        assert!(out.contains("star-2d1r/double/portable"), "kernel column renders: {out}");
        assert!(out.contains("75%"), "hit rate renders: {out}");
        assert!(out.contains("evicted"), "cache evictions render: {out}");
        assert!(out.contains("acme"), "tenant row renders: {out}");
        assert!(out.contains("2048 B"), "spilled bytes render: {out}");
        // empty session/tenant lists still render all tables
        let out = service_stats(&snap, &cache, &[], &[]);
        assert!(out.contains("service — sessions"));
        assert!(out.contains("service — tenants"));
    }

    #[test]
    fn service_stats_render_profile_and_drift_state() {
        use crate::coordinator::metrics::ServiceSnapshot;
        let snap = ServiceSnapshot {
            profile: crate::tune::drift::ProfileStatus {
                name: "measured-native".into(),
                source: "measured".into(),
                generation: 3,
                stale: true,
                drift_flags: 2,
                retunes: 1,
                drift_worst_permille: 312,
                drift_samples: 7,
            },
            ..Default::default()
        };
        let cache =
            crate::service::plan_cache::CacheStats { generation: 3, ..Default::default() };
        let out = service_stats(&snap, &cache, &[], &[]);
        assert!(out.contains("measured-native"), "{out}");
        assert!(out.contains("STALE"), "{out}");
        assert!(out.contains("31.2%"), "worst drift renders: {out}");
        // a fresh default snapshot renders placeholders, not panics
        let out = service_stats(&ServiceSnapshot::default(), &Default::default(), &[], &[]);
        assert!(out.contains("machine profile"));
    }
}

//! GPU hardware spec registry — the ℙ/𝔹 numbers the model runs against.
//!
//! The paper's testbed is an NVIDIA A100-80GB PCIe; we also carry V100,
//! H100 and RTX 4090 so the criteria can be explored across generations
//! (the analysis is hardware-parametric by construction).  Peaks follow
//! vendor datasheets; f32 stencil data on Tensor Cores uses the TF32 path
//! (what ConvStencil/SPIDER execute), f64 uses the FP64 TC path.
//!
//! `clock_lock` models the §4.2 observation that profiling runs lock the
//! GPU clock below boost, lowering the effective compute ceiling and
//! shifting empirical ridge points left of the datasheet prediction.

use anyhow::{anyhow, Result};

use crate::model::perf::{Dtype, Unit};
use crate::model::roofline::Roof;

/// Peak FLOP/s per execution unit and dtype (None = unit not present).
#[derive(Debug, Clone, Copy, Default)]
pub struct PeakTable {
    pub cuda_f32: Option<f64>,
    pub cuda_f64: Option<f64>,
    pub tc_f32: Option<f64>,  // TF32 MMA path
    pub tc_f64: Option<f64>,  // FP64 MMA path
    pub sptc_f32: Option<f64>,
    pub sptc_f64: Option<f64>,
}

/// A GPU model: bandwidth + per-unit peaks + clock-lock derating.
///
/// The name is a `String` (not `&'static str`) because machine profiles
/// (`tune::profile::MachineProfile`) reconstruct `Gpu`s with *measured*
/// identities ("measured-native") that never appear in this registry.
#[derive(Debug, Clone)]
pub struct Gpu {
    pub name: String,
    /// HBM bandwidth in bytes/s.
    pub bandwidth: f64,
    pub peaks: PeakTable,
    /// Multiplier (≤ 1.0) applied to compute peaks when the clock is
    /// locked for profiling stability (§4.2). 1.0 = boost clocks.
    pub clock_lock: f64,
}

impl Gpu {
    /// The paper's testbed: A100-80GB PCIe (GA100).
    pub fn a100() -> Gpu {
        Gpu {
            name: "A100-80GB-PCIe".to_string(),
            bandwidth: 1.935e12,
            peaks: PeakTable {
                cuda_f32: Some(19.5e12),
                cuda_f64: Some(9.7e12),
                tc_f32: Some(156e12), // TF32
                tc_f64: Some(19.5e12),
                sptc_f32: Some(312e12),
                sptc_f64: None, // FP64 MMA has no 2:4 sparse path
            },
            clock_lock: 1.0,
        }
    }

    pub fn v100() -> Gpu {
        Gpu {
            name: "V100-SXM2".to_string(),
            bandwidth: 0.9e12,
            peaks: PeakTable {
                cuda_f32: Some(15.7e12),
                cuda_f64: Some(7.8e12),
                tc_f32: None, // no TF32 on Volta
                tc_f64: None,
                sptc_f32: None,
                sptc_f64: None,
            },
            clock_lock: 1.0,
        }
    }

    pub fn h100() -> Gpu {
        Gpu {
            name: "H100-SXM5".to_string(),
            bandwidth: 3.35e12,
            peaks: PeakTable {
                cuda_f32: Some(66.9e12),
                cuda_f64: Some(33.5e12),
                tc_f32: Some(494.7e12),
                tc_f64: Some(66.9e12),
                sptc_f32: Some(989.4e12),
                sptc_f64: None,
            },
            clock_lock: 1.0,
        }
    }

    pub fn rtx4090() -> Gpu {
        Gpu {
            name: "RTX-4090".to_string(),
            bandwidth: 1.008e12,
            peaks: PeakTable {
                cuda_f32: Some(82.6e12),
                cuda_f64: Some(1.29e12),
                tc_f32: Some(82.6e12),
                tc_f64: None,
                sptc_f32: Some(165.2e12),
                sptc_f64: None,
            },
            clock_lock: 1.0,
        }
    }

    /// AMD MI300X — the paper (§2.1.1) notes Matrix Cores implement the
    /// same tensor contraction; the criteria apply verbatim.  CDNA3 has
    /// no 2:4 structured-sparse path for the XF32 pipe.
    pub fn mi300x() -> Gpu {
        Gpu {
            name: "MI300X".to_string(),
            bandwidth: 5.3e12,
            peaks: PeakTable {
                cuda_f32: Some(163.4e12), // vector FP32
                cuda_f64: Some(81.7e12),
                tc_f32: Some(653.7e12), // matrix XF32
                tc_f64: Some(163.4e12), // matrix FP64
                sptc_f32: None,
                sptc_f64: None,
            },
            clock_lock: 1.0,
        }
    }

    /// Lookup by (case-insensitive) name.
    pub fn lookup(name: &str) -> Result<Gpu> {
        match name.to_ascii_lowercase().as_str() {
            "a100" | "a100-80gb-pcie" => Ok(Gpu::a100()),
            "v100" | "v100-sxm2" => Ok(Gpu::v100()),
            "h100" | "h100-sxm5" => Ok(Gpu::h100()),
            "rtx4090" | "4090" => Ok(Gpu::rtx4090()),
            "mi300x" | "mi300" => Ok(Gpu::mi300x()),
            other => Err(anyhow!(
                "unknown GPU {other:?} (available: a100, v100, h100, rtx4090, mi300x)"
            )),
        }
    }

    pub fn all() -> Vec<Gpu> {
        vec![Gpu::a100(), Gpu::v100(), Gpu::h100(), Gpu::rtx4090(), Gpu::mi300x()]
    }

    /// Derated copy with the profiling clock lock applied.
    pub fn locked(&self, factor: f64) -> Gpu {
        assert!(factor > 0.0 && factor <= 1.0);
        let mut g = self.clone();
        g.clock_lock = factor;
        g
    }

    fn peak(&self, unit: Unit, dtype: Dtype) -> Option<f64> {
        let p = match (unit, dtype) {
            (Unit::CudaCore, Dtype::F32) => self.peaks.cuda_f32,
            (Unit::CudaCore, Dtype::F64) => self.peaks.cuda_f64,
            (Unit::TensorCore, Dtype::F32) => self.peaks.tc_f32,
            (Unit::TensorCore, Dtype::F64) => self.peaks.tc_f64,
            (Unit::SparseTensorCore, Dtype::F32) => self.peaks.sptc_f32,
            (Unit::SparseTensorCore, Dtype::F64) => self.peaks.sptc_f64,
        };
        p.map(|v| v * self.clock_lock)
    }

    /// The roofline for a unit × dtype. Errors when the unit is absent.
    pub fn roof(&self, unit: Unit, dtype: Dtype) -> Result<Roof> {
        let p = self.peak(unit, dtype).ok_or_else(|| {
            anyhow!(
                "{}: no {} path for {}",
                self.name,
                unit.as_str(),
                dtype.as_str()
            )
        })?;
        Ok(Roof::new(p, self.bandwidth))
    }

    /// Whether this GPU has a 2:4 sparse MMA path for the dtype.
    pub fn has_sptc(&self, dtype: Dtype) -> bool {
        self.peak(Unit::SparseTensorCore, dtype).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_ridge_points_match_table3() {
        let g = Gpu::a100();
        // Table 3 ridge column: CU double 5, TC double 10, CU float 10,
        // SpTC TF32 161 (and Table 4: dense TC TF32 81).
        let r = |u, d| g.roof(u, d).unwrap().ridge();
        assert!((r(Unit::CudaCore, Dtype::F64) - 5.01).abs() < 0.05);
        assert!((r(Unit::TensorCore, Dtype::F64) - 10.08).abs() < 0.1);
        assert!((r(Unit::CudaCore, Dtype::F32) - 10.08).abs() < 0.1);
        assert!((r(Unit::SparseTensorCore, Dtype::F32) - 161.2).abs() < 1.0);
        assert!((r(Unit::TensorCore, Dtype::F32) - 80.6).abs() < 0.5);
    }

    #[test]
    fn sptc_is_double_tc_on_a100() {
        let g = Gpu::a100();
        let tc = g.roof(Unit::TensorCore, Dtype::F32).unwrap();
        let sp = g.roof(Unit::SparseTensorCore, Dtype::F32).unwrap();
        assert!((sp.peak_flops / tc.peak_flops - 2.0).abs() < 1e-12);
    }

    #[test]
    fn missing_units_error() {
        assert!(Gpu::v100().roof(Unit::TensorCore, Dtype::F32).is_err());
        assert!(Gpu::a100().roof(Unit::SparseTensorCore, Dtype::F64).is_err());
        assert!(!Gpu::a100().has_sptc(Dtype::F64));
        assert!(Gpu::a100().has_sptc(Dtype::F32));
    }

    #[test]
    fn clock_lock_derates_compute_not_bandwidth() {
        let g = Gpu::a100().locked(0.87);
        let r = g.roof(Unit::CudaCore, Dtype::F32).unwrap();
        assert!((r.peak_flops - 0.87 * 19.5e12).abs() < 1e6);
        assert_eq!(r.bandwidth, 1.935e12);
        // §4.2: locking shifts the ridge LEFT → earlier compute-bound.
        assert!(r.ridge() < Gpu::a100().roof(Unit::CudaCore, Dtype::F32).unwrap().ridge());
    }

    #[test]
    fn lookup_known_and_unknown() {
        assert_eq!(Gpu::lookup("A100").unwrap().name, "A100-80GB-PCIe");
        assert_eq!(Gpu::lookup("h100").unwrap().name, "H100-SXM5");
        assert_eq!(Gpu::lookup("mi300").unwrap().name, "MI300X");
        assert!(Gpu::lookup("tpu-v5").is_err());
    }

    #[test]
    fn matrix_cores_follow_the_same_criteria() {
        // §2.1.1: AMD Matrix Cores implement the same contraction — the
        // Eq. 19 threshold computes the same way.  MI300X f64 ratio
        // P_MC/P_VALU = 2 exactly, like A100's TC/CUDA f64 ratio.
        let g = Gpu::mi300x();
        let cu = g.roof(Unit::CudaCore, Dtype::F64).unwrap();
        let tc = g.roof(Unit::TensorCore, Dtype::F64).unwrap();
        assert!((tc.peak_flops / cu.peak_flops - 2.0).abs() < 1e-9);
        assert!(!g.has_sptc(Dtype::F32)); // no 2:4 path on CDNA3
    }

    #[test]
    fn all_registry_entries_have_cuda_paths() {
        for g in Gpu::all() {
            assert!(g.roof(Unit::CudaCore, Dtype::F32).is_ok(), "{}", g.name);
            assert!(g.roof(Unit::CudaCore, Dtype::F64).is_ok(), "{}", g.name);
        }
    }

    #[test]
    #[should_panic]
    fn locked_rejects_bad_factor() {
        Gpu::a100().locked(1.5);
    }
}

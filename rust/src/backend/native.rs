//! The native CPU stencil engine: a tiled, halo-split, double-buffered,
//! multi-threaded executor for ANY `(pattern, dtype, t)` combination.
//!
//! Layout per time step (one "launch"):
//!
//! * the fused kernel (t-fold self-convolution, identical arithmetic to
//!   the golden oracle's [`golden::Weights::fuse`]) is compiled once into
//!   a flat-offset form bound to the domain's row-major strides;
//! * output rows are split across worker threads (disjoint `chunks_mut`
//!   slabs, no locks);
//! * each row is halo-split: the interior column window `[r·t, N−r·t)`
//!   of an interior row takes the fast path — per offset, one contiguous
//!   `zip` accumulation over the row segment, no per-element bounds
//!   checks — while boundary rows/columns take the scalar slow path with
//!   the zero-Dirichlet halo;
//! * fields are double-buffered and swapped between launches.
//!
//! Accumulation order per output point is exactly the oracle's (hull
//! row-major, zero weights skipped, out-of-domain reads contribute
//! `w·0`), so f64 results are bit-identical to `golden::apply_fused` /
//! `apply_once` chains; f32 jobs run genuinely in f32 (mirroring the
//! AOT artifacts' precision) and match the oracle to rounding.

use std::time::Instant;

use anyhow::Result;

use crate::backend::{Backend, Job};
use crate::coordinator::metrics::RunMetrics;
use crate::model::perf::Dtype;
use crate::sim::golden;

/// Element type the engine is instantiated at (f32 mirrors artifact
/// precision, f64 mirrors the oracle).
trait Scalar: Copy + Send + Sync + 'static {
    const ZERO: Self;
    fn from_f64(v: f64) -> Self;
    fn mul_acc(acc: Self, w: Self, v: Self) -> Self;
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    fn from_f64(v: f64) -> Self {
        v
    }
    fn mul_acc(acc: Self, w: Self, v: Self) -> Self {
        acc + w * v
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn mul_acc(acc: Self, w: Self, v: Self) -> Self {
        acc + w * v
    }
}

/// A stencil kernel compiled against one domain shape.
struct Kernel<T> {
    /// Hull radius (r·t after fusion).
    r: usize,
    /// Non-zero hull offsets in oracle order (multi-dim form, slow path).
    offsets: Vec<(Vec<i64>, T)>,
    /// The same offsets as flat row-major deltas (interior fast path).
    deltas: Vec<(isize, T)>,
}

fn compile<T: Scalar>(w: &golden::Weights, dims: &[usize]) -> Kernel<T> {
    let st = golden::strides_for(dims);
    let offsets: Vec<(Vec<i64>, T)> = w
        .offsets()
        .into_iter()
        .map(|(off, v)| (off, T::from_f64(v)))
        .collect();
    let deltas = offsets
        .iter()
        .map(|(off, v)| {
            let d: isize = off
                .iter()
                .zip(&st)
                .map(|(&o, &s)| o as isize * s as isize)
                .sum();
            (d, *v)
        })
        .collect();
    Kernel { r: w.r(), offsets, deltas }
}

/// One output point via the scalar slow path (zero-Dirichlet halo),
/// accumulating in exactly the oracle's order.
fn point<T: Scalar>(
    k: &Kernel<T>,
    dims: &[usize],
    st: &[usize],
    src: &[T],
    outer: &[usize],
    col: usize,
    coords: &mut [i64],
) -> T {
    let d = dims.len();
    for (c, &o) in coords.iter_mut().zip(outer) {
        *c = o as i64;
    }
    coords[d - 1] = col as i64;
    let mut acc = T::ZERO;
    for (off, w) in &k.offsets {
        let mut flat = 0isize;
        let mut ok = true;
        for kk in 0..d {
            let c = coords[kk] + off[kk];
            if c < 0 || c >= dims[kk] as i64 {
                ok = false;
                break;
            }
            flat += c as isize * st[kk] as isize;
        }
        let v = if ok { src[flat as usize] } else { T::ZERO };
        acc = T::mul_acc(acc, *w, v);
    }
    acc
}

/// Compute rows `[row0, row0 + dst.len()/n_last)` of one step into `dst`.
fn step_rows<T: Scalar>(dims: &[usize], k: &Kernel<T>, src: &[T], dst: &mut [T], row0: usize) {
    let d = dims.len();
    let n_last = dims[d - 1];
    let r = k.r;
    let nrows = dst.len() / n_last;
    let st = golden::strides_for(dims);
    // Interior column window shared by every interior row.
    let (clo, chi) = if n_last > 2 * r { (r, n_last - r) } else { (0, 0) };
    let mut outer = vec![0usize; d - 1];
    let mut coords = vec![0i64; d];
    for lr in 0..nrows {
        let rr = row0 + lr;
        let mut rem = rr;
        for kk in (0..d - 1).rev() {
            outer[kk] = rem % dims[kk];
            rem /= dims[kk];
        }
        let row_interior = outer.iter().zip(dims).all(|(&c, &n)| c >= r && c + r < n);
        let row_base = rr * n_last;
        let drow = &mut dst[lr * n_last..(lr + 1) * n_last];
        if row_interior && chi > clo {
            // Fast path: the whole interior window, offset-major, one
            // contiguous source segment per offset.  Bounds are
            // guaranteed by the interior condition, so the only checks
            // left are one slice construction per offset per row.
            let out = &mut drow[clo..chi];
            out.fill(T::ZERO);
            for &(delta, w) in &k.deltas {
                let start = ((row_base + clo) as isize + delta) as usize;
                let seg = &src[start..start + (chi - clo)];
                for (o, &v) in out.iter_mut().zip(seg) {
                    *o = T::mul_acc(*o, w, v);
                }
            }
            for c in (0..clo).chain(chi..n_last) {
                drow[c] = point(k, dims, &st, src, &outer, c, &mut coords);
            }
        } else {
            for c in 0..n_last {
                drow[c] = point(k, dims, &st, src, &outer, c, &mut coords);
            }
        }
    }
}

/// One full step `dst = K(src)`, rows split across `threads` workers.
fn step<T: Scalar>(dims: &[usize], k: &Kernel<T>, src: &[T], dst: &mut [T], threads: usize) {
    let n_last = dims[dims.len() - 1];
    let rows = src.len() / n_last;
    let workers = threads.max(1).min(rows);
    if workers <= 1 {
        step_rows(dims, k, src, dst, 0);
        return;
    }
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|s| {
        for (ci, chunk) in dst.chunks_mut(chunk_rows * n_last).enumerate() {
            s.spawn(move || step_rows(dims, k, src, chunk, ci * chunk_rows));
        }
    });
}

fn run_typed<T: Scalar>(
    dims: &[usize],
    fused: &golden::Weights,
    base: &golden::Weights,
    launches: usize,
    rem: usize,
    threads: usize,
    buf: &mut Vec<T>,
    metrics: &mut RunMetrics,
) {
    let mut next = vec![T::ZERO; buf.len()];
    if launches > 0 {
        let fk = compile::<T>(fused, dims);
        for _ in 0..launches {
            let t0 = Instant::now();
            step(dims, &fk, buf, &mut next, threads);
            metrics.add_execute(t0.elapsed());
            std::mem::swap(buf, &mut next);
        }
    }
    if rem > 0 {
        let bk = compile::<T>(base, dims);
        for _ in 0..rem {
            let t0 = Instant::now();
            step(dims, &bk, buf, &mut next, threads);
            metrics.add_execute(t0.elapsed());
            std::mem::swap(buf, &mut next);
        }
    }
}

/// The native CPU backend (stateless; all state lives in the job).
#[derive(Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, job: &Job) -> Result<(), String> {
        // Any pattern/dtype/fusion depth runs here; only structural
        // inconsistencies are rejected.
        job.validate(job.points() as usize).map_err(|e| format!("{e:#}"))
    }

    fn advance(&mut self, job: &Job, field: &mut Vec<f64>) -> Result<RunMetrics> {
        job.validate(field.len())?;
        let launches = job.steps / job.t;
        let rem = job.steps % job.t;
        let base =
            golden::Weights::new(job.pattern.d, 2 * job.pattern.r + 1, job.weights.clone());
        // Fusing is itself a t-fold convolution — skip it when no fused
        // launch will run (steps < t jobs are pure remainder).
        let fused = if launches > 0 && job.t > 1 { base.fuse(job.t) } else { base.clone() };
        let mut metrics = RunMetrics {
            steps: job.steps,
            points: job.points(),
            launches: (launches + rem) as u64,
            ..Default::default()
        };
        let wall0 = Instant::now();
        match job.dtype {
            Dtype::F64 => run_typed::<f64>(
                &job.domain,
                &fused,
                &base,
                launches,
                rem,
                job.threads,
                field,
                &mut metrics,
            ),
            Dtype::F32 => {
                // Marshal through f32 buffers so the arithmetic runs at
                // artifact precision; conversion cost is accounted like
                // the PJRT backend's gather/scatter phases.
                let t0 = Instant::now();
                let mut buf: Vec<f32> = field.iter().map(|&v| v as f32).collect();
                metrics.add_gather(t0.elapsed());
                run_typed::<f32>(
                    &job.domain,
                    &fused,
                    &base,
                    launches,
                    rem,
                    job.threads,
                    &mut buf,
                    &mut metrics,
                );
                let t1 = Instant::now();
                for (o, &v) in field.iter_mut().zip(&buf) {
                    *o = v as f64;
                }
                metrics.add_scatter(t1.elapsed());
            }
        }
        metrics.wall_ns = wall0.elapsed().as_nanos() as u64;
        Ok(metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::stencil::{Shape, StencilPattern};
    use crate::util::rng::Rng;

    fn box_weights(d: usize, r: usize) -> Vec<f64> {
        let side = 2 * r + 1;
        let n = side.pow(d as u32);
        vec![1.0 / n as f64; n]
    }

    fn job(d: usize, r: usize, domain: Vec<usize>, steps: usize, t: usize) -> Job {
        Job {
            pattern: StencilPattern::new(Shape::Box, d, r).unwrap(),
            dtype: Dtype::F64,
            domain,
            steps,
            t,
            weights: box_weights(d, r),
            threads: 1,
        }
    }

    fn rand_field(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn golden_mirror(job: &Job, init: &[f64]) -> golden::Field {
        let w = golden::Weights::new(job.pattern.d, 2 * job.pattern.r + 1, job.weights.clone());
        let mut cur = golden::Field::from_vec(&job.domain, init.to_vec());
        for _ in 0..job.steps / job.t {
            cur = golden::apply_fused(&cur, &w, job.t);
        }
        for _ in 0..job.steps % job.t {
            cur = golden::apply_once(&cur, &w);
        }
        cur
    }

    #[test]
    fn f64_single_step_bit_identical_to_oracle() {
        let j = job(2, 1, vec![17, 13], 1, 1);
        let init = rand_field(1, 17 * 13);
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn f64_fused_launches_bit_identical_to_oracle() {
        let mut j = job(2, 1, vec![20, 21], 6, 3);
        j.threads = 3;
        let init = rand_field(2, 20 * 21);
        let mut field = init.clone();
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        assert_eq!(m.launches, 2);
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn remainder_steps_use_base_kernel() {
        // steps=5, t=2 → two fused launches + one single step.
        let j = job(2, 1, vec![12, 12], 5, 2);
        let init = rand_field(3, 144);
        let mut field = init.clone();
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        assert_eq!(m.launches, 3);
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn works_in_1d_and_3d() {
        for (d, domain) in [(1usize, vec![40usize]), (3, vec![9, 8, 10])] {
            let j = job(d, 1, domain.clone(), 2, 2);
            let n: usize = domain.iter().product();
            let init = rand_field(4, n);
            let mut field = init.clone();
            NativeBackend::new().advance(&j, &mut field).unwrap();
            let want = golden_mirror(&j, &init);
            let got = golden::Field::from_vec(&j.domain, field);
            assert_eq!(got.max_abs_diff(&want), 0.0, "d={d}");
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let init = rand_field(5, 31 * 29);
        let mut want: Option<Vec<f64>> = None;
        for threads in [1usize, 2, 7] {
            let mut j = job(2, 2, vec![31, 29], 4, 2);
            j.threads = threads;
            let mut field = init.clone();
            NativeBackend::new().advance(&j, &mut field).unwrap();
            match &want {
                None => want = Some(field),
                Some(w) => assert_eq!(w, &field, "threads={threads}"),
            }
        }
    }

    #[test]
    fn f32_matches_oracle_to_rounding() {
        let mut j = job(2, 1, vec![24, 24], 4, 2);
        j.dtype = Dtype::F32;
        let init: Vec<f64> = rand_field(6, 576).iter().map(|&v| v as f32 as f64).collect();
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn domain_smaller_than_hull_is_all_boundary() {
        // 3×3 domain with a fused radius of 2: no interior fast path at
        // all — every point must still match the oracle.
        let j = job(2, 1, vec![3, 3], 2, 2);
        let init = rand_field(7, 9);
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn star_pattern_runs() {
        let mut j = job(2, 1, vec![16, 16], 3, 3);
        j.pattern = StencilPattern::new(Shape::Star, 2, 1).unwrap();
        // star weights: centre + axes over the 3×3 hull
        let mut w = vec![0.0; 9];
        w[4] = 0.2;
        for i in [1usize, 3, 5, 7] {
            w[i] = 0.2;
        }
        j.weights = w;
        let init = rand_field(8, 256);
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn zero_steps_is_identity() {
        let j = job(2, 1, vec![8, 8], 0, 2);
        let init = rand_field(9, 64);
        let mut field = init.clone();
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        assert_eq!(field, init);
        assert_eq!(m.launches, 0);
    }
}

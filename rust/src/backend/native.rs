//! The native CPU stencil engine: a tiled, halo-split, double-buffered,
//! multi-threaded executor for ANY `(pattern, dtype, t)` combination —
//! with two temporal execution strategies selected by
//! [`Job::temporal`](crate::backend::Job):
//!
//! **Fused sweeps** ([`TemporalMode::Sweep`]) — one launch per `t` steps:
//!
//! * the fused kernel (t-fold self-convolution, identical arithmetic to
//!   the golden oracle's [`golden::Weights::fuse`]) is compiled once into
//!   a flat-offset form bound to the domain's row-major strides;
//! * output rows are split across worker threads (disjoint `chunks_mut`
//!   slabs, no locks);
//! * each row is halo-split: the interior column window `[r·t, N−r·t)`
//!   of an interior row takes the fast path — a shape-specialized,
//!   vectorized row kernel from [`crate::backend::kernels`] when the
//!   tap count is registered (AVX2/NEON intrinsics or the unrolled
//!   portable body, selected once at compile time by runtime ISA
//!   detection), else the generic offset-major `zip` accumulation —
//!   while boundary rows/columns take the scalar slow path with the
//!   zero-Dirichlet halo;
//! * fields are double-buffered and swapped between launches.
//!
//! **Temporal blocking** ([`TemporalMode::Blocked`]) — the paper's
//! arithmetic-intensity shift (Eq. 8, `I = t·K/D`) made real: the domain
//! is tiled into dim-0 slabs sized to stay cache-resident, and each tile
//! carries `t` base-kernel steps before the next tile is touched.  The
//! tile's read footprint deepens by `r` per fused step (the `t·r` halo
//! skew of a trapezoidal/parallelogram time tile); intermediate steps
//! rotate through two tile-local scratch buffers that never spill to the
//! full-domain arrays, so principal-memory traffic is one read + one
//! write of the domain per `t` steps instead of per step.  Neighboring
//! tiles recompute the overlapped halo region (overlapped tiling — no
//! inter-tile dependencies, so tiles parallelize freely across workers).
//! The trapezoid reuses `step_rows`, so the same specialized row kernel
//! serves both realizations.
//!
//! Compiled kernels (offsets + flat deltas + resolved row kernel) are
//! cached inside [`NativeBackend`] per (dims, depth, weight bits), so
//! repeated `advance` calls on a resident session stop re-deriving
//! strides, neighbor tables, and fused hulls.
//!
//! Accumulation order per output point is exactly the oracle's (hull
//! row-major, zero weights skipped, out-of-domain reads contribute
//! `w·0`), so f64 sweep results are bit-identical to
//! `golden::apply_fused` / `apply_once` chains and f64 blocked results
//! are bit-identical to chained `golden::apply_once` (sequential
//! semantics); f32 jobs run genuinely in f32 (mirroring the AOT
//! artifacts' precision) and match the oracle to rounding.  The
//! specialized row kernels preserve the same per-point chain (they
//! vectorize across output points, never across taps), so the guarantee
//! holds under dispatch — and `--kernels generic` removes them entirely.
//!
//! [`RunMetrics`] carries instrumented traffic accounting: `bytes_moved`
//! counts principal-memory reads+writes of field-level buffers (tile
//! scratch is cache-resident by construction and excluded), `flops`
//! counts `2 × non-zero kernel points` per computed output point, and
//! their ratio is the *achieved* arithmetic intensity that
//! [`crate::model::calib`] compares against the model's prediction.
//! `interior_points`/`boundary_points` split every computed point by
//! which path produced it, so a mostly-boundary domain is visible when
//! model error spikes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::backend::kernels::{self, KernelMode, RowFn, Scalar};
use crate::backend::{Backend, Job, ShardPhase, TemporalMode};
use crate::coordinator::grid::ShardPlan;
use crate::coordinator::metrics::RunMetrics;
use crate::model::perf::Dtype;
use crate::obs;
use crate::sim::golden;

/// A stencil kernel compiled against one domain shape.
struct Kernel<T> {
    /// Hull radius (r·t after fusion, r for the blocked base kernel).
    r: usize,
    /// Non-zero hull offsets in oracle order (multi-dim form, slow path).
    offsets: Vec<(Vec<i64>, T)>,
    /// The same offsets as flat row-major deltas (interior fast path).
    deltas: Vec<(isize, T)>,
    /// Specialized row kernel for the interior window, when the tap
    /// count is registered for this dtype/ISA and dispatch is enabled.
    row: Option<RowFn<T>>,
    /// Variable-coefficient execution: every tap's weight is modulated
    /// per output point by [`golden::vc_mod`] (tap index = position in
    /// `deltas`/`offsets`, matching the oracle's enumeration).  Row
    /// kernels broadcast one weight across points, so `row` is `None`
    /// whenever this is set.
    varcoef: bool,
}

fn compile<T: Scalar>(w: &golden::Weights, dims: &[usize], mode: KernelMode, varcoef: bool) -> Kernel<T> {
    let st = golden::strides_for(dims);
    let offsets: Vec<(Vec<i64>, T)> = w
        .offsets()
        .into_iter()
        .map(|(off, v)| (off, T::from_f64(v)))
        .collect();
    let deltas: Vec<(isize, T)> = offsets
        .iter()
        .map(|(off, v)| {
            let d: isize = off
                .iter()
                .zip(&st)
                .map(|(&o, &s)| o as isize * s as isize)
                .sum();
            (d, *v)
        })
        .collect();
    let row = if varcoef {
        None
    } else {
        kernels::resolve::<T>(deltas.len(), mode, kernels::Isa::detect())
    };
    Kernel { r: w.r(), offsets, deltas, row, varcoef }
}

/// One output point via the scalar slow path (zero-Dirichlet halo),
/// accumulating in exactly the oracle's order.  `src` may be a slab of
/// the field starting at global flat index `src_base`.
#[allow(clippy::too_many_arguments)]
fn point<T: Scalar>(
    k: &Kernel<T>,
    dims: &[usize],
    st: &[usize],
    src: &[T],
    src_base: usize,
    outer: &[usize],
    col: usize,
    coords: &mut [i64],
) -> T {
    let d = dims.len();
    for (c, &o) in coords.iter_mut().zip(outer) {
        *c = o as i64;
    }
    coords[d - 1] = col as i64;
    // Global flat index of the OUTPUT point — the varcoef modulation's
    // spatial coordinate (coords are global even on slab/tile paths).
    let out_flat: usize = if k.varcoef {
        coords.iter().zip(st).map(|(&c, &s)| c as usize * s).sum()
    } else {
        0
    };
    let mut acc = T::ZERO;
    for (j, (off, w)) in k.offsets.iter().enumerate() {
        let mut flat = 0isize;
        let mut ok = true;
        for kk in 0..d {
            let c = coords[kk] + off[kk];
            if c < 0 || c >= dims[kk] as i64 {
                ok = false;
                break;
            }
            flat += c as isize * st[kk] as isize;
        }
        let v = if ok { src[(flat - src_base as isize) as usize] } else { T::ZERO };
        let w = if k.varcoef {
            T::mul(*w, T::from_f64(golden::vc_mod(out_flat, j)))
        } else {
            *w
        };
        acc = T::mul_acc(acc, w, v);
    }
    acc
}

/// Compute global rows `[dst_row0, dst_row0 + dst.len()/n_last)` of one
/// step into `dst`, reading `src` — a slab of the field whose first
/// element is global row `src_row0` (the full field when `src_row0 == 0`
/// and `src` spans it).  Rows are flattened outer indices (all dims but
/// the last); a dim-0 slab with full extent in the other dims is a
/// contiguous row range, which is what lets the blocked path reuse the
/// flat-delta fast path unchanged: strides of dims `1..` are unaffected
/// by slicing dim 0.  Returns `(interior, boundary)` point counts —
/// the fast-path coverage split surfaced through [`RunMetrics`].
fn step_rows<T: Scalar>(
    dims: &[usize],
    k: &Kernel<T>,
    src: &[T],
    src_row0: usize,
    dst: &mut [T],
    dst_row0: usize,
) -> (u64, u64) {
    let d = dims.len();
    let n_last = dims[d - 1];
    let r = k.r;
    let nrows = dst.len() / n_last;
    let st = golden::strides_for(dims);
    let src_base = src_row0 * n_last;
    // Interior column window shared by every interior row.
    let (clo, chi) = if n_last > 2 * r { (r, n_last - r) } else { (0, 0) };
    let mut outer = vec![0usize; d - 1];
    let mut coords = vec![0i64; d];
    let mut interior = 0u64;
    let mut boundary = 0u64;
    for lr in 0..nrows {
        let rr = dst_row0 + lr;
        let mut rem = rr;
        for kk in (0..d - 1).rev() {
            outer[kk] = rem % dims[kk];
            rem /= dims[kk];
        }
        let row_interior = outer.iter().zip(dims).all(|(&c, &n)| c >= r && c + r < n);
        let row_base = rr * n_last;
        let drow = &mut dst[lr * n_last..(lr + 1) * n_last];
        if row_interior && chi > clo {
            // Fast path: the whole interior window in one call.  Bounds
            // are guaranteed by the interior condition (and, on the
            // blocked path, by the trapezoid's halo bookkeeping), so the
            // only checks left are one slice construction per offset.
            let out = &mut drow[clo..chi];
            if let Some(row) = k.row {
                // Specialized: vectorized across the window's points,
                // per-point tap chain in oracle order (bit-identical).
                let center = ((row_base + clo) as isize - src_base as isize) as usize;
                row(&k.deltas, src, center, out);
            } else if k.varcoef {
                // Variable-coefficient: same offset-major walk, but each
                // tap's weight is scaled per output point by vc_mod of
                // the point's GLOBAL flat index — the per-point chain is
                // still in deltas order, so bit-identity to the oracle's
                // `apply_once_varcoef` holds.
                out.fill(T::ZERO);
                for (j, &(delta, w)) in k.deltas.iter().enumerate() {
                    let start = ((row_base + clo) as isize + delta - src_base as isize) as usize;
                    let seg = &src[start..start + (chi - clo)];
                    let flat0 = row_base + clo;
                    for (i, (o, &v)) in out.iter_mut().zip(seg).enumerate() {
                        let wm = T::mul(w, T::from_f64(golden::vc_mod(flat0 + i, j)));
                        *o = T::mul_acc(*o, wm, v);
                    }
                }
            } else {
                // Generic: offset-major, one contiguous source segment
                // per offset, no per-element bounds checks.
                out.fill(T::ZERO);
                for &(delta, w) in &k.deltas {
                    let start = ((row_base + clo) as isize + delta - src_base as isize) as usize;
                    let seg = &src[start..start + (chi - clo)];
                    for (o, &v) in out.iter_mut().zip(seg) {
                        *o = T::mul_acc(*o, w, v);
                    }
                }
            }
            for c in (0..clo).chain(chi..n_last) {
                drow[c] = point(k, dims, &st, src, src_base, &outer, c, &mut coords);
            }
            interior += (chi - clo) as u64;
            boundary += (n_last - (chi - clo)) as u64;
        } else {
            for c in 0..n_last {
                drow[c] = point(k, dims, &st, src, src_base, &outer, c, &mut coords);
            }
            boundary += n_last as u64;
        }
    }
    (interior, boundary)
}

/// One full step `dst = K(src)`, rows split across `threads` workers.
/// Returns the aggregated `(interior, boundary)` coverage counts.
fn step<T: Scalar>(
    dims: &[usize],
    k: &Kernel<T>,
    src: &[T],
    dst: &mut [T],
    threads: usize,
) -> (u64, u64) {
    let n_last = dims[dims.len() - 1];
    let rows = src.len() / n_last;
    let workers = threads.max(1).min(rows);
    if workers <= 1 {
        return step_rows(dims, k, src, 0, dst, 0);
    }
    let chunk_rows = rows.div_ceil(workers);
    let interior = AtomicU64::new(0);
    let boundary = AtomicU64::new(0);
    std::thread::scope(|s| {
        for (ci, chunk) in dst.chunks_mut(chunk_rows * n_last).enumerate() {
            let (int_ref, bnd_ref) = (&interior, &boundary);
            s.spawn(move || {
                let (ip, bp) = step_rows(dims, k, src, 0, chunk, ci * chunk_rows);
                int_ref.fetch_add(ip, Ordering::Relaxed);
                bnd_ref.fetch_add(bp, Ordering::Relaxed);
            });
        }
    });
    (interior.into_inner(), boundary.into_inner())
}

/// Fused-sweep execution: `launches` passes of the fused kernel plus
/// `rem` passes of the base kernel, full-domain double buffering.  The
/// kernels arrive pre-compiled (from the backend's cache); `fused` /
/// `base` may be `None` only when the corresponding pass count is zero.
#[allow(clippy::too_many_arguments)]
fn run_sweeps<T: Scalar>(
    dims: &[usize],
    fused: Option<&Kernel<T>>,
    base: Option<&Kernel<T>>,
    t: usize,
    launches: usize,
    rem: usize,
    threads: usize,
    buf: &mut Vec<T>,
    metrics: &mut RunMetrics,
) {
    let n = buf.len() as u64;
    let elem = std::mem::size_of::<T>() as u64;
    let mut next = vec![T::ZERO; buf.len()];
    if launches > 0 {
        let fk = fused.expect("fused kernel required when launches > 0");
        let nnz = fk.deltas.len() as u64;
        let mark = metrics.phase_mark();
        for _ in 0..launches {
            let t0 = Instant::now();
            let (ip, bp) = step(dims, fk, buf, &mut next, threads);
            metrics.add_execute(t0.elapsed());
            std::mem::swap(buf, &mut next);
            metrics.launches += 1;
            metrics.bytes_moved += 2 * n * elem;
            metrics.flops += 2 * nnz * n;
            metrics.interior_points += ip;
            metrics.boundary_points += bp;
        }
        metrics.close_phase(&mark, t, t > 1);
    }
    if rem > 0 {
        let bk = base.expect("base kernel required when rem > 0");
        let nnz = bk.deltas.len() as u64;
        let mark = metrics.phase_mark();
        for _ in 0..rem {
            let t0 = Instant::now();
            let (ip, bp) = step(dims, bk, buf, &mut next, threads);
            metrics.add_execute(t0.elapsed());
            std::mem::swap(buf, &mut next);
            metrics.launches += 1;
            metrics.bytes_moved += 2 * n * elem;
            metrics.flops += 2 * nnz * n;
            metrics.interior_points += ip;
            metrics.boundary_points += bp;
        }
        metrics.close_phase(&mark, 1, false);
    }
}

/// Scratch budget for one worker's pair of tile-resident buffers —
/// sized to sit comfortably inside a per-core L2 slice.
const TILE_BUDGET_BYTES: usize = 2 << 20;

/// Dim-0 planes per time tile: fit the two tile-resident scratch
/// buffers in [`TILE_BUDGET_BYTES`], keep at least one tile per worker
/// for parallelism, floor at a single plane.
fn tile_planes(n0: usize, plane_bytes: usize, tb: usize, r: usize, threads: usize) -> usize {
    let halo = 2 * (tb - 1) * r;
    let fit = (TILE_BUDGET_BYTES / (2 * plane_bytes).max(1)).saturating_sub(halo).max(1);
    let spread = n0.div_ceil(threads.max(1)).max(1);
    fit.min(spread).min(n0).max(1)
}

/// Carry `tb` base-kernel steps over the output dim-0 plane range
/// `[a, b)`: step 1 reads `src` — a slab of the field whose first
/// element is global plane `src_row0` (the full field when 0) —
/// intermediate steps rotate through the tile-local scratch slabs
/// `sa`/`sb` (each sized for the widest intermediate extent), and the
/// final step writes straight into `dst` (exactly `(b − a) · plane`
/// elements).  The read/compute extent shrinks by `r` per step — the
/// classic trapezoidal time tile — and every intermediate value equals
/// the corresponding global-sweep value, which is what makes the
/// result bit-identical to sequential stepping (and shard-count
/// invariant: a shard's trapezoid and a cache tile's trapezoid are the
/// same computation).  Every step reuses `step_rows`, so the
/// specialized row kernel serves the blocked interior too; returns the
/// summed `(interior, boundary)` coverage counts.
#[allow(clippy::too_many_arguments)]
fn trapezoid<T: Scalar>(
    dims: &[usize],
    k: &Kernel<T>,
    tb: usize,
    src: &[T],
    src_row0: usize,
    a: usize,
    b: usize,
    dst: &mut [T],
    sa: &mut [T],
    sb: &mut [T],
) -> (u64, u64) {
    let d = dims.len();
    let n0 = dims[0];
    let plane: usize = dims[1..].iter().product();
    let outer_rest = plane / dims[d - 1];
    let r = k.r;
    let (mut prev, mut cur): (&mut [T], &mut [T]) = (sa, sb);
    let mut interior = 0u64;
    let mut boundary = 0u64;
    for s in 1..=tb {
        let olo = a.saturating_sub((tb - s) * r);
        let ohi = (b + (tb - s) * r).min(n0);
        // The source slab: the field slab for step 1, otherwise the
        // previous step's output planes [plo, phi) — the same range the
        // previous iteration computed (the trapezoid shrinks by r).
        let plo = a.saturating_sub((tb - s + 1) * r);
        let phi = (b + (tb - s + 1) * r).min(n0);
        let (ip, bp) = if s == tb {
            let (src_sl, src_lo): (&[T], usize) =
                if s == 1 { (src, src_row0) } else { (&prev[..(phi - plo) * plane], plo) };
            step_rows(dims, k, src_sl, src_lo * outer_rest, dst, a * outer_rest)
        } else if s == 1 {
            let out = &mut prev[..(ohi - olo) * plane];
            step_rows(dims, k, src, src_row0 * outer_rest, out, olo * outer_rest)
        } else {
            let src_sl: &[T] = &prev[..(phi - plo) * plane];
            let out = &mut cur[..(ohi - olo) * plane];
            let counts = step_rows(dims, k, src_sl, plo * outer_rest, out, olo * outer_rest);
            std::mem::swap(&mut prev, &mut cur);
            counts
        };
        interior += ip;
        boundary += bp;
    }
    (interior, boundary)
}

/// Temporal-blocked execution: `steps` sequential base-kernel steps,
/// grouped into time blocks of depth ≤ `t`; within a block each dim-0
/// tile is carried through the whole block while cache-resident.  `k`
/// is the pre-compiled base kernel (depth 1).
#[allow(clippy::too_many_arguments)]
fn run_blocked<T: Scalar>(
    dims: &[usize],
    k: &Kernel<T>,
    steps: usize,
    t: usize,
    threads: usize,
    buf: &mut Vec<T>,
    metrics: &mut RunMetrics,
) {
    if steps == 0 {
        return;
    }
    let nnz = k.deltas.len() as u64;
    let d = dims.len();
    let n = buf.len();
    let elem = std::mem::size_of::<T>();
    let n0 = dims[0];
    let plane: usize = dims[1..].iter().product();
    let r = k.r;
    let mut next = vec![T::ZERO; n];
    let mut remaining = steps;
    while remaining > 0 {
        let tb = t.min(remaining);
        let mark = metrics.phase_mark();
        let bheight = tile_planes(n0, plane * elem, tb, r, threads);
        let tiles: Vec<(usize, usize)> =
            (0..n0).step_by(bheight).map(|a| (a, (a + bheight).min(n0))).collect();
        // Tiling is only profitable when the tile is thicker than its
        // per-block halo growth — thinner tiles spend more work
        // recomputing overlap than advancing, and their scratch slabs
        // (cap ≤ 2·bheight planes when this holds) stay budget-bounded.
        let tileable = d > 1 && tiles.len() > 1 && bheight >= 2 * (tb - 1) * r;
        let t0 = Instant::now();
        if tb == 1 || !tileable {
            // Degenerate tile: 1-D domains have no plane axis to slab,
            // a single tile spanning the domain is just sequential
            // stepping, and halo-dominated thin tiles would recompute
            // more than they advance — run the block as plain sweeps
            // (bit-identical, and `step` keeps the row-level thread
            // parallelism), recording the fallback for the model
            // feedback path.
            if tb > 1 {
                metrics.degenerate_blocks += 1;
            }
            for _ in 0..tb {
                let (ip, bp) = step(dims, k, buf, &mut next, threads);
                std::mem::swap(buf, &mut next);
                metrics.bytes_moved += 2 * (n * elem) as u64;
                metrics.flops += 2 * nnz * n as u64;
                metrics.interior_points += ip;
                metrics.boundary_points += bp;
            }
        } else {
            let cap_planes = (bheight + 2 * (tb - 1) * r).min(n0);
            let workers = threads.max(1).min(tiles.len());
            let tpw = tiles.len().div_ceil(workers);
            let src: &[T] = buf.as_slice();
            let kref = k;
            let tiles_ref = &tiles;
            let interior = AtomicU64::new(0);
            let boundary = AtomicU64::new(0);
            std::thread::scope(|s| {
                for (wi, chunk) in next.chunks_mut(tpw * bheight * plane).enumerate() {
                    let (int_ref, bnd_ref) = (&interior, &boundary);
                    s.spawn(move || {
                        let mut sa = vec![T::ZERO; cap_planes * plane];
                        let mut sb = vec![T::ZERO; cap_planes * plane];
                        let lo = wi * tpw;
                        let hi = (lo + tpw).min(tiles_ref.len());
                        let base_plane = tiles_ref[lo].0;
                        let mut counts = (0u64, 0u64);
                        for &(ta, tbound) in &tiles_ref[lo..hi] {
                            let off = (ta - base_plane) * plane;
                            let dst = &mut chunk[off..off + (tbound - ta) * plane];
                            let (ip, bp) = trapezoid(
                                dims, kref, tb, src, 0, ta, tbound, dst, &mut sa, &mut sb,
                            );
                            counts.0 += ip;
                            counts.1 += bp;
                        }
                        int_ref.fetch_add(counts.0, Ordering::Relaxed);
                        bnd_ref.fetch_add(counts.1, Ordering::Relaxed);
                    });
                }
            });
            std::mem::swap(buf, &mut next);
            metrics.interior_points += interior.into_inner();
            metrics.boundary_points += boundary.into_inner();
            // Traffic/flop accounting is a pure function of the tile
            // geometry the workers just executed: each tile reads its
            // tb·r-deepened input slab from the field and writes its
            // output planes; overlapped-halo recompute shows up as the
            // extra per-step extents.
            for &(ta, tbound) in &tiles {
                let read_planes = (tbound + tb * r).min(n0) - ta.saturating_sub(tb * r);
                metrics.bytes_moved +=
                    ((read_planes + (tbound - ta)) * plane * elem) as u64;
                for s in 1..=tb {
                    let olo = ta.saturating_sub((tb - s) * r);
                    let ohi = (tbound + (tb - s) * r).min(n0);
                    metrics.flops += 2 * nnz * ((ohi - olo) * plane) as u64;
                }
            }
        }
        metrics.add_execute(t0.elapsed());
        metrics.launches += 1;
        metrics.close_phase(&mark, tb, false);
        remaining -= tb;
    }
}

/// Dispatch one dtype-monomorphized execution over the resolved mode,
/// fetching kernels through the backend's compile cache and recording
/// the resolved kernel name.
fn run_field<T: CacheSlot>(
    nb: &NativeBackend,
    job: &Job,
    blocked: bool,
    buf: &mut Vec<T>,
    metrics: &mut RunMetrics,
) {
    let k0 = if obs::enabled() { obs::now_ns() } else { 0 };
    let base = golden::Weights::new(job.pattern.d, 2 * job.pattern.r + 1, job.weights.clone());
    let varcoef = job.pattern.coeffs == crate::model::stencil::Coeffs::VarCoef;
    let mut nnz = 0u64;
    if blocked {
        if job.steps == 0 {
            return;
        }
        let k = nb.kernel::<T>(&job.domain, &base, 1, varcoef);
        metrics.kernel = kernels::label(&job.pattern, job.dtype, k.row.is_some());
        nnz = k.deltas.len() as u64;
        run_blocked::<T>(&job.domain, &k, job.steps, job.t, job.threads, buf, metrics);
    } else {
        let launches = job.steps / job.t;
        let rem = job.steps % job.t;
        // Fusing is itself a t-fold convolution — skip it when no fused
        // launch will run (steps < t jobs are pure remainder).
        let fk = if launches > 0 {
            Some(nb.kernel::<T>(&job.domain, &base, job.t, varcoef))
        } else {
            None
        };
        let bk = if rem > 0 { Some(nb.kernel::<T>(&job.domain, &base, 1, varcoef)) } else { None };
        if let Some(k) = fk.as_deref().or(bk.as_deref()) {
            metrics.kernel = kernels::label(&job.pattern, job.dtype, k.row.is_some());
            nnz = k.deltas.len() as u64;
        }
        run_sweeps::<T>(
            &job.domain,
            fk.as_deref(),
            bk.as_deref(),
            job.t,
            launches,
            rem,
            job.threads,
            buf,
            metrics,
        );
    }
    if obs::enabled() {
        obs::record(
            obs::SpanKind::Kernel,
            k0,
            obs::now_ns(),
            obs::Payload::Kernel { name: metrics.kernel.clone(), nnz },
        );
    }
}

/// One shard × one phase of a sharded execution, dtype-monomorphized.
/// `src` is a slab of the phase-start field whose first element is
/// global plane `src_row0` (the full field when 0); `dst` is the
/// shard's disjoint write-back slab for planes `[a, b)`.  Traffic and
/// flop accounting mirror `model::shard::predicted_job_intensity` term
/// for term: halo reads count against `bytes_moved`, trapezoid
/// recompute against `flops`.  Shard tasks stay stateless across the
/// queue's workers; the kernel comes from the backend's compile cache,
/// so repeated phases of a resident session skip the fuse+compile.
#[allow(clippy::too_many_arguments)]
fn shard_phase_field<T: CacheSlot>(
    nb: &NativeBackend,
    job: &Job,
    phase: ShardPhase,
    a: usize,
    b: usize,
    src: &[T],
    src_row0: usize,
    dst: &mut [T],
    metrics: &mut RunMetrics,
) {
    let dims = &job.domain;
    let base = golden::Weights::new(job.pattern.d, 2 * job.pattern.r + 1, job.weights.clone());
    let varcoef = job.pattern.coeffs == crate::model::stencil::Coeffs::VarCoef;
    let n0 = dims[0];
    let plane: usize = dims[1..].iter().product();
    let outer_rest = plane / dims[dims.len() - 1];
    let r = base.r();
    let elem = std::mem::size_of::<T>();
    let t0 = Instant::now();
    let mark = metrics.phase_mark();
    if phase.fused || phase.depth == 1 {
        let k = nb.kernel::<T>(dims, &base, phase.depth, varcoef);
        metrics.kernel = kernels::label(&job.pattern, job.dtype, k.row.is_some());
        let (ip, bp) = step_rows(dims, &k, src, src_row0 * outer_rest, dst, a * outer_rest);
        metrics.interior_points += ip;
        metrics.boundary_points += bp;
        let h = r * phase.depth;
        let read = (b + h).min(n0) - a.saturating_sub(h);
        metrics.bytes_moved += ((read + (b - a)) * plane * elem) as u64;
        metrics.flops += 2 * k.deltas.len() as u64 * ((b - a) * plane) as u64;
    } else {
        let tb = phase.depth;
        let k = nb.kernel::<T>(dims, &base, 1, varcoef);
        metrics.kernel = kernels::label(&job.pattern, job.dtype, k.row.is_some());
        let cap = ((b - a) + 2 * (tb - 1) * r).min(n0);
        let mut sa = vec![T::ZERO; cap * plane];
        let mut sb = vec![T::ZERO; cap * plane];
        let (ip, bp) = trapezoid(dims, &k, tb, src, src_row0, a, b, dst, &mut sa, &mut sb);
        metrics.interior_points += ip;
        metrics.boundary_points += bp;
        let read = (b + tb * r).min(n0) - a.saturating_sub(tb * r);
        metrics.bytes_moved += ((read + (b - a)) * plane * elem) as u64;
        let nnz = k.deltas.len() as u64;
        for s in 1..=tb {
            let olo = a.saturating_sub((tb - s) * r);
            let ohi = (b + (tb - s) * r).min(n0);
            metrics.flops += 2 * nnz * ((ohi - olo) * plane) as u64;
        }
    }
    metrics.add_execute(t0.elapsed());
    // One entry at index 0 — only the driver knows this phase's slot
    // in the `shard_phases` schedule and re-tags it before absorbing.
    metrics.close_phase(&mark, phase.depth, phase.fused);
}

/// Key for one cached compiled kernel: (domain dims, fusion depth,
/// variable-coefficient flag, the base weights' exact bits) —
/// everything `compile` depends on besides the backend-wide dispatch
/// mode.
type CacheKey = (Vec<usize>, usize, bool, Vec<u64>);

/// One dtype's compartment of the compile cache.
struct KernelSlot<T>(Mutex<HashMap<CacheKey, Arc<Kernel<T>>>>);

impl<T> KernelSlot<T> {
    fn new() -> KernelSlot<T> {
        KernelSlot(Mutex::new(HashMap::new()))
    }
}

/// Selects the dtype's compartment of [`NativeBackend`]'s kernel cache.
trait CacheSlot: Scalar {
    fn slot(nb: &NativeBackend) -> &KernelSlot<Self>;
}

impl CacheSlot for f64 {
    fn slot(nb: &NativeBackend) -> &KernelSlot<f64> {
        &nb.f64_kernels
    }
}

impl CacheSlot for f32 {
    fn slot(nb: &NativeBackend) -> &KernelSlot<f32> {
        &nb.f32_kernels
    }
}

/// The native CPU backend.  Field state lives in the job; the backend
/// itself carries only the kernel dispatch mode and the compile cache,
/// so a resident instance (a serve session, the shard queue) reuses
/// compiled kernels across `advance` calls.
pub struct NativeBackend {
    mode: KernelMode,
    f64_kernels: KernelSlot<f64>,
    f32_kernels: KernelSlot<f32>,
}

impl std::fmt::Debug for NativeBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NativeBackend").field("mode", &self.mode).finish()
    }
}

impl Default for NativeBackend {
    fn default() -> NativeBackend {
        NativeBackend::new()
    }
}

impl NativeBackend {
    /// Construct the native backend with the process-default kernel
    /// mode (`--kernels` / `STENCILCTL_KERNELS`, else auto dispatch).
    pub fn new() -> NativeBackend {
        NativeBackend::with_mode(kernels::default_mode())
    }

    /// Construct with an explicit kernel dispatch mode — the in-process
    /// A/B hook the dispatch tests and benches use.
    pub fn with_mode(mode: KernelMode) -> NativeBackend {
        NativeBackend { mode, f64_kernels: KernelSlot::new(), f32_kernels: KernelSlot::new() }
    }

    /// The kernel dispatch mode this backend resolves row kernels with.
    pub fn mode(&self) -> KernelMode {
        self.mode
    }

    /// Fetch (or compile and cache) the kernel for `base` fused to
    /// depth `t` over `dims`.  The fuse + stride/neighbor derivation
    /// runs once per distinct (dims, t, varcoef, weights) per backend
    /// instance.  Variable-coefficient kernels never fuse (the per-point
    /// modulation does not commute with self-convolution), so `varcoef`
    /// requires `t == 1`.
    fn kernel<T: CacheSlot>(
        &self,
        dims: &[usize],
        base: &golden::Weights,
        t: usize,
        varcoef: bool,
    ) -> Arc<Kernel<T>> {
        assert!(!(varcoef && t > 1), "variable-coefficient kernels cannot be fused");
        let key: CacheKey =
            (dims.to_vec(), t, varcoef, base.data.iter().map(|w| w.to_bits()).collect());
        let slot = T::slot(self);
        if let Some(k) = slot.0.lock().unwrap().get(&key) {
            return Arc::clone(k);
        }
        let w = if t > 1 { base.fuse(t) } else { base.clone() };
        let k = Arc::new(compile::<T>(&w, dims, self.mode, varcoef));
        slot.0.lock().unwrap().insert(key, Arc::clone(&k));
        k
    }

    /// Advance ONE shard of a sharded execution through ONE
    /// synchronization phase — the shard plane's compute primitive,
    /// shared by the service's dependency-aware shard executor
    /// (`service::queue`) and the one-shot driver
    /// (`coordinator::scheduler::advance_sharded`).
    ///
    /// `src` is the whole phase-start field (row-major f64 host
    /// representation, immutable for the duration of the phase); `dst`
    /// is this shard's disjoint write-back slab (`extent₀ · plane`
    /// elements for dim-0 planes `[a, b)`).  The per-point arithmetic
    /// is exactly the monolithic executor's — fused phases run the
    /// self-convolved kernel over the shard's rows, blocked phases run
    /// the same trapezoid a cache tile would — so assembling the slabs
    /// of every shard reproduces the unsharded result bit-for-bit in
    /// f64.  f32 jobs marshal the `depth·r`-deepened read slab through
    /// genuine f32 (exact both ways: every intermediate is an f32
    /// value), mirroring the artifact-precision path.
    ///
    /// Returned metrics are per-shard-phase: `launches == 1`,
    /// `bytes_moved`/`flops` include this shard's halo re-reads and
    /// trapezoid recompute; callers aggregate them into job-level
    /// [`RunMetrics`].
    pub fn advance_shard(
        &self,
        job: &Job,
        plan: &ShardPlan,
        index: usize,
        phase: ShardPhase,
        src: &[f64],
        dst: &mut [f64],
    ) -> Result<RunMetrics> {
        job.validate(src.len())?;
        anyhow::ensure!(
            plan.domain == job.domain,
            "shard plan domain {:?} != job domain {:?}",
            plan.domain,
            job.domain
        );
        anyhow::ensure!(job.domain.len() > 1, "sharded execution needs d >= 2 (dim-0 slabs)");
        anyhow::ensure!(plan.dim0_only(), "native sharding requires a dim-0-only decomposition");
        anyhow::ensure!(
            plan.r == job.pattern.r,
            "shard plan halo radius {} != pattern radius {}",
            plan.r,
            job.pattern.r
        );
        anyhow::ensure!(
            phase.depth >= 1 && phase.depth <= plan.t,
            "phase depth {} outside the plan's halo ring depth {}",
            phase.depth,
            plan.t
        );
        anyhow::ensure!(
            !(job.pattern.coeffs == crate::model::stencil::Coeffs::VarCoef
                && phase.fused
                && phase.depth > 1),
            "variable-coefficient phases cannot run the fused kernel (depth {})",
            phase.depth
        );
        let shard = plan
            .shards()
            .get(index)
            .ok_or_else(|| anyhow::anyhow!("shard index {index} out of range"))?;
        let (a, b) = shard.rows();
        let plane = plan.plane();
        anyhow::ensure!(
            dst.len() == (b - a) * plane,
            "dst slab has {} elements, shard wants {}",
            dst.len(),
            (b - a) * plane
        );
        let mut metrics = RunMetrics::default();
        match job.dtype {
            Dtype::F64 => {
                shard_phase_field::<f64>(self, job, phase, a, b, src, 0, dst, &mut metrics);
            }
            Dtype::F32 => {
                // Marshal only the depth·r-deepened read slab.
                let (lo, hi) = plan.read_rows(shard, phase.depth);
                let t0 = Instant::now();
                let src32: Vec<f32> =
                    src[lo * plane..hi * plane].iter().map(|&v| v as f32).collect();
                let mut dst32 = vec![0.0f32; dst.len()];
                metrics.add_gather(t0.elapsed());
                shard_phase_field::<f32>(
                    self,
                    job,
                    phase,
                    a,
                    b,
                    &src32,
                    lo,
                    &mut dst32,
                    &mut metrics,
                );
                let t1 = Instant::now();
                for (o, &v) in dst.iter_mut().zip(&dst32) {
                    *o = v as f64;
                }
                metrics.add_scatter(t1.elapsed());
            }
        }
        metrics.launches = 1;
        Ok(metrics)
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, job: &Job) -> Result<(), String> {
        // Any pattern/dtype/fusion depth/temporal mode runs here; only
        // structural inconsistencies are rejected.
        job.validate(job.points() as usize).map_err(|e| format!("{e:#}"))
    }

    fn advance(&mut self, job: &Job, field: &mut Vec<f64>) -> Result<RunMetrics> {
        job.validate(field.len())?;
        // An unresolved Auto means no planner scored this job; blocked
        // does strictly less arithmetic per useful step (no α
        // redundancy) and t× less principal-memory traffic, so it is
        // the CPU default whenever there is a time axis to tile.
        let blocked = match job.temporal {
            TemporalMode::Sweep => false,
            TemporalMode::Blocked => true,
            TemporalMode::Auto => job.t > 1,
        };
        let mut metrics = RunMetrics {
            steps: job.steps,
            points: job.points(),
            ..Default::default()
        };
        let wall0 = Instant::now();
        match job.dtype {
            Dtype::F64 => run_field::<f64>(self, job, blocked, field, &mut metrics),
            Dtype::F32 => {
                // Marshal through f32 buffers so the arithmetic runs at
                // artifact precision; conversion cost is accounted like
                // the PJRT backend's gather/scatter phases.
                let t0 = Instant::now();
                let mut buf: Vec<f32> = field.iter().map(|&v| v as f32).collect();
                metrics.add_gather(t0.elapsed());
                run_field::<f32>(self, job, blocked, &mut buf, &mut metrics);
                let t1 = Instant::now();
                for (o, &v) in field.iter_mut().zip(&buf) {
                    *o = v as f64;
                }
                metrics.add_scatter(t1.elapsed());
            }
        }
        metrics.wall_ns = wall0.elapsed().as_nanos() as u64;
        Ok(metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::stencil::{Shape, StencilPattern};
    use crate::util::rng::Rng;

    fn box_weights(d: usize, r: usize) -> Vec<f64> {
        let side = 2 * r + 1;
        let n = side.pow(d as u32);
        vec![1.0 / n as f64; n]
    }

    fn job(d: usize, r: usize, domain: Vec<usize>, steps: usize, t: usize) -> Job {
        Job {
            pattern: StencilPattern::new(Shape::Box, d, r).unwrap(),
            dtype: Dtype::F64,
            domain,
            steps,
            t,
            temporal: TemporalMode::Sweep,
            weights: box_weights(d, r),
            threads: 1,
        }
    }

    fn rand_field(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn golden_mirror(job: &Job, init: &[f64]) -> golden::Field {
        let w = golden::Weights::new(job.pattern.d, 2 * job.pattern.r + 1, job.weights.clone());
        let mut cur = golden::Field::from_vec(&job.domain, init.to_vec());
        for _ in 0..job.steps / job.t {
            cur = golden::apply_fused(&cur, &w, job.t);
        }
        for _ in 0..job.steps % job.t {
            cur = golden::apply_once(&cur, &w);
        }
        cur
    }

    fn golden_sequential(job: &Job, init: &[f64]) -> golden::Field {
        let w = golden::Weights::new(job.pattern.d, 2 * job.pattern.r + 1, job.weights.clone());
        let cur = golden::Field::from_vec(&job.domain, init.to_vec());
        golden::apply_steps(&cur, &w, job.steps)
    }

    #[test]
    fn f64_single_step_bit_identical_to_oracle() {
        let j = job(2, 1, vec![17, 13], 1, 1);
        let init = rand_field(1, 17 * 13);
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn f64_fused_launches_bit_identical_to_oracle() {
        let mut j = job(2, 1, vec![20, 21], 6, 3);
        j.threads = 3;
        let init = rand_field(2, 20 * 21);
        let mut field = init.clone();
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        assert_eq!(m.launches, 2);
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn remainder_steps_use_base_kernel() {
        // steps=5, t=2 → two fused launches + one single step.
        let j = job(2, 1, vec![12, 12], 5, 2);
        let init = rand_field(3, 144);
        let mut field = init.clone();
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        assert_eq!(m.launches, 3);
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn works_in_1d_and_3d() {
        for (d, domain) in [(1usize, vec![40usize]), (3, vec![9, 8, 10])] {
            let j = job(d, 1, domain.clone(), 2, 2);
            let n: usize = domain.iter().product();
            let init = rand_field(4, n);
            let mut field = init.clone();
            NativeBackend::new().advance(&j, &mut field).unwrap();
            let want = golden_mirror(&j, &init);
            let got = golden::Field::from_vec(&j.domain, field);
            assert_eq!(got.max_abs_diff(&want), 0.0, "d={d}");
        }
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        let init = rand_field(5, 31 * 29);
        for temporal in [TemporalMode::Sweep, TemporalMode::Blocked] {
            let mut want: Option<Vec<f64>> = None;
            for threads in [1usize, 2, 7] {
                let mut j = job(2, 2, vec![31, 29], 4, 2);
                j.temporal = temporal;
                j.threads = threads;
                let mut field = init.clone();
                NativeBackend::new().advance(&j, &mut field).unwrap();
                match &want {
                    None => want = Some(field),
                    Some(w) => assert_eq!(w, &field, "threads={threads} {temporal:?}"),
                }
            }
        }
    }

    #[test]
    fn f32_matches_oracle_to_rounding() {
        let mut j = job(2, 1, vec![24, 24], 4, 2);
        j.dtype = Dtype::F32;
        let init: Vec<f64> = rand_field(6, 576).iter().map(|&v| v as f32 as f64).collect();
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn domain_smaller_than_hull_is_all_boundary() {
        // 3×3 domain with a fused radius of 2: no interior fast path at
        // all — every point must still match the oracle.
        let j = job(2, 1, vec![3, 3], 2, 2);
        let init = rand_field(7, 9);
        let mut field = init.clone();
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
        // ...and the coverage counters agree: zero interior points.
        assert_eq!(m.interior_points, 0);
        assert_eq!(m.boundary_points, 9);
    }

    #[test]
    fn star_pattern_runs() {
        let mut j = job(2, 1, vec![16, 16], 3, 3);
        j.pattern = StencilPattern::new(Shape::Star, 2, 1).unwrap();
        // star weights: centre + axes over the 3×3 hull
        let mut w = vec![0.0; 9];
        w[4] = 0.2;
        for i in [1usize, 3, 5, 7] {
            w[i] = 0.2;
        }
        j.weights = w;
        let init = rand_field(8, 256);
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_mirror(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn zero_steps_is_identity() {
        for temporal in [TemporalMode::Sweep, TemporalMode::Blocked] {
            let mut j = job(2, 1, vec![8, 8], 0, 2);
            j.temporal = temporal;
            let init = rand_field(9, 64);
            let mut field = init.clone();
            let m = NativeBackend::new().advance(&j, &mut field).unwrap();
            assert_eq!(field, init);
            assert_eq!(m.launches, 0);
        }
    }

    #[test]
    fn blocked_is_bit_identical_to_sequential_oracle() {
        // Odd domain, deep fusion, multiple workers: the trapezoid path
        // must reproduce chained apply_once exactly.
        let mut j = job(2, 1, vec![37, 23], 9, 4);
        j.temporal = TemporalMode::Blocked;
        j.threads = 3;
        let init = rand_field(11, 37 * 23);
        let mut field = init.clone();
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        // 9 steps at depth 4 → blocks of 4, 4, 1.
        assert_eq!(m.launches, 3);
        let want = golden_sequential(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn auto_mode_resolves_blocked_above_t1() {
        // Auto with t>1 runs the blocked (sequential-semantics) path.
        let mut j = job(2, 1, vec![19, 19], 4, 2);
        j.temporal = TemporalMode::Auto;
        let init = rand_field(12, 19 * 19);
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_sequential(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
        // ...and at t=1 both semantics coincide anyway.
        let mut j1 = job(2, 1, vec![19, 19], 3, 1);
        j1.temporal = TemporalMode::Auto;
        let mut f1 = init.clone();
        NativeBackend::new().advance(&j1, &mut f1).unwrap();
        let want1 = golden_sequential(&j1, &init);
        assert_eq!(golden::Field::from_vec(&j1.domain, f1).max_abs_diff(&want1), 0.0);
    }

    #[test]
    fn traffic_accounting_matches_model_geometry() {
        // Sweep t=1: per step one read + one write of the field and
        // 2·nnz flops per point — exactly Eq. 8 at t=1.
        let j = job(2, 1, vec![32, 32], 4, 1);
        let mut field = rand_field(13, 1024);
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        assert_eq!(m.bytes_moved, 4 * 2 * 1024 * 8);
        assert_eq!(m.flops, 4 * 2 * 9 * 1024);
        assert!((m.achieved_intensity() - 9.0 / 8.0).abs() < 1e-12);
        // Coverage counters partition every computed point.
        assert_eq!(m.interior_points + m.boundary_points, 4 * 1024);
        assert_eq!(m.interior_points, 4 * 30 * 30);
        // Blocked t=4 over a domain with many tiles: achieved intensity
        // approaches t·K/D from below (halo re-reads/recompute).
        // threads=2 splits the 256-plane domain into two 128-plane
        // tiles (the single-tile case degrades to sweeps by design).
        let mut jb = job(2, 1, vec![256, 256], 8, 4);
        jb.temporal = TemporalMode::Blocked;
        jb.threads = 2;
        let mut fieldb = rand_field(14, 256 * 256);
        let mb = NativeBackend::new().advance(&jb, &mut fieldb).unwrap();
        let model = 4.0 * 9.0 / 8.0;
        let got = mb.achieved_intensity();
        assert!(got > 0.5 * model && got <= model + 1e-9, "I={got} vs model {model}");
    }

    #[test]
    fn blocked_f32_tracks_sequential_oracle() {
        let mut j = job(2, 1, vec![33, 21], 6, 3);
        j.temporal = TemporalMode::Blocked;
        j.dtype = Dtype::F32;
        let init: Vec<f64> = rand_field(15, 33 * 21).iter().map(|&v| v as f32 as f64).collect();
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let want = golden_sequential(&j, &init);
        let got = golden::Field::from_vec(&j.domain, field);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn generic_mode_matches_auto_mode_bitwise() {
        // The dispatch escape hatch must not change a single bit, for
        // both temporal realizations.
        for temporal in [TemporalMode::Sweep, TemporalMode::Blocked] {
            let mut j = job(2, 1, vec![29, 31], 5, 2);
            j.temporal = temporal;
            j.threads = 2;
            let init = rand_field(21, 29 * 31);
            let mut fa = init.clone();
            let ma = NativeBackend::with_mode(KernelMode::Auto).advance(&j, &mut fa).unwrap();
            let mut fg = init.clone();
            let mg = NativeBackend::with_mode(KernelMode::Generic).advance(&j, &mut fg).unwrap();
            assert_eq!(fa, fg, "{temporal:?}");
            assert_eq!(mg.kernel, "generic");
            assert_ne!(ma.kernel, "", "{temporal:?}");
            assert_eq!(ma.interior_points, mg.interior_points);
            assert_eq!(ma.boundary_points, mg.boundary_points);
        }
    }

    #[test]
    fn varcoef_single_step_bit_identical_to_oracle() {
        use crate::model::stencil::Coeffs;
        let mut j = job(2, 1, vec![13, 11], 1, 1);
        j.pattern = j.pattern.with_coeffs(Coeffs::VarCoef);
        let init = rand_field(31, 13 * 11);
        let mut field = init.clone();
        let m = NativeBackend::new().advance(&j, &mut field).unwrap();
        assert_eq!(m.kernel, "generic", "varcoef never resolves a row kernel");
        let w = golden::Weights::new(2, 3, j.weights.clone());
        let want = golden::apply_once_varcoef(&golden::Field::from_vec(&j.domain, init), &w);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn varcoef_blocked_bit_identical_to_sequential_varcoef_oracle() {
        use crate::model::stencil::Coeffs;
        let mut j = job(2, 1, vec![37, 23], 7, 3);
        j.pattern = j.pattern.with_coeffs(Coeffs::VarCoef);
        j.temporal = TemporalMode::Blocked;
        j.threads = 3;
        let init = rand_field(32, 37 * 23);
        let mut field = init.clone();
        NativeBackend::new().advance(&j, &mut field).unwrap();
        let w = golden::Weights::new(2, 3, j.weights.clone());
        let want =
            golden::apply_steps_varcoef(&golden::Field::from_vec(&j.domain, init), &w, j.steps);
        let got = golden::Field::from_vec(&j.domain, field);
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn varcoef_rejects_fused_sweeps() {
        use crate::model::stencil::Coeffs;
        let mut j = job(2, 1, vec![8, 8], 4, 2);
        j.pattern = j.pattern.with_coeffs(Coeffs::VarCoef);
        j.temporal = TemporalMode::Sweep;
        let mut field = rand_field(33, 64);
        assert!(NativeBackend::new().advance(&j, &mut field).is_err());
        // ...but t=1 sweeps and Auto (→ blocked) both run.
        j.t = 1;
        j.steps = 2;
        assert!(NativeBackend::new().advance(&j, &mut field).is_ok());
    }

    #[test]
    fn sparse24_pattern_dispatches_the_pruned_arity() {
        use crate::model::stencil::{Coeffs, Shape, StencilPattern};
        // box-2d1r:sparse24 → 5 live taps → the arity-5 row kernel.
        let p = StencilPattern::new(Shape::Box, 2, 1).unwrap().with_coeffs(Coeffs::Sparse24);
        let j = Job {
            pattern: p,
            dtype: Dtype::F64,
            domain: vec![19, 17],
            steps: 3,
            t: 1,
            temporal: TemporalMode::Sweep,
            weights: p.default_weights(),
            threads: 1,
        };
        let init = rand_field(34, 19 * 17);
        let mut field = init.clone();
        let m = NativeBackend::with_mode(KernelMode::Auto).advance(&j, &mut field).unwrap();
        assert!(m.kernel.starts_with("box-2d1r-sparse24/double/"), "{}", m.kernel);
        // flops account 2·nnz per point with the PRUNED tap count.
        assert_eq!(m.flops, 3 * 2 * 5 * (19 * 17) as u64);
        // and the result is the plain dense oracle over the pruned weights
        let w = golden::Weights::new(2, 3, j.weights.clone());
        let want = golden::apply_steps(&golden::Field::from_vec(&j.domain, init), &w, 3);
        assert_eq!(golden::Field::from_vec(&j.domain, field).max_abs_diff(&want), 0.0);
    }

    #[test]
    fn kernel_cache_reuses_compiled_kernels() {
        let mut be = NativeBackend::new();
        let j = job(2, 1, vec![16, 16], 5, 2);
        let mut field = rand_field(22, 256);
        be.advance(&j, &mut field).unwrap();
        // Sweep steps=5 t=2 → one fused (t=2) + one base (t=1) kernel.
        assert_eq!(be.f64_kernels.0.lock().unwrap().len(), 2);
        be.advance(&j, &mut field).unwrap();
        assert_eq!(be.f64_kernels.0.lock().unwrap().len(), 2);
        // A different fusion depth compiles (and caches) a new kernel.
        let mut j3 = j.clone();
        j3.t = 3;
        be.advance(&j3, &mut field).unwrap();
        assert_eq!(be.f64_kernels.0.lock().unwrap().len(), 3);
    }

    #[test]
    fn resolved_kernel_label_reflects_specialization() {
        // box-2d1r base kernel: 9 taps — registered, so Auto resolves a
        // specialized kernel and says which one.
        let mut j = job(2, 1, vec![16, 16], 2, 1);
        j.temporal = TemporalMode::Sweep;
        let mut field = rand_field(23, 256);
        let m = NativeBackend::with_mode(KernelMode::Auto).advance(&j, &mut field).unwrap();
        assert!(m.kernel.starts_with("box-2d1r/double/"), "{}", m.kernel);
        // box-3d1r fused t=2 has 125 taps — unregistered, generic.
        let mut j125 = job(3, 1, vec![12, 12, 12], 2, 2);
        j125.temporal = TemporalMode::Sweep;
        let mut f3 = rand_field(24, 12 * 12 * 12);
        let m3 = NativeBackend::with_mode(KernelMode::Auto).advance(&j125, &mut f3).unwrap();
        assert_eq!(m3.kernel, "generic");
    }
}

//! Portable unrolled-scalar row kernels.
//!
//! One monomorphized function per registered arity: the tap count is a
//! const generic, so the inner per-point loop fully unrolls into a
//! fixed chain of `acc + w·v` steps over precomputed contiguous
//! segments — a shape LLVM reliably autovectorizes across output
//! points (independent lanes) without reassociating the per-point
//! chain, preserving bit-identity with the oracle.

use super::{RowFn, Scalar};

/// The fixed-arity row body. `segs[j]` is the `j`-th tap's shifted view
/// of `src`, so `out[i] = Σ_j w[j]·segs[j][i]` with the sum evaluated
/// left-to-right from zero — the oracle's exact accumulation order.
#[inline(always)]
fn row_n<T: Scalar, const N: usize>(deltas: &[(isize, T)], src: &[T], center: usize, out: &mut [T]) {
    assert_eq!(deltas.len(), N);
    let len = out.len();
    let w: [T; N] = core::array::from_fn(|j| deltas[j].1);
    let segs: [&[T]; N] =
        core::array::from_fn(|j| &src[(center as isize + deltas[j].0) as usize..][..len]);
    for (i, o) in out.iter_mut().enumerate() {
        let mut acc = T::ZERO;
        for j in 0..N {
            acc = T::mul_acc(acc, w[j], segs[j][i]);
        }
        *o = acc;
    }
}

/// Look up the portable kernel for `arity` taps — registered for
/// exactly the counts in [`super::ARITIES`].
pub(super) fn row<T: Scalar>(arity: usize) -> Option<RowFn<T>> {
    Some(match arity {
        2 => row_n::<T, 2>,
        3 => row_n::<T, 3>,
        4 => row_n::<T, 4>,
        5 => row_n::<T, 5>,
        6 => row_n::<T, 6>,
        7 => row_n::<T, 7>,
        9 => row_n::<T, 9>,
        13 => row_n::<T, 13>,
        14 => row_n::<T, 14>,
        25 => row_n::<T, 25>,
        27 => row_n::<T, 27>,
        41 => row_n::<T, 41>,
        49 => row_n::<T, 49>,
        _ => return None,
    })
}

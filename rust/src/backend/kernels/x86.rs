//! Explicit AVX2 row kernels (x86-64), one per registered arity.
//!
//! 256-bit lanes: 4 × f64 or 8 × f32 output points per iteration.
//! Vectorization is strictly *across output points* — each lane runs
//! the same `acc + w·v` tap chain in deltas order, so results are
//! bit-identical to the scalar reference (no FMA contraction, no
//! reassociation).  The bounds contract is re-checked through safe
//! slice construction before any raw-pointer load.  Callers may only
//! select these kernels after `is_x86_feature_detected!("avx2")`.

use core::arch::x86_64::*;

use super::RowFn;

macro_rules! avx2_rows {
    ($($n:literal => $f64name:ident / $f64wrap:ident, $f32name:ident / $f32wrap:ident;)*) => {
        $(
            #[target_feature(enable = "avx2")]
            unsafe fn $f64name(deltas: &[(isize, f64)], src: &[f64], center: usize, out: &mut [f64]) {
                assert_eq!(deltas.len(), $n);
                let len = out.len();
                let w: [f64; $n] = core::array::from_fn(|j| deltas[j].1);
                let segs: [&[f64]; $n] =
                    core::array::from_fn(|j| &src[(center as isize + deltas[j].0) as usize..][..len]);
                let mut i = 0usize;
                unsafe {
                    let mut wv = [_mm256_setzero_pd(); $n];
                    for (v, &wj) in wv.iter_mut().zip(&w) {
                        *v = _mm256_set1_pd(wj);
                    }
                    while i + 4 <= len {
                        let mut acc = _mm256_setzero_pd();
                        for j in 0..$n {
                            let v = _mm256_loadu_pd(segs[j].as_ptr().add(i));
                            acc = _mm256_add_pd(acc, _mm256_mul_pd(wv[j], v));
                        }
                        _mm256_storeu_pd(out.as_mut_ptr().add(i), acc);
                        i += 4;
                    }
                }
                while i < len {
                    let mut acc = 0.0f64;
                    for j in 0..$n {
                        acc += w[j] * segs[j][i];
                    }
                    out[i] = acc;
                    i += 1;
                }
            }

            #[target_feature(enable = "avx2")]
            unsafe fn $f32name(deltas: &[(isize, f32)], src: &[f32], center: usize, out: &mut [f32]) {
                assert_eq!(deltas.len(), $n);
                let len = out.len();
                let w: [f32; $n] = core::array::from_fn(|j| deltas[j].1);
                let segs: [&[f32]; $n] =
                    core::array::from_fn(|j| &src[(center as isize + deltas[j].0) as usize..][..len]);
                let mut i = 0usize;
                unsafe {
                    let mut wv = [_mm256_setzero_ps(); $n];
                    for (v, &wj) in wv.iter_mut().zip(&w) {
                        *v = _mm256_set1_ps(wj);
                    }
                    while i + 8 <= len {
                        let mut acc = _mm256_setzero_ps();
                        for j in 0..$n {
                            let v = _mm256_loadu_ps(segs[j].as_ptr().add(i));
                            acc = _mm256_add_ps(acc, _mm256_mul_ps(wv[j], v));
                        }
                        _mm256_storeu_ps(out.as_mut_ptr().add(i), acc);
                        i += 8;
                    }
                }
                while i < len {
                    let mut acc = 0.0f32;
                    for j in 0..$n {
                        acc += w[j] * segs[j][i];
                    }
                    out[i] = acc;
                    i += 1;
                }
            }
            fn $f64wrap(deltas: &[(isize, f64)], src: &[f64], center: usize, out: &mut [f64]) {
                // SAFETY: the registry only hands out this kernel after
                // runtime AVX2 detection succeeded on this machine.
                unsafe { $f64name(deltas, src, center, out) }
            }

            fn $f32wrap(deltas: &[(isize, f32)], src: &[f32], center: usize, out: &mut [f32]) {
                // SAFETY: as above — gated on runtime AVX2 detection.
                unsafe { $f32name(deltas, src, center, out) }
            }
        )*

        /// f64 AVX2 kernel for `arity` taps (caller verified AVX2).
        pub(super) fn f64_row(arity: usize) -> Option<RowFn<f64>> {
            Some(match arity {
                $($n => $f64wrap,)*
                _ => return None,
            })
        }

        /// f32 AVX2 kernel for `arity` taps (caller verified AVX2).
        pub(super) fn f32_row(arity: usize) -> Option<RowFn<f32>> {
            Some(match arity {
                $($n => $f32wrap,)*
                _ => return None,
            })
        }
    };
}

avx2_rows! {
    2 => avx2_f64_2 / row_f64_2, avx2_f32_2 / row_f32_2;
    3 => avx2_f64_3 / row_f64_3, avx2_f32_3 / row_f32_3;
    4 => avx2_f64_4 / row_f64_4, avx2_f32_4 / row_f32_4;
    5 => avx2_f64_5 / row_f64_5, avx2_f32_5 / row_f32_5;
    6 => avx2_f64_6 / row_f64_6, avx2_f32_6 / row_f32_6;
    7 => avx2_f64_7 / row_f64_7, avx2_f32_7 / row_f32_7;
    9 => avx2_f64_9 / row_f64_9, avx2_f32_9 / row_f32_9;
    13 => avx2_f64_13 / row_f64_13, avx2_f32_13 / row_f32_13;
    14 => avx2_f64_14 / row_f64_14, avx2_f32_14 / row_f32_14;
    25 => avx2_f64_25 / row_f64_25, avx2_f32_25 / row_f32_25;
    27 => avx2_f64_27 / row_f64_27, avx2_f32_27 / row_f32_27;
    41 => avx2_f64_41 / row_f64_41, avx2_f32_41 / row_f32_41;
    49 => avx2_f64_49 / row_f64_49, avx2_f32_49 / row_f32_49;
}

//! Explicit NEON row kernels (aarch64), one per registered arity.
//!
//! 128-bit lanes: 2 × f64 or 4 × f32 output points per iteration,
//! strictly mirroring the AVX2 kernels' structure — vectorization
//! across output points only, per-point tap chain in deltas order, no
//! FMA — so results are bit-identical to the scalar reference.  NEON
//! is baseline on every aarch64 target std supports, so the kernels
//! are safe functions; only the raw-pointer loads/stores are unsafe.

use core::arch::aarch64::*;

use super::RowFn;

macro_rules! neon_rows {
    ($($n:literal => $f64name:ident, $f32name:ident;)*) => {
        $(
            fn $f64name(deltas: &[(isize, f64)], src: &[f64], center: usize, out: &mut [f64]) {
                assert_eq!(deltas.len(), $n);
                let len = out.len();
                let w: [f64; $n] = core::array::from_fn(|j| deltas[j].1);
                let segs: [&[f64]; $n] =
                    core::array::from_fn(|j| &src[(center as isize + deltas[j].0) as usize..][..len]);
                let mut i = 0usize;
                // SAFETY: every lane read stays inside segs[j] (length-
                // checked above); the store stays inside `out`.
                unsafe {
                    let mut wv = [vdupq_n_f64(0.0); $n];
                    for (v, &wj) in wv.iter_mut().zip(&w) {
                        *v = vdupq_n_f64(wj);
                    }
                    while i + 2 <= len {
                        let mut acc = vdupq_n_f64(0.0);
                        for j in 0..$n {
                            let v = vld1q_f64(segs[j].as_ptr().add(i));
                            acc = vaddq_f64(acc, vmulq_f64(wv[j], v));
                        }
                        vst1q_f64(out.as_mut_ptr().add(i), acc);
                        i += 2;
                    }
                }
                while i < len {
                    let mut acc = 0.0f64;
                    for j in 0..$n {
                        acc += w[j] * segs[j][i];
                    }
                    out[i] = acc;
                    i += 1;
                }
            }

            fn $f32name(deltas: &[(isize, f32)], src: &[f32], center: usize, out: &mut [f32]) {
                assert_eq!(deltas.len(), $n);
                let len = out.len();
                let w: [f32; $n] = core::array::from_fn(|j| deltas[j].1);
                let segs: [&[f32]; $n] =
                    core::array::from_fn(|j| &src[(center as isize + deltas[j].0) as usize..][..len]);
                let mut i = 0usize;
                // SAFETY: as in the f64 kernel — all lane accesses are
                // inside length-checked slices.
                unsafe {
                    let mut wv = [vdupq_n_f32(0.0); $n];
                    for (v, &wj) in wv.iter_mut().zip(&w) {
                        *v = vdupq_n_f32(wj);
                    }
                    while i + 4 <= len {
                        let mut acc = vdupq_n_f32(0.0);
                        for j in 0..$n {
                            let v = vld1q_f32(segs[j].as_ptr().add(i));
                            acc = vaddq_f32(acc, vmulq_f32(wv[j], v));
                        }
                        vst1q_f32(out.as_mut_ptr().add(i), acc);
                        i += 4;
                    }
                }
                while i < len {
                    let mut acc = 0.0f32;
                    for j in 0..$n {
                        acc += w[j] * segs[j][i];
                    }
                    out[i] = acc;
                    i += 1;
                }
            }
        )*

        /// f64 NEON kernel for `arity` taps.
        pub(super) fn f64_row(arity: usize) -> Option<RowFn<f64>> {
            Some(match arity {
                $($n => $f64name,)*
                _ => return None,
            })
        }

        /// f32 NEON kernel for `arity` taps.
        pub(super) fn f32_row(arity: usize) -> Option<RowFn<f32>> {
            Some(match arity {
                $($n => $f32name,)*
                _ => return None,
            })
        }
    };
}

neon_rows! {
    2 => neon_f64_2, neon_f32_2;
    3 => neon_f64_3, neon_f32_3;
    4 => neon_f64_4, neon_f32_4;
    5 => neon_f64_5, neon_f32_5;
    6 => neon_f64_6, neon_f32_6;
    7 => neon_f64_7, neon_f32_7;
    9 => neon_f64_9, neon_f32_9;
    13 => neon_f64_13, neon_f32_13;
    14 => neon_f64_14, neon_f32_14;
    25 => neon_f64_25, neon_f32_25;
    27 => neon_f64_27, neon_f32_27;
    41 => neon_f64_41, neon_f32_41;
    49 => neon_f64_49, neon_f32_49;
}

//! Shape-specialized, vectorized row kernels with runtime ISA dispatch.
//!
//! The paper's suitability criteria compare every Tensor-Core engine
//! against the per-unit peak ℙ of the scalar baseline — a comparison
//! that is only meaningful when the baseline actually runs near its
//! vector peak ("Can Tensor Cores Benefit Memory-Bound Kernels?
//! (No!)").  The generic executor in [`crate::backend::native`] walks a
//! runtime offset list per output point; this module replaces its
//! interior fast path with **monomorphized row kernels**: one function
//! per tap count (the hot shapes star-1/2/3D and box-2/3D, their
//! radius-2/3 variants, and the fused sweeps whose support lands on the
//! same arities), unrolled at compile time and — on x86-64 with AVX2 /
//! AVX-512 and on aarch64 with NEON — written directly in `std::arch`
//! SIMD intrinsics behind runtime feature detection.  A portable
//! unrolled-scalar fallback (guaranteed to autovectorize: fixed-arity
//! inner loop over precomputed contiguous segments) covers every other
//! machine.
//!
//! **Bit-identity invariant.**  Every kernel accumulates each output
//! point in exactly the oracle's order (`golden::Weights::offsets` —
//! hull row-major, zero weights skipped, starting from `0.0`): the SIMD
//! variants vectorize *across output points* (independent lanes), never
//! across taps, so the per-point addition chain is unchanged and f64
//! results stay bit-identical to `golden::apply_once` and to the
//! generic loop.  `--kernels generic` (or `STENCILCTL_KERNELS=generic`)
//! disables dispatch entirely and reproduces the pre-specialization
//! executor exactly.
//!
//! The registry resolves once per compiled kernel: tap count × dtype ×
//! detected [`Isa`] → fn pointer, generic loop as the universal
//! fallback.  The tune plane closes the loop: `tune::micro` probes each
//! specialized kernel and stores per-(shape, dtype, temporal) measured
//! ℙ entries ([`KernelPeak`]) in the machine profile, which the planner
//! consumes via [`peak_for`] so sweep/blocked/shard crossovers are
//! priced against the kernel that will actually run.

use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{bail, Result};

use crate::model::perf::Dtype;
use crate::model::stencil::StencilPattern;

mod portable;
#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

/// A specialized interior row kernel: `out[i] = Σ_j w_j ·
/// src[center + i + d_j]` for `i in 0..out.len()`, accumulating taps in
/// the given order per point.  The caller guarantees every read is in
/// bounds (the interior-window contract of the native executor's fast
/// path); kernels re-check it through safe slice construction.
pub(crate) type RowFn<T> = fn(deltas: &[(isize, T)], src: &[T], center: usize, out: &mut [T]);

/// Element type the engine is instantiated at (f32 mirrors artifact
/// precision, f64 mirrors the oracle).
pub(crate) trait Scalar: Copy + Send + Sync + 'static {
    /// Additive identity — the accumulation chain starts here, exactly
    /// like the oracle's.
    const ZERO: Self;
    /// Convert an f64 weight/field value into this precision.
    fn from_f64(v: f64) -> Self;
    /// One accumulation step: `acc + w·v` (never fused — FMA would
    /// change rounding and break bit-identity with the oracle).
    fn mul_acc(acc: Self, w: Self, v: Self) -> Self;
    /// Plain product in this precision — used by the variable-coefficient
    /// path to form the effective weight `w·m` *before* the tap's
    /// multiply-accumulate, exactly as the oracle does.
    fn mul(a: Self, b: Self) -> Self;
    /// The specialized row kernel for `arity` taps on `isa`, if one is
    /// registered (ISA-specific first, portable unrolled fallback).
    fn specialized(arity: usize, isa: Isa) -> Option<RowFn<Self>>;
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    fn from_f64(v: f64) -> Self {
        v
    }
    fn mul_acc(acc: Self, w: Self, v: Self) -> Self {
        acc + w * v
    }
    fn mul(a: Self, b: Self) -> Self {
        a * b
    }
    fn specialized(arity: usize, isa: Isa) -> Option<RowFn<Self>> {
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 | Isa::Avx512 => {
                x86::f64_row(arity).or_else(|| portable::row::<f64>(arity))
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => neon::f64_row(arity).or_else(|| portable::row::<f64>(arity)),
            _ => portable::row::<f64>(arity),
        }
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    fn mul_acc(acc: Self, w: Self, v: Self) -> Self {
        acc + w * v
    }
    fn mul(a: Self, b: Self) -> Self {
        a * b
    }
    fn specialized(arity: usize, isa: Isa) -> Option<RowFn<Self>> {
        match isa {
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 | Isa::Avx512 => {
                x86::f32_row(arity).or_else(|| portable::row::<f32>(arity))
            }
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => neon::f32_row(arity).or_else(|| portable::row::<f32>(arity)),
            _ => portable::row::<f32>(arity),
        }
    }
}

/// Tap counts with a registered specialized kernel: the base hot shapes
/// (star-1/2/3D: 3/5/7, box-2D: 9, box-3D: 27), their radius-2/3
/// variants (star-2D2R: 9, star-2D3R / star-3D2R: 13, box-2D2R: 25,
/// box-2D3R: 49), the fused-sweep supports that land on the same
/// counts (box-2D1R t=2/3 → 25/49, star-2D1R t=2/3 → 13/25, star-3D1R
/// t=2 → 25, star-1D1R any t ≤ 4, star-2D1R t=4 → 41), and the
/// 2:4-pruned tap family (star-1/2/3D1R → 2/4/6, box-2D1R → 5,
/// box-3D1R → 14, box-2D2R → 13).
pub const ARITIES: [usize; 13] = [2, 3, 4, 5, 6, 7, 9, 13, 14, 25, 27, 41, 49];

/// The instruction set a kernel was compiled/selected for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// x86-64 AVX-512 (512-bit); runs the 256-bit `std::arch` kernels —
    /// explicit 512-bit intrinsics need a newer toolchain than our MSRV,
    /// and LLVM prefers 256-bit lanes on most AVX-512 parts anyway —
    /// but detection still reports the tier so profiles stay honest.
    Avx512,
    /// x86-64 AVX2: explicit 256-bit `std::arch` intrinsics.
    Avx2,
    /// aarch64 NEON: explicit 128-bit `std::arch` intrinsics.
    Neon,
    /// Portable unrolled-scalar kernels (compiler-autovectorized).
    Portable,
}

impl Isa {
    /// Runtime detection of the best available tier on this machine.
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx2") {
                return Isa::Avx512;
            }
            if is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Isa::Neon;
            }
        }
        Isa::Portable
    }

    /// Stable lowercase name (profiles, stats, kernel labels).
    pub fn as_str(&self) -> &'static str {
        match self {
            Isa::Avx512 => "avx512",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
            Isa::Portable => "portable",
        }
    }
}

/// How the executor resolves row kernels (`--kernels auto|generic`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// Dispatch to the specialized kernel registry (generic loop only
    /// when no arity matches) — the default.
    Auto,
    /// Escape hatch: always run the generic offset-list loop, exactly
    /// reproducing the pre-specialization executor (planning included).
    Generic,
}

impl KernelMode {
    /// Parse a `--kernels` / `STENCILCTL_KERNELS` value.
    pub fn parse(s: &str) -> Result<KernelMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelMode::Auto),
            "generic" => Ok(KernelMode::Generic),
            other => bail!("unknown kernel mode {other:?} (want auto|generic)"),
        }
    }

    /// The stable CLI name.
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelMode::Auto => "auto",
            KernelMode::Generic => "generic",
        }
    }
}

/// Process-wide default mode override (0 = unset, 1 = auto, 2 = generic)
/// — set once by the CLI from `--kernels`; the env var covers harnesses
/// (CI runs the tier-1 suite under `STENCILCTL_KERNELS=generic`).
static MODE_OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Install the process default (the CLI's `--kernels`).  Backends built
/// afterwards via [`crate::backend::NativeBackend::new`] inherit it.
pub fn set_default_mode(mode: KernelMode) {
    let v = match mode {
        KernelMode::Auto => 1,
        KernelMode::Generic => 2,
    };
    MODE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// The process default kernel mode: the CLI override if set, else the
/// `STENCILCTL_KERNELS` environment variable, else [`KernelMode::Auto`].
pub fn default_mode() -> KernelMode {
    match MODE_OVERRIDE.load(Ordering::Relaxed) {
        1 => KernelMode::Auto,
        2 => KernelMode::Generic,
        _ => match std::env::var("STENCILCTL_KERNELS") {
            Ok(v) if v.eq_ignore_ascii_case("generic") => KernelMode::Generic,
            _ => KernelMode::Auto,
        },
    }
}

/// Resolve the specialized row kernel for a compiled kernel with
/// `arity` non-zero taps, honoring the mode; `None` = generic loop.
pub(crate) fn resolve<T: Scalar>(arity: usize, mode: KernelMode, isa: Isa) -> Option<RowFn<T>> {
    match mode {
        KernelMode::Generic => None,
        KernelMode::Auto => T::specialized(arity, isa),
    }
}

/// The stable per-shape key used by profiles and kernel labels:
/// `"{shape}-{d}d{r}r"`, e.g. `"box-2d1r"`; non-constant coefficient
/// variants carry a suffix (e.g. `"box-2d1r-sparse24"`) so their probed
/// peaks and labels never alias the dense kernel's.
pub fn shape_key(pattern: &StencilPattern) -> String {
    use crate::model::stencil::Coeffs;
    match pattern.coeffs {
        Coeffs::Const => {
            format!("{}-{}d{}r", pattern.shape.as_str(), pattern.d, pattern.r)
        }
        c => format!("{}-{}d{}r-{}", pattern.shape.as_str(), pattern.d, pattern.r, c.as_str()),
    }
}

/// The resolved kernel name surfaced in metrics, advance replies and
/// service stats: `"{shape}/{dtype}/{isa}"` when a specialized kernel
/// will run the interior, `"generic"` otherwise.
pub fn label(pattern: &StencilPattern, dtype: Dtype, specialized: bool) -> String {
    if specialized {
        format!("{}/{}/{}", shape_key(pattern), dtype.as_str(), Isa::detect().as_str())
    } else {
        "generic".to_string()
    }
}

/// One measured per-kernel peak: the ℙ entry of Eq. 4/5 for the
/// specialized kernel that actually executes a (shape, dtype, temporal
/// realization) triple — probed by `tune::micro`, carried by
/// `tune::profile::MachineProfile`, consumed by the planner.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelPeak {
    /// Shape key as produced by [`shape_key`] (e.g. `"star-2d1r"`).
    pub shape: String,
    /// Element type the probe ran at.
    pub dtype: Dtype,
    /// `true` — probed through the temporal-blocked trapezoid path;
    /// `false` — plain fused-sweep interior.
    pub blocked: bool,
    /// Measured FLOP/s (instrumented flops over execute time).
    pub flops: f64,
}

/// Look up the measured per-kernel ℙ for a (pattern, dtype, temporal
/// realization), if the profile carries one.
pub fn peak_for(
    peaks: &[KernelPeak],
    pattern: &StencilPattern,
    dtype: Dtype,
    blocked: bool,
) -> Option<f64> {
    let key = shape_key(pattern);
    peaks
        .iter()
        .find(|p| p.shape == key && p.dtype == dtype && p.blocked == blocked)
        .map(|p| p.flops)
}

/// The canonical probe set for `tune::micro`: every shape with a
/// registered base-kernel specialization — star-1/2/3D and box-2/3D at
/// radius 1 — plus their 2:4-pruned variants (arities 4/5/14), so the
/// planner prices sparse candidates against the pruned kernel that will
/// actually run.
pub fn probe_shapes() -> Vec<StencilPattern> {
    use crate::model::stencil::{Coeffs, Shape};
    vec![
        StencilPattern::new(Shape::Star, 1, 1).unwrap(),
        StencilPattern::new(Shape::Star, 2, 1).unwrap(),
        StencilPattern::new(Shape::Star, 3, 1).unwrap(),
        StencilPattern::new(Shape::Box, 2, 1).unwrap(),
        StencilPattern::new(Shape::Box, 3, 1).unwrap(),
        StencilPattern::new(Shape::Star, 2, 1).unwrap().with_coeffs(Coeffs::Sparse24),
        StencilPattern::new(Shape::Box, 2, 1).unwrap().with_coeffs(Coeffs::Sparse24),
        StencilPattern::new(Shape::Box, 3, 1).unwrap().with_coeffs(Coeffs::Sparse24),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Reference: the exact per-point accumulation chain of the oracle
    /// and the generic loop.
    fn reference<T: Scalar>(deltas: &[(isize, T)], src: &[T], center: usize, out: &mut [T]) {
        for (i, o) in out.iter_mut().enumerate() {
            let mut acc = T::ZERO;
            for &(d, w) in deltas {
                acc = T::mul_acc(acc, w, src[(center as isize + i as isize + d) as usize]);
            }
            *o = acc;
        }
    }

    fn synth_deltas<T: Scalar>(rng: &mut Rng, arity: usize) -> Vec<(isize, T)> {
        // Distinct spread-out taps resembling a 2-D row context.
        (0..arity)
            .map(|j| ((j as isize - arity as isize / 2) * 11, T::from_f64(rng.normal())))
            .collect()
    }

    fn check_dtype<T: Scalar + PartialEq + std::fmt::Debug>(seed: u64) {
        let mut rng = Rng::new(seed);
        for &arity in &ARITIES {
            let len = 237; // odd: exercises every SIMD tail
            let pad = 11 * (arity + 1);
            let src: Vec<T> =
                (0..len + 2 * pad).map(|_| T::from_f64(rng.normal())).collect();
            let center = pad;
            let deltas = synth_deltas::<T>(&mut rng, arity);
            let mut want = vec![T::ZERO; len];
            reference(&deltas, &src, center, &mut want);
            for isa in [Isa::detect(), Isa::Portable] {
                let row = T::specialized(arity, isa)
                    .unwrap_or_else(|| panic!("no kernel for arity {arity} on {isa:?}"));
                let mut got = vec![T::ZERO; len];
                row(&deltas, &src, center, &mut got);
                assert_eq!(got, want, "arity={arity} isa={isa:?}");
            }
        }
    }

    #[test]
    fn every_registered_arity_is_bit_identical_to_the_reference_f64() {
        check_dtype::<f64>(41);
    }

    #[test]
    fn every_registered_arity_is_bit_identical_to_the_reference_f32() {
        check_dtype::<f32>(43);
    }

    #[test]
    fn unregistered_arities_resolve_to_the_generic_loop() {
        assert!(<f64 as Scalar>::specialized(125, Isa::detect()).is_none());
        assert!(resolve::<f64>(9, KernelMode::Generic, Isa::detect()).is_none());
        assert!(resolve::<f64>(9, KernelMode::Auto, Isa::Portable).is_some());
    }

    #[test]
    fn mode_parsing_and_labels() {
        assert_eq!(KernelMode::parse("AUTO").unwrap(), KernelMode::Auto);
        assert_eq!(KernelMode::parse("generic").unwrap(), KernelMode::Generic);
        assert!(KernelMode::parse("simd").is_err());
        let p = crate::model::stencil::StencilPattern::new(
            crate::model::stencil::Shape::Box,
            2,
            1,
        )
        .unwrap();
        assert_eq!(shape_key(&p), "box-2d1r");
        assert_eq!(label(&p, Dtype::F64, false), "generic");
        let l = label(&p, Dtype::F64, true);
        assert!(l.starts_with("box-2d1r/double/"), "{l}");
    }

    #[test]
    fn coeff_variants_get_distinct_shape_keys() {
        use crate::model::stencil::{Coeffs, Shape, StencilPattern};
        let p = StencilPattern::new(Shape::Box, 2, 1).unwrap();
        assert_eq!(shape_key(&p.with_coeffs(Coeffs::Sparse24)), "box-2d1r-sparse24");
        assert_eq!(shape_key(&p.with_coeffs(Coeffs::VarCoef)), "box-2d1r-varcoef");
        let l = label(&p.with_coeffs(Coeffs::Sparse24), Dtype::F32, true);
        assert!(l.starts_with("box-2d1r-sparse24/float/"), "{l}");
        // peaks probed for the sparse variant never alias the dense one
        let peaks = vec![KernelPeak {
            shape: "box-2d1r-sparse24".into(),
            dtype: Dtype::F32,
            blocked: false,
            flops: 5e9,
        }];
        assert_eq!(peak_for(&peaks, &p.with_coeffs(Coeffs::Sparse24), Dtype::F32, false), Some(5e9));
        assert_eq!(peak_for(&peaks, &p, Dtype::F32, false), None);
    }

    #[test]
    fn pruned_tap_arities_are_registered() {
        // 2:4-pruned supports: star-1/2/3D1R → 2/4/6, box-3D1R → 14
        // (box-2D1R → 5 and box-2D2R → 13 were already dense arities).
        for arity in [2usize, 4, 5, 6, 13, 14] {
            assert!(ARITIES.contains(&arity), "arity {arity} missing");
            assert!(<f64 as Scalar>::specialized(arity, Isa::Portable).is_some());
            assert!(<f32 as Scalar>::specialized(arity, Isa::Portable).is_some());
        }
    }

    #[test]
    fn peak_lookup_matches_on_the_full_triple() {
        let p = probe_shapes();
        let peaks = vec![
            KernelPeak { shape: shape_key(&p[3]), dtype: Dtype::F64, blocked: false, flops: 1e9 },
            KernelPeak { shape: shape_key(&p[3]), dtype: Dtype::F64, blocked: true, flops: 2e9 },
        ];
        assert_eq!(peak_for(&peaks, &p[3], Dtype::F64, false), Some(1e9));
        assert_eq!(peak_for(&peaks, &p[3], Dtype::F64, true), Some(2e9));
        assert_eq!(peak_for(&peaks, &p[3], Dtype::F32, false), None);
        assert_eq!(peak_for(&peaks, &p[0], Dtype::F64, false), None);
    }
}

//! The unified execution layer: one [`Backend`] trait between the planner
//! and every substrate that can actually advance a stencil field.
//!
//! The paper's planner (§4) decides *where* a stencil should run; this
//! module decides *how* it runs once decided.  Two backends exist:
//!
//! * [`NativeBackend`] — a tiled, halo-split, double-buffered,
//!   multi-threaded CPU engine.  Executes ANY `(pattern, dtype, t)`
//!   combination, bit-identical (f64) to the golden oracle.
//! * [`PjrtBackend`] — the pre-built AOT artifacts through the PJRT
//!   runtime (requires the `pjrt` cargo feature and a manifest).
//!
//! A [`Job`] is backend-agnostic; [`Backend::supports`] is the
//! capability probe the scheduler/planner use to pick a substrate, and
//! [`Backend::advance`] runs it, returning phase-split [`RunMetrics`].
//!
//! A job also carries a [`TemporalMode`]: *how* its fusion depth `t` is
//! realized.  [`TemporalMode::Sweep`] launches the t-fold fused kernel
//! once per `t` steps (Tensor-Core semantics, what the AOT artifacts
//! execute); [`TemporalMode::Blocked`] carries `t` base-kernel steps
//! through a cache-resident tile (true temporal blocking — the paper's
//! CUDA-Core Eq. 8 intensity `t·K/D`, bit-identical to sequential time
//! stepping).  The two differ numerically within `t·r` of the domain
//! boundary (fused kernels see the initial zero halo once; sequential
//! stepping re-applies it each step), so the mode is part of the job's
//! identity, never a silent backend choice.

#![warn(missing_docs)]

pub mod kernels;
pub mod native;
pub mod pjrt;

pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::metrics::RunMetrics;
use crate::model::perf::Dtype;
use crate::model::sparsity::Scheme;
use crate::model::stencil::StencilPattern;

/// How a job's fusion depth `t` is realized by the executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TemporalMode {
    /// Let the resolver pick: the planner scores sweep vs. blocked with
    /// the model's fused-intensity equations; a backend receiving an
    /// unresolved `Auto` runs blocked whenever `t > 1`.
    Auto,
    /// `steps / t` monolithic fused-kernel launches (each applying the
    /// t-fold self-convolved kernel once — Tensor-Core semantics),
    /// followed by `steps % t` single base-kernel steps.
    Sweep,
    /// Time-tiled temporal blocking: `t` base-kernel steps carried
    /// through each cache-resident tile per pass over the domain.
    /// Numerically identical to plain sequential stepping (f64
    /// bit-identical to chained [`crate::sim::golden::apply_once`]).
    Blocked,
}

impl TemporalMode {
    /// Parse a `--temporal` / protocol value.
    pub fn parse(s: &str) -> Result<TemporalMode> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(TemporalMode::Auto),
            "sweep" => Ok(TemporalMode::Sweep),
            "blocked" => Ok(TemporalMode::Blocked),
            other => bail!("unknown temporal mode {other:?} (want auto|sweep|blocked)"),
        }
    }

    /// The stable wire/CLI name.
    pub fn as_str(&self) -> &'static str {
        match self {
            TemporalMode::Auto => "auto",
            TemporalMode::Sweep => "sweep",
            TemporalMode::Blocked => "blocked",
        }
    }
}

/// One executable stencil job, independent of where it runs.
///
/// Default semantics ([`TemporalMode::Sweep`]): `steps / t` monolithic
/// fused launches (each applying the t-fold self-convolved kernel once —
/// Tensor-Core semantics), followed by `steps % t` single base-kernel
/// steps.  With `t == 1` this is plain sequential time stepping.
/// [`TemporalMode::Blocked`] instead advances `steps` sequential
/// base-kernel steps, grouped into cache-resident time tiles of depth
/// `t`.
#[derive(Debug, Clone)]
pub struct Job {
    /// Stencil pattern (shape, dimensionality, radius).
    pub pattern: StencilPattern,
    /// Element type the kernel arithmetic runs at.
    pub dtype: Dtype,
    /// Domain extents N^d (any size ≥ 1 per dim); rank must equal
    /// `pattern.d`.
    pub domain: Vec<usize>,
    /// Total time steps to advance.
    pub steps: usize,
    /// Fusion depth per launch / temporal-tile depth (t ≥ 1).
    pub t: usize,
    /// How `t` is realized (fused sweeps vs. temporal blocking).
    pub temporal: TemporalMode,
    /// Base stencil weights over the (2r+1)^d hull (row-major).
    pub weights: Vec<f64>,
    /// Worker threads (1 = serial).
    pub threads: usize,
}

impl Job {
    /// Structural validation shared by all backends.
    pub fn validate(&self, field_len: usize) -> Result<()> {
        if self.domain.len() != self.pattern.d {
            bail!(
                "domain rank {} != pattern dimensionality {}",
                self.domain.len(),
                self.pattern.d
            );
        }
        if self.domain.iter().any(|&n| n == 0) {
            bail!("domain extents must be positive");
        }
        let want: usize = self.domain.iter().product();
        if field_len != want {
            bail!("field has {field_len} elements, domain wants {want}");
        }
        let side = 2 * self.pattern.r + 1;
        if self.weights.len() != side.pow(self.pattern.d as u32) {
            bail!(
                "weights length {} != hull size {}",
                self.weights.len(),
                side.pow(self.pattern.d as u32)
            );
        }
        if self.t == 0 {
            bail!("fusion depth t must be >= 1");
        }
        if self.pattern.coeffs == crate::model::stencil::Coeffs::VarCoef {
            // The per-point modulation does not commute with kernel
            // self-convolution, so fused sweeps above depth 1 have no
            // well-defined variable-coefficient semantics.  Blocked (and
            // Auto, which resolves blocked for t > 1) runs base steps
            // sequentially and stays exact at any depth.
            if self.temporal == TemporalMode::Sweep && self.t > 1 {
                bail!("variable-coefficient jobs cannot run fused sweeps with t > 1 (use blocked)");
            }
        }
        Ok(())
    }

    /// Total domain points.
    pub fn points(&self) -> u64 {
        self.domain.iter().map(|&n| n as u64).product()
    }
}

/// One synchronization phase of a sharded execution.
///
/// A sharded job advances in barrier-separated phases: within a phase
/// every shard computes only its disjoint write-back slab from the
/// shared phase-start field, so shards never depend on each other
/// mid-phase and the barrier IS the halo exchange.  The phase schedule
/// ([`shard_phases`]) mirrors the monolithic executor exactly, which
/// is what makes sharded f64 results bit-identical to the unsharded
/// path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardPhase {
    /// Time steps this phase carries (≤ the job's `t`).
    pub depth: usize,
    /// `true` — one launch of the `depth`-fold self-convolved kernel
    /// (sweep semantics); `false` — `depth` sequential base-kernel
    /// steps through a trapezoid (blocked semantics, with the
    /// `depth·r` halo recompute).  At `depth == 1` the two coincide.
    pub fused: bool,
}

/// The barrier-phase schedule of a job, resolved over its temporal
/// mode: sweep → `steps/t` fused launches plus `steps%t` base
/// launches; blocked → time blocks of depth ≤ `t` (an unresolved
/// `Auto` blocks whenever `t > 1`, matching
/// [`NativeBackend::advance`]).  The schedule itself is
/// [`crate::model::shard::phase_schedule`] — one source of truth for
/// the executor and the model's intensity prediction.
pub fn shard_phases(job: &Job) -> Vec<ShardPhase> {
    let blocked = match job.temporal {
        TemporalMode::Sweep => false,
        TemporalMode::Blocked => true,
        TemporalMode::Auto => job.t > 1,
    };
    crate::model::shard::phase_schedule(job.steps, job.t, blocked)
        .into_iter()
        .map(|(depth, fused)| ShardPhase { depth, fused })
        .collect()
}

/// An execution substrate for stencil jobs.
pub trait Backend {
    /// Short stable name ("native", "pjrt") for logs and metrics.
    fn name(&self) -> &'static str;

    /// Capability probe: `Ok(())` iff [`Backend::advance`] can execute
    /// this job; `Err` carries the human-readable reason it cannot.
    fn supports(&self, job: &Job) -> Result<(), String>;

    /// Advance `field` (row-major f64 host representation) by
    /// `job.steps` time steps, double-buffered internally.
    fn advance(&mut self, job: &Job, field: &mut Vec<f64>) -> Result<RunMetrics>;
}

/// CLI-selectable backend kind (`--backend auto|native|pjrt`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Prefer a matching AOT artifact on PJRT, fall back to native.
    Auto,
    /// Force the native CPU engine (any pattern/dtype/t runs).
    Native,
    /// Require a pre-built AOT artifact through the PJRT runtime.
    Pjrt,
}

impl BackendKind {
    /// Parse a `--backend` / protocol value.
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(BackendKind::Auto),
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => bail!("unknown backend {other:?} (want auto|native|pjrt)"),
        }
    }

    /// The stable wire/CLI name.
    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Resolve a kind into a concrete backend able to run `job`.
///
/// `prefer` restricts PJRT artifact lookup to one compilation scheme
/// (used when the CLI forces an engine); the native backend ignores it.
pub fn create(
    kind: BackendKind,
    artifacts_dir: &Path,
    job: &Job,
    prefer: Option<Scheme>,
) -> Result<Box<dyn Backend>> {
    match kind {
        BackendKind::Native => {
            let native = NativeBackend::new();
            native
                .supports(job)
                .map_err(|why| anyhow!("native backend cannot run this job: {why}"))?;
            Ok(Box::new(native))
        }
        BackendKind::Pjrt => {
            let mut b = PjrtBackend::load(artifacts_dir)?;
            b.prefer_scheme(prefer);
            b.supports(job)
                .map_err(|why| anyhow!("pjrt backend cannot run this job: {why}"))?;
            Ok(Box::new(b))
        }
        BackendKind::Auto => {
            if let Ok(mut b) = PjrtBackend::load(artifacts_dir) {
                b.prefer_scheme(prefer);
                if b.supports(job).is_ok() {
                    return Ok(Box::new(b));
                }
            }
            let native = NativeBackend::new();
            native
                .supports(job)
                .map_err(|why| anyhow!("no backend can run this job: {why}"))?;
            Ok(Box::new(native))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::stencil::Shape;

    fn job() -> Job {
        Job {
            pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
            dtype: Dtype::F64,
            domain: vec![8, 8],
            steps: 4,
            t: 2,
            temporal: TemporalMode::Sweep,
            weights: vec![1.0 / 9.0; 9],
            threads: 1,
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in [BackendKind::Auto, BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.as_str()).unwrap(), k);
        }
        assert_eq!(BackendKind::parse("NATIVE").unwrap(), BackendKind::Native);
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn temporal_parse_roundtrip() {
        for m in [TemporalMode::Auto, TemporalMode::Sweep, TemporalMode::Blocked] {
            assert_eq!(TemporalMode::parse(m.as_str()).unwrap(), m);
        }
        assert_eq!(TemporalMode::parse("BLOCKED").unwrap(), TemporalMode::Blocked);
        assert!(TemporalMode::parse("fused").is_err());
    }

    #[test]
    fn job_validation_catches_shape_errors() {
        let j = job();
        assert!(j.validate(64).is_ok());
        assert!(j.validate(63).is_err()); // field length
        let mut bad = job();
        bad.domain = vec![8, 8, 8]; // rank mismatch
        assert!(bad.validate(512).is_err());
        let mut bad = job();
        bad.weights = vec![0.0; 4]; // hull size
        assert!(bad.validate(64).is_err());
        let mut bad = job();
        bad.t = 0;
        assert!(bad.validate(64).is_err());
        let mut bad = job();
        bad.domain = vec![8, 0];
        assert!(bad.validate(0).is_err());
        // varcoef: fused sweeps above depth 1 are structurally invalid;
        // blocked (and t=1 sweep) stay legal.
        let mut vc = job();
        vc.pattern = vc.pattern.with_coeffs(crate::model::stencil::Coeffs::VarCoef);
        assert!(vc.validate(64).is_err());
        vc.temporal = TemporalMode::Blocked;
        assert!(vc.validate(64).is_ok());
        vc.temporal = TemporalMode::Sweep;
        vc.t = 1;
        assert!(vc.validate(64).is_ok());
    }

    #[test]
    fn shard_phase_schedule_mirrors_the_executor() {
        // sweep: steps=7, t=3 → two fused t=3 launches + one base step.
        let mut j = job();
        j.steps = 7;
        j.t = 3;
        assert_eq!(
            shard_phases(&j),
            vec![
                ShardPhase { depth: 3, fused: true },
                ShardPhase { depth: 3, fused: true },
                ShardPhase { depth: 1, fused: true },
            ]
        );
        // blocked: 7 steps at depth 3 → blocks of 3, 3, 1.
        j.temporal = TemporalMode::Blocked;
        assert_eq!(
            shard_phases(&j),
            vec![
                ShardPhase { depth: 3, fused: false },
                ShardPhase { depth: 3, fused: false },
                ShardPhase { depth: 1, fused: false },
            ]
        );
        // Auto resolves blocked above t=1, sweep at t=1.
        j.temporal = TemporalMode::Auto;
        assert!(shard_phases(&j).iter().all(|p| !p.fused));
        j.t = 1;
        assert!(shard_phases(&j).iter().all(|p| p.fused && p.depth == 1));
        // zero steps → no phases
        j.steps = 0;
        assert!(shard_phases(&j).is_empty());
    }

    #[test]
    fn create_native_works_without_artifacts() {
        let dir = std::path::PathBuf::from("/nonexistent-artifacts");
        let b = create(BackendKind::Native, &dir, &job(), None).unwrap();
        assert_eq!(b.name(), "native");
        // Auto must fall back to native when no manifest exists.
        let b = create(BackendKind::Auto, &dir, &job(), None).unwrap();
        assert_eq!(b.name(), "native");
        // Pjrt without artifacts is an error.
        assert!(create(BackendKind::Pjrt, &dir, &job(), None).is_err());
    }
}

//! The PJRT backend: [`Backend`] over the AOT artifact runtime.
//!
//! Capability = "a manifest artifact exists for exactly this
//! (shape, d, r, t, dtype) with n_outer == 1, and the requested step
//! count divides into whole launches".  Execution delegates to the
//! tiled halo-exchange driver in [`crate::coordinator::scheduler`],
//! which decomposes arbitrary domains onto the artifact's fixed grid.
//!
//! Built without the `pjrt` cargo feature, loading still succeeds when
//! a manifest is present (planning/listing work) but `supports` reports
//! the substrate unavailable, so `--backend auto` falls through to the
//! native engine instead of failing at execute time.

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::backend::{Backend, Job, TemporalMode};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::scheduler;
use crate::model::sparsity::Scheme;
use crate::runtime::manifest::ArtifactMeta;
use crate::runtime::Runtime;

/// Backend over the PJRT runtime + artifact manifest.
pub struct PjrtBackend {
    rt: Runtime,
    prefer: Option<Scheme>,
}

impl PjrtBackend {
    /// Load the manifest (and, with the `pjrt` feature, the CPU client).
    pub fn load(artifacts_dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend { rt: Runtime::load(artifacts_dir)?, prefer: None })
    }

    /// Restrict artifact lookup to one compilation scheme (forced
    /// engine); `None` accepts any scheme.
    pub fn prefer_scheme(&mut self, scheme: Option<Scheme>) {
        self.prefer = scheme;
    }

    /// The underlying artifact runtime (manifest access).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// The artifact that would serve `job`, if any.
    pub fn find_artifact(&self, job: &Job) -> Option<&ArtifactMeta> {
        self.rt.manifest.variants.iter().find(|v| {
            v.shape == job.pattern.shape
                && v.d == job.pattern.d
                && v.r == job.pattern.r
                && v.t == job.t
                && v.dtype == job.dtype
                && v.n_outer == 1
                && self.prefer.map_or(true, |s| v.scheme == s)
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn supports(&self, job: &Job) -> Result<(), String> {
        if let Err(e) = job.validate(job.points() as usize) {
            return Err(format!("{e:#}"));
        }
        // AOT artifacts are monolithic fused launches; there is no
        // time-tiled execution path through PJRT (auto resolves to the
        // sweep it can run, an explicit blocked request cannot).
        if job.temporal == TemporalMode::Blocked {
            return Err("pjrt executes fused-kernel sweeps only (temporal=blocked \
                        needs the native backend)"
                .to_string());
        }
        let Some(meta) = self.find_artifact(job) else {
            return Err(format!(
                "no AOT artifact for {} t={} {}{}",
                job.pattern.label(),
                job.t,
                job.dtype.as_str(),
                self.prefer
                    .map(|s| format!(" scheme={}", s.as_str()))
                    .unwrap_or_default(),
            ));
        };
        let spe = meta.steps_per_exec();
        if job.steps % spe != 0 {
            return Err(format!(
                "steps {} not a multiple of artifact steps-per-exec {spe} ({})",
                job.steps, meta.name
            ));
        }
        // Last: a matching artifact is useless if this build cannot
        // execute it — auto mode then falls through to native.
        if !Runtime::available() {
            return Err("built without the `pjrt` feature".to_string());
        }
        Ok(())
    }

    fn advance(&mut self, job: &Job, field: &mut Vec<f64>) -> Result<RunMetrics> {
        self.supports(job).map_err(|why| anyhow!("pjrt backend: {why}"))?;
        let meta = self.find_artifact(job).expect("checked by supports").clone();
        let sj = scheduler::Job {
            artifact: meta.name.clone(),
            domain: job.domain.clone(),
            steps: job.steps,
            weights: job.weights.clone(),
            threads: job.threads,
        };
        scheduler::run(&mut self.rt, &sj, field)
    }
}

impl std::fmt::Debug for PjrtBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PjrtBackend")
            .field("runtime", &self.rt)
            .field("prefer", &self.prefer)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::perf::Dtype;
    use crate::model::stencil::{Shape, StencilPattern};
    use crate::runtime::manifest::Manifest;

    const SAMPLE: &str = r#"{
      "variants": [
        {
          "name": "direct_box2d_r1_t3_f32_g64x64",
          "file": "direct_box2d_r1_t3_f32_g64x64.hlo.txt",
          "scheme": "direct", "shape": "box", "d": 2, "r": 1, "t": 3,
          "dtype": "float32", "grid": [64, 64], "tile": [32, 32],
          "halo": 3, "k_points": 9, "k_fused": 49, "alpha": 1.8148,
          "sparsity_measured": null, "vmem_bytes": 17328, "n_outer": 1
        }
      ]
    }"#;

    fn backend() -> PjrtBackend {
        // No client needed for capability probing; build via a parsed
        // manifest only when the stub runtime is in play.
        let manifest = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let dir = std::env::temp_dir().join("tc-stencil-pjrt-probe");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let b = PjrtBackend::load(&dir).unwrap();
        assert_eq!(b.runtime().manifest.variants.len(), manifest.variants.len());
        b
    }

    fn job(t: usize, steps: usize, dtype: Dtype) -> Job {
        Job {
            pattern: StencilPattern::new(Shape::Box, 2, 1).unwrap(),
            dtype,
            domain: vec![32, 32],
            steps,
            t,
            temporal: TemporalMode::Sweep,
            weights: vec![1.0 / 9.0; 9],
            threads: 1,
        }
    }

    #[test]
    fn artifact_lookup_matches_key_fields() {
        let b = backend();
        assert!(b.find_artifact(&job(3, 6, Dtype::F32)).is_some());
        assert!(b.find_artifact(&job(2, 6, Dtype::F32)).is_none()); // t
        assert!(b.find_artifact(&job(3, 6, Dtype::F64)).is_none()); // dtype
    }

    #[test]
    fn prefer_scheme_filters() {
        let mut b = backend();
        b.prefer_scheme(Some(Scheme::Flatten));
        assert!(b.find_artifact(&job(3, 6, Dtype::F32)).is_none());
        b.prefer_scheme(Some(Scheme::Direct));
        assert!(b.find_artifact(&job(3, 6, Dtype::F32)).is_some());
    }

    #[test]
    fn supports_requires_whole_launches() {
        let b = backend();
        // steps=4 is not a multiple of t=3
        let err = b.supports(&job(3, 4, Dtype::F32)).unwrap_err();
        assert!(err.contains("steps"), "{err}");
    }

    #[test]
    fn supports_reports_missing_artifact() {
        let b = backend();
        let err = b.supports(&job(5, 5, Dtype::F32)).unwrap_err();
        assert!(err.contains("no AOT artifact"), "{err}");
    }

    #[test]
    fn supports_rejects_temporal_blocking() {
        let b = backend();
        let mut j = job(3, 6, Dtype::F32);
        j.temporal = TemporalMode::Blocked;
        let err = b.supports(&j).unwrap_err();
        assert!(err.contains("temporal"), "{err}");
        // auto is fine: it resolves to the sweep PJRT can execute
        j.temporal = TemporalMode::Auto;
        let _ = b.supports(&j); // may still fail on Runtime::available(), not on temporal
    }
}
